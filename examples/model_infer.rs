//! Real ternary-transformer inference walkthrough (DESIGN.md §2/§6):
//! synthesize a seeded toy checkpoint, run the kernel-path
//! `TernaryTransformer` next to the pure-scalar `ReferenceModel`, show
//! that their logits and greedy tokens are identical, round-trip the
//! checkpoint through the TSARCKP1 container, then serve the same model
//! through the streaming Engine via `runtime::ModelBackend`.
//!
//!   cargo run --release --example model_infer
//!   TSAR_MODEL_SEED=99 TSAR_MAX_NEW=24 cargo run --release --example model_infer
//!
//! Every token printed here is sampled from logits a real BitNet-style
//! forward pass produced on this machine — AVX2 pshufb kernels where
//! the host has them, the portable scalar path elsewhere
//! (`TSAR_NATIVE_FORCE_SCALAR=1` forces it).

use std::sync::mpsc::channel;

use tsar::config::IsaConfig;
use tsar::coordinator::{Engine, GenerationRequest, ServerConfig, TokenEvent};
use tsar::model::{
    Checkpoint, LinearEngine, ReferenceModel, SamplerConfig, TernaryTransformer,
    TransformerConfig,
};
use tsar::runtime::{Backend, ModelBackend, ModelBackendConfig};
use tsar::util::error::Result;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let seed = env_u64("TSAR_MODEL_SEED", 0x75AB);
    let max_new = env_u64("TSAR_MAX_NEW", 12) as usize;

    // 1. A deterministic random-init checkpoint: same (config, seed) →
    //    bit-identical weights on every platform, no weights file needed.
    let config = TransformerConfig::toy();
    let ckpt = Checkpoint::synthesize(config, seed)?;
    println!(
        "== synthesized checkpoint: seed {seed:#x}, {} params (L={} d={} heads={}/{} ffn={} vocab={}) ==",
        ckpt.param_count(),
        config.n_layers,
        config.d_model,
        config.n_heads,
        config.n_kv_heads,
        config.ffn_dim,
        config.vocab
    );

    // 2. Kernel path vs. scalar reference on the same weights.  The two
    //    share only the checkpoint loader, yet the logits are
    //    bit-identical (integer ternary×int8 accumulation + one pinned
    //    f32 evaluation order everywhere else).
    let model = TernaryTransformer::from_checkpoint(
        &ckpt,
        LinearEngine::native(IsaConfig::C2, 1)?,
    )?;
    let reference = ReferenceModel::new(&ckpt)?;
    let prompt = [3i32, 141, 59, 26];
    let kernel_logits = model.forward(&prompt, &mut model.new_kv())?;
    let ref_logits = reference.logits(&prompt)?;
    let max_abs_diff = kernel_logits
        .iter()
        .zip(&ref_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "\nkernel engine {} vs scalar reference on prompt {prompt:?}:",
        model.engine().name()
    );
    println!(
        "  max |logit diff| = {max_abs_diff:e}  (bit-identical: {})",
        kernel_logits == ref_logits
    );

    // 3. Checkpoint container round-trip.
    let bytes = ckpt.to_bytes();
    let back = Checkpoint::parse(&bytes)?;
    println!("\nTSARCKP1 container: {} bytes, round-trip exact: {}", bytes.len(), back == ckpt);

    // 4. Serve the model: every streamed token comes out of the real
    //    forward pass, per-layer KV caches threaded between steps.
    let backend = ModelBackend::new(
        &ckpt,
        LinearEngine::native(IsaConfig::C2, 1)?,
        ModelBackendConfig {
            prefill_len: 16,
            max_seq: 16 + max_new + 8,
            sampler: SamplerConfig::greedy(),
        },
    )?;
    println!("\n== serving {} ==", backend.describe());
    let expect = backend.generate(&prompt, max_new)?;
    let expect_ref =
        reference.generate_until(&prompt, max_new, &SamplerConfig::greedy(), &[])?;
    assert_eq!(expect, expect_ref, "backend and reference disagreed on greedy tokens");

    let (rec_tx, rec_rx) = channel();
    let handle = Engine::start_with_sink(
        backend,
        ServerConfig { max_batch: 2, kv_slots: 2, workers: 2 },
        Some(rec_tx),
    )?;
    let ticket = handle.submit(GenerationRequest::new(prompt.to_vec(), max_new));
    print!("  streamed:");
    let mut streamed = Vec::new();
    while let Some(ev) = ticket.recv() {
        match ev {
            TokenEvent::Prefilled { token } | TokenEvent::Token { token, .. } => {
                streamed.push(token);
                print!(" {token}");
            }
            TokenEvent::Retired(res) => {
                println!("  [{} | {:.1} tok/s]", res.finish.label(), res.decode_tokens_per_s());
            }
            TokenEvent::Cancelled(res) | TokenEvent::Failed(res) => {
                println!("  [{}]", res.finish.label());
            }
        }
    }
    let report = handle.shutdown()?;
    assert_eq!(streamed, expect, "engine stream diverged from Backend::generate");
    println!("  stream matches Backend::generate and the scalar reference exactly");
    drop(rec_rx);
    report.print();
    Ok(())
}
