//! End-to-end serving driver (DESIGN.md §6): drive a Poisson stream of
//! requests through the session-based streaming Engine (`TSAR_WORKERS`
//! lanes, batched decode rounds per lane): every submission returns a
//! `Ticket` immediately, tokens stream over the ticket's event channel
//! as decode rounds land, and the run ends with the merged serve
//! report plus the per-request metrics records.
//!
//! Default build — the simulator-costed backend (no dependencies, no
//! artifacts): BitNet shapes + §III-D kernel plans through the timing
//! engine, so the reported latencies are the paper-faithful model:
//!
//!   cargo run --release --example serve_bitnet
//!   TSAR_MODEL=BitNet-7B cargo run --release --example serve_bitnet
//!
//! PJRT build — load the AOT-compiled BitNet-style model (built by
//! `make artifacts`: JAX + Pallas LUT-GEMV kernel lowered to HLO text)
//! and execute it for real.  This is the proof that all three layers
//! compose: the Pallas kernel (L1) inside the JAX transformer (L2)
//! executed by the Rust coordinator (L3) over PJRT, with Python nowhere
//! on the request path:
//!
//!   make artifacts && cargo run --release --features pjrt --example serve_bitnet -- artifacts
//!
//! Both paths drive the same generic loop over `runtime::Backend`.
//!
//! HTTP mode — put the zero-dependency HTTP front-end over the engine
//! and exercise it with raw `TcpStream` clients against our own
//! listener (one thread per request, chunked NDJSON token streams),
//! then scrape `GET /metrics` before shutting down:
//!
//!   TSAR_HTTP=1 cargo run --release --example serve_bitnet          # 127.0.0.1:0
//!   TSAR_HTTP=127.0.0.1:8080 cargo run --release --example serve_bitnet

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use tsar::config::platforms::Platform;
use tsar::coordinator::{
    Engine, GenerationRequest, HttpConfig, HttpServer, PromAggregator, RequestRecord,
    ServerConfig, Ticket, TokenEvent,
};
use tsar::runtime::{Backend, SimBackend, SimBackendConfig};
use tsar::util::error::Result;
use tsar::util::rng::Rng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    // Read the knobs once; max_new feeds both the request budget and
    // the sim backend's KV window, so it must be one value.
    let n_requests = env_usize("TSAR_REQUESTS", 12);
    let max_new = env_usize("TSAR_MAX_NEW", 16);
    let workers = env_usize("TSAR_WORKERS", 2);
    let dir = std::env::args().nth(1);

    if let Ok(addr) = std::env::var("TSAR_HTTP") {
        return http_main(&addr, n_requests, max_new, workers);
    }

    #[cfg(feature = "pjrt")]
    if let Some(d) = dir.as_deref() {
        return pjrt_main(d, n_requests, max_new, workers);
    }

    if let Some(d) = dir.as_deref() {
        println!(
            "note: ignoring artifacts dir {d:?} — this build has no PJRT runtime \
             (rebuild with --features pjrt); serving on the SimBackend instead"
        );
    }
    sim_main(n_requests, max_new, workers)
}

/// HTTP mode: the same SimBackend engine behind the zero-dependency
/// HTTP front-end, self-driven by raw `TcpStream` clients so the
/// walkthrough needs no second terminal.
fn http_main(addr_env: &str, n_requests: usize, max_new: usize, workers: usize) -> Result<()> {
    let addr = if addr_env == "1" { "127.0.0.1:0" } else { addr_env };
    let model = std::env::var("TSAR_MODEL").unwrap_or_else(|_| "BitNet-2B-4T".into());
    let backend = SimBackend::by_name(
        &model,
        Platform::workstation(),
        SimBackendConfig {
            prefill_len: 32,
            max_seq: 32 + max_new + 8,
            ..SimBackendConfig::default()
        },
    )?;
    println!("== T-SAR HTTP serving ({}) ==", backend.describe());
    let vocab = backend.config().vocab as u64;

    // Engine records feed the Prometheus aggregator behind /metrics.
    let (rec_tx, rec_rx) = channel::<RequestRecord>();
    let aggregator = PromAggregator::spawn(rec_rx);
    let handle = Arc::new(Engine::start_with_sink(
        backend,
        ServerConfig { max_batch: 4, kv_slots: 4, workers },
        Some(rec_tx),
    )?);
    let http = HttpServer::start(
        addr,
        Arc::clone(&handle),
        aggregator.counters(),
        HttpConfig::default(),
    )?;
    let bound = http.local_addr();
    println!("listening on {bound}: POST /v1/generate, GET /metrics, GET /healthz\n");

    // One client thread per request, each a plain TcpStream speaking
    // HTTP/1.1 — exactly what curl -N does.
    let mut rng = Rng::new(7);
    let clients: Vec<_> = (0..n_requests)
        .map(|id| {
            let plen = 3 + rng.below(13) as usize;
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
            std::thread::spawn(move || http_generate(bound, id, &prompt, max_new))
        })
        .collect();
    for client in clients {
        client.join().expect("client thread panicked")?;
    }

    println!("\n== GET /metrics (scrape) ==");
    let scrape = http_get(bound, "/metrics")?;
    for line in scrape.lines().filter(|l| !l.starts_with('#')) {
        println!("  {line}");
    }

    http.stop();
    let handle = Arc::try_unwrap(handle)
        .map_err(|_| tsar::err!("HTTP workers still hold the engine"))?;
    let report = handle.shutdown()?;
    println!("\n== serve report ==");
    report.print();
    println!("prometheus aggregator observed {} record(s)", aggregator.finish());
    Ok(())
}

/// POST one generation and consume its chunked NDJSON stream.
fn http_generate(addr: SocketAddr, id: usize, prompt: &[i32], max_new: usize) -> Result<()> {
    let body = format!("{{\"prompt\":{prompt:?},\"max_new_tokens\":{max_new}}}");
    let mut conn = TcpStream::connect(addr)?;
    write!(
        conn,
        "POST /v1/generate HTTP/1.1\r\nHost: tsar\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )?;
    let mut response = String::new();
    conn.read_to_string(&mut response)?;
    tsar::ensure!(response.starts_with("HTTP/1.1 200"), "client {id}: got {response:?}");
    let tokens = response.matches("\"event\":\"token\"").count()
        + response.matches("\"event\":\"prefilled\"").count();
    let finish = response
        .rsplit("\"finish\":\"")
        .next()
        .and_then(|rest| rest.split('"').next())
        .unwrap_or("?");
    println!("  client {id:>2}: {tokens:>2} tokens streamed, finish {finish}");
    Ok(())
}

/// One plain GET, returning the response body.
fn http_get(addr: SocketAddr, path: &str) -> Result<String> {
    let mut conn = TcpStream::connect(addr)?;
    write!(conn, "GET {path} HTTP/1.1\r\nHost: tsar\r\nConnection: close\r\n\r\n")?;
    let mut response = String::new();
    conn.read_to_string(&mut response)?;
    let body = response
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok(body)
}

/// Default path: the simulator-costed backend.
fn sim_main(n_requests: usize, max_new: usize, workers: usize) -> Result<()> {
    let model = std::env::var("TSAR_MODEL").unwrap_or_else(|_| "BitNet-2B-4T".into());
    let backend = SimBackend::by_name(
        &model,
        Platform::workstation(),
        SimBackendConfig {
            prefill_len: 32,
            max_seq: 32 + max_new + 8,
            ..SimBackendConfig::default()
        },
    )?;
    println!("== T-SAR end-to-end serving ({}) ==", backend.describe());
    println!(
        "decode plan: {:.2} simulated tok/s at N=1; prefill pass {:.1} ms",
        1.0 / backend.decode_plan().pass_seconds(),
        backend.prefill_plan().pass_seconds() * 1e3
    );
    drive(backend, n_requests, max_new, workers)
}

/// PJRT path: load the AOT artifacts, check the Python golden, serve.
#[cfg(feature = "pjrt")]
fn pjrt_main(dir: &str, n_requests: usize, max_new: usize, workers: usize) -> Result<()> {
    let variant = std::env::var("TSAR_VARIANT").unwrap_or_else(|_| "tsar".into());
    println!("== T-SAR end-to-end serving (variant: {variant}) ==");
    let t0 = std::time::Instant::now();
    let rt = tsar::runtime::ModelRuntime::load(dir, &variant)?;
    println!(
        "loaded {} ({} params tensors, d={}, L={}, vocab={}) in {:.2}s",
        rt.manifest.config_name,
        rt.manifest.params.len(),
        rt.manifest.config.d_model,
        rt.manifest.config.n_layers,
        rt.manifest.config.vocab,
        t0.elapsed().as_secs_f64()
    );

    // Sanity: the runtime must reproduce the Python golden (ref variant
    // exactly; tsar variant is bit-identical by construction).
    let g = rt.manifest.golden.clone();
    let check = rt.generate(&g.prompt, 4.min(g.tokens.len()))?;
    assert_eq!(
        check,
        g.tokens[..check.len()].to_vec(),
        "runtime does not reproduce the AOT golden"
    );
    println!("golden check passed: first {} tokens match Python", check.len());
    drive(rt, n_requests, max_new, workers)
}

/// The generic serving loop over the streaming Engine API: the main
/// thread plays the open-loop client (Poisson arrivals, mixed prompt
/// lengths, one `submit` per request — each returning its `Ticket`
/// before any model work runs), a collector thread drains every
/// ticket's event stream and prints the completion lines, a metrics
/// sink drains the streamed per-request records, and `shutdown`
/// returns the merged report.
fn drive<B: Backend + Send + Sync + 'static>(
    backend: B,
    n_requests: usize,
    max_new: usize,
    workers: usize,
) -> Result<()> {
    let vocab = backend.config().vocab as u64;
    let window = backend.config().prefill_len;
    let (rec_tx, rec_rx) = channel::<RequestRecord>();
    let handle = Engine::start_with_sink(
        backend,
        ServerConfig { max_batch: 4, kv_slots: 4, workers },
        Some(rec_tx),
    )?;

    // The scrape-endpoint stand-in: drain the request-record stream as
    // it arrives (one record per retired request, any lane).  See
    // `coordinator::Exporter` (and `tsar-cli serve --metrics`) for the
    // JSONL file/stdout endpoint over this same channel.
    let sink = std::thread::spawn(move || {
        let mut records: Vec<RequestRecord> = Vec::new();
        while let Ok(rec) = rec_rx.recv() {
            records.push(rec);
        }
        records
    });

    // Collector: consume each ticket's live event stream in submission
    // order, counting streamed tokens and printing the terminal result.
    let (ticket_tx, ticket_rx) = channel::<Ticket>();
    let collector = std::thread::spawn(move || {
        let mut done = 0usize;
        while let Ok(ticket) = ticket_rx.recv() {
            let id = ticket.id();
            let mut streamed = 0usize;
            while let Some(ev) = ticket.recv() {
                match ev {
                    TokenEvent::Prefilled { .. } | TokenEvent::Token { .. } => streamed += 1,
                    TokenEvent::Retired(res)
                    | TokenEvent::Cancelled(res)
                    | TokenEvent::Failed(res) => {
                        assert_eq!(streamed, res.tokens.len(), "stream/result mismatch");
                        done += 1;
                        println!(
                            "  req {:>2}: {:>2} tokens ({}) | queue {:>6.1} ms | \
                             prefill {:>6.1} ms | decode {:>6.1} tok/s",
                            id,
                            res.tokens.len(),
                            res.finish.label(),
                            res.queue_s * 1e3,
                            res.prefill_s * 1e3,
                            res.decode_tokens_per_s()
                        );
                    }
                }
            }
        }
        done
    });

    // Open-loop client on the main thread: Poisson arrivals.
    let lambda_per_s = 4.0;
    let mut rng = Rng::new(7);
    for _ in 0..n_requests {
        let wait = rng.exp(lambda_per_s);
        std::thread::sleep(Duration::from_secs_f64(wait.min(0.5)));
        let plen = 3 + rng.below((window as u64 / 2).max(1)) as usize;
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
        let ticket = handle.submit(GenerationRequest::new(prompt, max_new));
        if ticket_tx.send(ticket).is_err() {
            break;
        }
    }
    drop(ticket_tx); // no more sessions: the collector drains and exits

    // Graceful shutdown: drains every in-flight sequence, joins the
    // lanes, merges the per-lane clocks.  Dropping the handle's record
    // sender also closes the metrics sink.
    let report = handle.shutdown()?;
    let done = collector.join().unwrap();
    assert_eq!(done, n_requests);
    let records = sink.join().unwrap();
    assert_eq!(records.len(), n_requests);

    println!("\n== serve report ==");
    report.print();
    println!("\n== metrics sink ({} records streamed) ==", records.len());
    for rec in records.iter().take(3) {
        println!(
            "  req {:>2} via lane {}: queue {:>6.1} ms  prefill {:>6.1} ms  \
             decode {:>7.1} ms  finish {}  plan [{}]",
            rec.id,
            rec.lane.map_or_else(|| "-".into(), |l| l.to_string()),
            rec.queue_s * 1e3,
            rec.prefill_s * 1e3,
            rec.decode_s * 1e3,
            rec.finish.label(),
            rec.plan.as_deref().unwrap_or("n/a")
        );
    }
    if records.len() > 3 {
        println!("  ... {} more", records.len() - 3);
    }
    Ok(())
}
