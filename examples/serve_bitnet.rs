//! End-to-end serving driver (DESIGN.md §6): serve a Poisson stream of
//! batched requests through the sharded coordinator (`TSAR_WORKERS`
//! lanes, batched decode rounds per lane) and report
//! latency/throughput, including the per-lane breakdown and the
//! streamed request-level metrics records.
//!
//! Default build — the simulator-costed backend (no dependencies, no
//! artifacts): BitNet shapes + §III-D kernel plans through the timing
//! engine, so the reported latencies are the paper-faithful model:
//!
//!   cargo run --release --example serve_bitnet
//!   TSAR_MODEL=BitNet-7B cargo run --release --example serve_bitnet
//!
//! PJRT build — load the AOT-compiled BitNet-style model (built by
//! `make artifacts`: JAX + Pallas LUT-GEMV kernel lowered to HLO text)
//! and execute it for real.  This is the proof that all three layers
//! compose: the Pallas kernel (L1) inside the JAX transformer (L2)
//! executed by the Rust coordinator (L3) over PJRT, with Python nowhere
//! on the request path:
//!
//!   make artifacts && cargo run --release --features pjrt --example serve_bitnet -- artifacts
//!
//! Both paths drive the same generic loop over `runtime::Backend`.

use std::sync::mpsc::channel;
use std::time::Duration;

use tsar::config::platforms::Platform;
use tsar::coordinator::{Request, RequestRecord, RequestResult, Server, ServerConfig};
use tsar::runtime::{Backend, SimBackend, SimBackendConfig};
use tsar::util::error::Result;
use tsar::util::rng::Rng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    // Read the knobs once; max_new feeds both the request budget and
    // the sim backend's KV window, so it must be one value.
    let n_requests = env_usize("TSAR_REQUESTS", 12);
    let max_new = env_usize("TSAR_MAX_NEW", 16);
    let workers = env_usize("TSAR_WORKERS", 2);
    let dir = std::env::args().nth(1);

    #[cfg(feature = "pjrt")]
    if let Some(d) = dir.as_deref() {
        return pjrt_main(d, n_requests, max_new, workers);
    }

    if let Some(d) = dir.as_deref() {
        println!(
            "note: ignoring artifacts dir {d:?} — this build has no PJRT runtime \
             (rebuild with --features pjrt); serving on the SimBackend instead"
        );
    }
    sim_main(n_requests, max_new, workers)
}

/// Default path: the simulator-costed backend.
fn sim_main(n_requests: usize, max_new: usize, workers: usize) -> Result<()> {
    let model = std::env::var("TSAR_MODEL").unwrap_or_else(|_| "BitNet-2B-4T".into());
    let backend = SimBackend::by_name(
        &model,
        Platform::workstation(),
        SimBackendConfig {
            prefill_len: 32,
            max_seq: 32 + max_new + 8,
            ..SimBackendConfig::default()
        },
    )?;
    println!("== T-SAR end-to-end serving ({}) ==", backend.describe());
    println!(
        "decode plan: {:.2} simulated tok/s at N=1; prefill pass {:.1} ms",
        1.0 / backend.decode_plan().pass_seconds(),
        backend.prefill_plan().pass_seconds() * 1e3
    );
    drive(backend, n_requests, max_new, workers)
}

/// PJRT path: load the AOT artifacts, check the Python golden, serve.
#[cfg(feature = "pjrt")]
fn pjrt_main(dir: &str, n_requests: usize, max_new: usize, workers: usize) -> Result<()> {
    let variant = std::env::var("TSAR_VARIANT").unwrap_or_else(|_| "tsar".into());
    println!("== T-SAR end-to-end serving (variant: {variant}) ==");
    let t0 = std::time::Instant::now();
    let rt = tsar::runtime::ModelRuntime::load(dir, &variant)?;
    println!(
        "loaded {} ({} params tensors, d={}, L={}, vocab={}) in {:.2}s",
        rt.manifest.config_name,
        rt.manifest.params.len(),
        rt.manifest.config.d_model,
        rt.manifest.config.n_layers,
        rt.manifest.config.vocab,
        t0.elapsed().as_secs_f64()
    );

    // Sanity: the runtime must reproduce the Python golden (ref variant
    // exactly; tsar variant is bit-identical by construction).
    let g = rt.manifest.golden.clone();
    let check = rt.generate(&g.prompt, 4.min(g.tokens.len()))?;
    assert_eq!(
        check,
        g.tokens[..check.len()].to_vec(),
        "runtime does not reproduce the AOT golden"
    );
    println!("golden check passed: first {} tokens match Python", check.len());
    drive(rt, n_requests, max_new, workers)
}

/// The generic serving loop: Poisson arrivals (open-loop) with mixed
/// prompt lengths, a collector thread printing completions, a metrics
/// sink draining the streamed per-request records, and the sharded
/// engine (dispatcher + worker lanes) on the main thread.
fn drive<B: Backend + Sync>(
    backend: B,
    n_requests: usize,
    max_new: usize,
    workers: usize,
) -> Result<()> {
    let vocab = backend.config().vocab as u64;
    let window = backend.config().prefill_len;
    let (rec_tx, rec_rx) = channel::<RequestRecord>();
    let server = Server::new(
        backend,
        ServerConfig { max_batch: 4, kv_slots: 4, workers },
    )?
    .with_metrics_sink(rec_tx);

    let lambda_per_s = 4.0;
    let (req_tx, req_rx) = channel::<Request>();
    let (res_tx, res_rx) = channel::<RequestResult>();

    // The scrape-endpoint stand-in: drain the request-record stream as
    // it arrives (one record per retired request, any lane).
    let sink = std::thread::spawn(move || {
        let mut records: Vec<RequestRecord> = Vec::new();
        while let Ok(rec) = rec_rx.recv() {
            records.push(rec);
        }
        records
    });

    let producer = std::thread::spawn(move || {
        let mut rng_p = Rng::new(7);
        for id in 0..n_requests as u64 {
            let wait = rng_p.exp(lambda_per_s);
            std::thread::sleep(Duration::from_secs_f64(wait.min(0.5)));
            let plen = 3 + rng_p.below((window as u64 / 2).max(1)) as usize;
            let prompt: Vec<i32> =
                (0..plen).map(|_| rng_p.below(vocab) as i32).collect();
            if req_tx.send(Request::new(id, prompt, max_new)).is_err() {
                break;
            }
        }
    });

    let collector = std::thread::spawn(move || {
        let mut done = 0usize;
        while let Ok(res) = res_rx.recv() {
            done += 1;
            println!(
                "  req {:>2}: {:>2} tokens | queue {:>6.1} ms | prefill {:>6.1} ms | decode {:>6.1} tok/s",
                res.id,
                res.tokens.len(),
                res.queue_s * 1e3,
                res.prefill_s * 1e3,
                res.decode_tokens_per_s()
            );
        }
        done
    });

    let report = server.run(req_rx, res_tx)?;
    producer.join().unwrap();
    let done = collector.join().unwrap();
    assert_eq!(done, n_requests);

    // Drop the server (and with it the sink's last sender) so the
    // record stream closes and the sink thread drains out.
    drop(server);
    let records = sink.join().unwrap();
    assert_eq!(records.len(), n_requests);

    println!("\n== serve report ==");
    report.print();
    println!("\n== metrics sink ({} records streamed) ==", records.len());
    for rec in records.iter().take(3) {
        println!(
            "  req {:>2} via lane {}: queue {:>6.1} ms  prefill {:>6.1} ms  \
             decode {:>7.1} ms  plan [{}]",
            rec.id,
            rec.lane,
            rec.queue_s * 1e3,
            rec.prefill_s * 1e3,
            rec.decode_s * 1e3,
            rec.plan.as_deref().unwrap_or("n/a")
        );
    }
    if records.len() > 3 {
        println!("  ... {} more", records.len() - 3);
    }
    Ok(())
}
