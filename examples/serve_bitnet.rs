//! End-to-end serving driver (DESIGN.md §6): load the AOT-compiled
//! BitNet-style model (built by `make artifacts`: JAX + Pallas LUT-GEMV
//! kernel lowered to HLO text), serve a Poisson stream of batched
//! requests through the coordinator, and report latency/throughput.
//!
//!   make artifacts && cargo run --release --example serve_bitnet
//!
//! This is the proof that all three layers compose: the Pallas kernel
//! (L1) inside the JAX transformer (L2) executed by the Rust
//! coordinator (L3) over PJRT, with Python nowhere on the request path.

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use tsar::coordinator::{Request, Server, ServerConfig};
use tsar::runtime::ModelRuntime;
use tsar::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let variant = std::env::var("TSAR_VARIANT").unwrap_or_else(|_| "tsar".into());
    let n_requests: usize = std::env::var("TSAR_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12);
    let max_new: usize = std::env::var("TSAR_MAX_NEW")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);

    println!("== T-SAR end-to-end serving (variant: {variant}) ==");
    let t0 = Instant::now();
    let rt = ModelRuntime::load(&dir, &variant)?;
    println!(
        "loaded {} ({} params tensors, d={}, L={}, vocab={}) in {:.2}s",
        rt.manifest.config_name,
        rt.manifest.params.len(),
        rt.manifest.config.d_model,
        rt.manifest.config.n_layers,
        rt.manifest.config.vocab,
        t0.elapsed().as_secs_f64()
    );

    // Sanity: the runtime must reproduce the Python golden (ref variant
    // exactly; tsar variant is bit-identical by construction).
    let g = rt.manifest.golden.clone();
    let check = rt.generate(&g.prompt, 4.min(g.tokens.len()))?;
    assert_eq!(
        check,
        g.tokens[..check.len()].to_vec(),
        "runtime does not reproduce the AOT golden"
    );
    println!("golden check passed: first {} tokens match Python", check.len());

    let vocab = rt.manifest.config.vocab as u64;
    let window = rt.manifest.config.prefill_len;
    let server = Server::new(rt, ServerConfig { max_batch: 4, kv_slots: 4 });

    // Poisson arrivals (open-loop) with mixed prompt lengths.
    let mut rng = Rng::new(123);
    let lambda_per_s = 4.0;
    let (req_tx, req_rx) = channel::<Request>();
    let (res_tx, res_rx) = channel::<tsar::coordinator::RequestResult>();

    let producer = std::thread::spawn(move || {
        let mut rng_p = Rng::new(7);
        for id in 0..n_requests as u64 {
            let wait = rng_p.exp(lambda_per_s);
            std::thread::sleep(Duration::from_secs_f64(wait.min(0.5)));
            let plen = 3 + rng_p.below(window as u64 / 2) as usize;
            let prompt: Vec<i32> =
                (0..plen).map(|_| rng_p.below(vocab) as i32).collect();
            if req_tx.send(Request::new(id, prompt, max_new)).is_err() {
                break;
            }
        }
    });

    let collector = std::thread::spawn(move || {
        let mut done = 0usize;
        while let Ok(res) = res_rx.recv() {
            done += 1;
            println!(
                "  req {:>2}: {:>2} tokens | queue {:>6.1} ms | prefill {:>6.1} ms | decode {:>6.1} tok/s",
                res.id,
                res.tokens.len(),
                res.queue_s * 1e3,
                res.prefill_s * 1e3,
                res.decode_tokens_per_s()
            );
        }
        done
    });

    let report = server.run(req_rx, res_tx)?;
    producer.join().unwrap();
    let done = collector.join().unwrap();
    assert_eq!(done, n_requests);

    println!("\n== serve report ==");
    report.print();
    let _ = rng.next_u64();
    println!("\nrecorded in EXPERIMENTS.md §End-to-end.");
    Ok(())
}
