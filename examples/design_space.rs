//! Design-space exploration: sweep the T-SAR ISA parameterization and
//! kernel dataflows across layer shapes and platforms — the study behind
//! §III-D's adaptive selection and the c ∈ {2,4} configuration choice.
//!
//!   cargo run --release --example design_space [model]

use tsar::config::platforms::{Platform, ALL_PLATFORMS};
use tsar::config::IsaConfig;
use tsar::coordinator::select_plan;
use tsar::kernels::{Dataflow, TernaryKernel, TsarKernel};
use tsar::model::zoo;
use tsar::model::Workload;
use tsar::sim::simulate;
use tsar::util::table::Table;

fn main() {
    let model = std::env::args().nth(1).unwrap_or_else(|| "BitNet-2B-4T".into());
    let spec = zoo::by_name(&model).unwrap_or_else(|| {
        eprintln!("unknown model {model}; use `tsar-cli models`");
        std::process::exit(2)
    });

    println!("== design space for {} ==", spec.name);

    // 1. Per-shape kernel landscape (decode + prefill on workstation).
    let plat = Platform::workstation();
    for n in [1usize, 128] {
        let wl = Workload::new(spec, n);
        println!("\n-- N={n} ({}) --", if n == 1 { "decode" } else { "prefill" });
        let mut t = Table::new(vec![
            "site", "shape", "AP-min c2", "AP-max c2", "OP c2", "AP-max c4", "OP c4", "winner",
        ]);
        for op in &wl.ops {
            let mut cells = vec![
                op.site.to_string(),
                format!("{}x{}x{}", op.shape.n, op.shape.k, op.shape.m),
            ];
            let variants = [
                TsarKernel::new(IsaConfig::C2, Dataflow::ApMin),
                TsarKernel::new(IsaConfig::C2, Dataflow::ApMax),
                TsarKernel::new(IsaConfig::C2, Dataflow::Op),
                TsarKernel::new(IsaConfig::C4, Dataflow::ApMax),
                TsarKernel::new(IsaConfig::C4, Dataflow::Op),
            ];
            let times: Vec<f64> = variants
                .iter()
                .map(|k| {
                    simulate(&k.profile(op.shape, &plat, plat.threads), &plat, plat.threads)
                        .seconds
                })
                .collect();
            let best = times
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            for (i, s) in times.iter().enumerate() {
                let mark = if i == best { "*" } else { " " };
                cells.push(format!("{:.3}ms{mark}", s * 1e3));
            }
            cells.push(variants[best].name());
            t.row(cells);
        }
        t.print();
    }

    // 2. The adaptive plan per platform (what the coordinator loads).
    println!("\n-- adaptive plans (decode) --");
    for kind in ALL_PLATFORMS {
        let plat = Platform::by_kind(kind);
        let plan = select_plan(spec, &plat, 1, plat.threads);
        println!(
            "{:<12} {:>8.2} tok/s | plan:",
            plat.kind.name(),
            1.0 / plan.pass_seconds()
        );
        for l in &plan.layers {
            println!("    {}", l.describe());
        }
    }

    // 3. Register-budget view of the ISA configs (why c=2 and c=4).
    println!("\n-- ISA configuration register budgets --");
    for cfg in [IsaConfig::C2, IsaConfig::C4] {
        println!(
            "{:<24} LUT pair entries/block {:>3}, result {:>4} bits = {} YMM regs",
            cfg.name(),
            cfg.lut_entries_per_block(),
            cfg.tlut_result_bits(),
            cfg.tlut_result_regs()
        );
    }
}
