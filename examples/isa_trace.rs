//! ISA-level walkthrough of the paper's Fig. 6 worked example: encode the
//! TLUT_2×4 / TGEMV_8×16 instructions to VEX3 bytes, execute them on the
//! modeled register file, and dump the register contents at each step —
//! the "hand-written assembly with byte-pattern encodings" verification
//! of §IV-A, reproduced as a runnable program.
//!
//!   cargo run --release --example isa_trace

use tsar::config::IsaConfig;
use tsar::quant::encode_indices;
use tsar::simd::RegFile;
use tsar::tsar::encoding::{fig6_examples, Instruction};
use tsar::tsar::exec::{scalar_dot, tgemv, tlut, TgemvWeights};
use tsar::tsar::lut_lane;

fn main() {
    let cfg = IsaConfig::C2; // TLUT_2x4 + TGEMV_8x16, Fig. 6(a)
    println!("== T-SAR ISA trace: {} ==\n", cfg.name());

    // ---- instruction encodings (Fig. 6(d)) --------------------------------
    for insn in fig6_examples() {
        let bytes = insn.encode();
        let hex: Vec<String> = bytes.iter().map(|b| format!("{b:02X}")).collect();
        println!(
            "{:?} cfg={} dst=YMM{}{} src=YMM{}  ->  {}",
            insn.op,
            insn.cfg_sel,
            insn.dst,
            if insn.op == tsar::tsar::encoding::Opcode::Tlut {
                format!(":{}", insn.dst + 1) // register pair
            } else {
                String::new()
            },
            insn.src,
            hex.join(" ")
        );
        assert_eq!(Instruction::decode(&bytes).unwrap(), insn);
    }

    // ---- TLUT_2x4: build LUTs from 8 activations ---------------------------
    let acts: [i8; 8] = [3, -1, 4, 1, -5, 9, -2, 6];
    println!("\nactivations (k = c*s = 8): {acts:?}");
    let mut rf = RegFile::new();
    tlut(&mut rf, &cfg, 8, &acts); // dst = YMM8:9 (the paper's example)

    println!("\nTLUT_2x4 -> YMM8:9 (dense | sparse entries per block):");
    for b in 0..cfg.s {
        let block = &acts[b * cfg.c..(b + 1) * cfg.c];
        let lanes = rf.read_pair(8);
        let dense: Vec<i16> =
            (0..4).map(|p| lanes[lut_lane(&cfg, b, false, p)]).collect();
        let sparse: Vec<i16> =
            (0..4).map(|p| lanes[lut_lane(&cfg, b, true, p)]).collect();
        println!("  block {b} {block:?}: dense {dense:?} | sparse {sparse:?}");
    }

    // ---- TGEMV_8x16: one (1,8)x(8,16) GEMV ---------------------------------
    let mut w = vec![0i8; cfg.m * cfg.k];
    for j in 0..cfg.m {
        for x in 0..cfg.k {
            w[j * cfg.k + x] = match (j + 2 * x) % 3 {
                0 => 1,
                1 => 0,
                _ => -1,
            };
        }
    }
    let enc = encode_indices(&w, cfg.m, cfg.k, cfg.c);
    let wop = TgemvWeights::new(&cfg, enc.wd, enc.ws);
    let mut acc = vec![0i32; cfg.m];
    tgemv(&rf, &cfg, 8, &wop, &mut acc);

    println!("\nTGEMV_8x16 accumulators (vs scalar dot):");
    for j in 0..cfg.m {
        let want = scalar_dot(&w[j * cfg.k..(j + 1) * cfg.k], &acts);
        let mark = if acc[j] == want { "ok" } else { "MISMATCH" };
        println!("  y[{j:>2}] = {:>5}  (scalar {:>5})  {mark}", acc[j], want);
        assert_eq!(acc[j], want);
    }

    // ---- µ-op accounting (§III-C) ------------------------------------------
    println!(
        "\nu-ops: TLUT_2x4 = {} (paper: 2), TGEMV_8x16 = {} (paper: 4)",
        tsar::tsar::uops::tlut_uops(&cfg),
        tsar::tsar::uops::tgemv_uops(&cfg)
    );
    println!("\nall ISA-level checks passed.");
}
