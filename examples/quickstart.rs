//! Quickstart: the T-SAR public API in one file.
//!
//!   cargo run --release --example quickstart
//!
//! Walks the whole stack bottom-up: quantize a float matrix to ternary,
//! encode it for the T-SAR ISA, run a bit-exact LUT GEMV through the
//! modeled TLUT/TGEMV instructions, then ask the simulator what the same
//! operation costs on the paper's three platforms and how that compares
//! to the BitNet.cpp TL-2 baseline.

use tsar::config::platforms::{Platform, ALL_PLATFORMS};
use tsar::config::IsaConfig;
use tsar::kernels::{scalar_gemm, Dataflow, TernaryKernel, Tl2Kernel, TsarKernel};
use tsar::quant::{absmax_quantize, absmean_ternarize};
use tsar::sim::{simulate, GemmShape};
use tsar::util::rng::Rng;

fn main() {
    // 1. A "layer": float weights (M x K) and one activation vector.
    let (k, m) = (256usize, 128usize);
    let mut rng = Rng::new(42);
    let w_f32: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32 * 0.05).collect();
    let x_f32: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();

    // 2. Quantize: absmean ternary weights, absmax int8 activations.
    let (w_t, w_scale) = absmean_ternarize(&w_f32);
    let (x_q, x_scale) = absmax_quantize(&x_f32);
    let zeros = w_t.iter().filter(|&&v| v == 0).count();
    println!(
        "ternarized {}x{} weights: scale {:.4}, {:.0}% zeros",
        m,
        k,
        w_scale,
        100.0 * zeros as f64 / w_t.len() as f64
    );

    // 3. Run the T-SAR LUT GEMV through the modeled ISA (bit-exact).
    let shape = GemmShape::new(1, k, m);
    let kernel = TsarKernel::new(IsaConfig::C2, Dataflow::Op);
    let y_int = kernel.run(&x_q, &w_t, shape);
    assert_eq!(y_int, scalar_gemm(&x_q, &w_t, shape), "bit-exact vs scalar");
    // Dequantize the first few outputs.
    let y: Vec<f32> = y_int
        .iter()
        .take(4)
        .map(|&v| v as f32 * w_scale / x_scale)
        .collect();
    println!("first outputs (dequantized): {y:?}");

    // 4. What does this cost on real platforms, and what would TL-2 pay?
    println!("\nsimulated cost of a 1x{k}x{m} BitLinear GEMV:");
    for kind in ALL_PLATFORMS {
        let plat = Platform::by_kind(kind);
        let t = plat.threads;
        let r_tsar = simulate(&kernel.profile(shape, &plat, t), &plat, t);
        let tl2 = Tl2Kernel::new();
        let r_tl2 = simulate(&tl2.profile(shape, &plat, t), &plat, t);
        println!(
            "  {:<12} T-SAR {:>8.2} us | TL-2 {:>8.2} us | speedup {:>5.1}x | request volume {:>6.0} KB vs {:>6.0} KB",
            plat.kind.name(),
            r_tsar.seconds * 1e6,
            r_tl2.seconds * 1e6,
            r_tl2.seconds / r_tsar.seconds,
            r_tsar.request_bytes / 1e3,
            r_tl2.request_bytes / 1e3,
        );
    }

    println!("\nnext steps:");
    println!("  tsar-cli report all        # regenerate every paper table/figure");
    println!("  cargo run --release --example serve_bitnet   # end-to-end serving");
}
