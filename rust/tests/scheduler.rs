//! Integration tests of the shared-admission-queue scheduler
//! (`coordinator::scheduler`, DESIGN.md §3): cross-lane work stealing
//! beats the old static round-robin sharding on skewed workloads,
//! continuous-batching mid-flight joins preserve the `pos ==
//! cache.len()` KV contract and per-request tokens, preloaded runs are
//! deterministic across repetitions, and admission backpressure sheds
//! with accounting that matches the Prometheus surface.

use std::collections::BTreeMap;
use std::sync::mpsc::channel;
use std::time::Duration;

use tsar::config::platforms::Platform;
use tsar::coordinator::{
    Engine, FinishReason, GenerationRequest, PromAggregator, Request, RequestRecord,
    RequestResult, Server, ServerConfig, TokenEvent,
};
use tsar::runtime::{
    Backend, BatchItem, ModelConfig, SimBackend, SimBackendConfig, SimKvCache, Step,
};
use tsar::util::error::Result;

fn backend() -> SimBackend {
    SimBackend::by_name(
        "BitNet-2B-4T",
        Platform::workstation(),
        SimBackendConfig { prefill_len: 16, max_seq: 64, threads: 0, seed: 3 },
    )
    .expect("zoo model")
}

fn cfg(max_batch: usize, kv_slots: usize, workers: usize) -> ServerConfig {
    ServerConfig { max_batch, kv_slots, workers, queue_cap: None }
}

/// Drain the legacy result channel into an id → tokens map.
fn tokens_by_id(results: &[RequestResult]) -> BTreeMap<u64, Vec<i32>> {
    results.iter().map(|r| (r.id, r.tokens.clone())).collect()
}

/// The skewed workload: submission order interleaves one long request
/// (ids 0, 4, 8, 12) with three short ones, so the old shard-at-submit
/// engine's round-robin pins *all four* long requests onto lane 0 of a
/// four-lane server while lanes 1–3 finish their shorts and idle.
fn skewed_requests() -> Vec<Request> {
    (0..16u64)
        .map(|i| {
            let prompt = vec![1 + (i % 7) as i32, 2 + (i % 5) as i32, 3];
            let max_new = if i % 4 == 0 { 24 } else { 4 };
            Request::new(i, prompt, max_new)
        })
        .collect()
}

#[test]
fn skewed_workload_steals_beat_static_sharding() {
    let server = Server::new(backend(), cfg(1, 1, 4)).expect("server config");
    let (tx, rx) = channel();
    let report = server.run_preloaded(skewed_requests(), tx).expect("preloaded run");
    let results: Vec<RequestResult> = rx.iter().collect();
    let stolen = tokens_by_id(&results);

    // Analytic makespan of the retired shard-at-submit engine on this
    // workload: round-robin assignment puts every long request on lane
    // 0, which then serves them strictly serially (max_batch 1) — four
    // prefills plus 4 × 23 decode rounds — while the other lanes idle.
    let b = backend();
    let prefill = b.prefill_plan().pass_seconds();
    let round = b.decode_round_plan(1).pass_seconds();
    let static_makespan = 4.0 * (prefill + 23.0 * round);

    assert!(report.steals >= 1, "idle lanes must steal the queued longs");
    assert!(
        report.wall_s < static_makespan * 0.95,
        "stealing must beat static sharding: wall {} vs static {}",
        report.wall_s,
        static_makespan
    );
    assert_eq!(report.lanes.len(), 4);
    for lane in &report.lanes {
        assert!(lane.requests >= 1, "lane {} idled through a non-empty queue", lane.lane);
        assert!(lane.clock_s > 0.0, "lane {} never ran", lane.lane);
    }
    // Steals are the only way work leaves lane 0's deque, so its
    // retirement count plus the steal count must cover its assignment.
    assert_eq!(
        report.lanes[0].requests + report.steals,
        4,
        "every long either ran on lane 0 or was stolen off it"
    );

    // Tokens are schedule-independent: the stolen four-lane run must be
    // bit-identical, per request, to a serial one-lane run.
    let serial_server = Server::new(backend(), cfg(1, 1, 1)).expect("server config");
    let (tx1, rx1) = channel();
    let serial_report =
        serial_server.run_preloaded(skewed_requests(), tx1).expect("serial run");
    let serial_results: Vec<RequestResult> = rx1.iter().collect();
    assert_eq!(serial_report.steals, 0, "one lane has nobody to steal from");
    assert_eq!(stolen, tokens_by_id(&serial_results), "stealing changed a token stream");
}

/// A pass-through backend that panics (killing its serving lane, which
/// the report surfaces as a lane error) if a decode step ever runs with
/// a position out of step with its KV cache — the invariant mid-flight
/// joins must not perturb.
struct KvGuardBackend {
    inner: SimBackend,
}

impl Backend for KvGuardBackend {
    type Cache = SimKvCache;

    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn describe(&self) -> String {
        format!("kv-guard({})", self.inner.describe())
    }

    fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<Step<SimKvCache>> {
        self.inner.prefill(tokens, prompt_len)
    }

    fn decode(&self, token: i32, pos: i32, cache: &SimKvCache) -> Result<Step<SimKvCache>> {
        assert_eq!(pos as usize, cache.len(), "decode pos drifted from the KV cache");
        self.inner.decode(token, pos, cache)
    }

    fn decode_batch(
        &self,
        reqs: &[BatchItem<'_, SimKvCache>],
    ) -> Result<Vec<Step<SimKvCache>>> {
        for r in reqs {
            assert_eq!(
                r.pos as usize,
                r.cache.len(),
                "batched decode pos drifted from the KV cache"
            );
        }
        self.inner.decode_batch(reqs)
    }
}

#[test]
fn midflight_joins_preserve_kv_contract_and_tokens() {
    // One lane, batch width 3, staggered budgets: ids 0–2 prefill
    // together, then every later admission joins a batch that already
    // ran decode rounds (id 0 spans the whole run, so the active set
    // never drains in between).
    let budgets = [16usize, 3, 5, 9, 4, 7];
    let requests: Vec<Request> = budgets
        .iter()
        .enumerate()
        .map(|(i, &max_new)| Request::new(i as u64, vec![2 + i as i32, 5, 9], max_new))
        .collect();
    let server =
        Server::new(KvGuardBackend { inner: backend() }, cfg(3, 3, 1)).expect("server config");
    let (tx, rx) = channel();
    let report = server.run_preloaded(requests, tx).expect("preloaded run");
    let results: Vec<RequestResult> = rx.iter().collect();

    assert!(report.lane_errors.is_empty(), "a join perturbed pos == cache.len()");
    assert_eq!(report.completed, 6);
    assert!(
        report.midflight_joins >= 2,
        "staggered retirements must produce mid-flight joins, got {}",
        report.midflight_joins
    );
    assert_eq!(report.lanes[0].joins, report.midflight_joins);

    // Joining mid-flight must not change a single token: every request
    // matches the direct batch-1 reference generation.
    let reference = backend();
    let tokens = tokens_by_id(&results);
    for (i, &max_new) in budgets.iter().enumerate() {
        let expect = reference.generate(&[2 + i as i32, 5, 9], max_new).expect("reference");
        assert_eq!(tokens[&(i as u64)], expect, "request {i} tokens diverged");
    }
}

/// One run's schedule, reduced to its deterministic (virtual-clock)
/// fields: wall bits, per-lane accounting, and per-request placement.
/// Real-time fields (queue waits) are deliberately excluded.
type Fingerprint =
    (u64, Vec<(usize, usize, usize, u64, usize, usize, Vec<usize>)>, BTreeMap<u64, Placement>);
type Placement = (Option<usize>, bool, bool, usize);

fn run_fingerprinted(requests: Vec<Request>, scfg: ServerConfig) -> Fingerprint {
    let (rec_tx, rec_rx) = channel::<RequestRecord>();
    let server =
        Server::new(backend(), scfg).expect("server config").with_metrics_sink(rec_tx);
    let (tx, rx) = channel();
    let report = server.run_preloaded(requests, tx).expect("preloaded run");
    drop(rx);
    let lanes = report
        .lanes
        .iter()
        .map(|l| {
            (l.lane, l.requests, l.rounds, l.clock_s.to_bits(), l.steals, l.joins,
             l.width_hist.clone())
        })
        .collect();
    let placements = rec_rx
        .try_iter()
        .map(|r| (r.id, (r.executed_lane, r.stolen, r.joined_midflight, r.tokens)))
        .collect();
    (report.wall_s.to_bits(), lanes, placements)
}

#[test]
fn preloaded_schedule_is_deterministic_across_runs() {
    let budgets = [5usize, 9, 3, 12, 4, 8, 6, 10, 2, 7];
    let requests = || -> Vec<Request> {
        budgets
            .iter()
            .enumerate()
            .map(|(i, &max_new)| Request::new(i as u64, vec![1 + i as i32, 4], max_new))
            .collect()
    };
    let first = run_fingerprinted(requests(), cfg(2, 2, 3));
    for run in 0..2 {
        let again = run_fingerprinted(requests(), cfg(2, 2, 3));
        assert_eq!(
            first, again,
            "run {run}: preloaded schedule must be a pure function of the request list"
        );
    }
    // The fingerprint actually covers placements (one per request, all
    // executed on a lane — preloaded runs never shed).
    assert_eq!(first.2.len(), budgets.len());
    assert!(first.2.values().all(|(lane, ..)| lane.is_some()));
}

/// A backend that spends real wall time per step, so the test can hold
/// a request in the admission queue while another decodes.
struct SlowBackend {
    inner: SimBackend,
    step: Duration,
}

impl Backend for SlowBackend {
    type Cache = SimKvCache;

    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn describe(&self) -> String {
        format!("slow({})", self.inner.describe())
    }

    fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<Step<SimKvCache>> {
        std::thread::sleep(self.step);
        self.inner.prefill(tokens, prompt_len)
    }

    fn decode(&self, token: i32, pos: i32, cache: &SimKvCache) -> Result<Step<SimKvCache>> {
        std::thread::sleep(self.step);
        self.inner.decode(token, pos, cache)
    }

    fn decode_batch(
        &self,
        reqs: &[BatchItem<'_, SimKvCache>],
    ) -> Result<Vec<Step<SimKvCache>>> {
        std::thread::sleep(self.step);
        self.inner.decode_batch(reqs)
    }
}

#[test]
fn queue_cap_sheds_with_consistent_accounting() {
    let slow = SlowBackend { inner: backend(), step: Duration::from_millis(20) };
    let (rec_tx, rec_rx) = channel();
    let aggregator = PromAggregator::spawn(rec_rx);
    let counters = aggregator.counters();
    let scfg = ServerConfig { max_batch: 1, kv_slots: 1, workers: 1, queue_cap: Some(1) };
    let handle = Engine::start_with_sink(slow, scfg, Some(rec_tx)).expect("engine start");

    // A occupies the lane (wait for its prefill so it has left the
    // admission queue), B fills the queue to its cap, C is shed.
    let ticket_a = handle.submit(GenerationRequest::new(vec![1, 2, 3], 50));
    match ticket_a.recv() {
        Some(TokenEvent::Prefilled { .. }) => {}
        other => panic!("expected A's prefill event, got {other:?}"),
    }
    let ticket_b = handle.submit(GenerationRequest::new(vec![4, 5], 3));
    let ticket_c = handle.submit(GenerationRequest::new(vec![6, 7], 3));
    let shed = ticket_c.join();
    assert_eq!(shed.finish, FinishReason::Failed);
    let error = shed.error.as_deref().expect("shed result carries the reason");
    assert!(error.contains("admission queue full"), "got {error:?}");

    ticket_a.cancel();
    let report = handle.shutdown().expect("merged report");
    assert_eq!(report.requests, 3);
    assert_eq!(report.completed, 1, "B completes after A's cancellation frees the lane");
    assert_eq!(report.cancelled, 1);
    assert_eq!(report.failed, 1);
    assert_eq!(report.rejected, 1, "the shed submission is a rejection, not a lane failure");
    drop(ticket_b);

    // The Prometheus surface agrees with the shutdown report: one
    // rejection, and all three submissions streamed a record.
    assert_eq!(aggregator.finish(), 3, "A, B, and the shed C each stream a record");
    assert_eq!(counters.rejections_total(), 1);
    assert_eq!(counters.steals_total(), 0, "a single lane has nobody to steal from");
}
