//! Cross-module integration tests: ISA ⇄ kernels ⇄ simulator ⇄ reports,
//! plus analytic-engine vs trace-driven-cache cross-validation.

use tsar::bench;
use tsar::config::platforms::{CacheLevel, Platform};
use tsar::config::IsaConfig;
use tsar::kernels::{select_tsar_kernel, scalar_gemm, TernaryKernel, Tl2Kernel, TsarKernel, Dataflow};
use tsar::sim::cache::{Access, Hierarchy};
use tsar::sim::{simulate, GemmShape};
use tsar::util::rng::Rng;

// ---------------------------------------------------------------------------
// Engine vs trace-driven cache hierarchy: working-set placement agreement
// ---------------------------------------------------------------------------

fn tiny_platform() -> Platform {
    // A shrunken hierarchy so trace simulation is fast: 4 KiB / 64 KiB /
    // 1 MiB.
    let mut p = Platform::workstation();
    p.l1d = CacheLevel { size_bytes: 4096, assoc: 8, line_bytes: 64, latency_cycles: 4.0, shared: false };
    p.l2 = CacheLevel { size_bytes: 64 * 1024, assoc: 8, line_bytes: 64, latency_cycles: 14.0, shared: false };
    p.l3 = CacheLevel { size_bytes: 1024 * 1024, assoc: 16, line_bytes: 64, latency_cycles: 50.0, shared: true };
    p
}

#[test]
fn analytic_placement_matches_trace_simulation() {
    // Sweep working sets around each level boundary; the analytic rule
    // ("fits level L => deeper levels see the cold fill only") must agree
    // with the real LRU hierarchy on where repeated sweeps hit.
    let plat = tiny_platform();
    for &(ws, passes) in &[
        (2 * 1024usize, 4usize),   // fits L1
        (32 * 1024, 4),            // fits L2
        (512 * 1024, 4),           // fits L3
        (4 * 1024 * 1024, 2),      // DRAM-resident
    ] {
        // Trace-driven ground truth.
        let mut h = Hierarchy::new(plat.l1d, plat.l2, plat.l3);
        for _ in 0..passes {
            h.stream(0, ws as u64, Access::Read);
        }
        let lines = (ws / 64) as u64;
        let dram_per_pass = h.dram_reads as f64 / passes as f64;

        // Analytic prediction.
        let p = tsar::sim::KernelProfile {
            kernel: "ws".into(),
            shape: GemmShape::new(1, 8, 8),
            streams: vec![tsar::sim::Stream::swept("w", ws as f64, passes as f64)],
            simd_uops: 1.0,
            scalar_uops: 0.0,
        };
        let r = simulate(&p, &plat, 1);
        let analytic_dram_lines = r.traffic.bytes[3] / 64.0;

        if ws as f64 <= plat.l3.size_bytes as f64 * 0.8 {
            // Fits somewhere on-chip: trace shows cold-fill-only DRAM
            // traffic; analytic must agree within 10%.
            assert!(
                (h.dram_reads as f64 - lines as f64).abs() < lines as f64 * 0.05,
                "trace: ws {ws} should cold-fill {lines} lines, got {}",
                h.dram_reads
            );
            assert!(
                (analytic_dram_lines - lines as f64).abs() < lines as f64 * 0.1,
                "analytic: ws {ws} DRAM lines {analytic_dram_lines} != {lines}"
            );
        } else {
            // Thrashes: every pass reaches DRAM in both models.
            assert!(dram_per_pass > lines as f64 * 0.9, "trace should thrash");
            assert!(
                analytic_dram_lines > (passes as f64 - 0.5) * lines as f64 * 0.9,
                "analytic should thrash: {analytic_dram_lines} vs {}",
                passes as u64 * lines
            );
        }
    }
}

// ---------------------------------------------------------------------------
// ISA-level kernels vs Python-oracle-style test vectors
// ---------------------------------------------------------------------------

#[test]
fn tsar_kernel_against_known_vector() {
    // A fixed vector, mirrored in python/tests/test_kernel.py semantics:
    // acts = [1..8], weights row j alternates (+1, 0, -1, ...), shifted.
    let k = 8usize;
    let m = 4usize;
    let acts: Vec<i8> = (1..=8).collect();
    let mut w = vec![0i8; m * k];
    for j in 0..m {
        for x in 0..k {
            w[j * k + x] = match (x + j) % 3 {
                0 => 1,
                1 => 0,
                _ => -1,
            };
        }
    }
    let shape = GemmShape::new(1, k, m);
    let want = scalar_gemm(&acts, &w, shape);
    // Hand-check one entry: row 0 = [1,0,-1,1,0,-1,1,0] · [1..8]
    assert_eq!(want[0], 1 - 3 + 4 - 6 + 7);
    for isa in [IsaConfig::C2, IsaConfig::C4] {
        for df in [Dataflow::ApMin, Dataflow::ApMax, Dataflow::Op] {
            let kern = TsarKernel::new(isa, df);
            assert_eq!(kern.run(&acts, &w, shape), want, "{}", kern.name());
        }
    }
}

// ---------------------------------------------------------------------------
// Figure-level invariants (the paper's headline shapes)
// ---------------------------------------------------------------------------

#[test]
fn fig8_tsar_wins_prefill_everywhere() {
    let rows = bench::fig8();
    for r in &rows {
        assert!(
            r.prefill_tsar_s < r.prefill_tl2_s,
            "{} on {}: prefill T-SAR {:.4}s !< TL-2 {:.4}s",
            r.model,
            r.platform,
            r.prefill_tsar_s,
            r.prefill_tl2_s
        );
        assert!(
            r.decode_tsar_tps >= r.decode_tl2_tps * 0.999,
            "{} on {}: decode T-SAR must not lose",
            r.model,
            r.platform
        );
    }
}

#[test]
fn fig10_gemm_scales_further_than_gemv() {
    // The paper's Fig. 10 story: compute-bound GEMM keeps scaling where
    // bandwidth-bound GEMV plateaus early.
    let plat = Platform::workstation();
    let gemm = GemmShape::new(128, 2560, 6912);
    let gemv = GemmShape::new(1, 2560, 6912);
    let speedup = |shape: GemmShape| {
        let t1 = {
            let (k, _) = select_tsar_kernel(shape, &plat, 1);
            simulate(&k.profile(shape, &plat, 1), &plat, 1).seconds
        };
        let t16 = {
            let (k, _) = select_tsar_kernel(shape, &plat, 16);
            simulate(&k.profile(shape, &plat, 16), &plat, 16).seconds
        };
        t1 / t16
    };
    let s_gemm = speedup(gemm);
    let s_gemv = speedup(gemv);
    assert!(
        s_gemm > 1.7 * s_gemv,
        "GEMM thread-scaling {s_gemm:.1}x must exceed GEMV {s_gemv:.1}x"
    );
    assert!(s_gemm > 6.0, "GEMM should scale well, got {s_gemm:.1}x");
    assert!(s_gemv < 4.0, "GEMV should plateau, got {s_gemv:.1}x");
}

#[test]
fn mobile_prefill_becomes_interactive() {
    // §IV-B: Mobile 7B prefill drops from >20 s to interactive range.
    let spec = tsar::model::zoo::by_name("BitNet-7B").unwrap();
    let plat = Platform::mobile();
    let tl2 = bench::pass_seconds(spec, &plat, 128, false);
    let tsar = bench::pass_seconds(spec, &plat, 128, true);
    assert!(tl2 > 4.0 * tsar, "tl2 {tl2:.1}s vs tsar {tsar:.1}s");
    assert!(tsar < 5.0, "T-SAR mobile 7B prefill should be interactive");
}

#[test]
fn request_volume_reduction_grows_then_saturates_with_size() {
    // §IV-C: request reduction grows with model size for GEMV.
    let rows = bench::fig9();
    let gemv: Vec<f64> = rows
        .iter()
        .filter(|r| r.phase.starts_with("GEMV"))
        .map(|r| r.tl2_mb / r.tsar_mb)
        .collect();
    assert_eq!(gemv.len(), 3);
    for r in &gemv {
        assert!(*r > 4.0, "GEMV reduction {r:.1} too small");
    }
}

// ---------------------------------------------------------------------------
// Functional kernels at model-layer scale (spot check, moderate size)
// ---------------------------------------------------------------------------

#[test]
fn functional_kernels_agree_at_layer_scale() {
    let mut rng = Rng::new(99);
    let shape = GemmShape::new(2, 256, 320);
    let acts = rng.int8_acts(shape.n * shape.k);
    let w = rng.ternary_matrix(shape.m, shape.k, 0.34);
    let want = scalar_gemm(&acts, &w, shape);
    assert_eq!(
        TsarKernel::new(IsaConfig::C2, Dataflow::Op).run(&acts, &w, shape),
        want
    );
    assert_eq!(Tl2Kernel::new().run(&acts, &w, shape), want);
}
