//! Differential fuzz: the native GEMV (AVX2 pshufb kernels, or the
//! portable scalar fallback) must be **bit-identical** to the modeled
//! ISA (`tsar::exec` semantics driven through `TsarKernel`) across
//! randomized shapes and ISA configs — and, through the serving stack,
//! `--backend native` must emit exactly the tokens `SimBackend` emits.
//!
//! CI runs this suite twice on AVX2 runners: once with
//! `RUSTFLAGS="-C target-cpu=native"` (exercising the AVX2 path) and
//! once with `TSAR_NATIVE_FORCE_SCALAR=1` (proving the fallback), and
//! scalar-only on every other architecture.

use std::sync::mpsc::channel;

use tsar::config::platforms::Platform;
use tsar::config::IsaConfig;
use tsar::coordinator::{Request, Server, ServerConfig};
use tsar::kernels::native::{detect_path, NativeGemv, NativePath};
use tsar::kernels::{scalar_gemm, Dataflow, TernaryKernel, TsarKernel};
use tsar::model::zoo::ModelSpec;
use tsar::runtime::{Backend, NativeBackend, SimBackend, SimBackendConfig};
use tsar::sim::GemmShape;
use tsar::util::rng::Rng;

/// Run `cases` randomized (shape, IsaConfig, sparsity) comparisons of
/// the native path against the modeled ISA and the scalar reference.
fn fuzz_against_modeled(path: NativePath, cases: usize, seed0: u64) {
    assert!(cases >= 100, "acceptance demands >= 100 randomized cases");
    for case in 0..cases {
        let mut rng = Rng::new(seed0 + case as u64);
        let isa = if rng.f64() < 0.5 { IsaConfig::C2 } else { IsaConfig::C4 };
        let n = rng.range_i64(1, 3) as usize;
        let k = rng.range_i64(1, 180) as usize;
        let m = rng.range_i64(1, 70) as usize;
        let shape = GemmShape::new(n, k, m);
        let acts = rng.int8_acts(n * k);
        let zero_frac = rng.f64();
        let w = rng.ternary_matrix(m, k, zero_frac);

        // Ground truth 1: the modeled ISA (TLUT/TGEMV on the Ymm
        // register-file model).  Ground truth 2: the scalar dot product.
        let modeled = TsarKernel::new(isa, Dataflow::Op).run(&acts, &w, shape);
        assert_eq!(
            modeled,
            scalar_gemm(&acts, &w, shape),
            "case {case}: modeled ISA drifted from the scalar reference"
        );

        let gemv = NativeGemv::with_path(isa, path).unwrap();
        let packed = gemv.pack(&w, m, k).unwrap();
        let mut out = vec![0i32; n * m];
        gemv.gemm(&acts, &packed, n, &mut out).unwrap();
        assert_eq!(
            out,
            modeled,
            "case {case}: native {} != modeled ISA for {} {shape:?} (zeros {zero_frac:.2})",
            path.name(),
            isa.name()
        );
    }
}

#[test]
fn native_matches_modeled_isa_on_randomized_cases() {
    // Whatever the host supports: AVX2 where available, else scalar.
    fuzz_against_modeled(detect_path(), 120, 0xD1FF_0000);
}

#[test]
fn scalar_fallback_matches_modeled_isa_on_randomized_cases() {
    // The portable path must hold everywhere, including AVX2 hosts.
    fuzz_against_modeled(NativePath::Scalar, 120, 0xD1FF_9999);
}

#[test]
fn threaded_gemv_matches_single_threaded_on_randomized_cases() {
    // The `threads` knob chunks output tiles across lanes of the
    // persistent worker pool; every chunking must reproduce the
    // single-threaded result bit for bit (disjoint tiles, exact i32
    // accumulation) on whatever path the host detects.  n spans row
    // blocks so pool dispatch is exercised on ragged batches too
    // (`tests/native_gemm_batched.rs` carries the dedicated suite).
    for case in 0..40u64 {
        let mut rng = Rng::new(0x7117_0000 + case);
        let isa = if rng.f64() < 0.5 { IsaConfig::C2 } else { IsaConfig::C4 };
        let n = rng.range_i64(1, 10) as usize;
        let k = rng.range_i64(1, 160) as usize;
        let m = rng.range_i64(1, 200) as usize;
        let shape = GemmShape::new(n, k, m);
        let acts = rng.int8_acts(n * k);
        let zero_frac = rng.f64();
        let w = rng.ternary_matrix(m, k, zero_frac);
        let gemv = NativeGemv::with_path(isa, detect_path()).unwrap();
        let packed = gemv.pack(&w, m, k).unwrap();
        let mut single = vec![0i32; n * m];
        gemv.gemm(&acts, &packed, n, &mut single).unwrap();
        let threads = rng.range_i64(2, 6) as usize;
        let threaded = gemv.with_threads(threads).unwrap();
        let mut out = vec![0i32; n * m];
        threaded.gemm(&acts, &packed, n, &mut out).unwrap();
        assert_eq!(
            out, single,
            "case {case}: threads={threads} diverged for {} {shape:?}",
            isa.name()
        );
    }
}

// ---------------------------------------------------------------------------
// Serving-stack parity: `tsar-cli serve --backend native` ≡ SimBackend
// ---------------------------------------------------------------------------

/// Tiny architecture so real native execution stays cheap in debug CI.
static TINY: ModelSpec = ModelSpec {
    name: "Tiny-Native-E2E",
    layers: 2,
    d_model: 64,
    n_heads: 4,
    n_kv_heads: 4,
    ffn_dim: 128,
    vocab: 512,
};

fn serve_tokens<B: Backend + Send + Sync + 'static>(backend: B) -> Vec<(u64, Vec<i32>)> {
    let server = Server::new(
        backend,
        ServerConfig { max_batch: 2, kv_slots: 2, workers: 1, queue_cap: None },
    )
    .unwrap();
    let requests: Vec<Request> = (0..3u64)
        .map(|id| Request::new(id, vec![2 + id as i32, 7], 2))
        .collect();
    let (tx, rx) = channel();
    server.run_preloaded(requests, tx).unwrap();
    let mut results: Vec<(u64, Vec<i32>)> =
        rx.try_iter().map(|r| (r.id, r.tokens)).collect();
    results.sort_by_key(|(id, _)| *id);
    results
}

#[test]
fn native_serve_produces_identical_tokens_to_sim_serve() {
    let cfg = SimBackendConfig { prefill_len: 4, max_seq: 16, threads: 0, seed: 0xBEE5 };
    let sim = SimBackend::new(&TINY, Platform::workstation(), cfg);
    let native = NativeBackend::new(&TINY, IsaConfig::C2, cfg).unwrap();

    // Direct generation parity first (isolates backend from scheduler).
    let a = sim.generate(&[3, 1, 4], 3).unwrap();
    let b = native.generate(&[3, 1, 4], 3).unwrap();
    assert_eq!(a, b, "generate() token streams diverged");

    // Then through the coordinator, exactly as `tsar-cli serve` drives
    // both backends.
    let sim_tokens = serve_tokens(sim);
    let native_tokens = serve_tokens(native);
    assert_eq!(sim_tokens.len(), 3);
    assert_eq!(
        sim_tokens, native_tokens,
        "served tokens diverged between --backend sim and --backend native"
    );
}

#[test]
fn native_serve_parity_holds_for_c4_too() {
    let cfg = SimBackendConfig { prefill_len: 4, max_seq: 16, threads: 0, seed: 0x7E54 };
    let sim = SimBackend::new(&TINY, Platform::workstation(), cfg);
    let native = NativeBackend::new(&TINY, IsaConfig::C4, cfg).unwrap();
    assert_eq!(
        sim.generate(&[9, 9], 4).unwrap(),
        native.generate(&[9, 9], 4).unwrap()
    );
}
