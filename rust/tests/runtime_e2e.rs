//! End-to-end runtime tests: load the AOT artifacts (built by
//! `make artifacts`), execute prefill/decode through PJRT, and check the
//! Rust-side generation against the Python-recorded goldens.
//!
//! Gated on `--features pjrt` (the default offline build has no PJRT
//! runtime; the SimBackend counterpart lives in `serve_sim.rs`), and
//! skipped with a visible message when `artifacts/` has not been built
//! — `cargo test` must be runnable before `make artifacts` in CI.

#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use tsar::coordinator::{serve::serve_all, Request, Server, ServerConfig};
use tsar::runtime::{Backend, ModelRuntime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn ref_variant_reproduces_python_golden() {
    let dir = require_artifacts!();
    let rt = ModelRuntime::load(&dir, "ref").expect("load ref variant");
    let g = rt.manifest.golden.clone();
    let toks = rt
        .generate(&g.prompt, g.tokens.len())
        .expect("generation");
    assert_eq!(
        toks, g.tokens,
        "Rust PJRT generation must reproduce the Python golden exactly"
    );
}

#[test]
fn tsar_variant_matches_ref_variant() {
    // The Pallas LUT-kernel path and the direct ternary matmul path are
    // bit-identical in the int32 domain; greedy decoding through PJRT
    // must therefore produce the same tokens.
    let dir = require_artifacts!();
    let rt_tsar = ModelRuntime::load(&dir, "tsar").expect("load tsar variant");
    let g = rt_tsar.manifest.golden.clone();
    let n = g.tokens.len().min(8); // keep the slower LUT path short
    let toks = rt_tsar.generate(&g.prompt, n).expect("generation");
    assert_eq!(toks, g.tokens[..n].to_vec());
}

#[test]
fn prefill_is_padding_invariant() {
    let dir = require_artifacts!();
    let rt = ModelRuntime::load(&dir, "ref").unwrap();
    let p = rt.manifest.config.prefill_len;
    let prompt = [3i32, 5, 7];
    let mut padded_zeros = vec![0i32; p];
    padded_zeros[..3].copy_from_slice(&prompt);
    let mut padded_junk = vec![11i32; p];
    padded_junk[..3].copy_from_slice(&prompt);
    let a = rt.prefill(&padded_zeros, 3).unwrap();
    let b = rt.prefill(&padded_junk, 3).unwrap();
    assert_eq!(a.next_token, b.next_token);
}

#[test]
fn decode_is_deterministic() {
    let dir = require_artifacts!();
    let rt = ModelRuntime::load(&dir, "ref").unwrap();
    let g = rt.manifest.golden.clone();
    let t1 = rt.generate(&g.prompt, 4).unwrap();
    let t2 = rt.generate(&g.prompt, 4).unwrap();
    assert_eq!(t1, t2);
}

#[test]
fn server_serves_batched_requests() {
    let dir = require_artifacts!();
    let rt = ModelRuntime::load(&dir, "ref").unwrap();
    let vocab = rt.manifest.config.vocab as i32;
    let server =
        Server::new(rt, ServerConfig { max_batch: 3, kv_slots: 3, workers: 1, queue_cap: None })
            .expect("server config");
    let requests: Vec<Request> = (0..6u64)
        .map(|id| {
            Request::new(
                id,
                vec![
                    (1 + id as i32) % vocab,
                    (3 + 2 * id as i32) % vocab,
                    (7 + id as i32) % vocab,
                ],
                5,
            )
        })
        .collect();
    let report = serve_all(&server, requests).expect("serve");
    assert_eq!(report.requests, 6);
    assert_eq!(report.total_tokens, 30);
    assert!(report.tokens_per_s > 0.0);
    assert!(report.prefill.p95 >= report.prefill.p50);
}

#[test]
fn server_interleaves_under_tight_batch() {
    // max_batch=1 degenerates to sequential serving; all requests still
    // complete with the same token counts.
    let dir = require_artifacts!();
    let rt = ModelRuntime::load(&dir, "ref").unwrap();
    let server =
        Server::new(rt, ServerConfig { max_batch: 1, kv_slots: 1, workers: 1, queue_cap: None })
            .expect("server config");
    let requests: Vec<Request> =
        (0..3u64).map(|id| Request::new(id, vec![2, 4, 6], 4)).collect();
    let report = serve_all(&server, requests).expect("serve");
    assert_eq!(report.requests, 3);
    assert_eq!(report.total_tokens, 12);
}
