//! Integration tests of the open-loop load-generation subsystem
//! (`loadgen`, DESIGN.md §5): full bench runs against the in-process
//! serving stack, seeded reproducibility of the recorded trace
//! identity, and deterministic queue-cap shedding under a deliberately
//! slow backend — with every run's client-side counts reconciled
//! against the engine's `/metrics` scrape.

use std::time::Duration;

use tsar::config::platforms::Platform;
use tsar::loadgen::{self, BenchConfig, BenchOutput};
use tsar::runtime::{
    Backend, BatchItem, ModelConfig, SimBackend, SimBackendConfig, SimKvCache, Step,
};
use tsar::util::error::Result;
use tsar::util::json::Json;

fn sim() -> SimBackend {
    SimBackend::by_name(
        "BitNet-2B-4T",
        Platform::workstation(),
        SimBackendConfig { prefill_len: 16, max_seq: 64, threads: 0, seed: 3 },
    )
    .expect("zoo model")
}

/// A backend that spends real wall time per step, so the admission
/// queue actually backs up and the cap sheds deterministically.
struct SlowBackend {
    inner: SimBackend,
    step: Duration,
}

impl Backend for SlowBackend {
    type Cache = SimKvCache;

    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn describe(&self) -> String {
        format!("slow({})", self.inner.describe())
    }

    fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<Step<SimKvCache>> {
        std::thread::sleep(self.step);
        self.inner.prefill(tokens, prompt_len)
    }

    fn decode(&self, token: i32, pos: i32, cache: &SimKvCache) -> Result<Step<SimKvCache>> {
        std::thread::sleep(self.step);
        self.inner.decode(token, pos, cache)
    }

    fn decode_batch(
        &self,
        reqs: &[BatchItem<'_, SimKvCache>],
    ) -> Result<Vec<Step<SimKvCache>>> {
        std::thread::sleep(self.step);
        self.inner.decode_batch(reqs)
    }
}

fn recorded_fingerprint(out: &BenchOutput) -> String {
    out.artifact
        .get("workload")
        .and_then(|w| w.get("trace_fingerprint"))
        .and_then(Json::as_str)
        .expect("artifact records the trace fingerprint")
        .to_string()
}

#[test]
fn fixed_seed_reproduces_the_workload_trace() {
    let cfg = BenchConfig { requests: 8, ..BenchConfig::smoke() };

    // The planned trace is a pure function of the spec.  (The bench
    // itself draws prompt tokens from the zoo model's real vocab.)
    let vocab = sim().config().vocab;
    let a = cfg.workload_spec(vocab).build().unwrap();
    let b = cfg.workload_spec(vocab).build().unwrap();
    assert_eq!(a.fingerprint, b.fingerprint);
    assert_eq!(a.requests, b.requests);

    // Two full measured runs at the same seed replay the identical
    // workload and record the same trace identity in their artifacts.
    let run1 = loadgen::run(&cfg).unwrap();
    let run2 = loadgen::run(&cfg).unwrap();
    assert!(run1.agree, "run1 cross-check mismatches: {:?}", run1.mismatches);
    assert!(run2.agree, "run2 cross-check mismatches: {:?}", run2.mismatches);
    assert_eq!(recorded_fingerprint(&run1), recorded_fingerprint(&run2));
    assert_eq!(recorded_fingerprint(&run1), a.fingerprint_hex());
}

#[test]
fn smoke_run_cross_checks_against_the_scrape() {
    let cfg = BenchConfig { requests: 12, ..BenchConfig::smoke() };
    let out = loadgen::run(&cfg).unwrap();

    // The acceptance bar: every outcome the client observed is matched
    // by the engine's own /metrics deltas, exactly.
    assert!(out.agree, "cross-check mismatches: {:?}", out.mismatches);
    assert!(out.mismatches.is_empty());

    // The artifact satisfies its schema and mirrors the client tally.
    let n = tsar::util::artifact::validate_serve(&out.artifact.to_string()).unwrap();
    assert_eq!(n, 12);
    let outcomes = out.artifact.get("outcomes").expect("outcomes block");
    for (key, want) in [
        ("completed", out.counts.completed),
        ("cancelled", out.counts.cancelled),
        ("rejected", out.counts.rejected),
        ("failed", out.counts.failed),
        ("http_shed", out.counts.http_shed),
    ] {
        assert_eq!(outcomes.get(key).and_then(Json::as_f64), Some(want as f64), "{key}");
    }
    let tokens = out.artifact.get("tokens").expect("tokens block");
    assert_eq!(tokens.get("total").and_then(Json::as_f64), Some(out.counts.tokens_total as f64));
    assert_eq!(out.artifact.get("smoke"), Some(&Json::Bool(true)));
}

#[test]
fn a_capped_queue_under_slow_service_sheds_with_429() {
    // Twelve near-simultaneous arrivals over three connections into a
    // single slow lane with one queue slot: most submissions must find
    // the queue full and come back as HTTP 429 — and the engine's
    // failed/rejection counters must account for every one of them.
    let cfg = BenchConfig {
        requests: 12,
        rate_rps: 10_000.0,
        bursty: false,
        conns: 3,
        workers: 1,
        max_batch: 1,
        queue_cap: Some(1),
        cancel_rate: 0.5,
        deadline_frac: 0.5,
        smoke: true,
        ..BenchConfig::default()
    };
    let slow = SlowBackend { inner: sim(), step: Duration::from_millis(20) };
    let out = loadgen::run_with_backend(&cfg, slow, "slow:BitNet-2B-4T").unwrap();

    assert!(out.counts.rejected > 0, "no 429 sheds under a full queue: {:?}", out.counts);
    assert_eq!(out.counts.http_shed, 0, "503s would mean the client overran the stream cap");
    assert!(out.agree, "cross-check mismatches: {:?}", out.mismatches);
    tsar::util::artifact::validate_serve(&out.artifact.to_string()).unwrap();
    let shed = out.artifact.get("shed_rate").and_then(Json::as_f64).unwrap();
    assert!(shed > 0.0 && shed <= 1.0, "shed_rate {shed}");
}
