//! Integration tests of HTTP/1.1 keep-alive multiplexing on the
//! zero-dependency front-end (`coordinator::http`, DESIGN.md §3):
//! sequential requests on one connection, pipelined generate streams
//! capped per connection (excess shed with `503`), and mid-stream
//! disconnects cancelling only the affected session.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tsar::config::platforms::Platform;
use tsar::coordinator::{
    Engine, EngineHandle, HttpConfig, HttpServer, PromAggregator, ServeReport, ServerConfig,
};
use tsar::runtime::{
    Backend, BatchItem, ModelConfig, SimBackend, SimBackendConfig, SimKvCache, Step,
};
use tsar::util::error::Result;
use tsar::util::json::Json;

fn backend() -> SimBackend {
    SimBackend::by_name(
        "BitNet-2B-4T",
        Platform::workstation(),
        SimBackendConfig { prefill_len: 16, max_seq: 64, threads: 0, seed: 3 },
    )
    .expect("zoo model")
}

fn cfg(max_batch: usize, kv_slots: usize, workers: usize) -> ServerConfig {
    ServerConfig { max_batch, kv_slots, workers, queue_cap: None }
}

/// Engine + aggregator + HTTP front-end on an ephemeral port, with the
/// caller's [`HttpConfig`] (the keep-alive tests tune the stream cap).
fn start_http<B: Backend + Send + Sync + 'static>(
    backend: B,
    scfg: ServerConfig,
    hcfg: HttpConfig,
) -> (Arc<EngineHandle<B>>, HttpServer, PromAggregator) {
    let (rec_tx, rec_rx) = channel();
    let aggregator = PromAggregator::spawn(rec_rx);
    let handle = Arc::new(Engine::start_with_sink(backend, scfg, Some(rec_tx)).unwrap());
    let http = HttpServer::start(
        "127.0.0.1:0",
        Arc::clone(&handle),
        aggregator.counters(),
        hcfg,
    )
    .unwrap();
    (handle, http, aggregator)
}

/// Stop the front-end and shut the engine down for the merged report.
fn finish<B: Backend>(handle: Arc<EngineHandle<B>>, http: HttpServer) -> Result<ServeReport> {
    http.stop();
    let handle = Arc::try_unwrap(handle).ok().expect("HTTP workers joined");
    handle.shutdown()
}

/// Write one request on an already-open connection.  `connection` is
/// the optional `Connection:` header value — `None` leaves HTTP/1.1's
/// keep-alive default in force.
fn send(stream: &mut TcpStream, method: &str, path: &str, body: &str, connection: Option<&str>) {
    let conn_header =
        connection.map(|c| format!("Connection: {c}\r\n")).unwrap_or_default();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n{conn_header}\r\n{body}",
        body.len()
    )
    .expect("send request");
    stream.flush().expect("flush request");
}

fn gen_body(prompt: &[i32], max_new: usize) -> String {
    format!("{{\"prompt\":{prompt:?},\"max_new_tokens\":{max_new}}}")
}

/// Read one response head off the connection: (status line, raw head).
fn read_head(reader: &mut BufReader<TcpStream>) -> (String, String) {
    let mut head = String::new();
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("read header line");
        assert!(n > 0, "connection closed while reading headers:\n{head}");
        if line == "\r\n" {
            break;
        }
        head.push_str(&line);
    }
    let status = head.lines().next().unwrap_or("").to_string();
    (status, head)
}

/// Does the head carry `name: value` (case-insensitive)?
fn head_has(head: &str, name: &str, value: &str) -> bool {
    head.lines().any(|l| {
        l.split_once(':').is_some_and(|(n, v)| {
            n.eq_ignore_ascii_case(name) && v.trim().eq_ignore_ascii_case(value)
        })
    })
}

/// Read a chunked body through its zero-length delimiter chunk — the
/// framing that lets a keep-alive connection survive a stream.
fn read_chunked(reader: &mut BufReader<TcpStream>) -> String {
    let mut out = String::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).expect("chunk size line");
        let size = usize::from_str_radix(line.trim(), 16).expect("hex chunk size");
        let mut chunk = vec![0u8; size + 2]; // payload + trailing CRLF
        reader.read_exact(&mut chunk).expect("chunk payload");
        if size == 0 {
            break;
        }
        out.push_str(std::str::from_utf8(&chunk[..size]).expect("UTF-8 chunk"));
    }
    out
}

/// Read a fixed-length body using the head's `Content-Length`.
fn read_sized(reader: &mut BufReader<TcpStream>, head: &str) -> String {
    let length: usize = head
        .lines()
        .find_map(|l| {
            let (name, value) = l.split_once(':')?;
            name.eq_ignore_ascii_case("content-length")
                .then(|| value.trim().parse().expect("Content-Length value"))
        })
        .expect("Content-Length header");
    let mut body = vec![0u8; length];
    reader.read_exact(&mut body).expect("sized body");
    String::from_utf8(body).expect("UTF-8 body")
}

/// The terminal `retired` line's tokens of a streamed NDJSON body.
fn last_tokens(body: &str) -> Vec<i32> {
    let last =
        Json::parse(body.lines().last().expect("terminal line")).expect("valid NDJSON line");
    assert_eq!(last.get("event").and_then(Json::as_str), Some("retired"), "got {body}");
    last.get("tokens")
        .and_then(Json::as_arr)
        .expect("terminal carries tokens")
        .iter()
        .map(|t| t.as_f64().expect("token is a number") as i32)
        .collect()
}

#[test]
fn keep_alive_serves_sequential_requests_on_one_connection() {
    let (handle, http, aggregator) = start_http(backend(), cfg(2, 2, 1), HttpConfig::default());
    let addr = http.local_addr();
    let reference = backend();

    let conn = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(conn);

    // First generate: no Connection header, so HTTP/1.1 keep-alive
    // holds the socket open past the chunked stream.
    send(reader.get_mut(), "POST", "/v1/generate", &gen_body(&[3, 1, 4], 5), None);
    let (status, head) = read_head(&mut reader);
    assert!(status.contains("200"), "got {status}");
    assert!(head_has(&head, "connection", "keep-alive"), "head: {head}");
    assert!(head_has(&head, "transfer-encoding", "chunked"), "head: {head}");
    let body = read_chunked(&mut reader);
    assert_eq!(last_tokens(&body), reference.generate(&[3, 1, 4], 5).unwrap());

    // A metadata route between the generates, on the same socket.
    send(reader.get_mut(), "GET", "/healthz", "", None);
    let (status, head) = read_head(&mut reader);
    assert!(status.contains("200"), "got {status}");
    assert_eq!(read_sized(&mut reader, &head), "ok\n");

    // Second generate closes the connection explicitly.
    send(reader.get_mut(), "POST", "/v1/generate", &gen_body(&[9, 2], 4), Some("close"));
    let (status, head) = read_head(&mut reader);
    assert!(status.contains("200"), "got {status}");
    assert!(head_has(&head, "connection", "close"), "head: {head}");
    let body = read_chunked(&mut reader);
    assert_eq!(last_tokens(&body), reference.generate(&[9, 2], 4).unwrap());
    let mut probe = [0u8; 1];
    let n = reader.read(&mut probe).unwrap_or(0);
    assert_eq!(n, 0, "server must close after Connection: close");

    let report = finish(handle, http).unwrap();
    assert_eq!(report.requests, 2);
    assert_eq!(report.completed, 2);
    assert_eq!(aggregator.finish(), 2);
}

#[test]
fn pipelined_generates_past_the_stream_cap_are_shed() {
    let hcfg = HttpConfig { max_streams_per_conn: 2, ..HttpConfig::default() };
    let (handle, http, aggregator) = start_http(backend(), cfg(2, 2, 2), hcfg);
    let addr = http.local_addr();
    let reference = backend();

    // Three pipelined generates in one write: the first two are
    // admitted (and run concurrently), the third exceeds the cap.
    let prompts: [&[i32]; 3] = [&[1, 2, 3], &[4, 5], &[6, 7, 8]];
    let mut wire = String::new();
    for prompt in &prompts {
        let body = gen_body(prompt, 4);
        wire.push_str(&format!(
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ));
    }
    let conn = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(conn);
    reader.get_mut().write_all(wire.as_bytes()).expect("pipelined write");
    reader.get_mut().flush().expect("flush");

    // Responses come back strictly in request order: two streams...
    for prompt in &prompts[..2] {
        let (status, head) = read_head(&mut reader);
        assert!(status.contains("200"), "got {status}");
        assert!(head_has(&head, "transfer-encoding", "chunked"), "head: {head}");
        let body = read_chunked(&mut reader);
        assert_eq!(last_tokens(&body), reference.generate(prompt, 4).unwrap());
    }
    // ...then the shed response, which keeps the connection alive.
    let (status, head) = read_head(&mut reader);
    assert!(status.contains("503"), "got {status}");
    assert!(head_has(&head, "connection", "keep-alive"), "head: {head}");
    let body = read_sized(&mut reader, &head);
    assert!(body.contains("too many concurrent streams"), "got {body}");

    // The connection is still usable after the shed.
    send(reader.get_mut(), "GET", "/healthz", "", Some("close"));
    let (status, head) = read_head(&mut reader);
    assert!(status.contains("200"), "got {status}");
    assert_eq!(read_sized(&mut reader, &head), "ok\n");

    let report = finish(handle, http).unwrap();
    assert_eq!(report.requests, 2, "the shed generate never reached the engine");
    assert_eq!(report.completed, 2);
    assert_eq!(aggregator.finish(), 2);
}

/// A backend that spends real wall time per step so a client can
/// abandon a generation mid-stream.
struct SlowBackend {
    inner: SimBackend,
    step: Duration,
}

impl Backend for SlowBackend {
    type Cache = SimKvCache;

    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn describe(&self) -> String {
        format!("slow({})", self.inner.describe())
    }

    fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<Step<SimKvCache>> {
        std::thread::sleep(self.step);
        self.inner.prefill(tokens, prompt_len)
    }

    fn decode(&self, token: i32, pos: i32, cache: &SimKvCache) -> Result<Step<SimKvCache>> {
        std::thread::sleep(self.step);
        self.inner.decode(token, pos, cache)
    }

    fn decode_batch(
        &self,
        reqs: &[BatchItem<'_, SimKvCache>],
    ) -> Result<Vec<Step<SimKvCache>>> {
        std::thread::sleep(self.step);
        self.inner.decode_batch(reqs)
    }
}

#[test]
fn mid_stream_disconnect_cancels_only_the_affected_session() {
    let slow = SlowBackend { inner: backend(), step: Duration::from_millis(10) };
    let (handle, http, aggregator) = start_http(slow, cfg(2, 2, 1), HttpConfig::default());
    let addr = http.local_addr();

    // Session A: a keep-alive streaming client that reads a few token
    // lines, then drops the socket mid-generation.
    {
        let conn = TcpStream::connect(addr).expect("connect A");
        let mut reader = BufReader::new(conn);
        send(reader.get_mut(), "POST", "/v1/generate", &gen_body(&[2, 3, 4], 55), None);
        let (status, _head) = read_head(&mut reader);
        assert!(status.contains("200"), "got {status}");
        let mut token_lines = 0;
        while token_lines < 4 {
            let mut line = String::new();
            if reader.read_line(&mut line).expect("stream line") == 0 {
                break;
            }
            if line.contains("\"event\":\"prefilled\"") || line.contains("\"event\":\"token\"")
            {
                token_lines += 1;
            }
        }
        assert!(token_lines >= 1, "never saw a streamed token");
    } // A's socket drops here, mid-stream.

    // Session B on its own connection completes untouched while A's
    // cancellation lands (both share the single two-wide lane).
    let conn = TcpStream::connect(addr).expect("connect B");
    let mut reader = BufReader::new(conn);
    send(reader.get_mut(), "POST", "/v1/generate", &gen_body(&[8, 9], 5), Some("close"));
    let (status, _head) = read_head(&mut reader);
    assert!(status.contains("200"), "got {status}");
    let body = read_chunked(&mut reader);
    assert_eq!(last_tokens(&body), backend().generate(&[8, 9], 5).unwrap());

    // Wait for A's retirement record so the report below is complete.
    let counters = aggregator.counters();
    let deadline = Instant::now() + Duration::from_secs(10);
    while counters.queue_depth() != 0 {
        assert!(Instant::now() < deadline, "disconnect cancellation never landed");
        std::thread::sleep(Duration::from_millis(20));
    }

    let report = finish(handle, http).unwrap();
    assert_eq!(report.requests, 2);
    assert_eq!(report.cancelled, 1, "only the disconnected session is cancelled");
    assert_eq!(report.completed, 1, "the surviving session is untouched");
    assert!(
        report.total_tokens < 55 + 5,
        "A was cancelled early, yet {} tokens were generated",
        report.total_tokens
    );
    assert_eq!(aggregator.finish(), 2);
}
