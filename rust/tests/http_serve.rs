//! In-tree integration tests of the zero-dependency HTTP front-end
//! (`coordinator::http`, DESIGN.md §3): raw `std::net::TcpStream`
//! clients against a live engine — streaming token parity with the
//! direct backend, concurrent sessions, mid-stream disconnect
//! cancellation, and a Prometheus scrape that matches the shutdown
//! `ServeReport`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tsar::config::platforms::Platform;
use tsar::coordinator::{
    Engine, EngineHandle, HttpConfig, HttpServer, PromAggregator, ServeReport, ServerConfig,
};
use tsar::runtime::{
    Backend, BatchItem, ModelConfig, SimBackend, SimBackendConfig, SimKvCache, Step,
};
use tsar::util::error::Result;
use tsar::util::json::Json;

fn backend() -> SimBackend {
    SimBackend::by_name(
        "BitNet-2B-4T",
        Platform::workstation(),
        SimBackendConfig { prefill_len: 16, max_seq: 64, threads: 0, seed: 3 },
    )
    .expect("zoo model")
}

fn cfg(max_batch: usize, kv_slots: usize, workers: usize) -> ServerConfig {
    ServerConfig { max_batch, kv_slots, workers, queue_cap: None }
}

/// A backend that spends real wall time per step so a client can
/// observe (and abandon) a generation mid-stream.
struct SlowBackend {
    inner: SimBackend,
    step: Duration,
}

impl Backend for SlowBackend {
    type Cache = SimKvCache;

    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn describe(&self) -> String {
        format!("slow({})", self.inner.describe())
    }

    fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<Step<SimKvCache>> {
        std::thread::sleep(self.step);
        self.inner.prefill(tokens, prompt_len)
    }

    fn decode(&self, token: i32, pos: i32, cache: &SimKvCache) -> Result<Step<SimKvCache>> {
        std::thread::sleep(self.step);
        self.inner.decode(token, pos, cache)
    }

    fn decode_batch(
        &self,
        reqs: &[BatchItem<'_, SimKvCache>],
    ) -> Result<Vec<Step<SimKvCache>>> {
        std::thread::sleep(self.step);
        self.inner.decode_batch(reqs)
    }
}

/// Engine + aggregator + HTTP front-end on an ephemeral port.
fn start_http<B: Backend + Send + Sync + 'static>(
    backend: B,
    scfg: ServerConfig,
) -> (Arc<EngineHandle<B>>, HttpServer, PromAggregator) {
    let (rec_tx, rec_rx) = channel();
    let aggregator = PromAggregator::spawn(rec_rx);
    let handle = Arc::new(Engine::start_with_sink(backend, scfg, Some(rec_tx)).unwrap());
    let http = HttpServer::start(
        "127.0.0.1:0",
        Arc::clone(&handle),
        aggregator.counters(),
        HttpConfig::default(),
    )
    .unwrap();
    (handle, http, aggregator)
}

/// Stop the front-end and shut the engine down for the merged report.
fn finish<B: Backend>(handle: Arc<EngineHandle<B>>, http: HttpServer) -> Result<ServeReport> {
    http.stop();
    let handle = Arc::try_unwrap(handle).ok().expect("HTTP workers joined");
    handle.shutdown()
}

/// One blocking HTTP/1.1 exchange over a raw `TcpStream`: returns the
/// status line, the raw header block, and the (de-chunked) body.
fn http_request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> (String, String, String) {
    let mut conn = TcpStream::connect(addr).expect("connect");
    write!(
        conn,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )
    .expect("send request");
    let mut raw = Vec::new();
    conn.read_to_end(&mut raw).expect("read response");
    let text = String::from_utf8(raw).expect("UTF-8 response");
    let head_end = text.find("\r\n\r\n").expect("header terminator");
    let head = text[..head_end].to_string();
    let status = head.lines().next().unwrap_or("").to_string();
    let payload = &text[head_end + 4..];
    let body = if head.to_ascii_lowercase().contains("transfer-encoding: chunked") {
        dechunk(payload)
    } else {
        payload.to_string()
    };
    (status, head, body)
}

/// Reassemble a chunked transfer-encoding payload.
fn dechunk(payload: &str) -> String {
    let mut out = String::new();
    let mut rest = payload;
    loop {
        let Some(nl) = rest.find("\r\n") else { break };
        let size = usize::from_str_radix(rest[..nl].trim(), 16).expect("chunk size");
        if size == 0 {
            break;
        }
        let start = nl + 2;
        out.push_str(&rest[start..start + size]);
        rest = &rest[start + size + 2..]; // skip the chunk's trailing CRLF
    }
    out
}

/// Open a streaming generate request and hand back the raw socket
/// (the caller reads the chunked NDJSON off it).
fn open_stream(addr: SocketAddr, body: &str) -> TcpStream {
    let mut conn = TcpStream::connect(addr).expect("connect");
    conn.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
    write!(
        conn,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )
    .expect("send request");
    conn
}

/// Read raw lines until the first NDJSON event line and return it
/// (chunk framing and header lines never contain `"event":`).
fn read_until_event(reader: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("stream read");
        assert!(n > 0, "stream closed before any event line");
        if line.contains("\"event\":") {
            return line;
        }
    }
}

/// The engine request id carried by every NDJSON event line.
fn extract_id(line: &str) -> u64 {
    let json = Json::parse(line.trim()).expect("event line is JSON");
    json.get("id").and_then(Json::as_f64).expect("event line carries the id") as u64
}

/// The terminal event's `tokens` array, as i32.
fn terminal_tokens(line: &Json) -> Vec<i32> {
    line.get("tokens")
        .and_then(Json::as_arr)
        .expect("terminal carries tokens")
        .iter()
        .map(|t| t.as_f64().expect("token is a number") as i32)
        .collect()
}

#[test]
fn generate_streams_ndjson_and_matches_the_reference() {
    let (handle, http, aggregator) = start_http(backend(), cfg(2, 2, 1));
    let addr = http.local_addr();

    let (status, head, body) = http_request(
        addr,
        "POST",
        "/v1/generate",
        r#"{"prompt":[3,1,4,1,5],"max_new_tokens":6}"#,
    );
    assert!(status.contains("200"), "got {status}");
    assert!(head.to_ascii_lowercase().contains("transfer-encoding: chunked"), "head: {head}");

    let events: Vec<Json> =
        body.lines().map(|l| Json::parse(l).expect("valid NDJSON line")).collect();
    assert!(events.len() >= 2, "got {body}");
    assert_eq!(events[0].get("event").and_then(Json::as_str), Some("prefilled"));
    let last = events.last().unwrap();
    assert_eq!(last.get("event").and_then(Json::as_str), Some("retired"));
    assert_eq!(last.get("finish").and_then(Json::as_str), Some("length"));

    // Streamed tokens (prefilled + per-round) == terminal result ==
    // the direct reference generation.
    let streamed: Vec<i32> = events
        .iter()
        .filter_map(|e| match e.get("event").and_then(Json::as_str) {
            Some("prefilled") | Some("token") => {
                e.get("token").and_then(Json::as_f64).map(|t| t as i32)
            }
            _ => None,
        })
        .collect();
    let direct = backend().generate(&[3, 1, 4, 1, 5], 6).unwrap();
    assert_eq!(streamed, direct);
    assert_eq!(terminal_tokens(last), direct);

    let report = finish(handle, http).unwrap();
    assert_eq!(report.requests, 1);
    assert_eq!(aggregator.finish(), 1);
}

#[test]
fn generate_honors_stop_tokens() {
    let reference = backend();
    let full = reference.generate(&[4, 4, 8], 10).unwrap();
    let stop = full[3];
    let expected = reference.generate_until(&[4, 4, 8], 10, &[stop]).unwrap();

    let (handle, http, aggregator) = start_http(backend(), cfg(1, 1, 1));
    let addr = http.local_addr();
    let body = format!("{{\"prompt\":[4,4,8],\"max_new_tokens\":10,\"stop_tokens\":[{stop}]}}");
    let (status, _head, resp) = http_request(addr, "POST", "/v1/generate", &body);
    assert!(status.contains("200"));
    let last = Json::parse(resp.lines().last().unwrap()).unwrap();
    assert_eq!(last.get("finish").and_then(Json::as_str), Some("stop"));
    assert_eq!(terminal_tokens(&last), expected);

    let report = finish(handle, http).unwrap();
    assert_eq!(report.completed, 1);
    assert_eq!(aggregator.finish(), 1);
}

#[test]
fn concurrent_clients_stream_and_the_scrape_matches_the_report() {
    let (handle, http, aggregator) = start_http(backend(), cfg(2, 2, 2));
    let addr = http.local_addr();

    let prompts: Vec<Vec<i32>> =
        vec![vec![1, 2, 3], vec![4, 5], vec![6, 7, 8, 9], vec![10, 11]];
    let clients: Vec<_> = prompts
        .iter()
        .cloned()
        .map(|prompt| {
            std::thread::spawn(move || {
                let body = format!("{{\"prompt\":{prompt:?},\"max_new_tokens\":7}}");
                let (status, _head, resp) = http_request(addr, "POST", "/v1/generate", &body);
                assert!(status.contains("200"), "got {status}");
                let last = Json::parse(resp.lines().last().expect("terminal line")).unwrap();
                assert_eq!(last.get("event").and_then(Json::as_str), Some("retired"));
                (prompt, terminal_tokens(&last))
            })
        })
        .collect();
    let reference = backend();
    for client in clients {
        let (prompt, tokens) = client.join().expect("client thread");
        assert_eq!(tokens, reference.generate(&prompt, 7).unwrap(), "prompt {prompt:?}");
    }

    // Counters race the final record sends by microseconds; poll the
    // scrape until all four retirements landed.
    let deadline = Instant::now() + Duration::from_secs(10);
    let scrape = loop {
        let (status, head, scrape) = http_request(addr, "GET", "/metrics", "");
        assert!(status.contains("200"));
        assert!(head.contains("text/plain"), "head: {head}");
        if scrape.contains("tsar_requests_total{finish=\"length\"} 4") {
            break scrape;
        }
        assert!(Instant::now() < deadline, "scrape never saw 4 retirements:\n{scrape}");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert!(scrape.contains("tsar_queue_depth 0"), "scrape:\n{scrape}");

    let counters = aggregator.counters();
    let report = finish(handle, http).unwrap();
    assert_eq!(report.requests, 4);
    assert_eq!(report.completed, 4);

    // Scrape counters and the merged report agree: outcomes, tokens,
    // and lane busy seconds (Σ prefill+decode == Σ lane clocks, up to
    // the microsecond truncation per record).
    let tokens_line = scrape
        .lines()
        .find(|l| l.starts_with("tsar_tokens_emitted_total"))
        .expect("tokens series");
    let scraped_tokens: usize =
        tokens_line.rsplit(' ').next().unwrap().parse().expect("token count");
    assert_eq!(scraped_tokens, report.total_tokens);
    assert!(
        (counters.busy_seconds() - report.lane_clock_sum_s).abs() < 1e-3,
        "busy {} vs lane clocks {}",
        counters.busy_seconds(),
        report.lane_clock_sum_s
    );
    assert_eq!(aggregator.finish(), 4);
}

#[test]
fn mid_stream_disconnect_cancels_the_session() {
    let slow = SlowBackend { inner: backend(), step: Duration::from_millis(10) };
    let (handle, http, aggregator) = start_http(slow, cfg(1, 1, 1));
    let addr = http.local_addr();

    // Hand-rolled streaming client: read a few token lines, then drop
    // the connection mid-generation (55 tokens at 10 ms/round leave
    // hundreds of milliseconds of stream to abandon).
    {
        let body = r#"{"prompt":[2,3,4],"max_new_tokens":55}"#;
        let mut conn = TcpStream::connect(addr).unwrap();
        write!(
            conn,
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        let mut token_lines = 0;
        while token_lines < 4 {
            line.clear();
            if reader.read_line(&mut line).unwrap() == 0 {
                break;
            }
            if line.contains("\"event\":\"prefilled\"") || line.contains("\"event\":\"token\"") {
                token_lines += 1;
            }
        }
        assert!(token_lines >= 1, "never saw a streamed token");
        // The connection drops here, mid-stream.
    }

    // The handler notices the dead socket on a later chunk write,
    // cancels the ticket, and the lane retires the session at a round
    // boundary.  Wait for the retirement record to land.
    let counters = aggregator.counters();
    let deadline = Instant::now() + Duration::from_secs(10);
    while counters.queue_depth() != 0 {
        assert!(Instant::now() < deadline, "disconnect cancellation never landed");
        std::thread::sleep(Duration::from_millis(20));
    }

    let report = finish(handle, http).unwrap();
    assert_eq!(report.requests, 1);
    assert_eq!(report.cancelled, 1, "disconnect must cancel the in-flight session");
    assert!(
        report.total_tokens < 55,
        "cancelled early, yet {} tokens were generated",
        report.total_tokens
    );
    assert_eq!(aggregator.finish(), 1);
}

/// A backend whose decode panics past a position threshold — kills the
/// serving lane mid-session.
struct PanickyBackend {
    inner: SimBackend,
    panic_at_pos: i32,
}

impl Backend for PanickyBackend {
    type Cache = SimKvCache;

    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn describe(&self) -> String {
        format!("panicky({})", self.inner.describe())
    }

    fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<Step<SimKvCache>> {
        self.inner.prefill(tokens, prompt_len)
    }

    fn decode(&self, token: i32, pos: i32, cache: &SimKvCache) -> Result<Step<SimKvCache>> {
        assert!(pos < self.panic_at_pos, "injected decode panic at pos {pos}");
        self.inner.decode(token, pos, cache)
    }

    fn decode_batch(
        &self,
        reqs: &[BatchItem<'_, SimKvCache>],
    ) -> Result<Vec<Step<SimKvCache>>> {
        for r in reqs {
            assert!(r.pos < self.panic_at_pos, "injected decode panic at pos {}", r.pos);
        }
        self.inner.decode_batch(reqs)
    }
}

#[test]
fn lane_death_mid_stream_still_ends_with_a_terminal_line() {
    // The serving lane panics mid-session: the ticket stream closes
    // without a terminal event, but the NDJSON contract is one
    // terminal line per response — the front-end must synthesize the
    // `failed` line instead of ending the chunked body cleanly after a
    // token event.
    let panicky = PanickyBackend { inner: backend(), panic_at_pos: 12 };
    let (handle, http, aggregator) = start_http(panicky, cfg(1, 1, 1));
    let addr = http.local_addr();

    let (status, _head, body) = http_request(
        addr,
        "POST",
        "/v1/generate",
        r#"{"prompt":[1,1,1,1,1,1,1,1,1,1],"max_new_tokens":20}"#,
    );
    assert!(status.contains("200"), "got {status}");
    let last = Json::parse(body.lines().last().expect("terminal line")).unwrap();
    assert_eq!(last.get("event").and_then(Json::as_str), Some("failed"));
    assert_eq!(last.get("finish").and_then(Json::as_str), Some("failed"));
    let error = last.get("error").and_then(Json::as_str).expect("failure reason");
    assert!(error.contains("without a terminal event"), "got {error}");

    http.stop();
    let handle = Arc::try_unwrap(handle).ok().expect("HTTP workers joined");
    // The lane died, so there is nothing to report — but shutdown must
    // return the lane error instead of panicking.
    let err = handle.shutdown().unwrap_err();
    assert!(err.to_string().contains("injected decode panic"), "got {err}");
    assert_eq!(aggregator.finish(), 0, "the dead lane never retired the session");
}

#[test]
fn queue_cap_sheds_with_429_and_books_a_rejection() {
    // One slow lane, one KV slot, one queue slot: the first stream
    // occupies the lane, the second parks in the queue, and the third
    // submission must be shed with HTTP 429 — booked engine-side as a
    // failed retirement *and* a rejection.
    let slow = SlowBackend { inner: backend(), step: Duration::from_millis(100) };
    let scfg = ServerConfig { max_batch: 1, kv_slots: 1, workers: 1, queue_cap: Some(1) };
    let (handle, http, aggregator) = start_http(slow, scfg);
    let addr = http.local_addr();

    // Wait for the first stream's prefill line, so it is off the queue
    // and holding the only lane.
    let s1 = open_stream(addr, r#"{"prompt":[1,2,3],"max_new_tokens":40}"#);
    let mut r1 = BufReader::new(s1);
    read_until_event(&mut r1);

    // The second stream fills the single queue slot.
    let s2 = open_stream(addr, r#"{"prompt":[4,5,6],"max_new_tokens":40}"#);
    std::thread::sleep(Duration::from_millis(300));

    let (status, _head, body) =
        http_request(addr, "POST", "/v1/generate", r#"{"prompt":[7,8],"max_new_tokens":4}"#);
    assert!(status.contains("429"), "got {status}");
    assert!(body.contains("queue full (queue_cap 1)"), "got {body}");

    // Dropping both live streams disconnect-cancels them; wait until
    // the rejection and both cancellations land on the scrape.
    drop(r1);
    drop(s2);
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let (status, _head, scrape) = http_request(addr, "GET", "/metrics", "");
        assert!(status.contains("200"), "got {status}");
        if scrape.contains("tsar_rejections_total 1") && scrape.contains("tsar_queue_depth 0") {
            break;
        }
        assert!(Instant::now() < deadline, "shed never hit the scrape:\n{scrape}");
        std::thread::sleep(Duration::from_millis(20));
    }

    let report = finish(handle, http).unwrap();
    assert_eq!(report.requests, 3);
    assert_eq!(report.rejected, 1, "the full queue shed exactly one submission");
    assert_eq!(report.failed, 1, "the shed books as a failed retirement");
    assert_eq!(report.cancelled, 2, "both dropped streams disconnect-cancelled");
    assert_eq!(aggregator.finish(), 3);
}

#[test]
fn cancel_route_cancels_a_live_stream() {
    let slow = SlowBackend { inner: backend(), step: Duration::from_millis(100) };
    let (handle, http, aggregator) = start_http(slow, cfg(1, 1, 1));
    let addr = http.local_addr();

    // Start a long stream and learn its engine id from the first event
    // line (every NDJSON line carries it).
    let s = open_stream(addr, r#"{"prompt":[5,6,7],"max_new_tokens":40}"#);
    let mut reader = BufReader::new(s);
    let first = read_until_event(&mut reader);
    let id = extract_id(&first);

    // Unknown ids answer 404 without touching the live session.
    let (status, _head, _body) = http_request(addr, "POST", "/v1/cancel", r#"{"id": 999999}"#);
    assert!(status.contains("404"), "got {status}");

    // Cancelling the real id over a *different* connection acks 200
    // and the original stream ends with a cancelled terminal line.
    let body = format!("{{\"id\": {id}}}");
    let (status, _head, reply) = http_request(addr, "POST", "/v1/cancel", &body);
    assert!(status.contains("200"), "got {status}");
    assert_eq!(reply, format!("cancelling {id}\n"));

    let mut line = String::new();
    let saw_cancelled = loop {
        line.clear();
        if reader.read_line(&mut line).expect("stream read") == 0 {
            break false;
        }
        if line.contains("\"event\":\"cancelled\"") {
            break true;
        }
    };
    assert!(saw_cancelled, "stream never delivered the cancelled terminal");

    drop(reader);
    let report = finish(handle, http).unwrap();
    assert_eq!(report.requests, 1);
    assert_eq!(report.cancelled, 1, "the cancel route retired the session as cancelled");
    assert!(report.total_tokens < 40, "cancel must land well before the token budget");
    assert_eq!(aggregator.finish(), 1);
}

#[test]
fn healthz_metrics_and_error_routes() {
    let (handle, http, aggregator) = start_http(backend(), cfg(1, 1, 1));
    let addr = http.local_addr();

    let (status, _head, body) = http_request(addr, "GET", "/healthz", "");
    assert!(status.contains("200"));
    assert_eq!(body, "ok\n");

    let (status, _head, _body) = http_request(addr, "GET", "/nope", "");
    assert!(status.contains("404"), "got {status}");

    let (status, _head, body) = http_request(addr, "POST", "/v1/generate", "{not json");
    assert!(status.contains("400"), "got {status}");
    assert!(body.contains("bad request"), "got {body}");

    let (status, _head, _body) = http_request(addr, "GET", "/v1/generate", "");
    assert!(status.contains("405"), "got {status}");

    let (status, _head, _body) = http_request(addr, "POST", "/metrics", "");
    assert!(status.contains("405"), "got {status}");

    // A session that fails admission streams a single failed terminal
    // event (still HTTP 200: the session itself is the failure).
    let (status, _head, body) =
        http_request(addr, "POST", "/v1/generate", r#"{"prompt":[1],"max_new_tokens":500}"#);
    assert!(status.contains("200"), "got {status}");
    let last = Json::parse(body.lines().last().unwrap()).unwrap();
    assert_eq!(last.get("event").and_then(Json::as_str), Some("failed"));
    let error = last.get("error").and_then(Json::as_str).expect("failure reason");
    assert!(error.contains("KV capacity"), "got {error}");

    // The rejection is visible on the scrape — counted in
    // `tsar_rejections_total` *and* retired out of `tsar_queue_depth`,
    // so shed requests never read as forever-queued.  (Poll: the
    // rejection record races the scrape by microseconds.)
    let deadline = Instant::now() + Duration::from_secs(10);
    let scrape = loop {
        let (status, _head, scrape) = http_request(addr, "GET", "/metrics", "");
        assert!(status.contains("200"), "got {status}");
        if scrape.contains("tsar_rejections_total 1") {
            break scrape;
        }
        assert!(Instant::now() < deadline, "rejection never hit the scrape:\n{scrape}");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert!(scrape.contains("tsar_queue_depth 0"), "scrape:\n{scrape}");

    let report = finish(handle, http).unwrap();
    assert_eq!(report.requests, 1, "only the rejected session was submitted");
    assert_eq!(report.failed, 1);
    assert_eq!(report.rejected, 1, "the shutdown report agrees with tsar_rejections_total");
    assert_eq!(aggregator.finish(), 1, "rejections stream a record too");
}
