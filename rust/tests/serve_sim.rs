//! Default-build end-to-end serving tests: drive the sharded
//! `coordinator::Server` on the dependency-free `SimBackend` through
//! the full lifecycle (dispatch → admission → prefill → batched decode
//! rounds → retire → clock merge), and check that the §III-D adaptive
//! selector shapes the plans the serving loop runs on (DESIGN.md §3).

use std::sync::mpsc::channel;

use tsar::config::platforms::Platform;
use tsar::coordinator::{
    serve_all, FinishReason, Request, RequestRecord, Server, ServerConfig,
};
use tsar::kernels::Dataflow;
use tsar::runtime::{Backend, BatchItem, SimBackend, SimBackendConfig};

fn backend() -> SimBackend {
    SimBackend::by_name(
        "BitNet-2B-4T",
        Platform::workstation(),
        SimBackendConfig { prefill_len: 16, max_seq: 64, threads: 0, seed: 3 },
    )
    .expect("zoo model")
}

fn cfg(max_batch: usize, kv_slots: usize, workers: usize) -> ServerConfig {
    ServerConfig { max_batch, kv_slots, workers, queue_cap: None }
}

#[test]
fn selector_picks_op_for_gemv_shaped_decode_steps() {
    // §III-D: every decode-plan site is GEMV-shaped (N = 1), and the
    // empirical compile-time selection must prefer the output-persistent
    // dataflow for the high-M layers that dominate decode.
    let b = backend();
    let plan = b.decode_plan();
    assert!(plan.layers.iter().all(|l| l.shape.is_gemv()));
    let op_share = plan
        .layers
        .iter()
        .filter(|l| l.kernel.dataflow == Dataflow::Op)
        .count() as f64
        / plan.layers.len() as f64;
    assert!(op_share >= 0.5, "decode OP share {op_share}");
    // The fused FFN gate/up GEMV (1×2560×13824) is the paper's canonical
    // OP-winning shape.
    let gate = plan.layers.iter().find(|l| l.site == "ffn-gate-up").unwrap();
    assert_eq!(gate.kernel.dataflow, Dataflow::Op, "{}", gate.describe());
}

#[test]
fn bad_config_is_an_error_not_a_panic() {
    let e = Server::new(backend(), cfg(4, 2, 1)).err().expect("must reject");
    assert!(e.to_string().contains("kv_slots"), "got {e}");
    assert!(Server::new(backend(), cfg(0, 4, 1)).is_err());
    assert!(Server::new(backend(), cfg(1, 1, 0)).is_err());
}

#[test]
fn server_runs_admission_prefill_decode_retire() {
    let b = backend();
    let vocab = b.config().vocab as i32;
    let server = Server::new(b, cfg(3, 3, 1)).expect("config");
    let requests: Vec<Request> = (0..6u64)
        .map(|id| {
            Request::new(
                id,
                vec![
                    (1 + id as i32) % vocab,
                    (3 + 2 * id as i32) % vocab,
                    (7 + id as i32) % vocab,
                ],
                5,
            )
        })
        .collect();
    let report = serve_all(&server, requests).expect("serve");
    assert_eq!(report.requests, 6);
    assert_eq!(report.completed, 6, "all requests complete normally");
    assert_eq!(report.cancelled + report.failed, 0);
    assert_eq!(report.total_tokens, 30);
    assert!(report.tokens_per_s > 0.0);
    assert!(report.prefill.p95 >= report.prefill.p50);

    // Simulated timing is plumbed end-to-end: every prefill costs
    // exactly one padded-window pass of the prefill plan.
    let prefill_pass = server.backend().prefill_plan().pass_seconds();
    assert!(
        (report.prefill.mean - prefill_pass).abs() <= prefill_pass * 1e-9,
        "prefill mean {} != plan pass {}",
        report.prefill.mean,
        prefill_pass
    );
    // 6 requests in two waves of 3: per wave, 3 prefills then 4 batched
    // decode rounds of width 3 — 6 prefills + 8 rounds on the virtual
    // clock.
    let round3 = server.backend().decode_round_plan(3).pass_seconds();
    let expect_wall = 6.0 * prefill_pass + 8.0 * round3;
    assert!(
        (report.wall_s - expect_wall).abs() <= expect_wall * 1e-9,
        "wall {} != {}",
        report.wall_s,
        expect_wall
    );
    // One lane served everything; its width histogram saw exactly the
    // 8 width-3 rounds.
    assert_eq!(report.lanes.len(), 1);
    assert_eq!(report.lanes[0].requests, 6);
    assert_eq!(report.lanes[0].rounds, 8);
    assert_eq!(report.lanes[0].width_hist[3], 8);
    assert!((report.lanes[0].utilization - 1.0).abs() < 1e-9);
}

#[test]
fn tight_batch_degenerates_to_sequential_serving() {
    let b = backend();
    let server = Server::new(b, cfg(1, 1, 1)).expect("config");
    let requests: Vec<Request> =
        (0..3u64).map(|id| Request::new(id, vec![2, 4, 6], 4)).collect();
    let report = serve_all(&server, requests).expect("serve");
    assert_eq!(report.requests, 3);
    assert_eq!(report.total_tokens, 12);
    // Width-1 rounds cost exactly a batch-1 decode step, so the clock
    // is the fully serialized sum.
    let prefill_pass = server.backend().prefill_plan().pass_seconds();
    let decode_pass = server.backend().decode_plan().pass_seconds();
    let expect_wall = 3.0 * (prefill_pass + 3.0 * decode_pass);
    assert!(
        (report.wall_s - expect_wall).abs() <= expect_wall * 1e-9,
        "wall {} != {}",
        report.wall_s,
        expect_wall
    );
}

#[test]
fn served_tokens_match_direct_generation() {
    // The scheduler must not perturb per-sequence results: batched
    // decoding of many sequences produces exactly what Backend::generate
    // produces for each prompt alone (KV state is threaded correctly).
    let b = backend();
    let prompts: Vec<Vec<i32>> = vec![vec![1, 2, 3], vec![9, 8], vec![5, 5, 5, 5]];
    let direct: Vec<Vec<i32>> =
        prompts.iter().map(|p| b.generate(p, 4).unwrap()).collect();

    let server = Server::new(b, cfg(3, 3, 1)).expect("config");
    let requests: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(id, p)| Request::new(id as u64, p.clone(), 4))
        .collect();
    let (res_tx, res_rx) = channel();
    server.run_preloaded(requests, res_tx).expect("serve");
    let mut served: Vec<(u64, Vec<i32>)> = res_rx
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect();
    served.sort_by_key(|(id, _)| *id);
    for (id, tokens) in served {
        assert_eq!(tokens, direct[id as usize], "request {id}");
    }
}

#[test]
fn decode_batch_is_token_identical_to_serialized_batch1() {
    // Determinism of the batched surface over a whole generation: the
    // same prompts stepped through decode_batch rounds yield
    // token-for-token what the serialized batch-1 path (generate)
    // yields.
    let b = backend();
    let prompts: Vec<Vec<i32>> = vec![vec![4, 1], vec![2, 7, 1], vec![11; 5], vec![3]];
    let n_new = 6usize;
    let direct: Vec<Vec<i32>> =
        prompts.iter().map(|p| b.generate(p, n_new).unwrap()).collect();

    let p = b.config().prefill_len;
    let mut tokens: Vec<Vec<i32>> = Vec::new();
    let mut caches = Vec::new();
    let mut positions: Vec<i32> = Vec::new();
    for prompt in &prompts {
        let mut padded = vec![0i32; p];
        padded[..prompt.len()].copy_from_slice(prompt);
        let step = b.prefill(&padded, prompt.len() as i32).unwrap();
        tokens.push(vec![step.next_token]);
        caches.push(step.cache);
        positions.push(prompt.len() as i32);
    }
    for _ in 1..n_new {
        let steps = {
            let items: Vec<BatchItem<'_, _>> = (0..prompts.len())
                .map(|i| BatchItem {
                    token: *tokens[i].last().unwrap(),
                    pos: positions[i],
                    cache: &caches[i],
                })
                .collect();
            b.decode_batch(&items).unwrap()
        };
        for (i, step) in steps.into_iter().enumerate() {
            tokens[i].push(step.next_token);
            caches[i] = step.cache;
            positions[i] += 1;
        }
    }
    assert_eq!(tokens, direct, "batched decode diverged from batch-1");
}

#[test]
fn multi_worker_e2e_merges_lane_clocks() {
    // All requests retire across 3 lanes, and the merged virtual clock
    // is the slowest lane (≤ the sum of per-lane clocks).
    let b = backend();
    let prompts: Vec<Vec<i32>> =
        (0..9).map(|i| vec![1 + i, 2, 3 + (i % 4)]).collect();
    let direct: Vec<Vec<i32>> =
        prompts.iter().map(|pr| b.generate(pr, 5).unwrap()).collect();

    let server = Server::new(b, cfg(2, 2, 3)).expect("config");
    let requests: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(id, pr)| Request::new(id as u64, pr.clone(), 5))
        .collect();
    let (res_tx, res_rx) = channel();
    let report = server.run_preloaded(requests, res_tx).expect("serve");

    assert_eq!(report.requests, 9, "every request must retire");
    let mut served: Vec<(u64, Vec<i32>)> =
        res_rx.into_iter().map(|r| (r.id, r.tokens)).collect();
    served.sort_by_key(|(id, _)| *id);
    assert_eq!(served.len(), 9);
    for (id, tokens) in served {
        assert_eq!(tokens, direct[id as usize], "request {id}");
    }

    assert_eq!(report.lanes.len(), 3);
    assert_eq!(report.lanes.iter().map(|l| l.requests).sum::<usize>(), 9);
    let max_clock = report
        .lanes
        .iter()
        .map(|l| l.clock_s)
        .fold(0.0f64, f64::max);
    assert!(
        (report.wall_s - max_clock).abs() <= max_clock * 1e-12,
        "merged timeline must be the slowest lane"
    );
    assert!(
        report.wall_s <= report.lane_clock_sum_s * (1.0 + 1e-12),
        "merged clock {} exceeds lane sum {}",
        report.wall_s,
        report.lane_clock_sum_s
    );
    // 9 requests round-robined over 3 lanes: every lane did real work.
    for l in &report.lanes {
        assert_eq!(l.requests, 3, "lane {} shard", l.lane);
        assert!(l.clock_s > 0.0);
        assert!(l.utilization > 0.0 && l.utilization <= 1.0 + 1e-12);
    }
}

#[test]
fn sharded_batched_serving_beats_single_lane_batch1() {
    // The acceptance workload: the same request set served at
    // --workers 4 with batched decode must report a strictly lower
    // simulated makespan than --workers 1 batch-1, with identical
    // generated tokens per request.
    let prompts: Vec<Vec<i32>> = (0..8).map(|i| vec![2 + i, 5, 9 - (i % 3)]).collect();
    let max_new = 6usize;
    let serve = |config: ServerConfig| {
        let server = Server::new(backend(), config).expect("config");
        let requests: Vec<Request> = prompts
            .iter()
            .enumerate()
            .map(|(id, pr)| Request::new(id as u64, pr.clone(), max_new))
            .collect();
        let (res_tx, res_rx) = channel();
        let report = server.run_preloaded(requests, res_tx).expect("serve");
        let mut served: Vec<(u64, Vec<i32>)> =
            res_rx.into_iter().map(|r| (r.id, r.tokens)).collect();
        served.sort_by_key(|(id, _)| *id);
        (report, served)
    };

    let (serial, tokens_serial) = serve(cfg(1, 1, 1));
    let (sharded, tokens_sharded) = serve(cfg(4, 4, 4));

    assert_eq!(tokens_serial, tokens_sharded, "sharding changed tokens");
    assert!(
        sharded.wall_s < serial.wall_s,
        "sharded batched makespan {} not below batch-1 single-lane {}",
        sharded.wall_s,
        serial.wall_s
    );
    assert_eq!(sharded.lanes.len(), 4);
}

#[test]
fn idle_lanes_do_not_pollute_the_merged_clock() {
    // More lanes than requests: unused lanes count zero busy time (a
    // lane clock is busy time, never blocked real time), so the merged
    // makespan is exactly the one busy lane's clock.
    let b = backend();
    let server = Server::new(b, cfg(2, 2, 3)).expect("config");
    let report =
        serve_all(&server, vec![Request::new(0, vec![1, 2, 3], 4)]).expect("serve");
    assert_eq!(report.requests, 1);
    assert_eq!(report.lanes.len(), 3);
    assert!(report.lanes[0].clock_s > 0.0);
    assert_eq!(report.lanes[1].clock_s, 0.0);
    assert_eq!(report.lanes[2].clock_s, 0.0);
    assert!(
        (report.wall_s - report.lanes[0].clock_s).abs() <= report.wall_s * 1e-12,
        "makespan must be the busy lane's clock"
    );
    assert!(
        (report.lane_clock_sum_s - report.wall_s).abs() <= report.wall_s * 1e-12,
        "idle lanes must not add busy time"
    );
}

#[test]
fn metrics_sink_streams_one_record_per_request() {
    let b = backend();
    let (rec_tx, rec_rx) = channel::<RequestRecord>();
    let server = Server::new(b, cfg(2, 2, 2))
        .expect("config")
        .with_metrics_sink(rec_tx);
    let requests: Vec<Request> =
        (0..5u64).map(|id| Request::new(id, vec![1 + id as i32, 4], 3)).collect();
    let report = serve_all(&server, requests).expect("serve");
    drop(server); // close the sink's last sender
    let mut records: Vec<RequestRecord> = rec_rx.into_iter().collect();
    records.sort_by_key(|r| r.id);

    assert_eq!(report.requests, 5);
    assert_eq!(records.len(), 5, "one record per retired request");
    for (i, rec) in records.iter().enumerate() {
        assert_eq!(rec.id, i as u64);
        assert!(rec.lane.is_some_and(|l| l < 2), "served records carry their lane");
        assert_eq!(
            rec.executed_lane, rec.lane,
            "preloaded runs never migrate a request off its assigned lane"
        );
        assert!(rec.queue_wait_s >= 0.0, "queue wait is stamped at pull time");
        assert_eq!(rec.tokens, 3);
        assert_eq!(rec.finish, FinishReason::Length);
        assert!(rec.prefill_s > 0.0 && rec.decode_s > 0.0);
        assert!(rec.total_s >= rec.prefill_s + rec.decode_s - 1e-12);
        let plan = rec.plan.as_deref().expect("SimBackend exposes its plan");
        assert!(plan.contains("ffn-gate-up"), "plan {plan:?}");
    }
}

#[test]
fn max_seq_guard_caps_generation() {
    // A KV window too small for the full budget retires the sequence at
    // the cap instead of erroring the engine.
    let b = SimBackend::by_name(
        "BitNet-2B-4T",
        Platform::workstation(),
        SimBackendConfig { prefill_len: 8, max_seq: 10, threads: 0, seed: 3 },
    )
    .unwrap();
    let server = Server::new(b, cfg(1, 1, 1)).expect("config");
    let report =
        serve_all(&server, vec![Request::new(0, vec![1, 2, 3], 50)]).expect("serve");
    assert_eq!(report.requests, 1);
    assert!(report.total_tokens < 50);
}
