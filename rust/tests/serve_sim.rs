//! Default-build end-to-end serving tests: drive `coordinator::Server`
//! on the dependency-free `SimBackend` through the full lifecycle
//! (admission → prefill → interleaved decode → retire), and check that
//! the §III-D adaptive selector shapes the plan the serving loop runs on
//! (DESIGN.md §3).

use tsar::config::platforms::Platform;
use tsar::coordinator::{serve::serve_all, Request, Server, ServerConfig};
use tsar::kernels::Dataflow;
use tsar::runtime::{Backend, SimBackend, SimBackendConfig};

fn backend() -> SimBackend {
    SimBackend::by_name(
        "BitNet-2B-4T",
        Platform::workstation(),
        SimBackendConfig { prefill_len: 16, max_seq: 64, threads: 0, seed: 3 },
    )
    .expect("zoo model")
}

#[test]
fn selector_picks_op_for_gemv_shaped_decode_steps() {
    // §III-D: every decode-plan site is GEMV-shaped (N = 1), and the
    // empirical compile-time selection must prefer the output-persistent
    // dataflow for the high-M layers that dominate decode.
    let b = backend();
    let plan = b.decode_plan();
    assert!(plan.layers.iter().all(|l| l.shape.is_gemv()));
    let op_share = plan
        .layers
        .iter()
        .filter(|l| l.kernel.dataflow == Dataflow::Op)
        .count() as f64
        / plan.layers.len() as f64;
    assert!(op_share >= 0.5, "decode OP share {op_share}");
    // The fused FFN gate/up GEMV (1×2560×13824) is the paper's canonical
    // OP-winning shape.
    let gate = plan.layers.iter().find(|l| l.site == "ffn-gate-up").unwrap();
    assert_eq!(gate.kernel.dataflow, Dataflow::Op, "{}", gate.describe());
}

#[test]
fn server_runs_admission_prefill_decode_retire() {
    let b = backend();
    let vocab = b.config().vocab as i32;
    let server = Server::new(b, ServerConfig { max_batch: 3, kv_slots: 3 });
    let requests: Vec<Request> = (0..6u64)
        .map(|id| {
            Request::new(
                id,
                vec![
                    (1 + id as i32) % vocab,
                    (3 + 2 * id as i32) % vocab,
                    (7 + id as i32) % vocab,
                ],
                5,
            )
        })
        .collect();
    let report = serve_all(&server, requests).expect("serve");
    assert_eq!(report.requests, 6);
    assert_eq!(report.total_tokens, 30);
    assert!(report.tokens_per_s > 0.0);
    assert!(report.prefill.p95 >= report.prefill.p50);

    // Simulated timing is plumbed end-to-end: every prefill costs
    // exactly one padded-window pass of the prefill plan.
    let prefill_pass = server.backend().prefill_plan().pass_seconds();
    assert!(
        (report.prefill.mean - prefill_pass).abs() <= prefill_pass * 1e-9,
        "prefill mean {} != plan pass {}",
        report.prefill.mean,
        prefill_pass
    );
    // 6 requests × (1 prefill + 4 decode steps) on the virtual clock.
    let decode_pass = server.backend().decode_plan().pass_seconds();
    let expect_wall = 6.0 * (prefill_pass + 4.0 * decode_pass);
    assert!(
        (report.wall_s - expect_wall).abs() <= expect_wall * 1e-9,
        "wall {} != {}",
        report.wall_s,
        expect_wall
    );
}

#[test]
fn tight_batch_degenerates_to_sequential_serving() {
    let b = backend();
    let server = Server::new(b, ServerConfig { max_batch: 1, kv_slots: 1 });
    let requests: Vec<Request> =
        (0..3u64).map(|id| Request::new(id, vec![2, 4, 6], 4)).collect();
    let report = serve_all(&server, requests).expect("serve");
    assert_eq!(report.requests, 3);
    assert_eq!(report.total_tokens, 12);
}

#[test]
fn served_tokens_match_direct_generation() {
    // The scheduler must not perturb per-sequence results: interleaved
    // decoding of many sequences produces exactly what Backend::generate
    // produces for each prompt alone (KV state is threaded correctly).
    let b = backend();
    let prompts: Vec<Vec<i32>> = vec![vec![1, 2, 3], vec![9, 8], vec![5, 5, 5, 5]];
    let direct: Vec<Vec<i32>> =
        prompts.iter().map(|p| b.generate(p, 4).unwrap()).collect();

    let server = Server::new(b, ServerConfig { max_batch: 3, kv_slots: 3 });
    let (req_tx, req_rx) = std::sync::mpsc::channel();
    let (res_tx, res_rx) = std::sync::mpsc::channel();
    for (id, p) in prompts.iter().enumerate() {
        req_tx.send(Request::new(id as u64, p.clone(), 4)).unwrap();
    }
    drop(req_tx);
    server.run(req_rx, res_tx).expect("serve");
    let mut served: Vec<(u64, Vec<i32>)> = res_rx
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect();
    served.sort_by_key(|(id, _)| *id);
    for (id, tokens) in served {
        assert_eq!(tokens, direct[id as usize], "request {id}");
    }
}

#[test]
fn max_seq_guard_caps_generation() {
    // A KV window too small for the full budget retires the sequence at
    // the cap instead of erroring the engine.
    let b = SimBackend::by_name(
        "BitNet-2B-4T",
        Platform::workstation(),
        SimBackendConfig { prefill_len: 8, max_seq: 10, threads: 0, seed: 3 },
    )
    .unwrap();
    let server = Server::new(b, ServerConfig { max_batch: 1, kv_slots: 1 });
    let report =
        serve_all(&server, vec![Request::new(0, vec![1, 2, 3], 50)]).expect("serve");
    assert_eq!(report.requests, 1);
    assert!(report.total_tokens < 50);
}
