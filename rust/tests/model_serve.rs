//! End-to-end serving tests of the real-forward-pass model backend
//! (`runtime::ModelBackend`, DESIGN.md §2/§3): the streaming Engine,
//! the legacy preloaded server, stop tokens / deadlines / cancellation
//! against a real ternary transformer, interleaved batched-decode KV
//! state, and the HTTP front-end — every token compared against
//! `Backend::generate` on the same checkpoint.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tsar::config::IsaConfig;
use tsar::coordinator::{
    Engine, FinishReason, GenParams, GenerationRequest, HttpConfig, HttpServer, PromCounters,
    Request, Server, ServerConfig, TokenEvent,
};
use tsar::model::{Checkpoint, LinearEngine, SamplerConfig, TransformerConfig};
use tsar::runtime::{
    Backend, BatchItem, ModelBackend, ModelBackendConfig, ModelConfig, ModelKvCache, Step,
};
use tsar::util::error::Result;
use tsar::util::json::Json;

const PREFILL: usize = 8;
const MAX_SEQ: usize = 48;

/// A fresh model backend on the same seeded toy checkpoint: every call
/// reproduces bit-identical weights, so separately built backends are
/// valid references for each other.
fn backend() -> ModelBackend {
    let ckpt = Checkpoint::synthesize(TransformerConfig::toy(), 0x51ED).expect("toy checkpoint");
    ModelBackend::new(
        &ckpt,
        LinearEngine::native(IsaConfig::C2, 1).expect("native engine"),
        ModelBackendConfig {
            prefill_len: PREFILL,
            max_seq: MAX_SEQ,
            sampler: SamplerConfig::greedy(),
        },
    )
    .expect("model backend")
}

fn cfg(max_batch: usize, kv_slots: usize, workers: usize) -> ServerConfig {
    ServerConfig { max_batch, kv_slots, workers, queue_cap: None }
}

/// The model backend with real wall time added per step, so tests can
/// interrupt a generation mid-stream deterministically (the toy
/// transformer itself decodes in microseconds).
struct SlowModel {
    inner: ModelBackend,
    step: Duration,
}

impl Backend for SlowModel {
    type Cache = ModelKvCache;

    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn describe(&self) -> String {
        format!("slow({})", self.inner.describe())
    }

    fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<Step<ModelKvCache>> {
        std::thread::sleep(self.step);
        self.inner.prefill(tokens, prompt_len)
    }

    fn decode(&self, token: i32, pos: i32, cache: &ModelKvCache) -> Result<Step<ModelKvCache>> {
        std::thread::sleep(self.step);
        self.inner.decode(token, pos, cache)
    }

    fn decode_batch(
        &self,
        reqs: &[BatchItem<'_, ModelKvCache>],
    ) -> Result<Vec<Step<ModelKvCache>>> {
        std::thread::sleep(self.step);
        self.inner.decode_batch(reqs)
    }
}

#[test]
fn engine_stream_matches_backend_generate() {
    let prompt = vec![3, 5, 7];
    let max_new = 6usize;
    let direct = backend().generate(&prompt, max_new).unwrap();

    let handle = Engine::start(backend(), cfg(2, 2, 1)).unwrap();
    let ticket = handle.submit(GenerationRequest::new(prompt, max_new));
    let mut streamed: Vec<i32> = Vec::new();
    let mut terminal = None;
    while let Some(ev) = ticket.recv() {
        match ev {
            TokenEvent::Prefilled { token } => {
                assert!(streamed.is_empty(), "Prefilled must be the first event");
                streamed.push(token);
            }
            TokenEvent::Token { token, index } => {
                assert_eq!(index, streamed.len(), "token indices must be contiguous");
                streamed.push(token);
            }
            ev => {
                assert!(terminal.is_none(), "more than one terminal event");
                terminal = Some(ev.result().expect("terminal carries the result").clone());
            }
        }
    }
    let result = terminal.expect("stream must end with a terminal event");
    assert_eq!(result.finish, FinishReason::Length);
    assert_eq!(streamed, result.tokens, "streamed order must equal the joined result");
    assert_eq!(streamed, direct, "engine stream diverged from Backend::generate");

    let report = handle.shutdown().unwrap();
    assert_eq!(report.requests, 1);
    assert_eq!(report.completed, 1);
}

/// Serve a fixed workload through the preloaded server and return the
/// per-request token streams, sorted by request id.
fn serve_tokens(workers: usize, prompts: &[Vec<i32>], max_new: usize) -> Vec<(u64, Vec<i32>)> {
    let server = Server::new(backend(), cfg(2, 4, workers)).unwrap();
    let requests: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(id, p)| Request::new(id as u64, p.clone(), max_new))
        .collect();
    let (tx, rx) = channel();
    server.run_preloaded(requests, tx).unwrap();
    let mut served: Vec<(u64, Vec<i32>)> = rx.try_iter().map(|r| (r.id, r.tokens)).collect();
    served.sort_by_key(|(id, _)| *id);
    served
}

#[test]
fn worker_count_never_changes_greedy_model_tokens() {
    // Sharding across lanes changes round widths and decode interleaving
    // but must not change a single token of a greedy real-model run.
    let prompts: Vec<Vec<i32>> = vec![
        vec![3, 5, 7],
        vec![2, 4],
        vec![9, 1, 6, 2],
        vec![8],
        vec![5, 5, 5],
        vec![1, 2, 3, 4, 5],
    ];
    let max_new = 5usize;
    let one = serve_tokens(1, &prompts, max_new);
    let three = serve_tokens(3, &prompts, max_new);
    assert_eq!(one, three, "worker count changed the served model tokens");

    let reference = backend();
    for (id, tokens) in &one {
        let direct = reference.generate(&prompts[*id as usize], max_new).unwrap();
        assert_eq!(tokens, &direct, "request {id} diverged from Backend::generate");
    }
}

#[test]
fn stop_tokens_retire_a_real_model_session() {
    let b = backend();
    let prompt = vec![4, 4, 8];
    let full = b.generate(&prompt, 10).unwrap();
    let stop = full[3]; // stop on its first occurrence in the stream
    let cut = full.iter().position(|&t| t == stop).unwrap();
    let expected = full[..=cut].to_vec();
    let until = b.generate_until(&prompt, 10, &[stop]).unwrap();
    assert_eq!(until, expected, "generate_until keeps the stop token");

    let handle = Engine::start(backend(), cfg(1, 1, 1)).unwrap();
    let ticket = handle.submit(GenerationRequest::with_params(
        prompt,
        GenParams::new(10).with_stop_tokens(vec![stop]),
    ));
    let res = ticket.join();
    assert_eq!(res.finish, FinishReason::Stop);
    assert_eq!(res.tokens, until, "served stop-token stream must match generate_until");
    handle.shutdown().unwrap();
}

#[test]
fn expired_deadline_retires_before_the_forward_pass_runs() {
    let handle = Engine::start(backend(), cfg(1, 1, 1)).unwrap();
    let ticket = handle.submit(GenerationRequest::with_params(
        vec![1, 2, 3],
        GenParams::new(8).with_deadline(Instant::now()),
    ));
    let res = ticket.join();
    assert_eq!(res.finish, FinishReason::DeadlineExpired);
    assert!(res.tokens.is_empty(), "expired before prefill: no tokens");
    let report = handle.shutdown().unwrap();
    assert_eq!(report.cancelled, 1);
}

#[test]
fn cancel_interrupts_a_real_model_generation_mid_stream() {
    // 10 ms per round against a 30-token budget: cancel after three
    // streamed tokens lands at a round boundary long before the budget.
    let prompt = vec![6, 2];
    let direct = backend().generate(&prompt, 30).unwrap();
    let slow = SlowModel { inner: backend(), step: Duration::from_millis(10) };
    let handle = Engine::start(slow, cfg(1, 1, 1)).unwrap();
    let ticket = handle.submit(GenerationRequest::new(prompt, 30));

    let mut streamed: Vec<i32> = Vec::new();
    while let Some(ev) = ticket.recv() {
        if let Some(tok) = ev.token() {
            streamed.push(tok);
        }
        if streamed.len() == 3 {
            break;
        }
    }
    ticket.cancel();
    let res = ticket.join();
    assert_eq!(res.finish, FinishReason::Cancelled);
    assert!(
        res.tokens.len() >= 3 && res.tokens.len() < 30,
        "cancelled mid-generation, got {} tokens",
        res.tokens.len()
    );
    assert_eq!(
        res.tokens[..],
        direct[..res.tokens.len()],
        "cancelled ticket's partial tokens must be a prefix of the direct generation"
    );
    let report = handle.shutdown().unwrap();
    assert_eq!(report.cancelled, 1);
}

/// One in-flight sequence under the direct `Backend` API.
struct Seq {
    tok: i32,
    pos: i32,
    cache: ModelKvCache,
    toks: Vec<i32>,
}

fn prefill_seq(b: &ModelBackend, prompt: &[i32]) -> Seq {
    let mut padded = vec![0i32; PREFILL];
    padded[..prompt.len()].copy_from_slice(prompt);
    let step = b.prefill(&padded, prompt.len() as i32).unwrap();
    Seq {
        tok: step.next_token,
        pos: prompt.len() as i32,
        cache: step.cache,
        toks: vec![step.next_token],
    }
}

fn advance_serial(b: &ModelBackend, s: &mut Seq) {
    let step = b.decode(s.tok, s.pos, &s.cache).unwrap();
    s.tok = step.next_token;
    s.pos += 1;
    s.cache = step.cache;
    s.toks.push(step.next_token);
}

fn advance_batch(b: &ModelBackend, seqs: &mut [Seq], idx: &[usize]) {
    let items: Vec<_> = idx
        .iter()
        .map(|&i| BatchItem { token: seqs[i].tok, pos: seqs[i].pos, cache: &seqs[i].cache })
        .collect();
    let steps = b.decode_batch(&items).unwrap();
    assert_eq!(steps.len(), idx.len());
    drop(items);
    for (&i, step) in idx.iter().zip(steps) {
        let s = &mut seqs[i];
        s.tok = step.next_token;
        s.pos += 1;
        s.cache = step.cache;
        s.toks.push(step.next_token);
    }
}

#[test]
fn interleaved_batch_widths_leave_kv_state_identical() {
    // Regression for the batched-decode path: advancing sequences
    // through decode_batch rounds of varying width and membership must
    // leave every sequence's tokens and KV state identical to a purely
    // serialized decode.
    let b = backend();
    let prompts: [&[i32]; 3] = [&[3, 1, 4], &[1, 5, 9, 2], &[6, 5]];

    let mut serial: Vec<Seq> = prompts.iter().map(|p| prefill_seq(&b, p)).collect();
    for _ in 0..4 {
        for s in serial.iter_mut() {
            advance_serial(&b, s);
        }
    }

    let mut mixed: Vec<Seq> = prompts.iter().map(|p| prefill_seq(&b, p)).collect();
    advance_batch(&b, &mut mixed, &[0, 1, 2]); // round 1: full width
    advance_batch(&b, &mut mixed, &[0, 2]); // round 2: seq 1 goes alone
    advance_serial(&b, &mut mixed[1]);
    advance_serial(&b, &mut mixed[0]); // round 3: seq 0 goes alone
    advance_batch(&b, &mut mixed, &[1, 2]);
    for i in [0, 1, 2] {
        advance_batch(&b, &mut mixed, &[i]); // round 4: width-1 batches
    }

    for (i, (s, m)) in serial.iter().zip(&mixed).enumerate() {
        assert_eq!(s.toks, m.toks, "seq {i}: batching changed the token stream");
        assert_eq!(s.pos, m.pos);
        assert_eq!(
            s.cache.len(),
            m.cache.len(),
            "seq {i}: batching changed the KV cache length"
        );
    }
}

/// One blocking HTTP/1.1 exchange over a raw `TcpStream`: status line
/// plus the de-chunked body.
fn http_request(addr: SocketAddr, body: &str) -> (String, String) {
    let mut conn = TcpStream::connect(addr).expect("connect");
    write!(
        conn,
        "POST /v1/generate HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )
    .expect("send request");
    let mut raw = Vec::new();
    conn.read_to_end(&mut raw).expect("read response");
    let text = String::from_utf8(raw).expect("UTF-8 response");
    let head_end = text.find("\r\n\r\n").expect("header terminator");
    let head = &text[..head_end];
    let status = head.lines().next().unwrap_or("").to_string();
    let payload = &text[head_end + 4..];
    let body = if head.to_ascii_lowercase().contains("transfer-encoding: chunked") {
        dechunk(payload)
    } else {
        payload.to_string()
    };
    (status, body)
}

/// Reassemble a chunked transfer-encoding payload.
fn dechunk(payload: &str) -> String {
    let mut out = String::new();
    let mut rest = payload;
    loop {
        let Some(nl) = rest.find("\r\n") else { break };
        let size = usize::from_str_radix(rest[..nl].trim(), 16).expect("chunk size");
        if size == 0 {
            break;
        }
        let start = nl + 2;
        out.push_str(&rest[start..start + size]);
        rest = &rest[start + size + 2..]; // skip the chunk's trailing CRLF
    }
    out
}

#[test]
fn http_streams_real_forward_pass_tokens() {
    let prompt = vec![3, 5, 7];
    let max_new = 5usize;
    let direct = backend().generate(&prompt, max_new).unwrap();

    let handle = Arc::new(Engine::start(backend(), cfg(1, 1, 1)).unwrap());
    let http = HttpServer::start(
        "127.0.0.1:0",
        Arc::clone(&handle),
        Arc::new(PromCounters::new()),
        HttpConfig::default(),
    )
    .unwrap();
    let addr = http.local_addr();

    let (status, body) =
        http_request(addr, r#"{"prompt":[3,5,7],"max_new_tokens":5}"#);
    assert!(status.contains("200"), "got {status}");
    let events: Vec<Json> =
        body.lines().map(|l| Json::parse(l).expect("valid NDJSON line")).collect();
    let last = events.last().expect("terminal line");
    assert_eq!(last.get("event").and_then(Json::as_str), Some("retired"));
    assert_eq!(last.get("finish").and_then(Json::as_str), Some("length"));

    let streamed: Vec<i32> = events
        .iter()
        .filter_map(|e| match e.get("event").and_then(Json::as_str) {
            Some("prefilled") | Some("token") => {
                e.get("token").and_then(Json::as_f64).map(|t| t as i32)
            }
            _ => None,
        })
        .collect();
    assert_eq!(streamed, direct, "HTTP stream diverged from Backend::generate");
    let terminal: Vec<i32> = last
        .get("tokens")
        .and_then(Json::as_arr)
        .expect("terminal carries tokens")
        .iter()
        .map(|t| t.as_f64().expect("token is a number") as i32)
        .collect();
    assert_eq!(terminal, direct);

    http.stop();
    let handle = Arc::try_unwrap(handle).ok().expect("HTTP workers joined");
    let report = handle.shutdown().unwrap();
    assert_eq!(report.requests, 1);
    assert_eq!(report.completed, 1);
}
