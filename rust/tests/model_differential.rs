//! Model-level differential fuzz: the kernel-path
//! [`TernaryTransformer`] (native AVX2/scalar pshufb GEMVs, or the
//! modeled T-SAR ISA) must match the pure-scalar [`ReferenceModel`] —
//! **bit-for-bit on sampled tokens** and within 1e-4 relative error on
//! the pre-sampling logits (in practice the logits are bit-identical
//! too: integer ternary×int8 accumulation plus one pinned f32
//! evaluation order everywhere else) — across randomized seeds,
//! architectures, and prompts.
//!
//! The two implementations share only the checkpoint loader and the
//! sampler, so agreement here covers quantization, the GEMV kernels,
//! RMSNorm, rotary embedding, causal GQA attention, SiLU, the KV
//! cache, and the batched-prefill path all at once.
//!
//! CI runs this suite twice on AVX2 runners: once with
//! `RUSTFLAGS="-C target-cpu=native"` (AVX2 kernels) and once with
//! `TSAR_NATIVE_FORCE_SCALAR=1` (portable fallback), and scalar-only
//! on every other architecture.

use tsar::config::IsaConfig;
use tsar::model::{
    Checkpoint, LinearEngine, ReferenceModel, SamplerConfig, TernaryTransformer,
    TransformerConfig,
};
use tsar::runtime::{Backend, ModelBackend, ModelBackendConfig};
use tsar::util::rng::Rng;

/// A random valid toy architecture: even head_dim, grouped-query head
/// counts, deliberately unaligned d_model/ffn_dim (nothing rounds to
/// the kernels' tile sizes), everything small enough that debug-mode
/// fuzzing stays in seconds.
fn random_config(rng: &mut Rng) -> TransformerConfig {
    let head_dim = 2 * rng.range_i64(2, 7) as usize; // 4..14, even
    let n_heads = rng.range_i64(1, 4) as usize;
    let divisors: Vec<usize> = (1..=n_heads).filter(|h| n_heads % h == 0).collect();
    let n_kv_heads = divisors[rng.below(divisors.len() as u64) as usize];
    TransformerConfig {
        vocab: rng.range_i64(33, 120) as usize,
        d_model: n_heads * head_dim,
        n_layers: rng.range_i64(1, 2) as usize,
        n_heads,
        n_kv_heads,
        ffn_dim: rng.range_i64(9, 45) as usize,
        rope_theta: 10_000.0,
        norm_eps: 1e-5,
    }
}

fn random_isa(rng: &mut Rng) -> IsaConfig {
    if rng.f64() < 0.5 {
        IsaConfig::C2
    } else {
        IsaConfig::C4
    }
}

/// One randomized case: build both implementations on the same
/// synthesized checkpoint, compare pre-sampling logits and generated
/// tokens.
fn run_case(case: u64, seed0: u64, modeled: bool) {
    let mut rng = Rng::new(seed0 + case);
    let config = random_config(&mut rng);
    let ckpt_seed = rng.below(u64::MAX - 1) + 1;
    let ckpt = Checkpoint::synthesize(config, ckpt_seed).unwrap();
    let isa = random_isa(&mut rng);
    let engine = |threads: usize| {
        if modeled {
            LinearEngine::modeled(isa)
        } else {
            LinearEngine::native(isa, threads).unwrap()
        }
    };
    let threads = rng.range_i64(1, 3) as usize;
    let model = TernaryTransformer::from_checkpoint(&ckpt, engine(threads)).unwrap();
    let reference = ReferenceModel::new(&ckpt).unwrap();

    let plen = rng.range_i64(1, 6) as usize;
    let prompt: Vec<i32> =
        (0..plen).map(|_| rng.below(config.vocab as u64) as i32).collect();

    // Pre-sampling logits: ≤ 1e-4 relative error demanded, bit identity
    // delivered.
    let kernel_logits = model.forward(&prompt, &mut model.new_kv()).unwrap();
    let ref_logits = reference.logits(&prompt).unwrap();
    assert_eq!(kernel_logits.len(), ref_logits.len());
    for (i, (&a, &b)) in kernel_logits.iter().zip(&ref_logits).enumerate() {
        let rel = (a - b).abs() / b.abs().max(1e-3);
        assert!(
            rel <= 1e-4,
            "case {case}: logit {i} drifted: kernel {a} vs reference {b} (rel {rel:e}, \
             {} {:?} prompt {prompt:?})",
            isa.name(),
            config
        );
    }
    assert_eq!(
        kernel_logits, ref_logits,
        "case {case}: logits not bit-identical ({} {:?})",
        isa.name(),
        config
    );

    // Token identity end to end, through the serving Backend (padded
    // prefill + per-layer KV decode) vs the reference's full recompute.
    // Half the cases sample with temperature/top-k; both sides share
    // the seeded sampler, so tokens must still be identical.
    let sampler = if rng.f64() < 0.5 {
        SamplerConfig::greedy()
    } else {
        SamplerConfig {
            temperature: 0.5 + rng.f64() as f32,
            top_k: rng.range_i64(0, 8) as usize,
            seed: rng.below(1 << 32),
        }
    };
    let backend = ModelBackend::new(
        &ckpt,
        engine(1),
        ModelBackendConfig { prefill_len: 8, max_seq: 24, sampler },
    )
    .unwrap();
    let n_new = rng.range_i64(2, 5) as usize;
    let got = backend.generate(&prompt, n_new).unwrap();
    let want = reference.generate_until(&prompt, n_new, &sampler, &[]).unwrap();
    assert_eq!(
        got, want,
        "case {case}: token streams diverged ({} {:?} sampler {sampler:?})",
        isa.name(),
        config
    );
}

#[test]
fn kernel_path_matches_scalar_reference_on_randomized_cases() {
    // Whatever the host supports: AVX2 where available, else scalar
    // (TSAR_NATIVE_FORCE_SCALAR=1 pins the fallback in CI).
    let cases = 110u64;
    assert!(cases >= 100, "acceptance demands >= 100 randomized cases");
    for case in 0..cases {
        run_case(case, 0x3D1F_0000, false);
    }
}

#[test]
fn modeled_isa_engine_matches_scalar_reference() {
    // The register-file-model engine is orders of magnitude slower per
    // GEMV, so fewer cases — the native-vs-modeled bit-identity is
    // already pinned per kernel by tests/native_differential.rs.
    for case in 0..8u64 {
        run_case(case, 0x3D1F_8888, true);
    }
}

#[test]
fn checkpoint_roundtrip_preserves_the_forward_pass() {
    // Serialize → parse → rebuild: the container must reproduce the
    // exact forward pass, not just the tensor values.
    for case in 0..6u64 {
        let mut rng = Rng::new(0x3D1F_CC00 + case);
        let config = random_config(&mut rng);
        let ckpt = Checkpoint::synthesize(config, 0xFEED + case).unwrap();
        let back = Checkpoint::parse(&ckpt.to_bytes()).unwrap();
        assert_eq!(ckpt, back);
        let isa = random_isa(&mut rng);
        let a = TernaryTransformer::from_checkpoint(&ckpt, LinearEngine::native(isa, 1).unwrap())
            .unwrap();
        let b = TernaryTransformer::from_checkpoint(&back, LinearEngine::native(isa, 1).unwrap())
            .unwrap();
        let prompt = [1i32, 2, 3];
        assert_eq!(
            a.forward(&prompt, &mut a.new_kv()).unwrap(),
            b.forward(&prompt, &mut b.new_kv()).unwrap(),
            "case {case}: parsed checkpoint changed the forward pass"
        );
    }
}

#[test]
fn prefill_padding_never_leaks_into_logits() {
    // The Backend contract: tokens beyond prompt_len in the padded
    // prefill buffer must not affect anything.  The model backend
    // slices the real prompt before the forward pass — pin that.
    let ckpt = Checkpoint::synthesize(TransformerConfig::toy(), 0x9A9A).unwrap();
    let backend = ModelBackend::new(
        &ckpt,
        LinearEngine::native(IsaConfig::C2, 1).unwrap(),
        ModelBackendConfig { prefill_len: 8, max_seq: 24, sampler: SamplerConfig::greedy() },
    )
    .unwrap();
    let mut zeros = vec![0i32; 8];
    zeros[..3].copy_from_slice(&[4, 5, 6]);
    let mut junk = vec![200i32; 8];
    junk[..3].copy_from_slice(&[4, 5, 6]);
    let a = backend.prefill(&zeros, 3).unwrap();
    let b = backend.prefill(&junk, 3).unwrap();
    assert_eq!(a.next_token, b.next_token, "prefill padding leaked into the model");
}
