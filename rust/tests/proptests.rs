//! Property-based tests (in-tree harness: seeded PRNG + generators; the
//! proptest crate is not in the offline cache).  Each property runs many
//! randomized cases and reports the failing seed on violation, so cases
//! reproduce deterministically.

use tsar::config::IsaConfig;
use tsar::coordinator::{Batcher, KvSlotPool, Request};
use tsar::kernels::{all_kernels, scalar_gemm, Dataflow, TernaryKernel, TsarKernel};
use tsar::model::{reference, Checkpoint, ReferenceModel, TransformerConfig};
use tsar::quant::{absmax_quantize, absmean_ternarize, decompose, decode_indices, encode_indices};
use tsar::quant::pack::{Tl2Packed, TmacPacked};
use tsar::sim::{simulate, GemmShape};
use tsar::config::platforms::Platform;
use tsar::tsar::encoding::{Instruction, Opcode};
use tsar::util::rng::Rng;

const CASES: usize = 60;

/// Run `f` over `CASES` seeded cases, reporting the failing seed.
fn for_all_seeds(name: &str, f: impl Fn(&mut Rng)) {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(0xDEAD_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            panic!("property {name:?} failed at seed {seed}: {e:?}");
        }
    }
}

// ---------------------------------------------------------------------------
// Quantization & packing properties
// ---------------------------------------------------------------------------

#[test]
fn prop_decomposition_identity() {
    for_all_seeds("w == w_D - w_S", |rng| {
        let m = rng.range_i64(1, 16) as usize;
        let k = rng.range_i64(1, 64) as usize;
        let zf = rng.f64();
        let w = rng.ternary_matrix(m, k, zf);
        let (d, s) = decompose(&w);
        for i in 0..w.len() {
            assert_eq!(w[i], d[i] - s[i]);
        }
    });
}

#[test]
fn prop_encode_decode_roundtrip() {
    for_all_seeds("encode_indices round-trips", |rng| {
        let c = if rng.f64() < 0.5 { 2 } else { 4 };
        let m = rng.range_i64(1, 12) as usize;
        let k = c * rng.range_i64(1, 16) as usize;
        let zf = rng.f64();
        let w = rng.ternary_matrix(m, k, zf);
        let enc = encode_indices(&w, m, k, c);
        assert_eq!(decode_indices(&enc), w);
    });
}

#[test]
fn prop_tl2_tmac_roundtrip() {
    for_all_seeds("baseline packings round-trip", |rng| {
        let m = rng.range_i64(1, 10) as usize;
        let k = rng.range_i64(1, 50) as usize;
        let zf = rng.f64();
        let w = rng.ternary_matrix(m, k, zf);
        assert_eq!(Tl2Packed::pack(&w, m, k).unpack(), w);
        let k4 = k.div_ceil(4) * 4;
        let mut wp = vec![0i8; m * k4];
        for r in 0..m {
            wp[r * k4..r * k4 + k].copy_from_slice(&w[r * k..(r + 1) * k]);
        }
        assert_eq!(TmacPacked::pack(&wp, m, k4, 4).unpack(), wp);
    });
}

#[test]
fn prop_packings_roundtrip_with_unaligned_k() {
    // K deliberately *not* a multiple of the group size: TL-2's 3-weight
    // groups and T-MAC's g-weight groups must both pad internally and
    // still round-trip the logical matrix exactly.
    for_all_seeds("packings round-trip on unaligned K", |rng| {
        let m = rng.range_i64(1, 8) as usize;
        let g = if rng.f64() < 0.5 { 2usize } else { 4 };
        // Force k % g != 0 (and usually k % 3 != 0 too).
        let k = (g * rng.range_i64(1, 9) as usize) + rng.range_i64(1, g as i64 - 1).max(1) as usize;
        let zf = rng.f64();
        let w = rng.ternary_matrix(m, k, zf);
        let tl2 = Tl2Packed::pack(&w, m, k);
        assert_eq!(tl2.unpack(), w, "TL-2 m={m} k={k}");
        assert_ne!(k % g, 0, "generator must produce unaligned K");
        let tmac = TmacPacked::pack(&w, m, k, g);
        assert_eq!(tmac.unpack(), w, "T-MAC m={m} k={k} g={g}");
    });
}

#[test]
fn prop_tl2_packed_bytes_match_bitstream() {
    // The footprint a bench would report must equal the physical
    // 5-bit-packed buffer, row-aligned: m * ceil(groups*5/8).
    for_all_seeds("TL-2 packed_bytes is the real bitstream size", |rng| {
        let m = rng.range_i64(1, 8) as usize;
        let k = rng.range_i64(1, 40) as usize;
        let zf = rng.f64();
        let w = rng.ternary_matrix(m, k, zf);
        let p = Tl2Packed::pack(&w, m, k);
        assert_eq!(p.packed_bytes(), p.codes.len());
        assert_eq!(p.packed_bytes(), m * (p.groups_per_row * 5).div_ceil(8));
        assert_eq!(p.unpack(), w);
    });
}

#[test]
fn prop_act_quant_bounds() {
    for_all_seeds("absmax quant stays in [-127,127] and scales back", |rng| {
        let n = rng.range_i64(1, 128) as usize;
        let x: Vec<f32> = (0..n).map(|_| (rng.normal() * 10.0) as f32).collect();
        let (q, s) = absmax_quantize(&x);
        assert!(q.iter().all(|&v| (-127..=127).contains(&v)));
        assert!(s > 0.0);
        let absmax = x.iter().fold(0f32, |a, &b| a.max(b.abs()));
        if absmax > 1e-5 {
            // Max-magnitude element quantizes to ±127.
            assert_eq!(q.iter().map(|v| v.abs()).max().unwrap(), 127);
        }
    });
}

#[test]
fn prop_ternarize_values() {
    for_all_seeds("absmean ternary values", |rng| {
        let n = rng.range_i64(1, 64) as usize;
        let w: Vec<f32> = (0..n).map(|_| (rng.normal() * 3.0) as f32).collect();
        let (t, s) = absmean_ternarize(&w);
        assert!(s > 0.0);
        assert!(t.iter().all(|&v| (-1..=1).contains(&v)));
    });
}

// ---------------------------------------------------------------------------
// Kernel equivalence: every kernel == scalar reference on random inputs
// ---------------------------------------------------------------------------

#[test]
fn prop_all_kernels_match_scalar() {
    for_all_seeds("kernels == scalar reference", |rng| {
        let n = rng.range_i64(1, 4) as usize;
        let k = 4 * rng.range_i64(1, 24) as usize;
        let m = rng.range_i64(1, 40) as usize;
        let shape = GemmShape::new(n, k, m);
        let acts = rng.int8_acts(n * k);
        let zf = rng.f64();
        let w = rng.ternary_matrix(m, k, zf);
        let want = scalar_gemm(&acts, &w, shape);
        for kern in all_kernels() {
            assert_eq!(kern.run(&acts, &w, shape), want, "{}", kern.name());
        }
    });
}

#[test]
fn prop_tsar_dataflow_invariance() {
    // The dataflow/tiling choice may never change the numeric result.
    for_all_seeds("AP/OP produce identical results", |rng| {
        let shape = GemmShape::new(
            rng.range_i64(1, 3) as usize,
            8 * rng.range_i64(1, 16) as usize,
            rng.range_i64(1, 48) as usize,
        );
        let acts = rng.int8_acts(shape.n * shape.k);
        let w = rng.ternary_matrix(shape.m, shape.k, 0.33);
        let a = TsarKernel::new(IsaConfig::C2, Dataflow::ApMin).run(&acts, &w, shape);
        let b = TsarKernel::new(IsaConfig::C2, Dataflow::Op).run(&acts, &w, shape);
        let c = TsarKernel::new(IsaConfig::C4, Dataflow::ApMax).run(&acts, &w, shape);
        assert_eq!(a, b);
        assert_eq!(a, c);
    });
}

// ---------------------------------------------------------------------------
// ISA encoding
// ---------------------------------------------------------------------------

#[test]
fn prop_encoding_roundtrip() {
    for_all_seeds("VEX3 encode/decode round-trip", |rng| {
        let insn = Instruction {
            op: if rng.f64() < 0.5 { Opcode::Tlut } else { Opcode::Tgemv },
            cfg_sel: rng.below(2) as u8,
            dst: rng.below(16) as u8,
            aux: rng.below(16) as u8,
            src: rng.below(16) as u8,
        };
        assert_eq!(Instruction::decode(&insn.encode()).unwrap(), insn);
    });
}

// ---------------------------------------------------------------------------
// Simulator sanity under random profiles
// ---------------------------------------------------------------------------

#[test]
fn prop_simulator_monotonic_in_threads_for_compute() {
    for_all_seeds("compute-bound time never grows with threads", |rng| {
        let plat = Platform::workstation();
        let uops = 1e6 + rng.f64() * 1e9;
        let p = tsar::sim::KernelProfile {
            kernel: "prop".into(),
            shape: GemmShape::new(1, 64, 64),
            streams: vec![tsar::sim::Stream::read_once("w", 1e4)],
            simd_uops: uops,
            scalar_uops: 0.0,
        };
        let mut last = f64::INFINITY;
        for t in [1, 2, 4, 8, 16] {
            let s = simulate(&p, &plat, t).seconds;
            assert!(s <= last * 1.001);
            last = s;
        }
    });
}

#[test]
fn prop_simulator_positive_finite() {
    for_all_seeds("simulated time positive & finite", |rng| {
        let plat = match rng.below(3) {
            0 => Platform::workstation(),
            1 => Platform::laptop(),
            _ => Platform::mobile(),
        };
        let shape = GemmShape::new(
            1 + rng.below(128) as usize,
            8 * (1 + rng.below(512) as usize),
            1 + rng.below(8192) as usize,
        );
        for kern in all_kernels() {
            let t = 1 + rng.below(plat.cores as u64) as usize;
            let r = simulate(&kern.profile(shape, &plat, t), &plat, t);
            assert!(r.seconds.is_finite() && r.seconds > 0.0, "{}", kern.name());
            assert!(r.request_bytes.is_finite() && r.request_bytes > 0.0);
        }
    });
}

// ---------------------------------------------------------------------------
// Coordinator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_kvpool_never_double_allocates() {
    for_all_seeds("KV slots unique among live", |rng| {
        let cap = 1 + rng.below(16) as usize;
        let mut pool = KvSlotPool::new(cap);
        let mut live = Vec::new();
        for _ in 0..200 {
            if rng.f64() < 0.55 {
                if let Some(slot) = pool.allocate() {
                    assert!(!live.contains(&slot), "slot double-allocated");
                    live.push(slot);
                }
            } else if let Some(i) = (!live.is_empty())
                .then(|| rng.below(live.len() as u64) as usize)
            {
                let slot = live.swap_remove(i);
                pool.release(slot).unwrap();
            }
            assert_eq!(pool.live_count(), live.len());
            assert_eq!(pool.available(), cap - live.len());
        }
    });
}

#[test]
fn prop_batcher_no_request_lost_or_duplicated() {
    for_all_seeds("batcher conserves requests", |rng| {
        let max_batch = 1 + rng.below(6) as usize;
        let mut b = Batcher::new(max_batch);
        let total = 1 + rng.below(40) as u64;
        let mut submitted = 0u64;
        let mut admitted = Vec::new();
        let mut finished = Vec::new();
        let mut steps = 0;
        while (finished.len() as u64) < total && steps < 10_000 {
            steps += 1;
            match rng.below(3) {
                0 if submitted < total => {
                    b.submit(Request::new(submitted, vec![1], 4));
                    submitted += 1;
                }
                1 => {
                    if let Some(r) = b.admit() {
                        assert!(!admitted.contains(&r.id), "duplicate admit");
                        admitted.push(r.id);
                        assert!(b.active_len() <= max_batch);
                    }
                }
                _ => {
                    if let Some(id) = b.next_decode() {
                        if rng.f64() < 0.3 {
                            b.finish(id).unwrap();
                            assert!(!finished.contains(&id), "duplicate finish");
                            finished.push(id);
                        }
                    }
                }
            }
            // Submit any stragglers so the loop can drain.
            if submitted < total && rng.f64() < 0.2 {
                b.submit(Request::new(submitted, vec![1], 4));
                submitted += 1;
            }
        }
        // Everything admitted exactly once, in FIFO order.
        let mut sorted = admitted.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), admitted.len());
        for w in admitted.windows(2) {
            assert!(w[0] < w[1], "admission must be FIFO");
        }
    });
}

// ---------------------------------------------------------------------------
// Real-model invariants (checkpoint container + scalar reference)
// ---------------------------------------------------------------------------

/// A random valid toy architecture with deliberately unaligned
/// d_model/ffn_dim (nothing rounds to the kernels' tile sizes).
fn random_model_config(rng: &mut Rng) -> TransformerConfig {
    let head_dim = 2 * rng.range_i64(2, 7) as usize; // 4..14, even
    let n_heads = rng.range_i64(1, 4) as usize;
    let divisors: Vec<usize> = (1..=n_heads).filter(|h| n_heads % h == 0).collect();
    let n_kv_heads = divisors[rng.below(divisors.len() as u64) as usize];
    TransformerConfig {
        vocab: rng.range_i64(33, 120) as usize,
        d_model: n_heads * head_dim,
        n_layers: rng.range_i64(1, 2) as usize,
        n_heads,
        n_kv_heads,
        ffn_dim: rng.range_i64(9, 45) as usize,
        rope_theta: 10_000.0,
        norm_eps: 1e-5,
    }
}

#[test]
fn prop_checkpoint_roundtrip_on_unaligned_dims() {
    // The TSARCKP1 container packs ternary planes at bit granularity;
    // row lengths that are not multiples of 8 must still round-trip
    // both the value and the byte stream exactly.
    for_all_seeds("TSARCKP1 round-trips on unaligned dims", |rng| {
        let config = random_model_config(rng);
        let ckpt = Checkpoint::synthesize(config, rng.below(u64::MAX - 1) + 1).unwrap();
        let bytes = ckpt.to_bytes();
        let back = Checkpoint::parse(&bytes).unwrap();
        assert_eq!(back, ckpt, "parse(to_bytes) changed the checkpoint");
        assert_eq!(back.to_bytes(), bytes, "re-serialization is not canonical");
    });
}

#[test]
fn prop_rmsnorm_is_scale_invariant() {
    // RMSNorm(c·x) == RMSNorm(x) for c > 0, up to the eps floor (the
    // generator keeps |x| bounded away from 0 so eps stays negligible).
    for_all_seeds("RMSNorm scale invariance", |rng| {
        let n = rng.range_i64(1, 64) as usize;
        let x: Vec<f32> = (0..n)
            .map(|_| {
                let mag = 0.5 + rng.f64() as f32 * 1.5;
                if rng.f64() < 0.5 {
                    mag
                } else {
                    -mag
                }
            })
            .collect();
        let gains: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let c = (0.25 + rng.f64() * 3.75) as f32;
        let scaled: Vec<f32> = x.iter().map(|v| v * c).collect();
        let a = reference::rms_norm(&x, &gains, 1e-5);
        let b = reference::rms_norm(&scaled, &gains, 1e-5);
        for (i, (&u, &v)) in a.iter().zip(&b).enumerate() {
            let tol = 1e-2 * u.abs().max(1e-3);
            assert!((u - v).abs() <= tol, "element {i}: {u} vs {v} under scale {c}");
        }
    });
}

#[test]
fn prop_reference_attention_rows_are_distributions() {
    // Every (layer, head, position) attention row the scalar reference
    // produces is a probability distribution: entries in [0, 1],
    // summing to 1 — i.e. the softmax actually normalizes.
    for_all_seeds("attention rows sum to one", |rng| {
        let config = random_model_config(rng);
        let ckpt = Checkpoint::synthesize(config, rng.below(u64::MAX - 1) + 1).unwrap();
        let model = ReferenceModel::new(&ckpt).unwrap();
        let plen = rng.range_i64(1, 5) as usize;
        let tokens: Vec<i32> =
            (0..plen).map(|_| rng.below(config.vocab as u64) as i32).collect();
        let rows = model.attention_probe(&tokens).unwrap();
        assert!(!rows.is_empty(), "probe returned no attention rows");
        for (r, row) in rows.iter().enumerate() {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() <= 1e-5, "row {r} sums to {sum}");
            assert!(
                row.iter().all(|&p| (0.0..=1.0 + 1e-6).contains(&p)),
                "row {r} has an out-of-range probability: {row:?}"
            );
        }
    });
}
