//! End-to-end tests of the session-based streaming Engine API
//! (DESIGN.md §3): submit/stream/cancel tickets over the sharded
//! server, per-request generation params, admission-time validation,
//! and token/clock parity with the legacy blocking wrappers.

use std::time::{Duration, Instant};

use tsar::config::platforms::Platform;
use tsar::coordinator::{
    serve_all, Engine, FinishReason, GenParams, GenerationRequest, Request, Server,
    ServerConfig, TokenEvent,
};
use tsar::runtime::{
    Backend, BatchItem, ModelConfig, SimBackend, SimBackendConfig, SimKvCache, Step,
};
use tsar::util::error::Result;

fn sim_cfg() -> SimBackendConfig {
    SimBackendConfig { prefill_len: 16, max_seq: 64, threads: 0, seed: 3 }
}

fn backend() -> SimBackend {
    SimBackend::by_name("BitNet-2B-4T", Platform::workstation(), sim_cfg()).expect("zoo model")
}

fn cfg(max_batch: usize, kv_slots: usize, workers: usize) -> ServerConfig {
    ServerConfig { max_batch, kv_slots, workers, queue_cap: None }
}

/// A backend that spends real wall time per step (on top of the
/// simulator's virtual costs), so tests can observe and interrupt
/// generation mid-stream deterministically.
struct SlowBackend {
    inner: SimBackend,
    step: Duration,
}

impl SlowBackend {
    fn new(step_ms: u64) -> SlowBackend {
        SlowBackend { inner: backend(), step: Duration::from_millis(step_ms) }
    }
}

impl Backend for SlowBackend {
    type Cache = SimKvCache;

    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn describe(&self) -> String {
        format!("slow({})", self.inner.describe())
    }

    fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<Step<SimKvCache>> {
        std::thread::sleep(self.step);
        self.inner.prefill(tokens, prompt_len)
    }

    fn decode(&self, token: i32, pos: i32, cache: &SimKvCache) -> Result<Step<SimKvCache>> {
        std::thread::sleep(self.step);
        self.inner.decode(token, pos, cache)
    }

    fn decode_batch(
        &self,
        reqs: &[BatchItem<'_, SimKvCache>],
    ) -> Result<Vec<Step<SimKvCache>>> {
        std::thread::sleep(self.step);
        self.inner.decode_batch(reqs)
    }

    fn plan_summary(&self) -> Option<String> {
        self.inner.plan_summary()
    }
}

/// The acceptance workload: two concurrent tickets stream tokens,
/// one is cancelled mid-generation (freeing its KV slot for a later
/// request), and the survivor's tokens are bit-identical to
/// `Backend::generate`.
#[test]
fn concurrent_tickets_cancel_one_mid_stream() {
    let prompt_a = vec![3, 1, 4, 1, 5];
    let prompt_b = vec![2, 7, 1, 8];
    let prompt_c = vec![9, 9, 2];
    let max_new = 12usize;
    let reference = backend();
    let direct_a = reference.generate(&prompt_a, max_new).unwrap();
    let direct_b = reference.generate(&prompt_b, max_new).unwrap();
    let direct_c = reference.generate(&prompt_c, 4).unwrap();

    // One lane, two KV slots: A and B occupy the whole pool, so C can
    // only be admitted once a slot frees up.  15 ms per round leaves
    // generous scheduling headroom between "cancel sent" and "budget
    // reached" even on a loaded CI machine.
    let handle = Engine::start(SlowBackend::new(15), cfg(2, 2, 1)).unwrap();
    let ticket_a = handle.submit(GenerationRequest::new(prompt_a, max_new));
    let ticket_b = handle.submit(GenerationRequest::new(prompt_b, max_new));

    // submit() returned before decode completed: nothing terminal can
    // have been emitted yet (the backend sleeps per step).
    if let Some(ev) = ticket_a.try_recv() {
        assert!(ev.result().is_none(), "terminal event before decode ran: {ev:?}");
    }

    // Stream A until a few tokens landed, then cancel it mid-flight.
    let mut streamed_a: Vec<i32> = Vec::new();
    while let Some(ev) = ticket_a.recv() {
        if let Some(tok) = ev.token() {
            streamed_a.push(tok);
        }
        if streamed_a.len() == 3 {
            break;
        }
    }
    ticket_a.cancel();

    // A's KV slot frees at the next round boundary: C gets admitted
    // and retires even though A+B saturated the pool.
    let ticket_c = handle.submit(GenerationRequest::new(prompt_c, 4));
    let res_c = ticket_c.join();
    assert_eq!(res_c.finish, FinishReason::Length);
    assert_eq!(res_c.tokens, direct_c, "later request after a cancel must be unperturbed");

    let res_a = ticket_a.join();
    assert_eq!(res_a.finish, FinishReason::Cancelled);
    assert!(
        res_a.tokens.len() >= 3 && res_a.tokens.len() < max_new,
        "cancelled mid-generation, got {} tokens",
        res_a.tokens.len()
    );
    assert_eq!(
        res_a.tokens[..],
        direct_a[..res_a.tokens.len()],
        "cancelled ticket's partial tokens must be a prefix of the direct generation"
    );

    let res_b = ticket_b.join();
    assert_eq!(res_b.finish, FinishReason::Length);
    assert_eq!(
        res_b.tokens, direct_b,
        "survivor's tokens must be bit-identical to Backend::generate"
    );

    let report = handle.shutdown().unwrap();
    assert_eq!(report.requests, 3);
    assert_eq!(report.completed, 2);
    assert_eq!(report.cancelled, 1);
}

#[test]
fn streamed_token_order_equals_join_result() {
    let handle = Engine::start(backend(), cfg(2, 2, 2)).unwrap();
    let ticket = handle.submit(GenerationRequest::new(vec![5, 6, 7], 6));

    // Drain the stream manually: Prefilled first (index 0), then
    // strictly increasing Token indices, then exactly one terminal.
    let mut streamed: Vec<i32> = Vec::new();
    let mut terminal = None;
    while let Some(ev) = ticket.recv() {
        match ev {
            TokenEvent::Prefilled { token } => {
                assert!(streamed.is_empty(), "Prefilled must be the first event");
                streamed.push(token);
            }
            TokenEvent::Token { token, index } => {
                assert_eq!(index, streamed.len(), "token indices must be contiguous");
                streamed.push(token);
            }
            ev => {
                assert!(terminal.is_none(), "more than one terminal event");
                terminal = Some(ev.result().expect("terminal carries the result").clone());
            }
        }
    }
    let result = terminal.expect("stream must end with a terminal event");
    assert_eq!(result.finish, FinishReason::Length);
    assert_eq!(streamed, result.tokens, "streamed order must equal the joined result");
    assert_eq!(streamed, backend().generate(&[5, 6, 7], 6).unwrap());
    handle.shutdown().unwrap();
}

#[test]
fn stop_tokens_end_generation_early() {
    let b = backend();
    let full = b.generate(&[4, 4, 8], 10).unwrap();
    let stop = full[3]; // stop on the 4th generated token
    // (or its first occurrence, should the stream repeat it earlier)
    let cut = full.iter().position(|&t| t == stop).unwrap();
    let expected = full[..=cut].to_vec();
    let until = b.generate_until(&[4, 4, 8], 10, &[stop]).unwrap();
    assert_eq!(until, expected, "generate_until keeps the stop token");

    let handle = Engine::start(backend(), cfg(2, 2, 1)).unwrap();
    let ticket = handle.submit(GenerationRequest::with_params(
        vec![4, 4, 8],
        GenParams::new(10).with_stop_tokens(vec![stop]),
    ));
    let res = ticket.join();
    assert_eq!(res.finish, FinishReason::Stop);
    assert_eq!(res.tokens, until, "served stop-token stream must match generate_until");
    handle.shutdown().unwrap();
}

#[test]
fn expired_deadline_cancels_at_admission() {
    let handle = Engine::start(backend(), cfg(1, 1, 1)).unwrap();
    let ticket = handle.submit(GenerationRequest::with_params(
        vec![1, 2, 3],
        GenParams::new(8).with_deadline(Instant::now()),
    ));
    let res = ticket.join();
    assert_eq!(res.finish, FinishReason::DeadlineExpired);
    assert!(res.tokens.is_empty(), "expired before prefill: no tokens");
    let report = handle.shutdown().unwrap();
    assert_eq!(report.cancelled, 1);
}

#[test]
fn deadline_expiry_cancels_mid_generation() {
    // 10 ms per round against an 80 ms deadline and a 50-token budget
    // (≥ 500 ms of work): the deadline must fire at a round boundary
    // long before the budget is reached, and the 80 ms headroom lets
    // prefill land first even on a loaded CI machine.
    let b = SlowBackend::new(10);
    let handle = Engine::start(b, cfg(1, 1, 1)).unwrap();
    let ticket = handle.submit(GenerationRequest::with_params(
        vec![2, 3],
        GenParams::new(50).with_deadline(Instant::now() + Duration::from_millis(80)),
    ));
    let res = ticket.join();
    assert_eq!(res.finish, FinishReason::DeadlineExpired);
    assert!(
        !res.tokens.is_empty() && res.tokens.len() < 50,
        "deadline should interrupt mid-generation, got {} tokens",
        res.tokens.len()
    );
    handle.shutdown().unwrap();
}

#[test]
fn admission_validation_rejects_oversized_requests_at_submit() {
    let handle = Engine::start(backend(), cfg(2, 2, 1)).unwrap();

    // prompt_len + max_new_tokens > max_seq (= 64): clean Failed at
    // submit time instead of a mid-decode KV-exhaustion error.
    let res = handle.submit(GenerationRequest::new(vec![1; 10], 60)).join();
    assert_eq!(res.finish, FinishReason::Failed);
    let err = res.error.expect("admission rejection carries the reason");
    assert!(err.contains("KV capacity"), "got {err:?}");
    assert!(res.tokens.is_empty());

    // Prompt longer than the prefill window.
    let res = handle.submit(GenerationRequest::new(vec![1; 17], 4)).join();
    assert_eq!(res.finish, FinishReason::Failed);
    assert!(res.error.unwrap().contains("prefill window"));

    // Empty prompt: an error, not a panic.
    let res = handle.submit(GenerationRequest::new(vec![], 4)).join();
    assert_eq!(res.finish, FinishReason::Failed);

    // An exactly-fitting request still passes and the engine serves it.
    let fit = handle.submit(GenerationRequest::new(vec![1; 10], 54)).join();
    assert_eq!(fit.finish, FinishReason::Length);
    assert_eq!(fit.tokens.len(), 54);

    // Rejected submissions never reach a lane but still count in the
    // report's failed outcomes alongside the one served request.
    let report = handle.shutdown().unwrap();
    assert_eq!(report.requests, 4);
    assert_eq!(report.failed, 3);
    assert_eq!(report.completed, 1);
    assert_eq!(report.total_tokens, 54);
    // The rejections' zeroed timings must not drag the latency
    // percentiles to 0 ms — only the served request counts.
    assert!(report.e2e.p50 > 0.0 && report.prefill.p50 > 0.0);
}

#[test]
fn engine_and_legacy_wrapper_agree_on_tokens_and_clock() {
    // Mixed workload (varying prompts and budgets) at batch 1 on one
    // lane: round widths are always 1, so the virtual clock is a pure
    // sum of step costs — the engine (live arrivals) and the legacy
    // preloaded wrapper must agree exactly on tokens and makespan.
    let prompts: Vec<(Vec<i32>, usize)> = vec![
        (vec![1, 2, 3], 5),
        (vec![9, 8], 3),
        (vec![5, 5, 5, 5], 7),
        (vec![11, 3], 4),
    ];

    let legacy_server = Server::new(backend(), cfg(1, 1, 1)).unwrap();
    let requests: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(id, (p, n))| Request::new(id as u64, p.clone(), *n))
        .collect();
    let legacy_report = serve_all(&legacy_server, requests).unwrap();

    let handle = Engine::start(backend(), cfg(1, 1, 1)).unwrap();
    let tickets: Vec<_> = prompts
        .iter()
        .map(|(p, n)| handle.submit(GenerationRequest::new(p.clone(), *n)))
        .collect();
    let engine_results: Vec<_> = tickets.into_iter().map(|t| t.join()).collect();
    let engine_report = handle.shutdown().unwrap();

    let reference = backend();
    for (res, (p, n)) in engine_results.iter().zip(&prompts) {
        assert_eq!(res.finish, FinishReason::Length);
        assert_eq!(&res.tokens, &reference.generate(p, *n).unwrap());
    }
    assert_eq!(engine_report.requests, legacy_report.requests);
    assert_eq!(engine_report.total_tokens, legacy_report.total_tokens);
    assert!(
        (engine_report.wall_s - legacy_report.wall_s).abs()
            <= legacy_report.wall_s * 1e-12,
        "engine makespan {} != legacy makespan {}",
        engine_report.wall_s,
        legacy_report.wall_s
    );
}

#[test]
fn legacy_run_still_drains_a_live_request_stream() {
    // The wrappers delegate to the engine; `Server::run` (the live
    // dispatcher path) must still drain a closed request channel to
    // completion with correct per-request tokens.  (Exact virtual
    // clocks are pinned for the deterministic preloaded path in
    // serve_sim.rs; the live path's round widths depend on arrival
    // timing, as they always did.)
    let b = backend();
    let reference = backend();
    let server = Server::new(b, cfg(3, 3, 1)).unwrap();
    let (req_tx, req_rx) = std::sync::mpsc::channel();
    let (res_tx, res_rx) = std::sync::mpsc::channel();
    let prompts: Vec<Vec<i32>> =
        (0..6).map(|i| vec![1 + i as i32, 2, 3]).collect();
    for (id, p) in prompts.iter().enumerate() {
        req_tx.send(Request::new(id as u64, p.clone(), 5)).unwrap();
    }
    drop(req_tx);
    let report = server.run(req_rx, res_tx).unwrap();
    assert_eq!(report.requests, 6);
    assert_eq!(report.total_tokens, 30);
    assert!(report.wall_s > 0.0);
    let mut served: Vec<(u64, Vec<i32>)> =
        res_rx.into_iter().map(|r| (r.id, r.tokens)).collect();
    served.sort_by_key(|(id, _)| *id);
    assert_eq!(served.len(), 6);
    for (id, tokens) in served {
        assert_eq!(tokens, reference.generate(&prompts[id as usize], 5).unwrap());
    }
}

#[test]
fn shutdown_with_no_requests_is_an_error() {
    let handle = Engine::start(backend(), cfg(1, 1, 1)).unwrap();
    assert!(handle.shutdown().is_err(), "nothing served: report must be an Err");
}

/// A backend whose decode panics once a sequence's position crosses a
/// threshold — the deterministic stand-in for a backend bug that
/// unwinds a lane thread mid-decode.
struct PanickyBackend {
    inner: SimBackend,
    panic_at_pos: i32,
}

impl Backend for PanickyBackend {
    type Cache = SimKvCache;

    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn describe(&self) -> String {
        format!("panicky({})", self.inner.describe())
    }

    fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<Step<SimKvCache>> {
        self.inner.prefill(tokens, prompt_len)
    }

    fn decode(&self, token: i32, pos: i32, cache: &SimKvCache) -> Result<Step<SimKvCache>> {
        assert!(pos < self.panic_at_pos, "injected decode panic at pos {pos}");
        self.inner.decode(token, pos, cache)
    }

    fn decode_batch(
        &self,
        reqs: &[BatchItem<'_, SimKvCache>],
    ) -> Result<Vec<Step<SimKvCache>>> {
        for r in reqs {
            assert!(r.pos < self.panic_at_pos, "injected decode panic at pos {}", r.pos);
        }
        self.inner.decode_batch(reqs)
    }
}

#[test]
fn lane_panic_does_not_poison_shutdown() {
    // Two lanes, round-robin sharding: the first submission lands on
    // lane 0 and panics its backend mid-decode (its position starts at
    // prompt length 10 and crosses the threshold after two steps); the
    // second lands on lane 1 and stays below the threshold for its
    // whole generation.
    let b = PanickyBackend { inner: backend(), panic_at_pos: 12 };
    let handle = Engine::start(b, cfg(1, 1, 2)).unwrap();
    let doomed = handle.submit(GenerationRequest::new(vec![1; 10], 20));
    let survivor_prompt = vec![2, 3, 4];
    let survivor = handle.submit(GenerationRequest::new(survivor_prompt.clone(), 5));

    let res = survivor.join();
    assert_eq!(res.finish, FinishReason::Length);
    assert_eq!(res.tokens, backend().generate(&survivor_prompt, 5).unwrap());

    // The doomed ticket's stream closed without a terminal event (its
    // lane unwound); join must synthesize a Failed result, not hang.
    let res = doomed.join();
    assert_eq!(res.finish, FinishReason::Failed);
    assert!(res.error.unwrap().contains("without a terminal event"));

    // Pre-fix, this shutdown re-panicked on the lane join ("lane
    // thread panicked") and every other lane's results were lost.
    let report = handle.shutdown().unwrap();
    assert_eq!(report.requests, 1, "the survivor's result must be kept");
    assert_eq!(report.completed, 1);
    assert_eq!(report.lane_errors.len(), 1, "the dead lane must be reported");
    assert!(
        report.lane_errors[0].contains("injected decode panic"),
        "got {:?}",
        report.lane_errors
    );
}

#[test]
fn stream_then_join_returns_the_real_result() {
    let handle = Engine::start(backend(), cfg(1, 1, 1)).unwrap();
    let prompt = vec![5, 6, 7];
    let ticket = handle.submit(GenerationRequest::new(prompt.clone(), 6));

    // Consume the whole stream — terminal event included — via recv().
    let mut streamed: Vec<i32> = Vec::new();
    let mut saw_terminal = false;
    while let Some(ev) = ticket.recv() {
        if let Some(tok) = ev.token() {
            streamed.push(tok);
        }
        if ev.result().is_some() {
            saw_terminal = true;
        }
    }
    assert!(saw_terminal, "stream must end with a terminal event");

    // Pre-fix, join() after the terminal event was consumed synthesized
    // a phantom Failed result for a request that retired cleanly.
    let res = ticket.join();
    assert_eq!(res.finish, FinishReason::Length);
    assert!(res.error.is_none(), "got phantom error {:?}", res.error);
    assert_eq!(res.tokens, streamed);
    assert_eq!(res.tokens, backend().generate(&prompt, 6).unwrap());
    handle.shutdown().unwrap();
}

#[test]
fn kv_window_exact_fit_reaches_the_full_budget() {
    // The passing side of the boundary: prompt_len + max_new_tokens ==
    // max_seq (= 64) is admitted, and the engine must deliver the full
    // budget, token-identical to the direct reference.
    let prompt = vec![7; 14];
    let max_new = 50;
    let direct = backend().generate(&prompt, max_new).unwrap();
    assert_eq!(direct.len(), max_new);

    let handle = Engine::start(backend(), cfg(1, 1, 1)).unwrap();
    let res = handle.submit(GenerationRequest::new(prompt, max_new)).join();
    assert_eq!(res.finish, FinishReason::Length);
    assert_eq!(res.tokens, direct);
    handle.shutdown().unwrap();
}

#[test]
fn kv_window_caps_legacy_requests_at_the_backend_capacity() {
    // The other side: the legacy surface admits without validation and
    // caps at the KV window.  One token past the admission limit
    // (prompt_len + budget == max_seq + 1) still fits the backend's
    // real decode capacity — `generate` succeeds with the full budget —
    // so the lane must not retire it a token early (the pre-fix
    // `max_seq - 1` cutoff did).
    let prompt = vec![3; 5];
    let max_new = 60; // 5 + 60 == 65 == max_seq + 1
    let direct = backend().generate(&prompt, max_new).unwrap();
    assert_eq!(direct.len(), max_new);

    let server = Server::new(backend(), cfg(1, 1, 1)).unwrap();
    let report = serve_all(&server, vec![Request::new(0, prompt.clone(), max_new)]).unwrap();
    assert_eq!(report.total_tokens, max_new, "retired a token short of the window");

    // Far past the window, generation caps exactly at the backend's
    // capacity: decode positions run to max_seq - 1 inclusive, so
    // prompt of 5 in a 64-token window yields 60 tokens.
    let report = serve_all(&server, vec![Request::new(0, prompt, 999)]).unwrap();
    assert_eq!(report.total_tokens, 64 - 5 + 1);
}

/// Minimal backend with a degenerate KV window, for pinning the lane's
/// cutoff arithmetic (SimBackend itself refuses max_seq <= prefill).
struct TinyWindowBackend {
    config: ModelConfig,
}

impl Backend for TinyWindowBackend {
    type Cache = ();

    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn describe(&self) -> String {
        "tiny-window stub".into()
    }

    fn prefill(&self, _tokens: &[i32], prompt_len: i32) -> Result<Step<()>> {
        Ok(Step { next_token: prompt_len, cache: (), cost_s: Some(1e-6) })
    }

    fn decode(&self, token: i32, _pos: i32, _cache: &()) -> Result<Step<()>> {
        Ok(Step { next_token: token + 1, cache: (), cost_s: Some(1e-6) })
    }
}

#[test]
fn zero_kv_window_retires_immediately_instead_of_underflowing() {
    // max_seq == 0: the pre-fix cutoff computed `max_seq - 1` on a
    // usize — an arithmetic underflow (a panic in debug builds).  The
    // aligned check retires the sequence right after prefill.
    let config = ModelConfig {
        vocab: 100,
        d_model: 8,
        n_layers: 1,
        n_heads: 1,
        ffn_dim: 16,
        max_seq: 0,
        prefill_len: 4,
    };
    let server = Server::new(TinyWindowBackend { config }, cfg(1, 1, 1)).unwrap();
    let report = serve_all(&server, vec![Request::new(0, vec![1, 2], 8)]).unwrap();
    assert_eq!(report.requests, 1);
    assert_eq!(
        report.total_tokens, 1,
        "prefill token only: a zero-token window admits no decode"
    );
}

#[test]
fn http_smoke_submit_over_tcp_while_a_lane_drains() {
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::sync::Arc;

    use tsar::coordinator::{HttpConfig, HttpServer, PromCounters};

    // Occupy the single lane with a direct submission, then land an
    // HTTP session on the same engine while that shard drains.
    let handle = Arc::new(Engine::start(SlowBackend::new(2), cfg(2, 2, 1)).unwrap());
    let busy = handle.submit(GenerationRequest::new(vec![1, 2, 3], 8));
    let http = HttpServer::start(
        "127.0.0.1:0",
        Arc::clone(&handle),
        Arc::new(PromCounters::new()),
        HttpConfig::default(),
    )
    .unwrap();
    let addr = http.local_addr();

    let body = r#"{"prompt":[4,5,6],"max_new_tokens":5}"#;
    let mut conn = TcpStream::connect(addr).unwrap();
    write!(
        conn,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut response = String::new();
    conn.read_to_string(&mut response).unwrap();
    assert!(response.starts_with("HTTP/1.1 200"), "got {response}");
    assert!(response.contains("\"event\":\"prefilled\""), "got {response}");
    assert!(response.contains("\"event\":\"retired\""), "got {response}");
    assert!(response.contains("\"finish\":\"length\""), "got {response}");

    assert_eq!(busy.join().finish, FinishReason::Length);
    http.stop();
    let handle = Arc::try_unwrap(handle).ok().expect("HTTP workers joined");
    let report = handle.shutdown().unwrap();
    assert_eq!(report.requests, 2);
    assert_eq!(report.completed, 2);
}

#[test]
fn bad_engine_config_is_an_error() {
    assert!(Engine::start(backend(), cfg(4, 2, 1)).is_err());
    assert!(Engine::start(backend(), cfg(0, 1, 1)).is_err());
    assert!(Engine::start(backend(), cfg(1, 1, 0)).is_err());
}
