//! Differential fuzz: the batched row-blocked GEMM on the persistent
//! worker pool must be **bit-identical** to n serialized per-row GEMVs.
//!
//! The serialized anchor is [`NativeGemv::gemm_scoped`] — the legacy
//! per-call scoped-thread path, itself fuzzed against the modeled ISA
//! and the scalar dot product in `tests/native_differential.rs`.  The
//! batched path executes the identical per-(row, output) operation
//! sequence (same slice order, same madd pairing, same i16/i32
//! intermediates); only the loop nest changes, so equality holds bit
//! for bit — no tolerance anywhere in this suite.
//!
//! Layers covered, bottom up:
//!   1. kernel: randomized (n, k, m, ISA, threads, sparsity) across
//!      row-block boundaries, pool `gemm` + caller-owned-workspace
//!      `gemm_with` vs `gemm_scoped`, on the detected path AND forced
//!      scalar;
//!   2. BitLinear: batched `gemm_bitlinear` vs n single-row calls
//!      (f32 in/out: quantize + dequantize are per-row, so f32 results
//!      are bit-identical too);
//!   3. model: `ModelBackend::decode_batch` (whole decode rounds
//!      through `TernaryTransformer::decode_round`, one n-row GEMM per
//!      BitLinear site) with pool threads vs the serialized batch-1
//!      `decode` loop — tokens and KV lengths must not change.
//!
//! CI runs this suite twice on AVX2 runners: once with
//! `RUSTFLAGS="-C target-cpu=native"` and once with
//! `TSAR_NATIVE_FORCE_SCALAR=1` (proving the portable fallback).

use tsar::config::IsaConfig;
use tsar::kernels::native::{detect_path, NativeGemv, NativePath, Workspace};
use tsar::model::checkpoint::{Checkpoint, TransformerConfig};
use tsar::model::transformer::LinearEngine;
use tsar::runtime::{Backend, BatchItem, ModelBackend, ModelBackendConfig};
use tsar::sim::GemmShape;
use tsar::util::rng::Rng;

/// Run `cases` randomized comparisons of the batched pool GEMM against
/// the serialized scoped-thread anchor on `path`.  A single [`Workspace`]
/// persists across all cases, so buffer reuse across growing and
/// shrinking shapes is exercised as hard as the kernels themselves.
fn fuzz_batched_vs_serialized(path: NativePath, cases: usize, seed0: u64) {
    assert!(cases >= 120, "acceptance demands >= 120 randomized cases");
    let mut ws = Workspace::new();
    for case in 0..cases {
        let mut rng = Rng::new(seed0 + case as u64);
        let isa = if rng.f64() < 0.5 { IsaConfig::C2 } else { IsaConfig::C4 };
        // n crosses several GEMM_ROW_BLOCK boundaries (1..=18 with a
        // block of 4: full blocks, ragged tails, single rows).
        let n = rng.range_i64(1, 18) as usize;
        let k = rng.range_i64(1, 180) as usize;
        let m = rng.range_i64(1, 90) as usize;
        let threads = rng.range_i64(1, 5) as usize;
        let shape = GemmShape::new(n, k, m);
        let acts = rng.int8_acts(n * k);
        let zero_frac = rng.f64();
        let w = rng.ternary_matrix(m, k, zero_frac);

        let gemv = NativeGemv::with_path(isa, path).unwrap().with_threads(threads).unwrap();
        let packed = gemv.pack(&w, m, k).unwrap();

        // Anchor: n serialized per-row GEMVs (legacy scoped-thread path).
        let mut serial = vec![0i32; n * m];
        gemv.gemm_scoped(&acts, &packed, n, &mut serial).unwrap();

        let mut pooled = vec![0i32; n * m];
        gemv.gemm(&acts, &packed, n, &mut pooled).unwrap();
        assert_eq!(
            pooled,
            serial,
            "case {case}: pool gemm != serialized for {} {shape:?} threads={threads} \
             path={} (zeros {zero_frac:.2})",
            isa.name(),
            path.name()
        );

        let mut owned = vec![0i32; n * m];
        gemv.gemm_with(&mut ws, &acts, &packed, n, &mut owned).unwrap();
        assert_eq!(
            owned, serial,
            "case {case}: caller-owned workspace diverged for {} {shape:?}",
            isa.name()
        );
    }
}

#[test]
fn batched_gemm_matches_serialized_on_randomized_cases() {
    // Whatever the host supports: AVX2 where available, else scalar.
    fuzz_batched_vs_serialized(detect_path(), 140, 0xBA7C_0000);
}

#[test]
fn scalar_batched_gemm_matches_serialized_on_randomized_cases() {
    // The portable row-blocked path must hold everywhere, including
    // AVX2 hosts.
    fuzz_batched_vs_serialized(NativePath::Scalar, 140, 0xBA7C_9999);
}

#[test]
fn batched_bitlinear_matches_per_row_calls_bit_for_bit() {
    // Quantization (per row) and dequantization (per element) are
    // row-local, and the integer GEMM underneath is bit-identical to
    // the per-row path — so even the f32 outputs must match exactly.
    for case in 0..24u64 {
        let mut rng = Rng::new(0xB17_1000 + case);
        let isa = if rng.f64() < 0.5 { IsaConfig::C2 } else { IsaConfig::C4 };
        let n = rng.range_i64(1, 9) as usize;
        let k = rng.range_i64(1, 96) as usize;
        let m = rng.range_i64(1, 64) as usize;
        let threads = rng.range_i64(1, 4) as usize;
        let gemv = NativeGemv::new(isa).unwrap().with_threads(threads).unwrap();
        let zero_frac = rng.f64();
        let w = rng.ternary_matrix(m, k, zero_frac);
        let packed = gemv.pack(&w, m, k).unwrap();
        let x: Vec<f32> = (0..n * k).map(|_| (rng.f64() * 4.0 - 2.0) as f32).collect();
        let scale = 0.0625;

        let mut batched = vec![0f32; n * m];
        gemv.gemm_bitlinear(&x, &packed, n, scale, &mut batched).unwrap();

        let mut per_row = vec![0f32; n * m];
        for (r, out_row) in per_row.chunks_exact_mut(m).enumerate() {
            let row = &x[r * k..(r + 1) * k];
            gemv.gemm_bitlinear(row, &packed, 1, scale, out_row).unwrap();
        }
        let same = batched.iter().zip(&per_row).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(
            same,
            "case {case}: batched bitlinear f32 outputs drifted from per-row calls \
             (n={n} k={k} m={m} {})",
            isa.name()
        );

        // Caller-owned workspace variant agrees too.
        let mut ws = Workspace::new();
        let mut owned = vec![0f32; n * m];
        gemv.gemm_bitlinear_with(&mut ws, &x, &packed, n, scale, &mut owned).unwrap();
        assert!(owned.iter().zip(&batched).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}

// ---------------------------------------------------------------------------
// Model level: whole decode rounds through the pool must not change
// tokens or KV state.
// ---------------------------------------------------------------------------

fn toy_backend(threads: usize) -> ModelBackend {
    let ckpt = Checkpoint::synthesize(TransformerConfig::toy(), 0xC0FFEE).unwrap();
    let engine = LinearEngine::native(IsaConfig::C2, threads).unwrap();
    let cfg = ModelBackendConfig { prefill_len: 8, max_seq: 24, ..Default::default() };
    ModelBackend::new(&ckpt, engine, cfg).unwrap()
}

#[test]
fn model_decode_batch_on_pool_threads_leaves_tokens_unchanged() {
    let serial = toy_backend(1); // threads=1 never touches the pool
    let pooled = toy_backend(4); // pool-resident lanes per GEMM

    let prompts: [&[i32]; 3] = [&[2, 7, 1], &[5, 5], &[9, 3, 3, 1]];
    let p = serial.config().prefill_len;

    // Prefill every sequence on both backends; prefill itself is a
    // batched n-row GEMM per site, so tokens must already agree here.
    let mut caches = Vec::new();
    let mut tokens = Vec::new();
    for prompt in prompts {
        let mut padded = prompt.to_vec();
        padded.resize(p, 0);
        let a = serial.prefill(&padded, prompt.len() as i32).unwrap();
        let b = pooled.prefill(&padded, prompt.len() as i32).unwrap();
        assert_eq!(a.next_token, b.next_token, "prefill token diverged for {prompt:?}");
        tokens.push(a.next_token);
        caches.push((a.cache, b.cache));
    }

    // Three whole decode rounds: the pool backend's decode_batch vs the
    // serialized backend's batch-1 decode loop.
    for round in 0..3 {
        let reqs: Vec<BatchItem<'_, _>> = tokens
            .iter()
            .zip(&caches)
            .map(|(&token, (a, _))| BatchItem { token, pos: a.len() as i32, cache: a })
            .collect();
        let serial_steps: Vec<_> = reqs
            .iter()
            .map(|r| serial.decode(r.token, r.pos, r.cache).unwrap())
            .collect();

        let pooled_reqs: Vec<BatchItem<'_, _>> = tokens
            .iter()
            .zip(&caches)
            .map(|(&token, (_, b))| BatchItem { token, pos: b.len() as i32, cache: b })
            .collect();
        let pooled_steps = pooled.decode_batch(&pooled_reqs).unwrap();

        assert_eq!(serial_steps.len(), pooled_steps.len());
        for (i, (a, b)) in serial_steps.iter().zip(&pooled_steps).enumerate() {
            assert_eq!(
                a.next_token, b.next_token,
                "round {round} seq {i}: pooled decode_batch changed the token stream"
            );
            assert_eq!(a.cache.len(), b.cache.len(), "round {round} seq {i}: KV length drifted");
        }
        tokens = serial_steps.iter().map(|s| s.next_token).collect();
        caches = serial_steps
            .into_iter()
            .zip(pooled_steps)
            .map(|(a, b)| (a.cache, b.cache))
            .collect();
    }
}

#[test]
fn model_generate_is_thread_count_invariant() {
    // End-to-end greedy generation: the `--threads` knob must be
    // unobservable in the emitted tokens.
    let a = toy_backend(1).generate(&[4, 2, 8], 5).unwrap();
    let b = toy_backend(4).generate(&[4, 2, 8], 5).unwrap();
    assert_eq!(a, b, "generate() diverged between threads=1 and threads=4");
    assert!(!a.is_empty());
}
