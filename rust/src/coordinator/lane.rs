//! One worker lane of the continuous-batching serving engine
//! (DESIGN.md §3).
//!
//! A lane owns its own batcher, KV-slot pool, and **virtual clock**,
//! but no longer a static shard: per iteration it **pulls** as many
//! requests from the shared [`super::scheduler::Scheduler`] as it has
//! free batch+KV slots (joining them into the running batch mid-flight
//! whenever a retire freed a slot — continuous batching), admits them
//! (prefill), runs one *batched* decode round over its active set
//! through [`Backend::decode_batch`], and retires finished sequences —
//! freeing slots immediately, vLLM-style.  An idle lane steals
//! queued-but-unassigned requests from overloaded siblings through the
//! same pull (the scheduler's work-stealing deque layer); once a
//! request is pulled it executes on this lane to completion — a
//! sequence never migrates lanes mid-generation, so the per-sequence
//! `pos == cache.len()` KV contract is untouched by scheduling.
//!
//! Streaming: every step emits a [`TokenEvent`] on the request's ticket
//! channel as it lands (`Prefilled` after the prefill step, `Token` per
//! decode step, one terminal event at retire), so clients observe
//! generation mid-round instead of after the sequence retires.
//! Cancellation and per-request deadlines are honored at *round
//! boundaries* (admission time and between decode rounds): a cancelled
//! or expired sequence retires immediately — its KV slot and batch slot
//! free up for the next pending request — with the tokens generated so
//! far and a `Cancelled` terminal event.  Stop tokens retire a sequence
//! the moment one is emitted.
//!
//! Timing: backends that model execution report per-step simulated
//! costs; the lane accumulates them on its local clock (steps within a
//! lane are serialized, so lane-simulated time is their sum).  Backends
//! that execute for real contribute measured busy wall seconds instead.
//! Either way the clock counts *busy* time only — a lane that never
//! receives work stays at zero, so the server's merge-at-retire step
//! can reconcile the lane clocks into one global timeline: lanes run
//! concurrently over disjoint shards, so the merged makespan is the
//! slowest lane's clock (`max`), while the sum of lane clocks is
//! aggregate busy time.  Cancellation checks spend no virtual time, so
//! tokens and clocks of non-cancelled runs are bit-identical to the
//! pre-streaming engine.
//!
//! Fault isolation: a failing prefill retires that request with a
//! `Failed` result; a failing batched round falls back to serialized
//! batch-1 steps so one poisoned sequence retires (`Failed`, partial
//! output) instead of taking down its whole round.

use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::runtime::{Backend, BatchItem, Step};
use crate::util::error::Result;

use super::batcher::Batcher;
use super::kvpool::{KvSlotPool, SlotId};
use super::metrics::{LaneStats, RequestRecord};
use super::request::{FinishReason, Request, RequestId, RequestResult, TokenEvent};
use super::scheduler::{LaneParkGuard, Pull, Scheduler};
use super::serve::ServerConfig;

/// An active sequence's decode state, generic over the backend's KV
/// representation.
struct Active<C> {
    req: Request,
    tokens: Vec<i32>,
    cache: C,
    pos: i32,
    queue_s: f64,
    prefill_s: f64,
    decode_s: f64,
    /// Lane-clock reading at admission (simulated backends).
    admit_clock: f64,
    /// The sequence was admitted into a batch that had already run
    /// decode rounds (a mid-flight continuous-batching join).
    joined: bool,
    /// Terminal condition, once known (stop token, budget, KV window,
    /// backend failure).  Cancellation/deadline are decided at round
    /// boundaries, not stored here.
    finish: Option<FinishReason>,
    /// Backend error text accompanying `FinishReason::Failed`.
    error: Option<String>,
}

impl<C> Active<C> {
    /// After a token landed: record stop-token / budget / KV-window
    /// terminal conditions.  Stop tokens win over the length cap when a
    /// single token triggers both.
    ///
    /// The KV cutoff is aligned with both the backend's decode guard
    /// (`pos < max_seq`, see [`Backend::generate_until`]) and
    /// admission-time `validate_request` (`prompt_len + max_new_tokens
    /// <= max_seq`): `self.pos` is the position the *next* decode step
    /// would consume, so the window ends exactly when `pos` reaches
    /// `max_seq` — never a token earlier, and without the `max_seq - 1`
    /// underflow on a degenerate zero-token window.
    fn note_terminal(&mut self, token: i32, max_seq: usize) {
        if self.finish.is_some() {
            return;
        }
        if self.req.params.stop_tokens.contains(&token) {
            self.finish = Some(FinishReason::Stop);
        } else if self.tokens.len() >= self.req.params.max_new_tokens
            || (self.pos as usize) >= max_seq
        {
            self.finish = Some(FinishReason::Length);
        }
    }
}

/// Everything a lane hands back to the merge step.
pub(crate) struct LaneOutcome {
    pub results: Vec<RequestResult>,
    pub stats: LaneStats,
    /// Whether any step reported a simulated cost (the lane clock is a
    /// virtual timeline rather than busy wall time).
    pub sim_timed: bool,
}

/// Apply one decode step to `seq`, accounting its cost (simulated, or
/// `wall_s` measured busy seconds) on the lane clock, streaming the
/// token to the ticket, and recording any terminal condition.  The
/// clock accumulates *busy* time in both modes — an idle lane's clock
/// stays at zero, so the merge never mixes blocked real time into a
/// simulated timeline.
fn apply_step<C>(
    seq: &mut Active<C>,
    step: Step<C>,
    wall_s: f64,
    max_seq: usize,
    clock: &mut f64,
    sim_timed: &mut bool,
) {
    let cost = match step.cost_s {
        Some(c) => {
            *sim_timed = true;
            c
        }
        None => wall_s,
    };
    *clock += cost;
    seq.decode_s += cost;
    let index = seq.tokens.len();
    seq.tokens.push(step.next_token);
    seq.cache = step.cache;
    seq.pos += 1;
    seq.req.emit(TokenEvent::Token { token: step.next_token, index });
    seq.note_terminal(step.next_token, max_seq);
}

/// Retire one request: emit the terminal ticket event matching its
/// finish reason, stream the metrics record, and push the result to the
/// completion channel and the lane's result list.
#[allow(clippy::too_many_arguments)]
fn finish_request(
    req: &Request,
    res: RequestResult,
    lane_id: usize,
    joined: bool,
    plan: &Option<String>,
    tx: &Sender<RequestResult>,
    sink: &Option<Sender<RequestRecord>>,
    results: &mut Vec<RequestResult>,
    stats: &mut LaneStats,
) {
    // The terminal event clones the full result (token vector
    // included); skip building it entirely for legacy batch requests
    // that have no ticket stream.
    if req.events.is_some() {
        let event = match res.finish {
            FinishReason::Length | FinishReason::Stop => TokenEvent::Retired(res.clone()),
            FinishReason::Cancelled | FinishReason::DeadlineExpired => {
                TokenEvent::Cancelled(res.clone())
            }
            FinishReason::Failed => TokenEvent::Failed(res.clone()),
        };
        req.emit(event);
    }
    if let Some(sink) = sink {
        // The sink is best-effort: a hung-up scraper must not stall
        // serving.
        let _ = sink.send(RequestRecord {
            id: res.id,
            lane: Some(lane_id),
            executed_lane: Some(lane_id),
            queue_s: res.queue_s,
            queue_wait_s: req.queue_wait_s.unwrap_or(res.queue_s),
            prefill_s: res.prefill_s,
            decode_s: res.decode_s,
            total_s: res.total_s,
            tokens: res.tokens.len(),
            finish: res.finish,
            stolen: req.stolen,
            joined_midflight: joined,
            plan: plan.clone(),
        });
    }
    let _ = tx.send(res.clone());
    stats.requests += 1;
    results.push(res);
}

/// Pull requests for lane `lane_id` from the shared scheduler, pushing
/// completions into `tx` (and per-request records into `sink`, when
/// attached) until admission closes and all pulled work retires.
pub(crate) fn lane_loop<B: Backend>(
    backend: &B,
    cfg: &ServerConfig,
    lane_id: usize,
    sched: &Scheduler,
    tx: Sender<RequestResult>,
    sink: Option<Sender<RequestRecord>>,
) -> Result<LaneOutcome> {
    // Leave the ordered pull rotation even on panic or early `?` exit,
    // so sibling lanes never wait on this lane's stale clock.
    let _park = LaneParkGuard::new(sched, lane_id);
    let plan = backend.plan_summary();
    let mut batcher = Batcher::new(cfg.max_batch);
    let mut pool = KvSlotPool::new(cfg.kv_slots);
    let mut active: HashMap<RequestId, (Active<B::Cache>, SlotId)> = HashMap::new();
    let mut results: Vec<RequestResult> = Vec::new();
    let mut stats = LaneStats::new(lane_id, cfg.max_batch);
    // Lane-local clock: sum of backend-reported simulated step costs,
    // or of measured busy wall seconds for backends that execute for
    // real.  Either way it is *busy* time only — an idle lane stays at
    // zero and never pollutes the merged timeline.
    let mut clock = 0.0f64;
    let mut sim_timed = false;
    // The current batch has run at least one decode round: any further
    // admission before it drains is a mid-flight join.
    let mut batch_running = false;

    loop {
        // Pull from the shared admission queue: exactly as many
        // requests as this lane can admit right now (free batch + KV
        // slots), so every pulled request joins the batch this
        // iteration and the scheduler keeps the rest visible to
        // sibling lanes (stealable).  Blocks only when idle.
        let want = cfg
            .max_batch
            .saturating_sub(batcher.active_len() + batcher.pending_len())
            .min(pool.available());
        match sched.pull(lane_id, want, batcher.has_work()) {
            Pull::Batch(reqs) => {
                for req in reqs {
                    if req.stolen {
                        stats.steals += 1;
                    }
                    batcher.submit(req);
                }
            }
            Pull::Pending => {}
            Pull::Closed => {
                if !batcher.has_work() {
                    break;
                }
            }
        }

        // 1. Admission + prefill.
        while pool.available() > 0 {
            let Some(req) = batcher.admit() else { break };
            let slot = pool.allocate().expect("available() said so");
            let queue_s = req.arrival.elapsed().as_secs_f64();
            // Cancellation/deadline at the admission boundary: the
            // request retires before spending any prefill work.
            if req.cancel_requested() || req.deadline_expired() {
                let finish = if req.cancel_requested() {
                    FinishReason::Cancelled
                } else {
                    FinishReason::DeadlineExpired
                };
                batcher.finish(req.id)?;
                pool.release(slot)?;
                let res = RequestResult {
                    id: req.id,
                    tokens: Vec::new(),
                    finish,
                    error: None,
                    queue_s,
                    prefill_s: 0.0,
                    decode_s: 0.0,
                    total_s: queue_s,
                };
                finish_request(
                    &req, res, lane_id, false, &plan, &tx, &sink, &mut results, &mut stats,
                );
                continue;
            }
            let p = backend.config().prefill_len;
            let mut padded = vec![0i32; p];
            let plen = req.prompt.len().min(p);
            padded[..plen].copy_from_slice(&req.prompt[..plen]);
            let admit_clock = clock;
            let t0 = Instant::now();
            let out = match backend.prefill(&padded, plen as i32) {
                Ok(out) => out,
                Err(e) => {
                    // One malformed request must not take down the
                    // lane or the rest of the batch: retire it with a
                    // Failed result, free its slots, keep serving.
                    eprintln!("lane {lane_id}: request {}: prefill failed: {e}", req.id);
                    batcher.finish(req.id)?;
                    pool.release(slot)?;
                    let res = RequestResult {
                        id: req.id,
                        tokens: Vec::new(),
                        finish: FinishReason::Failed,
                        error: Some(format!("prefill failed: {e}")),
                        queue_s,
                        prefill_s: 0.0,
                        decode_s: 0.0,
                        total_s: queue_s,
                    };
                    finish_request(
                        &req, res, lane_id, false, &plan, &tx, &sink, &mut results,
                        &mut stats,
                    );
                    continue;
                }
            };
            let prefill_s = match out.cost_s {
                Some(c) => {
                    sim_timed = true;
                    c
                }
                None => t0.elapsed().as_secs_f64(),
            };
            clock += prefill_s;
            req.emit(TokenEvent::Prefilled { token: out.next_token });
            // Continuous batching: an admission into a batch that has
            // already decoded is a mid-flight join (a retire freed the
            // slot this sequence takes, without a batch boundary).
            let joined = batch_running;
            if joined {
                stats.joins += 1;
            }
            let mut seq = Active {
                pos: plen as i32,
                tokens: vec![out.next_token],
                cache: out.cache,
                req,
                queue_s,
                prefill_s,
                decode_s: 0.0,
                admit_clock,
                joined,
                finish: None,
                error: None,
            };
            seq.note_terminal(out.next_token, backend.config().max_seq);
            active.insert(seq.req.id, (seq, slot));
        }

        // 2. One batched decode round over the active set.  The round
        // boundary is also where cancellation and deadlines take
        // effect: a flagged sequence joins the retire list instead of
        // the round.
        let order: Vec<RequestId> = (0..batcher.active_len())
            .filter_map(|_| batcher.next_decode())
            .collect();
        let max_seq = backend.config().max_seq;
        let mut retired: Vec<RequestId> = Vec::new();
        let mut ready: Vec<RequestId> = Vec::new();
        for id in &order {
            let Some((seq, _slot)) = active.get_mut(id) else { continue };
            if seq.finish.is_some() {
                retired.push(*id);
            } else if seq.req.cancel_requested() {
                seq.finish = Some(FinishReason::Cancelled);
                retired.push(*id);
            } else if seq.req.deadline_expired() {
                seq.finish = Some(FinishReason::DeadlineExpired);
                retired.push(*id);
            } else {
                ready.push(*id);
            }
        }

        if !ready.is_empty() {
            batch_running = true;
            let width = ready.len();
            let t0 = Instant::now();
            let round = {
                let items: Vec<BatchItem<'_, B::Cache>> = ready
                    .iter()
                    .map(|id| {
                        let (seq, _slot) = &active[id];
                        BatchItem {
                            token: *seq.tokens.last().expect("prefill seeded tokens"),
                            pos: seq.pos,
                            cache: &seq.cache,
                        }
                    })
                    .collect();
                backend.decode_batch(&items)
            };
            match round {
                Ok(steps) => {
                    stats.record_round(width);
                    let wall_share = t0.elapsed().as_secs_f64() / width as f64;
                    for (id, step) in ready.iter().zip(steps) {
                        let (seq, _slot) =
                            active.get_mut(id).expect("ready ids are active");
                        apply_step(seq, step, wall_share, max_seq, &mut clock, &mut sim_timed);
                        if seq.finish.is_some() {
                            retired.push(*id);
                        }
                    }
                }
                Err(e) => {
                    // Round-level failure: fall back to serialized
                    // batch-1 steps so only the poisoned sequence(s)
                    // retire with partial output.
                    eprintln!(
                        "lane {lane_id}: decode_batch of width {width} failed: {e}; \
                         retrying serialized"
                    );
                    for id in &ready {
                        let (seq, _slot) =
                            active.get_mut(id).expect("ready ids are active");
                        let t1 = Instant::now();
                        match backend.decode(
                            *seq.tokens.last().expect("prefill seeded tokens"),
                            seq.pos,
                            &seq.cache,
                        ) {
                            Ok(step) => {
                                stats.record_round(1);
                                let wall = t1.elapsed().as_secs_f64();
                                apply_step(
                                    seq, step, wall, max_seq, &mut clock, &mut sim_timed,
                                );
                                if seq.finish.is_some() {
                                    retired.push(*id);
                                }
                            }
                            Err(e) => {
                                eprintln!(
                                    "lane {lane_id}: request {}: decode failed: {e}; \
                                     retiring with partial output",
                                    seq.req.id
                                );
                                seq.finish = Some(FinishReason::Failed);
                                seq.error = Some(format!("decode failed: {e}"));
                                retired.push(*id);
                            }
                        }
                    }
                }
            }
        }

        // 3. Retire: free the batch and KV slots immediately, then emit
        // the terminal event/result.
        for id in retired {
            let (seq, slot) = active.remove(&id).expect("retired ids are active");
            batcher.finish(id)?;
            pool.release(slot)?;
            let total_s = if sim_timed {
                // Virtual residency (including rounds spent on
                // interleaved neighbours) + real queue wait.
                seq.queue_s + (clock - seq.admit_clock)
            } else {
                seq.req.arrival.elapsed().as_secs_f64()
            };
            let Active { req, tokens, queue_s, prefill_s, decode_s, finish, error, joined, .. } =
                seq;
            let res = RequestResult {
                id,
                tokens,
                finish: finish.unwrap_or(FinishReason::Length),
                error,
                queue_s,
                prefill_s,
                decode_s,
                total_s,
            };
            finish_request(
                &req, res, lane_id, joined, &plan, &tx, &sink, &mut results, &mut stats,
            );
        }
        if active.is_empty() {
            // The batch drained: the next admissions form a fresh
            // batch, not a mid-flight join.
            batch_running = false;
        }

        // Publish the lane clock: in ordered (preloaded) mode this
        // hands the pull turn to the next lane in virtual-time order.
        sched.update_clock(lane_id, clock);
    }

    stats.clock_s = clock;
    Ok(LaneOutcome { results, stats, sim_timed })
}
