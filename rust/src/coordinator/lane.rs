//! One worker lane of the sharded serving engine (DESIGN.md §3).
//!
//! A lane owns a shard of the admitted sequences: its own continuous
//! batcher, KV-slot pool, and **virtual clock**.  Per iteration it
//! admits pending requests (prefill), runs one *batched* decode round
//! over its active set through [`Backend::decode_batch`], and retires
//! finished sequences — freeing slots immediately, vLLM-style.
//!
//! Timing: backends that model execution report per-step simulated
//! costs; the lane accumulates them on its local clock (steps within a
//! lane are serialized, so lane-simulated time is their sum).  Backends
//! that execute for real contribute measured busy wall seconds instead.
//! Either way the clock counts *busy* time only — a lane that never
//! receives work stays at zero, so the server's merge-at-retire step
//! can reconcile the lane clocks into one global timeline: lanes run
//! concurrently over disjoint shards, so the merged makespan is the
//! slowest lane's clock (`max`), while the sum of lane clocks is
//! aggregate busy time.
//!
//! Fault isolation: a failing prefill drops that request; a failing
//! batched round falls back to serialized batch-1 steps so one poisoned
//! sequence retires with partial output instead of taking down its
//! whole round.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::time::Instant;

use crate::runtime::{Backend, BatchItem, Step};
use crate::util::error::Result;

use super::batcher::Batcher;
use super::kvpool::{KvSlotPool, SlotId};
use super::metrics::{LaneStats, RequestRecord};
use super::request::{Request, RequestId, RequestResult};
use super::serve::ServerConfig;

/// An active sequence's decode state, generic over the backend's KV
/// representation.
struct Active<C> {
    req: Request,
    tokens: Vec<i32>,
    cache: C,
    pos: i32,
    queue_s: f64,
    prefill_s: f64,
    decode_s: f64,
    /// Lane-clock reading at admission (simulated backends).
    admit_clock: f64,
}

/// Everything a lane hands back to the merge step.
pub(crate) struct LaneOutcome {
    pub results: Vec<RequestResult>,
    pub stats: LaneStats,
    /// Whether any step reported a simulated cost (the lane clock is a
    /// virtual timeline rather than busy wall time).
    pub sim_timed: bool,
}

/// Has `seq` hit its token budget or the KV window?
fn seq_done<C>(seq: &Active<C>, max_seq: usize) -> bool {
    seq.tokens.len() >= seq.req.max_new_tokens || (seq.pos as usize) >= max_seq - 1
}

/// Apply one decode step to `seq`, accounting its cost (simulated, or
/// `wall_s` measured busy seconds) on the lane clock; returns whether
/// the sequence is now done.  The clock accumulates *busy* time in both
/// modes — an idle lane's clock stays at zero, so the merge never mixes
/// blocked real time into a simulated timeline.
fn apply_step<C>(
    seq: &mut Active<C>,
    step: Step<C>,
    wall_s: f64,
    max_seq: usize,
    clock: &mut f64,
    sim_timed: &mut bool,
) -> bool {
    let cost = match step.cost_s {
        Some(c) => {
            *sim_timed = true;
            c
        }
        None => wall_s,
    };
    *clock += cost;
    seq.decode_s += cost;
    seq.tokens.push(step.next_token);
    seq.cache = step.cache;
    seq.pos += 1;
    seq_done(seq, max_seq)
}

/// Drain `rx` on lane `lane_id`, pushing completions into `tx` (and
/// per-request records into `sink`, when attached) until the shard
/// channel closes and all admitted work retires.
pub(crate) fn lane_loop<B: Backend>(
    backend: &B,
    cfg: &ServerConfig,
    lane_id: usize,
    rx: Receiver<Request>,
    tx: Sender<RequestResult>,
    sink: Option<Sender<RequestRecord>>,
) -> Result<LaneOutcome> {
    let plan = backend.plan_summary();
    let mut batcher = Batcher::new(cfg.max_batch);
    let mut pool = KvSlotPool::new(cfg.kv_slots);
    let mut active: HashMap<RequestId, (Active<B::Cache>, SlotId)> = HashMap::new();
    let mut results: Vec<RequestResult> = Vec::new();
    let mut stats = LaneStats::new(lane_id, cfg.max_batch);
    let mut open = true;
    // Lane-local clock: sum of backend-reported simulated step costs,
    // or of measured busy wall seconds for backends that execute for
    // real.  Either way it is *busy* time only — an idle lane stays at
    // zero and never pollutes the merged timeline.
    let mut clock = 0.0f64;
    let mut sim_timed = false;

    while open || batcher.has_work() {
        // Pull newly arrived requests (non-blocking unless idle).
        loop {
            if !open {
                break;
            }
            let msg = if batcher.has_work() {
                match rx.try_recv() {
                    Ok(r) => Some(r),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        open = false;
                        None
                    }
                }
            } else {
                // Idle: block for the next request or shutdown.
                match rx.recv() {
                    Ok(r) => Some(r),
                    Err(_) => {
                        open = false;
                        None
                    }
                }
            };
            match msg {
                Some(r) => batcher.submit(r),
                None => break,
            }
        }

        // 1. Admission + prefill.
        while pool.available() > 0 {
            let Some(req) = batcher.admit() else { break };
            let slot = pool.allocate().expect("available() said so");
            let queue_s = req.arrival.elapsed().as_secs_f64();
            let p = backend.config().prefill_len;
            let mut padded = vec![0i32; p];
            let plen = req.prompt.len().min(p);
            padded[..plen].copy_from_slice(&req.prompt[..plen]);
            let admit_clock = clock;
            let t0 = Instant::now();
            let out = match backend.prefill(&padded, plen as i32) {
                Ok(out) => out,
                Err(e) => {
                    // One malformed request must not take down the
                    // lane or the rest of the batch: drop it, free its
                    // slots, keep serving.
                    eprintln!("lane {lane_id}: request {}: prefill failed: {e}", req.id);
                    batcher.finish(req.id)?;
                    pool.release(slot)?;
                    continue;
                }
            };
            let prefill_s = match out.cost_s {
                Some(c) => {
                    sim_timed = true;
                    c
                }
                None => t0.elapsed().as_secs_f64(),
            };
            clock += prefill_s;
            active.insert(
                req.id,
                (
                    Active {
                        pos: plen as i32,
                        tokens: vec![out.next_token],
                        cache: out.cache,
                        req,
                        queue_s,
                        prefill_s,
                        decode_s: 0.0,
                        admit_clock,
                    },
                    slot,
                ),
            );
        }

        // 2. One batched decode round over the active set.
        let order: Vec<RequestId> = (0..batcher.active_len())
            .filter_map(|_| batcher.next_decode())
            .collect();
        let max_seq = backend.config().max_seq;
        let mut retired: Vec<RequestId> = Vec::new();
        let mut ready: Vec<RequestId> = Vec::new();
        for id in &order {
            let Some((seq, _slot)) = active.get(id) else { continue };
            if seq_done(seq, max_seq) {
                retired.push(*id);
            } else {
                ready.push(*id);
            }
        }

        if !ready.is_empty() {
            let width = ready.len();
            let t0 = Instant::now();
            let round = {
                let items: Vec<BatchItem<'_, B::Cache>> = ready
                    .iter()
                    .map(|id| {
                        let (seq, _slot) = &active[id];
                        BatchItem {
                            token: *seq.tokens.last().expect("prefill seeded tokens"),
                            pos: seq.pos,
                            cache: &seq.cache,
                        }
                    })
                    .collect();
                backend.decode_batch(&items)
            };
            match round {
                Ok(steps) => {
                    stats.record_round(width);
                    let wall_share = t0.elapsed().as_secs_f64() / width as f64;
                    for (id, step) in ready.iter().zip(steps) {
                        let (seq, _slot) =
                            active.get_mut(id).expect("ready ids are active");
                        if apply_step(seq, step, wall_share, max_seq, &mut clock, &mut sim_timed)
                        {
                            retired.push(*id);
                        }
                    }
                }
                Err(e) => {
                    // Round-level failure: fall back to serialized
                    // batch-1 steps so only the poisoned sequence(s)
                    // retire with partial output.
                    eprintln!(
                        "lane {lane_id}: decode_batch of width {width} failed: {e}; \
                         retrying serialized"
                    );
                    for id in &ready {
                        let (seq, _slot) =
                            active.get_mut(id).expect("ready ids are active");
                        let t1 = Instant::now();
                        match backend.decode(
                            *seq.tokens.last().expect("prefill seeded tokens"),
                            seq.pos,
                            &seq.cache,
                        ) {
                            Ok(step) => {
                                stats.record_round(1);
                                let wall = t1.elapsed().as_secs_f64();
                                if apply_step(
                                    seq,
                                    step,
                                    wall,
                                    max_seq,
                                    &mut clock,
                                    &mut sim_timed,
                                ) {
                                    retired.push(*id);
                                }
                            }
                            Err(e) => {
                                eprintln!(
                                    "lane {lane_id}: request {}: decode failed: {e}; \
                                     retiring with partial output",
                                    seq.req.id
                                );
                                retired.push(*id);
                            }
                        }
                    }
                }
            }
        }

        // 3. Retire.
        for id in retired {
            let (seq, slot) = active.remove(&id).expect("retired ids are active");
            batcher.finish(id)?;
            pool.release(slot)?;
            let total_s = if sim_timed {
                // Virtual residency (including rounds spent on
                // interleaved neighbours) + real queue wait.
                seq.queue_s + (clock - seq.admit_clock)
            } else {
                seq.req.arrival.elapsed().as_secs_f64()
            };
            let res = RequestResult {
                id,
                total_s,
                tokens: seq.tokens,
                queue_s: seq.queue_s,
                prefill_s: seq.prefill_s,
                decode_s: seq.decode_s,
            };
            if let Some(sink) = &sink {
                // The sink is best-effort: a hung-up scraper must not
                // stall serving.
                let _ = sink.send(RequestRecord {
                    id,
                    lane: lane_id,
                    queue_s: res.queue_s,
                    prefill_s: res.prefill_s,
                    decode_s: res.decode_s,
                    total_s: res.total_s,
                    tokens: res.tokens.len(),
                    plan: plan.clone(),
                });
            }
            let _ = tx.send(res.clone());
            stats.requests += 1;
            results.push(res);
        }
    }

    stats.clock_s = clock;
    Ok(LaneOutcome { results, stats, sim_timed })
}
