//! Adaptive kernel selection (paper §III-D): "At compile-time, T-SAR's
//! inference framework empirically selects the fastest kernel for each
//! layer."
//!
//! At model-load time every BitLinear site's GEMV/GEMM shape is swept
//! through all six T-SAR kernels on the target platform model; the
//! fastest (dataflow, ISA-config) pair is recorded in the [`ModelPlan`]
//! the serving loop consults.

use crate::config::platforms::Platform;
use crate::kernels::{select_tsar_kernel, TernaryKernel, TsarKernel};
use crate::model::zoo::ModelSpec;
use crate::model::Workload;
use crate::sim::GemmShape;

/// The chosen kernel for one BitLinear site.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub site: &'static str,
    pub shape: GemmShape,
    pub kernel: TsarKernel,
    /// Simulated execution seconds for one invocation.
    pub seconds: f64,
    /// Invocations per forward pass (layer count, 1 for the LM head).
    pub count_hint: usize,
}

/// The per-layer kernel plan for one (model, platform, phase).
#[derive(Debug, Clone)]
pub struct ModelPlan {
    pub model: &'static str,
    pub n: usize,
    pub layers: Vec<LayerPlan>,
}

impl ModelPlan {
    /// Simulated seconds for one full forward pass.
    pub fn pass_seconds(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| l.seconds * l.count_hint as f64)
            .sum::<f64>()
    }
}

impl LayerPlan {
    pub fn describe(&self) -> String {
        format!(
            "{:<12} {:>4}x{:>5}x{:>5} -> {} ({:.3} ms)",
            self.site,
            self.shape.n,
            self.shape.k,
            self.shape.m,
            self.kernel.name(),
            self.seconds * 1e3
        )
    }
}

/// Select kernels for every BitLinear site of a forward pass.
pub fn select_plan(
    spec: &'static ModelSpec,
    plat: &Platform,
    n: usize,
    threads: usize,
) -> ModelPlan {
    let wl = Workload::new(spec, n);
    let layers = wl
        .ops
        .iter()
        .map(|op| {
            let (kernel, res) = select_tsar_kernel(op.shape, plat, threads);
            LayerPlan {
                site: op.site,
                shape: op.shape,
                kernel,
                seconds: res.seconds,
                count_hint: op.count,
            }
        })
        .collect();
    ModelPlan { model: spec.name, n, layers }
}

/// Compact per-site summary for backends that execute their BitLinear
/// GEMVs for real and therefore carry no simulated [`ModelPlan`]
/// timings: `site:NxKxM@engine` per site, in forward-pass order.  The
/// request-level metrics records show this next to the simulator
/// backends' `site:kernel` plan strings.
pub fn describe_site_shapes(sites: &[(&str, GemmShape)], engine: &str) -> String {
    sites
        .iter()
        .map(|(site, sh)| format!("{site}:{}x{}x{}@{engine}", sh.n, sh.k, sh.m))
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Dataflow;
    use crate::model::zoo::by_name;

    #[test]
    fn decode_plan_prefers_op() {
        // §III-D: OP suits the high-M GEMV layers that dominate decode.
        let spec = by_name("BitNet-2B-4T").unwrap();
        let plat = Platform::workstation();
        let decode = select_plan(spec, &plat, 1, plat.threads);
        let op_share = decode
            .layers
            .iter()
            .filter(|l| l.kernel.dataflow == Dataflow::Op)
            .count() as f64
            / decode.layers.len() as f64;
        assert!(op_share >= 0.5, "decode OP share {op_share}");
        // Prefill gets a valid plan with positive cost.
        let prefill = select_plan(spec, &plat, 128, plat.threads);
        assert!(prefill.pass_seconds() > 0.0);
        assert!(prefill.pass_seconds() > decode.pass_seconds());
    }

    #[test]
    fn plan_covers_all_sites() {
        let spec = by_name("BitNet-125M").unwrap();
        let plat = Platform::mobile();
        let plan = select_plan(spec, &plat, 1, 4);
        let sites: Vec<&str> = plan.layers.iter().map(|l| l.site).collect();
        for want in ["wqkv", "wo", "ffn-gate-up", "ffn-down", "lm-head"] {
            assert!(sites.contains(&want), "{want} missing");
        }
    }

    #[test]
    fn site_shape_summary_format() {
        let sites = [
            ("wqkv", GemmShape::new(1, 64, 128)),
            ("lm-head", GemmShape::new(1, 64, 256)),
        ];
        let s = describe_site_shapes(&sites, "native-avx2/c2");
        assert_eq!(s, "wqkv:1x64x128@native-avx2/c2 lm-head:1x64x256@native-avx2/c2");
        assert_eq!(describe_site_shapes(&[], "x"), "");
    }
}
