//! The session-based streaming serving engine (DESIGN.md §3).
//!
//! [`Engine::start`] spawns the worker lanes in the background and
//! returns an [`EngineHandle`]; from then on the engine is a live
//! service: [`EngineHandle::submit`] hands in a
//! [`GenerationRequest`] and returns a [`Ticket`] *immediately* —
//! before prefill, let alone decode, has run.  The ticket is the
//! per-request session: a live [`TokenEvent`] stream
//! ([`Ticket::recv`]/[`Ticket::try_recv`]), [`Ticket::cancel`] to stop
//! generation at the next round boundary, and a blocking
//! [`Ticket::join`] that drains the stream into the final
//! [`RequestResult`].  [`EngineHandle::shutdown`] closes admission,
//! drains every in-flight sequence, joins the lanes, and merges the
//! per-lane virtual clocks into the run's [`ServeReport`].
//!
//! Admission control happens at submit time: a request whose prompt
//! does not fit the prefill window, or whose `prompt_len +
//! max_new_tokens` exceeds the backend's KV capacity
//! (`ModelConfig::max_seq`), resolves to a clean `Failed` ticket
//! without ever reaching a lane — instead of erroring mid-decode when
//! the KV cache actually runs out.
//!
//! The pre-Engine blocking surface ([`super::Server::run`],
//! [`super::Server::run_preloaded`], [`super::serve_all`]) is a thin
//! wrapper over this module: same lanes, same clocks, bit-identical
//! tokens and makespans for non-cancelled workloads.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::runtime::Backend;
use crate::util::error::Result;

use super::lane::{lane_loop, LaneOutcome};
use super::metrics::{RequestRecord, ServeReport};
use super::request::{
    FinishReason, GenerationRequest, Request, RequestId, RequestResult, TokenEvent,
};
use super::scheduler::Scheduler;
use super::serve::ServerConfig;

/// Entry point of the streaming serving API.  `Engine` itself is a
/// namespace: all state lives on the [`EngineHandle`] that
/// [`Engine::start`] returns.
pub struct Engine;

impl Engine {
    /// Validate `cfg`, spawn the worker lanes in the background, and
    /// return the submission handle.  The calling thread never blocks
    /// on serving; the lanes share `backend` through an `Arc`.
    pub fn start<B>(backend: B, cfg: ServerConfig) -> Result<EngineHandle<B>>
    where
        B: Backend + Send + Sync + 'static,
    {
        Engine::start_inner(Arc::new(backend), cfg, None, None, false)
    }

    /// [`Engine::start`] over an already shared backend (the legacy
    /// `Server` wrappers use this so `&self` methods can start runs).
    pub fn start_shared<B>(backend: Arc<B>, cfg: ServerConfig) -> Result<EngineHandle<B>>
    where
        B: Backend + Send + Sync + 'static,
    {
        Engine::start_inner(backend, cfg, None, None, false)
    }

    /// [`Engine::start`] with a metrics sink attached: every retired
    /// request streams one [`RequestRecord`] over `record_tx` while
    /// the engine is live (the channel the JSONL
    /// [`super::Exporter`] sits on).
    pub fn start_with_sink<B>(
        backend: B,
        cfg: ServerConfig,
        record_tx: Option<Sender<RequestRecord>>,
    ) -> Result<EngineHandle<B>>
    where
        B: Backend + Send + Sync + 'static,
    {
        Engine::start_inner(Arc::new(backend), cfg, record_tx, None, false)
    }

    /// Full-control start used by the compatibility wrappers:
    /// `record_tx` streams per-request metrics records, `results_tx`
    /// mirrors every completion onto a legacy result channel, and
    /// `gated` holds the lanes at a start gate so a fixed request list
    /// can be queued deterministically before any lane pulls
    /// ([`EngineHandle::open_gate`]).  Gated engines also run the
    /// scheduler in *ordered* mode: requests are pre-assigned
    /// round-robin and pulls are totally ordered by lane virtual
    /// clocks, so a preloaded run's schedule (and its steals) is a
    /// pure function of the request list.
    pub(crate) fn start_inner<B>(
        backend: Arc<B>,
        cfg: ServerConfig,
        record_tx: Option<Sender<RequestRecord>>,
        results_tx: Option<Sender<RequestResult>>,
        gated: bool,
    ) -> Result<EngineHandle<B>>
    where
        B: Backend + Send + Sync + 'static,
    {
        crate::ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
        crate::ensure!(cfg.workers >= 1, "workers must be >= 1");
        crate::ensure!(
            cfg.kv_slots >= cfg.max_batch,
            "kv_slots ({}) must cover max_batch ({}) on every lane",
            cfg.kv_slots,
            cfg.max_batch
        );
        if let Some(cap) = cfg.queue_cap {
            crate::ensure!(cap >= 1, "queue_cap must be >= 1 when set");
        }
        let results_tx = results_tx.unwrap_or_else(|| {
            // No legacy channel: results flow through ticket events
            // only.  Lane sends are best-effort, so a dropped receiver
            // is fine.
            channel().0
        });
        let scheduler = Arc::new(Scheduler::new(cfg.workers, gated));
        let mut gate_txs = Vec::with_capacity(cfg.workers);
        let mut lanes = Vec::with_capacity(cfg.workers);
        for lane_id in 0..cfg.workers {
            let (gate_tx, gate_rx) = channel::<()>();
            if gated {
                gate_txs.push(gate_tx);
            }
            let backend = Arc::clone(&backend);
            let cfg = cfg.clone();
            let sched = Arc::clone(&scheduler);
            let res_tx = results_tx.clone();
            let sink = record_tx.clone();
            lanes.push(std::thread::spawn(move || {
                if gated {
                    // Held until the handle opens the gate (dropping
                    // the sender); an error is the open signal.
                    let _ = gate_rx.recv();
                }
                lane_loop(&*backend, &cfg, lane_id, &sched, res_tx, sink)
            }));
        }
        Ok(EngineHandle {
            backend,
            cfg,
            scheduler,
            preassigned: gated,
            gate_txs,
            lanes,
            next_id: AtomicU64::new(0),
            record_tx,
            rejected: Mutex::new(Vec::new()),
            started: Instant::now(),
        })
    }
}

/// Live handle over a started engine: submit sessions, then shut down
/// for the merged report.  The handle is `Sync` — client threads can
/// share it by reference and submit concurrently.
pub struct EngineHandle<B: Backend> {
    backend: Arc<B>,
    cfg: ServerConfig,
    /// Shared admission queue the lanes pull from (continuous
    /// batching + work stealing; see the `scheduler` module).
    scheduler: Arc<Scheduler>,
    /// Gated engines pre-assign round-robin for determinism; live
    /// engines enqueue onto the shared injector.
    preassigned: bool,
    gate_txs: Vec<Sender<()>>,
    lanes: Vec<JoinHandle<Result<LaneOutcome>>>,
    next_id: AtomicU64,
    /// Metrics sink, kept so submit-time rejections are streamed too.
    record_tx: Option<Sender<RequestRecord>>,
    /// Results of submissions rejected at admission (they never reach
    /// a lane); merged into the shutdown report.
    rejected: Mutex<Vec<RequestResult>>,
    started: Instant,
}

impl<B: Backend> EngineHandle<B> {
    /// The shared backend the lanes serve on.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Submit one generation request; returns its [`Ticket`]
    /// immediately (before any model work runs).  Admission-time
    /// validation failures — and, when [`ServerConfig::queue_cap`] is
    /// set, admission-queue overflow (backpressure shedding) — resolve
    /// the ticket to a `Failed` terminal event instead of reaching a
    /// lane.
    pub fn submit(&self, req: GenerationRequest) -> Ticket {
        self.submit_classified(req).0
    }

    /// [`EngineHandle::submit`] that additionally *classifies* an
    /// admission failure, so callers can distinguish backpressure
    /// shedding ([`SubmitError::QueueFull`] — the HTTP front-end maps
    /// it to `429 Too Many Requests`) from a request that can never
    /// succeed ([`SubmitError::Invalid`]).  Either way the ticket has
    /// already resolved to a `Failed` terminal event and the rejection
    /// is fully booked (shutdown report `failed` + `rejected`,
    /// `tsar_rejections_total`); the classification is advisory.
    pub fn submit_classified(&self, req: GenerationRequest) -> (Ticket, Option<SubmitError>) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (ev_tx, ev_rx) = channel::<TokenEvent>();
        let cancel = Arc::new(AtomicBool::new(false));
        let ticket = Ticket {
            id,
            events: ev_rx,
            cancel: Arc::clone(&cancel),
            terminal: RefCell::new(None),
        };
        if let Err(reason) = self.admit_check(&req) {
            self.reject(id, &ev_tx, reason.clone());
            return (ticket, Some(SubmitError::Invalid(reason)));
        }
        let request = Request::with_plumbing(id, req, ev_tx.clone(), cancel);
        if !self.dispatch(request, self.cfg.queue_cap) {
            let cap = self.cfg.queue_cap.unwrap_or(0);
            self.reject(id, &ev_tx, format!("admission queue full (queue_cap {cap})"));
            return (ticket, Some(SubmitError::QueueFull { cap }));
        }
        (ticket, None)
    }

    /// Legacy escape hatch: queue a pre-built [`Request`] (caller-owned
    /// id, optional plumbing) without admission-time validation — the
    /// pre-Engine batch surface, which caps generation at the KV window
    /// instead of rejecting up front.  Backpressure never applies here:
    /// preloaded lists must arrive whole for the deterministic
    /// schedule.
    pub fn submit_request(&self, request: Request) {
        let _ = self.dispatch(request, None);
    }

    /// Hand one request to the shared scheduler: deterministic
    /// round-robin pre-assignment for gated (preloaded) engines, the
    /// shared injector — any lane pulls it — for live ones.  `false`
    /// means the admission queue is at `cap` (the request was refused).
    fn dispatch(&self, request: Request, cap: Option<usize>) -> bool {
        if self.preassigned {
            self.scheduler.preassign(request, cap)
        } else {
            self.scheduler.enqueue(request, cap)
        }
    }

    /// Per-request admission limits against the backend's window
    /// (DESIGN.md §3): the prompt must be non-empty and fit the prefill
    /// window, and `prompt_len + max_new_tokens` must fit the KV
    /// capacity — rejecting at submit time what would otherwise die
    /// mid-decode on KV exhaustion.
    fn admit_check(&self, req: &GenerationRequest) -> std::result::Result<(), String> {
        let cfg = self.backend.config();
        cfg.validate_request(req.prompt.len(), req.params.max_new_tokens)
            .map_err(|e| e.to_string())
    }

    /// Resolve a ticket as `Failed` without involving a lane, and keep
    /// the rejection observable engine-wide: the result joins the
    /// shutdown report's `failed` *and* `rejected` counts and, when a
    /// metrics sink is attached, a `RequestRecord` with
    /// `executed_lane: None` streams out (counted by
    /// `tsar_rejections_total`, consistent with the report).
    fn reject(&self, id: RequestId, ev_tx: &Sender<TokenEvent>, reason: String) {
        let res = RequestResult {
            id,
            tokens: Vec::new(),
            finish: FinishReason::Failed,
            error: Some(reason),
            queue_s: 0.0,
            prefill_s: 0.0,
            decode_s: 0.0,
            total_s: 0.0,
        };
        let _ = ev_tx.send(TokenEvent::Failed(res.clone()));
        if let Some(sink) = &self.record_tx {
            let _ = sink.send(RequestRecord {
                id,
                lane: None,
                executed_lane: None,
                queue_s: 0.0,
                queue_wait_s: 0.0,
                prefill_s: 0.0,
                decode_s: 0.0,
                total_s: 0.0,
                tokens: 0,
                finish: FinishReason::Failed,
                stolen: false,
                joined_midflight: false,
                plan: None,
            });
        }
        self.rejected.lock().expect("rejected list poisoned").push(res);
    }

    /// Release the start gate of a [`Engine::start_inner`]-gated
    /// engine; a no-op otherwise.  Until released, lanes hold before
    /// their first pull, so everything submitted beforehand is queued
    /// (round-robin pre-assigned) deterministically.
    pub(crate) fn open_gate(&mut self) {
        self.gate_txs.clear();
    }

    /// Graceful shutdown: close admission, let every lane drain the
    /// queue (in-flight sequences run to their natural or cancelled
    /// end), join the lanes, and merge the per-lane virtual clocks —
    /// plus any submit-time rejections — into the run's
    /// [`ServeReport`].  A lane that panicked (or returned an error)
    /// becomes an entry in [`ServeReport::lane_errors`] while every
    /// surviving lane's results are kept — one poisoned backend must
    /// not erase the rest of the run.  Errs only when *nothing* can be
    /// reported: no request was ever submitted, or every lane failed
    /// before retiring anything.
    pub fn shutdown(mut self) -> Result<ServeReport> {
        self.open_gate();
        self.scheduler.close(); // close admission: lanes drain the queue and exit
        let outcomes: Vec<Result<LaneOutcome>> = self
            .lanes
            .drain(..)
            .map(|h| match h.join() {
                Ok(outcome) => outcome,
                // A panicking backend must not panic shutdown with it:
                // surface the lane's death as a failed lane outcome and
                // let the merge keep the survivors.
                Err(payload) => {
                    Err(crate::err!("lane thread panicked: {}", panic_text(payload.as_ref())))
                }
            })
            .collect();
        let rejected =
            std::mem::take(&mut *self.rejected.lock().expect("rejected list poisoned"));
        merge_outcomes(outcomes, rejected, self.started)
    }
}

impl<B: Backend> Drop for EngineHandle<B> {
    /// A handle dropped without [`EngineHandle::shutdown`] must still
    /// let the lanes exit: close admission (idempotent) so no lane
    /// blocks forever on an open queue.  Threads are detached, not
    /// joined — drop must not block.
    fn drop(&mut self) {
        self.gate_txs.clear();
        self.scheduler.close();
    }
}

/// Best-effort text of a thread's panic payload (`&str` and `String`
/// payloads cover `panic!`/`expect`/`assert!`).
fn panic_text(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

/// Merge-at-retire: reconcile the lane outcomes (and submit-time
/// rejections, which carry no lane or clock) into one report.  Lanes
/// are concurrent engines over disjoint shards, so the global simulated
/// timeline is the slowest lane's clock; real backends report elapsed
/// wall time instead.
///
/// A failed lane (panic or error) does *not* abort the merge: its
/// error text joins [`ServeReport::lane_errors`] and the surviving
/// lanes' results and stats are reported as usual.  Requests that were
/// in flight on a failed lane carry no terminal result — their ticket
/// streams simply close (and [`Ticket::join`] synthesizes a `Failed`
/// result client-side).
pub(crate) fn merge_outcomes(
    outcomes: Vec<Result<LaneOutcome>>,
    rejected: Vec<RequestResult>,
    started: Instant,
) -> Result<ServeReport> {
    let rejected_n = rejected.len();
    let mut results: Vec<RequestResult> = rejected;
    let mut lanes = Vec::with_capacity(outcomes.len());
    let mut lane_errors: Vec<String> = Vec::new();
    let mut sim_timed = false;
    for outcome in outcomes {
        match outcome {
            Ok(outcome) => {
                sim_timed |= outcome.sim_timed;
                results.extend(outcome.results);
                lanes.push(outcome.stats);
            }
            Err(e) => lane_errors.push(e.to_string()),
        }
    }
    let wall_s = if sim_timed {
        lanes.iter().map(|l| l.clock_s).fold(0.0f64, f64::max)
    } else {
        started.elapsed().as_secs_f64()
    };
    results.sort_by_key(|r| r.id);
    match ServeReport::from_lanes(&results, wall_s, lanes) {
        Some(mut report) => {
            report.lane_errors = lane_errors;
            report.rejected = rejected_n;
            Ok(report)
        }
        None if lane_errors.is_empty() => Err(crate::err!("no requests served")),
        None => Err(crate::err!("no lane survived: {}", lane_errors.join("; "))),
    }
}

/// How a submission was refused at admission, as reported by
/// [`EngineHandle::submit_classified`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded admission queue is at [`ServerConfig::queue_cap`]:
    /// backpressure shedding — retrying later may succeed.  The HTTP
    /// front-end surfaces this as `429 Too Many Requests`.
    QueueFull {
        /// The configured queue cap that was hit.
        cap: usize,
    },
    /// Admission-time validation failed: the request can never succeed
    /// as submitted (empty or oversized prompt, token budget past the
    /// KV window).  Carries the validation error text.
    Invalid(String),
}

/// A `Send + Sync` cancellation handle split off a [`Ticket`] via
/// [`Ticket::cancel_handle`].  It raises the same flag as
/// [`Ticket::cancel`] but can cross threads — the ticket itself is not
/// `Sync` (its terminal cache is a `RefCell`).  The HTTP front-end's
/// `POST /v1/cancel` route keeps one per in-flight stream.
#[derive(Debug, Clone)]
pub struct CancelHandle {
    flag: Arc<AtomicBool>,
}

impl CancelHandle {
    /// Request cancellation at the next round boundary (idempotent; a
    /// no-op if the request already retired).
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Relaxed);
    }
}

/// One submitted session: a live event stream plus cancellation and a
/// blocking join.
pub struct Ticket {
    id: RequestId,
    events: Receiver<TokenEvent>,
    cancel: Arc<AtomicBool>,
    /// Terminal result seen by [`Ticket::recv`]/[`Ticket::try_recv`],
    /// kept so a later [`Ticket::join`] returns the real result instead
    /// of synthesizing a phantom failure (the stream closes right after
    /// the terminal event, so without this cache a client that streamed
    /// to the end had already consumed the only copy).
    terminal: RefCell<Option<RequestResult>>,
}

impl Ticket {
    /// The engine-assigned request id (also on every event's result).
    pub fn id(&self) -> RequestId {
        self.id
    }

    /// Block for the next event; `None` once the stream is closed
    /// (after the terminal event, or if the engine died).
    pub fn recv(&self) -> Option<TokenEvent> {
        let ev = self.events.recv().ok()?;
        self.note_terminal(&ev);
        Some(ev)
    }

    /// Non-blocking poll of the event stream.
    pub fn try_recv(&self) -> Option<TokenEvent> {
        let ev = self.events.try_recv().ok()?;
        self.note_terminal(&ev);
        Some(ev)
    }

    /// The raw event receiver, for `select`-style integration or
    /// iteration (`for ev in ticket.events()`).  Events consumed
    /// through the raw receiver bypass the terminal cache that
    /// [`Ticket::join`] falls back on — prefer [`Ticket::recv`] when a
    /// later `join` must see the real result.
    pub fn events(&self) -> &Receiver<TokenEvent> {
        &self.events
    }

    /// Cache the terminal result as it passes through a receive call.
    fn note_terminal(&self, ev: &TokenEvent) {
        if let Some(res) = ev.result() {
            *self.terminal.borrow_mut() = Some(res.clone());
        }
    }

    /// Request cancellation: the serving lane retires the sequence at
    /// the next round boundary (admission or between decode rounds),
    /// freeing its KV slot immediately.  The ticket then receives a
    /// `Cancelled` terminal event carrying the tokens generated so far.
    /// Idempotent; a no-op if the request already retired.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Split off a thread-safe [`CancelHandle`] sharing this ticket's
    /// cancellation flag.
    pub fn cancel_handle(&self) -> CancelHandle {
        CancelHandle { flag: Arc::clone(&self.cancel) }
    }

    /// Block until the request leaves the engine and return its final
    /// result, draining any events not yet consumed.  If the terminal
    /// event was already taken off the stream by [`Ticket::recv`]/
    /// [`Ticket::try_recv`], the cached copy of that result is
    /// returned — stream-then-join sees the same result as join-only.
    /// Only when the engine died before retiring the request (its lane
    /// panicked, so the stream closed without a terminal event) is a
    /// synthesized `Failed` result returned.
    pub fn join(self) -> RequestResult {
        while let Ok(ev) = self.events.recv() {
            if let Some(res) = ev.result() {
                return res.clone();
            }
        }
        self.closed_result()
    }

    /// The result of a ticket whose stream has closed: the cached
    /// terminal result if a receive call saw one, else a synthesized
    /// `Failed` (the engine died before retiring the request).  Used by
    /// [`Ticket::join`] and by the HTTP layer when a stream ends
    /// without a terminal event.
    pub(crate) fn closed_result(&self) -> RequestResult {
        if let Some(res) = self.terminal.borrow_mut().take() {
            return res;
        }
        RequestResult {
            id: self.id,
            tokens: Vec::new(),
            finish: FinishReason::Failed,
            error: Some("ticket stream closed without a terminal event".into()),
            queue_s: 0.0,
            prefill_s: 0.0,
            decode_s: 0.0,
            total_s: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::LaneStats;

    fn completed(id: RequestId) -> RequestResult {
        RequestResult {
            id,
            tokens: vec![1, 2, 3],
            finish: FinishReason::Length,
            error: None,
            queue_s: 0.01,
            prefill_s: 0.1,
            decode_s: 0.4,
            total_s: 0.51,
        }
    }

    fn healthy_lane(lane: usize, clock_s: f64, ids: &[RequestId]) -> LaneOutcome {
        let mut stats = LaneStats::new(lane, 2);
        stats.requests = ids.len();
        stats.clock_s = clock_s;
        LaneOutcome {
            results: ids.iter().map(|&id| completed(id)).collect(),
            stats,
            sim_timed: true,
        }
    }

    #[test]
    fn merge_keeps_survivors_and_carries_lane_errors() {
        // Pre-fix, the first lane `Err` made the whole merge bail with
        // `?`, discarding the healthy lanes' results and stats.
        let outcomes: Vec<Result<LaneOutcome>> = vec![
            Ok(healthy_lane(0, 2.0, &[0, 2])),
            Err(crate::err!("lane thread panicked: injected")),
            Ok(healthy_lane(2, 1.5, &[1])),
        ];
        let report = merge_outcomes(outcomes, Vec::new(), Instant::now()).unwrap();
        assert_eq!(report.requests, 3);
        assert_eq!(report.completed, 3);
        assert_eq!(report.lanes.len(), 2, "only surviving lanes carry stats");
        assert!((report.wall_s - 2.0).abs() < 1e-12, "makespan = slowest survivor");
        assert_eq!(report.lane_errors, vec!["lane thread panicked: injected".to_string()]);
    }

    #[test]
    fn merge_with_no_survivors_reports_the_lane_errors() {
        let outcomes: Vec<Result<LaneOutcome>> =
            vec![Err(crate::err!("boom 0")), Err(crate::err!("boom 1"))];
        let err = merge_outcomes(outcomes, Vec::new(), Instant::now()).unwrap_err();
        let text = err.to_string();
        assert!(text.contains("boom 0") && text.contains("boom 1"), "got {text}");
    }

    #[test]
    fn merge_keeps_rejections_alongside_a_dead_lane() {
        // Submit-time rejections must stay observable even when a lane
        // died: they never reached a lane, so they cannot have been
        // lost with it.
        let mut rejected = completed(7);
        rejected.finish = FinishReason::Failed;
        rejected.tokens = Vec::new();
        rejected.total_s = 0.0;
        let outcomes: Vec<Result<LaneOutcome>> = vec![Err(crate::err!("gone"))];
        let report = merge_outcomes(outcomes, vec![rejected], Instant::now()).unwrap();
        assert_eq!(report.requests, 1);
        assert_eq!(report.failed, 1);
        assert_eq!(report.rejected, 1, "shed/rejection count mirrors the failed record");
        assert_eq!(report.lane_errors, vec!["gone".to_string()]);
    }

    #[test]
    fn panic_payload_text_is_extracted() {
        assert_eq!(panic_text(&"static str"), "static str");
        assert_eq!(panic_text(&String::from("owned")), "owned");
        assert_eq!(panic_text(&42u32), "non-string panic payload");
    }
}
