//! KV-cache slot pool: fixed-capacity allocator of per-sequence cache
//! slots.  Invariants (enforced here, property-tested in
//! `rust/tests/proptests.rs`):
//!
//! * a slot is never handed to two live sequences,
//! * free/allocate round-trips restore capacity,
//! * double-free and foreign-slot free are rejected.
//!
//! A retiring sequence's `release` immediately re-arms `allocate` for
//! the lane's next pull from the admission queue — the slot recycle is
//! what triggers a mid-flight join under continuous batching, and it
//! never touches the slots of sequences still decoding.

use std::collections::BTreeSet;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct SlotId(pub usize);

#[derive(Debug)]
pub struct KvSlotPool {
    capacity: usize,
    free: Vec<SlotId>,
    live: BTreeSet<usize>,
}

impl KvSlotPool {
    pub fn new(capacity: usize) -> KvSlotPool {
        KvSlotPool {
            capacity,
            free: (0..capacity).rev().map(SlotId).collect(),
            live: BTreeSet::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// Allocate a slot, or None if the pool is exhausted.
    pub fn allocate(&mut self) -> Option<SlotId> {
        let slot = self.free.pop()?;
        let fresh = self.live.insert(slot.0);
        debug_assert!(fresh, "slot {slot:?} was already live");
        Some(slot)
    }

    /// Release a slot back to the pool.
    pub fn release(&mut self, slot: SlotId) -> crate::util::error::Result<()> {
        crate::ensure!(slot.0 < self.capacity, "foreign slot {slot:?}");
        crate::ensure!(self.live.remove(&slot.0), "double free of {slot:?}");
        self.free.push(slot);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exhausts_and_recovers() {
        let mut p = KvSlotPool::new(2);
        let a = p.allocate().unwrap();
        let b = p.allocate().unwrap();
        assert_ne!(a, b);
        assert!(p.allocate().is_none());
        p.release(a).unwrap();
        assert_eq!(p.available(), 1);
        let c = p.allocate().unwrap();
        assert_eq!(c, a); // LIFO reuse
    }

    #[test]
    fn midflight_recycle_never_touches_live_slots() {
        let mut p = KvSlotPool::new(3);
        let a = p.allocate().unwrap();
        let b = p.allocate().unwrap();
        let c = p.allocate().unwrap();
        // One sequence retires mid-flight; the joiner that replaces it
        // gets exactly the recycled slot while the others stay live.
        p.release(b).unwrap();
        assert_eq!(p.available(), 1);
        assert_eq!(p.live_count(), 2);
        let joiner = p.allocate().unwrap();
        assert_eq!(joiner, b);
        assert_ne!(joiner, a);
        assert_ne!(joiner, c);
        assert_eq!(p.live_count(), 3);
    }

    #[test]
    fn double_free_rejected() {
        let mut p = KvSlotPool::new(1);
        let a = p.allocate().unwrap();
        p.release(a).unwrap();
        assert!(p.release(a).is_err());
    }

    #[test]
    fn foreign_slot_rejected() {
        let mut p = KvSlotPool::new(1);
        assert!(p.release(SlotId(7)).is_err());
    }
}
