//! Request/response types of the serving API: per-request generation
//! parameters ([`GenParams`]), the submission payload
//! ([`GenerationRequest`]), the per-token event stream
//! ([`TokenEvent`]), and the completed-request record
//! ([`RequestResult`]).
//!
//! [`Request`] is the *internal* envelope the admission queue carries
//! to the lanes: a [`GenerationRequest`] plus the engine-assigned id,
//! arrival timestamp, the ticket's event sender, and the shared
//! cancellation flag (the scheduler stamps queue-wait and steal
//! provenance at pull time).  Legacy callers build it directly with [`Request::new`]
//! (defaulted params, no event stream) — the pre-Engine batch surface.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

pub type RequestId = u64;

/// Per-request generation parameters carried by every submission
/// (DESIGN.md §3 "per-request control").
#[derive(Debug, Clone)]
pub struct GenParams {
    /// Token budget: generation retires after this many tokens
    /// (prefill token included) unless a stop token, cancellation, or
    /// the KV window ends it first.
    pub max_new_tokens: usize,
    /// Stop-token set: generation retires as soon as any of these is
    /// emitted (the stop token itself is kept in the output).
    pub stop_tokens: Vec<i32>,
    /// Optional wall-clock deadline: the serving lane cancels the
    /// request at the first admission or decode-round boundary past
    /// this instant.
    pub deadline: Option<Instant>,
}

impl GenParams {
    pub fn new(max_new_tokens: usize) -> GenParams {
        GenParams { max_new_tokens, stop_tokens: Vec::new(), deadline: None }
    }

    pub fn with_stop_tokens(mut self, stop: Vec<i32>) -> GenParams {
        self.stop_tokens = stop;
        self
    }

    pub fn with_deadline(mut self, deadline: Instant) -> GenParams {
        self.deadline = Some(deadline);
        self
    }
}

/// What a client submits to [`crate::coordinator::EngineHandle::submit`]:
/// a prompt plus its generation parameters.
#[derive(Debug, Clone)]
pub struct GenerationRequest {
    pub prompt: Vec<i32>,
    pub params: GenParams,
}

impl GenerationRequest {
    pub fn new(prompt: Vec<i32>, max_new_tokens: usize) -> GenerationRequest {
        GenerationRequest { prompt, params: GenParams::new(max_new_tokens) }
    }

    pub fn with_params(prompt: Vec<i32>, params: GenParams) -> GenerationRequest {
        GenerationRequest { prompt, params }
    }
}

/// Why a request left the engine (carried on [`RequestResult`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit its `max_new_tokens` budget or the backend's KV window.
    Length,
    /// Emitted a token from its stop-token set.
    Stop,
    /// Cancelled through [`crate::coordinator::Ticket::cancel`].
    Cancelled,
    /// Its per-request deadline expired at a round boundary.
    DeadlineExpired,
    /// Rejected at admission or failed in the backend (see
    /// [`RequestResult::error`]).
    Failed,
}

impl FinishReason {
    /// Stable lower-case label (used by the JSONL metrics exporter).
    pub fn label(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::Cancelled => "cancelled",
            FinishReason::DeadlineExpired => "deadline",
            FinishReason::Failed => "failed",
        }
    }

    /// Did the request complete normally (budget or stop token)?
    pub fn is_success(&self) -> bool {
        matches!(self, FinishReason::Length | FinishReason::Stop)
    }
}

/// One event on a ticket's stream.  Ordering guarantee per request
/// (DESIGN.md §3): zero or one `Prefilled`, then zero or more `Token`s
/// with strictly increasing `index`, then exactly one terminal event
/// (`Retired`, `Cancelled`, or `Failed`), after which the stream
/// closes.  The tokens carried by `Prefilled` + `Token` events, in
/// order, equal the terminal result's `tokens`.
#[derive(Debug, Clone)]
pub enum TokenEvent {
    /// Prefill completed; `token` is the first generated token
    /// (index 0 of the output).
    Prefilled { token: i32 },
    /// One decode step landed; `index` counts from 1 (0 is the
    /// prefill token).
    Token { token: i32, index: usize },
    /// Terminal: the request completed (budget, KV window, or stop
    /// token — see [`RequestResult::finish`]).
    Retired(RequestResult),
    /// Terminal: cancelled by the client or by deadline expiry; the
    /// result carries any tokens generated before the cancellation.
    Cancelled(RequestResult),
    /// Terminal: rejected at admission or failed in the backend.
    Failed(RequestResult),
}

impl TokenEvent {
    /// The terminal result, if this is a terminal event.
    pub fn result(&self) -> Option<&RequestResult> {
        match self {
            TokenEvent::Retired(r) | TokenEvent::Cancelled(r) | TokenEvent::Failed(r) => Some(r),
            _ => None,
        }
    }

    /// The token this event carries, if it is a token event.
    pub fn token(&self) -> Option<i32> {
        match self {
            TokenEvent::Prefilled { token } | TokenEvent::Token { token, .. } => Some(*token),
            _ => None,
        }
    }
}

/// One inference request as the lanes see it: the client payload plus
/// the engine-side plumbing (id, arrival clock, event sender,
/// cancellation flag).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub params: GenParams,
    pub arrival: Instant,
    /// The ticket's event stream; `None` for legacy batch submissions.
    pub(crate) events: Option<Sender<TokenEvent>>,
    /// Shared with the ticket: set means "cancel at the next round
    /// boundary".  `None` for legacy batch submissions (never
    /// cancellable).
    pub(crate) cancel: Option<Arc<AtomicBool>>,
    /// Stamped by the scheduler at pull time: seconds spent on the
    /// admission queue before a lane took the request.  `None` until
    /// pulled.
    pub(crate) queue_wait_s: Option<f64>,
    /// Stamped by the scheduler: the request was stolen off another
    /// lane's deque rather than pulled from the thief's own assignment
    /// or the shared injector.
    pub(crate) stolen: bool,
}

impl Request {
    /// Legacy batch constructor: defaulted params, no event stream, not
    /// cancellable.  The pre-Engine serving surface
    /// ([`crate::coordinator::serve_all`] and friends) is built on this.
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(max_new_tokens >= 1);
        Request {
            id,
            prompt,
            params: GenParams::new(max_new_tokens),
            arrival: Instant::now(),
            events: None,
            cancel: None,
            queue_wait_s: None,
            stolen: false,
        }
    }

    /// Full constructor used by the engine's submit path.
    pub(crate) fn with_plumbing(
        id: RequestId,
        req: GenerationRequest,
        events: Sender<TokenEvent>,
        cancel: Arc<AtomicBool>,
    ) -> Request {
        Request {
            id,
            prompt: req.prompt,
            params: req.params,
            arrival: Instant::now(),
            events: Some(events),
            cancel: Some(cancel),
            queue_wait_s: None,
            stolen: false,
        }
    }

    /// Token budget (sugar over `params`; the field the legacy surface
    /// exposed directly).
    pub fn max_new_tokens(&self) -> usize {
        self.params.max_new_tokens
    }

    /// Has the ticket's cancel flag been raised?
    pub(crate) fn cancel_requested(&self) -> bool {
        self.cancel.as_ref().is_some_and(|c| c.load(Ordering::Relaxed))
    }

    /// Has the per-request deadline passed?
    pub(crate) fn deadline_expired(&self) -> bool {
        self.params.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Best-effort event emission (a dropped ticket must never stall a
    /// lane).
    pub(crate) fn emit(&self, ev: TokenEvent) {
        if let Some(tx) = &self.events {
            let _ = tx.send(ev);
        }
    }
}

/// Completed request with per-phase latency accounting.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    /// Why the request left the engine.
    pub finish: FinishReason,
    /// Backend/admission error text when `finish` is
    /// [`FinishReason::Failed`].
    pub error: Option<String>,
    /// Queue wait before prefill started.
    pub queue_s: f64,
    /// Prefill execution time.
    pub prefill_s: f64,
    /// Total decode time (all tokens after the first).
    pub decode_s: f64,
    /// End-to-end latency (arrival → completion).
    pub total_s: f64,
}

impl RequestResult {
    /// Steady-state decode throughput for this request.
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.tokens.len() <= 1 || self.decode_s <= 0.0 {
            return 0.0;
        }
        (self.tokens.len() - 1) as f64 / self.decode_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn result_throughput() {
        let r = RequestResult {
            id: 1,
            tokens: vec![1, 2, 3, 4, 5],
            finish: FinishReason::Length,
            error: None,
            queue_s: 0.0,
            prefill_s: 0.1,
            decode_s: 2.0,
            total_s: 2.1,
        };
        assert!((r.decode_tokens_per_s() - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_prompt_rejected() {
        Request::new(1, vec![], 4);
    }

    #[test]
    fn legacy_request_is_never_cancelled() {
        let r = Request::new(1, vec![1], 4);
        assert!(!r.cancel_requested());
        assert!(!r.deadline_expired());
        assert_eq!(r.max_new_tokens(), 4);
        r.emit(TokenEvent::Prefilled { token: 1 }); // no stream: a no-op
    }

    #[test]
    fn deadline_in_the_past_reads_expired() {
        let mut r = Request::new(1, vec![1], 4);
        r.params.deadline = Some(Instant::now());
        assert!(r.deadline_expired());
    }

    #[test]
    fn event_accessors() {
        let res = RequestResult {
            id: 0,
            tokens: vec![7],
            finish: FinishReason::Stop,
            error: None,
            queue_s: 0.0,
            prefill_s: 0.0,
            decode_s: 0.0,
            total_s: 0.0,
        };
        assert_eq!(TokenEvent::Prefilled { token: 7 }.token(), Some(7));
        assert_eq!(TokenEvent::Token { token: 9, index: 1 }.token(), Some(9));
        assert!(TokenEvent::Retired(res.clone()).result().is_some());
        assert!(TokenEvent::Retired(res).token().is_none());
        assert!(FinishReason::Stop.is_success());
        assert!(!FinishReason::Cancelled.is_success());
        assert_eq!(FinishReason::DeadlineExpired.label(), "deadline");
    }
}
