//! Request/response types of the serving API.

use std::time::Instant;

pub type RequestId = u64;

/// One inference request: a prompt and a generation budget.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(max_new_tokens >= 1);
        Request { id, prompt, max_new_tokens, arrival: Instant::now() }
    }
}

/// Completed request with per-phase latency accounting.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    /// Queue wait before prefill started.
    pub queue_s: f64,
    /// Prefill execution time.
    pub prefill_s: f64,
    /// Total decode time (all tokens after the first).
    pub decode_s: f64,
    /// End-to-end latency (arrival → completion).
    pub total_s: f64,
}

impl RequestResult {
    /// Steady-state decode throughput for this request.
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.tokens.len() <= 1 || self.decode_s <= 0.0 {
            return 0.0;
        }
        (self.tokens.len() - 1) as f64 / self.decode_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn result_throughput() {
        let r = RequestResult {
            id: 1,
            tokens: vec![1, 2, 3, 4, 5],
            queue_s: 0.0,
            prefill_s: 0.1,
            decode_s: 2.0,
            total_s: 2.1,
        };
        assert!((r.decode_tokens_per_s() - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_prompt_rejected() {
        Request::new(1, vec![], 4);
    }
}
