//! Serving metrics: latency percentiles and throughput aggregation.

use crate::util::stats::{geomean, max, mean, percentile};

use super::request::RequestResult;

/// Latency summary over a set of samples (seconds).
#[derive(Debug, Clone)]
pub struct LatencyStats {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl LatencyStats {
    pub fn from(samples: &[f64]) -> Option<LatencyStats> {
        if samples.is_empty() {
            return None;
        }
        Some(LatencyStats {
            mean: mean(samples),
            p50: percentile(samples, 50.0),
            p95: percentile(samples, 95.0),
            max: max(samples),
        })
    }

    pub fn fmt_ms(&self) -> String {
        format!(
            "mean {:.1} ms  p50 {:.1} ms  p95 {:.1} ms  max {:.1} ms",
            self.mean * 1e3,
            self.p50 * 1e3,
            self.p95 * 1e3,
            self.max * 1e3
        )
    }
}

/// Aggregate report over a completed serve run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    pub total_tokens: usize,
    pub wall_s: f64,
    pub prefill: LatencyStats,
    pub e2e: LatencyStats,
    pub queue: LatencyStats,
    /// Aggregate decode throughput (generated tokens / wall time).
    pub tokens_per_s: f64,
    /// Geomean of per-request decode throughputs.
    pub per_request_tps_geomean: f64,
}

impl ServeReport {
    pub fn from(results: &[RequestResult], wall_s: f64) -> Option<ServeReport> {
        if results.is_empty() {
            return None;
        }
        let total_tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
        let prefill: Vec<f64> = results.iter().map(|r| r.prefill_s).collect();
        let e2e: Vec<f64> = results.iter().map(|r| r.total_s).collect();
        let queue: Vec<f64> = results.iter().map(|r| r.queue_s).collect();
        let tps: Vec<f64> = results
            .iter()
            .map(|r| r.decode_tokens_per_s())
            .filter(|&t| t > 0.0)
            .collect();
        Some(ServeReport {
            requests: results.len(),
            total_tokens,
            wall_s,
            prefill: LatencyStats::from(&prefill)?,
            e2e: LatencyStats::from(&e2e)?,
            queue: LatencyStats::from(&queue)?,
            tokens_per_s: total_tokens as f64 / wall_s,
            per_request_tps_geomean: if tps.is_empty() { 0.0 } else { geomean(&tps) },
        })
    }

    pub fn print(&self) {
        println!("requests        : {}", self.requests);
        println!("generated tokens: {}", self.total_tokens);
        println!("wall time       : {:.2} s", self.wall_s);
        println!("throughput      : {:.1} tok/s aggregate", self.tokens_per_s);
        println!(
            "per-req decode  : {:.1} tok/s (geomean)",
            self.per_request_tps_geomean
        );
        println!("queue   latency : {}", self.queue.fmt_ms());
        println!("prefill latency : {}", self.prefill.fmt_ms());
        println!("e2e     latency : {}", self.e2e.fmt_ms());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(prefill: f64, decode: f64, n: usize) -> RequestResult {
        RequestResult {
            id: 0,
            tokens: vec![1; n],
            queue_s: 0.01,
            prefill_s: prefill,
            decode_s: decode,
            total_s: prefill + decode + 0.01,
        }
    }

    #[test]
    fn aggregates() {
        let rs = vec![result(0.1, 1.0, 11), result(0.2, 2.0, 21)];
        let rep = ServeReport::from(&rs, 4.0).unwrap();
        assert_eq!(rep.requests, 2);
        assert_eq!(rep.total_tokens, 32);
        assert!((rep.tokens_per_s - 8.0).abs() < 1e-12);
        assert!((rep.per_request_tps_geomean - 10.0).abs() < 1e-9);
        assert!((rep.prefill.p50 - 0.15).abs() < 1e-12);
    }

    #[test]
    fn empty_is_none() {
        assert!(ServeReport::from(&[], 1.0).is_none());
        assert!(LatencyStats::from(&[]).is_none());
    }
}
