//! Serving metrics: latency percentiles, throughput aggregation,
//! per-lane breakdowns of the continuous-batching engine (including
//! work-steal and mid-flight-join counts), and the streaming
//! request-record channel a scrape endpoint can sit on.

use crate::util::stats::{geomean, max, mean, percentile};

use super::request::{FinishReason, RequestId, RequestResult};

/// Latency summary over a set of samples (seconds).
#[derive(Debug, Clone)]
pub struct LatencyStats {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl LatencyStats {
    pub fn from(samples: &[f64]) -> Option<LatencyStats> {
        if samples.is_empty() {
            return None;
        }
        Some(LatencyStats {
            mean: mean(samples),
            p50: percentile(samples, 50.0),
            p95: percentile(samples, 95.0),
            max: max(samples),
        })
    }

    pub fn fmt_ms(&self) -> String {
        format!(
            "mean {:.1} ms  p50 {:.1} ms  p95 {:.1} ms  max {:.1} ms",
            self.mean * 1e3,
            self.p50 * 1e3,
            self.p95 * 1e3,
            self.max * 1e3
        )
    }
}

/// One retired request's record, streamed over the server's optional
/// metrics sink while the run is still in flight (the channel half of
/// the request-level metrics endpoint: a scrape/export loop sits on the
/// receiving end).
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: RequestId,
    /// Worker lane that served the request; `None` for submissions
    /// rejected at admission, which never reached a lane.  Legacy
    /// alias of `executed_lane`, kept for exporter-schema stability.
    pub lane: Option<usize>,
    /// Lane the scheduler's pull landed the request on (it executes
    /// there to completion — sequences never migrate mid-generation);
    /// `None` for admission rejections/sheds, which never executed.
    pub executed_lane: Option<usize>,
    pub queue_s: f64,
    /// Seconds between arrival and the scheduler pull that assigned
    /// the request its executing lane (the admission-queue wait the
    /// `tsar_queue_wait_seconds` histogram observes).
    pub queue_wait_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub total_s: f64,
    /// Generated tokens (prefill token included).
    pub tokens: usize,
    /// How the request left the engine (completed / cancelled /
    /// failed).
    pub finish: FinishReason,
    /// The executing lane stole this request off a sibling's deque.
    pub stolen: bool,
    /// The request was admitted into a batch that had already run
    /// decode rounds (a continuous-batching mid-flight join).
    pub joined_midflight: bool,
    /// The backend's chosen §III-D kernel plan, `None` for backends
    /// that don't model one (PJRT).
    pub plan: Option<String>,
}

/// One worker lane's accounting over a completed run.
#[derive(Debug, Clone)]
pub struct LaneStats {
    pub lane: usize,
    /// Requests retired on this lane.
    pub requests: usize,
    /// Batched decode rounds executed.
    pub rounds: usize,
    /// Lane-local clock: *busy* seconds only (simulated for modeled
    /// backends, measured wall time for real ones); a lane that never
    /// received work reads zero.
    pub clock_s: f64,
    /// `width_hist[w]` counts decode rounds that stepped exactly `w`
    /// sequences (index 0 unused).
    pub width_hist: Vec<usize>,
    /// Requests this lane stole off a sibling's deque (work stealing).
    pub steals: usize,
    /// Admissions that joined a batch already running decode rounds
    /// (continuous-batching mid-flight joins).
    pub joins: usize,
    /// `clock_s` / merged wall time — filled in by the clock merge at
    /// report time.
    pub utilization: f64,
}

impl LaneStats {
    pub fn new(lane: usize, max_width: usize) -> LaneStats {
        LaneStats {
            lane,
            requests: 0,
            rounds: 0,
            clock_s: 0.0,
            width_hist: vec![0; max_width + 1],
            steals: 0,
            joins: 0,
            utilization: 0.0,
        }
    }

    /// Account one decode round of `width` sequences.
    pub fn record_round(&mut self, width: usize) {
        self.rounds += 1;
        if width < self.width_hist.len() {
            self.width_hist[width] += 1;
        } else if let Some(last) = self.width_hist.last_mut() {
            *last += 1;
        }
    }

    /// Mean batched-round width (0 when the lane never decoded).
    pub fn mean_width(&self) -> f64 {
        let steps: usize = self
            .width_hist
            .iter()
            .enumerate()
            .map(|(w, &c)| w * c)
            .sum();
        if self.rounds == 0 {
            0.0
        } else {
            steps as f64 / self.rounds as f64
        }
    }

    fn fmt_hist(&self) -> String {
        self.width_hist
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(w, &c)| format!("{w}x{c}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Aggregate report over a completed serve run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    /// Requests that completed normally (token budget, KV window, or a
    /// stop token).
    pub completed: usize,
    /// Requests cancelled by the client or by deadline expiry.
    pub cancelled: usize,
    /// Requests rejected at admission or failed in the backend.
    pub failed: usize,
    /// The subset of `failed` shed at submit time (admission-queue
    /// overflow or validation) — they never executed on a lane.  The
    /// same count `tsar_rejections_total` reports, so the shutdown
    /// report and the Prometheus surface always agree.
    pub rejected: usize,
    /// Σ per-lane work-stealing pulls over the run.
    pub steals: usize,
    /// Σ per-lane continuous-batching mid-flight joins over the run.
    pub midflight_joins: usize,
    pub total_tokens: usize,
    /// Merged timeline: max over the lanes' virtual clocks (the lanes
    /// run concurrently, so the simulated makespan is the slowest
    /// lane), or real elapsed time for non-modeled backends.
    pub wall_s: f64,
    pub prefill: LatencyStats,
    pub e2e: LatencyStats,
    pub queue: LatencyStats,
    /// Aggregate decode throughput (generated tokens / wall time).
    pub tokens_per_s: f64,
    /// Geomean of per-request decode throughputs.
    pub per_request_tps_geomean: f64,
    /// Per-lane breakdowns, ordered by lane id (empty for reports built
    /// without lane accounting).
    pub lanes: Vec<LaneStats>,
    /// Σ lane clocks: aggregate busy time across the lanes (≥ `wall_s`
    /// whenever more than one lane did work).
    pub lane_clock_sum_s: f64,
    /// Errors of lanes that did not survive the run (a panicked or
    /// failed lane thread).  Results served by those lanes before they
    /// died are lost with them; the surviving lanes' results are merged
    /// as usual.  Empty on a healthy run.
    pub lane_errors: Vec<String>,
}

impl ServeReport {
    pub fn from(results: &[RequestResult], wall_s: f64) -> Option<ServeReport> {
        ServeReport::from_lanes(results, wall_s, Vec::new())
    }

    /// Build the report and run the clock merge: lane utilizations are
    /// normalized against the merged `wall_s` timeline.
    pub fn from_lanes(
        results: &[RequestResult],
        wall_s: f64,
        mut lanes: Vec<LaneStats>,
    ) -> Option<ServeReport> {
        if results.is_empty() {
            return None;
        }
        let total_tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
        let completed = results.iter().filter(|r| r.finish.is_success()).count();
        let cancelled = results
            .iter()
            .filter(|r| {
                matches!(
                    r.finish,
                    FinishReason::Cancelled | FinishReason::DeadlineExpired
                )
            })
            .count();
        let failed = results
            .iter()
            .filter(|r| r.finish == FinishReason::Failed)
            .count();
        // Prefill latency is only meaningful for requests whose prefill
        // actually ran (cancelled-at-admission and failed requests
        // report zero and would skew the percentiles).
        let prefill: Vec<f64> = results
            .iter()
            .filter(|r| !r.tokens.is_empty())
            .map(|r| r.prefill_s)
            .collect();
        let prefill = if prefill.is_empty() {
            vec![0.0]
        } else {
            prefill
        };
        // Likewise e2e/queue: submit-time rejections never enter a
        // lane and carry exactly-zero timings (every request that did
        // enter has total_s > 0 — real queue wait and/or virtual
        // residency); including their zeros would drag the percentiles
        // toward 0 ms.
        let in_lane: Vec<&RequestResult> =
            results.iter().filter(|r| r.total_s > 0.0).collect();
        let e2e: Vec<f64> = if in_lane.is_empty() {
            vec![0.0]
        } else {
            in_lane.iter().map(|r| r.total_s).collect()
        };
        let queue: Vec<f64> = if in_lane.is_empty() {
            vec![0.0]
        } else {
            in_lane.iter().map(|r| r.queue_s).collect()
        };
        let tps: Vec<f64> = results
            .iter()
            .map(|r| r.decode_tokens_per_s())
            .filter(|&t| t > 0.0)
            .collect();
        lanes.sort_by_key(|l| l.lane);
        let lane_clock_sum_s: f64 = lanes.iter().map(|l| l.clock_s).sum();
        let steals: usize = lanes.iter().map(|l| l.steals).sum();
        let midflight_joins: usize = lanes.iter().map(|l| l.joins).sum();
        for l in &mut lanes {
            l.utilization = if wall_s > 0.0 { l.clock_s / wall_s } else { 0.0 };
        }
        Some(ServeReport {
            requests: results.len(),
            completed,
            cancelled,
            failed,
            // Rejections carry no lane; the engine's merge overwrites
            // this with its authoritative shed count.
            rejected: 0,
            steals,
            midflight_joins,
            total_tokens,
            wall_s,
            prefill: LatencyStats::from(&prefill)?,
            e2e: LatencyStats::from(&e2e)?,
            queue: LatencyStats::from(&queue)?,
            tokens_per_s: total_tokens as f64 / wall_s,
            per_request_tps_geomean: if tps.is_empty() { 0.0 } else { geomean(&tps) },
            lanes,
            lane_clock_sum_s,
            lane_errors: Vec::new(),
        })
    }

    pub fn print(&self) {
        println!("requests        : {}", self.requests);
        if self.cancelled > 0 || self.failed > 0 {
            println!(
                "outcomes        : {} completed  {} cancelled  {} failed",
                self.completed, self.cancelled, self.failed
            );
        }
        if !self.lane_errors.is_empty() {
            println!(
                "lane errors     : {} (results on those lanes were lost)",
                self.lane_errors.len()
            );
            for e in &self.lane_errors {
                println!("  ! {e}");
            }
        }
        if self.steals > 0 || self.midflight_joins > 0 || self.rejected > 0 {
            println!(
                "scheduler       : {} steals  {} mid-flight joins  {} shed at admission",
                self.steals, self.midflight_joins, self.rejected
            );
        }
        println!("generated tokens: {}", self.total_tokens);
        println!("wall time       : {:.2} s", self.wall_s);
        println!("throughput      : {:.1} tok/s aggregate", self.tokens_per_s);
        println!(
            "per-req decode  : {:.1} tok/s (geomean)",
            self.per_request_tps_geomean
        );
        println!("queue   latency : {}", self.queue.fmt_ms());
        println!("prefill latency : {}", self.prefill.fmt_ms());
        println!("e2e     latency : {}", self.e2e.fmt_ms());
        if !self.lanes.is_empty() {
            println!(
                "lane busy sum   : {:.2} s across {} lane(s)",
                self.lane_clock_sum_s,
                self.lanes.len()
            );
            for l in &self.lanes {
                println!(
                    "  lane {:>2}: {:>3} req  {:>5} rounds  busy {:>8.3} s  \
                     util {:>5.1}%  mean width {:.2}  [{}]",
                    l.lane,
                    l.requests,
                    l.rounds,
                    l.clock_s,
                    l.utilization * 100.0,
                    l.mean_width(),
                    l.fmt_hist()
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(prefill: f64, decode: f64, n: usize) -> RequestResult {
        RequestResult {
            id: 0,
            tokens: vec![1; n],
            finish: FinishReason::Length,
            error: None,
            queue_s: 0.01,
            prefill_s: prefill,
            decode_s: decode,
            total_s: prefill + decode + 0.01,
        }
    }

    #[test]
    fn aggregates() {
        let rs = vec![result(0.1, 1.0, 11), result(0.2, 2.0, 21)];
        let rep = ServeReport::from(&rs, 4.0).unwrap();
        assert_eq!(rep.requests, 2);
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.cancelled + rep.failed, 0);
        assert_eq!(rep.total_tokens, 32);
        assert!((rep.tokens_per_s - 8.0).abs() < 1e-12);
        assert!((rep.per_request_tps_geomean - 10.0).abs() < 1e-9);
        assert!((rep.prefill.p50 - 0.15).abs() < 1e-12);
        assert!(rep.lanes.is_empty());
    }

    #[test]
    fn outcome_counts_and_prefill_filtering() {
        let mut cancelled = result(0.0, 0.0, 0);
        cancelled.finish = FinishReason::Cancelled;
        let mut failed = result(0.0, 0.0, 0);
        failed.finish = FinishReason::Failed;
        let mut stopped = result(0.3, 0.5, 4);
        stopped.finish = FinishReason::Stop;
        let rep = ServeReport::from(&[cancelled, failed, stopped], 1.0).unwrap();
        assert_eq!((rep.completed, rep.cancelled, rep.failed), (1, 1, 1));
        // Zero-token (never-prefilled) results must not skew prefill
        // latency percentiles.
        assert!((rep.prefill.mean - 0.3).abs() < 1e-12);
    }

    #[test]
    fn empty_is_none() {
        assert!(ServeReport::from(&[], 1.0).is_none());
        assert!(LatencyStats::from(&[]).is_none());
    }

    #[test]
    fn lane_merge_normalizes_utilization() {
        let rs = vec![result(0.1, 1.0, 4), result(0.1, 1.0, 4)];
        let mut a = LaneStats::new(1, 4);
        a.clock_s = 2.0;
        a.record_round(3);
        a.record_round(3);
        a.record_round(1);
        let mut b = LaneStats::new(0, 4);
        b.clock_s = 4.0;
        // Merged wall = slowest lane; utilizations come out of the
        // merge, and the busy sum exceeds the merged timeline.
        let rep = ServeReport::from_lanes(&rs, 4.0, vec![a, b]).unwrap();
        assert_eq!(rep.lanes[0].lane, 0, "lanes ordered by id");
        assert!((rep.lane_clock_sum_s - 6.0).abs() < 1e-12);
        assert!((rep.lanes[1].utilization - 0.5).abs() < 1e-12);
        assert!((rep.lanes[0].utilization - 1.0).abs() < 1e-12);
        assert!((rep.lanes[1].mean_width() - 7.0 / 3.0).abs() < 1e-12);
        assert_eq!(rep.lanes[1].width_hist[3], 2);
    }

    #[test]
    fn scheduler_counters_aggregate_across_lanes() {
        let rs = vec![result(0.1, 1.0, 4)];
        let mut a = LaneStats::new(0, 2);
        a.steals = 2;
        a.joins = 1;
        let mut b = LaneStats::new(1, 2);
        b.steals = 1;
        let rep = ServeReport::from_lanes(&rs, 1.0, vec![a, b]).unwrap();
        assert_eq!(rep.steals, 3);
        assert_eq!(rep.midflight_joins, 1);
        assert_eq!(rep.rejected, 0, "from_lanes never counts sheds on its own");
    }

    #[test]
    fn width_histogram_clamps_oversized_rounds() {
        let mut l = LaneStats::new(0, 2);
        l.record_round(5); // wider than declared max: clamp to the top bin
        assert_eq!(l.width_hist, vec![0, 0, 1]);
    }
}
