//! Layer-3 serving coordinator.
//!
//! The paper's system contribution is the kernel/ISA layer, so the
//! coordinator is the serving harness a deployment wraps around it
//! (DESIGN.md §3): a session-based streaming **engine**
//! ([`Engine::start`] → [`EngineHandle::submit`] → [`Ticket`]) whose
//! worker lanes *pull* sequences from a shared admission queue between
//! decode rounds (continuous batching with cross-lane work stealing —
//! see the `scheduler` module), each lane a continuous
//! batcher + KV-slot pool driving *batched* decode rounds against any
//! [`crate::runtime::Backend`] (the simulator-costed `SimBackend` by
//! default, PJRT behind the `pjrt` feature), and the paper's §III-D
//! *adaptive kernel selector* that picks the AP/OP dataflow per layer
//! at compile (model-load) time.  Requests carry per-request
//! generation parameters ([`GenParams`]: token budget, stop tokens,
//! deadline), tickets stream [`TokenEvent`]s as tokens land and can
//! cancel mid-generation, and the blocking [`Server`] surface remains
//! as a thin compatibility wrapper.  The network surface is the
//! zero-dependency HTTP front-end ([`http::HttpServer`]: streaming
//! `POST /v1/generate`, Prometheus `GET /metrics` backed by
//! [`prom::PromAggregator`], `GET /healthz`), wired as
//! `tsar-cli serve --http <addr>`.
//!
//! Threading: std::thread + mpsc channels (tokio is not in the offline
//! crate cache).  The dispatcher runs on the calling thread; each lane
//! is a scoped worker thread sharing the backend by reference (all
//! [`crate::runtime::Backend`] methods take `&self`).  Lanes keep
//! per-lane virtual clocks that the server merges at retire into one
//! global simulated timeline — the same topology a tokio implementation
//! would have, with the async reactor replaced by blocking queues.

pub mod batcher;
pub mod engine;
pub mod export;
pub mod http;
pub mod kvpool;
mod lane;
pub mod metrics;
pub mod prom;
pub mod request;
mod scheduler;
pub mod selector;
pub mod serve;

pub use batcher::Batcher;
pub use engine::{CancelHandle, Engine, EngineHandle, SubmitError, Ticket};
pub use export::{tee_records, Exporter};
pub use http::{HttpConfig, HttpServer};
pub use kvpool::KvSlotPool;
pub use metrics::{LaneStats, LatencyStats, RequestRecord, ServeReport};
pub use prom::{PromAggregator, PromCounters};
pub use request::{
    FinishReason, GenParams, GenerationRequest, Request, RequestId, RequestResult, TokenEvent,
};
pub use selector::{describe_site_shapes, select_plan, LayerPlan, ModelPlan};
pub use serve::{serve_all, Server, ServerConfig};
