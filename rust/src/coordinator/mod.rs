//! Layer-3 serving coordinator.
//!
//! The paper's system contribution is the kernel/ISA layer, so the
//! coordinator is the serving harness a deployment wraps around it
//! (DESIGN.md §3): a request queue feeding a continuous batcher, a
//! prefill/decode scheduler driving any [`crate::runtime::Backend`]
//! (the simulator-costed `SimBackend` by default, PJRT behind the
//! `pjrt` feature), a KV-slot pool, and the paper's §III-D *adaptive
//! kernel selector* that picks the AP/OP dataflow per layer at compile
//! (model-load) time.
//!
//! Threading: std::thread + mpsc channels (tokio is not in the offline
//! crate cache).  One engine thread owns the backend; client threads
//! submit requests and await results over channels — the same topology
//! a tokio implementation would have, with the async reactor replaced
//! by blocking queues.

pub mod batcher;
pub mod kvpool;
pub mod metrics;
pub mod request;
pub mod selector;
pub mod serve;

pub use batcher::Batcher;
pub use kvpool::KvSlotPool;
pub use metrics::{LatencyStats, ServeReport};
pub use request::{Request, RequestId, RequestResult};
pub use selector::{select_plan, LayerPlan, ModelPlan};
pub use serve::{Server, ServerConfig};
