//! The blocking batch surface over the streaming engine
//! ([`super::Engine`], DESIGN.md §3): `Server` owns a backend and a
//! validated config, and its `run`/`run_preloaded`/[`serve_all`] calls
//! are thin compatibility wrappers that start an engine, feed it, and
//! block until the merged [`ServeReport`] is ready.
//!
//! Topology per run (all inside the engine):
//!
//!   1. requests land on the engine's **shared admission queue**
//!      (preloaded runs pre-assign round-robin onto per-lane steal
//!      deques for determinism),
//!   2. each **lane** (background thread) runs pull → join → prefill →
//!      batched decode rounds → retire (the `lane` module), pulling
//!      from the queue between rounds — joining sequences mid-flight
//!      when KV slots free, stealing from overloaded siblings when
//!      idle — and sharing the backend through an `Arc` — every
//!      [`Backend`] method takes `&self`, so `B: Sync` is all that is
//!      required,
//!   3. on shutdown the lanes drain the queue and exit, and the
//!      **merge-at-retire** step reconciles the per-lane virtual
//!      clocks into one global simulated timeline for the
//!      [`ServeReport`].
//!
//! Clock-merge rule: lanes run concurrently (a sequence executes on
//! exactly one lane), so the merged makespan is the *slowest lane's*
//! clock (`max` over lanes), while Σ lane clocks is aggregate busy
//! time — both are reported.  A queued request spends real queue wait
//! before its pull and virtual residency after; `total_s` adds the two
//! (DESIGN.md §3).  Backends that really execute report no step costs
//! and the engine falls back to wall-clock timing.  Tokens are
//! bit-identical to serving the same workload through the streaming
//! API: the wrappers add no model work and no virtual time.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::runtime::Backend;
use crate::util::error::Result;

use super::engine::Engine;
use super::metrics::{RequestRecord, ServeReport};
use super::request::{Request, RequestResult};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max sequences decoded concurrently *per lane* (the batched
    /// decode round width ceiling).
    pub max_batch: usize,
    /// KV slots per lane (>= max_batch; extra slots admit prefills
    /// early).
    pub kv_slots: usize,
    /// Worker lanes pulling from the shared admission queue.
    pub workers: usize,
    /// Admission backpressure: max queued-but-unassigned requests
    /// before [`super::EngineHandle::submit`] sheds (`Failed` ticket,
    /// counted in `ServeReport::rejected` / `tsar_rejections_total`).
    /// `None` = unbounded.  Live submissions only — preloaded lists
    /// bypass the cap.
    pub queue_cap: Option<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 4, kv_slots: 4, workers: 1, queue_cap: None }
    }
}

/// The blocking serving surface. Owns the backend; `run` drains a
/// request stream to completion.
pub struct Server<B: Backend> {
    backend: Arc<B>,
    cfg: ServerConfig,
    record_tx: Option<Sender<RequestRecord>>,
}

impl<B: Backend> Server<B> {
    /// Validate `cfg` and build the server.  Library code must not
    /// abort the caller on bad config, so every constraint is an `Err`,
    /// never a panic.
    pub fn new(backend: B, cfg: ServerConfig) -> Result<Server<B>> {
        crate::ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
        crate::ensure!(cfg.workers >= 1, "workers must be >= 1");
        crate::ensure!(
            cfg.kv_slots >= cfg.max_batch,
            "kv_slots ({}) must cover max_batch ({}) on every lane",
            cfg.kv_slots,
            cfg.max_batch
        );
        if let Some(cap) = cfg.queue_cap {
            crate::ensure!(cap >= 1, "queue_cap must be >= 1 when set");
        }
        Ok(Server { backend: Arc::new(backend), cfg, record_tx: None })
    }

    /// Attach a metrics sink: every retired request streams one
    /// [`RequestRecord`] (queue/prefill/decode seconds, lane id, chosen
    /// kernel plan) over `tx` while a run is in flight.  Sends are
    /// best-effort — a dropped receiver never stalls serving.  (See
    /// [`super::Exporter`] for the JSONL endpoint that sits on the
    /// receiving side.)
    pub fn with_metrics_sink(mut self, tx: Sender<RequestRecord>) -> Server<B> {
        self.record_tx = Some(tx);
        self
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Recover the backend.  Panics if a run is still holding a clone
    /// of the shared backend (impossible once every `run*` call has
    /// returned — the blocking wrappers join their lanes).
    pub fn into_backend(self) -> B {
        let Server { backend, .. } = self;
        match Arc::try_unwrap(backend) {
            Ok(b) => b,
            Err(_) => panic!("into_backend while a serve run is still live"),
        }
    }
}

impl<B: Backend + Send + Sync + 'static> Server<B> {
    /// Serve every request from `rx` until the channel closes and all
    /// work drains; completed results go out through `tx`.  Blocks the
    /// caller for the whole run (the engine's lanes do the serving).
    pub fn run(
        &self,
        rx: Receiver<Request>,
        tx: Sender<RequestResult>,
    ) -> Result<ServeReport> {
        let handle = Engine::start_inner(
            Arc::clone(&self.backend),
            self.cfg.clone(),
            self.record_tx.clone(),
            Some(tx),
            false,
        )?;
        while let Ok(req) = rx.recv() {
            handle.submit_request(req);
        }
        handle.shutdown()
    }

    /// Serve a fixed request list: the whole list is pre-assigned
    /// round-robin onto the lanes' steal deques before any lane starts
    /// (the engine holds its lanes at a start gate), and the scheduler
    /// orders pulls by lane virtual clock, so the schedule (lane
    /// assignment, steals, batched round widths, virtual clocks) is a
    /// pure function of the list — the mode batch jobs and integration
    /// tests want.
    pub fn run_preloaded(
        &self,
        requests: Vec<Request>,
        tx: Sender<RequestResult>,
    ) -> Result<ServeReport> {
        let mut handle = Engine::start_inner(
            Arc::clone(&self.backend),
            self.cfg.clone(),
            self.record_tx.clone(),
            Some(tx),
            true,
        )?;
        for req in requests {
            handle.submit_request(req);
        }
        handle.open_gate();
        handle.shutdown()
    }
}

/// Convenience: serve a fixed list of requests synchronously with
/// deterministic sharding (used by the examples and integration tests).
pub fn serve_all<B: Backend + Send + Sync + 'static>(
    server: &Server<B>,
    requests: Vec<Request>,
) -> Result<ServeReport> {
    let (res_tx, _res_rx) = std::sync::mpsc::channel();
    server.run_preloaded(requests, res_tx)
}
