//! The serving engine: one thread owns the model backend and drives
//! continuous batching; clients submit requests over a channel.
//!
//! Scheduling policy per engine iteration:
//!   1. admit pending requests into free batch + KV slots (prefill),
//!   2. run one decode step for each active sequence (round-robin),
//!   3. retire sequences that hit EOS-budget, freeing slots immediately.
//!
//! The backends execute batch-1 steps, so "continuous batching"
//! interleaves sequences at step granularity — the same policy a
//! multi-batch executable would follow, with the batch dimension
//! serialized (DESIGN.md §3).
//!
//! Timing: backends that *model* execution ([`SimBackend`]) report a
//! simulated cost per step; the engine accumulates those on a virtual
//! clock (steps are serialized on the engine thread, so simulated wall
//! time is their sum) and per-request latencies come out paper-faithful.
//! Backends that really execute (PJRT) report no cost and the engine
//! falls back to wall-clock timing.
//!
//! [`SimBackend`]: crate::runtime::SimBackend

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use crate::runtime::Backend;
use crate::util::error::Result;

use super::batcher::Batcher;
use super::kvpool::KvSlotPool;
use super::metrics::ServeReport;
use super::request::{Request, RequestId, RequestResult};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max sequences decoded concurrently (continuous-batch width).
    pub max_batch: usize,
    /// KV slots (>= max_batch; extra slots admit prefills early).
    pub kv_slots: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 4, kv_slots: 4 }
    }
}

/// An active sequence's decode state, generic over the backend's KV
/// representation.
struct Active<C> {
    req: Request,
    tokens: Vec<i32>,
    cache: C,
    pos: i32,
    queue_s: f64,
    prefill_s: f64,
    decode_s: f64,
    /// Virtual-clock reading at admission (simulated backends).
    admit_clock: f64,
}

/// The serving engine. Owns the backend; `run` drains a request stream.
pub struct Server<B: Backend> {
    backend: B,
    cfg: ServerConfig,
}

impl<B: Backend> Server<B> {
    pub fn new(backend: B, cfg: ServerConfig) -> Server<B> {
        assert!(cfg.kv_slots >= cfg.max_batch);
        Server { backend, cfg }
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn into_backend(self) -> B {
        self.backend
    }

    /// Serve every request from `rx` until the channel closes and all
    /// work drains; completed results go out through `tx`.
    pub fn run(
        &self,
        rx: Receiver<Request>,
        tx: Sender<RequestResult>,
    ) -> Result<ServeReport> {
        let start = Instant::now();
        let mut batcher = Batcher::new(self.cfg.max_batch);
        let mut pool = KvSlotPool::new(self.cfg.kv_slots);
        let mut active: HashMap<RequestId, (Active<B::Cache>, super::kvpool::SlotId)> =
            HashMap::new();
        let mut results: Vec<RequestResult> = Vec::new();
        let mut open = true;
        // Virtual clock: sum of backend-reported step costs.  Stays at
        // zero (and unused) for backends that execute for real.
        let mut sim_clock = 0.0f64;
        let mut sim_timed = false;

        while open || batcher.has_work() {
            // Pull newly arrived requests (non-blocking unless idle).
            loop {
                if !open {
                    break;
                }
                let msg = if batcher.has_work() {
                    match rx.try_recv() {
                        Ok(r) => Some(r),
                        Err(std::sync::mpsc::TryRecvError::Empty) => None,
                        Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                            open = false;
                            None
                        }
                    }
                } else {
                    // Idle: block for the next request or shutdown.
                    match rx.recv() {
                        Ok(r) => Some(r),
                        Err(_) => {
                            open = false;
                            None
                        }
                    }
                };
                match msg {
                    Some(r) => batcher.submit(r),
                    None => break,
                }
            }

            // 1. Admission + prefill.
            while pool.available() > 0 {
                let Some(req) = batcher.admit() else { break };
                let slot = pool.allocate().expect("available() said so");
                let queue_s = req.arrival.elapsed().as_secs_f64();
                let p = self.backend.config().prefill_len;
                let mut padded = vec![0i32; p];
                let plen = req.prompt.len().min(p);
                padded[..plen].copy_from_slice(&req.prompt[..plen]);
                let admit_clock = sim_clock;
                let t0 = Instant::now();
                let out = match self.backend.prefill(&padded, plen as i32) {
                    Ok(out) => out,
                    Err(e) => {
                        // One malformed request must not take down the
                        // engine or the rest of the batch: drop it,
                        // free its slots, keep serving.
                        eprintln!("request {}: prefill failed: {e}", req.id);
                        batcher.finish(req.id)?;
                        pool.release(slot)?;
                        continue;
                    }
                };
                let prefill_s = match out.cost_s {
                    Some(c) => {
                        sim_clock += c;
                        sim_timed = true;
                        c
                    }
                    None => t0.elapsed().as_secs_f64(),
                };
                active.insert(
                    req.id,
                    (
                        Active {
                            pos: plen as i32,
                            tokens: vec![out.next_token],
                            cache: out.cache,
                            req,
                            queue_s,
                            prefill_s,
                            decode_s: 0.0,
                            admit_clock,
                        },
                        slot,
                    ),
                );
            }

            // 2. One decode step per active sequence this round.
            let round: Vec<RequestId> = (0..batcher.active_len())
                .filter_map(|_| batcher.next_decode())
                .collect();
            for id in round {
                let Some((seq, _slot)) = active.get_mut(&id) else { continue };
                let max_seq = self.backend.config().max_seq;
                let done = seq.tokens.len() >= seq.req.max_new_tokens
                    || (seq.pos as usize) >= max_seq - 1;
                let mut failed = false;
                if !done {
                    let t0 = Instant::now();
                    match self.backend.decode(*seq.tokens.last().unwrap(), seq.pos, &seq.cache)
                    {
                        Ok(out) => {
                            seq.decode_s += match out.cost_s {
                                Some(c) => {
                                    sim_clock += c;
                                    sim_timed = true;
                                    c
                                }
                                None => t0.elapsed().as_secs_f64(),
                            };
                            seq.tokens.push(out.next_token);
                            seq.cache = out.cache;
                            seq.pos += 1;
                        }
                        Err(e) => {
                            // Same policy as prefill: one failing
                            // sequence must not take down the engine.
                            // Retire it with the tokens it has.
                            eprintln!(
                                "request {}: decode failed: {e}; retiring with partial output",
                                seq.req.id
                            );
                            failed = true;
                        }
                    }
                }
                let done = failed
                    || seq.tokens.len() >= seq.req.max_new_tokens
                    || (seq.pos as usize) >= max_seq - 1;
                if done {
                    // 3. Retire.
                    let (seq, slot) = active.remove(&id).unwrap();
                    batcher.finish(id)?;
                    pool.release(slot)?;
                    let total_s = if sim_timed {
                        // Virtual residency (including steps spent on
                        // interleaved neighbours) + real queue wait.
                        seq.queue_s + (sim_clock - seq.admit_clock)
                    } else {
                        seq.req.arrival.elapsed().as_secs_f64()
                    };
                    let res = RequestResult {
                        id,
                        total_s,
                        tokens: seq.tokens,
                        queue_s: seq.queue_s,
                        prefill_s: seq.prefill_s,
                        decode_s: seq.decode_s,
                    };
                    let _ = tx.send(res.clone());
                    results.push(res);
                }
            }
        }

        let wall_s = if sim_timed { sim_clock } else { start.elapsed().as_secs_f64() };
        ServeReport::from(&results, wall_s)
            .ok_or_else(|| crate::err!("no requests served"))
    }
}

/// Convenience: serve a fixed list of requests synchronously (used by
/// the examples and integration tests).
pub fn serve_all<B: Backend>(server: &Server<B>, requests: Vec<Request>) -> Result<ServeReport> {
    let (req_tx, req_rx) = channel();
    let (res_tx, _res_rx) = channel();
    for r in requests {
        req_tx.send(r).unwrap();
    }
    drop(req_tx);
    server.run(req_rx, res_tx)
}
