//! The sharded serving engine: admitted sequences are sharded across N
//! worker lanes (std::thread + mpsc channels), each lane driving
//! *batched* decode rounds against a shared backend and keeping its own
//! virtual clock; clients submit requests over a channel.
//!
//! Topology per [`Server::run`]:
//!
//!   1. the calling thread becomes the **dispatcher**: it drains the
//!      request channel and shards arrivals round-robin across lanes,
//!   2. each **lane** (scoped worker thread) runs admission → prefill →
//!      batched decode rounds → retire over its shard (the `lane`
//!      module), sharing the backend by reference — every [`Backend`]
//!      method takes `&self`, so `B: Sync` is all that is required,
//!   3. when the request channel closes, the lane channels close, the
//!      lanes drain and exit, and the **merge-at-retire** step
//!      reconciles the per-lane virtual clocks into one global
//!      simulated timeline for the [`ServeReport`].
//!
//! Clock-merge rule: lanes run concurrently over disjoint shards, so
//! the merged makespan is the *slowest lane's* clock (`max` over
//! lanes), while Σ lane clocks is aggregate busy time — both are
//! reported.  Backends that really execute (PJRT) report no step costs
//! and the engine falls back to wall-clock timing.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use crate::runtime::Backend;
use crate::util::error::Result;

use super::lane::{lane_loop, LaneOutcome};
use super::metrics::{RequestRecord, ServeReport};
use super::request::{Request, RequestResult};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max sequences decoded concurrently *per lane* (the batched
    /// decode round width ceiling).
    pub max_batch: usize,
    /// KV slots per lane (>= max_batch; extra slots admit prefills
    /// early).
    pub kv_slots: usize,
    /// Worker lanes the admitted sequences are sharded across.
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 4, kv_slots: 4, workers: 1 }
    }
}

/// The serving engine. Owns the backend; `run` drains a request stream.
pub struct Server<B: Backend> {
    backend: B,
    cfg: ServerConfig,
    record_tx: Option<Sender<RequestRecord>>,
}

impl<B: Backend> Server<B> {
    /// Validate `cfg` and build the engine.  Library code must not
    /// abort the caller on bad config, so every constraint is an `Err`,
    /// never a panic.
    pub fn new(backend: B, cfg: ServerConfig) -> Result<Server<B>> {
        crate::ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
        crate::ensure!(cfg.workers >= 1, "workers must be >= 1");
        crate::ensure!(
            cfg.kv_slots >= cfg.max_batch,
            "kv_slots ({}) must cover max_batch ({}) on every lane",
            cfg.kv_slots,
            cfg.max_batch
        );
        Ok(Server { backend, cfg, record_tx: None })
    }

    /// Attach a metrics sink: every retired request streams one
    /// [`RequestRecord`] (queue/prefill/decode seconds, lane id, chosen
    /// kernel plan) over `tx` while the run is in flight.  Sends are
    /// best-effort — a dropped receiver never stalls serving.
    pub fn with_metrics_sink(mut self, tx: Sender<RequestRecord>) -> Server<B> {
        self.record_tx = Some(tx);
        self
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn into_backend(self) -> B {
        self.backend
    }
}

/// How `run_inner` is fed: a live request stream (open-loop serving,
/// the dispatcher shards arrivals as they come) or a preloaded list
/// (sharded up front, so lane assignment and batched round widths are
/// deterministic — no dispatch/lane-startup race).
enum Feed {
    Stream(Receiver<Request>),
    Preloaded(Vec<Request>),
}

impl<B: Backend + Sync> Server<B> {
    /// Serve every request from `rx` until the channel closes and all
    /// work drains; completed results go out through `tx`.
    pub fn run(
        &self,
        rx: Receiver<Request>,
        tx: Sender<RequestResult>,
    ) -> Result<ServeReport> {
        self.run_inner(Feed::Stream(rx), tx)
    }

    /// Serve a fixed request list: the whole list is sharded
    /// round-robin across the lanes before any lane starts, so the
    /// schedule (lane assignment, batched round widths, virtual clocks)
    /// is a pure function of the list — the mode batch jobs and
    /// integration tests want.
    pub fn run_preloaded(
        &self,
        requests: Vec<Request>,
        tx: Sender<RequestResult>,
    ) -> Result<ServeReport> {
        self.run_inner(Feed::Preloaded(requests), tx)
    }

    fn run_inner(&self, feed: Feed, tx: Sender<RequestResult>) -> Result<ServeReport> {
        let start = Instant::now();
        let workers = self.cfg.workers;
        let outcomes: Vec<Result<LaneOutcome>> = std::thread::scope(|s| {
            let mut lane_txs: Vec<Sender<Request>> = Vec::with_capacity(workers);
            let mut lane_rxs = Vec::with_capacity(workers);
            for _ in 0..workers {
                let (lane_tx, lane_rx) = channel::<Request>();
                lane_txs.push(lane_tx);
                lane_rxs.push(lane_rx);
            }
            // Preloaded work is sharded (and the shard channels closed)
            // before the lanes spawn, so every lane sees its whole
            // shard at its first pull.
            let feed = match feed {
                Feed::Preloaded(requests) => {
                    for (i, req) in requests.into_iter().enumerate() {
                        let _ = lane_txs[i % workers].send(req);
                    }
                    lane_txs.clear();
                    None
                }
                Feed::Stream(rx) => Some(rx),
            };
            let mut handles = Vec::with_capacity(workers);
            for (lane_id, lane_rx) in lane_rxs.into_iter().enumerate() {
                let backend = &self.backend;
                let cfg = &self.cfg;
                let res_tx = tx.clone();
                let sink = self.record_tx.clone();
                handles.push(s.spawn(move || {
                    lane_loop(backend, cfg, lane_id, lane_rx, res_tx, sink)
                }));
            }
            // Dispatcher: shard live arrivals round-robin across the
            // lanes.  A send only fails if a lane died early; stop
            // feeding and surface that lane's error through its join.
            if let Some(rx) = feed {
                let mut next = 0usize;
                while let Ok(req) = rx.recv() {
                    if lane_txs[next % workers].send(req).is_err() {
                        break;
                    }
                    next += 1;
                }
            }
            drop(lane_txs); // close the shard channels: lanes drain and exit
            handles
                .into_iter()
                .map(|h| h.join().expect("lane thread panicked"))
                .collect()
        });

        let mut results: Vec<RequestResult> = Vec::new();
        let mut lanes = Vec::with_capacity(workers);
        let mut sim_timed = false;
        for outcome in outcomes {
            let outcome = outcome?;
            sim_timed |= outcome.sim_timed;
            results.extend(outcome.results);
            lanes.push(outcome.stats);
        }
        // Merge at retire: lanes are concurrent engines over disjoint
        // shards, so the global simulated timeline is the slowest
        // lane's clock; real backends report elapsed wall time instead.
        let wall_s = if sim_timed {
            lanes.iter().map(|l| l.clock_s).fold(0.0f64, f64::max)
        } else {
            start.elapsed().as_secs_f64()
        };
        results.sort_by_key(|r| r.id);
        ServeReport::from_lanes(&results, wall_s, lanes)
            .ok_or_else(|| crate::err!("no requests served"))
    }
}

/// Convenience: serve a fixed list of requests synchronously with
/// deterministic sharding (used by the examples and integration tests).
pub fn serve_all<B: Backend + Sync>(
    server: &Server<B>,
    requests: Vec<Request>,
) -> Result<ServeReport> {
    let (res_tx, _res_rx) = channel();
    server.run_preloaded(requests, res_tx)
}
