//! The serving engine: one thread owns the PJRT runtime and drives
//! continuous batching; clients submit requests over a channel.
//!
//! Scheduling policy per engine iteration:
//!   1. admit pending requests into free batch + KV slots (prefill),
//!   2. run one decode step for each active sequence (round-robin),
//!   3. retire sequences that hit EOS-budget, freeing slots immediately.
//!
//! The AOT artifact is a batch-1 executable, so "continuous batching"
//! interleaves sequences at step granularity — the same policy a
//! multi-batch executable would follow, with the batch dimension
//! serialized (DESIGN.md §3).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{KvCache, ModelRuntime};

use super::batcher::Batcher;
use super::kvpool::KvSlotPool;
use super::metrics::ServeReport;
use super::request::{Request, RequestId, RequestResult};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max sequences decoded concurrently (continuous-batch width).
    pub max_batch: usize,
    /// KV slots (>= max_batch; extra slots admit prefills early).
    pub kv_slots: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 4, kv_slots: 4 }
    }
}

/// An active sequence's decode state.
struct Active {
    req: Request,
    tokens: Vec<i32>,
    cache: KvCache,
    pos: i32,
    queue_s: f64,
    prefill_s: f64,
    decode_s: f64,
}

/// The serving engine. Owns the runtime; `run` drains a request stream.
pub struct Server {
    runtime: ModelRuntime,
    cfg: ServerConfig,
}

impl Server {
    pub fn new(runtime: ModelRuntime, cfg: ServerConfig) -> Server {
        assert!(cfg.kv_slots >= cfg.max_batch);
        Server { runtime, cfg }
    }

    /// Serve every request from `rx` until the channel closes and all
    /// work drains; completed results go out through `tx`.
    pub fn run(
        &self,
        rx: Receiver<Request>,
        tx: Sender<RequestResult>,
    ) -> Result<ServeReport> {
        let start = Instant::now();
        let mut batcher = Batcher::new(self.cfg.max_batch);
        let mut pool = KvSlotPool::new(self.cfg.kv_slots);
        let mut active: HashMap<RequestId, (Active, super::kvpool::SlotId)> =
            HashMap::new();
        let mut results: Vec<RequestResult> = Vec::new();
        let mut open = true;

        while open || batcher.has_work() {
            // Pull newly arrived requests (non-blocking unless idle).
            loop {
                if !open {
                    break;
                }
                let msg = if batcher.has_work() {
                    match rx.try_recv() {
                        Ok(r) => Some(r),
                        Err(std::sync::mpsc::TryRecvError::Empty) => None,
                        Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                            open = false;
                            None
                        }
                    }
                } else {
                    // Idle: block for the next request or shutdown.
                    match rx.recv() {
                        Ok(r) => Some(r),
                        Err(_) => {
                            open = false;
                            None
                        }
                    }
                };
                match msg {
                    Some(r) => batcher.submit(r),
                    None => break,
                }
            }

            // 1. Admission + prefill.
            while pool.available() > 0 {
                let Some(req) = batcher.admit() else { break };
                let slot = pool.allocate().expect("available() said so");
                let queue_s = req.arrival.elapsed().as_secs_f64();
                let p = self.runtime.manifest.config.prefill_len;
                let mut padded = vec![0i32; p];
                let plen = req.prompt.len().min(p);
                padded[..plen].copy_from_slice(&req.prompt[..plen]);
                let t0 = Instant::now();
                let out = self.runtime.prefill(&padded, plen as i32)?;
                let prefill_s = t0.elapsed().as_secs_f64();
                active.insert(
                    req.id,
                    (
                        Active {
                            pos: plen as i32,
                            tokens: vec![out.next_token],
                            cache: out.cache,
                            req,
                            queue_s,
                            prefill_s,
                            decode_s: 0.0,
                        },
                        slot,
                    ),
                );
            }

            // 2. One decode step per active sequence this round.
            let round: Vec<RequestId> = (0..batcher.active_len())
                .filter_map(|_| batcher.next_decode())
                .collect();
            for id in round {
                let Some((seq, _slot)) = active.get_mut(&id) else { continue };
                let done = seq.tokens.len() >= seq.req.max_new_tokens
                    || (seq.pos as usize) >= self.runtime.manifest.config.max_seq - 1;
                if !done {
                    let t0 = Instant::now();
                    let out =
                        self.runtime.decode(*seq.tokens.last().unwrap(), seq.pos, &seq.cache)?;
                    seq.decode_s += t0.elapsed().as_secs_f64();
                    seq.tokens.push(out.next_token);
                    seq.cache = out.cache;
                    seq.pos += 1;
                }
                let done = seq.tokens.len() >= seq.req.max_new_tokens
                    || (seq.pos as usize) >= self.runtime.manifest.config.max_seq - 1;
                if done {
                    // 3. Retire.
                    let (seq, slot) = active.remove(&id).unwrap();
                    batcher.finish(id)?;
                    pool.release(slot)?;
                    let res = RequestResult {
                        id,
                        total_s: seq.req.arrival.elapsed().as_secs_f64(),
                        tokens: seq.tokens,
                        queue_s: seq.queue_s,
                        prefill_s: seq.prefill_s,
                        decode_s: seq.decode_s,
                    };
                    let _ = tx.send(res.clone());
                    results.push(res);
                }
            }
        }

        ServeReport::from(&results, start.elapsed().as_secs_f64())
            .ok_or_else(|| anyhow::anyhow!("no requests served"))
    }
}

/// Convenience: serve a fixed list of requests synchronously (used by
/// the examples and integration tests).
pub fn serve_all(server: &Server, requests: Vec<Request>) -> Result<ServeReport> {
    let (req_tx, req_rx) = channel();
    let (res_tx, _res_rx) = channel();
    for r in requests {
        req_tx.send(r).unwrap();
    }
    drop(req_tx);
    server.run(req_rx, res_tx)
}
