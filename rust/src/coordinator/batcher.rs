//! Continuous batcher: admission control + round-robin decode scheduling.
//!
//! Requests wait in a FIFO; up to `max_batch` sequences are active at
//! once.  Each scheduling round yields (a) the next admission, if a slot
//! is free, which triggers a prefill, and (b) the round-robin order of
//! active sequences for one decode step each — vLLM-style continuous
//! batching (a finished sequence's slot is refilled immediately, without
//! waiting for the rest of the batch).
//!
//! Invariants (property-tested): no request is lost or duplicated, FIFO
//! admission order, the active set never exceeds `max_batch`.
//!
//! Mid-flight joins: `submit` and `admit` are legal at *any* round
//! boundary, including while other sequences are mid-decode — the lane
//! pulls from the shared admission queue between rounds and feeds new
//! sequences in here the moment a slot frees.  Joining only appends to
//! `active`; positions (and the round-robin cursor discipline) of the
//! sequences already decoding are untouched, which is what keeps their
//! per-sequence KV state (`pos == cache.len()`) unperturbed.

use std::collections::VecDeque;

use super::request::{Request, RequestId};

#[derive(Debug)]
pub struct Batcher {
    max_batch: usize,
    pending: VecDeque<Request>,
    active: Vec<RequestId>,
    /// Round-robin cursor into `active`.
    cursor: usize,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Batcher {
        assert!(max_batch >= 1);
        Batcher { max_batch, pending: VecDeque::new(), active: Vec::new(), cursor: 0 }
    }

    pub fn submit(&mut self, req: Request) {
        self.pending.push_back(req);
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.active.is_empty()
    }

    /// Admit the next pending request if batch capacity allows.
    /// The caller performs the prefill and owns the returned request.
    pub fn admit(&mut self) -> Option<Request> {
        if self.active.len() >= self.max_batch {
            return None;
        }
        let req = self.pending.pop_front()?;
        self.active.push(req.id);
        Some(req)
    }

    /// Next active sequence to decode one step (round-robin).
    pub fn next_decode(&mut self) -> Option<RequestId> {
        if self.active.is_empty() {
            return None;
        }
        self.cursor %= self.active.len();
        let id = self.active[self.cursor];
        self.cursor += 1;
        Some(id)
    }

    /// Retire a finished sequence, freeing its batch slot.
    pub fn finish(&mut self, id: RequestId) -> crate::util::error::Result<()> {
        let idx = self
            .active
            .iter()
            .position(|&a| a == id)
            .ok_or_else(|| crate::err!("finish of inactive request {id}"))?;
        self.active.remove(idx);
        if self.cursor > idx {
            self.cursor -= 1;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: RequestId) -> Request {
        Request::new(id, vec![1, 2], 4)
    }

    #[test]
    fn fifo_admission_respects_capacity() {
        let mut b = Batcher::new(2);
        for i in 0..4 {
            b.submit(req(i));
        }
        assert_eq!(b.admit().unwrap().id, 0);
        assert_eq!(b.admit().unwrap().id, 1);
        assert!(b.admit().is_none(), "batch full");
        b.finish(0).unwrap();
        assert_eq!(b.admit().unwrap().id, 2, "refill keeps FIFO order");
        assert_eq!(b.active_len(), 2);
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn round_robin_covers_all_active() {
        let mut b = Batcher::new(3);
        for i in 0..3 {
            b.submit(req(i));
            b.admit().unwrap();
        }
        let seen: Vec<RequestId> =
            (0..6).map(|_| b.next_decode().unwrap()).collect();
        assert_eq!(seen, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn finish_mid_round_keeps_fairness() {
        let mut b = Batcher::new(3);
        for i in 0..3 {
            b.submit(req(i));
            b.admit().unwrap();
        }
        assert_eq!(b.next_decode(), Some(0));
        b.finish(1).unwrap();
        // Remaining actives continue to be served.
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..4 {
            seen.insert(b.next_decode().unwrap());
        }
        assert_eq!(seen.into_iter().collect::<Vec<_>>(), vec![0, 2]);
    }

    #[test]
    fn midflight_join_leaves_running_sequences_undisturbed() {
        let mut b = Batcher::new(3);
        for i in 0..2 {
            b.submit(req(i));
            b.admit().unwrap();
        }
        // Two sequences are mid-decode when a third lands (a lane pull
        // between rounds): it joins on admit without reordering them.
        assert_eq!(b.next_decode(), Some(0));
        b.submit(req(2));
        assert_eq!(b.admit().unwrap().id, 2);
        assert_eq!(b.next_decode(), Some(1), "running round-robin order is unchanged");
        assert_eq!(b.next_decode(), Some(2), "the joiner decodes at the round's end");
        assert_eq!(b.next_decode(), Some(0));
    }

    #[test]
    fn finish_unknown_errors() {
        let mut b = Batcher::new(1);
        assert!(b.finish(99).is_err());
    }
}
