//! Shared admission queue + cross-lane work stealing (DESIGN.md §3).
//!
//! The continuous-batching scheduler replaces the old shard-at-submit
//! routing: requests land on a shared **injector** queue (live
//! submissions) or on per-lane **deques** (deterministic round-robin
//! pre-assignment for preloaded runs), and lanes *pull* between decode
//! rounds — as many requests as they have free batch and KV slots, so
//! new sequences join a running batch mid-flight the moment a slot
//! frees.  A lane whose own deque and the injector are empty **steals**
//! from the back of the most-loaded sibling deque, but only from a
//! sibling whose published virtual clock is *strictly ahead* of its
//! own: that victim is busy past the thief's virtual now, so its queued
//! work would otherwise wait.  At equal clocks no steal fires, which
//! keeps balanced preloaded schedules exactly on their round-robin
//! assignment (and their pinned per-lane stats).
//!
//! Determinism contract for preloaded runs (`ordered` mode): pulls are
//! totally ordered by `(published lane clock, lane id)` — a lane takes
//! its pull turn only when no other runnable lane is earlier in virtual
//! time.  Lanes publish their clock after every loop iteration
//! ([`Scheduler::update_clock`]) and leave the order when they exit or
//! die ([`Scheduler::park`], or the [`LaneParkGuard`] drop on a panic),
//! so the schedule — lane assignment, steals, round widths, virtual
//! clocks — is a pure function of the request list.  Live engines skip
//! the ordering (real arrival times are not reproducible anyway) and
//! race for the injector, which is exactly the work-sharing a
//! production front-end wants.
//!
//! The queue also carries admission backpressure: `enqueue`/`preassign`
//! take an optional cap on queued-but-unassigned requests and refuse
//! the request when it is reached, so a flooded engine sheds at submit
//! time instead of growing an unbounded backlog.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::request::Request;

/// What a lane gets back from [`Scheduler::pull`].
pub(crate) enum Pull {
    /// Requests to admit this iteration — from the lane's own deque,
    /// the shared injector, or stolen from a sibling; each is stamped
    /// with its queue wait and steal flag.
    Batch(Vec<Request>),
    /// Nothing to pull right now, but the lane has active sequences:
    /// run the next decode round and pull again at the round boundary.
    Pending,
    /// Admission is closed and the queue is drained: exit once the
    /// active set retires.
    Closed,
}

struct LaneSlot {
    /// Pre-assigned (and steal-able) requests for this lane.
    deque: VecDeque<Request>,
    /// The lane's last published virtual clock (busy seconds).
    clock: f64,
    /// In the ordered pull rotation; `false` once parked (idle-blocked,
    /// exited, or dead), so turn-taking never waits on a stale clock.
    runnable: bool,
}

struct Inner {
    injector: VecDeque<Request>,
    lanes: Vec<LaneSlot>,
    open: bool,
    /// Round-robin cursor for [`Scheduler::preassign`].
    assign_cursor: usize,
}

impl Inner {
    fn queued(&self) -> usize {
        self.injector.len() + self.lanes.iter().map(|l| l.deque.len()).sum::<usize>()
    }

    fn is_drained(&self) -> bool {
        self.injector.is_empty() && self.lanes.iter().all(|l| l.deque.is_empty())
    }
}

/// The shared admission queue + per-lane steal deques (see the module
/// docs for the scheduling and determinism contract).
pub(crate) struct Scheduler {
    inner: Mutex<Inner>,
    cv: Condvar,
    /// Preloaded mode: order pulls by `(clock, lane id)` so the
    /// schedule is a pure function of the request list.
    ordered: bool,
}

impl Scheduler {
    pub(crate) fn new(workers: usize, ordered: bool) -> Scheduler {
        Scheduler {
            inner: Mutex::new(Inner {
                injector: VecDeque::new(),
                lanes: (0..workers)
                    .map(|_| LaneSlot { deque: VecDeque::new(), clock: 0.0, runnable: true })
                    .collect(),
                open: true,
                assign_cursor: 0,
            }),
            cv: Condvar::new(),
            ordered,
        }
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().expect("scheduler state poisoned")
    }

    /// Queued-but-unassigned requests (shared injector + lane deques);
    /// excludes sequences already active on a lane.
    pub(crate) fn queued(&self) -> usize {
        self.lock().queued()
    }

    /// Enqueue onto the shared injector — any lane may pull it.  With a
    /// `cap`, admission backpressure: `false` (request refused) when
    /// the queue already holds `cap` requests.
    pub(crate) fn enqueue(&self, req: Request, cap: Option<usize>) -> bool {
        let mut inner = self.lock();
        if cap.is_some_and(|cap| inner.queued() >= cap) {
            return false;
        }
        inner.injector.push_back(req);
        drop(inner);
        self.cv.notify_all();
        true
    }

    /// Deterministically pre-assign onto a lane deque (round-robin in
    /// submission order) — the preloaded mode.  Stealing rebalances the
    /// deques once the lane clocks diverge.
    pub(crate) fn preassign(&self, req: Request, cap: Option<usize>) -> bool {
        let mut inner = self.lock();
        if cap.is_some_and(|cap| inner.queued() >= cap) {
            return false;
        }
        let lane = inner.assign_cursor % inner.lanes.len();
        inner.assign_cursor += 1;
        inner.lanes[lane].deque.push_back(req);
        drop(inner);
        self.cv.notify_all();
        true
    }

    /// Close admission: queued work is still drained, then pulls return
    /// [`Pull::Closed`].  Idempotent.
    pub(crate) fn close(&self) {
        self.lock().open = false;
        self.cv.notify_all();
    }

    /// Publish a lane's virtual clock (after every lane-loop
    /// iteration).  In ordered mode this is what hands the pull turn to
    /// the next lane in `(clock, lane id)` order.
    pub(crate) fn update_clock(&self, lane: usize, clock: f64) {
        self.lock().lanes[lane].clock = clock;
        self.cv.notify_all();
    }

    /// Take a lane out of the pull rotation (exited or died), so
    /// ordered pulls never wait on its stale clock.  Idempotent.
    pub(crate) fn park(&self, lane: usize) {
        self.lock().lanes[lane].runnable = false;
        self.cv.notify_all();
    }

    /// Pull up to `want` requests for `lane`.  Sources in order: the
    /// lane's own deque (FIFO), the shared injector (FIFO), then a
    /// steal from the back of the most-loaded eligible sibling deque.
    /// Blocks when the lane is idle (`has_active == false`) and
    /// admission is still open; in ordered mode, also blocks until the
    /// lane's `(clock, id)` turn whenever queued work remains.
    pub(crate) fn pull(&self, lane: usize, want: usize, has_active: bool) -> Pull {
        let mut inner = self.lock();
        loop {
            if self.ordered && !inner.is_drained() && !Self::my_turn(&inner, lane) {
                // Another runnable lane is earlier in virtual time and
                // could still take from the queue: wait for our turn so
                // the schedule stays a pure function of the request
                // list.  (An empty queue cannot refill in ordered mode
                // — preloaded runs only drain — so no turn is needed to
                // observe it.)
                inner = self.wait(inner);
                continue;
            }
            let mut got: Vec<Request> = Vec::new();
            if want > 0 {
                let now = Instant::now();
                while got.len() < want {
                    let (mut req, stolen) = if let Some(r) = inner.lanes[lane].deque.pop_front()
                    {
                        (r, false)
                    } else if let Some(r) = inner.injector.pop_front() {
                        (r, false)
                    } else if let Some(r) = Self::steal(&mut inner, lane) {
                        (r, true)
                    } else {
                        break;
                    };
                    req.stolen = stolen;
                    req.queue_wait_s =
                        Some(now.saturating_duration_since(req.arrival).as_secs_f64());
                    got.push(req);
                }
            }
            if !got.is_empty() {
                // The queue shrank (and may now be drained): wake
                // ordered waiters so they re-evaluate their gate.
                drop(inner);
                self.cv.notify_all();
                return Pull::Batch(got);
            }
            if has_active {
                return Pull::Pending;
            }
            if !inner.open {
                inner.lanes[lane].runnable = false;
                drop(inner);
                self.cv.notify_all();
                return Pull::Closed;
            }
            // Idle with nothing queued: park until work arrives or
            // admission closes.
            inner.lanes[lane].runnable = false;
            self.cv.notify_all();
            inner = self.wait(inner);
            inner.lanes[lane].runnable = true;
        }
    }

    /// Condvar wait with a safety timeout: wake-ups re-check state, so
    /// a spurious or timed-out wake is always benign.
    fn wait<'a>(&self, inner: MutexGuard<'a, Inner>) -> MutexGuard<'a, Inner> {
        self.cv
            .wait_timeout(inner, Duration::from_millis(50))
            .expect("scheduler state poisoned")
            .0
    }

    /// Ordered mode: is `lane` the earliest runnable lane in
    /// `(published clock, lane id)` order?
    fn my_turn(inner: &Inner, lane: usize) -> bool {
        let mine = inner.lanes[lane].clock;
        !inner.lanes.iter().enumerate().any(|(i, l)| {
            i != lane && l.runnable && (l.clock < mine || (l.clock == mine && i < lane))
        })
    }

    /// Steal one request from the back of the most-loaded eligible
    /// sibling deque (ties to the smallest lane id).  Eligibility: the
    /// victim's published clock is *strictly ahead* of the thief's —
    /// see the module docs for why equal clocks never steal.
    fn steal(inner: &mut Inner, thief: usize) -> Option<Request> {
        let my_clock = inner.lanes[thief].clock;
        let victim = inner
            .lanes
            .iter()
            .enumerate()
            .filter(|&(i, l)| i != thief && l.clock > my_clock && !l.deque.is_empty())
            .max_by_key(|&(i, l)| (l.deque.len(), std::cmp::Reverse(i)))
            .map(|(i, _)| i)?;
        inner.lanes[victim].deque.pop_back()
    }
}

/// Parks its lane on drop, so a panicking lane leaves the ordered pull
/// rotation instead of deadlocking the siblings on its stale clock.
pub(crate) struct LaneParkGuard<'a> {
    sched: &'a Scheduler,
    lane: usize,
}

impl<'a> LaneParkGuard<'a> {
    pub(crate) fn new(sched: &'a Scheduler, lane: usize) -> LaneParkGuard<'a> {
        LaneParkGuard { sched, lane }
    }
}

impl Drop for LaneParkGuard<'_> {
    fn drop(&mut self) {
        self.sched.park(self.lane);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2], 4)
    }

    fn ids(pull: Pull) -> Vec<u64> {
        match pull {
            Pull::Batch(reqs) => reqs.iter().map(|r| r.id).collect(),
            _ => panic!("expected a batch"),
        }
    }

    #[test]
    fn preassign_is_round_robin_and_pulls_are_fifo() {
        let s = Scheduler::new(2, true);
        for id in 0..4 {
            assert!(s.preassign(req(id), None));
        }
        assert_eq!(s.queued(), 4);
        // Lane 0 holds {0, 2}, lane 1 holds {1, 3}; both at clock 0, so
        // lane 0 has the first turn and must not steal from its
        // equal-clock sibling.
        assert_eq!(ids(s.pull(0, 4, false)), vec![0, 2]);
        s.update_clock(0, 1.0);
        assert_eq!(ids(s.pull(1, 4, false)), vec![1, 3]);
        assert_eq!(s.queued(), 0);
    }

    #[test]
    fn steal_requires_a_strictly_slower_thief() {
        let s = Scheduler::new(2, true);
        for id in 0..4 {
            assert!(s.preassign(req(id), None));
        }
        // Lane 0 races ahead on its virtual clock with {0, 2} still
        // queued; idle lane 1 (clock 0) may steal from the *back* of
        // lane 0's deque after draining its own.
        s.update_clock(0, 5.0);
        let got = ids(s.pull(1, 4, false));
        assert_eq!(got, vec![1, 3, 2], "own FIFO first, then steal lane 0's back");
        // Lane 0 keeps its front-of-queue request.
        assert_eq!(ids(s.pull(0, 4, false)), vec![0]);
    }

    #[test]
    fn live_enqueue_is_shared_fifo() {
        let s = Scheduler::new(2, false);
        for id in 0..3 {
            assert!(s.enqueue(req(id), None));
        }
        assert_eq!(ids(s.pull(1, 2, false)), vec![0, 1]);
        assert_eq!(ids(s.pull(0, 2, true)), vec![2]);
    }

    #[test]
    fn cap_refuses_when_full_and_admits_after_a_pull() {
        let s = Scheduler::new(1, false);
        assert!(s.enqueue(req(0), Some(2)));
        assert!(s.enqueue(req(1), Some(2)));
        assert!(!s.enqueue(req(2), Some(2)), "queue at cap");
        assert!(!s.preassign(req(2), Some(2)), "cap applies to both paths");
        let _ = s.pull(0, 1, false);
        assert!(s.enqueue(req(3), Some(2)), "a pull frees queue room");
    }

    #[test]
    fn closed_and_drained_queue_reports_closed() {
        let s = Scheduler::new(1, false);
        assert!(s.enqueue(req(0), None));
        s.close();
        assert!(!s.enqueue(req(1), None) || true, "close is about pulls, not sends");
        assert_eq!(ids(s.pull(0, 1, false)), vec![0], "drain after close");
        assert!(matches!(s.pull(0, 1, true), Pull::Pending), "active lane never blocks");
        assert!(matches!(s.pull(0, 1, false), Pull::Closed));
    }

    #[test]
    fn pulled_requests_are_stamped_with_queue_wait() {
        let s = Scheduler::new(1, false);
        assert!(s.enqueue(req(7), None));
        match s.pull(0, 1, false) {
            Pull::Batch(reqs) => {
                assert!(reqs[0].queue_wait_s.is_some_and(|w| w >= 0.0));
                assert!(!reqs[0].stolen);
            }
            _ => panic!("expected a batch"),
        }
    }
}
