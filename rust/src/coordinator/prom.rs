//! The scrape half of the request-level metrics endpoint (ROADMAP
//! item; the export half is the JSONL [`super::Exporter`]):
//! [`PromAggregator`] drains the engine's [`RequestRecord`] channel in
//! the background and folds every record into a shared set of
//! Prometheus-style counters ([`PromCounters`]), which
//! `GET /metrics` on the HTTP front-end ([`super::http`]) renders in
//! the Prometheus text exposition format.
//!
//! The aggregator can *tee* alongside the JSONL exporter: feed the
//! engine one sender and fan the records out with
//! [`super::export::tee_records`] so both sinks see every record.
//!
//! Exposed series (all prefixed `tsar_`):
//!
//! | series | type | meaning |
//! |---|---|---|
//! | `tsar_requests_total{finish=...}` | counter | retired requests by finish reason (`length`, `stop`, `cancelled`, `deadline`, `failed`) |
//! | `tsar_tokens_emitted_total` | counter | tokens emitted by retired requests (prefill token included) |
//! | `tsar_lane_busy_seconds_total` | counter | busy seconds accumulated by the lanes (Σ prefill + decode over retired requests — simulated seconds for modeled backends, measured for real ones) |
//! | `tsar_queue_depth` | gauge | sessions submitted (via [`PromCounters::note_submitted`]) and not yet retired |
//! | `tsar_steals_total` | counter | retired requests that executed on a lane which stole them off a sibling's deque |
//! | `tsar_joins_midflight_total` | counter | retired requests admitted into a batch already running decode rounds (continuous-batching joins) |
//! | `tsar_rejections_total` | counter | submissions shed at admission (validation or queue backpressure) — they never executed on a lane; the same count `ServeReport::rejected` carries, so gauge and shutdown report stay consistent |
//! | `tsar_queue_wait_seconds` | histogram | admission-queue wait (arrival → scheduler pull) of executed requests |
//!
//! Rejections *do* count as retired (the request's lifecycle is over),
//! so `tsar_queue_depth` returns to zero after a shed instead of
//! leaking a phantom in-flight session.
//!
//! Counters are relaxed atomics: scrapes race retirements by at most
//! one in-flight record, which Prometheus' pull model tolerates by
//! design.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::thread::JoinHandle;

use super::metrics::RequestRecord;
use super::request::FinishReason;

/// Shared Prometheus-style counter set over the serving engine.
///
/// Writers are the aggregator thread ([`PromCounters::observe`], one
/// call per retired [`RequestRecord`]) and the HTTP submit path
/// ([`PromCounters::note_submitted`]); readers call
/// [`PromCounters::render`] for the text exposition.
#[derive(Debug, Default)]
pub struct PromCounters {
    /// Sessions submitted through the front-end (feeds the queue-depth
    /// gauge together with the retirement counters).
    submitted: AtomicU64,
    length: AtomicU64,
    stop: AtomicU64,
    cancelled: AtomicU64,
    deadline: AtomicU64,
    failed: AtomicU64,
    /// Tokens emitted by retired requests (prefill token included).
    tokens: AtomicU64,
    /// Σ (prefill_s + decode_s) over retired requests, in microseconds
    /// (an integer so it can live in an atomic; rendered as seconds).
    busy_us: AtomicU64,
    /// Retired requests that were stolen onto their executing lane.
    steals: AtomicU64,
    /// Retired requests that joined a running batch mid-flight.
    joins: AtomicU64,
    /// Submissions shed at admission (no executing lane).
    rejections: AtomicU64,
    /// Σ queue wait of executed requests, in microseconds.
    qw_sum_us: AtomicU64,
    /// Non-cumulative queue-wait histogram counts: one bucket per
    /// [`QUEUE_WAIT_BUCKETS`] bound plus a final `+Inf` overflow bin
    /// (cumulated at render time, as the exposition format requires).
    qw_buckets: [AtomicU64; QUEUE_WAIT_BUCKETS.len() + 1],
}

/// Upper bounds (seconds) of the `tsar_queue_wait_seconds` buckets.
const QUEUE_WAIT_BUCKETS: [f64; 7] = [0.001, 0.005, 0.025, 0.1, 0.5, 2.5, 10.0];

impl PromCounters {
    pub fn new() -> PromCounters {
        PromCounters::default()
    }

    /// Count one submitted session (the HTTP front-end calls this per
    /// accepted `POST /v1/generate`).
    pub fn note_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one retired request's record into the counters.
    pub fn observe(&self, rec: &RequestRecord) {
        self.by_reason(rec.finish).fetch_add(1, Ordering::Relaxed);
        self.tokens.fetch_add(rec.tokens as u64, Ordering::Relaxed);
        let busy_us = ((rec.prefill_s + rec.decode_s) * 1e6).max(0.0) as u64;
        self.busy_us.fetch_add(busy_us, Ordering::Relaxed);
        if rec.executed_lane.is_some() {
            // Scheduler provenance only exists for requests a lane
            // actually executed.
            if rec.stolen {
                self.steals.fetch_add(1, Ordering::Relaxed);
            }
            if rec.joined_midflight {
                self.joins.fetch_add(1, Ordering::Relaxed);
            }
            let wait = rec.queue_wait_s.max(0.0);
            self.qw_sum_us.fetch_add((wait * 1e6) as u64, Ordering::Relaxed);
            let bin = QUEUE_WAIT_BUCKETS
                .iter()
                .position(|&le| wait <= le)
                .unwrap_or(QUEUE_WAIT_BUCKETS.len());
            self.qw_buckets[bin].fetch_add(1, Ordering::Relaxed);
        } else if rec.finish == FinishReason::Failed {
            // Shed at admission: consistent with ServeReport::rejected.
            self.rejections.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn by_reason(&self, finish: FinishReason) -> &AtomicU64 {
        match finish {
            FinishReason::Length => &self.length,
            FinishReason::Stop => &self.stop,
            FinishReason::Cancelled => &self.cancelled,
            FinishReason::DeadlineExpired => &self.deadline,
            FinishReason::Failed => &self.failed,
        }
    }

    /// Requests retired so far (any finish reason).
    pub fn retired(&self) -> u64 {
        [&self.length, &self.stop, &self.cancelled, &self.deadline, &self.failed]
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum()
    }

    /// Sessions submitted and not yet retired.  Reads zero when
    /// retirements outnumber submissions (records can also flow from
    /// sessions submitted outside the front-end).
    pub fn queue_depth(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed).saturating_sub(self.retired())
    }

    /// Tokens emitted by retired requests so far.
    pub fn tokens_emitted(&self) -> u64 {
        self.tokens.load(Ordering::Relaxed)
    }

    /// Busy seconds accumulated by the lanes so far.
    pub fn busy_seconds(&self) -> f64 {
        self.busy_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Retired requests that were stolen onto their executing lane.
    pub fn steals_total(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    /// Retired requests that joined a running batch mid-flight.
    pub fn joins_total(&self) -> u64 {
        self.joins.load(Ordering::Relaxed)
    }

    /// Submissions shed at admission (validation or queue
    /// backpressure); always equals the shutdown report's `rejected`.
    pub fn rejections_total(&self) -> u64 {
        self.rejections.load(Ordering::Relaxed)
    }

    /// Render the Prometheus text exposition (format version 0.0.4):
    /// `# HELP`/`# TYPE` headers plus one sample line per series, every
    /// finish-reason label always present so rates are well-defined
    /// from the first scrape.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("# HELP tsar_requests_total Requests retired, by finish reason.\n");
        out.push_str("# TYPE tsar_requests_total counter\n");
        let by_finish = [
            ("length", &self.length),
            ("stop", &self.stop),
            ("cancelled", &self.cancelled),
            ("deadline", &self.deadline),
            ("failed", &self.failed),
        ];
        for (label, counter) in by_finish {
            out.push_str(&format!(
                "tsar_requests_total{{finish=\"{label}\"}} {}\n",
                counter.load(Ordering::Relaxed)
            ));
        }
        out.push_str(
            "# HELP tsar_tokens_emitted_total Tokens emitted by retired requests \
             (prefill token included).\n",
        );
        out.push_str("# TYPE tsar_tokens_emitted_total counter\n");
        out.push_str(&format!("tsar_tokens_emitted_total {}\n", self.tokens_emitted()));
        out.push_str(
            "# HELP tsar_lane_busy_seconds_total Busy seconds accumulated by the serving \
             lanes (prefill + decode of retired requests).\n",
        );
        out.push_str("# TYPE tsar_lane_busy_seconds_total counter\n");
        out.push_str(&format!("tsar_lane_busy_seconds_total {:.6}\n", self.busy_seconds()));
        out.push_str("# HELP tsar_queue_depth Sessions submitted and not yet retired.\n");
        out.push_str("# TYPE tsar_queue_depth gauge\n");
        out.push_str(&format!("tsar_queue_depth {}\n", self.queue_depth()));
        out.push_str(
            "# HELP tsar_steals_total Retired requests stolen onto their executing lane \
             (work stealing).\n",
        );
        out.push_str("# TYPE tsar_steals_total counter\n");
        out.push_str(&format!("tsar_steals_total {}\n", self.steals_total()));
        out.push_str(
            "# HELP tsar_joins_midflight_total Retired requests that joined a running \
             batch mid-flight (continuous batching).\n",
        );
        out.push_str("# TYPE tsar_joins_midflight_total counter\n");
        out.push_str(&format!("tsar_joins_midflight_total {}\n", self.joins_total()));
        out.push_str(
            "# HELP tsar_rejections_total Submissions shed at admission (validation or \
             queue backpressure), never executed on a lane.\n",
        );
        out.push_str("# TYPE tsar_rejections_total counter\n");
        out.push_str(&format!("tsar_rejections_total {}\n", self.rejections_total()));
        out.push_str(
            "# HELP tsar_queue_wait_seconds Admission-queue wait (arrival to scheduler \
             pull) of executed requests.\n",
        );
        out.push_str("# TYPE tsar_queue_wait_seconds histogram\n");
        let mut cumulative = 0u64;
        for (i, le) in QUEUE_WAIT_BUCKETS.iter().enumerate() {
            cumulative += self.qw_buckets[i].load(Ordering::Relaxed);
            out.push_str(&format!("tsar_queue_wait_seconds_bucket{{le=\"{le}\"}} {cumulative}\n"));
        }
        cumulative += self.qw_buckets[QUEUE_WAIT_BUCKETS.len()].load(Ordering::Relaxed);
        out.push_str(&format!("tsar_queue_wait_seconds_bucket{{le=\"+Inf\"}} {cumulative}\n"));
        out.push_str(&format!(
            "tsar_queue_wait_seconds_sum {:.6}\n",
            self.qw_sum_us.load(Ordering::Relaxed) as f64 / 1e6
        ));
        out.push_str(&format!("tsar_queue_wait_seconds_count {cumulative}\n"));
        out
    }
}

/// Background aggregator over a [`RequestRecord`] channel: the
/// Prometheus counterpart of the JSONL [`super::Exporter`], updating
/// [`PromCounters`] live while the run is in flight.
///
/// Drop every sender of the channel (the engine handle included) and
/// call [`PromAggregator::finish`] to join the thread and get the
/// record count; [`PromAggregator::counters`] hands out the shared
/// counter set to scrape handlers at any point before or after.
pub struct PromAggregator {
    counters: Arc<PromCounters>,
    worker: JoinHandle<usize>,
}

impl PromAggregator {
    /// Spawn the aggregator thread over `rx`.
    pub fn spawn(rx: Receiver<RequestRecord>) -> PromAggregator {
        let counters = Arc::new(PromCounters::new());
        let shared = Arc::clone(&counters);
        let worker = std::thread::spawn(move || {
            let mut observed = 0usize;
            while let Ok(rec) = rx.recv() {
                shared.observe(&rec);
                observed += 1;
            }
            observed
        });
        PromAggregator { counters, worker }
    }

    /// The shared counter set (what `GET /metrics` renders).
    pub fn counters(&self) -> Arc<PromCounters> {
        Arc::clone(&self.counters)
    }

    /// Join the aggregator thread (blocks until every sender of the
    /// record channel is dropped) and return how many records it
    /// observed.
    pub fn finish(self) -> usize {
        self.worker.join().expect("prometheus aggregator thread panicked")
    }
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc::channel;

    use super::*;

    fn record(finish: FinishReason, tokens: usize) -> RequestRecord {
        RequestRecord {
            id: 0,
            lane: Some(0),
            executed_lane: Some(0),
            queue_s: 0.05,
            queue_wait_s: 0.05,
            prefill_s: 0.25,
            decode_s: 0.75,
            total_s: 1.05,
            tokens,
            finish,
            stolen: false,
            joined_midflight: false,
            plan: None,
        }
    }

    fn rejection() -> RequestRecord {
        RequestRecord {
            id: 9,
            lane: None,
            executed_lane: None,
            queue_s: 0.0,
            queue_wait_s: 0.0,
            prefill_s: 0.0,
            decode_s: 0.0,
            total_s: 0.0,
            tokens: 0,
            finish: FinishReason::Failed,
            stolen: false,
            joined_midflight: false,
            plan: None,
        }
    }

    #[test]
    fn counters_aggregate_and_render() {
        let c = PromCounters::new();
        c.note_submitted();
        c.note_submitted();
        c.note_submitted();
        c.observe(&record(FinishReason::Length, 4));
        c.observe(&record(FinishReason::Cancelled, 2));
        assert_eq!(c.retired(), 2);
        assert_eq!(c.queue_depth(), 1);
        assert_eq!(c.tokens_emitted(), 6);
        assert!((c.busy_seconds() - 2.0).abs() < 1e-5);

        let text = c.render();
        assert!(text.contains("# TYPE tsar_requests_total counter"));
        assert!(text.contains("tsar_requests_total{finish=\"length\"} 1"), "got:\n{text}");
        assert!(text.contains("tsar_requests_total{finish=\"cancelled\"} 1"));
        assert!(text.contains("tsar_requests_total{finish=\"failed\"} 0"));
        assert!(text.contains("tsar_tokens_emitted_total 6"));
        assert!(text.contains("tsar_lane_busy_seconds_total 2.000000"));
        assert!(text.contains("# TYPE tsar_queue_depth gauge"));
        assert!(text.contains("tsar_queue_depth 1"));
    }

    #[test]
    fn scheduler_series_render_and_rejections_retire() {
        let c = PromCounters::new();
        c.note_submitted();
        c.note_submitted();
        let mut stolen = record(FinishReason::Length, 4);
        stolen.stolen = true;
        stolen.joined_midflight = true;
        stolen.queue_wait_s = 0.003; // lands in the le="0.005" bucket
        c.observe(&stolen);
        c.observe(&rejection());

        assert_eq!(c.steals_total(), 1);
        assert_eq!(c.joins_total(), 1);
        assert_eq!(c.rejections_total(), 1, "shed counted once, not as scheduler work");
        // The fix under test: a rejection retires its session, so the
        // gauge returns to zero instead of leaking a phantom in-flight
        // request (consistent with ServeReport::rejected).
        assert_eq!(c.queue_depth(), 0);

        let text = c.render();
        assert!(text.contains("# TYPE tsar_steals_total counter"));
        assert!(text.contains("tsar_steals_total 1"), "got:\n{text}");
        assert!(text.contains("tsar_joins_midflight_total 1"));
        assert!(text.contains("tsar_rejections_total 1"));
        assert!(text.contains("# TYPE tsar_queue_wait_seconds histogram"));
        // Cumulative buckets: nothing under 1 ms, everything from 5 ms up.
        assert!(text.contains("tsar_queue_wait_seconds_bucket{le=\"0.001\"} 0"));
        assert!(text.contains("tsar_queue_wait_seconds_bucket{le=\"0.005\"} 1"));
        assert!(text.contains("tsar_queue_wait_seconds_bucket{le=\"10\"} 1"));
        assert!(text.contains("tsar_queue_wait_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("tsar_queue_wait_seconds_sum 0.003000"));
        assert!(text.contains("tsar_queue_wait_seconds_count 1"));
        // The rejection contributes no histogram sample (it never
        // waited on an executing lane's behalf).
    }

    #[test]
    fn queue_wait_overflow_lands_in_the_inf_bucket() {
        let c = PromCounters::new();
        let mut slow = record(FinishReason::Length, 1);
        slow.queue_wait_s = 99.0;
        c.observe(&slow);
        let text = c.render();
        assert!(text.contains("tsar_queue_wait_seconds_bucket{le=\"10\"} 0"));
        assert!(text.contains("tsar_queue_wait_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("tsar_queue_wait_seconds_count 1"));
    }

    #[test]
    fn queue_depth_saturates_instead_of_underflowing() {
        // Records from sessions that never went through note_submitted
        // (e.g. legacy batch submissions) must not wrap the gauge.
        let c = PromCounters::new();
        c.observe(&record(FinishReason::Length, 1));
        assert_eq!(c.queue_depth(), 0);
    }

    #[test]
    fn aggregator_drains_the_channel() {
        let (tx, rx) = channel();
        let agg = PromAggregator::spawn(rx);
        let counters = agg.counters();
        tx.send(record(FinishReason::Length, 3)).unwrap();
        tx.send(record(FinishReason::Failed, 0)).unwrap();
        drop(tx);
        assert_eq!(agg.finish(), 2);
        assert_eq!(counters.retired(), 2);
        assert_eq!(counters.tokens_emitted(), 3);
    }

    /// Pull the rendered `tsar_queue_wait_seconds_bucket` values, in
    /// exposition order (`le` ascending, `+Inf` last).
    fn bucket_values(text: &str) -> Vec<u64> {
        text.lines()
            .filter(|l| l.starts_with("tsar_queue_wait_seconds_bucket{"))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect()
    }

    fn series_value(text: &str, name: &str) -> u64 {
        text.lines()
            .find(|l| l.starts_with(name) && l.as_bytes().get(name.len()) == Some(&b' '))
            .and_then(|l| l.rsplit(' ').next())
            .unwrap_or_else(|| panic!("series {name} missing"))
            .parse()
            .unwrap()
    }

    #[test]
    fn rendered_buckets_cumulate_monotonically_and_inf_equals_count() {
        // One sample per bin boundary region, plus an overflow, so the
        // cumulation logic is exercised across every window.
        let c = PromCounters::new();
        for wait in [0.0005, 0.003, 0.02, 0.08, 0.4, 2.0, 9.0, 50.0] {
            let mut r = record(FinishReason::Length, 1);
            r.queue_wait_s = wait;
            c.observe(&r);
        }
        let text = c.render();
        let buckets = bucket_values(&text);
        // Seven finite bounds plus +Inf.
        assert_eq!(buckets.len(), QUEUE_WAIT_BUCKETS.len() + 1, "got:\n{text}");
        for w in buckets.windows(2) {
            assert!(w[0] <= w[1], "buckets must be cumulative: {buckets:?}");
        }
        // Every observation lands somewhere: +Inf is the total, and the
        // histogram's _count agrees with it exactly.
        assert_eq!(*buckets.last().unwrap(), 8);
        assert_eq!(series_value(&text, "tsar_queue_wait_seconds_count"), 8);
    }

    #[test]
    fn concurrent_recording_keeps_sum_count_and_buckets_consistent() {
        // Four writer threads race 100 observations each into the shared
        // counters.  The waits are binary-exact fractions so the µs sum
        // accumulates without rounding: the final render must balance to
        // the closed-form totals regardless of interleaving.
        let c = PromCounters::new();
        let waits = [0.25, 0.5, 2.0, 8.0];
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for i in 0..100 {
                        let mut r = record(FinishReason::Length, 1);
                        r.queue_wait_s = waits[i % waits.len()];
                        c.observe(&r);
                    }
                });
            }
        });
        assert_eq!(c.retired(), 400);
        assert_eq!(c.tokens_emitted(), 400);

        let text = c.render();
        // 0.25 and 0.5 share the le="0.5" window; 2.0 lands in le="2.5";
        // 8.0 in le="10".  Σ wait = 100 × (0.25 + 0.5 + 2 + 8) = 1075 s.
        assert!(text.contains("tsar_queue_wait_seconds_bucket{le=\"0.1\"} 0"), "got:\n{text}");
        assert!(text.contains("tsar_queue_wait_seconds_bucket{le=\"0.5\"} 200"));
        assert!(text.contains("tsar_queue_wait_seconds_bucket{le=\"2.5\"} 300"));
        assert!(text.contains("tsar_queue_wait_seconds_bucket{le=\"10\"} 400"));
        assert!(text.contains("tsar_queue_wait_seconds_bucket{le=\"+Inf\"} 400"));
        assert!(text.contains("tsar_queue_wait_seconds_sum 1075.000000"));
        assert!(text.contains("tsar_queue_wait_seconds_count 400"));
        assert!(text.contains("tsar_requests_total{finish=\"length\"} 400"));
    }
}
