//! The request-level metrics endpoint (ROADMAP item, export half):
//! [`Exporter`] sits on the receiving side of the server's
//! [`RequestRecord`] channel and writes one JSON line per retired
//! request to a file or stdout, while the run is still in flight —
//! `tsar-cli serve --metrics <path|->` wires it up.
//!
//! The exporter runs on its own thread so a slow disk never back-
//! pressures the serving lanes (the record channel is unbounded and
//! sends are best-effort).  Drop every sender (server/engine included)
//! and call [`Exporter::finish`] to flush and get the record count.

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::mpsc::{Receiver, Sender};
use std::thread::JoinHandle;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

use super::metrics::RequestRecord;

/// Fan one [`RequestRecord`] channel out to two sinks — e.g. the JSONL
/// [`Exporter`] *and* the Prometheus [`super::PromAggregator`] at the
/// same time (`tsar-cli serve --http ... --metrics ...`).  Forwarding
/// is best-effort like every record send: a dropped sink never stalls
/// the other, and the thread exits when the input channel closes.
pub fn tee_records(
    rx: Receiver<RequestRecord>,
    a: Sender<RequestRecord>,
    b: Sender<RequestRecord>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        while let Ok(rec) = rx.recv() {
            let _ = a.send(rec.clone());
            let _ = b.send(rec);
        }
    })
}

/// Serialize one record as a flat JSON object (stable keys, seconds as
/// f64, `finish` as its lower-case label, `lane`/`executed_lane` null
/// for submissions rejected before reaching a lane; scheduler
/// provenance as `queue_wait_s`, `stolen`, `joined_midflight`).
pub fn record_to_json(rec: &RequestRecord) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("id".into(), Json::Num(rec.id as f64));
    obj.insert(
        "lane".into(),
        match rec.lane {
            Some(l) => Json::Num(l as f64),
            None => Json::Null,
        },
    );
    obj.insert(
        "executed_lane".into(),
        match rec.executed_lane {
            Some(l) => Json::Num(l as f64),
            None => Json::Null,
        },
    );
    obj.insert("queue_s".into(), Json::Num(rec.queue_s));
    obj.insert("queue_wait_s".into(), Json::Num(rec.queue_wait_s));
    obj.insert("stolen".into(), Json::Bool(rec.stolen));
    obj.insert("joined_midflight".into(), Json::Bool(rec.joined_midflight));
    obj.insert("prefill_s".into(), Json::Num(rec.prefill_s));
    obj.insert("decode_s".into(), Json::Num(rec.decode_s));
    obj.insert("total_s".into(), Json::Num(rec.total_s));
    obj.insert("tokens".into(), Json::Num(rec.tokens as f64));
    obj.insert("finish".into(), Json::Str(rec.finish.label().into()));
    obj.insert(
        "plan".into(),
        match &rec.plan {
            Some(p) => Json::Str(p.clone()),
            None => Json::Null,
        },
    );
    Json::Obj(obj)
}

/// Background JSONL writer over a [`RequestRecord`] channel.
pub struct Exporter {
    worker: JoinHandle<Result<usize>>,
}

impl Exporter {
    /// Spawn an exporter writing to `target`: `"-"` means stdout,
    /// anything else is created/truncated as a file.  Returns once the
    /// sink (and, for files, the handle) is ready, so a bad path fails
    /// here and not mid-run.
    pub fn spawn(rx: Receiver<RequestRecord>, target: &str) -> Result<Exporter> {
        let writer: Box<dyn Write + Send> = if target == "-" {
            Box::new(std::io::stdout())
        } else {
            let file = std::fs::File::create(target)
                .with_context(|| format!("cannot create metrics file {target:?}"))?;
            Box::new(std::io::BufWriter::new(file))
        };
        Ok(Exporter::spawn_to(rx, writer))
    }

    /// Spawn over any writer (tests use an in-memory buffer).
    pub fn spawn_to(rx: Receiver<RequestRecord>, mut writer: Box<dyn Write + Send>) -> Exporter {
        let worker = std::thread::spawn(move || {
            let mut written = 0usize;
            while let Ok(rec) = rx.recv() {
                let line = record_to_json(&rec).to_string();
                writeln!(writer, "{line}").context("metrics write failed")?;
                written += 1;
            }
            writer.flush().context("metrics flush failed")?;
            Ok(written)
        });
        Exporter { worker }
    }

    /// Join the writer thread (blocks until every sender of the record
    /// channel is dropped) and return how many records were written.
    pub fn finish(self) -> Result<usize> {
        self.worker.join().expect("metrics exporter thread panicked")
    }
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc::channel;
    use std::sync::{Arc, Mutex};

    use super::*;
    use crate::coordinator::request::FinishReason;

    /// `Write` into a shared Vec so the test can inspect what the
    /// exporter thread produced.
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn record(id: u64, finish: FinishReason) -> RequestRecord {
        RequestRecord {
            id,
            lane: Some(1),
            executed_lane: Some(1),
            queue_s: 0.25,
            queue_wait_s: 0.125,
            prefill_s: 0.5,
            decode_s: 1.5,
            total_s: 2.25,
            tokens: 4,
            finish,
            stolen: true,
            joined_midflight: false,
            plan: Some("wqkv:TSAR".into()),
        }
    }

    #[test]
    fn writes_one_json_line_per_record() {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let (tx, rx) = channel();
        let exporter = Exporter::spawn_to(rx, Box::new(SharedBuf(Arc::clone(&buf))));
        tx.send(record(0, FinishReason::Length)).unwrap();
        tx.send(record(1, FinishReason::Cancelled)).unwrap();
        drop(tx);
        assert_eq!(exporter.finish().unwrap(), 2);

        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).expect("valid JSON");
        assert_eq!(first.get("id").and_then(Json::as_usize), Some(0));
        assert_eq!(first.get("lane").and_then(Json::as_usize), Some(1));
        assert_eq!(first.get("executed_lane").and_then(Json::as_usize), Some(1));
        assert_eq!(first.get("queue_wait_s").and_then(Json::as_f64), Some(0.125));
        assert_eq!(first.get("stolen"), Some(&Json::Bool(true)));
        assert_eq!(first.get("joined_midflight"), Some(&Json::Bool(false)));
        assert_eq!(first.get("tokens").and_then(Json::as_usize), Some(4));
        assert_eq!(
            first.get("finish").and_then(Json::as_str),
            Some("length")
        );
        assert_eq!(
            first.get("plan").and_then(Json::as_str),
            Some("wqkv:TSAR")
        );
        let second = Json::parse(lines[1]).expect("valid JSON");
        assert_eq!(
            second.get("finish").and_then(Json::as_str),
            Some("cancelled")
        );
    }

    #[test]
    fn file_target_round_trips_and_bad_path_errors() {
        let path = std::env::temp_dir().join("tsar_exporter_test.jsonl");
        let path_s = path.to_str().unwrap().to_string();
        let (tx, rx) = channel();
        let exporter = Exporter::spawn(rx, &path_s).unwrap();
        tx.send(record(7, FinishReason::Stop)).unwrap();
        drop(tx);
        assert_eq!(exporter.finish().unwrap(), 1);
        let text = std::fs::read_to_string(&path).unwrap();
        let rec = Json::parse(text.trim()).unwrap();
        assert_eq!(rec.get("id").and_then(Json::as_usize), Some(7));
        assert_eq!(rec.get("finish").and_then(Json::as_str), Some("stop"));
        std::fs::remove_file(&path).ok();

        let (_tx2, rx2) = channel();
        assert!(Exporter::spawn(rx2, "/nonexistent-dir/x/metrics.jsonl").is_err());
    }

    #[test]
    fn tee_feeds_both_sinks_and_survives_a_dropped_one() {
        let (in_tx, in_rx) = channel();
        let (a_tx, a_rx) = channel();
        let (b_tx, b_rx) = channel();
        let tee = tee_records(in_rx, a_tx, b_tx);
        in_tx.send(record(0, FinishReason::Length)).unwrap();
        assert_eq!(a_rx.recv().unwrap().id, 0);
        assert_eq!(b_rx.recv().unwrap().id, 0);
        // One sink hangs up: the other must keep receiving.
        drop(a_rx);
        in_tx.send(record(1, FinishReason::Stop)).unwrap();
        assert_eq!(b_rx.recv().unwrap().id, 1);
        drop(in_tx);
        tee.join().unwrap();
    }
}
