//! Zero-dependency HTTP/1.1 front-end over the streaming engine
//! (DESIGN.md §3): a `std::net::TcpListener` accept loop feeding a
//! small worker-thread pool, turning network clients into engine
//! sessions.  No external crates — the offline build constraint that
//! shaped the rest of the stack applies to the serving surface too.
//!
//! Routes:
//!
//! * `POST /v1/generate` — JSON body `{"prompt": [i32...],
//!   "max_new_tokens": N, "stop_tokens": [i32...]?, "deadline_ms": M?}`
//!   is submitted through [`EngineHandle::submit_classified`]; the
//!   response streams **one JSON line per [`TokenEvent`]** (NDJSON over
//!   chunked transfer-encoding) as decode rounds land — every line
//!   carries the engine-assigned `id` (the first line is how a client
//!   learns it, e.g. to target `POST /v1/cancel`) — ending with the
//!   terminal event (`retired` / `cancelled` / `failed`, carrying the
//!   full [`super::RequestResult`] fields).  Backpressure shedding
//!   (the bounded admission queue at [`super::ServerConfig::queue_cap`])
//!   is answered `429 Too Many Requests` instead of a stream, so
//!   open-loop clients can tell shed from failure; admission
//!   *validation* failures still stream their single `failed` terminal
//!   line (HTTP 200 — the request was understood, the engine refused
//!   it).  A client that disconnects mid-stream cancels its session
//!   ([`Ticket::cancel`]) at the next round boundary, freeing the
//!   KV/batch slot for the next request.
//! * `POST /v1/cancel` — JSON body `{"id": N}` cancels the in-flight
//!   generation stream with that engine id (on *any* connection) at
//!   the next round boundary: `200` if the stream was live, `404` if
//!   the id is unknown or already retired.  The cancelled stream itself
//!   ends with its `cancelled` terminal line as usual.
//! * `GET /metrics` — the Prometheus text exposition rendered from the
//!   shared [`PromCounters`] (see [`super::prom`] for the schema).
//! * `GET /healthz` — liveness probe (`200 ok`).
//!
//! Connections are **HTTP/1.1 keep-alive** by default: a client (e.g.
//! a load balancer holding one multiplexed socket) issues any number
//! of sequential requests per connection, and pipelined
//! `POST /v1/generate` requests already buffered are submitted to the
//! engine *together* — their generations run concurrently across the
//! lanes while the responses stream back in request order — up to
//! [`HttpConfig::max_streams_per_conn`] concurrent in-flight streams;
//! excess pipelined generates are answered `503` ("too many concurrent
//! streams").  `Connection: close` (or HTTP/1.0 without keep-alive)
//! restores one-exchange-per-connection behavior.  A mid-stream
//! disconnect on a keep-alive connection cancels only the affected
//! tickets — sessions on other connections are untouched.
//!
//! Lifecycle: [`HttpServer::start`] binds and spawns the acceptor plus
//! `threads` connection workers; [`HttpServer::stop`] closes admission
//! (no new connections) and joins the workers, draining in-flight
//! streams to their terminal events.  The engine handle is shared as
//! an `Arc` so that, after `stop`, the caller can unwrap it and run
//! [`EngineHandle::shutdown`] for the merged [`super::ServeReport`] —
//! the scrape counters and the report agree on outcome counts by
//! construction (both fold the same retirement stream).

use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::runtime::Backend;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

use super::engine::{CancelHandle, EngineHandle, SubmitError, Ticket};
use super::prom::PromCounters;
use super::request::{GenParams, GenerationRequest, RequestId, TokenEvent};

/// In-flight generation streams by engine request id, shared across
/// the connection workers so `POST /v1/cancel` can reach a stream
/// started on any connection.  Entries are inserted at admission and
/// removed as streams end, so a hit means the request may still be
/// cancellable (the engine treats a late cancel as a no-op anyway).
type CancelRegistry = Mutex<HashMap<RequestId, CancelHandle>>;

/// Tunables of the HTTP front-end.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Connection worker threads (each handles one connection at a
    /// time; a streaming generation occupies its worker until the
    /// terminal event).
    pub threads: usize,
    /// Request-head cap (request line + headers).
    pub max_head_bytes: usize,
    /// Request-body cap.
    pub max_body_bytes: usize,
    /// Per-connection read timeout (a silent client must not pin a
    /// worker forever).
    pub read_timeout: Duration,
    /// Accepted connections waiting for a free worker.  When every
    /// worker is busy and the backlog is full, further connections are
    /// answered `503` and dropped instead of queueing file descriptors
    /// without bound.
    pub backlog: usize,
    /// Concurrent in-flight generation streams one keep-alive
    /// connection may multiplex: pipelined `POST /v1/generate`
    /// requests beyond this are answered `503` instead of submitted.
    pub max_streams_per_conn: usize,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            threads: 4,
            max_head_bytes: 16 * 1024,
            max_body_bytes: 1024 * 1024,
            read_timeout: Duration::from_secs(30),
            backlog: 64,
            max_streams_per_conn: 4,
        }
    }
}

/// A running HTTP front-end: the acceptor thread, its connection
/// workers, and the bound address.
pub struct HttpServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:8080"`; port `0` picks a free
    /// port — see [`HttpServer::local_addr`]) and start serving the
    /// engine behind `engine`.  `counters` backs `GET /metrics`; keep
    /// the [`super::PromAggregator`] that owns them draining the
    /// engine's record channel, or the scrape stays at zero.
    pub fn start<B>(
        addr: &str,
        engine: Arc<EngineHandle<B>>,
        counters: Arc<PromCounters>,
        cfg: HttpConfig,
    ) -> Result<HttpServer>
    where
        B: Backend + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("cannot bind HTTP listener on {addr:?}"))?;
        let local_addr = listener.local_addr().context("listener has no local address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let (conn_tx, conn_rx) = sync_channel::<TcpStream>(cfg.backlog.max(1));
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let cancels: Arc<CancelRegistry> = Arc::new(Mutex::new(HashMap::new()));
        let workers = (0..cfg.threads.max(1))
            .map(|_| {
                let conn_rx = Arc::clone(&conn_rx);
                let engine = Arc::clone(&engine);
                let counters = Arc::clone(&counters);
                let cancels = Arc::clone(&cancels);
                let cfg = cfg.clone();
                std::thread::spawn(move || {
                    worker_loop(&conn_rx, &engine, &counters, &cancels, &cfg)
                })
            })
            .collect();
        let acceptor = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break; // the stop() wake-up connection lands here
                    }
                    match conn {
                        Ok(stream) => match conn_tx.try_send(stream) {
                            Ok(()) => {}
                            Err(TrySendError::Full(mut stream)) => {
                                // Every worker busy, backlog full: shed
                                // load instead of queueing fds without
                                // bound.
                                let _ = write_response(
                                    &mut stream,
                                    503,
                                    TEXT_PLAIN,
                                    "server busy\n",
                                    true,
                                );
                            }
                            Err(TrySendError::Disconnected(_)) => break,
                        },
                        Err(_) => {
                            // accept() can fail persistently (EMFILE
                            // under fd exhaustion): back off instead
                            // of busy-spinning the acceptor core.
                            std::thread::sleep(Duration::from_millis(50));
                        }
                    }
                }
                // conn_tx drops here: idle workers see a closed queue
                // and exit.
            })
        };
        Ok(HttpServer { local_addr, stop, acceptor: Some(acceptor), workers })
    }

    /// The bound address (resolves the port when started on port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting connections and join the workers.  In-flight
    /// streaming responses run to their terminal event first — stopping
    /// the front-end never truncates a generation mid-stream.
    pub fn stop(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the acceptor's blocking accept with a throwaway
        // connection; it observes the flag before handling it.  Bound
        // with a timeout, and via loopback when the listener sits on a
        // wildcard address — connecting to 0.0.0.0 hangs or errors on
        // some platforms.
        let woke =
            TcpStream::connect_timeout(&wake_addr(self.local_addr), Duration::from_secs(1))
                .is_ok();
        if woke {
            if let Some(acceptor) = self.acceptor.take() {
                let _ = acceptor.join();
            }
            for worker in self.workers.drain(..) {
                let _ = worker.join();
            }
        } else {
            // The wake-up never landed (e.g. a firewalled interface):
            // the acceptor is still blocked in accept() and the workers
            // on its queue.  Detach them rather than hang the caller —
            // they exit with the process.
            let _ = self.acceptor.take();
            self.workers.clear();
        }
    }
}

/// Where to connect to wake the acceptor: the bound address, with
/// wildcard IPs (`0.0.0.0` / `::`) rewritten to the matching loopback.
fn wake_addr(bound: SocketAddr) -> SocketAddr {
    let mut addr = bound;
    if addr.ip().is_unspecified() {
        addr.set_ip(match addr.ip() {
            IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
            IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
        });
    }
    addr
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// One worker: pull connections off the shared queue until the
/// acceptor closes it.  The queue mutex is held only while blocked on
/// `recv`, so handling a long streaming response never starves the
/// other workers of the queue.
fn worker_loop<B: Backend>(
    conn_rx: &Mutex<Receiver<TcpStream>>,
    engine: &EngineHandle<B>,
    counters: &PromCounters,
    cancels: &CancelRegistry,
    cfg: &HttpConfig,
) {
    loop {
        let conn = {
            let queue = conn_rx.lock().expect("http connection queue poisoned");
            queue.recv()
        };
        match conn {
            Ok(stream) => handle_connection(stream, engine, counters, cancels, cfg),
            Err(_) => break, // acceptor gone: server is stopping
        }
    }
}

/// Everything parsed from one request: the line, the path without its
/// query string, the body, and whether the client wants the connection
/// kept open afterwards (HTTP/1.1 defaults to keep-alive, HTTP/1.0 to
/// close; an explicit `Connection:` header overrides either default).
struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
    keep_alive: bool,
}

/// Serve one connection: a keep-alive loop of parse → route → respond.
/// `buf` carries bytes read past the current request (pipelined
/// requests) into the next iteration.  The loop ends when the client
/// asks for `Connection: close`, goes away, or a framing error makes
/// the byte stream unparseable.
fn handle_connection<B: Backend>(
    mut stream: TcpStream,
    engine: &EngineHandle<B>,
    counters: &PromCounters,
    cancels: &CancelRegistry,
    cfg: &HttpConfig,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(cfg.read_timeout));
    let mut buf: Vec<u8> = Vec::new();
    let mut served = 0usize;
    loop {
        let request = match read_request(&mut stream, &mut buf, cfg) {
            Ok(Some(request)) => request,
            Ok(None) => return, // clean close between requests
            Err(e) => {
                // Garbage, an oversized head, or a read timeout.
                // Answer 400 only when the client actually sent
                // something on this exchange; an idle keep-alive
                // connection timing out just closes.
                if served == 0 || !buf.is_empty() {
                    let _ = write_response(
                        &mut stream,
                        400,
                        TEXT_PLAIN,
                        &format!("bad request: {e}\n"),
                        true,
                    );
                }
                return;
            }
        };
        let keep = request.keep_alive;
        if request.method == "POST" && request.path == "/v1/generate" {
            // The generate handler owns the request (and may pull more
            // pipelined generates out of `buf`).
            if !handle_generate(&mut stream, engine, counters, cancels, request, &mut buf, cfg) {
                return;
            }
            served += 1;
            continue;
        }
        match (request.method.as_str(), request.path.as_str()) {
            ("GET", "/healthz") => {
                let _ = write_response(&mut stream, 200, TEXT_PLAIN, "ok\n", !keep);
            }
            ("GET", "/metrics") => {
                let _ = write_response(&mut stream, 200, PROM_TEXT, &counters.render(), !keep);
            }
            ("POST", "/v1/cancel") => {
                let (code, body) = match parse_cancel(&request.body) {
                    Ok(id) => {
                        let handle = {
                            let live = cancels.lock().expect("cancel registry poisoned");
                            live.get(&id).cloned()
                        };
                        match handle {
                            Some(handle) => {
                                handle.cancel();
                                (200, format!("cancelling {id}\n"))
                            }
                            None => (404, format!("unknown or already retired id {id}\n")),
                        }
                    }
                    Err(e) => (400, format!("bad request: {e}\n")),
                };
                let _ = write_response(&mut stream, code, TEXT_PLAIN, &body, !keep);
            }
            (_, "/healthz") | (_, "/metrics") => {
                let _ = write_response(&mut stream, 405, TEXT_PLAIN, "use GET\n", !keep);
            }
            (_, "/v1/generate") | (_, "/v1/cancel") => {
                let _ = write_response(&mut stream, 405, TEXT_PLAIN, "use POST\n", !keep);
            }
            _ => {
                let _ = write_response(&mut stream, 404, TEXT_PLAIN, "not found\n", !keep);
            }
        }
        served += 1;
        if !keep {
            return;
        }
    }
}

/// One response owed on the connection, in request order.
enum Reply {
    /// Malformed generate body: `400` carrying the parse error.
    BadBody(String),
    /// Pipelined past [`HttpConfig::max_streams_per_conn`]: `503`.
    Shed,
    /// Shed by the engine's bounded admission queue
    /// ([`SubmitError::QueueFull`]): `429` so clients distinguish
    /// backpressure from failure.
    QueueFull { cap: usize },
    /// An admitted session streaming its token events.
    Stream(Ticket),
}

/// `POST /v1/generate`, keep-alive aware: gather the pipelined
/// generates already buffered on this connection, admit up to
/// [`HttpConfig::max_streams_per_conn`] of them (the rest are shed
/// with `503 too many concurrent streams`), submit the admitted ones
/// *before* streaming anything — their generations run concurrently
/// across the lanes — then write the responses back strictly in
/// request order.  Returns whether the connection stays open.
fn handle_generate<B: Backend>(
    stream: &mut TcpStream,
    engine: &EngineHandle<B>,
    counters: &PromCounters,
    cancels: &CancelRegistry,
    first: HttpRequest,
    buf: &mut Vec<u8>,
    cfg: &HttpConfig,
) -> bool {
    let cap = cfg.max_streams_per_conn.max(1);
    let mut requests = vec![first];
    // Gathering never blocks on the socket: only requests whose bytes
    // already arrived join the batch, so a one-at-a-time client keeps
    // plain sequential keep-alive semantics.  `Connection: close` on a
    // request makes it the connection's last.
    while requests.last().is_some_and(|r| r.keep_alive) {
        match take_buffered_generate(buf, cfg) {
            Some(next) => requests.push(next),
            None => break,
        }
    }
    let keep = requests.last().is_some_and(|r| r.keep_alive);
    let mut admitted = 0usize;
    let replies: Vec<Reply> = requests
        .iter()
        .map(|req| match parse_generate(&req.body) {
            Ok(gen) if admitted < cap => {
                admitted += 1;
                counters.note_submitted();
                let (ticket, refused) = engine.submit_classified(gen);
                if let Some(SubmitError::QueueFull { cap }) = refused {
                    // The engine already booked the shed (report
                    // `rejected`, `tsar_rejections_total`); dropping
                    // the resolved ticket is safe.  Answer 429 so the
                    // client can retry instead of reading a stream.
                    Reply::QueueFull { cap }
                } else {
                    // Admitted — or Invalid, which keeps today's
                    // contract: an HTTP 200 stream whose single line
                    // is the `failed` terminal event.
                    let mut live = cancels.lock().expect("cancel registry poisoned");
                    live.insert(ticket.id(), ticket.cancel_handle());
                    Reply::Stream(ticket)
                }
            }
            Ok(_) => Reply::Shed,
            Err(e) => Reply::BadBody(e.to_string()),
        })
        .collect();
    let mut all_ok = true;
    for (i, reply) in replies.iter().enumerate() {
        // Only the connection's very last response announces the close.
        let close = !keep && i + 1 == replies.len();
        let ok = match reply {
            Reply::BadBody(e) => {
                write_response(stream, 400, TEXT_PLAIN, &format!("bad request: {e}\n"), close)
                    .is_ok()
            }
            Reply::Shed => {
                write_response(stream, 503, TEXT_PLAIN, "too many concurrent streams\n", close)
                    .is_ok()
            }
            Reply::QueueFull { cap } => {
                let body = format!("queue full (queue_cap {cap})\n");
                write_response(stream, 429, TEXT_PLAIN, &body, close).is_ok()
            }
            Reply::Stream(ticket) => {
                let ok = stream_ticket(stream, ticket, close);
                cancels.lock().expect("cancel registry poisoned").remove(&ticket.id());
                ok
            }
        };
        if !ok {
            // The client went away: stop paying for tokens nobody
            // reads.  Cancel this stream and every session still
            // queued behind the dead socket; the lanes retire them
            // (Cancelled, KV slots freed) at the next round boundary.
            // Sessions on other connections are untouched.
            for later in &replies[i..] {
                if let Reply::Stream(ticket) = later {
                    cancel_and_drain(ticket);
                    cancels.lock().expect("cancel registry poisoned").remove(&ticket.id());
                }
            }
            all_ok = false;
            break;
        }
    }
    keep && all_ok
}

/// Stream one ticket's token events as chunked NDJSON.  Returns
/// `false` when the client socket died mid-stream (the caller cancels
/// the affected sessions).
fn stream_ticket(stream: &mut TcpStream, ticket: &Ticket, close: bool) -> bool {
    if write_stream_head(stream, close).is_err() {
        return false;
    }
    let mut wrote_terminal = false;
    while let Some(ev) = ticket.recv() {
        let terminal = ev.result().is_some();
        let mut line = event_json(ticket.id(), &ev).to_string();
        line.push('\n');
        if write_chunk(stream, line.as_bytes()).is_err() {
            return false;
        }
        if terminal {
            wrote_terminal = true;
            break;
        }
    }
    if !wrote_terminal {
        // The ticket stream closed without a terminal event (the
        // serving lane died mid-session).  The response contract is
        // one terminal line per stream, so emit the same synthesized
        // `Failed` result `Ticket::join` reports for this case.
        let ev = TokenEvent::Failed(ticket.closed_result());
        let mut line = event_json(ticket.id(), &ev).to_string();
        line.push('\n');
        if write_chunk(stream, line.as_bytes()).is_err() {
            return false;
        }
    }
    write_last_chunk(stream).is_ok()
}

/// Cancel a session whose client disconnected and drain its stream so
/// the terminal event is consumed (and counted by the record sink)
/// before the worker moves on.
fn cancel_and_drain(ticket: &Ticket) {
    ticket.cancel();
    while ticket.recv().is_some() {}
}

/// Parse the `POST /v1/generate` body into a [`GenerationRequest`].
fn parse_generate(body: &[u8]) -> Result<GenerationRequest> {
    let text = std::str::from_utf8(body).map_err(|_| crate::err!("body is not UTF-8"))?;
    let json = Json::parse(text).map_err(|e| crate::err!("body is not valid JSON: {e}"))?;
    let prompt = json
        .req("prompt")?
        .as_arr()
        .context("\"prompt\" must be an array of token ids")?
        .iter()
        .map(|t| {
            t.as_f64()
                .map(|v| v as i32)
                .context("\"prompt\" entries must be numbers")
        })
        .collect::<Result<Vec<i32>>>()?;
    let max_new_tokens = match json.get("max_new_tokens") {
        Some(v) => v.as_usize().context("\"max_new_tokens\" must be a number")?,
        None => 16,
    };
    let mut params = GenParams::new(max_new_tokens);
    if let Some(stop) = json.get("stop_tokens") {
        let stop_tokens = stop
            .as_arr()
            .context("\"stop_tokens\" must be an array of token ids")?
            .iter()
            .map(|t| {
                t.as_f64()
                    .map(|v| v as i32)
                    .context("\"stop_tokens\" entries must be numbers")
            })
            .collect::<Result<Vec<i32>>>()?;
        params = params.with_stop_tokens(stop_tokens);
    }
    if let Some(ms) = json.get("deadline_ms") {
        let ms = ms.as_f64().context("\"deadline_ms\" must be a number")?;
        crate::ensure!(ms >= 0.0, "\"deadline_ms\" must be non-negative");
        params = params.with_deadline(Instant::now() + Duration::from_millis(ms as u64));
    }
    Ok(GenerationRequest::with_params(prompt, params))
}

/// Parse the `POST /v1/cancel` body (`{"id": N}`) into the engine
/// request id to cancel.
fn parse_cancel(body: &[u8]) -> Result<RequestId> {
    let text = std::str::from_utf8(body).map_err(|_| crate::err!("body is not UTF-8"))?;
    let json = Json::parse(text).map_err(|e| crate::err!("body is not valid JSON: {e}"))?;
    let id = json.req("id")?.as_f64().context("\"id\" must be a number")?;
    crate::ensure!(id >= 0.0 && id.fract() == 0.0, "\"id\" must be a non-negative integer");
    Ok(id as RequestId)
}

/// One [`TokenEvent`] as a flat JSON object (one NDJSON line of the
/// streaming response).  Every line carries the engine-assigned `id`
/// (how a client learns the id to target `POST /v1/cancel` with);
/// token events add `event`/`token`/`index`, the terminal event the
/// full result fields.
fn event_json(id: RequestId, ev: &TokenEvent) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("id".into(), Json::Num(id as f64));
    match ev {
        TokenEvent::Prefilled { token } => {
            obj.insert("event".into(), Json::Str("prefilled".into()));
            obj.insert("token".into(), Json::Num(f64::from(*token)));
            obj.insert("index".into(), Json::Num(0.0));
        }
        TokenEvent::Token { token, index } => {
            obj.insert("event".into(), Json::Str("token".into()));
            obj.insert("token".into(), Json::Num(f64::from(*token)));
            obj.insert("index".into(), Json::Num(*index as f64));
        }
        TokenEvent::Retired(res) | TokenEvent::Cancelled(res) | TokenEvent::Failed(res) => {
            let kind = match ev {
                TokenEvent::Retired(_) => "retired",
                TokenEvent::Cancelled(_) => "cancelled",
                _ => "failed",
            };
            obj.insert("event".into(), Json::Str(kind.into()));
            obj.insert("finish".into(), Json::Str(res.finish.label().into()));
            obj.insert(
                "tokens".into(),
                Json::Arr(res.tokens.iter().map(|&t| Json::Num(f64::from(t))).collect()),
            );
            obj.insert(
                "error".into(),
                match &res.error {
                    Some(e) => Json::Str(e.clone()),
                    None => Json::Null,
                },
            );
            obj.insert("queue_s".into(), Json::Num(res.queue_s));
            obj.insert("prefill_s".into(), Json::Num(res.prefill_s));
            obj.insert("decode_s".into(), Json::Num(res.decode_s));
            obj.insert("total_s".into(), Json::Num(res.total_s));
        }
    }
    Json::Obj(obj)
}

// -- wire-level helpers ----------------------------------------------------

const TEXT_PLAIN: &str = "text/plain; charset=utf-8";
/// Prometheus text exposition format 0.0.4.
const PROM_TEXT: &str = "text/plain; version=0.0.4; charset=utf-8";
const NDJSON: &str = "application/x-ndjson";

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

/// Try to parse one complete request from the front of `buf` without
/// consuming it.  `Ok(None)` means more bytes are needed;
/// `Ok(Some((request, used)))` means `buf[..used]` held one full
/// request (the caller drains those bytes).
fn parse_buffered(buf: &[u8], cfg: &HttpConfig) -> Result<Option<(HttpRequest, usize)>> {
    let Some(head_end) = buf.windows(4).position(|w| w == b"\r\n\r\n").map(|i| i + 4) else {
        crate::ensure!(buf.len() <= cfg.max_head_bytes, "request head too large");
        return Ok(None);
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| crate::err!("request head is not UTF-8"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().context("missing method in request line")?.to_string();
    let target = parts.next().context("missing path in request line")?;
    let version = parts.next().unwrap_or("");
    crate::ensure!(version.starts_with("HTTP/1."), "not an HTTP/1.x request");
    // HTTP/1.1 keeps the connection open by default, HTTP/1.0 closes;
    // an explicit `Connection:` header wins either way.
    let mut keep_alive = version != "HTTP/1.0";
    // Route on the path only; a query string is accepted and ignored.
    let path = target.split('?').next().unwrap_or(target).to_string();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| crate::err!("bad Content-Length {value:?}"))?;
            } else if name.eq_ignore_ascii_case("connection") {
                let value = value.trim();
                if value.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if value.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            }
        }
    }
    crate::ensure!(
        content_length <= cfg.max_body_bytes,
        "body of {content_length} bytes exceeds the {} byte cap",
        cfg.max_body_bytes
    );
    let total = head_end + content_length;
    if buf.len() < total {
        return Ok(None);
    }
    let body = buf[head_end..total].to_vec();
    Ok(Some((HttpRequest { method, path, body, keep_alive }, total)))
}

/// Read one request off the connection, consuming it from the
/// carry-over buffer (`buf` keeps any pipelined bytes read past the
/// request for the next call).  `Ok(None)` is a clean close between
/// requests; EOF mid-request is an error.
fn read_request(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    cfg: &HttpConfig,
) -> Result<Option<HttpRequest>> {
    let mut tmp = [0u8; 4096];
    loop {
        if let Some((request, used)) = parse_buffered(buf, cfg)? {
            buf.drain(..used);
            return Ok(Some(request));
        }
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            crate::ensure!(buf.is_empty(), "connection closed mid-request");
            return Ok(None);
        }
        buf.extend_from_slice(&tmp[..n]);
    }
}

/// Pop the next request off the carry-over buffer *only if* it is
/// already complete and is another `POST /v1/generate`.  Anything else
/// — incomplete bytes, a different route, a framing error — stays
/// buffered for the connection loop to handle after the current
/// response group.
fn take_buffered_generate(buf: &mut Vec<u8>, cfg: &HttpConfig) -> Option<HttpRequest> {
    match parse_buffered(buf, cfg) {
        Ok(Some((request, used))) if request.method == "POST" && request.path == "/v1/generate" => {
            buf.drain(..used);
            Some(request)
        }
        _ => None,
    }
}

/// One complete fixed-length response (status + body).  `close` says
/// whether this is the connection's last response.
fn write_response(
    stream: &mut TcpStream,
    code: u16,
    content_type: &str,
    body: &str,
    close: bool,
) -> std::io::Result<()> {
    let conn = if close { "close" } else { "keep-alive" };
    let head = format!(
        "HTTP/1.1 {code} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n\
         Connection: {conn}\r\n\r\n",
        status_text(code),
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Response head of the chunked NDJSON token stream.  The zero-length
/// chunk delimits the response, so a keep-alive connection survives it.
fn write_stream_head(stream: &mut TcpStream, close: bool) -> std::io::Result<()> {
    let conn = if close { "close" } else { "keep-alive" };
    let head = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: {NDJSON}\r\nTransfer-Encoding: chunked\r\n\
         Cache-Control: no-cache\r\nConnection: {conn}\r\n\r\n"
    );
    stream.write_all(head.as_bytes())?;
    stream.flush()
}

/// One chunk of a chunked response, flushed immediately so clients see
/// tokens as their decode rounds land.
fn write_chunk(stream: &mut TcpStream, data: &[u8]) -> std::io::Result<()> {
    stream.write_all(format!("{:x}\r\n", data.len()).as_bytes())?;
    stream.write_all(data)?;
    stream.write_all(b"\r\n")?;
    stream.flush()
}

/// The zero-length chunk terminating a chunked response.
fn write_last_chunk(stream: &mut TcpStream) -> std::io::Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{FinishReason, RequestResult};

    #[test]
    fn generate_body_parses_params() {
        let req = parse_generate(
            br#"{"prompt": [3, 1, 4], "max_new_tokens": 9, "stop_tokens": [7], "deadline_ms": 50}"#,
        )
        .unwrap();
        assert_eq!(req.prompt, vec![3, 1, 4]);
        assert_eq!(req.params.max_new_tokens, 9);
        assert_eq!(req.params.stop_tokens, vec![7]);
        assert!(req.params.deadline.is_some());

        let defaulted = parse_generate(br#"{"prompt": [1]}"#).unwrap();
        assert_eq!(defaulted.params.max_new_tokens, 16);
        assert!(defaulted.params.stop_tokens.is_empty());
        assert!(defaulted.params.deadline.is_none());
    }

    #[test]
    fn generate_body_rejects_malformed_input() {
        assert!(parse_generate(b"{not json").is_err());
        assert!(parse_generate(br#"{"max_new_tokens": 4}"#).is_err(), "prompt is required");
        assert!(parse_generate(br#"{"prompt": "text"}"#).is_err());
        assert!(parse_generate(br#"{"prompt": [1], "max_new_tokens": "x"}"#).is_err());
        assert!(parse_generate(&[0xff, 0xfe]).is_err(), "non-UTF-8 body");
    }

    #[test]
    fn event_lines_are_valid_json_and_carry_the_id() {
        let line = event_json(5, &TokenEvent::Token { token: 42, index: 3 }).to_string();
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("event").and_then(Json::as_str), Some("token"));
        assert_eq!(parsed.get("token").and_then(Json::as_usize), Some(42));
        assert_eq!(parsed.get("index").and_then(Json::as_usize), Some(3));
        assert_eq!(parsed.get("id").and_then(Json::as_usize), Some(5), "every line carries id");

        let res = RequestResult {
            id: 5,
            tokens: vec![1, 2],
            finish: FinishReason::Stop,
            error: None,
            queue_s: 0.0,
            prefill_s: 0.1,
            decode_s: 0.2,
            total_s: 0.3,
        };
        let line = event_json(5, &TokenEvent::Retired(res)).to_string();
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("event").and_then(Json::as_str), Some("retired"));
        assert_eq!(parsed.get("id").and_then(Json::as_usize), Some(5));
        assert_eq!(parsed.get("finish").and_then(Json::as_str), Some("stop"));
        assert_eq!(parsed.get("tokens").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        assert_eq!(parsed.get("error"), Some(&Json::Null));
    }

    #[test]
    fn cancel_body_parses_the_id() {
        assert_eq!(parse_cancel(br#"{"id": 7}"#).unwrap(), 7);
        assert!(parse_cancel(br#"{}"#).is_err(), "id is required");
        assert!(parse_cancel(br#"{"id": -1}"#).is_err(), "negative id rejected");
        assert!(parse_cancel(br#"{"id": 1.5}"#).is_err(), "fractional id rejected");
        assert!(parse_cancel(b"{not json").is_err());
    }

    #[test]
    fn buffered_parse_handles_keepalive_and_pipelining() {
        let cfg = HttpConfig::default();
        let wire: &[u8] = b"POST /v1/generate HTTP/1.1\r\nContent-Length: 3\r\n\r\nabc\
                            GET /healthz HTTP/1.0\r\n\r\n";
        let (first, used) = parse_buffered(wire, &cfg).unwrap().expect("complete request");
        assert_eq!(first.method, "POST");
        assert_eq!(first.path, "/v1/generate");
        assert_eq!(first.body, b"abc");
        assert!(first.keep_alive, "HTTP/1.1 defaults to keep-alive");
        let rest = &wire[used..];
        let (second, used2) = parse_buffered(rest, &cfg).unwrap().expect("second request");
        assert_eq!(second.path, "/healthz");
        assert!(!second.keep_alive, "HTTP/1.0 defaults to close");
        assert_eq!(used2, rest.len());

        assert!(
            parse_buffered(&wire[..10], &cfg).unwrap().is_none(),
            "incomplete head needs more bytes"
        );
        assert!(
            parse_buffered(&wire[..used - 1], &cfg).unwrap().is_none(),
            "complete head with an incomplete body needs more bytes"
        );
    }

    #[test]
    fn connection_header_overrides_the_version_default() {
        let cfg = HttpConfig::default();
        let close11: &[u8] = b"GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n";
        assert!(!parse_buffered(close11, &cfg).unwrap().unwrap().0.keep_alive);
        let keep10: &[u8] = b"GET /healthz HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n";
        assert!(parse_buffered(keep10, &cfg).unwrap().unwrap().0.keep_alive);
    }

    #[test]
    fn take_buffered_generate_only_pops_complete_generates() {
        let cfg = HttpConfig::default();
        let mut buf = b"POST /v1/generate HTTP/1.1\r\nContent-Length: 2\r\n\r\n{}\
                        GET /healthz HTTP/1.1\r\n\r\n"
            .to_vec();
        let popped = take_buffered_generate(&mut buf, &cfg).expect("complete generate pops");
        assert_eq!(popped.body, b"{}");
        assert!(
            take_buffered_generate(&mut buf, &cfg).is_none(),
            "a non-generate request stays for the connection loop"
        );
        assert!(buf.starts_with(b"GET /healthz"), "non-generate bytes are untouched");
    }

    #[test]
    fn status_lines_cover_the_routes() {
        assert_eq!(status_text(200), "OK");
        assert_eq!(status_text(404), "Not Found");
        assert_eq!(status_text(405), "Method Not Allowed");
        assert_eq!(status_text(429), "Too Many Requests");
        assert_eq!(status_text(503), "Service Unavailable");
        assert_eq!(status_text(500), "Internal Server Error");
    }

    #[test]
    fn wake_addr_rewrites_wildcard_binds_to_loopback() {
        let v4: SocketAddr = "0.0.0.0:8080".parse().unwrap();
        assert_eq!(wake_addr(v4), "127.0.0.1:8080".parse().unwrap());
        let v6: SocketAddr = "[::]:8080".parse().unwrap();
        assert_eq!(wake_addr(v6), "[::1]:8080".parse().unwrap());
        let bound: SocketAddr = "192.168.1.5:80".parse().unwrap();
        assert_eq!(wake_addr(bound), bound, "concrete binds are kept as-is");
    }
}
