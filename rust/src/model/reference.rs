//! The pure-scalar f32 reference transformer — the independent ground
//! truth `tests/model_differential.rs` pins the kernel path against,
//! playing the role `scalar_gemm` plays for the GEMV kernels.
//!
//! By design this module shares **only the checkpoint loader**
//! ([`super::Checkpoint`]) with [`super::TernaryTransformer`]: no
//! kernel dispatch, no packed layouts, no KV cache, no shared math
//! helpers.  Every step — activation quantization, the ternary matmul
//! (plain f32 accumulation), RMSNorm, rotary embedding, causal
//! attention, SiLU — is re-implemented here in the most literal scalar
//! form, recomputing the whole sequence from scratch per step instead
//! of threading cached state.
//!
//! Why the two implementations can be *bit*-identical rather than just
//! close: ternary×int8 products are integers and every partial sum
//! stays far below 2^24, so f32 accumulation here is exact and equals
//! the kernels' i32 accumulation; all remaining f32 ops follow the one
//! evaluation order the kernel path documents ("order matters" notes).
//! The differential suite asserts token identity and ≤ 1e-4 relative
//! logit error on top of that.

use crate::util::error::Result;

use super::checkpoint::{Checkpoint, TensorData, TransformerConfig};
use super::sample::{sample_token, SamplerConfig};

/// One ternary linear site held as raw rows.
struct RefLinear {
    rows: usize,
    cols: usize,
    scale: f32,
    w: Vec<i8>,
}

impl RefLinear {
    /// `out = W · x` for one activation row: absmax int8 quantization,
    /// scalar dot products in f32, dequantization by `scale / s`.
    fn forward_row(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        // Per-token absmax quantization (BitNet b1.58): s = 127/absmax.
        let absmax = x.iter().fold(0f32, |a, &b| a.max(b.abs())).max(1e-6);
        let s = 127.0 / absmax;
        let q: Vec<f32> =
            x.iter().map(|&v| (v * s).round().clamp(-127.0, 127.0)).collect();
        let deq = self.scale / s;
        self.w
            .chunks_exact(self.cols)
            .map(|row| {
                let mut acc = 0.0f32;
                for (&a, &w) in q.iter().zip(row) {
                    acc += a * w as f32;
                }
                acc * deq
            })
            .collect()
    }
}

struct RefLayer {
    attn_norm: Vec<f32>,
    wqkv: RefLinear,
    wo: RefLinear,
    ffn_norm: Vec<f32>,
    wgateup: RefLinear,
    wdown: RefLinear,
}

/// The scalar reference model: full-sequence recompute, no caches.
pub struct ReferenceModel {
    config: TransformerConfig,
    embed: Vec<f32>,
    layers: Vec<RefLayer>,
    final_norm: Vec<f32>,
    lm_head: RefLinear,
}

impl ReferenceModel {
    pub fn new(ckpt: &Checkpoint) -> Result<ReferenceModel> {
        let cfg = ckpt.config;
        cfg.validate()?;
        let d = cfg.d_model;
        let kv = cfg.kv_dim();
        let f = cfg.ffn_dim;
        let lin = |name: &str, rows: usize, cols: usize| -> Result<RefLinear> {
            let t = ckpt.tensor(name)?;
            crate::ensure!(
                t.rows == rows && t.cols == cols,
                "tensor {name:?} is {}x{}, expected {rows}x{cols}",
                t.rows,
                t.cols
            );
            match &t.data {
                TensorData::Ternary { scale, w } => {
                    Ok(RefLinear { rows, cols, scale: *scale, w: w.clone() })
                }
                TensorData::F32(_) => crate::bail!("tensor {name:?} is f32, expected ternary"),
            }
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            layers.push(RefLayer {
                attn_norm: ckpt.f32_tensor(&format!("layer{l}.attn_norm"), d)?.to_vec(),
                wqkv: lin(&format!("layer{l}.wqkv"), d + 2 * kv, d)?,
                wo: lin(&format!("layer{l}.wo"), d, d)?,
                ffn_norm: ckpt.f32_tensor(&format!("layer{l}.ffn_norm"), d)?.to_vec(),
                wgateup: lin(&format!("layer{l}.wgateup"), 2 * f, d)?,
                wdown: lin(&format!("layer{l}.wdown"), d, f)?,
            });
        }
        Ok(ReferenceModel {
            config: cfg,
            embed: ckpt.f32_tensor("embed", cfg.vocab * d)?.to_vec(),
            layers,
            final_norm: ckpt.f32_tensor("final_norm", d)?.to_vec(),
            lm_head: lin("lm_head", cfg.vocab, d)?,
        })
    }

    pub fn config(&self) -> &TransformerConfig {
        &self.config
    }

    /// The last position's logits for `tokens`, recomputed from
    /// scratch.
    pub fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let states = self.run(tokens, None)?;
        let d = self.config.d_model;
        let h = rms_norm(&states[(tokens.len() - 1) * d..], &self.final_norm, self.config.norm_eps);
        Ok(self.lm_head.forward_row(&h))
    }

    /// Generate like [`crate::runtime::Backend::generate_until`]: the
    /// returned tokens start with the one sampled after the prompt,
    /// stopping early (stop token included) on any of `stop`.  Each
    /// step recomputes the full sequence.
    pub fn generate_until(
        &self,
        prompt: &[i32],
        n_new: usize,
        sampler: &SamplerConfig,
        stop: &[i32],
    ) -> Result<Vec<i32>> {
        crate::ensure!(!prompt.is_empty(), "empty prompt");
        crate::ensure!(n_new >= 1, "n_new must be >= 1");
        let mut history = prompt.to_vec();
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            let logits = self.logits(&history)?;
            let next = sample_token(sampler, &logits, &history);
            history.push(next);
            out.push(next);
            if stop.contains(&next) {
                break;
            }
        }
        Ok(out)
    }

    /// Every attention probability row of a forward pass (all layers ×
    /// heads × positions; row `t` has `t + 1` entries).  The softmax
    /// property test asserts each sums to one.
    pub fn attention_probe(&self, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        let mut probe = Vec::new();
        self.run(tokens, Some(&mut probe))?;
        Ok(probe)
    }

    /// The full block stack, returning all hidden states (n × d_model).
    fn run(&self, tokens: &[i32], mut probe: Option<&mut Vec<Vec<f32>>>) -> Result<Vec<f32>> {
        let cfg = &self.config;
        let n = tokens.len();
        crate::ensure!(n >= 1, "forward needs at least one token");
        let d = cfg.d_model;
        let kvd = cfg.kv_dim();
        let hd = cfg.head_dim();
        let group = cfg.n_heads / cfg.n_kv_heads;
        let mut xs = vec![0.0f32; n * d];
        for (row, &t) in xs.chunks_exact_mut(d).zip(tokens) {
            crate::ensure!(
                t >= 0 && (t as usize) < cfg.vocab,
                "token {t} outside vocab {}",
                cfg.vocab
            );
            row.copy_from_slice(&self.embed[t as usize * d..(t as usize + 1) * d]);
        }
        for layer in &self.layers {
            // Attention half: q/k/v per position, rotary, causal MHA.
            let mut qs = Vec::with_capacity(n);
            let mut ks = Vec::with_capacity(n);
            let mut vs = Vec::with_capacity(n);
            for (pos, x) in xs.chunks_exact(d).enumerate() {
                let normed = rms_norm(x, &layer.attn_norm, cfg.norm_eps);
                let qkv = layer.wqkv.forward_row(&normed);
                let mut q = qkv[..d].to_vec();
                let mut k = qkv[d..d + kvd].to_vec();
                rotate(&mut q, cfg.n_heads, hd, pos, cfg.rope_theta);
                rotate(&mut k, cfg.n_kv_heads, hd, pos, cfg.rope_theta);
                qs.push(q);
                ks.push(k);
                vs.push(qkv[d + kvd..].to_vec());
            }
            for (pos, x) in xs.chunks_exact_mut(d).enumerate() {
                let mut attn = vec![0.0f32; d];
                for h in 0..cfg.n_heads {
                    let kvh = h / group;
                    let qh = &qs[pos][h * hd..(h + 1) * hd];
                    let scores: Vec<f32> = ks[..=pos]
                        .iter()
                        .map(|k| {
                            let kh = &k[kvh * hd..(kvh + 1) * hd];
                            let mut dot = 0.0f32;
                            for (&a, &b) in qh.iter().zip(kh) {
                                dot += a * b;
                            }
                            dot * (1.0 / (hd as f32).sqrt())
                        })
                        .collect();
                    let probs = softmax(&scores);
                    if let Some(p) = probe.as_deref_mut() {
                        p.push(probs.clone());
                    }
                    let oh = &mut attn[h * hd..(h + 1) * hd];
                    for (&w, v) in probs.iter().zip(&vs[..=pos]) {
                        let vh = &v[kvh * hd..(kvh + 1) * hd];
                        for (o, &vv) in oh.iter_mut().zip(vh) {
                            *o += w * vv;
                        }
                    }
                }
                let wo_out = layer.wo.forward_row(&attn);
                for (xv, o) in x.iter_mut().zip(&wo_out) {
                    *xv += o;
                }
            }
            // MLP half: x += Wdown · (silu(gate) · up).
            for x in xs.chunks_exact_mut(d) {
                let normed = rms_norm(x, &layer.ffn_norm, cfg.norm_eps);
                let gu = layer.wgateup.forward_row(&normed);
                let (gate, up) = gu.split_at(cfg.ffn_dim);
                let act: Vec<f32> = gate
                    .iter()
                    .zip(up)
                    .map(|(&g, &u)| g / (1.0 + (-g).exp()) * u)
                    .collect();
                let down = layer.wdown.forward_row(&act);
                for (xv, o) in x.iter_mut().zip(&down) {
                    *xv += o;
                }
            }
        }
        Ok(xs)
    }
}

/// Scalar RMSNorm: `x · gains / sqrt(mean(x²) + eps)` with ascending
/// sum of squares and `x · inv · gain` left to right.
pub fn rms_norm(x: &[f32], gains: &[f32], eps: f32) -> Vec<f32> {
    let mut ss = 0.0f32;
    for &v in x {
        ss += v * v;
    }
    let inv = 1.0 / ((ss / x.len() as f32) + eps).sqrt();
    x.iter().zip(gains).map(|(&v, &g)| v * inv * g).collect()
}

/// Numerically stable softmax: max-subtracted exp, sum accumulated in
/// the same pass, then one divide per entry.
pub fn softmax(x: &[f32]) -> Vec<f32> {
    let max = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut e: Vec<f32> = Vec::with_capacity(x.len());
    let mut sum = 0.0f32;
    for &v in x {
        let ev = (v - max).exp();
        sum += ev;
        e.push(ev);
    }
    for v in e.iter_mut() {
        *v /= sum;
    }
    e
}

/// Llama-style half-split rotary embedding: `freq = 1/theta^(2i/hd)`,
/// separate `.sin()`/`.cos()`, rotate `(x[i], x[i+hd/2])`.
fn rotate(x: &mut [f32], heads: usize, head_dim: usize, pos: usize, theta: f32) {
    let half = head_dim / 2;
    for h in 0..heads {
        let base = h * head_dim;
        for i in 0..half {
            let freq = 1.0f32 / theta.powf((2 * i) as f32 / head_dim as f32);
            let ang = pos as f32 * freq;
            let (sin, cos) = (ang.sin(), ang.cos());
            let a = x[base + i];
            let b = x[base + i + half];
            x[base + i] = a * cos - b * sin;
            x[base + i + half] = a * sin + b * cos;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> ReferenceModel {
        let ckpt = Checkpoint::synthesize(TransformerConfig::toy(), 0xAB).unwrap();
        ReferenceModel::new(&ckpt).unwrap()
    }

    #[test]
    fn logits_are_deterministic_and_causal() {
        let m = toy();
        let a = m.logits(&[5, 6, 7]).unwrap();
        let b = m.logits(&[5, 6, 7]).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), m.config().vocab);
        // Causality: appending a token must not change what the prefix
        // alone would have predicted — recompute the prefix and compare.
        let c = m.logits(&[5, 6]).unwrap();
        assert_ne!(a, c, "position 2 logits should differ from position 1");
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let m = toy();
        let s = SamplerConfig::greedy();
        let a = m.generate_until(&[3, 1, 4], 5, &s, &[]).unwrap();
        let b = m.generate_until(&[3, 1, 4], 5, &s, &[]).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 5);
    }

    #[test]
    fn stop_token_truncates() {
        let m = toy();
        let s = SamplerConfig::greedy();
        let full = m.generate_until(&[8, 9], 6, &s, &[]).unwrap();
        let stopped = m.generate_until(&[8, 9], 6, &s, &[full[1]]).unwrap();
        assert_eq!(stopped, full[..2].to_vec(), "stop token must end generation inclusively");
    }

    #[test]
    fn attention_rows_are_distributions() {
        let m = toy();
        let rows = m.attention_probe(&[1, 2, 3, 4]).unwrap();
        // layers × heads × positions rows.
        let cfg = m.config();
        assert_eq!(rows.len(), cfg.n_layers * cfg.n_heads * 4);
        for row in rows {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "softmax row sums to {sum}");
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn softmax_and_rmsnorm_helpers() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
        let g = vec![1.0f32; 4];
        let y = rms_norm(&[2.0, -2.0, 2.0, -2.0], &g, 1e-5);
        let rms: f32 = (y.iter().map(|v| v * v).sum::<f32>() / 4.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-3, "normalized rms {rms}");
    }
}
