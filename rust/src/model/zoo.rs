//! Architecture shape tables for the evaluated checkpoints.
//!
//! Weight *values* are synthesized (seeded ternary; see `util::rng`) —
//! end-to-end latency and memory behaviour depend only on the layer
//! shapes, which come from the public model cards.  BitNet-b1.58-2B-4T
//! is the paper's representative model: its (2560 × 6912) projections are
//! exactly the Fig. 10 microbenchmark shapes, and the 100B-class config
//! reproduces the paper's 1×8192×45568 fused-FFN GEMV example (§IV-C).

/// One ternary transformer architecture.
#[derive(Debug, Clone, Copy)]
pub struct ModelSpec {
    pub name: &'static str,
    pub layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// Grouped-query KV heads.
    pub n_kv_heads: usize,
    pub ffn_dim: usize,
    pub vocab: usize,
}

impl ModelSpec {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Total parameter count (BitLinear weights + embeddings).
    pub fn param_count(&self) -> f64 {
        let d = self.d_model as f64;
        let f = self.ffn_dim as f64;
        let kv = self.kv_dim() as f64;
        let per_layer = d * d // wq
            + 2.0 * d * kv // wk, wv
            + d * d // wo
            + 3.0 * d * f; // gate, up, down
        self.layers as f64 * per_layer + 2.0 * d * self.vocab as f64
    }

    /// Ternary (2 b/w packed) model bytes — Fig. 1(a)'s size axis.
    pub fn ternary_bytes(&self) -> f64 {
        self.param_count() / 4.0
    }

    /// FP16 model bytes.
    pub fn fp16_bytes(&self) -> f64 {
        self.param_count() * 2.0
    }
}

/// The BitNet scaling family (125M → 100B) followed by the two named
/// cross-platform models (Table III).
pub const MODEL_ZOO: &[ModelSpec] = &[
    ModelSpec { name: "BitNet-125M", layers: 12, d_model: 768, n_heads: 12, n_kv_heads: 12, ffn_dim: 2048, vocab: 32000 },
    ModelSpec { name: "BitNet-350M", layers: 24, d_model: 1024, n_heads: 16, n_kv_heads: 16, ffn_dim: 2816, vocab: 32000 },
    ModelSpec { name: "BitNet-700M", layers: 24, d_model: 1536, n_heads: 24, n_kv_heads: 24, ffn_dim: 4096, vocab: 32000 },
    ModelSpec { name: "BitNet-1.5B", layers: 24, d_model: 2048, n_heads: 32, n_kv_heads: 32, ffn_dim: 5504, vocab: 32000 },
    ModelSpec { name: "BitNet-2B-4T", layers: 30, d_model: 2560, n_heads: 20, n_kv_heads: 5, ffn_dim: 6912, vocab: 128256 },
    ModelSpec { name: "BitNet-3B", layers: 26, d_model: 3200, n_heads: 32, n_kv_heads: 32, ffn_dim: 8640, vocab: 32000 },
    ModelSpec { name: "BitNet-7B", layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 32, ffn_dim: 11008, vocab: 32000 },
    ModelSpec { name: "BitNet-13B", layers: 40, d_model: 5120, n_heads: 40, n_kv_heads: 40, ffn_dim: 13824, vocab: 32000 },
    ModelSpec { name: "BitNet-30B", layers: 60, d_model: 6656, n_heads: 52, n_kv_heads: 52, ffn_dim: 17920, vocab: 32000 },
    ModelSpec { name: "BitNet-70B", layers: 80, d_model: 8192, n_heads: 64, n_kv_heads: 8, ffn_dim: 28672, vocab: 32000 },
    ModelSpec { name: "BitNet-100B", layers: 105, d_model: 8192, n_heads: 64, n_kv_heads: 8, ffn_dim: 22784, vocab: 128256 },
    ModelSpec { name: "Llama-b1.58-8B", layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 8, ffn_dim: 14336, vocab: 128256 },
    ModelSpec { name: "Falcon3-b1.58-10B", layers: 40, d_model: 3072, n_heads: 12, n_kv_heads: 4, ffn_dim: 23040, vocab: 131072 },
];

pub fn by_name(name: &str) -> Option<&'static ModelSpec> {
    MODEL_ZOO.iter().find(|m| m.name == name)
}

/// The three representative models of Fig. 9.
pub fn fig9_models() -> [&'static ModelSpec; 3] {
    [
        by_name("BitNet-125M").unwrap(),
        by_name("BitNet-2B-4T").unwrap(),
        by_name("BitNet-100B").unwrap(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_models_exist() {
        for n in ["BitNet-2B-4T", "Llama-b1.58-8B", "Falcon3-b1.58-10B"] {
            assert!(by_name(n).is_some(), "{n} missing from zoo");
        }
    }

    #[test]
    fn param_counts_are_in_class() {
        // Each model's parameter count must be within ~35% of its name.
        let cases = [
            ("BitNet-125M", 125e6),
            ("BitNet-2B-4T", 2.4e9),
            ("BitNet-7B", 7e9),
            ("BitNet-70B", 70e9),
            ("BitNet-100B", 100e9),
            ("Llama-b1.58-8B", 8e9),
            ("Falcon3-b1.58-10B", 10e9),
        ];
        for (name, want) in cases {
            let got = by_name(name).unwrap().param_count();
            let ratio = got / want;
            assert!(
                (0.65..1.35).contains(&ratio),
                "{name}: {got:.3e} params vs class {want:.0e} (ratio {ratio:.2})"
            );
        }
    }

    #[test]
    fn fig10_shapes_come_from_2b4t() {
        let m = by_name("BitNet-2B-4T").unwrap();
        assert_eq!(m.d_model, 2560);
        assert_eq!(m.ffn_dim, 6912);
    }

    #[test]
    fn mobile_gemv_example_comes_from_100b() {
        // §IV-C quotes a 1×8192×45568 GEMV: 100B's fused gate+up.
        let m = by_name("BitNet-100B").unwrap();
        assert_eq!(m.d_model, 8192);
        assert_eq!(2 * m.ffn_dim, 45568);
    }

    #[test]
    fn size_reduction_is_8x() {
        let m = by_name("BitNet-2B-4T").unwrap();
        assert!((m.fp16_bytes() / m.ternary_bytes() - 8.0).abs() < 1e-9);
    }
}
