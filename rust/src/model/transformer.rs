//! The kernel-path ternary transformer (DESIGN.md §2 "model block"):
//! a BitNet-b1.58-style decoder block whose BitLinear GEMVs execute
//! through the T-SAR ternary kernels — the native AVX2/scalar pshufb
//! path ([`NativeGemv`]) or the modeled ISA ([`TsarKernel`]) — while
//! everything around them (RMSNorm, rotary embedding, causal attention
//! over per-sequence KV state, SiLU) runs in plain f32.
//!
//! Per block: `x += Wo·attn(rope(split(Wqkv·rmsnorm(x))))`, then
//! `x += Wdown·(silu(gate)·up)` with `[gate|up] = Wgateup·rmsnorm(x)`;
//! final RMSNorm and the ternary LM head produce the logits.
//!
//! ## The differential contract
//!
//! `tests/model_differential.rs` pins this implementation bit-for-bit
//! against the independent scalar [`super::ReferenceModel`].  That is
//! only meaningful because every step here is exactly reproducible:
//!
//! * BitLinear accumulates ternary×int8 products in exact i32 (both
//!   kernel engines are pinned bit-identical by
//!   `tests/native_differential.rs`), and the sums stay far below 2^24
//!   so the f32 dequantization is exact too;
//! * every f32 op outside the GEMVs (norm, rope, softmax, SiLU,
//!   quantization) fixes one evaluation order, mirrored by the
//!   reference — see the "order matters" notes on each helper.
//!
//! Batching is never semantic: a row of a batched GEMM runs the same
//! kernel over the same packed bytes as a lone GEMV, so prefill over T
//! tokens, one-token decode, and cross-sequence decode rounds
//! ([`TernaryTransformer::decode_round`]) all emit identical numbers.

use crate::config::IsaConfig;
use crate::kernels::native::{NativeGemv, NativePath};
use crate::kernels::{Dataflow, TernaryKernel, TsarKernel};
use crate::quant::absmax_quantize;
use crate::quant::pack::PshufbPacked;
use crate::sim::GemmShape;
use crate::util::error::Result;

use super::checkpoint::{Checkpoint, TransformerConfig};

/// Which ternary kernel executes the BitLinear GEMVs.
pub enum LinearEngine {
    /// Host-SIMD execution: AVX2 pshufb where detected, the portable
    /// scalar fallback elsewhere (`TSAR_NATIVE_FORCE_SCALAR=1` forces
    /// it).  `threads` chunks output tiles across lanes of the
    /// persistent process-wide worker pool
    /// ([`crate::kernels::WorkerPool`]); batched rounds run the
    /// row-blocked GEMM so the packed weight stream is read once per
    /// row block.
    Native(NativeGemv),
    /// The modeled T-SAR ISA (`tsar::exec` semantics, OP dataflow) —
    /// slower, but exercises the register-file model end to end.
    Modeled(IsaConfig),
}

impl LinearEngine {
    /// Native engine on the detected best path.
    pub fn native(isa: IsaConfig, threads: usize) -> Result<LinearEngine> {
        Ok(LinearEngine::Native(NativeGemv::new(isa)?.with_threads(threads.max(1))?))
    }

    /// Modeled-ISA engine (OP dataflow).
    pub fn modeled(isa: IsaConfig) -> LinearEngine {
        LinearEngine::Modeled(isa)
    }

    /// Short display name for logs/describe strings.
    pub fn name(&self) -> String {
        match self {
            LinearEngine::Native(g) => format!("native-{}/{}", g.path().name(), g.isa().name()),
            LinearEngine::Modeled(isa) => format!("modeled/{}", isa.name()),
        }
    }

    pub fn native_path(&self) -> Option<NativePath> {
        match self {
            LinearEngine::Native(g) => Some(g.path()),
            LinearEngine::Modeled(_) => None,
        }
    }

    /// The underlying native GEMV when this engine runs on host SIMD —
    /// lets serving surfaces report pool/threading facts (effective
    /// worker counts per site) in their plan summaries.
    pub fn native_gemv(&self) -> Option<&NativeGemv> {
        match self {
            LinearEngine::Native(g) => Some(g),
            LinearEngine::Modeled(_) => None,
        }
    }
}

/// Weight storage matching the engine: the native path keeps the
/// pshufb execution layout, the modeled path replays raw ternary rows.
enum LinearWeights {
    Packed(PshufbPacked),
    Raw(Vec<i8>),
}

/// One ternary linear site: `out = W · x` with W a `rows × cols`
/// ternary matrix scaled by one absmean factor.
pub struct BitLinear {
    site: &'static str,
    rows: usize,
    cols: usize,
    scale: f32,
    weights: LinearWeights,
}

impl BitLinear {
    fn new(
        engine: &LinearEngine,
        site: &'static str,
        w: &[i8],
        scale: f32,
        rows: usize,
        cols: usize,
    ) -> Result<BitLinear> {
        crate::ensure!(w.len() == rows * cols, "{site}: weight length mismatch");
        let weights = match engine {
            LinearEngine::Native(g) => LinearWeights::Packed(g.pack(w, rows, cols)?),
            LinearEngine::Modeled(_) => LinearWeights::Raw(w.to_vec()),
        };
        Ok(BitLinear { site, rows, cols, scale, weights })
    }

    /// Batched BitLinear forward over `n` f32 activation rows:
    /// per-row absmax int8 quantization, the ternary integer GEMM on
    /// the configured engine, then exact dequantization by
    /// `scale / s_row`.  The reference model mirrors this exact
    /// pipeline in scalar f32 — keep the op order in sync.
    fn forward(&self, engine: &LinearEngine, x: &[f32], n: usize) -> Result<Vec<f32>> {
        crate::ensure!(x.len() == n * self.cols, "{}: activation shape mismatch", self.site);
        match (engine, &self.weights) {
            // Native: the kernels' fused batched BitLinear entry.
            (LinearEngine::Native(g), LinearWeights::Packed(p)) => {
                let mut out = vec![0f32; n * self.rows];
                g.gemm_bitlinear(x, p, n, self.scale, &mut out)?;
                Ok(out)
            }
            // Modeled ISA: the same quantize → integer GEMM →
            // dequantize pipeline, with the GEMM replayed through the
            // register-file model.  Must mirror `gemm_bitlinear`'s op
            // order exactly (the engines are pinned bit-identical).
            (LinearEngine::Modeled(isa), LinearWeights::Raw(w)) => {
                let mut acts = Vec::with_capacity(n * self.cols);
                let mut row_scales = Vec::with_capacity(n);
                for row in x.chunks_exact(self.cols) {
                    let (q, s) = absmax_quantize(row);
                    acts.extend_from_slice(&q);
                    row_scales.push(s);
                }
                let ints = TsarKernel::new(*isa, Dataflow::Op).run(
                    &acts,
                    w,
                    GemmShape::new(n, self.cols, self.rows),
                );
                let mut out = Vec::with_capacity(n * self.rows);
                for (ints_row, &s) in ints.chunks_exact(self.rows).zip(&row_scales) {
                    let deq = self.scale / s;
                    out.extend(ints_row.iter().map(|&acc| acc as f32 * deq));
                }
                Ok(out)
            }
            _ => crate::bail!("{}: weight layout does not match the engine", self.site),
        }
    }
}

/// Per-sequence KV state: one flat key and value buffer per layer
/// (`len` cached positions × `kv_dim` floats each).  Cloned by the
/// backend on every step, so steps stay functional over explicit state
/// like every other [`crate::runtime::Backend`].
#[derive(Debug, Clone)]
pub struct ModelKv {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    len: usize,
}

impl ModelKv {
    fn new(n_layers: usize) -> ModelKv {
        ModelKv { k: vec![Vec::new(); n_layers], v: vec![Vec::new(); n_layers], len: 0 }
    }

    /// Cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

struct Layer {
    attn_norm: Vec<f32>,
    wqkv: BitLinear,
    wo: BitLinear,
    ffn_norm: Vec<f32>,
    wgateup: BitLinear,
    wdown: BitLinear,
}

/// The kernel-path model: checkpoint weights packed for one
/// [`LinearEngine`], driven through prefill/decode by
/// [`crate::runtime::ModelBackend`].
pub struct TernaryTransformer {
    config: TransformerConfig,
    engine: LinearEngine,
    embed: Vec<f32>,
    layers: Vec<Layer>,
    final_norm: Vec<f32>,
    lm_head: BitLinear,
}

impl TernaryTransformer {
    /// Load (pack) every tensor of `ckpt` for `engine`.
    pub fn from_checkpoint(ckpt: &Checkpoint, engine: LinearEngine) -> Result<TernaryTransformer> {
        let cfg = ckpt.config;
        cfg.validate()?;
        let d = cfg.d_model;
        let kv = cfg.kv_dim();
        let f = cfg.ffn_dim;
        let embed = ckpt.f32_tensor("embed", cfg.vocab * d)?.to_vec();
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let lin = |name: &str, site, rows, cols| -> Result<BitLinear> {
                let (w, scale) = ckpt.ternary_tensor(&format!("layer{l}.{name}"), rows, cols)?;
                BitLinear::new(&engine, site, w, scale, rows, cols)
            };
            layers.push(Layer {
                attn_norm: ckpt.f32_tensor(&format!("layer{l}.attn_norm"), d)?.to_vec(),
                wqkv: lin("wqkv", "wqkv", d + 2 * kv, d)?,
                wo: lin("wo", "wo", d, d)?,
                ffn_norm: ckpt.f32_tensor(&format!("layer{l}.ffn_norm"), d)?.to_vec(),
                wgateup: lin("wgateup", "ffn-gate-up", 2 * f, d)?,
                wdown: lin("wdown", "ffn-down", d, f)?,
            });
        }
        let final_norm = ckpt.f32_tensor("final_norm", d)?.to_vec();
        let (w, scale) = ckpt.ternary_tensor("lm_head", cfg.vocab, d)?;
        let lm_head = BitLinear::new(&engine, "lm-head", w, scale, cfg.vocab, d)?;
        Ok(TernaryTransformer { config: cfg, engine, embed, layers, final_norm, lm_head })
    }

    pub fn config(&self) -> &TransformerConfig {
        &self.config
    }

    pub fn engine(&self) -> &LinearEngine {
        &self.engine
    }

    /// Fresh empty KV state.
    pub fn new_kv(&self) -> ModelKv {
        ModelKv::new(self.config.n_layers)
    }

    /// The decode-shaped (N = 1) GEMV of every BitLinear site, for
    /// plan summaries.
    pub fn site_shapes(&self) -> Vec<(&'static str, GemmShape)> {
        let mut sites = Vec::new();
        if let Some(layer) = self.layers.first() {
            for lin in [&layer.wqkv, &layer.wo, &layer.wgateup, &layer.wdown] {
                sites.push((lin.site, GemmShape::new(1, lin.cols, lin.rows)));
            }
        }
        sites.push((self.lm_head.site, GemmShape::new(1, self.lm_head.cols, self.lm_head.rows)));
        sites
    }

    /// Packed/raw weight bytes held by the BitLinear sites.
    pub fn weight_bytes(&self) -> usize {
        let lin_bytes = |l: &BitLinear| match &l.weights {
            LinearWeights::Packed(p) => p.packed_bytes(),
            LinearWeights::Raw(w) => w.len(),
        };
        self.layers
            .iter()
            .flat_map(|l| [&l.wqkv, &l.wo, &l.wgateup, &l.wdown])
            .map(lin_bytes)
            .sum::<usize>()
            + lin_bytes(&self.lm_head)
    }

    /// Forward `tokens` (appended after `kv`'s cached positions),
    /// returning the last position's logits.  `tokens.len() > 1` is
    /// the batched prefill path: the BitLinear sites run one n-row
    /// GEMM per site instead of n GEMVs — numerically identical, one
    /// weight pass.
    pub fn forward(&self, tokens: &[i32], kv: &mut ModelKv) -> Result<Vec<f32>> {
        let n = tokens.len();
        crate::ensure!(n >= 1, "forward needs at least one token");
        crate::ensure!(kv.k.len() == self.config.n_layers, "KV state layer count mismatch");
        let d = self.config.d_model;
        let base = kv.len;
        let mut xs = vec![0.0f32; n * d];
        for (row, &t) in xs.chunks_exact_mut(d).zip(tokens) {
            crate::ensure!(
                t >= 0 && (t as usize) < self.config.vocab,
                "token {t} outside vocab {}",
                self.config.vocab
            );
            row.copy_from_slice(&self.embed[t as usize * d..(t as usize + 1) * d]);
        }
        for (li, layer) in self.layers.iter().enumerate() {
            self.block(layer, &mut xs, n, |i| base + i, kv_at(&mut kv.k, &mut kv.v, li))?;
        }
        kv.len += n;
        // Only the last position's logits are observed (sampling), so
        // the LM head runs on that single row.
        let last = &xs[(n - 1) * d..];
        let mut h = vec![0.0f32; d];
        rms_norm_row(last, &self.final_norm, self.config.norm_eps, &mut h);
        self.lm_head.forward(&self.engine, &h, 1)
    }

    /// One cross-sequence batched decode round: advance each sequence
    /// by its token (at its own position), stacking all sequences'
    /// activation rows into one n-row GEMM per BitLinear site.
    /// Returns each sequence's logits.  Token outputs are bit-identical
    /// to serialized [`TernaryTransformer::forward`] calls — batching
    /// is a throughput optimization, never a semantic one (the
    /// `decode_batch` contract of [`crate::runtime::Backend`]).
    pub fn decode_round(&self, tokens: &[i32], kvs: &mut [ModelKv]) -> Result<Vec<Vec<f32>>> {
        let n = tokens.len();
        crate::ensure!(n >= 1, "empty decode round");
        crate::ensure!(kvs.len() == n, "round has {n} tokens but {} KV states", kvs.len());
        let d = self.config.d_model;
        let mut xs = vec![0.0f32; n * d];
        for (row, &t) in xs.chunks_exact_mut(d).zip(tokens) {
            crate::ensure!(
                t >= 0 && (t as usize) < self.config.vocab,
                "token {t} outside vocab {}",
                self.config.vocab
            );
            row.copy_from_slice(&self.embed[t as usize * d..(t as usize + 1) * d]);
        }
        for (li, layer) in self.layers.iter().enumerate() {
            self.block_round(layer, li, &mut xs, kvs)?;
        }
        for kv in kvs.iter_mut() {
            kv.len += 1;
        }
        let mut normed = vec![0.0f32; n * d];
        for (out, x) in normed.chunks_exact_mut(d).zip(xs.chunks_exact(d)) {
            rms_norm_row(x, &self.final_norm, self.config.norm_eps, out);
        }
        let logits = self.lm_head.forward(&self.engine, &normed, n)?;
        Ok(logits.chunks_exact(self.config.vocab).map(|r| r.to_vec()).collect())
    }

    /// One decoder block over `n` same-sequence rows (`pos_of(i)` maps
    /// the row index to its absolute position; rows append to one
    /// shared layer cache in order).
    fn block(
        &self,
        layer: &Layer,
        xs: &mut [f32],
        n: usize,
        pos_of: impl Fn(usize) -> usize,
        (lk, lv): (&mut Vec<f32>, &mut Vec<f32>),
    ) -> Result<()> {
        let cfg = &self.config;
        let d = cfg.d_model;
        let kvd = cfg.kv_dim();
        let mut normed = vec![0.0f32; n * d];
        for (out, x) in normed.chunks_exact_mut(d).zip(xs.chunks_exact(d)) {
            rms_norm_row(x, &layer.attn_norm, cfg.norm_eps, out);
        }
        let qkv = layer.wqkv.forward(&self.engine, &normed, n)?;
        let mut attn = vec![0.0f32; n * d];
        for (i, (qkv_row, attn_row)) in
            qkv.chunks_exact(d + 2 * kvd).zip(attn.chunks_exact_mut(d)).enumerate()
        {
            let pos = pos_of(i);
            let mut q = qkv_row[..d].to_vec();
            let mut k = qkv_row[d..d + kvd].to_vec();
            rope_rotate(&mut q, cfg.n_heads, cfg.head_dim(), pos, cfg.rope_theta);
            rope_rotate(&mut k, cfg.n_kv_heads, cfg.head_dim(), pos, cfg.rope_theta);
            lk.extend_from_slice(&k);
            lv.extend_from_slice(&qkv_row[d + kvd..]);
            self.attend_row(&q, lk, lv, pos + 1, attn_row);
        }
        let wo_out = layer.wo.forward(&self.engine, &attn, n)?;
        for (x, o) in xs.iter_mut().zip(&wo_out) {
            *x += o;
        }
        self.mlp(layer, xs, n)
    }

    /// The same block over `n` independent sequences, each with its own
    /// cache and position.
    fn block_round(&self, layer: &Layer, li: usize, xs: &mut [f32], kvs: &mut [ModelKv]) -> Result<()> {
        let cfg = &self.config;
        let d = cfg.d_model;
        let kvd = cfg.kv_dim();
        let n = kvs.len();
        let mut normed = vec![0.0f32; n * d];
        for (out, x) in normed.chunks_exact_mut(d).zip(xs.chunks_exact(d)) {
            rms_norm_row(x, &layer.attn_norm, cfg.norm_eps, out);
        }
        let qkv = layer.wqkv.forward(&self.engine, &normed, n)?;
        let mut attn = vec![0.0f32; n * d];
        for ((qkv_row, attn_row), kv) in
            qkv.chunks_exact(d + 2 * kvd).zip(attn.chunks_exact_mut(d)).zip(kvs.iter_mut())
        {
            let pos = kv.len;
            let mut q = qkv_row[..d].to_vec();
            let mut k = qkv_row[d..d + kvd].to_vec();
            rope_rotate(&mut q, cfg.n_heads, cfg.head_dim(), pos, cfg.rope_theta);
            rope_rotate(&mut k, cfg.n_kv_heads, cfg.head_dim(), pos, cfg.rope_theta);
            kv.k[li].extend_from_slice(&k);
            kv.v[li].extend_from_slice(&qkv_row[d + kvd..]);
            self.attend_row(&q, &kv.k[li], &kv.v[li], pos + 1, attn_row);
        }
        let wo_out = layer.wo.forward(&self.engine, &attn, n)?;
        for (x, o) in xs.iter_mut().zip(&wo_out) {
            *x += o;
        }
        self.mlp(layer, xs, n)
    }

    /// The gated MLP half of the block: `x += Wdown·(silu(gate)·up)`.
    fn mlp(&self, layer: &Layer, xs: &mut [f32], n: usize) -> Result<()> {
        let cfg = &self.config;
        let d = cfg.d_model;
        let f = cfg.ffn_dim;
        let mut normed = vec![0.0f32; n * d];
        for (out, x) in normed.chunks_exact_mut(d).zip(xs.chunks_exact(d)) {
            rms_norm_row(x, &layer.ffn_norm, cfg.norm_eps, out);
        }
        let gu = layer.wgateup.forward(&self.engine, &normed, n)?;
        let mut act = vec![0.0f32; n * f];
        for (act_row, gu_row) in act.chunks_exact_mut(f).zip(gu.chunks_exact(2 * f)) {
            let (gate, up) = gu_row.split_at(f);
            for ((a, &g), &u) in act_row.iter_mut().zip(gate).zip(up) {
                *a = silu(g) * u;
            }
        }
        let down = layer.wdown.forward(&self.engine, &act, n)?;
        for (x, o) in xs.iter_mut().zip(&down) {
            *x += o;
        }
        Ok(())
    }

    /// Causal multi-head attention for one query row whose position is
    /// `t_len - 1`: `keys`/`vals` hold `t_len` cached kv_dim rows.
    /// Order matters (mirrored by the reference): ascending-index dot
    /// products, max-subtracted exp with the sum accumulated in the
    /// same pass, then the t-outer weighted-value accumulation.
    fn attend_row(&self, q: &[f32], keys: &[f32], vals: &[f32], t_len: usize, out: &mut [f32]) {
        let cfg = &self.config;
        let hd = cfg.head_dim();
        let kvd = cfg.kv_dim();
        let group = cfg.n_heads / cfg.n_kv_heads;
        let inv = 1.0 / (hd as f32).sqrt();
        let mut scores = vec![0.0f32; t_len];
        for h in 0..cfg.n_heads {
            let kvh = h / group;
            let qh = &q[h * hd..(h + 1) * hd];
            for (score, key_row) in scores.iter_mut().zip(keys.chunks_exact(kvd)) {
                let kh = &key_row[kvh * hd..(kvh + 1) * hd];
                let mut dot = 0.0f32;
                for (&a, &b) in qh.iter().zip(kh) {
                    dot += a * b;
                }
                *score = dot * inv;
            }
            let max = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - max).exp();
                sum += *s;
            }
            let oh = &mut out[h * hd..(h + 1) * hd];
            oh.fill(0.0);
            for (&p, val_row) in scores.iter().zip(vals.chunks_exact(kvd)) {
                let w = p / sum;
                let vh = &val_row[kvh * hd..(kvh + 1) * hd];
                for (o, &v) in oh.iter_mut().zip(vh) {
                    *o += w * v;
                }
            }
        }
    }
}

/// Split borrow of one layer's key/value buffers.
fn kv_at<'a>(
    k: &'a mut [Vec<f32>],
    v: &'a mut [Vec<f32>],
    li: usize,
) -> (&'a mut Vec<f32>, &'a mut Vec<f32>) {
    (&mut k[li], &mut v[li])
}

/// RMSNorm one row.  Order matters (mirrored by the reference):
/// ascending sum of squares, `inv = 1/sqrt(ss/n + eps)`, then
/// `x · inv · gain` left to right.
fn rms_norm_row(x: &[f32], gains: &[f32], eps: f32, out: &mut [f32]) {
    let mut ss = 0.0f32;
    for &v in x {
        ss += v * v;
    }
    let inv = 1.0 / ((ss / x.len() as f32) + eps).sqrt();
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(gains) {
        *o = v * inv * g;
    }
}

/// Llama-style half-split rotary embedding over `heads` contiguous
/// heads of `head_dim` floats.  Order matters (mirrored by the
/// reference): `freq = 1/theta^(2i/hd)`, separate `.sin()`/`.cos()`
/// calls, rotate `(x[i], x[i+hd/2])`.
fn rope_rotate(x: &mut [f32], heads: usize, head_dim: usize, pos: usize, theta: f32) {
    let half = head_dim / 2;
    for h in 0..heads {
        let base = h * head_dim;
        for i in 0..half {
            let freq = 1.0f32 / theta.powf((2 * i) as f32 / head_dim as f32);
            let ang = pos as f32 * freq;
            let (sin, cos) = (ang.sin(), ang.cos());
            let a = x[base + i];
            let b = x[base + i + half];
            x[base + i] = a * cos - b * sin;
            x[base + i + half] = a * sin + b * cos;
        }
    }
}

/// SiLU, the BitNet MLP activation: `x / (1 + e^(-x))` (mirrored by
/// the reference).
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model(engine: LinearEngine) -> TernaryTransformer {
        let ckpt = Checkpoint::synthesize(TransformerConfig::toy(), 0xAB).unwrap();
        TernaryTransformer::from_checkpoint(&ckpt, engine).unwrap()
    }

    #[test]
    fn batched_prefill_matches_token_by_token() {
        let m = toy_model(LinearEngine::native(IsaConfig::C2, 1).unwrap());
        let prompt = [3i32, 19, 7, 250];
        let mut kv_batched = m.new_kv();
        let batched = m.forward(&prompt, &mut kv_batched).unwrap();
        let mut kv_seq = m.new_kv();
        let mut seq = Vec::new();
        for &t in &prompt {
            seq = m.forward(&[t], &mut kv_seq).unwrap();
        }
        assert_eq!(batched, seq, "batched prefill diverged from sequential");
        assert_eq!(kv_batched.len(), kv_seq.len());
        assert_eq!(kv_batched.k, kv_seq.k, "KV caches diverged");
    }

    #[test]
    fn decode_round_matches_serialized_decode() {
        let m = toy_model(LinearEngine::native(IsaConfig::C2, 1).unwrap());
        // Three sequences with different histories and lengths.
        let prompts: [&[i32]; 3] = [&[1, 2, 3], &[9], &[100, 200]];
        let mut kvs: Vec<ModelKv> = Vec::new();
        for p in prompts {
            let mut kv = m.new_kv();
            m.forward(p, &mut kv).unwrap();
            kvs.push(kv);
        }
        let tokens = [5i32, 6, 7];
        let mut serial_logits = Vec::new();
        let mut serial_kvs = kvs.clone();
        for (kv, &t) in serial_kvs.iter_mut().zip(&tokens) {
            serial_logits.push(m.forward(&[t], kv).unwrap());
        }
        let round = m.decode_round(&tokens, &mut kvs).unwrap();
        assert_eq!(round, serial_logits, "batched round diverged from serialized decode");
        for (a, b) in kvs.iter().zip(&serial_kvs) {
            assert_eq!(a.len(), b.len());
            assert_eq!(a.k, b.k, "KV state diverged between batched and serialized");
            assert_eq!(a.v, b.v);
        }
    }

    #[test]
    fn native_and_modeled_engines_agree_bitwise() {
        let native = toy_model(LinearEngine::native(IsaConfig::C2, 1).unwrap());
        let modeled = toy_model(LinearEngine::modeled(IsaConfig::C2));
        let prompt = [42i32, 17, 99];
        let a = native.forward(&prompt, &mut native.new_kv()).unwrap();
        let b = modeled.forward(&prompt, &mut modeled.new_kv()).unwrap();
        assert_eq!(a, b, "kernel engines diverged on the same checkpoint");
    }

    #[test]
    fn logits_are_finite_and_vocab_sized() {
        let m = toy_model(LinearEngine::native(IsaConfig::C4, 2).unwrap());
        let logits = m.forward(&[0, 255], &mut m.new_kv()).unwrap();
        assert_eq!(logits.len(), m.config().vocab);
        assert!(logits.iter().all(|l| l.is_finite()));
        assert!(logits.iter().any(|&l| l != 0.0));
    }

    #[test]
    fn out_of_vocab_token_rejected() {
        let m = toy_model(LinearEngine::native(IsaConfig::C2, 1).unwrap());
        assert!(m.forward(&[256], &mut m.new_kv()).is_err());
        assert!(m.forward(&[-1], &mut m.new_kv()).is_err());
    }

    #[test]
    fn site_shapes_cover_the_block() {
        let m = toy_model(LinearEngine::native(IsaConfig::C2, 1).unwrap());
        let sites = m.site_shapes();
        let names: Vec<&str> = sites.iter().map(|(s, _)| *s).collect();
        for want in ["wqkv", "wo", "ffn-gate-up", "ffn-down", "lm-head"] {
            assert!(names.contains(&want), "{want} missing from {names:?}");
        }
        assert!(m.weight_bytes() > 0);
    }
}
