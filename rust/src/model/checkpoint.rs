//! The in-tree ternary checkpoint format (DESIGN.md §2 "checkpoint
//! format"): a tiny self-describing binary container holding a
//! transformer architecture header plus per-tensor payloads — f32 for
//! norms/embeddings, ternary bit-planes + one f32 scale for every
//! BitLinear site (1 + 1 bit per weight via
//! [`crate::quant::pack_ternary_planes`]).
//!
//! Checkpoints are either loaded from disk ([`Checkpoint::load`]) or
//! synthesized deterministically from a seed
//! ([`Checkpoint::synthesize`]) — CI and the differential suite never
//! need a weights file, exactly like the zoo's synthetic specs.  The
//! loader is the *only* code shared between the kernel-path
//! [`super::TernaryTransformer`] and the scalar
//! [`super::ReferenceModel`]; everything downstream is implemented
//! twice and pinned together by `tests/model_differential.rs`.
//!
//! ## Layout (all integers little-endian)
//!
//! ```text
//! magic    8  b"TSARCKP1"
//! vocab, d_model, n_layers, n_heads, n_kv_heads, ffn_dim   6 × u32
//! rope_theta, norm_eps                                     2 × f32
//! seed     u64   (synthesis seed; 0 for trained weights)
//! n_tensors u32
//! tensor record ×n:
//!   name_len u16, name bytes
//!   kind u8            0 = f32, 1 = ternary
//!   rows u32, cols u32
//!   f32:     rows·cols × f32
//!   ternary: scale f32, plus-plane, minus-plane (⌈rows·cols/8⌉ bytes each)
//! ```

use std::path::Path;

use crate::quant::{pack_ternary_planes, unpack_ternary_planes};
use crate::util::error::{Context, Result};
use crate::util::rng::Rng;

const MAGIC: &[u8; 8] = b"TSARCKP1";

/// Architecture header of a checkpoint — the model-shape fields a
/// forward pass needs (the serving-window fields live in
/// [`crate::runtime::ModelConfig`], set at backend construction).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransformerConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// Grouped-query KV heads (divides `n_heads`).
    pub n_kv_heads: usize,
    pub ffn_dim: usize,
    /// Rotary position embedding base.
    pub rope_theta: f32,
    /// RMSNorm epsilon.
    pub norm_eps: f32,
}

impl TransformerConfig {
    /// The seeded toy architecture the quickstart and CI default to:
    /// big enough to exercise GQA and multi-layer residuals, small
    /// enough that debug-mode differential fuzzing stays in seconds.
    pub fn toy() -> TransformerConfig {
        TransformerConfig {
            vocab: 256,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            ffn_dim: 96,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Shape sanity: every constraint the forward pass assumes.
    pub fn validate(&self) -> Result<()> {
        crate::ensure!(self.vocab >= 2, "vocab must be >= 2");
        crate::ensure!(
            self.n_layers >= 1 && self.n_heads >= 1 && self.n_kv_heads >= 1,
            "layers/heads must be >= 1"
        );
        crate::ensure!(
            self.d_model >= 2 && self.ffn_dim >= 1,
            "d_model/ffn_dim must be positive"
        );
        crate::ensure!(
            self.d_model % self.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            self.d_model,
            self.n_heads
        );
        crate::ensure!(
            self.n_heads % self.n_kv_heads == 0,
            "n_heads {} not divisible by n_kv_heads {}",
            self.n_heads,
            self.n_kv_heads
        );
        crate::ensure!(
            self.head_dim() % 2 == 0,
            "head_dim {} must be even for rotary embedding",
            self.head_dim()
        );
        crate::ensure!(
            self.rope_theta > 1.0 && self.norm_eps > 0.0,
            "rope_theta must exceed 1 and norm_eps must be positive"
        );
        Ok(())
    }
}

/// One tensor's payload.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    /// Full-precision (norm gains, token embedding).
    F32(Vec<f32>),
    /// A BitLinear site: ternary weights plus the absmean scale.
    Ternary { scale: f32, w: Vec<i8> },
}

/// One named `rows × cols` tensor (row-major; a GEMV weight is
/// `out_features × in_features`, vectors are `1 × n`).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub data: TensorData,
}

/// A loaded or synthesized model checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub config: TransformerConfig,
    /// Synthesis seed (0 when the tensors came from a trained model).
    pub seed: u64,
    tensors: Vec<Tensor>,
}

impl Checkpoint {
    /// The fixed tensor-name schedule of one model: `embed`, then per
    /// layer `layer{i}.{attn_norm,wqkv,wo,ffn_norm,wgateup,wdown}`,
    /// then `final_norm`, `lm_head`.
    fn expected_names(config: &TransformerConfig) -> Vec<(String, usize, usize, bool)> {
        let d = config.d_model;
        let kv = config.kv_dim();
        let f = config.ffn_dim;
        let mut names = vec![("embed".to_string(), config.vocab, d, false)];
        for l in 0..config.n_layers {
            names.push((format!("layer{l}.attn_norm"), 1, d, false));
            names.push((format!("layer{l}.wqkv"), d + 2 * kv, d, true));
            names.push((format!("layer{l}.wo"), d, d, true));
            names.push((format!("layer{l}.ffn_norm"), 1, d, false));
            names.push((format!("layer{l}.wgateup"), 2 * f, d, true));
            names.push((format!("layer{l}.wdown"), d, f, true));
        }
        names.push(("final_norm".to_string(), 1, d, false));
        names.push(("lm_head".to_string(), config.vocab, d, true));
        names
    }

    /// Deterministic random initialization: same `(config, seed)` →
    /// bit-identical tensors on every platform.  Ternary sites draw a
    /// ~60%-nonzero weight matrix with an absmean-style scale around
    /// `1/sqrt(cols)` (so activations keep unit-order magnitude through
    /// the residual stream), norms sit near 1, and the embedding is a
    /// small-variance normal.
    pub fn synthesize(config: TransformerConfig, seed: u64) -> Result<Checkpoint> {
        config.validate()?;
        let mut rng = Rng::new(seed ^ 0x7E5A_9C0D_E5EE_D001);
        let tensors = Checkpoint::expected_names(&config)
            .into_iter()
            .map(|(name, rows, cols, ternary)| {
                let data = if ternary {
                    let w = rng.ternary_matrix(rows, cols, 0.4);
                    let jitter = 0.75 + 0.5 * rng.f64() as f32;
                    let scale = jitter / (cols as f32).sqrt();
                    TensorData::Ternary { scale, w }
                } else if name.ends_with("norm") {
                    let g = (0..rows * cols)
                        .map(|_| 1.0 + 0.1 * rng.normal() as f32)
                        .collect();
                    TensorData::F32(g)
                } else {
                    let e = (0..rows * cols).map(|_| 0.3 * rng.normal() as f32).collect();
                    TensorData::F32(e)
                };
                Tensor { name, rows, cols, data }
            })
            .collect();
        Ok(Checkpoint { config, seed, tensors })
    }

    /// Look up a tensor by name.
    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| crate::err!("checkpoint has no tensor {name:?}"))
    }

    /// An f32 tensor's values, validated against the expected length.
    pub fn f32_tensor(&self, name: &str, len: usize) -> Result<&[f32]> {
        let t = self.tensor(name)?;
        match &t.data {
            TensorData::F32(v) => {
                crate::ensure!(
                    v.len() == len,
                    "tensor {name:?} holds {} values, expected {len}",
                    v.len()
                );
                Ok(v)
            }
            TensorData::Ternary { .. } => crate::bail!("tensor {name:?} is ternary, expected f32"),
        }
    }

    /// A ternary tensor's `(weights, scale)`, validated as `rows × cols`.
    pub fn ternary_tensor(&self, name: &str, rows: usize, cols: usize) -> Result<(&[i8], f32)> {
        let t = self.tensor(name)?;
        crate::ensure!(
            t.rows == rows && t.cols == cols,
            "tensor {name:?} is {}x{}, expected {rows}x{cols}",
            t.rows,
            t.cols
        );
        match &t.data {
            TensorData::Ternary { scale, w } => Ok((w, *scale)),
            TensorData::F32(_) => crate::bail!("tensor {name:?} is f32, expected ternary"),
        }
    }

    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    /// Total parameter count across all tensors.
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.rows * t.cols).sum()
    }

    /// Serialize to the binary container format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        let c = &self.config;
        for v in [c.vocab, c.d_model, c.n_layers, c.n_heads, c.n_kv_heads, c.ffn_dim] {
            out.extend_from_slice(&(v as u32).to_le_bytes());
        }
        out.extend_from_slice(&c.rope_theta.to_le_bytes());
        out.extend_from_slice(&c.norm_eps.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for t in &self.tensors {
            out.extend_from_slice(&(t.name.len() as u16).to_le_bytes());
            out.extend_from_slice(t.name.as_bytes());
            match &t.data {
                TensorData::F32(v) => {
                    out.push(0);
                    out.extend_from_slice(&(t.rows as u32).to_le_bytes());
                    out.extend_from_slice(&(t.cols as u32).to_le_bytes());
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                TensorData::Ternary { scale, w } => {
                    out.push(1);
                    out.extend_from_slice(&(t.rows as u32).to_le_bytes());
                    out.extend_from_slice(&(t.cols as u32).to_le_bytes());
                    out.extend_from_slice(&scale.to_le_bytes());
                    let (plus, minus) = pack_ternary_planes(w);
                    out.extend_from_slice(&plus);
                    out.extend_from_slice(&minus);
                }
            }
        }
        out
    }

    /// Parse the binary container, validating the header, every
    /// tensor's size, plane disjointness, and that no trailing bytes
    /// remain.
    pub fn parse(bytes: &[u8]) -> Result<Checkpoint> {
        let mut r = Reader { bytes, off: 0 };
        let magic = r.take(8)?;
        crate::ensure!(magic == MAGIC, "bad checkpoint magic (not a TSARCKP1 file)");
        let vocab = r.u32()? as usize;
        let d_model = r.u32()? as usize;
        let n_layers = r.u32()? as usize;
        let n_heads = r.u32()? as usize;
        let n_kv_heads = r.u32()? as usize;
        let ffn_dim = r.u32()? as usize;
        let rope_theta = r.f32()?;
        let norm_eps = r.f32()?;
        let config = TransformerConfig {
            vocab,
            d_model,
            n_layers,
            n_heads,
            n_kv_heads,
            ffn_dim,
            rope_theta,
            norm_eps,
        };
        config.validate()?;
        let seed = r.u64()?;
        let n_tensors = r.u32()? as usize;
        let mut tensors = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let name_len = r.u16()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())
                .map_err(|_| crate::err!("tensor name is not UTF-8"))?;
            let kind = r.take(1)?[0];
            let rows = r.u32()? as usize;
            let cols = r.u32()? as usize;
            let n = rows
                .checked_mul(cols)
                .filter(|&n| n >= 1)
                .ok_or_else(|| crate::err!("tensor {name:?} has degenerate shape {rows}x{cols}"))?;
            let data = match kind {
                0 => {
                    let mut v = Vec::with_capacity(n);
                    for _ in 0..n {
                        v.push(r.f32()?);
                    }
                    TensorData::F32(v)
                }
                1 => {
                    let scale = r.f32()?;
                    crate::ensure!(
                        scale.is_finite() && scale > 0.0,
                        "tensor {name:?} has non-positive scale {scale}"
                    );
                    let planes = n.div_ceil(8);
                    let plus = r.take(planes)?.to_vec();
                    let minus = r.take(planes)?.to_vec();
                    let w = unpack_ternary_planes(&plus, &minus, n)
                        .with_context(|| format!("tensor {name:?}"))?;
                    TensorData::Ternary { scale, w }
                }
                other => crate::bail!("tensor {name:?} has unknown kind {other}"),
            };
            tensors.push(Tensor { name, rows, cols, data });
        }
        crate::ensure!(
            r.off == bytes.len(),
            "{} trailing bytes after the last tensor",
            bytes.len() - r.off
        );
        Ok(Checkpoint { config, seed, tensors })
    }

    /// Write to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    /// Read and parse a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Checkpoint::parse(&bytes).with_context(|| format!("parsing {}", path.display()))
    }
}

/// Bounds-checked little-endian cursor over the container bytes.
struct Reader<'a> {
    bytes: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        crate::ensure!(
            self.off + n <= self.bytes.len(),
            "checkpoint truncated at byte {} (wanted {n} more)",
            self.off
        );
        let s = &self.bytes[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toy_config_is_valid() {
        TransformerConfig::toy().validate().unwrap();
        assert_eq!(TransformerConfig::toy().head_dim(), 16);
        assert_eq!(TransformerConfig::toy().kv_dim(), 32);
    }

    #[test]
    fn synthesis_is_deterministic() {
        let a = Checkpoint::synthesize(TransformerConfig::toy(), 42).unwrap();
        let b = Checkpoint::synthesize(TransformerConfig::toy(), 42).unwrap();
        assert_eq!(a, b);
        let c = Checkpoint::synthesize(TransformerConfig::toy(), 43).unwrap();
        assert_ne!(a, c, "different seeds must give different weights");
    }

    #[test]
    fn roundtrip_preserves_every_tensor() {
        let ckpt = Checkpoint::synthesize(TransformerConfig::toy(), 7).unwrap();
        let back = Checkpoint::parse(&ckpt.to_bytes()).unwrap();
        assert_eq!(ckpt, back);
    }

    #[test]
    fn tensor_schedule_covers_the_block() {
        let ckpt = Checkpoint::synthesize(TransformerConfig::toy(), 1).unwrap();
        for name in ["embed", "layer0.wqkv", "layer1.wdown", "final_norm", "lm_head"] {
            assert!(ckpt.tensor(name).is_ok(), "{name} missing");
        }
        let cfg = ckpt.config;
        let (w, scale) = ckpt
            .ternary_tensor("layer0.wqkv", cfg.d_model + 2 * cfg.kv_dim(), cfg.d_model)
            .unwrap();
        assert!(scale > 0.0);
        assert!(w.iter().all(|&x| (-1..=1).contains(&x)));
        assert!(ckpt.param_count() > cfg.vocab * cfg.d_model);
    }

    #[test]
    fn parse_rejects_corruption() {
        let ckpt = Checkpoint::synthesize(TransformerConfig::toy(), 3).unwrap();
        let bytes = ckpt.to_bytes();
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Checkpoint::parse(&bad).is_err());
        // Truncation.
        assert!(Checkpoint::parse(&bytes[..bytes.len() - 3]).is_err());
        // Trailing junk.
        let mut long = bytes.clone();
        long.push(0);
        assert!(Checkpoint::parse(&long).is_err());
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = TransformerConfig::toy();
        c.n_heads = 3; // d_model 64 not divisible
        assert!(Checkpoint::synthesize(c, 0).is_err());
        let mut c = TransformerConfig::toy();
        c.n_kv_heads = 3; // does not divide n_heads 4
        assert!(c.validate().is_err());
    }

    #[test]
    fn save_load_file() {
        let dir = std::env::temp_dir().join("tsar-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.tsarckp");
        let ckpt = Checkpoint::synthesize(TransformerConfig::toy(), 9).unwrap();
        ckpt.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ckpt);
        std::fs::remove_file(&path).ok();
    }
}
