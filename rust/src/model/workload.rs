//! Per-phase workload extraction: the GEMM/GEMV shapes one token (decode)
//! or one N-token prefill executes, per the BitLinear layout of Fig. 2(a).

use crate::sim::GemmShape;

use super::zoo::ModelSpec;

/// One BitLinear operation instance within a forward pass.
#[derive(Debug, Clone)]
pub struct LayerOp {
    /// Human-readable site, e.g. "wqkv", "wo", "ffn-gate-up", "ffn-down".
    pub site: &'static str,
    pub shape: GemmShape,
    /// How many times the whole model runs this op per forward pass
    /// (= layer count for per-layer ops, 1 for the LM head).
    pub count: usize,
}

/// The BitLinear workload of one forward pass.
#[derive(Debug, Clone)]
pub struct Workload {
    pub model: &'static str,
    /// Batch/sequence dimension (1 = decode, 128 = paper's prefill).
    pub n: usize,
    pub ops: Vec<LayerOp>,
}

impl Workload {
    /// Build the workload for `spec` with batch-rows `n`.
    ///
    /// Projections are fused the way optimized ternary runtimes execute
    /// them: Q/K/V as one (d → d + 2·kv) GEMM, FFN gate/up as one
    /// (d → 2·ffn) GEMM (this fusion is what makes the paper's
    /// 1×8192×45568 example shape appear).
    pub fn new(spec: &'static ModelSpec, n: usize) -> Workload {
        let d = spec.d_model;
        let kv = spec.kv_dim();
        let f = spec.ffn_dim;
        let ops = vec![
            LayerOp {
                site: "wqkv",
                shape: GemmShape::new(n, d, d + 2 * kv),
                count: spec.layers,
            },
            LayerOp {
                site: "wo",
                shape: GemmShape::new(n, d, d),
                count: spec.layers,
            },
            LayerOp {
                site: "ffn-gate-up",
                shape: GemmShape::new(n, d, 2 * f),
                count: spec.layers,
            },
            LayerOp {
                site: "ffn-down",
                shape: GemmShape::new(n, f, d),
                count: spec.layers,
            },
            LayerOp {
                site: "lm-head",
                shape: GemmShape::new(n, d, spec.vocab),
                count: 1,
            },
        ];
        Workload { model: spec.name, n, ops }
    }

    pub fn decode(spec: &'static ModelSpec) -> Workload {
        Workload::new(spec, 1)
    }

    pub fn prefill(spec: &'static ModelSpec, n: usize) -> Workload {
        Workload::new(spec, n)
    }

    /// Total dense-equivalent MACs of the pass.
    pub fn total_macs(&self) -> f64 {
        self.ops
            .iter()
            .map(|op| op.shape.macs() * op.count as f64)
            .sum()
    }

    /// Total BitLinear weight bytes touched per pass (2 b/w packing).
    pub fn weight_bytes(&self) -> f64 {
        self.ops
            .iter()
            .map(|op| op.shape.k as f64 * op.shape.m as f64 / 4.0 * op.count as f64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::by_name;

    #[test]
    fn decode_workload_of_2b() {
        let w = Workload::decode(by_name("BitNet-2B-4T").unwrap());
        assert!(w.ops.iter().all(|op| op.shape.n == 1));
        let gate_up = w.ops.iter().find(|o| o.site == "ffn-gate-up").unwrap();
        assert_eq!(gate_up.shape, GemmShape::new(1, 2560, 2 * 6912));
        assert_eq!(gate_up.count, 30);
    }

    #[test]
    fn hundred_b_fused_ffn_shape() {
        let w = Workload::decode(by_name("BitNet-100B").unwrap());
        let gate_up = w.ops.iter().find(|o| o.site == "ffn-gate-up").unwrap();
        assert_eq!(gate_up.shape, GemmShape::new(1, 8192, 45568));
    }

    #[test]
    fn macs_track_param_count() {
        // One decode pass ≈ params MACs (embedding excluded, GQA slack).
        let spec = by_name("BitNet-7B").unwrap();
        let w = Workload::decode(spec);
        let ratio = w.total_macs() / spec.param_count();
        assert!((0.8..1.1).contains(&ratio), "ratio {ratio:.2}");
    }

    #[test]
    fn prefill_scales_n() {
        let spec = by_name("BitNet-125M").unwrap();
        let d = Workload::decode(spec);
        let p = Workload::prefill(spec, 128);
        assert!((p.total_macs() / d.total_macs() - 128.0).abs() < 1e-9);
    }
}
