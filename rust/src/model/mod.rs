//! Ternary-LLM architecture descriptions, per-phase workload
//! extraction (paper §IV-A: BitNet models 125M–100B, Llama-b1.58-8B,
//! Falcon3-b1.58-10B) — and the *executable* model: the in-tree
//! checkpoint format ([`checkpoint`]), the kernel-path BitNet block
//! ([`transformer`]), its pure-scalar ground truth ([`reference`]),
//! and token sampling ([`sample`]).  The two forward-pass
//! implementations share only the checkpoint loader and are pinned
//! together by `tests/model_differential.rs`.

pub mod checkpoint;
pub mod reference;
pub mod sample;
pub mod transformer;
pub mod workload;
pub mod zoo;

pub use checkpoint::{Checkpoint, Tensor, TensorData, TransformerConfig};
pub use reference::ReferenceModel;
pub use sample::{sample_token, SamplerConfig};
pub use transformer::{LinearEngine, ModelKv, TernaryTransformer};
pub use workload::{LayerOp, Workload};
pub use zoo::{ModelSpec, MODEL_ZOO};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_is_ordered_by_size() {
        let sizes: Vec<f64> = MODEL_ZOO.iter().map(|m| m.param_count()).collect();
        // The BitNet family (everything before the two named models) is
        // monotonically increasing.
        let bitnet: Vec<f64> = MODEL_ZOO
            .iter()
            .filter(|m| m.name.starts_with("BitNet"))
            .map(|m| m.param_count())
            .collect();
        for w in bitnet.windows(2) {
            assert!(w[0] < w[1], "zoo must grow monotonically");
        }
        assert!(sizes.len() >= 8);
    }
}
