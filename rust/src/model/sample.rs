//! Token sampling over model logits: greedy argmax, or seeded
//! temperature / top-k sampling.
//!
//! Determinism contract (what `tests/model_serve.rs` pins): the sampled
//! token is a pure function of `(sampler config, logits, history)` —
//! the RNG is re-seeded per step from an FNV-1a fold of the history
//! (the same scheme as `runtime::synthetic_next_token`), never from
//! shared mutable state.  Worker count, batching, and scheduling order
//! therefore cannot perturb a sequence's tokens.  Both the kernel-path
//! [`super::TernaryTransformer`] stack and the scalar
//! [`super::ReferenceModel`] call this one implementation, so sampling
//! cannot be a source of differential drift.

use crate::util::rng::Rng;

/// Sampling parameters of one serving session.
#[derive(Debug, Clone, Copy)]
pub struct SamplerConfig {
    /// `<= 0` means greedy argmax (the default, and what the
    /// differential/serving determinism tests run).
    pub temperature: f32,
    /// Restrict sampling to the `top_k` highest logits; `0` means the
    /// whole vocabulary.  Ignored under greedy decoding.
    pub top_k: usize,
    /// Stream seed folded with the token history per step.
    pub seed: u64,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig { temperature: 0.0, top_k: 0, seed: 0 }
    }
}

impl SamplerConfig {
    pub fn greedy() -> SamplerConfig {
        SamplerConfig::default()
    }

    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

/// Greedy argmax with first-max tie-breaking — the shared deterministic
/// rule both model implementations resolve f32 ties by.
pub fn argmax(logits: &[f32]) -> i32 {
    assert!(!logits.is_empty());
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// Sample the next token from `logits` given the token `history` (the
/// prompt plus everything generated so far).
pub fn sample_token(cfg: &SamplerConfig, logits: &[f32], history: &[i32]) -> i32 {
    assert!(!logits.is_empty());
    if cfg.is_greedy() {
        return argmax(logits);
    }
    // Candidate set: the top_k highest logits (all of them for 0 /
    // oversized k), ties broken toward the lower index so the set is
    // deterministic.
    let mut order: Vec<usize> = (0..logits.len()).collect();
    order.sort_by(|&a, &b| {
        logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let k = if cfg.top_k == 0 { logits.len() } else { cfg.top_k.min(logits.len()) };
    let cand = &order[..k];
    // Softmax over logits / temperature (max-subtracted for stability).
    let max = cand.iter().map(|&i| logits[i] / cfg.temperature).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f32> =
        cand.iter().map(|&i| (logits[i] / cfg.temperature - max).exp()).collect();
    let total: f32 = weights.iter().sum();
    // One draw from a per-step RNG seeded by (seed, history): an FNV-1a
    // fold, so the step depends only on the sequence so far.
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ cfg.seed;
    for &t in history {
        h = (h ^ t as u32 as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    let draw = Rng::new(h).f64() as f32 * total;
    let mut acc = 0.0f32;
    for (&i, &w) in cand.iter().zip(&weights) {
        acc += w;
        if draw < acc {
            return i as i32;
        }
    }
    cand[k - 1] as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_takes_first_max() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        let cfg = SamplerConfig::greedy();
        assert_eq!(sample_token(&cfg, &[0.1, 0.9, 0.2], &[5, 6]), 1);
    }

    #[test]
    fn sampling_is_history_deterministic() {
        let cfg = SamplerConfig { temperature: 0.8, top_k: 3, seed: 99 };
        let logits = [0.5f32, 1.5, -0.2, 2.0, 0.0];
        let a = sample_token(&cfg, &logits, &[1, 2, 3]);
        let b = sample_token(&cfg, &logits, &[1, 2, 3]);
        assert_eq!(a, b);
        assert!((0..5).contains(&a));
    }

    #[test]
    fn different_histories_can_diverge() {
        let cfg = SamplerConfig { temperature: 1.0, top_k: 0, seed: 7 };
        let logits: Vec<f32> = (0..64).map(|i| (i % 7) as f32 * 0.3).collect();
        let picks: std::collections::HashSet<i32> =
            (0..32).map(|t| sample_token(&cfg, &logits, &[t])).collect();
        assert!(picks.len() > 1, "sampling never varied across histories");
    }

    #[test]
    fn top_k_restricts_support() {
        let cfg = SamplerConfig { temperature: 0.5, top_k: 2, seed: 3 };
        let logits = [10.0f32, -50.0, 9.0, -60.0];
        for t in 0..50 {
            let tok = sample_token(&cfg, &logits, &[t]);
            assert!(tok == 0 || tok == 2, "token {tok} outside the top-2 set");
        }
    }

    #[test]
    fn zero_temperature_matches_argmax_everywhere() {
        let logits: Vec<f32> = (0..17).map(|i| ((i * 31) % 13) as f32).collect();
        let cfg = SamplerConfig { temperature: 0.0, top_k: 4, seed: 1 };
        assert_eq!(sample_token(&cfg, &logits, &[9]), argmax(&logits));
    }
}
