//! Functional model of an AVX2-class 256-bit SIMD slice (paper §III-C).
//!
//! The paper's TLUT/TGEMV instructions reuse the *existing* datapath: 16
//! lanes of 16-bit ALUs plus the 4:1 adder trees (ADTs) that dot-product
//! instructions (`vpmaddwd`-style) already contain.  This module models
//! exactly that hardware — a register file of 256-bit YMM registers, the
//! 16×16-bit lane ALUs and the s-to-1 adder-tree reduction — so the
//! [`crate::tsar`] instruction semantics execute on the same structures
//! the paper's µ-ops use, and overflow behaviour is bit-faithful
//! (wrapping 16-bit lanes, 32-bit accumulators).

/// One 256-bit architectural register viewed as 16 × 16-bit lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ymm(pub [i16; 16]);

impl Ymm {
    pub const ZERO: Ymm = Ymm([0; 16]);

    pub fn splat(v: i16) -> Ymm {
        Ymm([v; 16])
    }

    /// Lane-wise wrapping add (vpaddw semantics).
    pub fn add(self, rhs: Ymm) -> Ymm {
        let mut out = [0i16; 16];
        for i in 0..16 {
            out[i] = self.0[i].wrapping_add(rhs.0[i]);
        }
        Ymm(out)
    }

    /// Lane-wise wrapping subtract (vpsubw semantics).
    pub fn sub(self, rhs: Ymm) -> Ymm {
        let mut out = [0i16; 16];
        for i in 0..16 {
            out[i] = self.0[i].wrapping_sub(rhs.0[i]);
        }
        Ymm(out)
    }

    /// Lane-wise wrapping negate.
    pub fn neg(self) -> Ymm {
        let mut out = [0i16; 16];
        for i in 0..16 {
            out[i] = self.0[i].wrapping_neg();
        }
        Ymm(out)
    }
}

/// The 4:1 adder tree (ADT) stage the AVX2 dot-product path provides:
/// reduces four 16-bit lanes into one 32-bit partial sum.  TGEMV's
/// "m = 16 s-to-1 ADT operations" are compositions of this primitive.
pub fn adt4(lanes: [i16; 4]) -> i32 {
    lanes.iter().map(|&x| x as i32).sum()
}

/// s-to-1 adder tree over up to 16 lanes (s ∈ {4, 8, 16} in the paper's
/// configurations), built from adt4 stages like the hardware.
pub fn adt(lanes: &[i16]) -> i32 {
    assert!(lanes.len() <= 16 && !lanes.is_empty());
    let mut acc = 0i32;
    for chunk in lanes.chunks(4) {
        let mut four = [0i16; 4];
        four[..chunk.len()].copy_from_slice(chunk);
        acc += adt4(four);
    }
    acc
}

/// Architectural register file: 16 YMM registers (x86-64 AVX2).
#[derive(Debug, Clone)]
pub struct RegFile {
    regs: [Ymm; 16],
    /// Write counter per register — lets tests assert the µ-op sequences
    /// touch exactly the registers the paper's Fig. 6 encodings claim.
    pub writes: [usize; 16],
}

impl Default for RegFile {
    fn default() -> Self {
        Self::new()
    }
}

impl RegFile {
    pub fn new() -> Self {
        RegFile { regs: [Ymm::ZERO; 16], writes: [0; 16] }
    }

    pub fn read(&self, idx: usize) -> Ymm {
        assert!(idx < 16, "YMM index {idx} out of range");
        self.regs[idx]
    }

    pub fn write(&mut self, idx: usize, v: Ymm) {
        assert!(idx < 16, "YMM index {idx} out of range");
        self.regs[idx] = v;
        self.writes[idx] += 1;
    }

    /// Read a register *pair* (the paper's dst=0x1000 ⇒ YMM8:9 register-
    /// pair convention, Fig. 6(d)): returns 32 16-bit lanes.
    pub fn read_pair(&self, base: usize) -> [i16; 32] {
        assert!(base + 1 < 16, "register pair {base}:{} out of range", base + 1);
        let mut out = [0i16; 32];
        out[..16].copy_from_slice(&self.read(base).0);
        out[16..].copy_from_slice(&self.read(base + 1).0);
        out
    }

    pub fn write_pair(&mut self, base: usize, lanes: [i16; 32]) {
        let mut lo = [0i16; 16];
        let mut hi = [0i16; 16];
        lo.copy_from_slice(&lanes[..16]);
        hi.copy_from_slice(&lanes[16..]);
        self.write(base, Ymm(lo));
        self.write(base + 1, Ymm(hi));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_ops_wrap() {
        let a = Ymm::splat(i16::MAX);
        let b = Ymm::splat(1);
        assert_eq!(a.add(b).0[0], i16::MIN); // wrapping, like vpaddw
        assert_eq!(b.sub(a).0[5], 1i16.wrapping_sub(i16::MAX));
    }

    #[test]
    fn adt_matches_scalar_sum() {
        let lanes: Vec<i16> = (1..=16).collect();
        assert_eq!(adt(&lanes), (1..=16).sum::<i32>());
        assert_eq!(adt4([1000, -1000, 30000, 30000]), 60000); // no 16-bit overflow
    }

    #[test]
    fn regfile_pairs() {
        let mut rf = RegFile::new();
        let mut lanes = [0i16; 32];
        for (i, l) in lanes.iter_mut().enumerate() {
            *l = i as i16;
        }
        rf.write_pair(8, lanes);
        assert_eq!(rf.read(8).0[0], 0);
        assert_eq!(rf.read(9).0[15], 31);
        assert_eq!(rf.read_pair(8), lanes);
        assert_eq!(rf.writes[8], 1);
        assert_eq!(rf.writes[9], 1);
        assert_eq!(rf.writes[0], 0);
    }

    #[test]
    #[should_panic]
    fn pair_out_of_range() {
        RegFile::new().read_pair(15);
    }
}
