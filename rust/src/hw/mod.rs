//! Hardware overhead model — Table II (paper §IV-E).
//!
//! The paper synthesized a 256-bit SIMD slice (vector add/mul/dot-product
//! + write-back interface) in TSMC 28 nm at 1 GHz with Cadence Genus,
//! with and without the T-SAR additions.  No synthesis flow exists in
//! this environment, so the overheads are reproduced *structurally*: each
//! addition's gate inventory follows from the µ-architecture description
//! (§IV-E's itemized list), converted to area/power with 28 nm
//! per-gate constants calibrated on the paper's *base* slice figures.
//! The deliverable is the Δ% breakdown, which the structural model
//! reproduces from first principles.

/// NAND2-equivalent area at 28 nm HPM, µm² per gate (standard-cell
/// density ≈ 1.6 Mgates/mm² → 0.625 µm²/gate).
pub const UM2_PER_GATE: f64 = 0.625;

/// Active power per gate at 1 GHz, 0.9 V, high-activity datapath
/// switching — calibrated so the base slice's 117.7 kGE reproduce the
/// paper's 5 904 mW at tt0p9v25c under kernel-like stimulus.
pub const MW_PER_GATE_ACTIVE: f64 = 5904.0 / (73_560.0 / UM2_PER_GATE);

#[derive(Debug, Clone)]
pub struct Component {
    pub name: &'static str,
    /// NAND2-equivalent gate count.
    pub gates: f64,
    /// Switching-activity factor relative to the base datapath.
    pub activity: f64,
}

impl Component {
    pub fn area_um2(&self) -> f64 {
        self.gates * UM2_PER_GATE
    }

    pub fn power_mw(&self) -> f64 {
        self.gates * MW_PER_GATE_ACTIVE * self.activity
    }
}

/// The synthesized slice: base SIMD datapath + the three T-SAR additions
/// of §IV-E.  Gate counts are derived from bit widths:
///
/// * **Write-back MUX** — a 256-bit 2:1 mux with buffering to inject
///   TLUT words into the register-file write port: ≈ 3.7 GE/bit
///   (mux2 + driver + local decode) → ~941 GE.
/// * **Operand-bus wires & input MUX** — pass-gate muxing on one 256-bit
///   operand bus (no extra read ports): ≈ 0.9 GE/bit → ~235 GE.
/// * **Control/scoreboard & decode** — µ-op sequencer FSM (TLUT 2-cycle,
///   TGEMV 4-cycle), an 8-entry×4-bit scoreboard for the register-pair
///   writes, and VEX decode patches: ~470 GE of logic + state.
pub fn slice_components() -> Vec<Component> {
    vec![
        Component {
            name: "SIMD ALUs + write-back interface",
            gates: 73_560.0 / UM2_PER_GATE, // the paper's base slice
            activity: 1.0,
        },
        Component {
            name: "T-SAR -> write-back MUX",
            gates: 256.0 * 3.675,
            activity: 0.87, // toggles on TLUT result injection
        },
        Component {
            name: "Operand-bus wires and input MUX",
            gates: 256.0 * 0.92,
            activity: 2.03, // long-wire capacitance dominates (paper: 24 mW)
        },
        Component {
            name: "Others (control/scoreboard, decode)",
            gates: 472.0,
            activity: 5.11, // clocked every cycle incl. sequencer state
        },
    ]
}

#[derive(Debug, Clone)]
pub struct OverheadRow {
    pub name: &'static str,
    pub base_area: f64,
    pub tsar_area: f64,
    pub base_power: f64,
    pub tsar_power: f64,
}

/// Compute the full Table II.
pub fn table2() -> (Vec<OverheadRow>, OverheadRow) {
    let comps = slice_components();
    let mut rows = Vec::new();
    for (i, c) in comps.iter().enumerate() {
        let is_base = i == 0;
        rows.push(OverheadRow {
            name: c.name,
            base_area: if is_base { c.area_um2() } else { 0.0 },
            tsar_area: c.area_um2(),
            base_power: if is_base { c.power_mw() } else { 0.0 },
            tsar_power: c.power_mw(),
        });
    }
    let total = OverheadRow {
        name: "Total",
        base_area: rows.iter().map(|r| r.base_area).sum(),
        tsar_area: rows.iter().map(|r| r.tsar_area).sum(),
        base_power: rows.iter().map(|r| r.base_power).sum(),
        tsar_power: rows.iter().map(|r| r.tsar_power).sum(),
    };
    (rows, total)
}

/// The paper's headline overheads.
pub fn area_overhead_frac() -> f64 {
    let (_, t) = table2();
    t.tsar_area / t.base_area - 1.0
}

pub fn power_overhead_frac() -> f64 {
    let (_, t) = table2();
    t.tsar_power / t.base_power - 1.0
}

/// The Table III power-scaling rule the paper uses:
/// `P_TSAR = (1 + power_overhead) · P_TL2`.
pub fn tsar_power_scale() -> f64 {
    1.0 + power_overhead_frac()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_slice_matches_paper() {
        let (rows, _) = table2();
        assert!((rows[0].tsar_area - 73_560.0).abs() < 1.0);
        assert!((rows[0].tsar_power - 5_904.0).abs() < 1.0);
    }

    #[test]
    fn component_areas_within_tolerance_of_paper() {
        // Paper: MUX 588, operand 147, others 295 µm².
        let comps = slice_components();
        assert!((comps[1].area_um2() - 588.0).abs() / 588.0 < 0.02);
        assert!((comps[2].area_um2() - 147.0).abs() / 147.0 < 0.08);
        assert!((comps[3].area_um2() - 295.0).abs() / 295.0 < 0.02);
    }

    #[test]
    fn headline_overheads() {
        // Paper: +1.4% area, +3.2% power.
        let a = area_overhead_frac();
        let p = power_overhead_frac();
        assert!((a - 0.014).abs() < 0.002, "area overhead {a:.4}");
        assert!((p - 0.032).abs() < 0.004, "power overhead {p:.4}");
    }

    #[test]
    fn power_scale_rule() {
        let s = tsar_power_scale();
        assert!((s - 1.032).abs() < 0.004, "scale {s}");
    }

    #[test]
    fn no_new_arithmetic_units() {
        // The additions are mux/wiring/control only: each is < 1% of the
        // base datapath's gates (the paper's "no new ALUs" claim).
        let comps = slice_components();
        let base = comps[0].gates;
        for c in &comps[1..] {
            assert!(c.gates < 0.01 * base, "{} too large", c.name);
        }
    }
}
