//! Functional (bit-faithful) semantics of TLUT_c×s / TGEMV_k×m executed
//! on the modeled SIMD register file.
//!
//! These are the semantics the paper verified "by executing hand-written
//! assembly with byte-pattern encodings" in gem5; here they are executed
//! directly and cross-checked against the scalar ternary dot product in
//! unit/property tests (and transitively against the Python oracle via
//! the shared test vectors in `rust/tests/`).

use crate::config::IsaConfig;
use crate::simd::{adt, RegFile, Ymm};

use super::{lut_lane, lut_regs};

/// Execute `TLUT_c×s dst_group, activations`: build s dense/sparse LUT
/// pairs from `k = c·s` int8 activations into the register group starting
/// at `dst` (paper Fig. 6(b)).
///
/// Dense entry p of block b:  Σ_i (bit_i(p) ? +a[b·c+i] : −a[b·c+i])
/// Sparse entry p of block b: Σ_i (bit_i(p) ?  a[b·c+i] : 0)
///
/// Entries are 16-bit; with int8 activations and c ≤ 4 the sums cannot
/// overflow (|entry| ≤ 4·127).
pub fn tlut(rf: &mut RegFile, cfg: &IsaConfig, dst: usize, acts: &[i8]) {
    assert_eq!(acts.len(), cfg.k, "TLUT consumes k = c*s activations");
    let nregs = lut_regs(cfg);
    assert!(dst + nregs <= 16, "TLUT dst group out of range");

    // Compute all lanes, then commit register by register (µ-op order).
    // Stack buffer: the largest config (c=4, s=4) spans 8 regs = 128
    // lanes — no heap allocation on the per-instruction hot path.
    let total_lanes = cfg.s * cfg.lut_entries_per_block();
    debug_assert!(total_lanes <= 128);
    let mut lanes = [0i16; 128];
    for b in 0..cfg.s {
        let block = &acts[b * cfg.c..(b + 1) * cfg.c];
        for p in 0..1usize << cfg.c {
            let mut dense = 0i16;
            let mut sparse = 0i16;
            for (i, &a) in block.iter().enumerate() {
                let a = a as i16;
                if p >> i & 1 == 1 {
                    dense += a;
                    sparse += a;
                } else {
                    dense -= a;
                }
            }
            lanes[lut_lane(cfg, b, false, p)] = dense;
            lanes[lut_lane(cfg, b, true, p)] = sparse;
        }
    }
    for r in 0..nregs {
        let mut reg = [0i16; 16];
        for (l, slot) in reg.iter_mut().enumerate() {
            let idx = r * 16 + l;
            if idx < total_lanes {
                *slot = lanes[idx];
            }
        }
        rf.write(dst + r, Ymm(reg));
    }
}

/// Weight operand of one TGEMV: per output channel j (0..m), per block b
/// (0..s), a dense index and a sparse index (c bits each) — the
/// pre-encoded compile-time form streamed from memory (Fig. 5).
#[derive(Debug, Clone)]
pub struct TgemvWeights {
    pub wd: Vec<u8>, // m*s entries, row-major [j][b]
    pub ws: Vec<u8>,
}

impl TgemvWeights {
    pub fn new(cfg: &IsaConfig, wd: Vec<u8>, ws: Vec<u8>) -> Self {
        assert_eq!(wd.len(), cfg.m * cfg.s);
        assert_eq!(ws.len(), cfg.m * cfg.s);
        TgemvWeights { wd, ws }
    }

    /// Packed memory size in bytes: 2·c bits per (output, block).
    pub fn packed_bytes(cfg: &IsaConfig) -> usize {
        (cfg.m * cfg.s * 2 * cfg.c).div_ceil(8)
    }
}

/// Execute `TGEMV_k×m acc_pair, lut_group, weights`: gather + subtract +
/// adder-tree reduce + accumulate (paper Fig. 6(c)).
///
/// The m 32-bit accumulators live in `acc` (a `Vec<i32>` standing in for
/// the accumulator register pair; the register-file pressure of real
/// accumulation is modeled by the kernels' register budgets).
pub fn tgemv(
    rf: &RegFile,
    cfg: &IsaConfig,
    lut_base: usize,
    w: &TgemvWeights,
    acc: &mut [i32],
) {
    tgemv_slices(rf, cfg, lut_base, &w.wd, &w.ws, cfg.s, acc)
}

/// `tgemv` over borrowed per-row index slices with an arbitrary row
/// stride — lets kernels stream operands straight from their pre-encoded
/// weight buffers without copying (§Perf L3).
pub fn tgemv_slices(
    rf: &RegFile,
    cfg: &IsaConfig,
    lut_base: usize,
    wd: &[u8],
    ws: &[u8],
    row_stride: usize,
    acc: &mut [i32],
) {
    assert_eq!(acc.len(), cfg.m);
    assert!(row_stride >= cfg.s);
    assert!(wd.len() >= (cfg.m - 1) * row_stride + cfg.s);
    assert_eq!(wd.len(), ws.len());
    let nregs = lut_regs(cfg);
    // Flatten the LUT register group back to lanes (stack buffer).
    debug_assert!(nregs * 16 <= 128);
    let mut lanes = [0i16; 128];
    for r in 0..nregs {
        lanes[r * 16..(r + 1) * 16].copy_from_slice(&rf.read(lut_base + r).0);
    }

    // Pre-resolve the per-block lane bases once (loop-invariant).
    let per_block = cfg.lut_entries_per_block();
    let sparse_off = 1usize << cfg.c;
    for j in 0..cfg.m {
        // s×m subtractions feed m s-to-1 adder trees (§III-C).
        let mut diffs = [0i16; 8];
        debug_assert!(cfg.s <= 8);
        let row_d = &wd[j * row_stride..j * row_stride + cfg.s];
        let row_s = &ws[j * row_stride..j * row_stride + cfg.s];
        for b in 0..cfg.s {
            let d_idx = row_d[b] as usize;
            let s_idx = row_s[b] as usize;
            debug_assert!(d_idx < 1 << cfg.c && s_idx < 1 << cfg.c);
            let base = b * per_block;
            let d = lanes[base + d_idx];
            let s = lanes[base + sparse_off + s_idx];
            diffs[b] = d.wrapping_sub(s);
        }
        acc[j] += adt(&diffs[..cfg.s]);
    }
}

/// Reference scalar ternary dot product used to validate the ISA path.
pub fn scalar_dot(w_row: &[i8], acts: &[i8]) -> i32 {
    w_row
        .iter()
        .zip(acts)
        .map(|(&w, &a)| w as i32 * a as i32)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::encode_indices;
    use crate::util::rng::Rng;

    /// Build TGEMV weight operands from a ternary (m × k) tile.
    fn weights_from_tile(cfg: &IsaConfig, tile: &[i8]) -> TgemvWeights {
        let enc = encode_indices(tile, cfg.m, cfg.k, cfg.c);
        // enc is (m × k/c) = (m × s) — exactly the TGEMV operand layout.
        TgemvWeights::new(cfg, enc.wd, enc.ws)
    }

    fn check_config(cfg: IsaConfig, seed: u64) {
        let mut rng = Rng::new(seed);
        for _ in 0..50 {
            let acts: Vec<i8> = rng.int8_acts(cfg.k);
            let tile = rng.ternary_matrix(cfg.m, cfg.k, 0.33);
            let mut rf = RegFile::new();
            tlut(&mut rf, &cfg, 8, &acts);
            let w = weights_from_tile(&cfg, &tile);
            let mut acc = vec![0i32; cfg.m];
            tgemv(&rf, &cfg, 8, &w, &mut acc);
            for j in 0..cfg.m {
                let want = scalar_dot(&tile[j * cfg.k..(j + 1) * cfg.k], &acts);
                assert_eq!(acc[j], want, "cfg={} output {j}", cfg.name());
            }
        }
    }

    #[test]
    fn tlut_tgemv_matches_scalar_c2() {
        check_config(IsaConfig::C2, 100);
    }

    #[test]
    fn tlut_tgemv_matches_scalar_c4() {
        check_config(IsaConfig::C4, 200);
    }

    #[test]
    fn tgemv_accumulates() {
        // Two TGEMV invocations over two K-slices must equal one dot
        // product over the concatenation (the fused-accumulation claim).
        let cfg = IsaConfig::C2;
        let mut rng = Rng::new(7);
        let acts: Vec<i8> = rng.int8_acts(2 * cfg.k);
        let tile = rng.ternary_matrix(cfg.m, 2 * cfg.k, 0.33);
        let mut acc = vec![0i32; cfg.m];
        for half in 0..2 {
            let a = &acts[half * cfg.k..(half + 1) * cfg.k];
            // Slice the tile columns for this half.
            let mut sub = Vec::with_capacity(cfg.m * cfg.k);
            for j in 0..cfg.m {
                sub.extend_from_slice(
                    &tile[j * 2 * cfg.k + half * cfg.k..j * 2 * cfg.k + (half + 1) * cfg.k],
                );
            }
            let mut rf = RegFile::new();
            tlut(&mut rf, &cfg, 0, a);
            let w = weights_from_tile(&cfg, &sub);
            tgemv(&rf, &cfg, 0, &w, &mut acc);
        }
        for j in 0..cfg.m {
            let want = scalar_dot(&tile[j * 2 * cfg.k..(j + 1) * 2 * cfg.k], &acts);
            assert_eq!(acc[j], want);
        }
    }

    #[test]
    fn tlut_writes_expected_registers() {
        let cfg = IsaConfig::C2;
        let mut rf = RegFile::new();
        tlut(&mut rf, &cfg, 8, &vec![1i8; cfg.k]);
        // Fig. 6(b): TLUT_2x4 writes the YMM8:9 pair, nothing else.
        assert_eq!(rf.writes[8], 1);
        assert_eq!(rf.writes[9], 1);
        assert!(rf.writes.iter().enumerate().all(|(i, &w)| w == 0 || i == 8 || i == 9));
    }

    #[test]
    fn tlut_zero_acts_gives_zero_luts() {
        let cfg = IsaConfig::C4;
        let mut rf = RegFile::new();
        tlut(&mut rf, &cfg, 0, &vec![0i8; cfg.k]);
        for r in 0..super::super::lut_regs(&cfg) {
            assert_eq!(rf.read(r), Ymm::ZERO);
        }
    }

    #[test]
    fn all_zero_weights_give_zero_outputs() {
        let cfg = IsaConfig::C2;
        let mut rng = Rng::new(9);
        let acts = rng.int8_acts(cfg.k);
        let tile = vec![0i8; cfg.m * cfg.k];
        let mut rf = RegFile::new();
        tlut(&mut rf, &cfg, 0, &acts);
        let w = weights_from_tile(&cfg, &tile);
        let mut acc = vec![0i32; cfg.m];
        tgemv(&rf, &cfg, 0, &w, &mut acc);
        assert!(acc.iter().all(|&x| x == 0));
    }
}
