//! The T-SAR ISA extension (paper §III-B/C, Fig. 6).
//!
//! Two register-to-register instructions over the AVX2 SIMD slice:
//!
//! * `TLUT_c×s` — build `s` dense/sparse binary LUT pairs (2^c 16-bit
//!   entries each) from `k = c·s` int8 activations, writing them to a
//!   YMM register group.  Split into ⌈result bits / 256⌉ µ-ops, each
//!   writing one 256-bit register per cycle (§III-C: two µ-ops for the
//!   2×4 configuration).
//! * `TGEMV_k×m` — a (1,k)×(k,m) GEMV: for each of the `m` outputs,
//!   gather one dense and one sparse entry per block (s blocks), apply
//!   the `s×m` subtractions, reduce with `m` s-to-1 adder trees, and
//!   accumulate into a 32-bit accumulator register pair.  Four µ-ops in
//!   the 8×16 configuration.
//!
//! [`exec`] gives the bit-faithful functional semantics on the
//! [`crate::simd::RegFile`]; [`encoding`] the VEX3 byte format of
//! Fig. 6(d); [`uops`] the µ-op sequences the timing simulator charges.

pub mod encoding;
pub mod exec;
pub mod uops;

use crate::config::IsaConfig;

/// LUT register-layout helper shared by exec + tests.
///
/// For block `b` of a TLUT result, dense entry `p` lives at flat 16-bit
/// lane `b * 2^(c+1) + p`, and sparse entry `p` at
/// `b * 2^(c+1) + 2^c + p`, across the destination register group in
/// ascending register order (Fig. 6(b)'s packing).
pub fn lut_lane(cfg: &IsaConfig, block: usize, sparse: bool, entry: usize) -> usize {
    debug_assert!(block < cfg.s);
    debug_assert!(entry < 1 << cfg.c);
    let per_block = cfg.lut_entries_per_block(); // 2^(c+1)
    block * per_block + (sparse as usize) * (1 << cfg.c) + entry
}

/// Number of YMM registers a TLUT destination group spans.
pub fn lut_regs(cfg: &IsaConfig) -> usize {
    cfg.tlut_result_regs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_layout_c2() {
        let c = IsaConfig::C2;
        // block 0: dense entries in lanes 0..4, sparse in 4..8
        assert_eq!(lut_lane(&c, 0, false, 0), 0);
        assert_eq!(lut_lane(&c, 0, true, 0), 4);
        // block 3 sparse entry 3 is the last lane of the 512-bit group
        assert_eq!(lut_lane(&c, 3, true, 3), 31);
        assert_eq!(lut_regs(&c), 2);
    }

    #[test]
    fn lane_layout_c4() {
        let c = IsaConfig::C4;
        assert_eq!(lut_lane(&c, 0, true, 0), 16);
        assert_eq!(lut_lane(&c, 3, true, 15), 127);
        assert_eq!(lut_regs(&c), 8);
    }
}
