//! VEX3 instruction encoding for the T-SAR primitives (paper Fig. 6(d)).
//!
//! Both instructions use the standard 3-byte VEX prefix (0xC4) in the
//! 0F38 opcode map, as AVX2 integer instructions do:
//!
//! ```text
//!   C4 | RXB m-mmmm | W vvvv L pp | opcode | ModRM
//! ```
//!
//! * `TLUT_c×s`  — opcode 0xE0, `vvvv` unused (=0b1111), reg = dst LUT
//!   group base, rm = activation source register.
//! * `TGEMV_k×m` — opcode 0xE1, `vvvv` = LUT group base register,
//!   reg = accumulator pair base, rm = weight source register.
//!
//! Register *groups* follow the paper's pair convention: an encoded
//! register id denotes the group base (e.g. dst=8 with a two-register
//! result uses YMM8:9).  The (c, s) / (k, m) configuration is carried in
//! the two low `pp`+`L` bits (00 ⇒ 2×4/8×16, 01 ⇒ 4×4/16×16), matching
//! the paper's "designed examples" which enumerate fixed configurations
//! rather than a general immediate.

use crate::config::IsaConfig;

pub const OPC_TLUT: u8 = 0xE0;
pub const OPC_TGEMV: u8 = 0xE1;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Opcode {
    Tlut,
    Tgemv,
}

/// A decoded T-SAR instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instruction {
    pub op: Opcode,
    pub cfg_sel: u8, // 0 = C2 config, 1 = C4 config
    /// Destination: TLUT LUT-group base / TGEMV accumulator base.
    pub dst: u8,
    /// vvvv operand: TGEMV LUT-group base (unused for TLUT).
    pub aux: u8,
    /// Source: TLUT activation reg / TGEMV weight stream reg.
    pub src: u8,
}

impl Instruction {
    pub fn isa_config(&self) -> IsaConfig {
        match self.cfg_sel {
            0 => IsaConfig::C2,
            1 => IsaConfig::C4,
            other => panic!("unknown T-SAR config selector {other}"),
        }
    }

    /// Encode to the 5-byte VEX3 form.
    pub fn encode(&self) -> [u8; 5] {
        assert!(self.dst < 16 && self.aux < 16 && self.src < 16);
        assert!(self.cfg_sel < 2);
        let opcode = match self.op {
            Opcode::Tlut => OPC_TLUT,
            Opcode::Tgemv => OPC_TGEMV,
        };
        // Byte 1: R̅X̅B̅ (inverted extension bits) | m-mmmm = 0b00010 (0F38).
        let r_inv = (!(self.dst >> 3)) & 1;
        let b_inv = (!(self.src >> 3)) & 1;
        let byte1 = (r_inv << 7) | (1 << 6) /* X̅=1 */ | (b_inv << 5) | 0b00010;
        // Byte 2: W=0 | v̅v̅v̅v̅ | L=cfg_sel | pp=00.
        let vvvv_inv = (!self.aux) & 0x0F;
        let byte2 = (vvvv_inv << 3) | ((self.cfg_sel & 1) << 2);
        // ModRM: mod=11 (register direct) | reg = dst[2:0] | rm = src[2:0].
        let modrm = 0b1100_0000 | ((self.dst & 0x7) << 3) | (self.src & 0x7);
        [0xC4, byte1, byte2, opcode, modrm]
    }

    /// Decode; rejects non-T-SAR byte patterns.
    pub fn decode(bytes: &[u8]) -> crate::util::error::Result<Instruction> {
        crate::ensure!(bytes.len() >= 5, "short instruction");
        crate::ensure!(bytes[0] == 0xC4, "not a VEX3 prefix");
        let byte1 = bytes[1];
        crate::ensure!(byte1 & 0b11111 == 0b00010, "not map 0F38");
        let op = match bytes[3] {
            OPC_TLUT => Opcode::Tlut,
            OPC_TGEMV => Opcode::Tgemv,
            o => crate::bail!("unknown opcode {o:#x}"),
        };
        let byte2 = bytes[2];
        let modrm = bytes[4];
        crate::ensure!(modrm >> 6 == 0b11, "T-SAR is register-direct");
        let r_inv = byte1 >> 7 & 1;
        let b_inv = byte1 >> 5 & 1;
        let dst = ((1 - r_inv) << 3) | (modrm >> 3 & 0x7);
        let src = ((1 - b_inv) << 3) | (modrm & 0x7);
        let aux = (!(byte2 >> 3)) & 0x0F;
        let cfg_sel = byte2 >> 2 & 1;
        Ok(Instruction { op, cfg_sel, dst, aux, src })
    }
}

/// The paper's worked example encodings (Fig. 6(d)): TLUT_2×4 writing the
/// YMM8:9 pair, and TGEMV_8×16 reading it.
pub fn fig6_examples() -> [Instruction; 2] {
    [
        Instruction { op: Opcode::Tlut, cfg_sel: 0, dst: 8, aux: 0, src: 1 },
        Instruction { op: Opcode::Tgemv, cfg_sel: 0, dst: 2, aux: 8, src: 3 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_fields() {
        for op in [Opcode::Tlut, Opcode::Tgemv] {
            for cfg_sel in 0..2u8 {
                for dst in [0u8, 5, 8, 15] {
                    for src in [0u8, 7, 9] {
                        for aux in [0u8, 8, 14] {
                            let insn = Instruction { op, cfg_sel, dst, aux, src };
                            let dec = Instruction::decode(&insn.encode()).unwrap();
                            assert_eq!(insn, dec);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn fig6_byte_patterns_are_vex3() {
        for insn in fig6_examples() {
            let b = insn.encode();
            assert_eq!(b[0], 0xC4);
            assert_eq!(b[1] & 0b11111, 0b00010); // 0F38 map
            assert_eq!(b[4] >> 6, 0b11); // register-direct
        }
    }

    #[test]
    fn rejects_foreign_bytes() {
        assert!(Instruction::decode(&[0x0F, 0, 0, 0, 0]).is_err());
        assert!(Instruction::decode(&[0xC4, 0b00000010, 0, 0x90, 0xC0]).is_err());
        assert!(Instruction::decode(&[0xC4]).is_err());
    }

    #[test]
    fn register_pair_convention() {
        // dst=8 in the TLUT example denotes the YMM8:9 pair (the paper's
        // "if dst is 0x1000, the operation uses YMM8 and YMM9").
        let insn = fig6_examples()[0];
        assert_eq!(insn.dst, 8);
        assert_eq!(insn.isa_config().tlut_result_regs(), 2);
    }
}
