//! µ-op sequencing and cost model for the T-SAR instructions (§III-C).
//!
//! The paper splits each instruction into µ-ops bounded by the existing
//! 256-bit write-back path (one 256-bit register write per cycle) and the
//! existing ALU/ADT issue capacity:
//!
//! * `TLUT_2×4`: 512-bit result ⇒ **2 µ-ops**, one YMM write each.
//! * `TLUT_4×4`: 2048-bit result ⇒ 8 µ-ops (same rule).
//! * `TGEMV_8×16`: 64 subtractions + 16 4:1 ADTs over the 16-lane ALU
//!   slice ⇒ **4 µ-ops**.
//! * `TGEMV_16×16`: twice the lookup volume ⇒ 8 µ-ops.
//!
//! The timing simulator charges these against the platform's SIMD ports.

use crate::config::IsaConfig;

/// µ-op classes the issue model distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UopClass {
    /// 256-bit SIMD ALU op (add/sub/mux network) with register write-back.
    SimdAlu,
    /// SIMD load (32 B) from the memory hierarchy.
    VecLoad,
    /// SIMD store (32 B).
    VecStore,
    /// Scalar bookkeeping (address generation, loop control).
    Scalar,
}

/// µ-op sequence of one architectural instruction.
#[derive(Debug, Clone)]
pub struct UopSeq {
    pub alu_uops: usize,
}

/// TLUT_c×s µ-op count: one per 256 bits of LUT result written back.
pub fn tlut_uops(cfg: &IsaConfig) -> usize {
    cfg.tlut_result_regs()
}

/// TGEMV_k×m µ-op count.
///
/// Per µ-op the slice retires 16 lanes of subtraction plus a 4:1 ADT
/// pass (the vpmaddwd-class resources).  Work = s·m subtractions and m
/// s-to-1 reductions; each reduction of s values consumes s/4 ADT slots.
pub fn tgemv_uops(cfg: &IsaConfig) -> usize {
    let subs = cfg.s * cfg.m; // 16-bit subtractions
    let adt_slots = cfg.m * cfg.s.div_ceil(4); // 4:1 tree passes
    // 16 sub lanes + 4 ADT outputs retire per µ-op (§III-C: four µ-ops
    // for the 64-sub / 16-ADT 8×16 example).
    let by_subs = subs.div_ceil(16);
    let by_adts = adt_slots.div_ceil(4);
    by_subs.max(by_adts)
}

/// Total SIMD-ALU µ-ops to compute a (1,K)×(K,M) GEMV with the T-SAR
/// instruction pair, excluding loads/stores (charged separately from the
/// kernel's traffic descriptor): one TLUT per k-slice per LUT residency,
/// one TGEMV per (k-slice, m-tile).
pub fn gemv_compute_uops(cfg: &IsaConfig, kk: usize, mm: usize, tlut_invocations: usize) -> usize {
    let k_slices = kk.div_ceil(cfg.k);
    let m_tiles = mm.div_ceil(cfg.m);
    tlut_invocations * tlut_uops(cfg) + k_slices * m_tiles * tgemv_uops(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_uop_counts() {
        // §III-C: TLUT_2×4 = two µ-ops, TGEMV_8×16 = four µ-ops.
        assert_eq!(tlut_uops(&IsaConfig::C2), 2);
        assert_eq!(tgemv_uops(&IsaConfig::C2), 4);
    }

    #[test]
    fn c4_uop_counts_scale() {
        assert_eq!(tlut_uops(&IsaConfig::C4), 8);
        assert_eq!(tgemv_uops(&IsaConfig::C4), 4); // s=4, m=16 same sub/ADT volume
    }

    #[test]
    fn gemv_uops_minimum_tluts() {
        // K=64, M=32 with C2: 8 k-slices, 2 m-tiles.
        let cfg = IsaConfig::C2;
        let uops = gemv_compute_uops(&cfg, 64, 32, 8);
        assert_eq!(uops, 8 * 2 + 8 * 2 * 4);
    }
}
