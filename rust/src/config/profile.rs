//! Platform profiles: Table I rows as *data*, not code.
//!
//! A [`PlatformProfile`] is a versioned JSON document describing one
//! evaluation platform — cache geometry, DRAM bandwidth/latency, SIMD
//! issue width — plus the free constants of the timing model
//! ([`ModelConstants`]) and a provenance record saying where the
//! numbers came from (`table1` for the paper-faithful in-tree rows,
//! `calibrated` for a profile fitted from measured wall-clock by
//! `tsar-cli calibrate`).
//!
//! The three Table I rows ship in-tree under `profiles/` and are
//! embedded into the binary, so `PlatformProfile::by_kind` keeps
//! working offline with zero I/O.  The in-tree documents carry
//! *identity* model constants, which the simulator applies as exact
//! IEEE no-ops (`x * 1.0 == x`, `x + 0.0 == x`): loading them
//! reproduces the hardcoded Table I numbers bit-identically.

use std::path::Path;
use std::sync::OnceLock;

use super::platforms::{CacheLevel, PlatformKind};
use crate::util::json::Json;
use crate::Result;

const WORKSTATION_JSON: &str = include_str!("../../../profiles/workstation.json");
const LAPTOP_JSON: &str = include_str!("../../../profiles/laptop.json");
const MOBILE_JSON: &str = include_str!("../../../profiles/mobile.json");

/// Free constants of the timing model, fitted by `tsar-cli calibrate`.
///
/// The defaults are exact identities: with them, the simulator's
/// arithmetic is bit-identical to the pre-calibration model, so the
/// in-tree Table I profiles reproduce the paper's numbers unchanged.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelConstants {
    /// Multiplier on every modeled cache/DRAM latency (identity 1.0).
    pub latency_scale: f64,
    /// Multiplier on the SIMD issue width `simd_ports` (identity 1.0).
    pub issue_scale: f64,
    /// Per-extra-thread DRAM contention: effective DRAM transfer time
    /// scales by `1 + thread_contention * (threads - 1)` (identity 0.0).
    pub thread_contention: f64,
}

impl Default for ModelConstants {
    fn default() -> Self {
        ModelConstants {
            latency_scale: 1.0,
            issue_scale: 1.0,
            thread_contention: 0.0,
        }
    }
}

impl ModelConstants {
    pub fn is_identity(&self) -> bool {
        *self == ModelConstants::default()
    }

    pub fn validate(&self) -> Result<()> {
        crate::ensure!(
            self.latency_scale.is_finite() && self.latency_scale > 0.0,
            "profile: model.latency_scale must be finite and positive"
        );
        crate::ensure!(
            self.issue_scale.is_finite() && self.issue_scale > 0.0,
            "profile: model.issue_scale must be finite and positive"
        );
        crate::ensure!(
            self.thread_contention.is_finite() && self.thread_contention >= 0.0,
            "profile: model.thread_contention must be finite and non-negative"
        );
        Ok(())
    }

    fn from_json(v: &Json) -> Result<ModelConstants> {
        Ok(ModelConstants {
            latency_scale: num(v, "latency_scale")?,
            issue_scale: num(v, "issue_scale")?,
            thread_contention: num(v, "thread_contention")?,
        })
    }

    fn to_json(self) -> Json {
        obj(&[
            ("latency_scale", Json::Num(self.latency_scale)),
            ("issue_scale", Json::Num(self.issue_scale)),
            ("thread_contention", Json::Num(self.thread_contention)),
        ])
    }
}

/// Fit-quality record attached to a calibrated profile.
#[derive(Debug, Clone, PartialEq)]
pub struct FitProvenance {
    /// RMS of `ln(predicted) - ln(measured)` over the training split.
    pub train_rmse_log: f64,
    /// Worst relative error over the held-out measurements.
    pub holdout_max_rel_err: f64,
    /// Human-readable description of the shape x thread grid.
    pub grid: String,
    /// Number of measurements the fit consumed (train + held-out).
    pub measurements: usize,
}

impl FitProvenance {
    pub fn validate(&self) -> Result<()> {
        crate::ensure!(
            self.train_rmse_log.is_finite() && self.train_rmse_log >= 0.0,
            "profile: fit.train_rmse_log must be finite and non-negative"
        );
        crate::ensure!(
            self.holdout_max_rel_err.is_finite() && self.holdout_max_rel_err >= 0.0,
            "profile: fit.holdout_max_rel_err must be finite and non-negative"
        );
        crate::ensure!(!self.grid.is_empty(), "profile: fit.grid must describe the grid");
        crate::ensure!(self.measurements >= 1, "profile: fit.measurements must be >= 1");
        Ok(())
    }

    fn from_json(v: &Json) -> Result<FitProvenance> {
        Ok(FitProvenance {
            train_rmse_log: num(v, "train_rmse_log")?,
            holdout_max_rel_err: num(v, "holdout_max_rel_err")?,
            grid: text(v, "grid")?,
            measurements: int(v, "measurements")?,
        })
    }

    fn to_json(&self) -> Json {
        obj(&[
            ("train_rmse_log", Json::Num(self.train_rmse_log)),
            ("holdout_max_rel_err", Json::Num(self.holdout_max_rel_err)),
            ("grid", Json::Str(self.grid.clone())),
            ("measurements", Json::Num(self.measurements as f64)),
        ])
    }
}

/// Where a profile's constants came from.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    /// `"table1"` (paper-faithful) or `"calibrated"` (fitted).
    pub source: String,
    /// Fingerprint of the host the fit ran on (calibrated profiles).
    pub host: Option<String>,
    /// Fit residuals and grid description (calibrated profiles).
    pub fit: Option<FitProvenance>,
}

impl Provenance {
    /// Provenance of the in-tree paper-faithful rows.
    pub fn table1() -> Provenance {
        Provenance { source: "table1".into(), host: None, fit: None }
    }

    pub fn validate(&self) -> Result<()> {
        match self.source.as_str() {
            "table1" => {}
            "calibrated" => {
                crate::ensure!(
                    self.host.is_some(),
                    "profile: calibrated provenance requires a host fingerprint"
                );
                crate::ensure!(
                    self.fit.is_some(),
                    "profile: calibrated provenance requires a fit record"
                );
            }
            other => {
                crate::bail!("profile: provenance source must be \"table1\" or \"calibrated\", got {other:?}")
            }
        }
        if let Some(fit) = &self.fit {
            fit.validate()?;
        }
        Ok(())
    }

    fn from_json(v: &Json) -> Result<Provenance> {
        let host = match v.req("host")? {
            Json::Null => None,
            Json::Str(s) => Some(s.clone()),
            _ => crate::bail!("profile: provenance.host must be a string or null"),
        };
        let fit = match v.req("fit")? {
            Json::Null => None,
            f => Some(FitProvenance::from_json(f)?),
        };
        Ok(Provenance { source: text(v, "source")?, host, fit })
    }

    fn to_json(&self) -> Json {
        obj(&[
            ("source", Json::Str(self.source.clone())),
            (
                "host",
                match &self.host {
                    Some(h) => Json::Str(h.clone()),
                    None => Json::Null,
                },
            ),
            (
                "fit",
                match &self.fit {
                    Some(f) => f.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// A modeled evaluation platform (one row of Table I, or a calibrated
/// profile of the machine `tsar-cli calibrate` ran on).
///
/// This type is re-exported as `config::Platform` — the historic name
/// every simulator/selector/bench call site uses.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformProfile {
    /// Display name ("Workstation", "Laptop", "Mobile", or a host tag).
    pub name: String,
    pub cpu_model: String,
    pub cores: usize,
    pub freq_ghz: f64,
    pub l1d: CacheLevel,
    pub l2: CacheLevel,
    pub l3: CacheLevel,
    /// Peak DRAM bandwidth, GB/s.
    pub dram_bw_gbps: f64,
    /// Fraction of peak bandwidth sustained by streaming reads (STREAM-
    /// class efficiency of the platform's memory controller; E-core
    /// single-channel parts sustain far less than peak).
    pub dram_efficiency: f64,
    /// DRAM access latency, ns.
    pub dram_lat_ns: f64,
    /// SIMD issue width: 256-bit ALU µ-ops issued per cycle per core
    /// (AVX2 cores have two 256-bit vector ALU ports; the efficiency
    /// cores of the N250 have one effective port).
    pub simd_ports: f64,
    /// Default thread count used by the paper's protocol ({16, 8, 4}).
    pub threads: usize,
    /// Package power running the LUT-kernel decode workload, watts —
    /// used by the Table III energy model (TDP-class constants; the
    /// paper measures TL-2 package power on real silicon).
    pub pkg_power_w: f64,
    /// Process node, for the Table III annotations.
    pub node: String,
    /// Free timing-model constants (identity unless calibrated).
    pub model: ModelConstants,
    /// Where these numbers came from.
    pub provenance: Provenance,
}

static WORKSTATION: OnceLock<PlatformProfile> = OnceLock::new();
static LAPTOP: OnceLock<PlatformProfile> = OnceLock::new();
static MOBILE: OnceLock<PlatformProfile> = OnceLock::new();

fn embedded(
    slot: &OnceLock<PlatformProfile>,
    json: &str,
    which: &str,
) -> PlatformProfile {
    slot.get_or_init(|| {
        PlatformProfile::parse(json)
            .unwrap_or_else(|e| panic!("embedded {which} profile: {e}"))
    })
    .clone()
}

impl PlatformProfile {
    /// Table I Workstation row (AMD Ryzen 9950X), from the embedded
    /// `profiles/workstation.json`.
    pub fn workstation() -> PlatformProfile {
        embedded(&WORKSTATION, WORKSTATION_JSON, "workstation")
    }

    /// Table I Laptop row (AMD Ryzen 7840U).
    pub fn laptop() -> PlatformProfile {
        embedded(&LAPTOP, LAPTOP_JSON, "laptop")
    }

    /// Table I Mobile row (Intel Processor N250).
    pub fn mobile() -> PlatformProfile {
        embedded(&MOBILE, MOBILE_JSON, "mobile")
    }

    pub fn by_kind(kind: PlatformKind) -> PlatformProfile {
        match kind {
            PlatformKind::Workstation => PlatformProfile::workstation(),
            PlatformKind::Laptop => PlatformProfile::laptop(),
            PlatformKind::Mobile => PlatformProfile::mobile(),
        }
    }

    /// Cycles per nanosecond.
    pub fn cycles_per_ns(&self) -> f64 {
        self.freq_ghz
    }

    /// Sustained DRAM bandwidth in bytes/cycle (whole package).
    pub fn dram_bytes_per_cycle(&self) -> f64 {
        self.dram_bw_gbps * self.dram_efficiency / self.freq_ghz
    }

    /// Short provenance tag for reports: `table1`, or
    /// `calibrated@<host>` for a fitted profile.
    pub fn provenance_label(&self) -> String {
        match (&self.provenance.source, &self.provenance.host) {
            (s, Some(h)) if s == "calibrated" => format!("{s}@{h}"),
            (s, _) => s.clone(),
        }
    }

    /// Parse and schema-validate a profile document.
    pub fn parse(json: &str) -> Result<PlatformProfile> {
        let v = Json::parse(json).map_err(|e| crate::err!("platform profile: {e}"))?;
        PlatformProfile::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<PlatformProfile> {
        crate::ensure!(
            v.get("profile").and_then(Json::as_str) == Some("tsar_platform"),
            "platform profile: missing \"profile\": \"tsar_platform\" discriminator"
        );
        let version = num(v, "schema_version")?;
        crate::ensure!(
            version == 1.0,
            "platform profile: unsupported schema_version {version}"
        );
        let caches = v.req("caches")?;
        let dram = v.req("dram")?;
        let p = PlatformProfile {
            name: text(v, "name")?,
            cpu_model: text(v, "cpu_model")?,
            cores: int(v, "cores")?,
            freq_ghz: num(v, "freq_ghz")?,
            l1d: cache_from_json(caches, "l1d")?,
            l2: cache_from_json(caches, "l2")?,
            l3: cache_from_json(caches, "l3")?,
            dram_bw_gbps: num(dram, "bw_gbps")?,
            dram_efficiency: num(dram, "efficiency")?,
            dram_lat_ns: num(dram, "lat_ns")?,
            simd_ports: num(v, "simd_ports")?,
            threads: int(v, "threads")?,
            pkg_power_w: num(v, "pkg_power_w")?,
            node: text(v, "node")?,
            model: ModelConstants::from_json(v.req("model")?)?,
            provenance: Provenance::from_json(v.req("provenance")?)?,
        };
        p.validate()?;
        Ok(p)
    }

    /// Schema-level sanity checks shared by `parse` and the artifact
    /// validator.
    pub fn validate(&self) -> Result<()> {
        crate::ensure!(!self.name.is_empty(), "profile: name must not be empty");
        crate::ensure!(self.cores >= 1, "profile: cores must be >= 1");
        crate::ensure!(self.threads >= 1, "profile: threads must be >= 1");
        crate::ensure!(
            self.freq_ghz.is_finite() && self.freq_ghz > 0.0,
            "profile: freq_ghz must be finite and positive"
        );
        crate::ensure!(
            self.simd_ports.is_finite() && self.simd_ports > 0.0,
            "profile: simd_ports must be finite and positive"
        );
        crate::ensure!(
            self.pkg_power_w.is_finite() && self.pkg_power_w > 0.0,
            "profile: pkg_power_w must be finite and positive"
        );
        for (label, c) in [("l1d", &self.l1d), ("l2", &self.l2), ("l3", &self.l3)] {
            crate::ensure!(
                c.line_bytes.is_power_of_two(),
                "profile: {label}.line_bytes must be a power of two"
            );
            crate::ensure!(
                c.assoc >= 1 && c.sets() >= 1 && c.sets().is_power_of_two(),
                "profile: {label} geometry must give a power-of-two set count"
            );
            crate::ensure!(
                c.latency_cycles.is_finite() && c.latency_cycles > 0.0,
                "profile: {label}.latency_cycles must be finite and positive"
            );
        }
        crate::ensure!(
            self.dram_bw_gbps.is_finite() && self.dram_bw_gbps > 0.0,
            "profile: dram.bw_gbps must be finite and positive"
        );
        crate::ensure!(
            self.dram_efficiency > 0.0 && self.dram_efficiency <= 1.0,
            "profile: dram.efficiency must be in (0, 1]"
        );
        crate::ensure!(
            self.dram_lat_ns.is_finite() && self.dram_lat_ns > 0.0,
            "profile: dram.lat_ns must be finite and positive"
        );
        self.model.validate()?;
        self.provenance.validate()?;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        obj(&[
            ("profile", Json::Str("tsar_platform".into())),
            ("schema_version", Json::Num(1.0)),
            ("name", Json::Str(self.name.clone())),
            ("cpu_model", Json::Str(self.cpu_model.clone())),
            ("node", Json::Str(self.node.clone())),
            ("cores", Json::Num(self.cores as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("freq_ghz", Json::Num(self.freq_ghz)),
            ("simd_ports", Json::Num(self.simd_ports)),
            ("pkg_power_w", Json::Num(self.pkg_power_w)),
            (
                "caches",
                obj(&[
                    ("l1d", cache_to_json(&self.l1d)),
                    ("l2", cache_to_json(&self.l2)),
                    ("l3", cache_to_json(&self.l3)),
                ]),
            ),
            (
                "dram",
                obj(&[
                    ("bw_gbps", Json::Num(self.dram_bw_gbps)),
                    ("efficiency", Json::Num(self.dram_efficiency)),
                    ("lat_ns", Json::Num(self.dram_lat_ns)),
                ]),
            ),
            ("model", self.model.to_json()),
            ("provenance", self.provenance.to_json()),
        ])
    }

    /// Load and schema-validate a profile from a JSON file.
    pub fn load(path: impl AsRef<Path>) -> Result<PlatformProfile> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| crate::err!("read platform profile {}: {e}", path.display()))?;
        PlatformProfile::parse(&text)
            .map_err(|e| crate::err!("{}: {e}", path.display()))
    }

    /// Serialize the profile to a JSON file (one compact line).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let mut text = self.to_json().to_string();
        text.push('\n');
        std::fs::write(path, text)
            .map_err(|e| crate::err!("write platform profile {}: {e}", path.display()))
    }
}

// -- JSON field helpers ----------------------------------------------------

fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(
        pairs
            .iter()
            .map(|(k, v)| ((*k).to_string(), v.clone()))
            .collect(),
    )
}

fn num(v: &Json, key: &str) -> Result<f64> {
    v.req(key)?
        .as_f64()
        .ok_or_else(|| crate::err!("profile: {key:?} must be a number"))
}

fn int(v: &Json, key: &str) -> Result<usize> {
    let n = num(v, key)?;
    crate::ensure!(
        n.fract() == 0.0 && n >= 0.0,
        "profile: {key:?} must be a non-negative integer"
    );
    Ok(n as usize)
}

fn text(v: &Json, key: &str) -> Result<String> {
    Ok(v.req(key)?
        .as_str()
        .ok_or_else(|| crate::err!("profile: {key:?} must be a string"))?
        .to_string())
}

fn boolean(v: &Json, key: &str) -> Result<bool> {
    match v.req(key)? {
        Json::Bool(b) => Ok(*b),
        _ => crate::bail!("profile: {key:?} must be a boolean"),
    }
}

fn cache_from_json(caches: &Json, which: &str) -> Result<CacheLevel> {
    let v = caches.req(which)?;
    Ok(CacheLevel {
        size_bytes: int(v, "size_bytes")?,
        assoc: int(v, "assoc")?,
        line_bytes: int(v, "line_bytes")?,
        latency_cycles: num(v, "latency_cycles")?,
        shared: boolean(v, "shared")?,
    })
}

fn cache_to_json(c: &CacheLevel) -> Json {
    obj(&[
        ("size_bytes", Json::Num(c.size_bytes as f64)),
        ("assoc", Json::Num(c.assoc as f64)),
        ("line_bytes", Json::Num(c.line_bytes as f64)),
        ("latency_cycles", Json::Num(c.latency_cycles)),
        ("shared", Json::Bool(c.shared)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ALL_PLATFORMS;

    #[test]
    fn embedded_profiles_reproduce_table1_constants() {
        // Exact f64 equality on every constant the simulator consumes:
        // the JSON profiles must be *bit-identical* to the historic
        // hardcoded Table I rows (correctly-rounded decimal parsing
        // guarantees "102.4" == 102.4).
        let w = PlatformProfile::workstation();
        assert_eq!(w.name, "Workstation");
        assert_eq!(w.cpu_model, "AMD Ryzen 9950X");
        assert_eq!((w.cores, w.threads), (16, 16));
        assert_eq!(w.freq_ghz, 5.7);
        assert_eq!(w.simd_ports, 2.0);
        assert_eq!(w.pkg_power_w, 79.4);
        assert_eq!(
            w.l1d,
            CacheLevel {
                size_bytes: 48 * 1024,
                assoc: 12,
                line_bytes: 64,
                latency_cycles: 4.0,
                shared: false,
            }
        );
        assert_eq!(w.l2.size_bytes, 1024 * 1024);
        assert_eq!(w.l3.size_bytes, 64 * 1024 * 1024);
        assert_eq!(w.l3.latency_cycles, 50.0);
        assert_eq!(
            (w.dram_bw_gbps, w.dram_efficiency, w.dram_lat_ns),
            (102.4, 0.85, 75.0)
        );
        assert_eq!(w.node, "4nm");

        let l = PlatformProfile::laptop();
        assert_eq!(l.name, "Laptop");
        assert_eq!((l.cores, l.freq_ghz), (8, 5.1));
        assert_eq!(l.l3.size_bytes, 16 * 1024 * 1024);
        assert_eq!(l.l3.latency_cycles, 47.0);
        assert_eq!(
            (l.dram_bw_gbps, l.dram_efficiency, l.dram_lat_ns),
            (70.4, 0.80, 85.0)
        );
        assert_eq!(l.pkg_power_w, 24.7);

        let m = PlatformProfile::mobile();
        assert_eq!(m.name, "Mobile");
        assert_eq!((m.cores, m.freq_ghz), (4, 3.8));
        assert_eq!(m.simd_ports, 1.0);
        assert!(m.l2.shared);
        assert_eq!(m.l2.size_bytes, 2 * 1024 * 1024);
        assert_eq!(
            (m.dram_bw_gbps, m.dram_efficiency, m.dram_lat_ns),
            (35.2, 0.55, 100.0)
        );
        assert_eq!(m.node, "10nm");
    }

    #[test]
    fn embedded_profiles_carry_identity_model_constants() {
        for kind in ALL_PLATFORMS {
            let p = PlatformProfile::by_kind(kind);
            assert!(p.model.is_identity(), "{}: non-identity constants", p.name);
            assert_eq!(p.provenance, Provenance::table1());
            assert_eq!(p.provenance_label(), "table1");
        }
    }

    #[test]
    fn json_round_trip_is_lossless() {
        for kind in ALL_PLATFORMS {
            let p = PlatformProfile::by_kind(kind);
            let back = PlatformProfile::parse(&p.to_json().to_string()).unwrap();
            assert_eq!(back, p);
        }
    }

    #[test]
    fn calibrated_round_trip_keeps_provenance() {
        let mut p = PlatformProfile::workstation();
        p.name = "host".into();
        p.model = ModelConstants {
            latency_scale: 1.5,
            issue_scale: 0.8,
            thread_contention: 0.12,
        };
        p.provenance = Provenance {
            source: "calibrated".into(),
            host: Some("x86_64/avx2/16t".into()),
            fit: Some(FitProvenance {
                train_rmse_log: 0.01,
                holdout_max_rel_err: 0.03,
                grid: "6 shapes x 3 thread counts".into(),
                measurements: 18,
            }),
        };
        let back = PlatformProfile::parse(&p.to_json().to_string()).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.provenance_label(), "calibrated@x86_64/avx2/16t");
    }

    #[test]
    fn save_load_round_trip() {
        let p = PlatformProfile::laptop();
        let path = std::env::temp_dir().join("tsar_profile_save_load_test.json");
        p.save(&path).unwrap();
        let back = PlatformProfile::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, p);
    }

    #[test]
    fn parse_rejects_bad_documents() {
        let good = PlatformProfile::workstation().to_json().to_string();
        // Wrong discriminator.
        let bad = good.replace("tsar_platform", "not_a_profile");
        assert!(PlatformProfile::parse(&bad).is_err());
        // Unsupported schema version.
        let bad = good.replace("\"schema_version\":1", "\"schema_version\":2");
        assert!(PlatformProfile::parse(&bad).is_err());
        // Out-of-range DRAM efficiency.
        let bad = good.replace("\"efficiency\":0.85", "\"efficiency\":1.5");
        assert!(PlatformProfile::parse(&bad).is_err());
        // Calibrated provenance without host fingerprint or fit record.
        let bad = good.replace("\"source\":\"table1\"", "\"source\":\"calibrated\"");
        assert!(PlatformProfile::parse(&bad).is_err());
        // Not JSON at all.
        assert!(PlatformProfile::parse("nope").is_err());
    }
}
