//! Evaluation configuration: the paper's Table I platforms and the T-SAR
//! ISA parameterization (c, s, k, m).

pub mod isa_family;
pub mod platforms;
pub mod profile;

pub use isa_family::{IsaFamily, ALL_FAMILIES};
pub use platforms::{CacheLevel, Platform, PlatformKind, ALL_PLATFORMS};
pub use profile::{FitProvenance, ModelConstants, PlatformProfile, Provenance};

/// Parameterization of the T-SAR instruction pair (paper §III-B):
/// `TLUT_c×s` builds `s` LUT pairs over blocks of `c` activations;
/// `TGEMV_k×m` computes a (1,k)×(k,m) GEMV with k = c·s.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct IsaConfig {
    /// Block size: activations per LUT (paper evaluates c ∈ {2, 4}).
    pub c: usize,
    /// Blocks per TLUT instruction.
    pub s: usize,
    /// Input channels per TGEMV (k = c·s).
    pub k: usize,
    /// Output channels per TGEMV.
    pub m: usize,
}

impl IsaConfig {
    pub const fn new(c: usize, s: usize, k: usize, m: usize) -> Self {
        IsaConfig { c, s, k, m }
    }

    /// `TLUT_2×4 + TGEMV_8×16` — the paper's worked AVX2 example
    /// (Fig. 6): 4 LUT pairs of 2^3 = 8 16-bit entries in two YMM regs.
    pub const C2: IsaConfig = IsaConfig::new(2, 4, 8, 16);

    /// `TLUT_4×4 + TGEMV_16×16` — the paper's second kernel config.
    pub const C4: IsaConfig = IsaConfig::new(4, 4, 16, 16);

    pub fn validate(&self) -> crate::util::error::Result<()> {
        crate::ensure!(self.k == self.c * self.s, "k must equal c*s");
        crate::ensure!(
            self.c == 2 || self.c == 4,
            "paper configs use c in {{2,4}}"
        );
        Ok(())
    }

    /// LUT entries per block pair: dense + sparse, each 2^c entries.
    pub fn lut_entries_per_block(&self) -> usize {
        2 * (1 << self.c)
    }

    /// Bits of SIMD register file one TLUT result occupies
    /// (s blocks × 2^(c+1) entries × 16 bits).
    pub fn tlut_result_bits(&self) -> usize {
        self.s * self.lut_entries_per_block() * 16
    }

    /// YMM (256-bit) registers needed to hold one TLUT result.
    pub fn tlut_result_regs(&self) -> usize {
        self.tlut_result_bits().div_ceil(256)
    }

    pub fn name(&self) -> String {
        format!(
            "TLUT_{}x{}+TGEMV_{}x{}",
            self.c, self.s, self.k, self.m
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs_valid() {
        IsaConfig::C2.validate().unwrap();
        IsaConfig::C4.validate().unwrap();
    }

    #[test]
    fn c2_register_budget_matches_paper() {
        // Paper §III-C: TLUT_2x4 produces 4 LUT pairs x 8 entries x 16 bit
        // = 512 bits = two YMM registers.
        assert_eq!(IsaConfig::C2.lut_entries_per_block(), 8);
        assert_eq!(IsaConfig::C2.tlut_result_bits(), 512);
        assert_eq!(IsaConfig::C2.tlut_result_regs(), 2);
    }

    #[test]
    fn c4_register_budget() {
        // c=4: 4 blocks x 32 entries x 16b = 2048 bits = 8 YMM registers.
        assert_eq!(IsaConfig::C4.lut_entries_per_block(), 32);
        assert_eq!(IsaConfig::C4.tlut_result_regs(), 8);
    }

    #[test]
    fn invalid_rejected() {
        assert!(IsaConfig::new(3, 4, 12, 16).validate().is_err());
        assert!(IsaConfig::new(2, 4, 9, 16).validate().is_err());
    }
}
