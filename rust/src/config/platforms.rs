//! Table I platform geometry: cache-level descriptors, the three
//! evaluation-platform kinds, and the historic `Platform` name.
//!
//! The platform *values* (Workstation / Laptop / Mobile rows) now live
//! as data in `profiles/*.json` and are loaded through
//! [`crate::config::profile::PlatformProfile`]; this module keeps the
//! geometry types and re-exports the profile struct under its historic
//! `Platform` name so existing call sites keep working.

pub use super::profile::PlatformProfile as Platform;

/// One cache level's geometry and access cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheLevel {
    pub size_bytes: usize,
    pub assoc: usize,
    pub line_bytes: usize,
    /// Load-to-use latency in cycles.
    pub latency_cycles: f64,
    /// True if shared by all cores (affects multi-thread contention).
    pub shared: bool,
}

impl CacheLevel {
    pub fn sets(&self) -> usize {
        self.size_bytes / (self.assoc * self.line_bytes)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlatformKind {
    Workstation,
    Laptop,
    Mobile,
}

impl PlatformKind {
    pub fn name(&self) -> &'static str {
        match self {
            PlatformKind::Workstation => "Workstation",
            PlatformKind::Laptop => "Laptop",
            PlatformKind::Mobile => "Mobile",
        }
    }
}

pub const ALL_PLATFORMS: [PlatformKind; 3] = [
    PlatformKind::Workstation,
    PlatformKind::Laptop,
    PlatformKind::Mobile,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_parameters() {
        let w = Platform::workstation();
        assert_eq!(w.cores, 16);
        assert_eq!(w.freq_ghz, 5.7);
        assert_eq!(w.l3.size_bytes, 64 * 1024 * 1024);
        let l = Platform::laptop();
        assert_eq!(l.cores, 8);
        assert_eq!(l.l2.size_bytes, 1024 * 1024);
        let m = Platform::mobile();
        assert_eq!(m.cores, 4);
        assert_eq!(m.l2.size_bytes, 2 * 1024 * 1024);
        assert!(m.l2.shared);
    }

    #[test]
    fn cache_geometry() {
        let w = Platform::workstation();
        assert_eq!(w.l1d.sets(), 48 * 1024 / (12 * 64));
        assert_eq!(w.l1d.sets() * w.l1d.assoc * w.l1d.line_bytes, w.l1d.size_bytes);
    }

    #[test]
    fn cache_sets_across_all_levels_and_platforms() {
        // sets * assoc * line_bytes must reconstruct the level size
        // exactly, and the simulator's index function needs a
        // power-of-two set count at every level of every platform.
        for kind in ALL_PLATFORMS {
            let p = Platform::by_kind(kind);
            for (label, c) in [("l1d", &p.l1d), ("l2", &p.l2), ("l3", &p.l3)] {
                assert_eq!(
                    c.sets() * c.assoc * c.line_bytes,
                    c.size_bytes,
                    "{}/{label}: geometry does not factor",
                    p.name
                );
                assert!(
                    c.sets().is_power_of_two(),
                    "{}/{label}: {} sets is not a power of two",
                    p.name,
                    c.sets()
                );
            }
        }
        // Spot-check the arithmetic itself on known rows.
        assert_eq!(Platform::workstation().l3.sets(), 64 * 1024 * 1024 / (16 * 64));
        assert_eq!(Platform::mobile().l2.sets(), 2 * 1024 * 1024 / (16 * 64));
    }

    #[test]
    fn cycles_per_ns_equals_clock() {
        // cycles/ns is numerically the GHz clock; the simulator uses it
        // to convert the DRAM latency (ns) into cycles.
        for kind in ALL_PLATFORMS {
            let p = Platform::by_kind(kind);
            assert_eq!(p.cycles_per_ns(), p.freq_ghz);
            assert_eq!(p.dram_lat_ns * p.cycles_per_ns(), p.dram_lat_ns * p.freq_ghz);
        }
        assert_eq!(Platform::mobile().cycles_per_ns(), 3.8);
    }

    #[test]
    fn dram_bytes_per_cycle_derivation() {
        // Sustained bytes/cycle = peak GB/s x efficiency / GHz, exactly.
        let w = Platform::workstation();
        assert_eq!(w.dram_bytes_per_cycle(), 102.4 * 0.85 / 5.7);
        let m = Platform::mobile();
        assert_eq!(m.dram_bytes_per_cycle(), 35.2 * 0.55 / 3.8);
        // The derived quantity must preserve the Table I bandwidth
        // ordering (workstation > laptop > mobile).
        let l = Platform::laptop();
        assert!(w.dram_bytes_per_cycle() > l.dram_bytes_per_cycle());
        assert!(l.dram_bytes_per_cycle() > m.dram_bytes_per_cycle());
    }

    #[test]
    fn bandwidth_ordering() {
        // Workstation > Laptop > Mobile in every memory-system dimension
        // that drives the paper's cross-platform trends.
        let (w, l, m) = (
            Platform::workstation(),
            Platform::laptop(),
            Platform::mobile(),
        );
        assert!(w.dram_bw_gbps > l.dram_bw_gbps && l.dram_bw_gbps > m.dram_bw_gbps);
        assert!(w.l3.size_bytes > l.l3.size_bytes && l.l3.size_bytes > m.l3.size_bytes);
        assert!(w.freq_ghz > l.freq_ghz && l.freq_ghz > m.freq_ghz);
    }
}
