//! Table I platform models: Workstation (Ryzen 9950X), Laptop (Ryzen
//! 7840U), Mobile (Intel N250) — the gem5 configurations reproduced as
//! parameters of our trace-driven simulator.

/// One cache level's geometry and access cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheLevel {
    pub size_bytes: usize,
    pub assoc: usize,
    pub line_bytes: usize,
    /// Load-to-use latency in cycles.
    pub latency_cycles: f64,
    /// True if shared by all cores (affects multi-thread contention).
    pub shared: bool,
}

impl CacheLevel {
    pub fn sets(&self) -> usize {
        self.size_bytes / (self.assoc * self.line_bytes)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlatformKind {
    Workstation,
    Laptop,
    Mobile,
}

impl PlatformKind {
    pub fn name(&self) -> &'static str {
        match self {
            PlatformKind::Workstation => "Workstation",
            PlatformKind::Laptop => "Laptop",
            PlatformKind::Mobile => "Mobile",
        }
    }
}

/// A modeled evaluation platform (one row of Table I).
#[derive(Debug, Clone)]
pub struct Platform {
    pub kind: PlatformKind,
    pub cpu_model: &'static str,
    pub cores: usize,
    pub freq_ghz: f64,
    pub l1d: CacheLevel,
    pub l2: CacheLevel,
    pub l3: CacheLevel,
    /// Peak DRAM bandwidth, GB/s.
    pub dram_bw_gbps: f64,
    /// Fraction of peak bandwidth sustained by streaming reads (STREAM-
    /// class efficiency of the platform's memory controller; E-core
    /// single-channel parts sustain far less than peak).
    pub dram_efficiency: f64,
    /// DRAM access latency, ns.
    pub dram_lat_ns: f64,
    /// SIMD issue width: 256-bit ALU µ-ops issued per cycle per core
    /// (AVX2 cores have two 256-bit vector ALU ports; the efficiency
    /// cores of the N250 have one effective port).
    pub simd_ports: f64,
    /// Default thread count used by the paper's protocol ({16, 8, 4}).
    pub threads: usize,
    /// Package power running the LUT-kernel decode workload, watts —
    /// used by the Table III energy model (TDP-class constants; the
    /// paper measures TL-2 package power on real silicon).
    pub pkg_power_w: f64,
    /// Process node, for the Table III annotations.
    pub node: &'static str,
}

impl Platform {
    pub fn workstation() -> Platform {
        Platform {
            kind: PlatformKind::Workstation,
            cpu_model: "AMD Ryzen 9950X",
            cores: 16,
            freq_ghz: 5.7,
            l1d: CacheLevel {
                size_bytes: 48 * 1024,
                assoc: 12,
                line_bytes: 64,
                latency_cycles: 4.0,
                shared: false,
            },
            l2: CacheLevel {
                size_bytes: 1024 * 1024,
                assoc: 8,
                line_bytes: 64,
                latency_cycles: 14.0,
                shared: false,
            },
            l3: CacheLevel {
                size_bytes: 64 * 1024 * 1024,
                assoc: 16,
                line_bytes: 64,
                latency_cycles: 50.0,
                shared: true,
            },
            dram_bw_gbps: 102.4, // DDR5-6400, dual channel
            dram_efficiency: 0.85,
            dram_lat_ns: 75.0,
            simd_ports: 2.0,
            threads: 16,
            // Package power under LUT-kernel decode (memory-bound, cores
            // partly stalled) — calibrated to the paper's implied
            // P = J/token x tokens/s = 0.616 x 128.96 = 79.4 W.
            pkg_power_w: 79.4,
            node: "4nm",
        }
    }

    pub fn laptop() -> Platform {
        Platform {
            kind: PlatformKind::Laptop,
            cpu_model: "AMD Ryzen 7840U",
            cores: 8,
            freq_ghz: 5.1,
            l1d: CacheLevel {
                size_bytes: 32 * 1024,
                assoc: 8,
                line_bytes: 64,
                latency_cycles: 4.0,
                shared: false,
            },
            l2: CacheLevel {
                size_bytes: 1024 * 1024,
                assoc: 8,
                line_bytes: 64,
                latency_cycles: 14.0,
                shared: false,
            },
            l3: CacheLevel {
                size_bytes: 16 * 1024 * 1024,
                assoc: 16,
                line_bytes: 64,
                latency_cycles: 47.0,
                shared: true,
            },
            dram_bw_gbps: 70.4, // DDR5-4400 dual channel
            dram_efficiency: 0.80,
            dram_lat_ns: 85.0,
            simd_ports: 2.0,
            threads: 8,
            // Paper-implied decode package power: 0.405 x 61.0 = 24.7 W.
            pkg_power_w: 24.7,
            node: "4nm",
        }
    }

    pub fn mobile() -> Platform {
        Platform {
            kind: PlatformKind::Mobile,
            cpu_model: "Intel Processor N250",
            cores: 4,
            freq_ghz: 3.8,
            l1d: CacheLevel {
                size_bytes: 32 * 1024,
                assoc: 8,
                line_bytes: 64,
                latency_cycles: 4.0,
                shared: false,
            },
            l2: CacheLevel {
                size_bytes: 2 * 1024 * 1024,
                assoc: 16,
                line_bytes: 64,
                latency_cycles: 17.0,
                shared: true, // 2MB shared by the 4 E-core cluster
            },
            l3: CacheLevel {
                size_bytes: 6 * 1024 * 1024,
                assoc: 12,
                line_bytes: 64,
                latency_cycles: 60.0,
                shared: true,
            },
            dram_bw_gbps: 35.2, // DDR5-4400 single channel
            dram_efficiency: 0.55, // E-core cluster, single channel
            dram_lat_ns: 100.0,
            simd_ports: 1.0, // Gracemont-class E-core: narrower vector issue
            threads: 4,
            // Paper-implied decode package power: 0.733 x 5.18 = 3.8 W.
            pkg_power_w: 3.8,
            node: "10nm",
        }
    }

    pub fn by_kind(kind: PlatformKind) -> Platform {
        match kind {
            PlatformKind::Workstation => Platform::workstation(),
            PlatformKind::Laptop => Platform::laptop(),
            PlatformKind::Mobile => Platform::mobile(),
        }
    }

    /// Cycles per nanosecond.
    pub fn cycles_per_ns(&self) -> f64 {
        self.freq_ghz
    }

    /// Sustained DRAM bandwidth in bytes/cycle (whole package).
    pub fn dram_bytes_per_cycle(&self) -> f64 {
        self.dram_bw_gbps * self.dram_efficiency / self.freq_ghz
    }
}

pub const ALL_PLATFORMS: [PlatformKind; 3] = [
    PlatformKind::Workstation,
    PlatformKind::Laptop,
    PlatformKind::Mobile,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_parameters() {
        let w = Platform::workstation();
        assert_eq!(w.cores, 16);
        assert_eq!(w.freq_ghz, 5.7);
        assert_eq!(w.l3.size_bytes, 64 * 1024 * 1024);
        let l = Platform::laptop();
        assert_eq!(l.cores, 8);
        assert_eq!(l.l2.size_bytes, 1024 * 1024);
        let m = Platform::mobile();
        assert_eq!(m.cores, 4);
        assert_eq!(m.l2.size_bytes, 2 * 1024 * 1024);
        assert!(m.l2.shared);
    }

    #[test]
    fn cache_geometry() {
        let w = Platform::workstation();
        assert_eq!(w.l1d.sets(), 48 * 1024 / (12 * 64));
        assert_eq!(w.l1d.sets() * w.l1d.assoc * w.l1d.line_bytes, w.l1d.size_bytes);
    }

    #[test]
    fn bandwidth_ordering() {
        // Workstation > Laptop > Mobile in every memory-system dimension
        // that drives the paper's cross-platform trends.
        let (w, l, m) = (
            Platform::workstation(),
            Platform::laptop(),
            Platform::mobile(),
        );
        assert!(w.dram_bw_gbps > l.dram_bw_gbps && l.dram_bw_gbps > m.dram_bw_gbps);
        assert!(w.l3.size_bytes > l.l3.size_bytes && l.l3.size_bytes > m.l3.size_bytes);
        assert!(w.freq_ghz > l.freq_ghz && l.freq_ghz > m.freq_ghz);
    }
}
