//! SIMD ISA families beyond AVX2 (paper footnote 1 + §V): retargeting
//! T-SAR to ARM NEON and RISC-V Vector "only requires c,s,k,m tuning due
//! to the different SIMD lane width but extant dot product extensions".
//!
//! This module captures each family's register-file geometry and the
//! retuned T-SAR instruction parameterizations, and provides the
//! family-scaled register budgets the kernel dataflows need.  The paper
//! names the NEON realization explicitly: the 128-bit datapath with
//! SDOT/UDOT support realizes `TLUT_2×4 + TGEMV_8×8`.

use super::IsaConfig;

/// A SIMD register-file family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IsaFamily {
    /// x86 AVX2: 16 × 256-bit YMM registers, vpmaddwd-class dot path.
    Avx2,
    /// ARMv8.2-A NEON: 32 × 128-bit V registers, SDOT/UDOT (4:1 ADTs).
    Neon,
    /// RISC-V Vector (Zve64x-class, VLEN=256, LMUL=1): 32 × 256-bit.
    Rvv256,
}

impl IsaFamily {
    pub fn name(&self) -> &'static str {
        match self {
            IsaFamily::Avx2 => "AVX2",
            IsaFamily::Neon => "NEON",
            IsaFamily::Rvv256 => "RVV(VLEN=256)",
        }
    }

    /// Architectural vector registers.
    pub fn num_regs(&self) -> usize {
        match self {
            IsaFamily::Avx2 => 16,
            IsaFamily::Neon => 32,
            IsaFamily::Rvv256 => 32,
        }
    }

    /// Register width in bits.
    pub fn reg_bits(&self) -> usize {
        match self {
            IsaFamily::Avx2 => 256,
            IsaFamily::Neon => 128,
            IsaFamily::Rvv256 => 256,
        }
    }

    /// 16-bit ALU lanes per register (the TLUT/TGEMV datapath width).
    pub fn lanes16(&self) -> usize {
        self.reg_bits() / 16
    }

    /// Total register-file bits available for LUT residency.
    pub fn regfile_bits(&self) -> usize {
        self.num_regs() * self.reg_bits()
    }

    /// The retuned T-SAR configurations for this family (paper fn. 1).
    ///
    /// The rule: `m` is sized so one TGEMV's output tile matches the
    /// family's accumulation width (lanes16 outputs), and `s` keeps one
    /// TLUT result within a small register group.
    pub fn configs(&self) -> Vec<IsaConfig> {
        match self {
            IsaFamily::Avx2 => vec![IsaConfig::C2, IsaConfig::C4],
            // Paper: "ARM NEON's 128-bit datapath with SDOT/UDOT ...
            // realizes the TLUT_2×4 + TGEMV_8×8".
            IsaFamily::Neon => vec![
                IsaConfig::new(2, 4, 8, 8),
                IsaConfig::new(4, 2, 8, 8),
            ],
            IsaFamily::Rvv256 => vec![
                IsaConfig::new(2, 4, 8, 16),
                IsaConfig::new(4, 4, 16, 16),
            ],
        }
    }

    /// YMM-equivalent registers a TLUT result occupies in this family.
    pub fn tlut_result_regs(&self, cfg: &IsaConfig) -> usize {
        (cfg.s * cfg.lut_entries_per_block() * 16).div_ceil(self.reg_bits())
    }

    /// Register budget for the AP dataflow's LUT groups: spare registers
    /// after staging (2) and one accumulator pair.
    pub fn lut_group_budget(&self, cfg: &IsaConfig) -> usize {
        let spare = self.num_regs().saturating_sub(4);
        (spare / self.tlut_result_regs(cfg)).max(1)
    }

    /// Relative per-core SIMD throughput scaling vs AVX2 for the timing
    /// model: issue width × datapath width.
    pub fn throughput_scale(&self) -> f64 {
        match self {
            IsaFamily::Avx2 => 1.0,
            IsaFamily::Neon => 0.5,   // 128-bit × 2 pipes vs 256-bit × 2
            IsaFamily::Rvv256 => 1.0, // 256-bit, dual-issue vector unit
        }
    }
}

pub const ALL_FAMILIES: [IsaFamily; 3] =
    [IsaFamily::Avx2, IsaFamily::Neon, IsaFamily::Rvv256];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neon_realizes_paper_footnote_config() {
        let neon = IsaFamily::Neon;
        let cfgs = neon.configs();
        // TLUT_2×4 + TGEMV_8×8, per footnote 1.
        assert_eq!((cfgs[0].c, cfgs[0].s, cfgs[0].k, cfgs[0].m), (2, 4, 8, 8));
        cfgs[0].validate().unwrap();
        // One TLUT_2×4 result (512 b) spans four 128-bit V registers.
        assert_eq!(neon.tlut_result_regs(&cfgs[0]), 4);
    }

    #[test]
    fn rvv_configs_valid() {
        for cfg in IsaFamily::Rvv256.configs() {
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn avx2_matches_base_definitions() {
        let a = IsaFamily::Avx2;
        assert_eq!(a.lanes16(), 16);
        assert_eq!(a.tlut_result_regs(&IsaConfig::C2), 2);
        assert_eq!(a.lut_group_budget(&IsaConfig::C2), 6);
    }

    #[test]
    fn wider_regfiles_hold_more_luts() {
        // NEON has 2× the registers; despite narrower lanes its total
        // LUT residency (bits) matches AVX2's register file.
        assert_eq!(IsaFamily::Neon.regfile_bits(), 32 * 128);
        assert_eq!(IsaFamily::Avx2.regfile_bits(), 16 * 256);
        assert!(IsaFamily::Rvv256.regfile_bits() > IsaFamily::Avx2.regfile_bits());
    }
}
