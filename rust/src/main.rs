//! `tsar-cli` — the leader entrypoint: report harnesses, the simulator,
//! kernel planning and the PJRT serving loop, behind a hand-rolled CLI
//! (clap is not in the offline crate cache).

use std::sync::mpsc::channel;

use anyhow::{Context, Result};

use tsar::bench;
use tsar::config::platforms::{Platform, PlatformKind};
use tsar::coordinator::{select_plan, Request, Server, ServerConfig};
use tsar::kernels::all_kernels;
use tsar::model::zoo;
use tsar::runtime::ModelRuntime;
use tsar::sim::{simulate, GemmShape};
use tsar::util::rng::Rng;

const USAGE: &str = "\
tsar-cli — T-SAR reproduction driver

USAGE:
  tsar-cli report <fig1a|fig1c|fig2c|fig2d|fig8|fig9|fig10|table1|table2|table3|llc|ablations|all>
  tsar-cli simulate --shape NxKxM [--platform workstation|laptop|mobile] [--threads T]
  tsar-cli plan --model <name> [--platform P] [--n N]
  tsar-cli serve [--artifacts DIR] [--variant tsar|ref] [--requests R] [--max-new T] [--batch B]
  tsar-cli models
  tsar-cli help
";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("report") => report(args.get(1).map(String::as_str).unwrap_or("all")),
        Some("simulate") => simulate_cmd(&args[1..]),
        Some("plan") => plan_cmd(&args[1..]),
        Some("serve") => serve_cmd(&args[1..]),
        Some("models") => {
            for m in zoo::MODEL_ZOO {
                println!(
                    "{:<22} L={:<4} d={:<6} ffn={:<6} heads={}/{} vocab={} ({:.2}B params)",
                    m.name,
                    m.layers,
                    m.d_model,
                    m.ffn_dim,
                    m.n_heads,
                    m.n_kv_heads,
                    m.vocab,
                    m.param_count() / 1e9
                );
            }
            Ok(())
        }
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn report(which: &str) -> Result<()> {
    match which {
        "fig1a" => {
            bench::fig1a();
        }
        "fig1c" => {
            bench::fig1c();
        }
        "fig2c" => {
            bench::fig2c();
        }
        "fig2d" => {
            bench::fig2d();
        }
        "fig8" => {
            bench::fig8();
        }
        "fig9" => {
            bench::fig9();
        }
        "fig10" => {
            bench::fig10();
        }
        "table1" => bench::table1(),
        "table2" => bench::table2(),
        "table3" => bench::table3(),
        "llc" => bench::llc_report(),
        "ablations" => bench::ablations::all(),
        "all" => {
            bench::report_all();
            println!();
            bench::ablations::all();
        }
        other => anyhow::bail!("unknown report {other:?}"),
    }
    Ok(())
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn parse_platform(args: &[String]) -> Platform {
    match flag(args, "--platform").as_deref() {
        Some("laptop") => Platform::by_kind(PlatformKind::Laptop),
        Some("mobile") => Platform::by_kind(PlatformKind::Mobile),
        _ => Platform::by_kind(PlatformKind::Workstation),
    }
}

fn simulate_cmd(args: &[String]) -> Result<()> {
    let shape_s = flag(args, "--shape").context("--shape NxKxM required")?;
    let dims: Vec<usize> = shape_s
        .split('x')
        .map(|p| p.parse::<usize>().context("bad shape"))
        .collect::<Result<_>>()?;
    anyhow::ensure!(dims.len() == 3, "--shape must be NxKxM");
    let shape = GemmShape::new(dims[0], dims[1], dims[2]);
    let plat = parse_platform(args);
    let threads = flag(args, "--threads")
        .map(|t| t.parse::<usize>().unwrap_or(plat.threads))
        .unwrap_or(plat.threads);

    println!(
        "simulating {}x{}x{} on {} with {} threads",
        shape.n, shape.k, shape.m, plat.kind.name(), threads
    );
    let mut t = tsar::util::table::Table::new(vec![
        "kernel", "time (ms)", "req vol (MB)", "DRAM (MB)", "LLC hit", "mem-bound",
    ]);
    for kern in all_kernels() {
        let r = simulate(&kern.profile(shape, &plat, threads), &plat, threads);
        t.row(vec![
            kern.name(),
            format!("{:.3}", r.seconds * 1e3),
            format!("{:.2}", r.request_bytes / 1e6),
            format!("{:.2}", r.traffic.bytes[3] / 1e6),
            format!("{:.0}%", r.llc_hit_rate * 100.0),
            format!("{:.0}%", r.mem_bound_frac * 100.0),
        ]);
    }
    t.print();
    Ok(())
}

fn plan_cmd(args: &[String]) -> Result<()> {
    let model = flag(args, "--model").unwrap_or_else(|| "BitNet-2B-4T".into());
    let spec = zoo::by_name(&model)
        .with_context(|| format!("unknown model {model:?} (see `tsar-cli models`)"))?;
    let plat = parse_platform(args);
    let n = flag(args, "--n").map(|v| v.parse().unwrap_or(1)).unwrap_or(1);
    println!(
        "adaptive kernel plan: {} on {} (N={}, {} threads)",
        spec.name, plat.kind.name(), n, plat.threads
    );
    let plan = select_plan(spec, &plat, n, plat.threads);
    for l in &plan.layers {
        println!("  {}", l.describe());
    }
    println!(
        "forward pass: {:.3} ms  ({:.2} tok/s at N=1)",
        plan.pass_seconds() * 1e3,
        1.0 / plan.pass_seconds()
    );
    Ok(())
}

fn serve_cmd(args: &[String]) -> Result<()> {
    let dir = flag(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    let variant = flag(args, "--variant").unwrap_or_else(|| "tsar".into());
    let n_req: usize = flag(args, "--requests").map(|v| v.parse().unwrap()).unwrap_or(8);
    let max_new: usize = flag(args, "--max-new").map(|v| v.parse().unwrap()).unwrap_or(16);
    let batch: usize = flag(args, "--batch").map(|v| v.parse().unwrap()).unwrap_or(4);

    println!("loading artifacts from {dir} (variant {variant}) ...");
    let rt = ModelRuntime::load(&dir, &variant)?;
    let cfg = rt.manifest.config.clone();
    println!(
        "model: {} (d={}, L={}, vocab={}), prefill window {}",
        rt.manifest.config_name, cfg.d_model, cfg.n_layers, cfg.vocab, cfg.prefill_len
    );

    let server = Server::new(rt, ServerConfig { max_batch: batch, kv_slots: batch });
    let mut rng = Rng::new(7);
    let requests: Vec<Request> = (0..n_req as u64)
        .map(|id| {
            let plen = rng.range_i64(3, cfg.prefill_len as i64 - 1) as usize;
            let prompt: Vec<i32> =
                (0..plen).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
            Request::new(id, prompt, max_new)
        })
        .collect();

    let (req_tx, req_rx) = channel();
    let (res_tx, res_rx) = channel();
    for r in requests {
        req_tx.send(r).unwrap();
    }
    drop(req_tx);
    let report = server.run(req_rx, res_tx)?;
    drop(res_rx);
    report.print();
    Ok(())
}
