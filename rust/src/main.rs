//! `tsar-cli` — the leader entrypoint: report harnesses, the simulator,
//! kernel planning and the serving loop, behind a hand-rolled CLI (clap
//! is not in the offline crate cache).
//!
//! `serve` runs on the default [`SimBackend`] (simulator-costed, zero
//! dependencies); pass `--artifacts DIR` on a `--features pjrt` build to
//! serve the AOT-compiled model through PJRT instead.

use std::sync::mpsc::channel;
use std::sync::Arc;

use tsar::bench;
use tsar::config::platforms::Platform;
use tsar::config::IsaConfig;
use tsar::coordinator::{
    select_plan, tee_records, Engine, Exporter, GenerationRequest, HttpConfig, HttpServer,
    PromAggregator, Request, RequestRecord, Server, ServerConfig, Ticket, TokenEvent,
};
use tsar::kernels::all_kernels;
use tsar::loadgen::BenchConfig;
use tsar::model::zoo;
use tsar::model::{Checkpoint, LinearEngine, SamplerConfig, TransformerConfig};
use tsar::runtime::{
    Backend, ModelBackend, ModelBackendConfig, NativeBackend, SimBackend, SimBackendConfig,
};
use tsar::sim::{simulate, GemmShape};
use tsar::util::error::{Context, Result};
use tsar::util::rng::Rng;

const USAGE: &str = "\
tsar-cli — T-SAR reproduction driver

USAGE:
  tsar-cli report <fig1a|fig1c|fig2c|fig2d|fig8|fig9|fig10|table1|table2|table3|llc|ablations|all>
  tsar-cli simulate --shape NxKxM [--platform P] [--threads T]
  tsar-cli plan --model <name> [--platform P] [--n N]
  tsar-cli calibrate [--smoke] [--isa c2|c4] [--threads T] [--base P] [--out PATH]
                     [--fixture PATH] [--emit-fixture PATH] [--validate PATH]
  tsar-cli serve [--model <name>] [--platform P] [--threads T] [--prefill-len L]
                 [--requests R] [--max-new T] [--batch B] [--workers W]
                 [--backend sim|native|model] [--isa c2|c4] [--queue-cap N]
                 [--metrics <path|->] [--stream] [--http ADDR]
                 [--ckpt PATH] [--model-seed S] [--engine native|modeled]
                 [--temperature T] [--top-k K]
                 [--layers N] [--dim D] [--heads H] [--kv-heads H] [--ffn F] [--vocab V]
                 [--artifacts DIR] [--variant tsar|ref]   (PJRT; needs --features pjrt)
  tsar-cli bench-serve [--smoke] [--seed S] [--requests R] [--rate RPS]
                       [--arrivals poisson|bursty] [--on S] [--off S] [--conns C]
                       [--cancel-rate F] [--deadline-frac F] [--workers W] [--batch B]
                       [--queue-cap N] [--model <name>] [--addr HOST:PORT]
                       [--out PATH] [--validate PATH]
  tsar-cli models
  tsar-cli help

Everywhere `--platform P` appears, P is one of the embedded Table I
profiles (workstation|laptop|mobile, default workstation) or a path to
a platform-profile JSON document — e.g. the PLATFORM_host.json written
by `tsar-cli calibrate`.  Every backend names the active profile and
its provenance (table1 vs calibrated@host) in its plan summary, so
serve metrics records carry it too.

`calibrate` closes the measure → model loop: it times the native
ternary GEMM kernels across a shape × thread grid on *this* host, fits
the platform profile's free constants (sustained DRAM efficiency, SIMD
issue scale, latency scale, per-thread DRAM contention) to the
measured wall-clock, reports the held-out prediction error, and writes
the fitted profile (default PLATFORM_host.json) with calibrated
provenance.  --base picks the profile the fit starts from; --smoke
shrinks the grid to CI size.  --emit-fixture PATH writes a synthetic
measurement set generated from a known perturbed profile (no timing);
--fixture PATH fits from such a file instead of measuring —
deterministic and offline — and cross-checks the recovered constants
against the embedded truth.  --validate PATH re-checks an existing
profile artifact against the schema and exits.

`serve --metrics <path|->` attaches the request-level metrics exporter:
one JSON line per retired request (queue/prefill/decode seconds, lane,
finish reason, kernel plan) to the file, or to stdout for `-`.
`serve --stream` drives the session-based streaming Engine API instead
of the blocking batch surface: tokens print as their decode rounds
land, per ticket.
`serve --http ADDR` (e.g. 127.0.0.1:8080) serves network clients
instead of a synthetic workload: POST /v1/generate streams one JSON
line per token event (chunked NDJSON, every line carrying the session
id; disconnecting cancels the session), POST /v1/cancel {\"id\": N}
cancels a live stream from any connection, GET /metrics exposes
Prometheus counters, GET /healthz is the liveness probe.  With
--queue-cap N a full admission queue sheds new submissions with a 429.
Composable with --backend/--threads/--workers/--batch and --metrics;
press Enter (or close stdin) to stop and print the merged serve
report.

`bench-serve` runs the open-loop load-generation subsystem: a seeded
deterministic workload (fixed --seed replays the byte-identical trace;
its fingerprint lands in the artifact) is dispatched over --conns
keep-alive connections at the scheduled --rate, with mixed prompt
lengths, scheduled mid-stream cancels (--cancel-rate) and per-request
deadlines (--deadline-frac).  By default the full serving stack is
spun up in-process on the simulator backend; --addr targets an
already-running `serve --http` front-end instead.  Per-request
timelines (TTFT, per-token gaps, end-to-end) are aggregated into the
schema-versioned BENCH_serve.json artifact (--out), every outcome
count is reconciled against the engine's /metrics deltas, and the run
fails if the two views disagree.  --smoke is the hostile CI profile
(bursty arrivals over a capped queue); --validate PATH only re-checks
an existing artifact against the schema.

`serve --backend native` executes every decode step's BitLinear GEMVs
through the host AVX2 pshufb kernels (scalar fallback elsewhere) and
reports measured wall-clock latency; tokens are bit-identical to the
default simulator backend.  `--threads T` chunks each GEMM's output
tiles across T lanes of a persistent, core-pinned worker pool created
once per process (no per-call thread spawns; bit-identical results).
Small sites clamp to fewer lanes (>= 2 tiles each) — the plan summary
reports the effective count per site.  The native weight layout costs
~1 B/weight, so it defaults to BitNet-125M — pass --model explicitly
to serve the billion-parameter zoo entries natively.

`serve --backend model` runs a *real* ternary transformer forward
pass: every streamed token is sampled from logits produced by the
checkpoint-loaded BitNet-style block stack, with true per-layer KV
caches.  `--ckpt PATH` loads a TSARCKP1 checkpoint (if the file is
missing a deterministic random-init one keyed by `--model-seed` is
synthesized and saved there); with no `--ckpt` the model is
synthesized in memory, so no weights file is ever required.  The
architecture flags (--layers/--dim/--heads/--kv-heads/--ffn/--vocab)
shape the synthesized model and default to the seeded toy config; a
loaded checkpoint's header always wins.  `--engine native` (default)
executes the BitLinear sites on the host AVX2/scalar kernels,
`--engine modeled` replays them through the modeled T-SAR ISA
(bit-identical, slower).  `--temperature`/`--top-k` enable seeded
sampling (greedy by default).  Composable with
--workers/--batch/--threads, --stream, --metrics and --http.
";

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("report") => report(args.get(1).map(String::as_str).unwrap_or("all")),
        Some("simulate") => simulate_cmd(&args[1..]),
        Some("plan") => plan_cmd(&args[1..]),
        Some("serve") => serve_cmd(&args[1..]),
        Some("bench-serve") => bench_serve_cmd(&args[1..]),
        Some("calibrate") => calibrate_cmd(&args[1..]),
        Some("models") => {
            for m in zoo::MODEL_ZOO {
                println!(
                    "{:<22} L={:<4} d={:<6} ffn={:<6} heads={}/{} vocab={} ({:.2}B params)",
                    m.name,
                    m.layers,
                    m.d_model,
                    m.ffn_dim,
                    m.n_heads,
                    m.n_kv_heads,
                    m.vocab,
                    m.param_count() / 1e9
                );
            }
            Ok(())
        }
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn report(which: &str) -> Result<()> {
    match which {
        "fig1a" => {
            bench::fig1a();
        }
        "fig1c" => {
            bench::fig1c();
        }
        "fig2c" => {
            bench::fig2c()?;
        }
        "fig2d" => {
            bench::fig2d()?;
        }
        "fig8" => {
            bench::fig8();
        }
        "fig9" => {
            bench::fig9();
        }
        "fig10" => {
            bench::fig10();
        }
        "table1" => bench::table1(),
        "table2" => bench::table2(),
        "table3" => bench::table3()?,
        "llc" => bench::llc_report(),
        "ablations" => bench::ablations::all()?,
        "all" => {
            bench::report_all()?;
            println!();
            bench::ablations::all()?;
        }
        other => tsar::bail!("unknown report {other:?}"),
    }
    Ok(())
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

/// Numeric flag with a default; a present-but-unparsable value is an
/// error, never a silent fallback.
fn parse_flag<T: std::str::FromStr>(args: &[String], name: &str, default: T) -> Result<T> {
    match flag(args, name) {
        Some(v) => v.parse::<T>().map_err(|_| tsar::err!("{name} expects a number, got {v:?}")),
        None => Ok(default),
    }
}

/// `--isa c2|c4` (the paper's two AVX2 configs), defaulting to C2.
fn parse_isa(args: &[String]) -> Result<IsaConfig> {
    match flag(args, "--isa").as_deref() {
        Some("c4") => Ok(IsaConfig::C4),
        Some("c2") | None => Ok(IsaConfig::C2),
        Some(other) => tsar::bail!("--isa must be c2 or c4, got {other:?}"),
    }
}

/// Resolve a profile name: one of the embedded Table I rows, or a path
/// to a platform-profile JSON document (schema-validated on load).
fn profile_by_name(name: Option<&str>) -> Result<Platform> {
    match name {
        None | Some("workstation") => Ok(Platform::workstation()),
        Some("laptop") => Ok(Platform::laptop()),
        Some("mobile") => Ok(Platform::mobile()),
        Some(path) => Platform::load(path),
    }
}

fn parse_platform(args: &[String]) -> Result<Platform> {
    profile_by_name(flag(args, "--platform").as_deref())
}

fn simulate_cmd(args: &[String]) -> Result<()> {
    let shape_s = flag(args, "--shape").context("--shape NxKxM required")?;
    let dims: Vec<usize> = shape_s
        .split('x')
        .map(|p| p.parse::<usize>().context("bad shape"))
        .collect::<Result<_>>()?;
    tsar::ensure!(dims.len() == 3, "--shape must be NxKxM");
    let shape = GemmShape::new(dims[0], dims[1], dims[2]);
    let plat = parse_platform(args)?;
    let threads = flag(args, "--threads")
        .map(|t| t.parse::<usize>().unwrap_or(plat.threads))
        .unwrap_or(plat.threads);

    println!(
        "simulating {}x{}x{} on {} [{}] with {} threads",
        shape.n,
        shape.k,
        shape.m,
        plat.name,
        plat.provenance_label(),
        threads
    );
    let mut t = tsar::util::table::Table::new(vec![
        "kernel", "time (ms)", "req vol (MB)", "DRAM (MB)", "LLC hit", "mem-bound",
    ]);
    for kern in all_kernels() {
        let r = simulate(&kern.profile(shape, &plat, threads), &plat, threads);
        t.row(vec![
            kern.name(),
            format!("{:.3}", r.seconds * 1e3),
            format!("{:.2}", r.request_bytes / 1e6),
            format!("{:.2}", r.traffic.bytes[3] / 1e6),
            format!("{:.0}%", r.llc_hit_rate * 100.0),
            format!("{:.0}%", r.mem_bound_frac * 100.0),
        ]);
    }
    t.print();
    Ok(())
}

fn plan_cmd(args: &[String]) -> Result<()> {
    let model = flag(args, "--model").unwrap_or_else(|| "BitNet-2B-4T".into());
    let spec = zoo::by_name(&model)
        .with_context(|| format!("unknown model {model:?} (see `tsar-cli models`)"))?;
    let plat = parse_platform(args)?;
    let n = flag(args, "--n").map(|v| v.parse().unwrap_or(1)).unwrap_or(1);
    println!(
        "adaptive kernel plan: {} on {} [{}] (N={}, {} threads)",
        spec.name,
        plat.name,
        plat.provenance_label(),
        n,
        plat.threads
    );
    let plan = select_plan(spec, &plat, n, plat.threads);
    for l in &plan.layers {
        println!("  {}", l.describe());
    }
    println!(
        "forward pass: {:.3} ms  ({:.2} tok/s at N=1)",
        plan.pass_seconds() * 1e3,
        1.0 / plan.pass_seconds()
    );
    Ok(())
}

fn serve_cmd(args: &[String]) -> Result<()> {
    let n_req: usize = parse_flag(args, "--requests", 8)?;
    let max_new: usize = parse_flag(args, "--max-new", 16)?;
    let batch: usize = parse_flag(args, "--batch", 4)?;
    let workers: usize = parse_flag(args, "--workers", 1)?;
    tsar::ensure!(max_new >= 1, "--max-new must be >= 1");
    tsar::ensure!(batch >= 1, "--batch must be >= 1");
    tsar::ensure!(workers >= 1, "--workers must be >= 1");
    let queue_cap = match flag(args, "--queue-cap") {
        Some(cap) => {
            let cap: usize =
                cap.parse().map_err(|_| tsar::err!("--queue-cap expects a number"))?;
            tsar::ensure!(cap >= 1, "--queue-cap must be >= 1");
            Some(cap)
        }
        None => None,
    };
    let opts = ServeOpts {
        metrics: flag(args, "--metrics"),
        stream: args.iter().any(|a| a == "--stream"),
        http: flag(args, "--http"),
        queue_cap,
    };
    // Both --stream token output and `--metrics -` write stdout; the
    // interleaving would corrupt the JSONL stream.
    tsar::ensure!(
        !(opts.stream && opts.metrics.as_deref() == Some("-")),
        "--stream prints tokens on stdout; use --metrics <file> with --stream"
    );
    // --http serves real network clients; --stream prints a synthetic
    // workload's tokens.  One serving mode per run.
    tsar::ensure!(
        !(opts.stream && opts.http.is_some()),
        "--http serves network clients; drop --stream (clients stream over HTTP)"
    );
    // The HTTP mode prints status lines and the report on stdout; a
    // stdout JSONL stream would interleave with them.
    tsar::ensure!(
        !(opts.http.is_some() && opts.metrics.as_deref() == Some("-")),
        "--http prints status lines on stdout; use --metrics <file> with --http"
    );

    if let Some(dir) = flag(args, "--artifacts") {
        return serve_pjrt(&dir, args, n_req, max_new, batch, workers, opts);
    }

    let model = flag(args, "--model");
    let plat = parse_platform(args)?;
    let threads: usize = parse_flag(args, "--threads", 0)?;
    let prefill_len: usize = parse_flag(args, "--prefill-len", 32)?;
    tsar::ensure!(prefill_len >= 1, "--prefill-len must be >= 1");
    let bcfg = SimBackendConfig {
        prefill_len,
        max_seq: prefill_len + max_new + 8,
        threads,
        ..SimBackendConfig::default()
    };

    match flag(args, "--backend").as_deref().unwrap_or("sim") {
        "sim" => {
            let model = model.unwrap_or_else(|| "BitNet-2B-4T".into());
            let backend = SimBackend::by_name(&model, plat, bcfg)?;
            println!("adaptive decode plan (§III-D):");
            for l in &backend.decode_plan().layers {
                println!("  {}", l.describe());
            }
            drive(backend, n_req, max_new, batch, workers, opts)
        }
        "native" => {
            // Native execution packs weights at ~1 B/weight and really
            // runs every GEMV, so the default model is the small end of
            // the zoo — the multi-billion-parameter entries need real
            // RAM and real patience and must be opted into explicitly.
            let model = model.unwrap_or_else(|| {
                println!("(no --model given: native backend defaults to BitNet-125M)");
                "BitNet-125M".into()
            });
            // The native path executes on the host CPU; the profile
            // named by --platform labels the plan summary and every
            // metrics record with the *modeled* platform it stands in
            // for (--threads chunks every GEMM's output tiles across
            // persistent pool lanes).
            let isa = parse_isa(args)?;
            println!("packing {model} for native execution ({}) ...", isa.name());
            let backend = NativeBackend::by_name(&model, isa, bcfg)?.with_profile(plat);
            println!(
                "native path: {} ({:.1} MB packed weights)",
                backend.path().name(),
                backend.packed_bytes() as f64 / 1e6
            );
            drive(backend, n_req, max_new, batch, workers, opts)
        }
        "model" => {
            // The real forward pass: architecture comes from the
            // checkpoint (or the arch flags when synthesizing), not the
            // zoo, and execution happens on this host.
            if model.is_some() {
                eprintln!(
                    "warning: --model names simulator zoo specs and is ignored by \
                     --backend model (use --ckpt or the --layers/--dim/... flags)"
                );
            }
            let isa = parse_isa(args)?;
            let engine = match flag(args, "--engine").as_deref() {
                Some("modeled") => LinearEngine::modeled(isa),
                Some("native") | None => LinearEngine::native(isa, threads.max(1))?,
                Some(other) => tsar::bail!("--engine must be native or modeled, got {other:?}"),
            };
            let toy = TransformerConfig::toy();
            let config = TransformerConfig {
                vocab: parse_flag(args, "--vocab", toy.vocab)?,
                d_model: parse_flag(args, "--dim", toy.d_model)?,
                n_layers: parse_flag(args, "--layers", toy.n_layers)?,
                n_heads: parse_flag(args, "--heads", toy.n_heads)?,
                n_kv_heads: parse_flag(args, "--kv-heads", toy.n_kv_heads)?,
                ffn_dim: parse_flag(args, "--ffn", toy.ffn_dim)?,
                ..toy
            };
            let seed: u64 = parse_flag(args, "--model-seed", 0x75AB)?;
            let ckpt = match flag(args, "--ckpt") {
                Some(path) if std::path::Path::new(&path).exists() => {
                    println!("loading checkpoint {path} ...");
                    Checkpoint::load(&path)?
                }
                Some(path) => {
                    let ckpt = Checkpoint::synthesize(config, seed)?;
                    ckpt.save(&path)?;
                    println!("synthesized checkpoint (seed {seed:#x}) saved to {path}");
                    ckpt
                }
                None => Checkpoint::synthesize(config, seed)?,
            };
            let sampler = SamplerConfig {
                temperature: parse_flag(args, "--temperature", 0.0f32)?,
                top_k: parse_flag(args, "--top-k", 0usize)?,
                seed,
            };
            let backend = ModelBackend::new(
                &ckpt,
                engine,
                ModelBackendConfig {
                    prefill_len,
                    max_seq: prefill_len + max_new + 8,
                    sampler,
                },
            )?
            .with_profile(plat);
            println!(
                "loaded {} parameters ({:.1} KB packed BitLinear weights)",
                ckpt.param_count(),
                backend.weight_bytes() as f64 / 1e3
            );
            if let Some(plan) = backend.plan_summary() {
                println!("BitLinear sites: {plan}");
            }
            drive(backend, n_req, max_new, batch, workers, opts)
        }
        other => tsar::bail!("--backend must be sim, native or model, got {other:?}"),
    }
}

#[cfg(feature = "pjrt")]
fn serve_pjrt(
    dir: &str,
    args: &[String],
    n_req: usize,
    max_new: usize,
    batch: usize,
    workers: usize,
    opts: ServeOpts,
) -> Result<()> {
    let variant = flag(args, "--variant").unwrap_or_else(|| "tsar".into());
    println!("loading artifacts from {dir} (variant {variant}) ...");
    let rt = tsar::runtime::ModelRuntime::load(dir, &variant)?;
    drive(rt, n_req, max_new, batch, workers, opts)
}

#[cfg(not(feature = "pjrt"))]
fn serve_pjrt(
    _dir: &str,
    _args: &[String],
    _n_req: usize,
    _max_new: usize,
    _batch: usize,
    _workers: usize,
    _opts: ServeOpts,
) -> Result<()> {
    tsar::bail!(
        "--artifacts needs the PJRT runtime; rebuild with `cargo build --features pjrt` \
         (see README.md for the dependency note), or drop --artifacts to serve on the \
         default SimBackend"
    )
}

/// Serve-mode options shared by every backend path.
struct ServeOpts {
    /// `--metrics <path|->`: JSONL request-record exporter target.
    metrics: Option<String>,
    /// `--stream`: drive the streaming Engine API (per-token output)
    /// instead of the blocking batch surface.
    stream: bool,
    /// `--http ADDR`: serve network clients over the HTTP front-end
    /// instead of a synthetic workload.
    http: Option<String>,
    /// `--queue-cap N`: bound the admission queue — submissions that
    /// find it full are shed (HTTP clients get a `429`).
    queue_cap: Option<usize>,
}

/// Drive any backend through the coordinator with a synthetic request
/// mix and print the serve report (per-lane breakdown included when
/// serving with more than one worker).  `--stream` submits the same
/// mix through the session-based Engine API and prints tokens as they
/// land; `--metrics` attaches the JSONL exporter either way.
/// `--http ADDR` dispatches to [`drive_http`] instead: no synthetic
/// mix — network clients submit over the HTTP front-end.
fn drive<B: Backend + Send + Sync + 'static>(
    backend: B,
    n_req: usize,
    max_new: usize,
    batch: usize,
    workers: usize,
    opts: ServeOpts,
) -> Result<()> {
    let cfg = backend.config().clone();
    println!("serving on {}", backend.describe());
    println!(
        "window: prefill {} tokens, KV capacity {}, vocab {}  ({workers} worker lane(s), \
         batch {batch}/lane)",
        cfg.prefill_len, cfg.max_seq, cfg.vocab
    );

    let scfg =
        ServerConfig { max_batch: batch, kv_slots: batch, workers, queue_cap: opts.queue_cap };

    if let Some(addr) = opts.http.as_deref() {
        // HTTP mode: no synthetic workload — network clients drive the
        // engine until stdin closes.
        return drive_http(backend, scfg, addr, opts.metrics.as_deref());
    }

    let mut rng = Rng::new(7);
    let prompts: Vec<Vec<i32>> = (0..n_req)
        .map(|_| {
            let hi = (cfg.prefill_len as i64 - 1).max(3);
            let plen = rng.range_i64(3, hi) as usize;
            (0..plen).map(|_| rng.below(cfg.vocab as u64) as i32).collect()
        })
        .collect();

    // The metrics endpoint: a JSONL exporter draining the request-
    // record channel while the run is in flight.
    let (rec_tx, exporter) = match opts.metrics.as_deref() {
        Some(target) => {
            let (tx, rx) = channel();
            (Some(tx), Some(Exporter::spawn(rx, target)?))
        }
        None => (None, None),
    };

    let report = if opts.stream {
        // The streaming path: a live engine, one ticket per request,
        // tokens printed as their decode rounds land.
        let handle = Engine::start_with_sink(backend, scfg, rec_tx)?;
        let tickets: Vec<Ticket> = prompts
            .into_iter()
            .map(|p| handle.submit(GenerationRequest::new(p, max_new)))
            .collect();
        for ticket in tickets {
            use std::io::Write;
            print!("  req {:>2} |", ticket.id());
            while let Some(ev) = ticket.recv() {
                match ev {
                    TokenEvent::Prefilled { token } | TokenEvent::Token { token, .. } => {
                        // Flush per token: line-buffered stdout would
                        // otherwise hold the whole line until retire,
                        // defeating the live stream.
                        print!(" {token}");
                        let _ = std::io::stdout().flush();
                    }
                    TokenEvent::Retired(r) => {
                        println!("  [{} | {:.1} tok/s]", r.finish.label(), r.decode_tokens_per_s());
                    }
                    TokenEvent::Cancelled(r) | TokenEvent::Failed(r) => {
                        println!("  [{}]", r.finish.label());
                    }
                }
            }
        }
        handle.shutdown()?
    } else {
        // Preloaded mode shards the fixed list before the lanes start,
        // so repeated runs (e.g. --workers 1 vs --workers 4
        // comparisons) are schedule-deterministic.
        let mut server = Server::new(backend, scfg)?;
        if let Some(tx) = rec_tx {
            server = server.with_metrics_sink(tx);
        }
        let requests: Vec<Request> = prompts
            .into_iter()
            .enumerate()
            .map(|(id, prompt)| Request::new(id as u64, prompt, max_new))
            .collect();
        let (res_tx, res_rx) = channel();
        let report = server.run_preloaded(requests, res_tx)?;
        drop(res_rx);
        drop(server); // release the metrics sender so the exporter drains
        report
    };
    report.print();
    if let Some(exporter) = exporter {
        let n = exporter.finish()?;
        println!(
            "metrics: {n} JSONL record(s) exported to {}",
            opts.metrics.as_deref().unwrap_or("-")
        );
    }
    Ok(())
}

/// `serve --http ADDR`: put the HTTP front-end over a live engine and
/// block until stdin closes.  The engine's request records feed the
/// Prometheus aggregator behind `GET /metrics` — teed into the JSONL
/// exporter as well when `--metrics` is set — and stopping prints the
/// merged serve report next to the final scrape.
fn drive_http<B: Backend + Send + Sync + 'static>(
    backend: B,
    scfg: ServerConfig,
    addr: &str,
    metrics: Option<&str>,
) -> Result<()> {
    let (agg_tx, agg_rx) = channel::<RequestRecord>();
    let aggregator = PromAggregator::spawn(agg_rx);
    let counters = aggregator.counters();
    let (engine_tx, exporter) = match metrics {
        Some(target) => {
            let (exp_tx, exp_rx) = channel();
            let exporter = Exporter::spawn(exp_rx, target)?;
            let (tee_tx, tee_rx) = channel();
            tee_records(tee_rx, agg_tx, exp_tx);
            (tee_tx, Some(exporter))
        }
        None => (agg_tx, None),
    };
    let handle = Arc::new(Engine::start_with_sink(backend, scfg, Some(engine_tx))?);
    let http = HttpServer::start(addr, Arc::clone(&handle), counters, HttpConfig::default())?;
    println!("HTTP front-end listening on {}", http.local_addr());
    println!("  POST /v1/generate  {{\"prompt\":[..],\"max_new_tokens\":N}} -> NDJSON stream");
    println!("  GET  /metrics      Prometheus counters    GET /healthz  liveness");
    println!("press Enter (or close stdin) to stop ...");
    let mut line = String::new();
    let _ = std::io::stdin().read_line(&mut line);

    println!("stopping: draining in-flight sessions ...");
    http.stop();
    let handle = Arc::try_unwrap(handle)
        .map_err(|_| tsar::err!("HTTP workers still hold the engine handle"))?;
    match handle.shutdown() {
        Ok(report) => report.print(),
        // A server that was never hit has nothing to report; that is
        // not an error for a front-end run.
        Err(e) => println!("no serve report: {e}"),
    }
    let observed = aggregator.finish();
    println!("prometheus aggregator observed {observed} record(s)");
    if let Some(exporter) = exporter {
        let n = exporter.finish()?;
        println!("metrics: {n} JSONL record(s) exported to {}", metrics.unwrap_or("-"));
    }
    Ok(())
}

/// `bench-serve`: run the open-loop load generator against the
/// in-process serving stack (or an external front-end via `--addr`)
/// and write the cross-checked `BENCH_serve.json` artifact.  The run
/// fails when the client's outcome counts disagree with the engine's
/// `/metrics` deltas — the artifact is still written for post-mortems.
fn bench_serve_cmd(args: &[String]) -> Result<()> {
    if let Some(path) = flag(args, "--validate") {
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("cannot read {path}"))?;
        let summary = tsar::util::artifact::validate_any(&text)?;
        println!("[bench-serve] {path}: {summary}");
        return Ok(());
    }
    let mut cfg = if args.iter().any(|a| a == "--smoke") {
        BenchConfig::smoke()
    } else {
        BenchConfig::default()
    };
    cfg.seed = parse_flag(args, "--seed", cfg.seed)?;
    cfg.requests = parse_flag(args, "--requests", cfg.requests)?;
    cfg.rate_rps = parse_flag(args, "--rate", cfg.rate_rps)?;
    cfg.bursty = match flag(args, "--arrivals").as_deref() {
        Some("poisson") => false,
        Some("bursty") => true,
        Some(other) => tsar::bail!("--arrivals expects poisson|bursty, got {other:?}"),
        None => cfg.bursty,
    };
    cfg.on_s = parse_flag(args, "--on", cfg.on_s)?;
    cfg.off_s = parse_flag(args, "--off", cfg.off_s)?;
    cfg.conns = parse_flag(args, "--conns", cfg.conns)?;
    cfg.cancel_rate = parse_flag(args, "--cancel-rate", cfg.cancel_rate)?;
    cfg.deadline_frac = parse_flag(args, "--deadline-frac", cfg.deadline_frac)?;
    cfg.workers = parse_flag(args, "--workers", cfg.workers)?;
    cfg.max_batch = parse_flag(args, "--batch", cfg.max_batch)?;
    if let Some(cap) = flag(args, "--queue-cap") {
        let cap = cap.parse().map_err(|_| tsar::err!("--queue-cap expects a number"))?;
        cfg.queue_cap = Some(cap);
    }
    if let Some(model) = flag(args, "--model") {
        cfg.model = model;
    }
    cfg.addr = flag(args, "--addr");
    let out = flag(args, "--out")
        .unwrap_or_else(|| format!("{}/../BENCH_serve.json", env!("CARGO_MANIFEST_DIR")));

    let output = tsar::loadgen::run(&cfg)?;
    std::fs::write(&out, output.artifact.to_string() + "\n")
        .with_context(|| format!("cannot write {out}"))?;
    let c = &output.counts;
    println!(
        "[bench-serve] {} requests ({} arrivals @ {:.0} rps, {} conns) in {:.2} s",
        cfg.requests,
        cfg.arrivals().name(),
        cfg.rate_rps,
        cfg.conns,
        output.wall_s
    );
    println!(
        "[bench-serve] outcomes: {} completed / {} cancelled / {} rejected / {} failed / \
         {} http-shed",
        c.completed, c.cancelled, c.rejected, c.failed, c.http_shed
    );
    println!(
        "[bench-serve] goodput {:.1} tok/s ({} of {} tokens on completed streams), \
         artifact -> {out}",
        c.tokens_completed as f64 / output.wall_s.max(f64::MIN_POSITIVE),
        c.tokens_completed,
        c.tokens_total
    );
    if !output.agree {
        for m in &output.mismatches {
            eprintln!("[bench-serve] cross-check mismatch: {m}");
        }
        tsar::bail!("client-side counts disagree with the /metrics scrape");
    }
    println!("[bench-serve] cross-check: client outcome counts match /metrics exactly");
    Ok(())
}

/// `calibrate`: measure the native GEMM kernels over a shape × thread
/// grid (or replay a fixture), fit the platform profile's free
/// constants, and write the calibrated `PLATFORM_host.json`.
fn calibrate_cmd(args: &[String]) -> Result<()> {
    use tsar::calibrate;

    if let Some(path) = flag(args, "--validate") {
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("cannot read {path}"))?;
        let summary = tsar::util::artifact::validate_any(&text)?;
        println!("[calibrate] {path}: {summary}");
        return Ok(());
    }

    let base = profile_by_name(flag(args, "--base").as_deref())?;
    let out = flag(args, "--out")
        .unwrap_or_else(|| format!("{}/../PLATFORM_host.json", env!("CARGO_MANIFEST_DIR")));

    if let Some(path) = flag(args, "--emit-fixture") {
        let truth = calibrate::Truth::example();
        let fx = calibrate::synthesize(&base, &truth);
        fx.save(&path)?;
        println!(
            "[calibrate] synthetic fixture: {} measurements from perturbed {} -> {path}",
            fx.measurements.len(),
            base.name
        );
        return Ok(());
    }

    let report;
    let grid_label;
    if let Some(path) = flag(args, "--fixture") {
        let fx = calibrate::Fixture::load(&path)?;
        let fx_base = profile_by_name(Some(fx.base.as_str()))?;
        grid_label = format!("fixture {path} ({} measurements)", fx.measurements.len());
        println!(
            "[calibrate] fitting {} offline measurements against base {}",
            fx.measurements.len(),
            fx_base.name
        );
        report = calibrate::fit(&fx_base, &fx.measurements, "fixture", &grid_label)?;
        if let Some(truth) = &fx.truth {
            calibrate::check_recovery(&report, truth)?;
            println!("[calibrate] recovered every embedded truth constant within tolerance");
        }
    } else {
        let smoke = args.iter().any(|a| a == "--smoke");
        let isa = parse_isa(args)?;
        let host_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let max_threads: usize = parse_flag(args, "--threads", host_threads)?;
        tsar::ensure!(max_threads >= 1, "--threads must be >= 1");
        let grid = calibrate::grid(smoke, max_threads);
        grid_label = calibrate::grid_desc(&grid, smoke);
        let (min_runs, min_secs) = if smoke { (3, 0.02) } else { (5, 0.2) };
        println!(
            "[calibrate] measuring native {} GEMM over {} ...",
            isa.name(),
            grid_label
        );
        let (meas, path_name) = calibrate::measure(isa, &grid, min_runs, min_secs)?;
        let host = format!("{}/{}/{}t", std::env::consts::ARCH, path_name, max_threads);
        report = calibrate::fit(&base, &meas, &host, &grid_label)?;
    }

    let p = &report.profile;
    println!(
        "[calibrate] fitted constants: dram.efficiency={:.3} issue_scale={:.3} \
         latency_scale={:.3} thread_contention={:.3}",
        p.dram_efficiency,
        p.model.issue_scale,
        p.model.latency_scale,
        p.model.thread_contention
    );
    println!(
        "[calibrate] residuals: train rmse(log)={:.4}, held-out max rel err={:.4}",
        report.train_rmse_log, report.holdout_max_rel_err
    );
    p.save(&out)?;
    println!("[calibrate] calibrated profile [{}] -> {out}", p.provenance_label());
    Ok(())
}
