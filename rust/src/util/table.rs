//! Plain-text table printer for the report harnesses (each paper table /
//! figure prints through this so rows line up and are easy to diff against
//! EXPERIMENTS.md).

#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width != header width"
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push(' ');
                line.push_str(c);
                line.push_str(&" ".repeat(w - c.chars().count()));
                line.push_str(" |");
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('|');
        for w in &width {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "x"]);
        t.row(vec!["a", "1"]).row(vec!["long-name", "22"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines equal width.
        assert!(lines.iter().all(|l| l.chars().count() == lines[0].chars().count()));
        assert!(lines[0].contains("name"));
        assert!(lines[3].contains("long-name"));
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        Table::new(vec!["a", "b"]).row(vec!["only-one"]);
    }
}
