//! Small self-contained utilities: errors, JSON, PRNG, statistics,
//! table printing, bench-artifact schema validation.
//!
//! The build environment is offline with a minimal crate cache (no serde,
//! rand, criterion, anyhow), so these are in-tree. Each is deliberately
//! tiny and fully unit-tested.

pub mod artifact;
pub mod error;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

/// Format a byte count with binary units.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a large count with SI units (1.2 M, 3.4 G, ...).
pub fn fmt_si(x: f64) -> String {
    const UNITS: [&str; 5] = ["", "K", "M", "G", "T"];
    let mut v = x;
    let mut u = 0;
    while v.abs() >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0}")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert_eq!(fmt_bytes(3.5 * 1024.0 * 1024.0), "3.50 MiB");
    }

    #[test]
    fn si_formatting() {
        assert_eq!(fmt_si(999.0), "999");
        assert_eq!(fmt_si(1200.0), "1.20 K");
        assert_eq!(fmt_si(2.5e9), "2.50 G");
    }
}
