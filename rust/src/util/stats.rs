//! Statistics helpers used by the bench harnesses and the coordinator's
//! latency reporting.

/// Geometric mean of strictly-positive values (paper's speedup summaries).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let logsum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values, got {x}");
            x.ln()
        })
        .sum();
    (logsum / xs.len() as f64).exp()
}

/// Percentile via linear interpolation on a sorted copy. `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if v.len() == 1 {
        return v[0];
    }
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] + (v[hi] - v[lo]) * frac
}

pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Simple wall-clock timing of repeated runs: returns (mean_s, min_s, runs).
/// The in-tree stand-in for criterion (not in the offline cache).
pub fn time_it<F: FnMut()>(mut f: F, min_runs: usize, min_secs: f64) -> (f64, f64, usize) {
    // Warmup.
    f();
    let mut times = Vec::new();
    let start = std::time::Instant::now();
    while times.len() < min_runs || start.elapsed().as_secs_f64() < min_secs {
        let t0 = std::time::Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
        if times.len() > 10_000 {
            break;
        }
    }
    (mean(&times), min(&times), times.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[8.0]) - 8.0).abs() < 1e-12);
        let g = geomean(&[2.0, 8.0, 4.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn mean_min_max() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(mean(&xs), 2.0);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 3.0);
    }

    #[test]
    fn timing_runs() {
        let (_, mn, n) = time_it(
            || {
                std::hint::black_box(1 + 1);
            },
            5,
            0.0,
        );
        assert!(n >= 5);
        assert!(mn >= 0.0);
    }
}
