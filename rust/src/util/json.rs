//! Minimal JSON parser + writer (no serde in the offline crate cache).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json`:
//! objects, arrays, strings (with escapes), numbers, booleans, null.
//! Numbers are held as f64 (manifest offsets stay well below 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that reports the missing key.
    pub fn req(&self, key: &str) -> crate::util::error::Result<&Json> {
        self.get(key)
            .ok_or_else(|| crate::err!("missing JSON key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn big_manifest_like() {
        let mut obj = String::from("{\"params\":[");
        for i in 0..100 {
            if i > 0 {
                obj.push(',');
            }
            obj.push_str(&format!(
                "{{\"name\":\"p{i}\",\"offset\":{},\"shape\":[{i},4]}}",
                i * 1024
            ));
        }
        obj.push_str("]}");
        let j = Json::parse(&obj).unwrap();
        let params = j.get("params").unwrap().as_arr().unwrap();
        assert_eq!(params.len(), 100);
        assert_eq!(params[7].get("offset").unwrap().as_usize(), Some(7168));
    }
}
