//! In-tree error type: the `anyhow` substitute for the offline default
//! build (no crates.io access; see `rust/Cargo.toml`).
//!
//! Mirrors the slice of anyhow's surface this crate uses — a context
//! chain, a [`Context`] extension trait for `Result`/`Option`, and the
//! [`crate::bail!`] / [`crate::ensure!`] / [`crate::err!`] macros — so
//! the PJRT feature (which keeps real `anyhow` for the `xla` crate's
//! error types) and the default build share call sites.

use std::fmt;

/// A boxed-string error with an outermost-first context chain.
pub struct Error {
    /// `chain[0]` is the most recently attached context; the root cause
    /// is last.
    chain: Vec<String>,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { chain: vec![msg.into()] }
    }

    /// Attach outer context (like `anyhow::Error::context`).
    pub fn context(mut self, ctx: impl Into<String>) -> Error {
        self.chain.insert(0, ctx.into());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    // `fn main() -> Result<()>` exits through Debug; keep it readable.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error::msg(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context attachment for `Result` and `Option`, like `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e.to_string()).context(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e.to_string()).context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string (the `anyhow::anyhow!`
/// substitute).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => { $crate::util::error::Error::msg(format!($($arg)*)) };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::err!($($arg)*)) };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn failing_io() -> Result<String> {
        std::fs::read_to_string("/definitely/not/a/path")
            .context("reading config")
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = failing_io().unwrap_err();
        let s = e.to_string();
        assert!(s.starts_with("reading config: "), "got {s:?}");
        assert!(!e.root_cause().contains("reading config"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
        assert_eq!(Some(3).context("never used").unwrap(), 3);
    }

    #[test]
    fn macros_roundtrip() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                crate::bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
        assert_eq!(crate::err!("e {}", 1).to_string(), "e 1");
    }

    #[test]
    fn bare_ensure_reports_condition() {
        fn f() -> Result<()> {
            crate::ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("1 + 1 == 3"));
    }
}
