//! Schema validation for the repo-root machine-readable artifacts.
//!
//! Every harness that emits one (`BENCH_native_gemm.json` from
//! `benches/native_gemv.rs`, `BENCH_serve.json` from
//! `tsar-cli bench-serve`, `PLATFORM_host.json` from
//! `tsar-cli calibrate`) validates its own output through this module,
//! and `ci/check.sh` re-validates the checked-in files — so a drifting
//! artifact fails CI with a *named* field error instead of silently
//! changing shape.
//!
//! Both schemas share the same conventions: a `bench` discriminator, a
//! numeric `schema_version` (the validators here speak v1), a
//! `measured` flag (placeholder artifacts are checked in with
//! `measured: false` and all-zero timings; a measured artifact must
//! carry strictly positive timings), and a `smoke` flag marking
//! CI-sized runs.

use crate::util::json::Json;

/// Percentile keys every latency block in `BENCH_serve.json` carries.
pub const LATENCY_STAT_KEYS: [&str; 5] = ["p50", "p95", "p99", "mean", "max"];

/// Outcome classes `BENCH_serve.json` counts (client-side view).
pub const SERVE_OUTCOME_KEYS: [&str; 5] =
    ["completed", "cancelled", "rejected", "failed", "http_shed"];

/// Validate any repo artifact, dispatching on its discriminator field
/// (`bench` for the perf artifacts, `profile` for platform profiles).
/// Returns a one-line human summary for the CLI/CI log.
pub fn validate_any(text: &str) -> crate::Result<String> {
    let v = parse(text)?;
    if v.get("bench").is_none() && v.get("profile").is_some() {
        let label = validate_platform_profile(text)?;
        return Ok(format!("platform profile schema v1 OK ({label})"));
    }
    match v.req("bench")?.as_str() {
        Some("native_gemm") => {
            let n = check_native_gemm(&v)?;
            Ok(format!("native_gemm schema v1 OK ({n} entries)"))
        }
        Some("serve") => {
            let n = check_serve(&v)?;
            Ok(format!("serve schema v1 OK ({n} requests)"))
        }
        Some(other) => crate::bail!("unknown bench artifact kind {other:?}"),
        None => crate::bail!("bench must be a string"),
    }
}

/// Schema contract for `BENCH_native_gemm.json`; returns the entry
/// count.
pub fn validate_native_gemm(text: &str) -> crate::Result<usize> {
    check_native_gemm(&parse(text)?)
}

/// Schema contract for `BENCH_serve.json`; returns the request count.
pub fn validate_serve(text: &str) -> crate::Result<usize> {
    check_serve(&parse(text)?)
}

/// Schema contract for platform-profile documents (`profiles/*.json`,
/// `PLATFORM_host.json` from `tsar-cli calibrate`): the full
/// [`crate::config::PlatformProfile`] field/provenance validation.
/// Returns `"<name> [<provenance>]"`.
pub fn validate_platform_profile(text: &str) -> crate::Result<String> {
    let prof = crate::config::PlatformProfile::parse(text)?;
    Ok(format!("{} [{}]", prof.name, prof.provenance_label()))
}

fn parse(text: &str) -> crate::Result<Json> {
    Json::parse(text).map_err(|e| crate::err!("artifact is not JSON: {e}"))
}

/// The header every `BENCH_*` artifact shares: a `bench` discriminator,
/// a v1 `schema_version`, and the `measured`/`smoke` run flags.
/// Returns the `measured` flag.
fn check_bench_header(v: &Json, kind: &str) -> crate::Result<bool> {
    crate::ensure!(v.req("bench")?.as_str() == Some(kind), "bench name must be {kind:?}");
    crate::ensure!(
        v.req("schema_version")?.as_f64() == Some(1.0),
        "unknown schema_version (validator speaks v1)"
    );
    let measured = v.req("measured")? == &Json::Bool(true);
    v.req("smoke")?;
    Ok(measured)
}

fn check_native_gemm(v: &Json) -> crate::Result<usize> {
    let measured = check_bench_header(v, "native_gemm")?;
    crate::ensure!(v.req("path")?.as_str().is_some(), "path must be a string");
    crate::ensure!(v.req("threads")?.as_usize().is_some_and(|t| t >= 1), "threads must be >= 1");
    crate::ensure!(
        v.req("row_block")?.as_usize().is_some_and(|r| r >= 1),
        "row_block must be >= 1"
    );
    let Some(entries) = v.req("entries")?.as_arr() else {
        crate::bail!("entries must be an array");
    };
    crate::ensure!(!entries.is_empty(), "entries must be non-empty");
    const ENTRY_NUM_KEYS: [&str; 5] =
        ["pool_min_s", "scoped_min_s", "amortization_ratio", "eff_weights_gb_s", "mac_per_s"];
    for (i, e) in entries.iter().enumerate() {
        for key in ["n", "k", "m"] {
            crate::ensure!(
                e.req(key)?.as_usize().is_some_and(|x| x >= 1),
                "entry {i}: {key} must be a positive integer"
            );
        }
        crate::ensure!(e.req("isa")?.as_str().is_some(), "entry {i}: isa must be a string");
        for key in ENTRY_NUM_KEYS {
            let x = e
                .req(key)?
                .as_f64()
                .ok_or_else(|| crate::err!("entry {i}: {key} must be a number"))?;
            crate::ensure!(x.is_finite() && x >= 0.0, "entry {i}: {key} must be finite and >= 0");
            crate::ensure!(!measured || x > 0.0, "entry {i}: measured artifact has zero {key}");
        }
    }
    Ok(entries.len())
}

fn check_serve(v: &Json) -> crate::Result<usize> {
    let measured = check_bench_header(v, "serve")?;
    crate::ensure!(v.req("seed")?.as_f64().is_some(), "seed must be a number");
    crate::ensure!(v.req("backend")?.as_str().is_some(), "backend must be a string");

    let cfg = v.req("config")?;
    for key in ["workers", "max_batch", "conns"] {
        crate::ensure!(
            cfg.req(key)?.as_usize().is_some_and(|x| x >= 1),
            "config.{key} must be >= 1"
        );
    }
    let queue_cap = cfg.req("queue_cap")?;
    crate::ensure!(
        queue_cap == &Json::Null || queue_cap.as_usize().is_some_and(|x| x >= 1),
        "config.queue_cap must be null or >= 1"
    );

    let w = v.req("workload")?;
    let requests = w
        .req("requests")?
        .as_usize()
        .filter(|&r| r >= 1)
        .ok_or_else(|| crate::err!("workload.requests must be >= 1"))?;
    crate::ensure!(w.req("arrivals")?.as_str().is_some(), "workload.arrivals must be a string");
    crate::ensure!(
        w.req("rate_rps")?.as_f64().is_some_and(|r| r.is_finite() && r > 0.0),
        "workload.rate_rps must be finite and > 0"
    );
    crate::ensure!(
        w.req("trace_fingerprint")?.as_str().is_some_and(|f| f.starts_with("0x")),
        "workload.trace_fingerprint must be a 0x-prefixed hex string"
    );

    let outcomes = v.req("outcomes")?;
    let mut outcome_sum = 0usize;
    for key in SERVE_OUTCOME_KEYS {
        let x = outcomes
            .req(key)?
            .as_usize()
            .ok_or_else(|| crate::err!("outcomes.{key} must be a non-negative integer"))?;
        outcome_sum += x;
    }
    crate::ensure!(
        outcome_sum == requests,
        "outcomes must sum to workload.requests ({outcome_sum} != {requests})"
    );

    let tokens = v.req("tokens")?;
    let total = tokens
        .req("total")?
        .as_usize()
        .ok_or_else(|| crate::err!("tokens.total must be a non-negative integer"))?;
    let completed = tokens
        .req("completed")?
        .as_usize()
        .ok_or_else(|| crate::err!("tokens.completed must be a non-negative integer"))?;
    crate::ensure!(completed <= total, "tokens.completed must be <= tokens.total");

    let latency = v.req("latency")?;
    for block in ["ttft_s", "tpot_s", "e2e_s"] {
        let b = latency.req(block)?;
        for key in LATENCY_STAT_KEYS {
            let x = b
                .req(key)?
                .as_f64()
                .ok_or_else(|| crate::err!("latency.{block}.{key} must be a number"))?;
            crate::ensure!(
                x.is_finite() && x >= 0.0,
                "latency.{block}.{key} must be finite and >= 0"
            );
        }
    }

    crate::ensure!(
        v.req("goodput_tok_per_s")?.as_f64().is_some_and(|g| g.is_finite() && g >= 0.0),
        "goodput_tok_per_s must be finite and >= 0"
    );
    crate::ensure!(
        v.req("shed_rate")?.as_f64().is_some_and(|s| (0.0..=1.0).contains(&s)),
        "shed_rate must be within [0, 1]"
    );
    let wall = v
        .req("wall_s")?
        .as_f64()
        .filter(|w| w.is_finite() && *w >= 0.0)
        .ok_or_else(|| crate::err!("wall_s must be finite and >= 0"))?;
    crate::ensure!(!measured || wall > 0.0, "measured artifact has zero wall_s");

    let cross = v.req("cross_check")?;
    let agree = match cross.req("metrics_agree")? {
        Json::Bool(b) => *b,
        _ => crate::bail!("cross_check.metrics_agree must be a bool"),
    };
    crate::ensure!(
        !measured || agree,
        "measured artifact failed its Prometheus cross-check (cross_check.metrics_agree is false)"
    );
    Ok(requests)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn serve_doc() -> String {
        let stats = r#"{"p50":0.01,"p95":0.02,"p99":0.03,"mean":0.015,"max":0.04}"#;
        format!(
            concat!(
                r#"{{"bench":"serve","schema_version":1,"measured":true,"smoke":true,"#,
                r#""seed":7,"backend":"sim","#,
                r#""config":{{"workers":2,"max_batch":4,"queue_cap":null,"conns":2}},"#,
                r#""workload":{{"requests":10,"arrivals":"poisson","rate_rps":50,"#,
                r#""trace_fingerprint":"0xdeadbeefdeadbeef"}},"#,
                r#""outcomes":{{"completed":7,"cancelled":1,"rejected":2,"failed":0,"http_shed":0}},"#,
                r#""tokens":{{"total":60,"completed":56}},"#,
                r#""latency":{{"ttft_s":{s},"tpot_s":{s},"e2e_s":{s}}},"#,
                r#""goodput_tok_per_s":120.5,"shed_rate":0.2,"wall_s":0.5,"#,
                r#""cross_check":{{"metrics_agree":true}}}}"#,
            ),
            s = stats
        )
    }

    #[test]
    fn serve_schema_accepts_a_complete_artifact() {
        assert_eq!(validate_serve(&serve_doc()).unwrap(), 10);
        assert!(validate_any(&serve_doc()).unwrap().contains("serve schema v1 OK"));
    }

    #[test]
    fn serve_schema_names_the_missing_field() {
        let doc = serve_doc().replace(r#""shed_rate":0.2,"#, "");
        let err = validate_serve(&doc).unwrap_err().to_string();
        assert!(err.contains("shed_rate"), "got {err:?}");
    }

    #[test]
    fn serve_schema_rejects_outcome_sum_mismatch() {
        let doc = serve_doc().replace(r#""completed":7"#, r#""completed":8"#);
        let err = validate_serve(&doc).unwrap_err().to_string();
        assert!(err.contains("sum to workload.requests"), "got {err:?}");
    }

    #[test]
    fn serve_schema_rejects_failed_cross_check_when_measured() {
        let doc = serve_doc().replace(r#""metrics_agree":true"#, r#""metrics_agree":false"#);
        let err = validate_serve(&doc).unwrap_err().to_string();
        assert!(err.contains("metrics_agree"), "got {err:?}");
        // A placeholder (measured: false) may carry an unchecked cross-check.
        let placeholder = doc
            .replace(r#""measured":true"#, r#""measured":false"#)
            .replace(r#""wall_s":0.5"#, r#""wall_s":0"#);
        validate_serve(&placeholder).unwrap();
    }

    #[test]
    fn native_gemm_schema_accepts_the_checked_in_placeholder_shape() {
        let doc = concat!(
            r#"{"bench":"native_gemm","schema_version":1,"measured":false,"smoke":true,"#,
            r#""path":"scalar","threads":2,"row_block":4,"entries":[{"isa":"C2","n":1,"#,
            r#""k":256,"m":256,"pool_min_s":0,"scoped_min_s":0,"amortization_ratio":0,"#,
            r#""eff_weights_gb_s":0,"mac_per_s":0}]}"#
        );
        assert_eq!(validate_native_gemm(doc).unwrap(), 1);
        // The same timings with measured:true must fail, field-named.
        let measured = doc.replace(r#""measured":false"#, r#""measured":true"#);
        let err = validate_native_gemm(&measured).unwrap_err().to_string();
        assert!(err.contains("zero pool_min_s"), "got {err:?}");
    }

    #[test]
    fn dispatch_rejects_unknown_kinds() {
        let err = validate_any(r#"{"bench":"nope"}"#).unwrap_err().to_string();
        assert!(err.contains("unknown bench artifact kind"), "got {err:?}");
    }

    #[test]
    fn dispatch_recognises_platform_profiles() {
        let doc = crate::config::PlatformProfile::workstation().to_json().to_string();
        let summary = validate_any(&doc).unwrap();
        assert!(summary.contains("platform profile schema v1 OK"), "got {summary:?}");
        assert!(summary.contains("Workstation [table1]"), "got {summary:?}");
        assert_eq!(validate_platform_profile(&doc).unwrap(), "Workstation [table1]");
    }

    #[test]
    fn platform_profile_validation_names_the_broken_field() {
        let doc = crate::config::PlatformProfile::mobile().to_json().to_string();
        let bad = doc.replace(r#""efficiency":0.55"#, r#""efficiency":1.55"#);
        assert_ne!(bad, doc, "replacement must hit the serialized document");
        let err = validate_platform_profile(&bad).unwrap_err().to_string();
        assert!(err.contains("efficiency"), "got {err:?}");
    }
}
