//! Deterministic PRNG (SplitMix64 + xoshiro256**) — no `rand` crate in the
//! offline cache.  Used for synthetic weights/activations, workload
//! generation and the in-tree property-test harness.

/// xoshiro256** seeded via SplitMix64. Deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Random ternary weight matrix (row-major M x K) with the given zero
    /// fraction — the synthetic stand-in for BitNet checkpoints.
    pub fn ternary_matrix(&mut self, m: usize, k: usize, zero_frac: f64) -> Vec<i8> {
        (0..m * k)
            .map(|_| {
                if self.f64() < zero_frac {
                    0
                } else if self.f64() < 0.5 {
                    -1
                } else {
                    1
                }
            })
            .collect()
    }

    /// Random int8 activations in [-127, 127].
    pub fn int8_acts(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| self.range_i64(-127, 127) as i8).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn ternary_matrix_stats() {
        let mut r = Rng::new(13);
        let w = r.ternary_matrix(100, 100, 0.3);
        let zeros = w.iter().filter(|&&x| x == 0).count() as f64 / 10_000.0;
        assert!((zeros - 0.3).abs() < 0.03, "zero fraction {zeros}");
        assert!(w.iter().all(|&x| (-1..=1).contains(&x)));
    }
}
