//! Analytic timing engine — the sweep-scale half of the gem5 substitute.
//!
//! Model (per kernel execution, per platform, `t` threads):
//!
//! 1. **Placement.** Each stream's *home level* is the smallest cache
//!    level whose effective capacity holds the stream's footprint plus
//!    all hotter (smaller) streams.  Shared levels are split across
//!    threads; private levels are per-thread.
//! 2. **Traffic.** A stream's requests all hit L1 ports (request volume);
//!    levels smaller than the footprint see the footprint on every pass,
//!    the home level and below see it once (cold fill).  Stores add a
//!    write-back copy of the dirty footprint below the home level.
//! 3. **Time.** Compute cycles = µ-ops / issue width.  Memory cycles =
//!    Σ_level latency·(line transfers)/MLP + L1 port bandwidth, lower-
//!    bounded by the shared-DRAM-bandwidth term.  Per-thread total =
//!    max(compute, memory) + a small serialization residue (OoO overlap).
//!
//! Validated against the trace-driven [`super::cache`] hierarchy on small
//! shapes in `rust/tests/integration.rs`.
//!
//! The platform's [`crate::config::ModelConstants`] scale the model's
//! free terms (issue width, latencies, thread-shared DRAM contention).
//! Identity constants — what every in-tree Table I profile carries —
//! are exact IEEE no-ops, so calibration support changes nothing for
//! paper-faithful profiles.

use crate::config::platforms::Platform;

use super::KernelProfile;

/// Utilization derate: caches don't hold their nameplate capacity of a
/// mixed working set (conflict misses, metadata, prefetch pollution).
const CAP_UTIL: f64 = 0.85;
/// Outstanding-miss parallelism the OoO core sustains per thread for
/// independent (prefetchable) access streams.
const MLP: f64 = 12.0;
/// Overlap for address-*dependent* accesses (LUT gathers): the lookup
/// address comes from a just-loaded weight code, so the OoO window can
/// barely overlap them — the baselines' latency wall (Fig. 2(d)).
const MLP_DEP: f64 = 1.5;
/// Access granularity of a dependent table fetch (one table line).
const DEP_ACCESS_BYTES: f64 = 64.0;
/// Scalar pipes issued per cycle.
const SCALAR_IPC: f64 = 3.0;
/// Residual serialization between the compute and memory sides.
const OVERLAP_RESIDUE: f64 = 0.08;

/// Per-level traffic in bytes. Index 0 = core→L1 requests, 1 = L1→L2
/// refills, 2 = L2→L3, 3 = L3→DRAM.
#[derive(Debug, Clone, Copy, Default)]
pub struct LevelTraffic {
    pub bytes: [f64; 4],
}

#[derive(Debug, Clone)]
pub struct SimResult {
    pub kernel: String,
    pub threads: usize,
    pub cycles: f64,
    pub seconds: f64,
    pub compute_cycles: f64,
    pub memory_cycles: f64,
    /// Whole-kernel (all threads summed) traffic per level.
    pub traffic: LevelTraffic,
    /// Core→L1 request volume in bytes (all threads) — Fig. 9's metric.
    pub request_bytes: f64,
    /// L3 hit rate among refills that reach L3.
    pub llc_hit_rate: f64,
    /// Fraction of the bottleneck attributable to the memory side —
    /// Fig. 2(d)'s "memory R/W share of execution time".
    pub mem_bound_frac: f64,
}

fn effective_caps(plat: &Platform, threads: usize) -> [f64; 4] {
    let t = threads as f64;
    let eff = |size: usize, shared: bool| {
        let base = size as f64 * CAP_UTIL;
        if shared {
            base / t
        } else {
            base
        }
    };
    [
        eff(plat.l1d.size_bytes, plat.l1d.shared),
        eff(plat.l2.size_bytes, plat.l2.shared),
        eff(plat.l3.size_bytes, plat.l3.shared),
        f64::INFINITY,
    ]
}

/// Home level per stream under cumulative competition: streams are
/// packed smallest-first (LRU keeps hot small tables resident), and a
/// stream homes at the smallest level that holds it plus everything
/// hotter.  Footprints are per-thread shares (kernels tile M across
/// threads, splitting every structure except truly shared read-only
/// data; the split is the conservative choice for contention).
fn home_levels(profile: &KernelProfile, caps: &[f64; 4], threads: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..profile.streams.len()).collect();
    order.sort_by(|&a, &b| {
        profile.streams[a]
            .footprint
            .partial_cmp(&profile.streams[b].footprint)
            .unwrap()
    });
    let mut homes = vec![3usize; profile.streams.len()];
    let mut cumulative = 0.0;
    for &idx in &order {
        cumulative += profile.streams[idx].footprint / threads as f64;
        homes[idx] = caps.iter().position(|&c| cumulative <= c).unwrap();
    }
    homes
}

/// Simulate one kernel execution on `threads` cores of `plat`.
pub fn simulate(profile: &KernelProfile, plat: &Platform, threads: usize) -> SimResult {
    assert!(threads >= 1, "need at least one thread");
    let threads = threads.min(plat.cores);
    let t = threads as f64;
    let caps = effective_caps(plat, threads);
    let homes = home_levels(profile, &caps, threads);

    // ---- traffic per level (whole kernel, all threads) --------------------
    // bytes[lvl] = demand flowing from level lvl into level lvl-1.
    // Levels *above* the home are too small: they re-fetch the footprint
    // on every pass.  The home level and everything below it see the
    // cold fill exactly once.  Dirty data adds a write-back copy
    // (write-allocate + write-back ≈ doubles the flow for the dirty
    // fraction).
    let mut traffic = LevelTraffic::default();
    for (s, &home) in profile.streams.iter().zip(&homes) {
        traffic.bytes[0] += s.bytes_accessed;
        for lvl in 1..4 {
            let flow = if lvl <= home {
                (s.footprint * s.passes).min(s.bytes_accessed).max(s.footprint)
            } else {
                s.footprint // cold fill through the levels below home
            };
            traffic.bytes[lvl] += flow * (1.0 + s.write_frac);
        }
    }

    // ---- compute time ------------------------------------------------------
    // Calibration scales the effective SIMD issue width; identity (1.0)
    // leaves `simd_ports` bit-identical.
    let ports = plat.simd_ports * plat.model.issue_scale;
    let compute_cycles =
        profile.simd_uops / (ports * t) + profile.scalar_uops / (SCALAR_IPC * t);

    // ---- memory time -------------------------------------------------------
    let line = plat.l1d.line_bytes as f64;
    // L2/L3 refill latency, MLP-overlapped.  DRAM traffic is charged by
    // bandwidth only: the kernels' miss streams are sequential (packed
    // weights, table arrays), so hardware prefetch hides DRAM latency
    // and the channel bandwidth is the binding resource.
    let ls = plat.model.latency_scale;
    let lat = [0.0, plat.l2.latency_cycles * ls, plat.l3.latency_cycles * ls, 0.0];
    let mut latency_cycles = 0.0;
    for lvl in 1..3 {
        let transfers = traffic.bytes[lvl] / line / t;
        latency_cycles += transfers * lat[lvl] / MLP;
    }
    // L1 port bandwidth: two 32 B accesses per cycle per core.
    let l1_port_cycles = traffic.bytes[0] / t / (2.0 * 32.0);
    // Dependent (non-prefetchable) accesses stall at their home level's
    // hit latency with MLP_DEP overlap — the baseline TLUT gather wall.
    let dep_lat = [
        plat.l1d.latency_cycles * ls,
        plat.l2.latency_cycles * ls,
        plat.l3.latency_cycles * ls,
        plat.dram_lat_ns * plat.cycles_per_ns() * ls,
    ];
    let mut dependent_cycles = 0.0;
    for (s, &home) in profile.streams.iter().zip(&homes) {
        if s.dependent {
            let accesses = s.bytes_accessed / DEP_ACCESS_BYTES / t;
            dependent_cycles += accesses * dep_lat[home] / MLP_DEP;
        }
    }
    // DRAM bandwidth is shared across all threads: a serial resource
    // (this is the Fig. 10 GEMV-plateau mechanism).  The calibrated
    // contention term models the sustained-bandwidth loss as more
    // threads compete for the controller; identity (0.0) is a no-op.
    let contention = 1.0 + plat.model.thread_contention * (t - 1.0);
    let dram_bw_cycles = traffic.bytes[3] * contention / plat.dram_bytes_per_cycle();

    // Dependent stalls serialize with everything else: the blocked load
    // also stalls the prefetch/miss pipeline behind it, so they add on
    // top of the bandwidth-bound streaming time rather than hiding
    // under it.
    let memory_cycles =
        (latency_cycles + l1_port_cycles).max(dram_bw_cycles) + dependent_cycles;

    let per_thread = compute_cycles.max(memory_cycles)
        + OVERLAP_RESIDUE * compute_cycles.min(memory_cycles);

    let seconds = per_thread / (plat.freq_ghz * 1e9);
    let l3_in = traffic.bytes[2];
    let llc_hit_rate = if l3_in > 0.0 {
        ((l3_in - traffic.bytes[3]) / l3_in).clamp(0.0, 1.0)
    } else {
        1.0
    };

    SimResult {
        kernel: profile.kernel.clone(),
        threads,
        cycles: per_thread,
        seconds,
        compute_cycles,
        memory_cycles,
        traffic,
        request_bytes: profile.request_bytes(),
        llc_hit_rate,
        mem_bound_frac: memory_cycles / (memory_cycles + compute_cycles).max(1e-12),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{GemmShape, KernelProfile, Stream};

    fn profile(streams: Vec<Stream>, uops: f64) -> KernelProfile {
        KernelProfile {
            kernel: "test".into(),
            shape: GemmShape::new(1, 64, 64),
            streams,
            simd_uops: uops,
            scalar_uops: uops * 0.2,
        }
    }

    #[test]
    fn small_footprint_stays_on_chip() {
        let plat = Platform::workstation();
        // Swept 100 times but fits L1: only the cold fill leaves DRAM.
        let p = profile(vec![Stream::swept("w", 16_384.0, 100.0)], 1000.0);
        let r = simulate(&p, &plat, 1);
        assert!(r.traffic.bytes[3] <= 16_384.0, "only the cold fill");
        assert!(r.traffic.bytes[1] <= 16_384.0, "L1 absorbs the passes");
        assert!(r.seconds > 0.0);
    }

    #[test]
    fn huge_footprint_goes_to_dram() {
        let plat = Platform::workstation();
        let gb = 1e9;
        let p = profile(vec![Stream::read_once("w", gb)], 1000.0);
        let r = simulate(&p, &plat, 1);
        assert!(r.traffic.bytes[3] >= gb * 0.99);
        assert!(r.mem_bound_frac > 0.9);
    }

    #[test]
    fn compute_bound_scales_with_threads() {
        let plat = Platform::workstation();
        let p = profile(vec![Stream::read_once("w", 1e4)], 1e9);
        let t1 = simulate(&p, &plat, 1).seconds;
        let t8 = simulate(&p, &plat, 8).seconds;
        let speedup = t1 / t8;
        assert!(speedup > 6.0, "compute-bound must scale, got {speedup:.2}x");
    }

    #[test]
    fn bandwidth_bound_saturates() {
        let plat = Platform::mobile();
        let p = profile(vec![Stream::read_once("w", 1e9)], 10.0);
        let t1 = simulate(&p, &plat, 1).seconds;
        let t4 = simulate(&p, &plat, 4).seconds;
        let speedup = t1 / t4;
        assert!(speedup < 1.6, "bandwidth-bound must plateau, got {speedup:.2}x");
    }

    #[test]
    fn multipass_over_l2_sized_data_hits_l3_not_dram() {
        let plat = Platform::laptop(); // 1 MB L2, 16 MB L3
        let mb4 = 4e6;
        let p = profile(vec![Stream::swept("w", mb4, 4.0)], 10.0);
        let r = simulate(&p, &plat, 1);
        // Home = L3: L1→L2 and L2→L3 see all 4 passes; DRAM only the
        // cold fill.
        assert!(r.traffic.bytes[1] >= 4.0 * mb4 * 0.99);
        assert!(r.traffic.bytes[2] >= 4.0 * mb4 * 0.99);
        assert!((r.traffic.bytes[3] - mb4).abs() < mb4 * 0.01);
    }

    #[test]
    fn tile_plus_cold_stream_decomposition() {
        // The blocked-reuse idiom: an L2-sized tile re-read many times
        // homes in L2 (its refills never reach DRAM beyond the cold
        // fill), while the full matrix streams from DRAM exactly once.
        let plat = Platform::workstation();
        let full = 4.4e6;
        let tile = 120e3; // > L1, < L2
        let p = profile(
            vec![
                Stream::read_once("weights-cold", full),
                Stream::swept("weights-tile", tile, 127.0),
            ],
            10.0,
        );
        let r = simulate(&p, &plat, 1);
        // DRAM sees ~ the cold passes only (full + tile fill).
        assert!(r.traffic.bytes[3] < (full + tile) * 1.05);
        // L2 absorbs the tile re-reads (tile > L1).
        assert!(r.traffic.bytes[1] > 126.0 * tile);
    }

    #[test]
    fn partitioned_working_sets_cancel_shared_capacity() {
        // Kernels tile M across threads, so per-thread footprints shrink
        // with the shared-L3 per-thread share: DRAM cold traffic is
        // invariant to the thread count for partitioned data.
        let plat = Platform::laptop(); // 16 MB shared L3
        let p = profile(vec![Stream::swept("w", 8e6, 4.0)], 100.0);
        let r1 = simulate(&p, &plat, 1);
        let r8 = simulate(&p, &plat, 8);
        assert!(r1.traffic.bytes[3] <= 8e6 * 1.01);
        assert!((r8.traffic.bytes[3] - r1.traffic.bytes[3]).abs() < 8e4);
    }

    #[test]
    fn oversized_shared_working_set_escalates_with_threads() {
        // A working set near the whole shared L3 stops fitting once the
        // per-thread share shrinks below the per-thread partition.
        let plat = Platform::laptop();
        let p = profile(vec![Stream::swept("w", 13e6, 4.0)], 100.0);
        let r1 = simulate(&p, &plat, 1);
        assert!(r1.traffic.bytes[3] <= 13e6 * 1.01, "fits at one thread");
        // At 8 threads each 1.6 MB share exceeds the 1.7 MB L3 slice
        // only marginally; use 16 MB to force the miss path.
        let p2 = profile(vec![Stream::swept("w", 16e6, 4.0)], 100.0);
        let r8 = simulate(&p2, &plat, 8);
        assert!(r8.traffic.bytes[3] > 16e6 * 1.5, "passes reach DRAM");
    }

    #[test]
    fn writes_add_writeback_traffic() {
        let plat = Platform::workstation();
        let big = 1e8;
        let r_read = simulate(&profile(vec![Stream::read_once("o", big)], 1.0), &plat, 1);
        let r_write = simulate(&profile(vec![Stream::write_once("o", big)], 1.0), &plat, 1);
        assert!(r_write.traffic.bytes[3] > 1.9 * r_read.traffic.bytes[3]);
    }

    #[test]
    fn llc_hit_rate_bounds() {
        let plat = Platform::workstation();
        let p = profile(vec![Stream::read_once("w", 1e6)], 100.0);
        let r = simulate(&p, &plat, 1);
        assert!((0.0..=1.0).contains(&r.llc_hit_rate));
    }

    #[test]
    fn calibrated_constants_scale_the_model() {
        let base = Platform::workstation();
        let mut cal = base.clone();
        cal.model.issue_scale = 0.5;
        cal.model.latency_scale = 2.0;
        cal.model.thread_contention = 0.2;

        // Compute-bound work slows when the effective issue width halves.
        let pc = profile(vec![Stream::read_once("w", 1e4)], 1e9);
        let c0 = simulate(&pc, &base, 8);
        let c1 = simulate(&pc, &cal, 8);
        assert!(c1.compute_cycles > 1.5 * c0.compute_cycles);

        // DRAM-bound work slows under the thread-contention term.
        let pm = profile(vec![Stream::read_once("w", 1e9)], 10.0);
        let m0 = simulate(&pm, &base, 8);
        let m1 = simulate(&pm, &cal, 8);
        assert!(m1.memory_cycles > 2.0 * m0.memory_cycles);

        // Identity constants are an exact no-op (bit-identical).
        let mut ident = base.clone();
        ident.model = Default::default();
        assert_eq!(simulate(&pm, &ident, 8).cycles, m0.cycles);
        assert_eq!(simulate(&pc, &ident, 8).cycles, c0.cycles);
    }

    #[test]
    fn more_threads_never_slower_for_compute() {
        let plat = Platform::workstation();
        let p = profile(vec![Stream::read_once("w", 1e5)], 1e8);
        let mut last = f64::INFINITY;
        for t in [1, 2, 4, 8, 16] {
            let s = simulate(&p, &plat, t).seconds;
            assert!(s <= last * 1.001, "t={t} regressed");
            last = s;
        }
    }
}
