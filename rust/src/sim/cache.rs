//! Set-associative cache simulator with LRU replacement — the detailed
//! half of the gem5 substitute (DESIGN.md §2).  Used directly on small /
//! representative access traces and to cross-validate the analytic
//! engine's working-set reasoning (`sim::engine`).

use crate::config::platforms::CacheLevel;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Read,
    Write,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    Hit,
    Miss,
}

/// One cache level: set-associative, LRU, write-allocate, write-back.
#[derive(Debug, Clone)]
pub struct Cache {
    pub level: CacheLevel,
    sets: usize,
    line_shift: u32,
    /// tags[set * assoc + way]; u64::MAX = invalid.
    tags: Vec<u64>,
    /// LRU stamps, larger = more recent.
    stamps: Vec<u64>,
    dirty: Vec<bool>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl Cache {
    pub fn new(level: CacheLevel) -> Cache {
        let sets = level.sets();
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        assert!(level.line_bytes.is_power_of_two());
        Cache {
            level,
            sets,
            line_shift: level.line_bytes.trailing_zeros(),
            tags: vec![u64::MAX; sets * level.assoc],
            stamps: vec![0; sets * level.assoc],
            dirty: vec![false; sets * level.assoc],
            clock: 0,
            hits: 0,
            misses: 0,
            writebacks: 0,
        }
    }

    fn set_of(&self, addr: u64) -> usize {
        ((addr >> self.line_shift) as usize) & (self.sets - 1)
    }

    fn tag_of(&self, addr: u64) -> u64 {
        addr >> self.line_shift
    }

    /// Access one address; returns the outcome and whether a dirty line
    /// was evicted (the write-back the next level must absorb).
    pub fn access(&mut self, addr: u64, kind: Access) -> (Outcome, bool) {
        self.clock += 1;
        let set = self.set_of(addr);
        let tag = self.tag_of(addr);
        let base = set * self.level.assoc;
        // Hit path.
        for way in 0..self.level.assoc {
            if self.tags[base + way] == tag {
                self.hits += 1;
                self.stamps[base + way] = self.clock;
                if kind == Access::Write {
                    self.dirty[base + way] = true;
                }
                return (Outcome::Hit, false);
            }
        }
        // Miss: fill into LRU way (write-allocate).
        self.misses += 1;
        let mut victim = 0;
        for way in 1..self.level.assoc {
            if self.stamps[base + way] < self.stamps[base + victim] {
                victim = way;
            }
        }
        let evict_dirty =
            self.tags[base + victim] != u64::MAX && self.dirty[base + victim];
        if evict_dirty {
            self.writebacks += 1;
        }
        self.tags[base + victim] = tag;
        self.stamps[base + victim] = self.clock;
        self.dirty[base + victim] = kind == Access::Write;
        (Outcome::Miss, evict_dirty)
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
        self.writebacks = 0;
    }
}

/// A three-level hierarchy (per-core L1/L2 view with the shared L3), plus
/// DRAM access counting.  `access` models the full miss cascade.
#[derive(Debug, Clone)]
pub struct Hierarchy {
    pub l1: Cache,
    pub l2: Cache,
    pub l3: Cache,
    pub dram_reads: u64,
    pub dram_writes: u64,
}

impl Hierarchy {
    pub fn new(l1: CacheLevel, l2: CacheLevel, l3: CacheLevel) -> Hierarchy {
        Hierarchy {
            l1: Cache::new(l1),
            l2: Cache::new(l2),
            l3: Cache::new(l3),
            dram_reads: 0,
            dram_writes: 0,
        }
    }

    /// Access one byte address; the cascade fetches lines inclusively.
    pub fn access(&mut self, addr: u64, kind: Access) {
        let (o1, wb1) = self.l1.access(addr, kind);
        if wb1 {
            // Dirty eviction from L1 lands in L2 (write-back).
            let (_, wb2) = self.l2.access(addr, Access::Write);
            if wb2 {
                let (_, wb3) = self.l3.access(addr, Access::Write);
                if wb3 {
                    self.dram_writes += 1;
                }
            }
        }
        if o1 == Outcome::Miss {
            let (o2, wb2) = self.l2.access(addr, Access::Read);
            if wb2 {
                let (_, wb3) = self.l3.access(addr, Access::Write);
                if wb3 {
                    self.dram_writes += 1;
                }
            }
            if o2 == Outcome::Miss {
                let (o3, wb3) = self.l3.access(addr, Access::Read);
                if wb3 {
                    self.dram_writes += 1;
                }
                if o3 == Outcome::Miss {
                    self.dram_reads += 1;
                }
            }
        }
    }

    /// Sequentially touch a byte range (line-granular), e.g. a streaming
    /// read of a packed weight row.
    pub fn stream(&mut self, base: u64, bytes: u64, kind: Access) {
        let line = self.l1.level.line_bytes as u64;
        let mut a = base & !(line - 1);
        while a < base + bytes {
            self.access(a, kind);
            a += line;
        }
    }

    pub fn reset_stats(&mut self) {
        self.l1.reset_stats();
        self.l2.reset_stats();
        self.l3.reset_stats();
        self.dram_reads = 0;
        self.dram_writes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_level(size: usize, assoc: usize) -> CacheLevel {
        CacheLevel {
            size_bytes: size,
            assoc,
            line_bytes: 64,
            latency_cycles: 4.0,
            shared: false,
        }
    }

    #[test]
    fn hit_after_fill() {
        let mut c = Cache::new(tiny_level(4096, 4));
        assert_eq!(c.access(0x100, Access::Read).0, Outcome::Miss);
        assert_eq!(c.access(0x100, Access::Read).0, Outcome::Hit);
        assert_eq!(c.access(0x130, Access::Read).0, Outcome::Hit); // same line
        assert_eq!(c.access(0x140, Access::Read).0, Outcome::Miss); // next line
    }

    #[test]
    fn lru_eviction_order() {
        // 2-way, 64B lines, 2 sets => same-set addresses stride by 128.
        let mut c = Cache::new(tiny_level(256, 2));
        let s = 128u64;
        c.access(0 * s, Access::Read); // A
        c.access(2 * s, Access::Read); // B (same set as A)
        c.access(0 * s, Access::Read); // touch A -> B is LRU
        c.access(4 * s, Access::Read); // C evicts B
        assert_eq!(c.access(0 * s, Access::Read).0, Outcome::Hit); // A still in
        assert_eq!(c.access(2 * s, Access::Read).0, Outcome::Miss); // B gone
    }

    #[test]
    fn writeback_on_dirty_eviction() {
        let mut c = Cache::new(tiny_level(128, 1)); // direct-mapped, 2 sets
        c.access(0, Access::Write);
        let (_, wb) = c.access(128, Access::Read); // evicts dirty line 0
        assert!(wb);
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn working_set_behaviour() {
        // A loop over a working set larger than the cache must thrash;
        // one that fits must hit after the cold pass.
        let mut small = Cache::new(tiny_level(4096, 8));
        for pass in 0..4 {
            for addr in (0..2048u64).step_by(64) {
                let (o, _) = small.access(addr, Access::Read);
                if pass > 0 {
                    assert_eq!(o, Outcome::Hit, "fits: pass {pass} addr {addr}");
                }
            }
        }
        let mut thrash = Cache::new(tiny_level(1024, 1));
        thrash.reset_stats();
        for _ in 0..3 {
            for addr in (0..4096u64).step_by(64) {
                thrash.access(addr, Access::Read);
            }
        }
        assert!(thrash.hit_rate() < 0.05, "direct-mapped thrash must miss");
    }

    #[test]
    fn hierarchy_cascade() {
        let mut h = Hierarchy::new(
            tiny_level(1024, 2),
            tiny_level(8192, 4),
            tiny_level(65536, 8),
        );
        // Stream 32 KiB: misses everywhere first pass (fits L3 only).
        h.stream(0, 32 * 1024, Access::Read);
        assert_eq!(h.dram_reads as usize, 32 * 1024 / 64);
        let l1_misses_first = h.l1.misses;
        // Second pass: hits in L3, not in L1 (too big).
        h.stream(0, 32 * 1024, Access::Read);
        assert_eq!(h.dram_reads as usize, 32 * 1024 / 64, "L3 now absorbs");
        assert!(h.l1.misses >= l1_misses_first);
        assert!(h.l3.hit_rate() > 0.4);
    }

    #[test]
    fn working_set_exactly_at_capacity_hits_after_cold_pass() {
        // 4 KiB cache, 64 lines of 64 B: a 4 KiB working set fills the
        // cache exactly — LRU keeps every line, so the second pass is
        // 100% hits and the miss count stays at the cold-fill 64.
        let mut c = Cache::new(tiny_level(4096, 8));
        for addr in (0..4096u64).step_by(64) {
            assert_eq!(c.access(addr, Access::Read).0, Outcome::Miss);
        }
        for addr in (0..4096u64).step_by(64) {
            assert_eq!(c.access(addr, Access::Read).0, Outcome::Hit, "addr {addr}");
        }
        assert_eq!(c.misses, 64);
        assert_eq!(c.hits, 64);
    }

    #[test]
    fn one_line_over_capacity_thrashes_the_victim_set() {
        // Same cache, working set = capacity + 1 line.  The extra line
        // aliases one set, and a cyclic scan is LRU's worst case: that
        // set never retains the line about to be referenced.
        let mut c = Cache::new(tiny_level(4096, 8));
        let lines = 4096 / 64 + 1;
        for _ in 0..4 {
            for i in 0..lines as u64 {
                c.access(i * 64, Access::Read);
            }
        }
        c.reset_stats();
        let mut set_misses = 0;
        for i in 0..lines as u64 {
            if c.access(i * 64, Access::Read).0 == Outcome::Miss {
                set_misses += 1;
            }
        }
        // 8 sets: 7 untouched sets keep hitting; the aliased set (8
        // ways + 9 resident candidates, cyclic) misses every access.
        assert_eq!(set_misses, 9, "aliased set must thrash under LRU");
    }

    #[test]
    fn single_set_cache_is_fully_associative() {
        // size == assoc * line_bytes => sets() == 1: a legal degenerate
        // geometry that must behave as a fully-associative cache.
        let level = tiny_level(4 * 64, 4);
        assert_eq!(level.sets(), 1);
        let mut c = Cache::new(level);
        // Any 4 addresses coexist regardless of alignment.
        for addr in [0u64, 64, 1 << 20, (1 << 30) + 192] {
            assert_eq!(c.access(addr, Access::Read).0, Outcome::Miss);
        }
        for addr in [0u64, 64, 1 << 20, (1 << 30) + 192] {
            assert_eq!(c.access(addr, Access::Read).0, Outcome::Hit);
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn zero_set_geometry_is_rejected() {
        // size < assoc * line_bytes gives sets() == 0 — not a cache.
        Cache::new(tiny_level(128, 4));
    }

    #[test]
    fn equal_size_levels_still_cascade_correctly() {
        // Degenerate hierarchy where L1 == L2 == L3 in capacity: every
        // L1 miss must still walk the cascade, and a working set that
        // fits produces zero DRAM traffic after the cold pass.
        let mut h = Hierarchy::new(
            tiny_level(1024, 2),
            tiny_level(1024, 2),
            tiny_level(1024, 2),
        );
        h.stream(0, 1024, Access::Read);
        assert_eq!(h.dram_reads, 16);
        h.stream(0, 1024, Access::Read);
        assert_eq!(h.dram_reads, 16, "identical levels must absorb the refill");
        assert_eq!(h.l1.misses, 16, "second pass hits in L1");
    }

    #[test]
    fn dirty_writeback_reaches_dram() {
        let mut h = Hierarchy::new(
            tiny_level(128, 1),
            tiny_level(256, 1),
            tiny_level(512, 1),
        );
        // Write a large streaming buffer: every level evicts dirty lines.
        h.stream(0, 16 * 1024, Access::Write);
        assert!(h.dram_writes > 0);
    }
}
