//! The gem5 substitute: a two-level performance model (DESIGN.md §2).
//!
//! * [`cache`] — a real set-associative LRU cache hierarchy, run on
//!   line-granular traces for small/representative shapes and used to
//!   validate the analytic engine.
//! * [`engine`] — the analytic timing model used for full sweeps:
//!   per-structure working-set placement, per-level traffic, µ-op issue
//!   and DRAM bandwidth with multi-core contention.
//!
//! Kernels describe themselves to the engine as a [`KernelProfile`]: a
//! set of [`Stream`]s (how many bytes each data structure requests from
//! the memory system, its footprint and how many passes sweep it) plus
//! compute µ-op counts.  This is the information a gem5 trace carries,
//! abstracted to structure granularity so 100B-parameter sweeps finish
//! in milliseconds.  Blocked reuse (e.g. a weight tile re-read N times
//! from L2 while the full matrix streams from DRAM once) is expressed by
//! splitting a structure into a cold stream plus a tile-reuse stream —
//! see `kernels::tsar` for worked examples.

pub mod cache;
pub mod engine;

pub use engine::{simulate, SimResult};

/// GEMM/GEMV operand shape: (N × K) · (K × M)ᵀ.  N = 1 is decode GEMV;
/// N = 128 is the paper's prefill batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmShape {
    pub n: usize,
    pub k: usize,
    pub m: usize,
}

impl GemmShape {
    pub const fn new(n: usize, k: usize, m: usize) -> Self {
        GemmShape { n, k, m }
    }

    pub fn is_gemv(&self) -> bool {
        self.n == 1
    }

    /// Multiply–accumulate count of the dense equivalent.
    pub fn macs(&self) -> f64 {
        self.n as f64 * self.k as f64 * self.m as f64
    }
}

/// One data structure's memory behaviour inside a kernel execution.
///
/// `bytes_accessed` is what the core *requests* (the paper's "memory
/// request volume" metric, Fig. 9); `footprint` × `passes` bounds the
/// refill traffic that escapes to levels that cannot hold the footprint.
#[derive(Debug, Clone)]
pub struct Stream {
    pub name: &'static str,
    /// Distinct bytes (working set of this stream).
    pub footprint: f64,
    /// Total bytes requested by the core at L1.
    pub bytes_accessed: f64,
    /// How many sequential sweeps of the footprint the access pattern
    /// makes (levels too small to hold the footprint see every sweep).
    pub passes: f64,
    /// Fraction of requests that are stores (adds write-back traffic).
    pub write_frac: f64,
    /// Address-dependent accesses (LUT gathers whose address comes from
    /// a just-loaded weight code): these cannot be prefetched and stall
    /// at their home level's latency with little overlap — the paper's
    /// Fig. 2(d) mechanism.  Streaming/prefetchable accesses leave this
    /// false.
    pub dependent: bool,
}

impl Stream {
    /// Read the whole structure exactly once, sequentially.
    pub fn read_once(name: &'static str, bytes: f64) -> Stream {
        Stream {
            name,
            footprint: bytes,
            bytes_accessed: bytes,
            passes: 1.0,
            write_frac: 0.0,
            dependent: false,
        }
    }

    /// Write the whole structure exactly once.
    pub fn write_once(name: &'static str, bytes: f64) -> Stream {
        Stream {
            name,
            footprint: bytes,
            bytes_accessed: bytes,
            passes: 1.0,
            write_frac: 1.0,
            dependent: false,
        }
    }

    /// Sweep a structure `passes` times (requests = footprint × passes).
    pub fn swept(name: &'static str, footprint: f64, passes: f64) -> Stream {
        Stream {
            name,
            footprint,
            bytes_accessed: footprint * passes,
            passes,
            write_frac: 0.0,
            dependent: false,
        }
    }
}

/// A kernel execution's complete description for the timing engine.
#[derive(Debug, Clone)]
pub struct KernelProfile {
    pub kernel: String,
    pub shape: GemmShape,
    pub streams: Vec<Stream>,
    /// 256-bit SIMD ALU µ-ops (TLUT/TGEMV/vector add-mul equivalents).
    pub simd_uops: f64,
    /// Scalar bookkeeping µ-ops (loop control, address generation).
    pub scalar_uops: f64,
}

impl KernelProfile {
    /// Core→memory-system request volume in bytes: Fig. 9's metric.
    pub fn request_bytes(&self) -> f64 {
        self.streams.iter().map(|s| s.bytes_accessed).sum()
    }

    /// Request volume of streams whose name contains `pat` (e.g. "lut"
    /// for the Fig. 1(c)/2(c) TLUT shares).
    pub fn request_bytes_matching(&self, pat: &str) -> f64 {
        self.streams
            .iter()
            .filter(|s| s.name.contains(pat))
            .map(|s| s.bytes_accessed)
            .sum()
    }

    pub fn stream(&self, name: &str) -> Option<&Stream> {
        self.streams.iter().find(|s| s.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_helpers() {
        let s = GemmShape::new(1, 2560, 6912);
        assert!(s.is_gemv());
        assert_eq!(s.macs(), 2560.0 * 6912.0);
    }

    #[test]
    fn stream_constructors() {
        let r = Stream::read_once("a", 64.0);
        assert_eq!(r.bytes_accessed, 64.0);
        assert_eq!(r.write_frac, 0.0);
        let w = Stream::write_once("b", 32.0);
        assert_eq!(w.write_frac, 1.0);
        let s = Stream::swept("c", 10.0, 3.0);
        assert_eq!(s.bytes_accessed, 30.0);
    }

    #[test]
    fn profile_request_volume_sums_streams() {
        let p = KernelProfile {
            kernel: "x".into(),
            shape: GemmShape::new(1, 8, 8),
            streams: vec![
                Stream { dependent: true, ..Stream::read_once("tlut-read", 100.0) },
                Stream::read_once("weights", 50.0),
            ],
            simd_uops: 1.0,
            scalar_uops: 0.0,
        };
        assert_eq!(p.request_bytes(), 150.0);
        assert_eq!(p.request_bytes_matching("lut"), 100.0);
        assert!(p.stream("weights").is_some());
        assert!(p.stream("zz").is_none());
    }
}
