//! The measure → model loop: fit a [`Platform`] profile's free
//! constants to wall-clock measured on *this* host.
//!
//! The simulator's platform constants come from the paper's Table I.
//! On any other machine they are a guess.  `tsar-cli calibrate` closes
//! the loop: it times the native ternary GEMM kernels across a
//! shape × thread grid ([`grid`]), then fits the four free constants of
//! the timing model — sustained DRAM efficiency plus the
//! [`ModelConstants`] triple (SIMD issue scale, latency scale,
//! per-thread DRAM contention) — by coordinate descent on the mean
//! squared *log* error between predicted and measured seconds.  A
//! quarter of the grid is held out of the fit; the worst held-out
//! relative error ships inside the written profile's provenance, so a
//! calibrated `PLATFORM_host.json` records how much to trust itself.
//!
//! The fitter itself is deterministic and model-pure: given the same
//! measurement list it always returns the same constants, with no
//! wall-clock or RNG dependence.  That makes it testable offline — a
//! [`Fixture`] is a JSON document of synthetic measurements generated
//! from a *known* perturbed profile ([`synthesize`]), and the in-tree
//! round-trip test (plus `calibrate --fixture` in CI) asserts the fit
//! recovers each embedded truth constant within [`check_recovery`]'s
//! tolerances.
//!
//! Grid regimes matter for identifiability: n = 1 tall-K shapes are
//! DRAM-streaming-bound (pin `dram.efficiency`), large-n shapes are
//! compute-bound (pin `issue_scale`), small working sets exercise the
//! cache-latency terms (`latency_scale`), and the single- vs
//! many-thread columns separate bandwidth from `thread_contention`.

use crate::config::{FitProvenance, IsaConfig, ModelConstants, Platform, Provenance};
use crate::kernels::native::NativeGemv;
use crate::kernels::select_tsar_kernel;
use crate::sim::GemmShape;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::time_it;
use crate::Result;

/// Relative tolerance on recovered DRAM efficiency.
pub const TOL_EFF_REL: f64 = 0.10;
/// Relative tolerance on recovered SIMD issue scale.
pub const TOL_ISSUE_REL: f64 = 0.10;
/// Relative tolerance on recovered latency scale (weakly identified —
/// latency terms are a minor fraction of streaming-kernel time).
pub const TOL_LATENCY_REL: f64 = 0.35;
/// Absolute tolerance on recovered thread contention.
pub const TOL_CONTENTION_ABS: f64 = 0.08;
/// Bound on the worst held-out relative prediction error for a fit
/// from model-consistent (fixture) measurements.
pub const TOL_HOLDOUT_REL: f64 = 0.10;

/// One wall-clock observation: the native GEMM at `shape` on
/// `threads` pool lanes took `seconds` (min over repeats).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measurement {
    pub shape: GemmShape,
    pub threads: usize,
    pub seconds: f64,
}

/// The shape × thread calibration grid.  `smoke` shrinks it to
/// CI-sized shapes; `max_threads` clamps the thread column to the
/// host (or modeled platform) core count.
pub fn grid(smoke: bool, max_threads: usize) -> Vec<(GemmShape, usize)> {
    let shapes: &[(usize, usize, usize)] = if smoke {
        &[(1, 2560, 2560), (1, 2560, 6912), (16, 1024, 1024)]
    } else {
        &[
            (1, 8192, 32768),  // DRAM-streaming-bound decode GEMV
            (1, 2560, 6912),   // BitNet-2B up-projection
            (1, 6912, 2560),   // BitNet-2B down-projection
            (64, 4096, 4096),  // compute-bound batched GEMM
            (256, 2048, 2048), // prefill-class GEMM
            (1, 2560, 2560),   // cache-resident small GEMV
        ]
    };
    let mut threads: Vec<usize> =
        if smoke { vec![1, 2] } else { vec![1, 4, 16] };
    for t in &mut threads {
        *t = (*t).clamp(1, max_threads.max(1));
    }
    threads.dedup();
    let mut g = Vec::new();
    for &(n, k, m) in shapes {
        for &t in &threads {
            g.push((GemmShape::new(n, k, m), t));
        }
    }
    g
}

/// One-line grid description for provenance records.
pub fn grid_desc(g: &[(GemmShape, usize)], smoke: bool) -> String {
    format!(
        "{} shape/thread points ({})",
        g.len(),
        if smoke { "smoke" } else { "full" }
    )
}

/// Model-predicted seconds for one grid point under a candidate
/// profile — the same selector + simulator path every report uses.
pub fn predict(prof: &Platform, shape: GemmShape, threads: usize) -> f64 {
    let (_, r) = select_tsar_kernel(shape, prof, threads);
    r.seconds
}

/// Time the native GEMM kernel over the grid.  Weights are generated
/// and packed once per shape (packing is thread-independent); each
/// grid point reports the min over repeats.  Returns the measurements
/// plus the executed kernel path name ("avx2" / "scalar") for the
/// host fingerprint.
pub fn measure(
    isa: IsaConfig,
    grid: &[(GemmShape, usize)],
    min_runs: usize,
    min_secs: f64,
) -> Result<(Vec<Measurement>, &'static str)> {
    let mut by_shape: Vec<(GemmShape, Vec<usize>)> = Vec::new();
    for &(s, t) in grid {
        match by_shape.iter_mut().find(|(s2, _)| *s2 == s) {
            Some(e) => e.1.push(t),
            None => by_shape.push((s, vec![t])),
        }
    }
    let mut rng = Rng::new(0xCA11B7A7E);
    let mut meas = Vec::new();
    let mut path_name = "scalar";
    for (shape, thread_list) in by_shape {
        let w = rng.ternary_matrix(shape.m, shape.k, 1.0 / 3.0);
        let acts = rng.int8_acts(shape.n * shape.k);
        let packed = NativeGemv::new(isa)?.pack(&w, shape.m, shape.k)?;
        drop(w);
        let mut out = vec![0i32; shape.n * shape.m];
        for threads in thread_list {
            let gemv = NativeGemv::new(isa)?.with_threads(threads)?;
            path_name = gemv.path().name();
            let (_, min_s, _) = time_it(
                || gemv.gemm(&acts, &packed, shape.n, &mut out).expect("gemm"),
                min_runs,
                min_secs,
            );
            meas.push(Measurement { shape, threads, seconds: min_s });
        }
    }
    Ok((meas, path_name))
}

// -- Fitting ---------------------------------------------------------------

/// Free-parameter vector: [dram_efficiency, issue_scale,
/// latency_scale, thread_contention].
#[derive(Debug, Clone, Copy)]
struct Params([f64; 4]);

/// Search box per parameter (efficiency capped at the physical 1.0).
const RANGES: [(f64, f64); 4] =
    [(0.05, 1.0), (0.25, 4.0), (0.25, 6.0), (0.0, 1.0)];

impl Params {
    fn of(prof: &Platform) -> Params {
        Params([
            prof.dram_efficiency,
            prof.model.issue_scale,
            prof.model.latency_scale,
            prof.model.thread_contention,
        ])
    }

    fn apply(&self, base: &Platform) -> Platform {
        let mut p = base.clone();
        p.dram_efficiency = self.0[0];
        p.model = ModelConstants {
            issue_scale: self.0[1],
            latency_scale: self.0[2],
            thread_contention: self.0[3],
        };
        p
    }
}

fn loss(base: &Platform, p: Params, train: &[Measurement]) -> f64 {
    let prof = p.apply(base);
    let sum: f64 = train
        .iter()
        .map(|m| {
            let e = (predict(&prof, m.shape, m.threads) / m.seconds).ln();
            e * e
        })
        .sum();
    sum / train.len() as f64
}

/// Coordinate descent: per round, scan each parameter over a bracket
/// centred on the incumbent, halving the bracket each round.  Round 1
/// brackets span the whole range, so the start point only matters for
/// tie-breaking; later rounds refine.  Deterministic.
fn descend(
    base: &Platform,
    train: &[Measurement],
    start: Params,
    width_frac: f64,
) -> (Params, f64) {
    let mut p = start;
    let mut best = loss(base, p, train);
    let mut width: [f64; 4] = std::array::from_fn(|c| {
        (RANGES[c].1 - RANGES[c].0) * width_frac
    });
    for round in 0..12 {
        let mut improved = false;
        for c in 0..4 {
            let lo = (p.0[c] - width[c] / 2.0).max(RANGES[c].0);
            let hi = (p.0[c] + width[c] / 2.0).min(RANGES[c].1);
            const STEPS: usize = 12;
            let mut cand = p;
            for i in 0..=STEPS {
                cand.0[c] = lo + (hi - lo) * i as f64 / STEPS as f64;
                let l = loss(base, cand, train);
                if l + 1e-15 < best {
                    best = l;
                    p.0[c] = cand.0[c];
                    improved = true;
                }
            }
            width[c] /= 2.0;
        }
        if best < 1e-16 || (!improved && round >= 4) {
            break;
        }
    }
    (p, best)
}

/// A completed fit: the calibrated profile (provenance filled in) and
/// its residuals.
#[derive(Debug, Clone)]
pub struct FitReport {
    pub profile: Platform,
    pub train_rmse_log: f64,
    pub holdout_max_rel_err: f64,
}

/// Fit the free constants to `meas`, holding out every fourth
/// measurement for validation.  `host` is the fingerprint recorded in
/// the calibrated provenance; `grid_label` describes the grid.
pub fn fit(
    base: &Platform,
    meas: &[Measurement],
    host: &str,
    grid_label: &str,
) -> Result<FitReport> {
    crate::ensure!(!meas.is_empty(), "calibrate: no measurements to fit");
    for m in meas {
        crate::ensure!(
            m.seconds.is_finite() && m.seconds > 0.0,
            "calibrate: non-positive measurement for {}x{}x{} @ {} threads",
            m.shape.n,
            m.shape.k,
            m.shape.m,
            m.threads
        );
    }
    let (train, holdout): (Vec<Measurement>, Vec<Measurement>) = if meas.len() >= 8 {
        let train: Vec<_> =
            meas.iter().enumerate().filter(|(i, _)| i % 4 != 3).map(|(_, m)| *m).collect();
        let holdout: Vec<_> =
            meas.iter().enumerate().filter(|(i, _)| i % 4 == 3).map(|(_, m)| *m).collect();
        (train, holdout)
    } else {
        // Too few points to spare any: validate on the training set.
        (meas.to_vec(), meas.to_vec())
    };

    // Multi-start: the base profile's own constants and a neutral
    // mid-box point, each descended over the full range, then the
    // winner polished with a narrow restart.
    let starts = [Params::of(base), Params([0.5, 1.0, 1.0, 0.1])];
    let mut best: Option<(Params, f64)> = None;
    for s in starts {
        let (p, l) = descend(base, &train, s, 1.0);
        if best.map(|(_, bl)| l < bl).unwrap_or(true) {
            best = Some((p, l));
        }
    }
    let (p0, l0) = best.expect("at least one start");
    let (p, l) = descend(base, &train, p0, 0.25);
    let (p, l) = if l < l0 { (p, l) } else { (p0, l0) };

    let fitted = p.apply(base);
    let holdout_max_rel_err = holdout
        .iter()
        .map(|m| (predict(&fitted, m.shape, m.threads) / m.seconds - 1.0).abs())
        .fold(0.0, f64::max);
    let train_rmse_log = l.sqrt();

    let mut profile = fitted;
    profile.provenance = Provenance {
        source: "calibrated".into(),
        host: Some(host.to_string()),
        fit: Some(FitProvenance {
            train_rmse_log,
            holdout_max_rel_err,
            grid: grid_label.to_string(),
            measurements: meas.len(),
        }),
    };
    profile.validate()?;
    Ok(FitReport { profile, train_rmse_log, holdout_max_rel_err })
}

// -- Fixtures --------------------------------------------------------------

/// Ground-truth constants a synthetic fixture embeds so the fit can be
/// cross-checked.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Truth {
    pub dram_efficiency: f64,
    pub model: ModelConstants,
}

impl Truth {
    /// The perturbation the CI fixture uses: every free constant moved
    /// well off its Table I/identity value, all inside the fitter's
    /// search ranges.
    pub fn example() -> Truth {
        Truth {
            dram_efficiency: 0.70,
            model: ModelConstants {
                issue_scale: 0.8,
                latency_scale: 1.5,
                thread_contention: 0.12,
            },
        }
    }
}

/// An offline calibration input: measurements (synthetic or replayed)
/// plus the base platform they perturb and, for synthetic fixtures,
/// the embedded truth constants.
#[derive(Debug, Clone, PartialEq)]
pub struct Fixture {
    /// Base profile name (`workstation` / `laptop` / `mobile`).
    pub base: String,
    pub truth: Option<Truth>,
    pub measurements: Vec<Measurement>,
}

/// Generate a deterministic synthetic fixture: full grid, seconds
/// predicted by the model itself under `truth` constants — no timing,
/// no RNG, so the fit's global optimum is exactly `truth`.
pub fn synthesize(base: &Platform, truth: &Truth) -> Fixture {
    let prof = Params([
        truth.dram_efficiency,
        truth.model.issue_scale,
        truth.model.latency_scale,
        truth.model.thread_contention,
    ])
    .apply(base);
    let g = grid(false, base.cores);
    let measurements = g
        .iter()
        .map(|&(shape, threads)| Measurement {
            shape,
            threads,
            seconds: predict(&prof, shape, threads),
        })
        .collect();
    Fixture {
        base: base.name.to_lowercase(),
        truth: Some(*truth),
        measurements,
    }
}

/// Assert the fitted constants match the fixture's embedded truth
/// within the documented tolerances, and that held-out predictions
/// stay bounded.  Errors name the violated constant.
pub fn check_recovery(report: &FitReport, truth: &Truth) -> Result<()> {
    let p = &report.profile;
    let rel = |fitted: f64, t: f64| (fitted / t - 1.0).abs();
    crate::ensure!(
        rel(p.dram_efficiency, truth.dram_efficiency) <= TOL_EFF_REL,
        "calibrate: dram efficiency {:.4} missed truth {:.4} (> {:.0}% rel)",
        p.dram_efficiency,
        truth.dram_efficiency,
        TOL_EFF_REL * 100.0
    );
    crate::ensure!(
        rel(p.model.issue_scale, truth.model.issue_scale) <= TOL_ISSUE_REL,
        "calibrate: issue scale {:.4} missed truth {:.4} (> {:.0}% rel)",
        p.model.issue_scale,
        truth.model.issue_scale,
        TOL_ISSUE_REL * 100.0
    );
    crate::ensure!(
        rel(p.model.latency_scale, truth.model.latency_scale) <= TOL_LATENCY_REL,
        "calibrate: latency scale {:.4} missed truth {:.4} (> {:.0}% rel)",
        p.model.latency_scale,
        truth.model.latency_scale,
        TOL_LATENCY_REL * 100.0
    );
    crate::ensure!(
        (p.model.thread_contention - truth.model.thread_contention).abs()
            <= TOL_CONTENTION_ABS,
        "calibrate: thread contention {:.4} missed truth {:.4} (> {:.2} abs)",
        p.model.thread_contention,
        truth.model.thread_contention,
        TOL_CONTENTION_ABS
    );
    crate::ensure!(
        report.holdout_max_rel_err <= TOL_HOLDOUT_REL,
        "calibrate: held-out prediction error {:.4} exceeds {:.2}",
        report.holdout_max_rel_err,
        TOL_HOLDOUT_REL
    );
    Ok(())
}

impl Fixture {
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(String, Json)> = vec![
            ("fixture".into(), Json::Str("tsar_calibration".into())),
            ("schema_version".into(), Json::Num(1.0)),
            ("base".into(), Json::Str(self.base.clone())),
            (
                "truth".into(),
                match &self.truth {
                    Some(t) => Json::Obj(
                        [
                            ("dram_efficiency".to_string(), Json::Num(t.dram_efficiency)),
                            ("issue_scale".to_string(), Json::Num(t.model.issue_scale)),
                            ("latency_scale".to_string(), Json::Num(t.model.latency_scale)),
                            (
                                "thread_contention".to_string(),
                                Json::Num(t.model.thread_contention),
                            ),
                        ]
                        .into_iter()
                        .collect(),
                    ),
                    None => Json::Null,
                },
            ),
        ];
        let meas: Vec<Json> = self
            .measurements
            .iter()
            .map(|m| {
                Json::Obj(
                    [
                        ("n".to_string(), Json::Num(m.shape.n as f64)),
                        ("k".to_string(), Json::Num(m.shape.k as f64)),
                        ("m".to_string(), Json::Num(m.shape.m as f64)),
                        ("threads".to_string(), Json::Num(m.threads as f64)),
                        ("seconds".to_string(), Json::Num(m.seconds)),
                    ]
                    .into_iter()
                    .collect(),
                )
            })
            .collect();
        pairs.push(("measurements".into(), Json::Arr(meas)));
        Json::Obj(pairs.into_iter().collect())
    }

    pub fn parse(text: &str) -> Result<Fixture> {
        let v = Json::parse(text)
            .map_err(|e| crate::err!("calibration fixture: {e}"))?;
        crate::ensure!(
            v.get("fixture").and_then(Json::as_str) == Some("tsar_calibration"),
            "calibration fixture: missing \"fixture\": \"tsar_calibration\" discriminator"
        );
        crate::ensure!(
            v.req("schema_version")?.as_f64() == Some(1.0),
            "calibration fixture: unsupported schema_version"
        );
        let base = v
            .req("base")?
            .as_str()
            .ok_or_else(|| crate::err!("calibration fixture: base must be a string"))?
            .to_string();
        let truth = match v.req("truth")? {
            Json::Null => None,
            t => Some(Truth {
                dram_efficiency: req_num(t, "dram_efficiency")?,
                model: ModelConstants {
                    issue_scale: req_num(t, "issue_scale")?,
                    latency_scale: req_num(t, "latency_scale")?,
                    thread_contention: req_num(t, "thread_contention")?,
                },
            }),
        };
        let Some(arr) = v.req("measurements")?.as_arr() else {
            crate::bail!("calibration fixture: measurements must be an array");
        };
        crate::ensure!(!arr.is_empty(), "calibration fixture: measurements must be non-empty");
        let mut measurements = Vec::with_capacity(arr.len());
        for m in arr {
            let dim = |key: &str| -> Result<usize> {
                m.req(key)?
                    .as_usize()
                    .filter(|&x| x >= 1)
                    .ok_or_else(|| crate::err!("calibration fixture: {key} must be >= 1"))
            };
            measurements.push(Measurement {
                shape: GemmShape::new(dim("n")?, dim("k")?, dim("m")?),
                threads: dim("threads")?,
                seconds: req_num(m, "seconds")?,
            });
        }
        Ok(Fixture { base, truth, measurements })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        let mut text = self.to_json().to_string();
        text.push('\n');
        std::fs::write(path, text)
            .map_err(|e| crate::err!("write calibration fixture {path}: {e}"))
    }

    pub fn load(path: &str) -> Result<Fixture> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| crate::err!("read calibration fixture {path}: {e}"))?;
        Fixture::parse(&text).map_err(|e| crate::err!("{path}: {e}"))
    }
}

fn req_num(v: &Json, key: &str) -> Result<f64> {
    v.req(key)?
        .as_f64()
        .filter(|x| x.is_finite())
        .ok_or_else(|| crate::err!("calibration fixture: {key} must be a finite number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_the_identifying_regimes() {
        let g = grid(false, 16);
        assert_eq!(g.len(), 18);
        // A DRAM-streaming GEMV at one thread pins dram.efficiency …
        assert!(g.iter().any(|(s, t)| s.n == 1 && s.k * s.m >= 1 << 28 && *t == 1));
        // … a fat batched GEMM pins issue_scale …
        assert!(g.iter().any(|(s, _)| s.n >= 64));
        // … and the same shapes at >1 threads separate contention.
        assert!(g.iter().any(|(_, t)| *t > 1));
        // Thread clamping on small hosts keeps the grid valid.
        let g2 = grid(true, 1);
        assert!(g2.iter().all(|(_, t)| *t == 1));
        assert!(g2.len() < g.len());
    }

    #[test]
    fn fixture_json_round_trips() {
        let fx = synthesize(&Platform::workstation(), &Truth::example());
        let back = Fixture::parse(&fx.to_json().to_string()).unwrap();
        assert_eq!(back, fx);
        assert_eq!(back.base, "workstation");
        assert_eq!(back.measurements.len(), 18);
    }

    #[test]
    fn fixture_parse_rejects_bad_documents() {
        let good = synthesize(&Platform::workstation(), &Truth::example())
            .to_json()
            .to_string();
        assert!(Fixture::parse(&good.replace("tsar_calibration", "nope")).is_err());
        assert!(Fixture::parse(
            &good.replace("\"schema_version\":1", "\"schema_version\":3")
        )
        .is_err());
        assert!(Fixture::parse("not json").is_err());
    }

    #[test]
    fn fit_recovers_known_constants_from_a_synthetic_fixture() {
        // The acceptance round-trip: synthesize measurements from a
        // known perturbed profile, fit from scratch, and require every
        // free constant back within tolerance — no wall-clock, no RNG.
        let base = Platform::workstation();
        let truth = Truth::example();
        let fx = synthesize(&base, &truth);
        let report =
            fit(&base, &fx.measurements, "test-host", "full grid").unwrap();
        check_recovery(&report, &truth).unwrap();
        assert!(report.train_rmse_log < 0.05, "rmse {}", report.train_rmse_log);

        // The calibrated profile is a valid, lossless artifact.
        let prof = &report.profile;
        assert_eq!(prof.provenance.source, "calibrated");
        assert!(prof.provenance_label().starts_with("calibrated@test-host"));
        let back = Platform::parse(&prof.to_json().to_string()).unwrap();
        assert_eq!(&back, prof);
    }

    #[test]
    fn check_recovery_names_the_missed_constant() {
        let base = Platform::workstation();
        let truth = Truth::example();
        let fx = synthesize(&base, &truth);
        let report =
            fit(&base, &fx.measurements, "test-host", "full grid").unwrap();
        let mut wrong = truth;
        wrong.dram_efficiency = 0.95;
        let err = check_recovery(&report, &wrong).unwrap_err().to_string();
        assert!(err.contains("dram efficiency"), "got {err:?}");
    }

    #[test]
    fn fit_rejects_degenerate_measurements() {
        let base = Platform::workstation();
        assert!(fit(&base, &[], "h", "g").is_err());
        let bad = [Measurement {
            shape: GemmShape::new(1, 256, 256),
            threads: 1,
            seconds: 0.0,
        }];
        assert!(fit(&base, &bad, "h", "g").is_err());
    }
}
