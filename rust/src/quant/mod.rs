//! Quantization & packing substrate (paper §II, §III-A).
//!
//! * absmean ternarization / absmax int8 activation quantization —
//!   mirrors `python/compile/kernels/ref.py` exactly.
//! * The T-SAR ternary→binary decomposition and dense/sparse index
//!   encoding (1+1 bit per weight).
//! * Baseline packings: BitNet.cpp **TL-2** (three ternary weights in
//!   five bits ≈ 1.67 b/w) and **T-MAC** (4-bit grouped LUT indices).

pub mod pack;

pub use pack::{PshufbPacked, Tl2Packed, TmacPacked, TsarEncoded};

use crate::util::error::Result;

/// Absmean ternarization: `scale = mean(|w|)`,
/// `w_t = clip(round(w/scale), -1, 1)` (BitNet b1.58).
pub fn absmean_ternarize(w: &[f32]) -> (Vec<i8>, f32) {
    assert!(!w.is_empty());
    let scale = (w.iter().map(|x| x.abs() as f64).sum::<f64>() / w.len() as f64)
        .max(1e-6) as f32;
    let t = w
        .iter()
        .map(|&x| (x / scale).round().clamp(-1.0, 1.0) as i8)
        .collect();
    (t, scale)
}

/// Per-token absmax int8 quantization. Returns (q, s) with x ≈ q / s.
pub fn absmax_quantize(x: &[f32]) -> (Vec<i8>, f32) {
    let mut q = Vec::new();
    let s = absmax_quantize_into(x, &mut q);
    (q, s)
}

/// [`absmax_quantize`] appending into a caller-owned buffer (returns
/// the scale) — the allocation-free form the batched GEMM workspace
/// uses per activation row.  Semantics are identical by construction:
/// the plain entry point delegates here.
pub fn absmax_quantize_into(x: &[f32], out: &mut Vec<i8>) -> f32 {
    assert!(!x.is_empty());
    let absmax = x.iter().fold(0f32, |a, &b| a.max(b.abs())).max(1e-6);
    let s = 127.0 / absmax;
    out.extend(x.iter().map(|&v| (v * s).round().clamp(-127.0, 127.0) as i8));
    s
}

/// The ternary→binary decomposition (paper §III-A):
/// `w_D[i] = w[i] if w[i] != 0 else +1`; `w_S[i] = (w[i] == 0) as int`.
/// Invariant: `w = w_D - w_S` elementwise.
pub fn decompose(w_t: &[i8]) -> (Vec<i8>, Vec<i8>) {
    let w_d = w_t.iter().map(|&w| if w == 0 { 1 } else { w }).collect();
    let w_s = w_t.iter().map(|&w| (w == 0) as i8).collect();
    (w_d, w_s)
}

/// Pack a ternary (M × K) row-major matrix into per-block dense/sparse
/// LUT indices for block size `c` (the compile-time weight encoding of
/// Fig. 5). Bit `i` of `wd[m][b]` is set iff the densified weight at
/// column `b*c+i` is +1; bit `i` of `ws` iff the original weight is 0.
pub fn encode_indices(w_t: &[i8], m: usize, k: usize, c: usize) -> TsarEncoded {
    assert_eq!(w_t.len(), m * k);
    assert_eq!(k % c, 0, "K={k} must be divisible by c={c}");
    assert!(c <= 8, "index must fit a byte");
    let nb = k / c;
    let mut wd = vec![0u8; m * nb];
    let mut ws = vec![0u8; m * nb];
    for row in 0..m {
        for b in 0..nb {
            let mut d = 0u8;
            let mut s = 0u8;
            for i in 0..c {
                let w = w_t[row * k + b * c + i];
                debug_assert!((-1..=1).contains(&w));
                if w != -1 {
                    d |= 1 << i; // +1 or densified zero
                }
                if w == 0 {
                    s |= 1 << i;
                }
            }
            wd[row * nb + b] = d;
            ws[row * nb + b] = s;
        }
    }
    TsarEncoded { m, k, c, wd, ws }
}

/// Pack a ternary buffer into two disjoint bit-planes — the checkpoint
/// serialization of a BitLinear tensor (1 + 1 bit per weight, the §II
/// storage density).  Bit `i % 8` of `plus[i / 8]` is set iff
/// `w_t[i] == 1`; the `minus` plane likewise marks the −1 positions.
/// Trailing bits of the last byte stay zero.
pub fn pack_ternary_planes(w_t: &[i8]) -> (Vec<u8>, Vec<u8>) {
    let bytes = w_t.len().div_ceil(8);
    let mut plus = vec![0u8; bytes];
    let mut minus = vec![0u8; bytes];
    for (i, &w) in w_t.iter().enumerate() {
        debug_assert!((-1..=1).contains(&w));
        match w {
            1 => plus[i / 8] |= 1 << (i % 8),
            -1 => minus[i / 8] |= 1 << (i % 8),
            _ => {}
        }
    }
    (plus, minus)
}

/// Inverse of [`pack_ternary_planes`]: rebuild `n` ternary weights.
/// Rejects malformed planes — wrong length, a position set in both
/// planes, or junk in the trailing bits — so a corrupted checkpoint
/// fails loudly at load instead of decoding to garbage weights.
pub fn unpack_ternary_planes(plus: &[u8], minus: &[u8], n: usize) -> Result<Vec<i8>> {
    let bytes = n.div_ceil(8);
    crate::ensure!(
        plus.len() == bytes && minus.len() == bytes,
        "ternary planes hold {}/{} bytes, expected {bytes} for {n} weights",
        plus.len(),
        minus.len()
    );
    let mut w = vec![0i8; n];
    for (i, slot) in w.iter_mut().enumerate() {
        let p = plus[i / 8] >> (i % 8) & 1;
        let m = minus[i / 8] >> (i % 8) & 1;
        crate::ensure!(p & m == 0, "weight {i} is marked both +1 and -1");
        *slot = p as i8 - m as i8;
    }
    if n % 8 != 0 {
        let mask = !((1u16 << (n % 8)) as u8).wrapping_sub(1);
        crate::ensure!(
            plus[bytes - 1] & mask == 0 && minus[bytes - 1] & mask == 0,
            "trailing plane bits beyond {n} weights are set"
        );
    }
    Ok(w)
}

/// Dequantize helper for tests: reconstruct ternary weights from indices.
pub fn decode_indices(enc: &TsarEncoded) -> Vec<i8> {
    let nb = enc.k / enc.c;
    let mut w = vec![0i8; enc.m * enc.k];
    for row in 0..enc.m {
        for b in 0..nb {
            let d = enc.wd[row * nb + b];
            let s = enc.ws[row * nb + b];
            for i in 0..enc.c {
                let dense = if d >> i & 1 == 1 { 1i8 } else { -1 };
                let sparse = (s >> i & 1) as i8;
                // w = w_D - w_S
                w[row * enc.k + b * enc.c + i] = dense - sparse;
            }
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ternarize_matches_python_semantics() {
        let w = [0.9f32, -0.8, 0.01, 0.0, 2.0, -2.0, 0.4, -0.4];
        let (t, scale) = absmean_ternarize(&w);
        let want_scale: f32 =
            w.iter().map(|x| x.abs()).sum::<f32>() / w.len() as f32;
        assert!((scale - want_scale).abs() < 1e-6);
        for (&orig, &tv) in w.iter().zip(&t) {
            assert!((-1..=1).contains(&tv));
            let expect = (orig / scale).round().clamp(-1.0, 1.0) as i8;
            assert_eq!(tv, expect);
        }
    }

    #[test]
    fn absmax_clamps_and_scales() {
        let (q, s) = absmax_quantize(&[1.0, -2.0, 0.5]);
        assert_eq!(q[1], -127);
        assert!((s - 63.5).abs() < 1e-4);
    }

    #[test]
    fn absmax_quantize_into_appends_and_matches_allocating_form() {
        let x = [0.3f32, -1.7, 0.0, 0.9, -0.2];
        let (q, s) = absmax_quantize(&x);
        let mut buf = vec![7i8; 2];
        let s2 = absmax_quantize_into(&x, &mut buf);
        assert_eq!(s.to_bits(), s2.to_bits(), "scales must be bit-identical");
        assert_eq!(&buf[..2], &[7, 7], "append semantics: existing bytes kept");
        assert_eq!(&buf[2..], &q[..]);
    }

    #[test]
    fn decompose_identity() {
        let mut rng = Rng::new(1);
        let w = rng.ternary_matrix(10, 10, 0.33);
        let (d, s) = decompose(&w);
        for i in 0..w.len() {
            assert_eq!(w[i], d[i] - s[i]);
            assert!(d[i] == 1 || d[i] == -1);
            assert!(s[i] == 0 || s[i] == 1);
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut rng = Rng::new(2);
        for &(m, k, c) in &[(4, 8, 2), (3, 12, 4), (16, 64, 2), (7, 32, 4)] {
            let w = rng.ternary_matrix(m, k, 0.3);
            let enc = encode_indices(&w, m, k, c);
            assert_eq!(decode_indices(&enc), w, "m={m} k={k} c={c}");
        }
    }

    #[test]
    fn encoded_index_ranges() {
        let mut rng = Rng::new(3);
        let w = rng.ternary_matrix(8, 16, 0.5);
        let enc = encode_indices(&w, 8, 16, 2);
        for (&d, &s) in enc.wd.iter().zip(&enc.ws) {
            assert!(d < 4 && s < 4);
            // sparse bit set implies dense bit set (zero densifies to +1)
            assert_eq!(s & !d, 0);
        }
    }

    #[test]
    #[should_panic]
    fn encode_rejects_bad_k() {
        encode_indices(&[0i8; 6], 2, 3, 2);
    }

    #[test]
    fn plane_roundtrip_on_unaligned_lengths() {
        let mut rng = Rng::new(4);
        for n in [1usize, 7, 8, 9, 63, 64, 100] {
            let w = rng.ternary_matrix(1, n, 0.4);
            let (plus, minus) = pack_ternary_planes(&w);
            assert_eq!(plus.len(), n.div_ceil(8));
            assert_eq!(unpack_ternary_planes(&plus, &minus, n).unwrap(), w, "n={n}");
        }
    }

    #[test]
    fn plane_unpack_rejects_corruption() {
        let w = [1i8, -1, 0, 1, 1];
        let (plus, minus) = pack_ternary_planes(&w);
        // Wrong length.
        assert!(unpack_ternary_planes(&plus, &minus, 9).is_err());
        // Both planes set at one position.
        let mut bad = minus.clone();
        bad[0] |= 1;
        assert!(unpack_ternary_planes(&plus, &bad, 5).is_err());
        // Junk past the last weight.
        let mut tail = plus.clone();
        tail[0] |= 1 << 7;
        assert!(unpack_ternary_planes(&tail, &minus, 5).is_err());
    }
}
