//! Weight packing formats: T-SAR 1+1-bit, BitNet.cpp TL-2 (1.67 b/w) and
//! T-MAC grouped 4-bit indices.  The *storage density* difference matters
//! for Fig. 9 (TL-2's denser packing limits T-SAR's GEMM-side memory
//! reduction — paper footnote 1: ~20% more static weight RAM for T-SAR).

/// T-SAR compile-time encoding: per block of `c` weights, one dense index
/// (bit per weight: +1 after densification) and one sparse index (bit per
/// weight: originally zero).  Storage: 2 bits/weight (the "1+1-bit split").
#[derive(Debug, Clone)]
pub struct TsarEncoded {
    pub m: usize,
    pub k: usize,
    pub c: usize,
    /// Dense LUT indices, (M × K/c), one byte each (low `c` bits used).
    pub wd: Vec<u8>,
    /// Sparse LUT indices, same layout.
    pub ws: Vec<u8>,
}

impl TsarEncoded {
    /// Packed storage in bits/weight: c dense bits + c sparse bits per
    /// c-weight block = 2 b/w regardless of c.
    pub const BITS_PER_WEIGHT: f64 = 2.0;

    /// Bytes of weight storage the encoded matrix occupies in memory
    /// (the form the TGEMV instruction streams).
    pub fn packed_bytes(&self) -> usize {
        // 2c bits per block, K/c blocks per row.
        (self.m * self.k * 2).div_ceil(8)
    }
}

/// BitNet.cpp TL-2-style packing: 3 ternary weights → 5 bits (3^3 = 27 ≤
/// 2^5 = 32), i.e. 1.67 bits/weight.  We store the base-3 digit group in
/// a byte-aligned 5-bit stream.
#[derive(Debug, Clone)]
pub struct Tl2Packed {
    pub m: usize,
    pub k: usize,
    /// 5-bit codes, one per 3-weight group, padded to bytes per row.
    pub codes: Vec<u8>,
    pub groups_per_row: usize,
}

pub const TL2_BITS_PER_WEIGHT: f64 = 5.0 / 3.0;

impl Tl2Packed {
    /// Pack a row-major ternary matrix; K is padded to a multiple of 3
    /// with zeros (as bitnet.cpp does).
    pub fn pack(w_t: &[i8], m: usize, k: usize) -> Tl2Packed {
        assert_eq!(w_t.len(), m * k);
        let groups = k.div_ceil(3);
        let mut codes = vec![0u8; m * groups];
        for row in 0..m {
            for g in 0..groups {
                let mut code = 0u16;
                for i in 0..3 {
                    let col = g * 3 + i;
                    let w = if col < k { w_t[row * k + col] } else { 0 };
                    code = code * 3 + (w + 1) as u16; // base-3 digit in {0,1,2}
                }
                debug_assert!(code < 27);
                codes[row * groups + g] = code as u8;
            }
        }
        Tl2Packed { m, k, codes, groups_per_row: groups }
    }

    pub fn unpack(&self) -> Vec<i8> {
        let mut w = vec![0i8; self.m * self.k];
        for row in 0..self.m {
            for g in 0..self.groups_per_row {
                let mut code = self.codes[row * self.groups_per_row + g] as i16;
                // Digits come out most-significant-first.
                let mut digits = [0i8; 3];
                for i in (0..3).rev() {
                    digits[i] = (code % 3) as i8 - 1;
                    code /= 3;
                }
                for i in 0..3 {
                    let col = g * 3 + i;
                    if col < self.k {
                        w[row * self.k + col] = digits[i];
                    }
                }
            }
        }
        w
    }

    /// In-memory footprint at the nominal 5-bit/group density.
    pub fn packed_bytes(&self) -> usize {
        (self.m * self.groups_per_row * 5).div_ceil(8)
    }
}

/// T-MAC-style packing: groups of `g` ternary weights (we use g=4, the
/// paper's LUT kernel default for low-bit weights) become one index into
/// a per-group activation LUT of 2^g entries after T-MAC's sign/offset
/// transform.  T-MAC stores weights bit-plane-wise at 2 bits/weight for
/// ternary, with a per-tile interleave suited to its table lookups.
#[derive(Debug, Clone)]
pub struct TmacPacked {
    pub m: usize,
    pub k: usize,
    pub g: usize,
    /// One byte per group holding the g-bit "sign-plane" index.
    pub sign_idx: Vec<u8>,
    /// One byte per group holding the g-bit "zero-plane" mask.
    pub zero_idx: Vec<u8>,
}

pub const TMAC_BITS_PER_WEIGHT: f64 = 2.0;

impl TmacPacked {
    pub fn pack(w_t: &[i8], m: usize, k: usize, g: usize) -> TmacPacked {
        assert_eq!(w_t.len(), m * k);
        assert_eq!(k % g, 0);
        let groups = k / g;
        let mut sign_idx = vec![0u8; m * groups];
        let mut zero_idx = vec![0u8; m * groups];
        for row in 0..m {
            for grp in 0..groups {
                let mut s = 0u8;
                let mut z = 0u8;
                for i in 0..g {
                    let w = w_t[row * k + grp * g + i];
                    if w > 0 {
                        s |= 1 << i;
                    }
                    if w == 0 {
                        z |= 1 << i;
                    }
                }
                sign_idx[row * groups + grp] = s;
                zero_idx[row * groups + grp] = z;
            }
        }
        TmacPacked { m, k, g, sign_idx, zero_idx }
    }

    pub fn unpack(&self) -> Vec<i8> {
        let groups = self.k / self.g;
        let mut w = vec![0i8; self.m * self.k];
        for row in 0..self.m {
            for grp in 0..groups {
                let s = self.sign_idx[row * groups + grp];
                let z = self.zero_idx[row * groups + grp];
                for i in 0..self.g {
                    let col = row * self.k + grp * self.g + i;
                    w[col] = if z >> i & 1 == 1 {
                        0
                    } else if s >> i & 1 == 1 {
                        1
                    } else {
                        -1
                    };
                }
            }
        }
        w
    }

    pub fn packed_bytes(&self) -> usize {
        (self.m * self.k * 2).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tl2_roundtrip() {
        let mut rng = Rng::new(4);
        for &(m, k) in &[(3, 9), (5, 12), (2, 10), (8, 33)] {
            let w = rng.ternary_matrix(m, k, 0.3);
            let p = Tl2Packed::pack(&w, m, k);
            assert_eq!(p.unpack(), w, "m={m} k={k}");
        }
    }

    #[test]
    fn tl2_density() {
        // 1.67 bits/weight: for k=3 the code is 5 bits vs T-SAR's 6.
        let w = vec![1i8, 0, -1];
        let p = Tl2Packed::pack(&w, 1, 3);
        assert_eq!(p.codes.len(), 1);
        assert!((TL2_BITS_PER_WEIGHT - 1.6667).abs() < 1e-3);
    }

    #[test]
    fn tl2_padding() {
        // K not divisible by 3: padded weights decode as explicit zeros.
        let w = vec![1i8, -1, 0, 1];
        let p = Tl2Packed::pack(&w, 1, 4);
        assert_eq!(p.groups_per_row, 2);
        assert_eq!(p.unpack(), w);
    }

    #[test]
    fn tmac_roundtrip() {
        let mut rng = Rng::new(5);
        for &(m, k, g) in &[(4, 16, 4), (3, 8, 2), (2, 32, 4)] {
            let w = rng.ternary_matrix(m, k, 0.4);
            let p = TmacPacked::pack(&w, m, k, g);
            assert_eq!(p.unpack(), w, "m={m} k={k} g={g}");
        }
    }

    #[test]
    fn density_comparison_matches_paper_footnote() {
        // Paper fn.1: TL-2's packing is ~20% denser than T-SAR's 1+1-bit.
        let ratio = TsarEncoded::BITS_PER_WEIGHT / TL2_BITS_PER_WEIGHT;
        assert!((ratio - 1.2).abs() < 0.01, "ratio {ratio}");
    }
}
