//! Weight packing formats: T-SAR 1+1-bit, BitNet.cpp TL-2 (1.67 b/w) and
//! T-MAC grouped 4-bit indices.  The *storage density* difference matters
//! for Fig. 9 (TL-2's denser packing limits T-SAR's GEMM-side memory
//! reduction — paper footnote 1: ~20% more static weight RAM for T-SAR).
//!
//! [`PshufbPacked`] is the *execution-layout* repack of [`TsarEncoded`]
//! consumed by the native AVX2 kernels (`kernels::native`): index bytes
//! pre-arranged per `_mm256_shuffle_epi8`'s 128-bit lane-crossing rules
//! so the hot loop issues shuffles straight off the weight stream.

/// T-SAR compile-time encoding: per block of `c` weights, one dense index
/// (bit per weight: +1 after densification) and one sparse index (bit per
/// weight: originally zero).  Storage: 2 bits/weight (the "1+1-bit split").
#[derive(Debug, Clone)]
pub struct TsarEncoded {
    pub m: usize,
    pub k: usize,
    pub c: usize,
    /// Dense LUT indices, (M × K/c), one byte each (low `c` bits used).
    pub wd: Vec<u8>,
    /// Sparse LUT indices, same layout.
    pub ws: Vec<u8>,
}

impl TsarEncoded {
    /// Packed storage in bits/weight: c dense bits + c sparse bits per
    /// c-weight block = 2 b/w regardless of c.
    pub const BITS_PER_WEIGHT: f64 = 2.0;

    /// Bytes of weight storage the encoded matrix occupies in memory
    /// (the form the TGEMV instruction streams).
    pub fn packed_bytes(&self) -> usize {
        // 2c bits per block, K/c blocks per row.
        (self.m * self.k * 2).div_ceil(8)
    }
}

/// BitNet.cpp TL-2-style packing: 3 ternary weights → 5 bits (3^3 = 27 ≤
/// 2^5 = 32), i.e. 1.67 bits/weight.  Codes are bit-packed into a true
/// 5-bit stream (rows byte-aligned), so [`Tl2Packed::packed_bytes`]
/// reports the bytes the buffer actually occupies — footprint
/// comparisons in the benches see the real density, not a nominal one.
#[derive(Debug, Clone)]
pub struct Tl2Packed {
    pub m: usize,
    pub k: usize,
    /// Bit-packed 5-bit codes: group `g` of row `r` occupies bits
    /// `[g*5, g*5+5)` of row `r`'s `row_bytes`-byte slice (rows start on
    /// byte boundaries).  Read through [`Tl2Packed::code`].
    pub codes: Vec<u8>,
    pub groups_per_row: usize,
    /// Bytes per row of the packed stream: ⌈groups·5 / 8⌉.
    pub row_bytes: usize,
}

pub const TL2_BITS_PER_WEIGHT: f64 = 5.0 / 3.0;

impl Tl2Packed {
    /// Pack a row-major ternary matrix; K is padded to a multiple of 3
    /// with zeros (as bitnet.cpp does).
    pub fn pack(w_t: &[i8], m: usize, k: usize) -> Tl2Packed {
        assert_eq!(w_t.len(), m * k);
        let groups = k.div_ceil(3);
        let row_bytes = (groups * 5).div_ceil(8);
        let mut codes = vec![0u8; m * row_bytes];
        for row in 0..m {
            for g in 0..groups {
                let mut code = 0u16;
                for i in 0..3 {
                    let col = g * 3 + i;
                    let w = if col < k { w_t[row * k + col] } else { 0 };
                    code = code * 3 + (w + 1) as u16; // base-3 digit in {0,1,2}
                }
                debug_assert!(code < 27);
                let code = code as u8;
                let bit = g * 5;
                let byte = row * row_bytes + bit / 8;
                let sh = bit % 8;
                codes[byte] |= code << sh;
                if sh >= 4 {
                    // The 5-bit code straddles the byte boundary.
                    codes[byte + 1] |= code >> (8 - sh);
                }
            }
        }
        Tl2Packed { m, k, codes, groups_per_row: groups, row_bytes }
    }

    /// The 5-bit code of group `g` in row `row`.
    pub fn code(&self, row: usize, g: usize) -> u8 {
        debug_assert!(row < self.m && g < self.groups_per_row);
        let bit = g * 5;
        let byte = row * self.row_bytes + bit / 8;
        let sh = bit % 8;
        let mut v = self.codes[byte] >> sh;
        if sh >= 4 {
            v |= self.codes[byte + 1] << (8 - sh);
        }
        v & 0x1F
    }

    pub fn unpack(&self) -> Vec<i8> {
        let mut w = vec![0i8; self.m * self.k];
        for row in 0..self.m {
            for g in 0..self.groups_per_row {
                let mut code = self.code(row, g) as i16;
                // Digits come out most-significant-first.
                let mut digits = [0i8; 3];
                for i in (0..3).rev() {
                    digits[i] = (code % 3) as i8 - 1;
                    code /= 3;
                }
                for (i, &d) in digits.iter().enumerate() {
                    let col = g * 3 + i;
                    if col < self.k {
                        w[row * self.k + col] = d;
                    }
                }
            }
        }
        w
    }

    /// In-memory footprint of the packed stream (true 5-bit density,
    /// rows byte-aligned) — exactly `codes.len()`.
    pub fn packed_bytes(&self) -> usize {
        self.codes.len()
    }
}

/// T-MAC-style packing: groups of `g` ternary weights (we use g=4, the
/// paper's LUT kernel default for low-bit weights) become one index into
/// a per-group activation LUT of 2^g entries after T-MAC's sign/offset
/// transform.  T-MAC stores weights bit-plane-wise at 2 bits/weight for
/// ternary, with a per-tile interleave suited to its table lookups.
#[derive(Debug, Clone)]
pub struct TmacPacked {
    pub m: usize,
    pub k: usize,
    pub g: usize,
    /// One byte per group holding the g-bit "sign-plane" index.
    pub sign_idx: Vec<u8>,
    /// One byte per group holding the g-bit "zero-plane" mask.
    pub zero_idx: Vec<u8>,
}

pub const TMAC_BITS_PER_WEIGHT: f64 = 2.0;

impl TmacPacked {
    /// Pack a row-major ternary matrix; K not divisible by the group
    /// size is padded with zeros (zero-plane bit set), so any K packs.
    pub fn pack(w_t: &[i8], m: usize, k: usize, g: usize) -> TmacPacked {
        assert_eq!(w_t.len(), m * k);
        assert!((1..=8).contains(&g), "group index must fit a byte");
        let groups = k.div_ceil(g);
        let mut sign_idx = vec![0u8; m * groups];
        let mut zero_idx = vec![0u8; m * groups];
        for row in 0..m {
            for grp in 0..groups {
                let mut s = 0u8;
                let mut z = 0u8;
                for i in 0..g {
                    let col = grp * g + i;
                    let w = if col < k { w_t[row * k + col] } else { 0 };
                    if w > 0 {
                        s |= 1 << i;
                    }
                    if w == 0 {
                        z |= 1 << i;
                    }
                }
                sign_idx[row * groups + grp] = s;
                zero_idx[row * groups + grp] = z;
            }
        }
        TmacPacked { m, k, g, sign_idx, zero_idx }
    }

    pub fn unpack(&self) -> Vec<i8> {
        let groups = self.k.div_ceil(self.g);
        let mut w = vec![0i8; self.m * self.k];
        for row in 0..self.m {
            for grp in 0..groups {
                let s = self.sign_idx[row * groups + grp];
                let z = self.zero_idx[row * groups + grp];
                for i in 0..self.g {
                    let col = grp * self.g + i;
                    if col >= self.k {
                        continue;
                    }
                    w[row * self.k + col] = if z >> i & 1 == 1 {
                        0
                    } else if s >> i & 1 == 1 {
                        1
                    } else {
                        -1
                    };
                }
            }
        }
        w
    }

    pub fn packed_bytes(&self) -> usize {
        (self.m * self.k * 2).div_ceil(8)
    }
}

// ---------------------------------------------------------------------------
// pshufb-ready execution layout for the native AVX2 kernels
// ---------------------------------------------------------------------------

/// Outputs per native tile: one tile's gathers fill complete YMM vectors
/// (both paper configs have TGEMV m = 16).
pub const PSHUFB_TILE_OUTS: usize = 16;

/// Bytes of one (tile, k-slice) record.  Both supported configs land on
/// 128 B: c=2 stores four 32-byte shuffle-index vectors (dense/sparse ×
/// output halves), c=4 stores four blocks × (16 dense + 16 sparse) index
/// bytes.
pub const PSHUFB_TILE_SLICE_BYTES: usize = 128;

/// [`TsarEncoded`] repacked for `_mm256_shuffle_epi8` consumption
/// (`kernels::native`): per (16-output tile, k-slice) record, the
/// dense/sparse LUT-entry indices are pre-arranged so the kernel loads
/// them straight into shuffle index operands — no per-iteration index
/// arithmetic on the hot path.
///
/// `pshufb` shuffles bytes *within each 128-bit lane*, so the layout
/// follows the lane-crossing rules the kernels rely on:
///
/// * **c=2**: the whole slice's dense LUT (4 blocks × 4 entries) fits
///   one 16-byte lane, so index bytes are stored pre-offset as
///   `4·block + entry` and grouped `[dense o0..o7 | sparse o0..o7 |
///   dense o8..o15 | sparse o8..o15]` (32 B each, outputs 0..3 in lane
///   0 and 4..7 in lane 1 of each vector, four consecutive block-bytes
///   per output so `vpmaddwd` reduces adjacent lanes of one output).
/// * **c=4**: one block's 16-entry LUT fills a full lane (split into
///   lo/hi byte planes by the kernel), so records hold per-block groups
///   `[dense o0..o15 | sparse o0..o15]` (raw entry indices).
#[derive(Debug, Clone)]
pub struct PshufbPacked {
    /// ISA block size (activations per LUT), 2 or 4.
    pub c: usize,
    /// Blocks per k-slice (both paper configs use 4).
    pub s: usize,
    /// Logical (unpadded) output / input-channel counts.
    pub m: usize,
    pub k: usize,
    /// Padded geometry: `m_pad = tiles·16`, `k_pad = slices·c·s`.
    pub m_pad: usize,
    pub k_pad: usize,
    pub tiles: usize,
    pub slices: usize,
    /// `tiles × slices` records of [`PSHUFB_TILE_SLICE_BYTES`],
    /// tile-major (`record(tile, slice) = tile·slices + slice`).
    pub data: Vec<u8>,
}

impl PshufbPacked {
    /// Repack an encoded (already padded) matrix.  `m`/`k` are the
    /// logical dims before padding; `enc` must be padded to whole tiles
    /// and slices (`enc.m % 16 == 0`, `enc.k % (c·s) == 0`).
    pub fn from_encoded(
        enc: &TsarEncoded,
        s: usize,
        m: usize,
        k: usize,
    ) -> crate::util::error::Result<PshufbPacked> {
        crate::ensure!(
            enc.c == 2 || enc.c == 4,
            "pshufb layout supports c in {{2,4}}, got {}",
            enc.c
        );
        crate::ensure!(s == 4, "pshufb layout assumes s = 4 blocks per slice, got {s}");
        crate::ensure!(
            enc.m % PSHUFB_TILE_OUTS == 0,
            "encoded M {} not padded to whole 16-output tiles",
            enc.m
        );
        crate::ensure!(
            enc.k % (enc.c * s) == 0,
            "encoded K {} not padded to whole k-slices of {}",
            enc.k,
            enc.c * s
        );
        crate::ensure!(m <= enc.m && k <= enc.k, "logical dims exceed encoded dims");
        let nb_row = enc.k / enc.c; // encoded blocks per row
        let tiles = enc.m / PSHUFB_TILE_OUTS;
        let slices = enc.k / (enc.c * s);
        let mut data = vec![0u8; tiles * slices * PSHUFB_TILE_SLICE_BYTES];
        for tile in 0..tiles {
            for slice in 0..slices {
                let rec = &mut data[(tile * slices + slice) * PSHUFB_TILE_SLICE_BYTES..]
                    [..PSHUFB_TILE_SLICE_BYTES];
                for o in 0..PSHUFB_TILE_OUTS {
                    let row = tile * PSHUFB_TILE_OUTS + o;
                    for b in 0..s {
                        let blk = slice * s + b;
                        let d = enc.wd[row * nb_row + blk];
                        let sp = enc.ws[row * nb_row + blk];
                        debug_assert!((d as usize) < 1 << enc.c);
                        debug_assert!((sp as usize) < 1 << enc.c);
                        match enc.c {
                            2 => {
                                let half = (o / 8) * 64;
                                rec[half + (o % 8) * 4 + b] = (4 * b) as u8 + d;
                                rec[half + 32 + (o % 8) * 4 + b] = (4 * b) as u8 + sp;
                            }
                            _ => {
                                rec[b * 32 + o] = d;
                                rec[b * 32 + 16 + o] = sp;
                            }
                        }
                    }
                }
            }
        }
        Ok(PshufbPacked {
            c: enc.c,
            s,
            m,
            k,
            m_pad: enc.m,
            k_pad: enc.k,
            tiles,
            slices,
            data,
        })
    }

    /// Decode the (dense, sparse) LUT-entry indices of tile-local output
    /// `o`, block `b` of one record — the layout contract shared by the
    /// packer, the portable fallback kernel and the AVX2 shuffles.
    pub fn indices(&self, tile: usize, slice: usize, o: usize, b: usize) -> (u8, u8) {
        let rec = &self.data[(tile * self.slices + slice) * PSHUFB_TILE_SLICE_BYTES..]
            [..PSHUFB_TILE_SLICE_BYTES];
        PshufbPacked::record_indices(self.c, rec, o, b)
    }

    /// [`PshufbPacked::indices`] over one raw record — lets kernels
    /// that operate on a contiguous tile *sub-range* of `data` (the
    /// multi-threaded row chunks) decode without the full struct.
    pub fn record_indices(c: usize, rec: &[u8], o: usize, b: usize) -> (u8, u8) {
        debug_assert_eq!(rec.len(), PSHUFB_TILE_SLICE_BYTES);
        match c {
            2 => {
                let half = (o / 8) * 64;
                (
                    rec[half + (o % 8) * 4 + b] - (4 * b) as u8,
                    rec[half + 32 + (o % 8) * 4 + b] - (4 * b) as u8,
                )
            }
            _ => (rec[b * 32 + o], rec[b * 32 + 16 + o]),
        }
    }

    /// Bytes the execution layout occupies (1 byte per weight for c=2,
    /// 0.5 for c=4 — a deliberate space-for-shuffle-throughput trade
    /// over [`TsarEncoded::BITS_PER_WEIGHT`]'s 2 b/w storage form).
    pub fn packed_bytes(&self) -> usize {
        self.data.len()
    }

    /// The records of `tiles` consecutive tiles starting at `tile0` —
    /// the contiguous byte range a worker lane owning that tile chunk
    /// streams (the layout is tile-major, so a tile range is one
    /// slice).
    pub fn tile_records(&self, tile0: usize, tiles: usize) -> &[u8] {
        let rec = self.slices * PSHUFB_TILE_SLICE_BYTES;
        &self.data[tile0 * rec..(tile0 + tiles) * rec]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tl2_roundtrip() {
        let mut rng = Rng::new(4);
        for &(m, k) in &[(3, 9), (5, 12), (2, 10), (8, 33)] {
            let w = rng.ternary_matrix(m, k, 0.3);
            let p = Tl2Packed::pack(&w, m, k);
            assert_eq!(p.unpack(), w, "m={m} k={k}");
        }
    }

    #[test]
    fn tl2_density() {
        // 1.67 bits/weight: for k=3 the code is 5 bits vs T-SAR's 6.
        let w = vec![1i8, 0, -1];
        let p = Tl2Packed::pack(&w, 1, 3);
        assert_eq!(p.codes.len(), 1);
        assert!((TL2_BITS_PER_WEIGHT - 1.6667).abs() < 1e-3);
    }

    #[test]
    fn tl2_padding() {
        // K not divisible by 3: padded weights decode as explicit zeros.
        let w = vec![1i8, -1, 0, 1];
        let p = Tl2Packed::pack(&w, 1, 4);
        assert_eq!(p.groups_per_row, 2);
        assert_eq!(p.unpack(), w);
    }

    #[test]
    fn tmac_roundtrip() {
        let mut rng = Rng::new(5);
        for &(m, k, g) in &[(4, 16, 4), (3, 8, 2), (2, 32, 4)] {
            let w = rng.ternary_matrix(m, k, 0.4);
            let p = TmacPacked::pack(&w, m, k, g);
            assert_eq!(p.unpack(), w, "m={m} k={k} g={g}");
        }
    }

    #[test]
    fn density_comparison_matches_paper_footnote() {
        // Paper fn.1: TL-2's packing is ~20% denser than T-SAR's 1+1-bit.
        let ratio = TsarEncoded::BITS_PER_WEIGHT / TL2_BITS_PER_WEIGHT;
        assert!((ratio - 1.2).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn tl2_packed_bytes_reports_true_bitstream_density() {
        // 8 groups/row = 40 bits = 5 bytes — the honest footprint, not
        // the old 1-byte-per-group storage.
        let w = Rng::new(6).ternary_matrix(3, 24, 0.3);
        let p = Tl2Packed::pack(&w, 3, 24);
        assert_eq!(p.groups_per_row, 8);
        assert_eq!(p.row_bytes, 5);
        assert_eq!(p.packed_bytes(), 15);
        assert_eq!(p.packed_bytes(), p.codes.len());
        let bits_per_weight = p.packed_bytes() as f64 * 8.0 / (3.0 * 24.0);
        assert!((bits_per_weight - TL2_BITS_PER_WEIGHT).abs() < 1e-9);
        assert_eq!(p.unpack(), w);
    }

    #[test]
    fn tl2_codes_survive_byte_straddling() {
        // Groups 1, 2, 4, 5... straddle byte boundaries; every code must
        // read back exactly.
        let w = Rng::new(7).ternary_matrix(2, 30, 0.4);
        let p = Tl2Packed::pack(&w, 2, 30);
        for row in 0..2 {
            for g in 0..p.groups_per_row {
                assert!(p.code(row, g) < 27, "row {row} group {g}");
            }
        }
        assert_eq!(p.unpack(), w);
    }

    #[test]
    fn tmac_pads_unaligned_k() {
        let w = vec![1i8, -1, 0, 1, 1, -1, 0];
        let p = TmacPacked::pack(&w, 1, 7, 4);
        assert_eq!(p.sign_idx.len(), 2);
        assert_eq!(p.unpack(), w);
    }

    #[test]
    fn pshufb_layout_round_trips_indices() {
        let mut rng = Rng::new(8);
        for &c in &[2usize, 4] {
            let s = 4;
            let (m_pad, k_pad) = (32, 2 * c * s);
            let w = rng.ternary_matrix(m_pad, k_pad, 0.33);
            let enc = crate::quant::encode_indices(&w, m_pad, k_pad, c);
            let p = PshufbPacked::from_encoded(&enc, s, 30, k_pad - c).unwrap();
            assert_eq!(p.tiles, 2);
            assert_eq!(p.slices, 2);
            assert_eq!(p.data.len(), 2 * 2 * PSHUFB_TILE_SLICE_BYTES);
            let nb_row = k_pad / c;
            for tile in 0..p.tiles {
                for slice in 0..p.slices {
                    for o in 0..PSHUFB_TILE_OUTS {
                        for b in 0..s {
                            let (d, sp) = p.indices(tile, slice, o, b);
                            let row = tile * PSHUFB_TILE_OUTS + o;
                            let blk = slice * s + b;
                            assert_eq!(d, enc.wd[row * nb_row + blk], "c={c}");
                            assert_eq!(sp, enc.ws[row * nb_row + blk], "c={c}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn pshufb_c2_index_bytes_stay_in_lane_range() {
        // pshufb zeroes lanes whose index byte has the high bit set; the
        // pre-offset c=2 bytes must all stay within 0..16.
        let mut rng = Rng::new(9);
        let w = rng.ternary_matrix(16, 16, 0.5);
        let enc = crate::quant::encode_indices(&w, 16, 16, 2);
        let p = PshufbPacked::from_encoded(&enc, 4, 16, 16).unwrap();
        assert!(p.data.iter().all(|&b| b < 16));
    }

    #[test]
    fn pshufb_tile_records_cover_data_contiguously() {
        let mut rng = Rng::new(10);
        let w = rng.ternary_matrix(48, 16, 0.4);
        let enc = crate::quant::encode_indices(&w, 48, 16, 2);
        let p = PshufbPacked::from_encoded(&enc, 4, 48, 16).unwrap();
        assert_eq!(p.tiles, 3);
        assert_eq!(p.tile_records(0, p.tiles), &p.data[..]);
        let rec = p.slices * PSHUFB_TILE_SLICE_BYTES;
        let mut rebuilt = Vec::new();
        for t in 0..p.tiles {
            let chunk = p.tile_records(t, 1);
            assert_eq!(chunk.len(), rec);
            rebuilt.extend_from_slice(chunk);
        }
        assert_eq!(rebuilt, p.data);
    }

    #[test]
    fn pshufb_rejects_unpadded_encodings() {
        let w = vec![0i8; 8 * 8];
        let enc = crate::quant::encode_indices(&w, 8, 8, 2);
        assert!(PshufbPacked::from_encoded(&enc, 4, 8, 8).is_err());
    }
}
