//! Cross-platform energy model — Table III (paper §IV-F).
//!
//! CPU side: the paper estimates T-SAR package power by scaling measured
//! TL-2 package power with the synthesis overhead
//! (`P_TSAR = 1.032 · P_TL2`); we apply the identical rule to the
//! TDP-class package powers in [`crate::config::platforms`].  Energy per
//! token is `P / (tokens/s)` with throughput from the simulator.
//!
//! GPU side: no Jetson AGX Orin exists here, so it is modeled as a
//! bandwidth-limited decoder (Ampere iGPU, 204.8 GB/s LPDDR5) running
//! llama.cpp *without ternary kernels* (weights at 4-bit quantization —
//! llama.cpp's densest viable format for these checkpoints), with an
//! effective-bandwidth efficiency calibrated to the paper's measured
//! 16.78 tok/s / 1.839 J/token anchor for Llama-b1.58-8B.  The anchor
//! calibration and its implications are recorded in EXPERIMENTS.md.

use crate::config::platforms::Platform;
use crate::hw;
use crate::kernels::{select_tsar_kernel, TernaryKernel, Tl2Kernel};
use crate::model::zoo::ModelSpec;
use crate::model::Workload;
use crate::sim::simulate;

/// Jetson AGX Orin module model.
#[derive(Debug, Clone)]
pub struct JetsonModel {
    /// LPDDR5 peak bandwidth, GB/s.
    pub bw_gbps: f64,
    /// Effective fraction of peak bandwidth llama.cpp decode sustains
    /// (calibrated on the paper's measured throughput).
    pub bw_efficiency: f64,
    /// Weight storage bits/weight (llama.cpp 4-bit quant).
    pub bits_per_weight: f64,
    /// Module power under decode load, W (measured boundary: module).
    pub module_power_w: f64,
}

impl Default for JetsonModel {
    fn default() -> Self {
        JetsonModel {
            bw_gbps: 204.8,
            // Calibrated: 16.78 tok/s on Llama-8B @ 4 b/w needs
            // 16.78 · 8e9 · 0.5 B = 67 GB/s effective = 0.33 of peak.
            bw_efficiency: 0.33,
            bits_per_weight: 4.0,
            module_power_w: 30.9, // 16.78 tok/s × 1.839 J/token
        }
    }
}

impl JetsonModel {
    /// Decode throughput: bandwidth-limited weight streaming.
    pub fn tokens_per_second(&self, spec: &ModelSpec) -> f64 {
        let bytes_per_token = spec.param_count() * self.bits_per_weight / 8.0;
        self.bw_gbps * 1e9 * self.bw_efficiency / bytes_per_token
    }

    pub fn joules_per_token(&self, spec: &ModelSpec) -> f64 {
        self.module_power_w / self.tokens_per_second(spec)
    }
}

/// Simulated decode throughput of the whole model on a platform using
/// the given kernel-selection strategy (tokens/s, steady-state decode).
pub fn cpu_decode_tokens_per_second(
    spec: &'static ModelSpec,
    plat: &Platform,
    use_tsar: bool,
) -> f64 {
    let wl = Workload::decode(spec);
    let mut token_seconds = 0.0;
    for op in &wl.ops {
        let r = if use_tsar {
            let (_, r) = select_tsar_kernel(op.shape, plat, plat.threads);
            r
        } else {
            let k = Tl2Kernel::new();
            simulate(&k.profile(op.shape, plat, plat.threads), plat, plat.threads)
        };
        token_seconds += r.seconds * op.count as f64;
    }
    // Attention + norms + sampling: small non-BitLinear residue, taken
    // as 5% of BitLinear time for the big models (KV cache reads are
    // covered by the wv/wo shapes' bandwidth in this granularity).
    token_seconds *= 1.05;
    1.0 / token_seconds
}

/// One Table III row.
#[derive(Debug, Clone)]
pub struct CrossPlatformRow {
    pub platform: String,
    pub node: String,
    pub tokens_per_s: f64,
    pub joules_per_token: f64,
}

/// Compute Table III for one model across the three CPU platforms plus
/// the Jetson comparator.
pub fn table3_rows(spec: &'static ModelSpec) -> Vec<CrossPlatformRow> {
    let mut rows = Vec::new();
    for kind in crate::config::ALL_PLATFORMS {
        let plat = Platform::by_kind(kind);
        let tps = cpu_decode_tokens_per_second(spec, &plat, true);
        // P_TSAR = 1.032 · P_TL2 (package boundary).
        let p = plat.pkg_power_w * hw::tsar_power_scale();
        rows.push(CrossPlatformRow {
            platform: format!("{} CPU ({}, T-SAR)", plat.name, plat.cpu_model),
            node: plat.node.clone(),
            tokens_per_s: tps,
            joules_per_token: p / tps,
        });
    }
    let jetson = JetsonModel::default();
    rows.push(CrossPlatformRow {
        platform: "Jetson AGX Orin GPU (llama.cpp)".into(),
        node: "8nm".into(),
        tokens_per_s: jetson.tokens_per_second(spec),
        joules_per_token: jetson.joules_per_token(spec),
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::by_name;

    #[test]
    fn jetson_anchor_reproduces_paper() {
        let j = JetsonModel::default();
        let llama = by_name("Llama-b1.58-8B").unwrap();
        let tps = j.tokens_per_second(llama);
        // Paper measured 16.78 tok/s; the calibrated model must be close
        // (shape-level agreement, ±15% for the param-count difference).
        assert!((tps / 16.78 - 1.0).abs() < 0.15, "jetson tok/s {tps:.2}");
        let jpt = j.joules_per_token(llama);
        assert!((jpt / 1.839 - 1.0).abs() < 0.2, "jetson J/token {jpt:.3}");
    }

    #[test]
    fn workstation_beats_jetson_in_throughput() {
        // Table III's headline: Workstation/Laptop CPUs with T-SAR beat
        // the Jetson GPU in decode throughput on Llama-8B.
        let llama = by_name("Llama-b1.58-8B").unwrap();
        let rows = table3_rows(llama);
        let ws = &rows[0];
        let jetson = rows.last().unwrap();
        assert!(
            ws.tokens_per_s > jetson.tokens_per_s,
            "workstation {:.1} <= jetson {:.1}",
            ws.tokens_per_s,
            jetson.tokens_per_s
        );
    }

    #[test]
    fn energy_efficiency_beats_jetson() {
        // Paper: 2.5–4.9× lower J/token than Jetson on all platforms.
        // Our simulator enforces the physical DRAM floor the paper's
        // workstation numbers exceed (EXPERIMENTS.md §Table III), so we
        // require: laptop & mobile clearly beat Jetson; workstation at
        // worst parity (within 10%).
        let llama = by_name("Llama-b1.58-8B").unwrap();
        let rows = table3_rows(llama);
        let jetson_jpt = rows.last().unwrap().joules_per_token;
        assert!(rows[1].joules_per_token < jetson_jpt / 1.5, "laptop");
        assert!(rows[2].joules_per_token < jetson_jpt / 2.0, "mobile");
        assert!(rows[0].joules_per_token < jetson_jpt * 1.10, "workstation");
    }

    #[test]
    fn mobile_throughput_below_jetson() {
        // Paper: Mobile is 0.31–0.32× Jetson's throughput (but wins on
        // energy).  Require the ordering, not the exact ratio.
        let llama = by_name("Llama-b1.58-8B").unwrap();
        let rows = table3_rows(llama);
        let mobile = &rows[2];
        let jetson = rows.last().unwrap();
        assert!(mobile.tokens_per_s < jetson.tokens_per_s);
    }
}
