//! Per-request timelines recorded by the load-generation client.
//!
//! The client stamps every observable point of a request's life against
//! one shared run clock: submission (the instant the request bytes hit
//! the socket), each streamed event line (the prefill line and every
//! decode token), and the terminal line.  The aggregation layer
//! ([`super::aggregate`]) derives the serving metrics from these raw
//! timelines — TTFT (submit → first event line), TPOT (gaps between
//! consecutive event lines) and end-to-end latency — instead of the
//! client keeping running statistics, so the artifact can always be
//! recomputed from first principles.

/// Terminal classification of one planned request, from the client's
/// point of view.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// HTTP 200 stream ending in a `retired` terminal line.
    Completed,
    /// HTTP 200 stream ending in a `cancelled` terminal line
    /// (mid-stream cancel or deadline expiry).
    Cancelled,
    /// HTTP 429: shed by the engine's bounded admission queue.  The
    /// engine books these as `failed` retirements *and* rejections.
    Rejected,
    /// HTTP 200 stream ending in a `failed` terminal line.
    Failed,
    /// Shed by the HTTP layer itself (e.g. 503); the request never
    /// reached the engine, so it is excluded from the engine-facing
    /// cross-check equations.
    HttpShed,
}

impl Outcome {
    /// Stable label used by the `outcomes` block of the artifact.
    pub fn label(self) -> &'static str {
        match self {
            Outcome::Completed => "completed",
            Outcome::Cancelled => "cancelled",
            Outcome::Rejected => "rejected",
            Outcome::Failed => "failed",
            Outcome::HttpShed => "http_shed",
        }
    }
}

/// Raw observable timeline of one request; all times are seconds on
/// the shared run clock.
#[derive(Debug, Clone)]
pub struct RequestTimeline {
    /// Trace position ([`super::workload::PlannedRequest::index`]).
    pub index: usize,
    /// Engine-assigned request id, learned from the first NDJSON line
    /// (`None` for requests shed before a stream started).
    pub id: Option<u64>,
    /// When the request bytes were written to the socket.
    pub submit_s: f64,
    /// Arrival time of every streamed event line (prefill + tokens).
    pub event_s: Vec<f64>,
    /// Arrival time of the terminal line (or the error response).
    pub done_s: f64,
    /// Terminal classification.
    pub outcome: Outcome,
    /// The terminal line's `finish` label, when a stream ran.
    pub finish: Option<String>,
    /// Tokens carried by the terminal line.
    pub tokens: usize,
}

impl RequestTimeline {
    /// Time to first token: submit → first streamed event line.
    pub fn ttft_s(&self) -> Option<f64> {
        self.event_s.first().map(|&t| t - self.submit_s)
    }

    /// Per-token gaps between consecutive streamed event lines.
    pub fn tpot_samples(&self) -> Vec<f64> {
        self.event_s.windows(2).map(|w| w[1] - w[0]).collect()
    }

    /// End-to-end latency: submit → terminal.
    pub fn e2e_s(&self) -> f64 {
        self.done_s - self.submit_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_derivations() {
        let tl = RequestTimeline {
            index: 0,
            id: Some(4),
            submit_s: 1.0,
            event_s: vec![1.25, 1.35, 1.50],
            done_s: 1.6,
            outcome: Outcome::Completed,
            finish: Some("length".into()),
            tokens: 3,
        };
        assert!((tl.ttft_s().unwrap() - 0.25).abs() < 1e-12);
        let tpot = tl.tpot_samples();
        assert_eq!(tpot.len(), 2);
        assert!((tpot[0] - 0.10).abs() < 1e-12);
        assert!((tpot[1] - 0.15).abs() < 1e-12);
        assert!((tl.e2e_s() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn shed_requests_have_no_first_token() {
        let tl = RequestTimeline {
            index: 1,
            id: None,
            submit_s: 0.5,
            event_s: Vec::new(),
            done_s: 0.51,
            outcome: Outcome::Rejected,
            finish: None,
            tokens: 0,
        };
        assert!(tl.ttft_s().is_none());
        assert!(tl.tpot_samples().is_empty());
        assert!(tl.e2e_s() > 0.0);
    }

    #[test]
    fn labels_match_the_artifact_schema() {
        let labels: Vec<&str> = [
            Outcome::Completed,
            Outcome::Cancelled,
            Outcome::Rejected,
            Outcome::Failed,
            Outcome::HttpShed,
        ]
        .iter()
        .map(|o| o.label())
        .collect();
        assert_eq!(labels, crate::util::artifact::SERVE_OUTCOME_KEYS);
    }
}
