//! The open-loop HTTP client: keep-alive multiplexed connections
//! driving the planned trace against a `tsar-cli serve --http` style
//! front-end.
//!
//! One writer thread per planned connection dispatches its requests at
//! their scheduled arrival times (open loop — see
//! [`super::arrivals`]); a paired reader thread drains the pipelined
//! responses strictly in request order (the front-end's contract) and
//! stamps every byte-level milestone into a
//! [`RequestTimeline`].  The writer caps in-flight streams per
//! connection at [`MAX_INFLIGHT_PER_CONN`] — matching the front-end's
//! `max_streams_per_conn` default — so a well-formed run never trips
//! the 503 concurrent-stream shed and the Prometheus cross-check stays
//! exact.  Responses are classified by status: a 200 chunked stream is
//! read to its terminal NDJSON line, 429 is a queue-cap shed
//! ([`Outcome::Rejected`]), anything else is an HTTP-layer shed
//! ([`Outcome::HttpShed`]).
//!
//! Scheduled cancellations replay real client behavior: once the
//! stream's engine id is known (every NDJSON line carries it) and the
//! planned number of events has arrived, a detached helper posts
//! `POST /v1/cancel {"id": N}` over a fresh connection, and the
//! original stream ends with its `cancelled` terminal line.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::error::{Context, Result};
use crate::util::json::Json;

use super::recorder::{Outcome, RequestTimeline};
use super::workload::{PlannedRequest, Workload};

/// In-flight streams one connection multiplexes; matches the HTTP
/// front-end's `max_streams_per_conn` default so pipelined generates
/// are never shed with 503.
pub const MAX_INFLIGHT_PER_CONN: usize = 4;

/// A stream that stalls longer than this is treated as a dead server.
const READ_TIMEOUT: Duration = Duration::from_secs(60);

/// Drive the whole planned trace against `addr` and return one
/// timeline per planned request (sorted by trace index).  `t0` is the
/// shared run clock every timeline is stamped against.
pub fn run_workload(addr: &str, workload: &Workload, t0: Instant) -> Result<Vec<RequestTimeline>> {
    let conns = workload.requests.iter().map(|r| r.conn).max().map_or(0, |c| c + 1);
    let mut handles = Vec::new();
    for conn in 0..conns {
        let mine: Vec<PlannedRequest> =
            workload.requests.iter().filter(|r| r.conn == conn).cloned().collect();
        if mine.is_empty() {
            continue;
        }
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || drive_connection(&addr, t0, &mine)));
    }
    let mut timelines = Vec::new();
    for handle in handles {
        let joined = handle.join().map_err(|_| crate::err!("load thread panicked"))?;
        timelines.extend(joined?);
    }
    timelines.sort_by_key(|t| t.index);
    Ok(timelines)
}

/// One pipelined request the reader still owes a response for.
struct Pending {
    index: usize,
    submit_s: f64,
    cancel_after: Option<usize>,
}

/// Counting semaphore bounding in-flight streams per connection.
struct Permits {
    inflight: Mutex<usize>,
    freed: Condvar,
    cap: usize,
}

impl Permits {
    fn new(cap: usize) -> Permits {
        Permits { inflight: Mutex::new(0), freed: Condvar::new(), cap: cap.max(1) }
    }

    fn acquire(&self) {
        let mut inflight = self.inflight.lock().expect("permit lock poisoned");
        while *inflight >= self.cap {
            inflight = self.freed.wait(inflight).expect("permit lock poisoned");
        }
        *inflight += 1;
    }

    fn release(&self) {
        let mut inflight = self.inflight.lock().expect("permit lock poisoned");
        *inflight = inflight.saturating_sub(1);
        self.freed.notify_one();
    }
}

/// The writer half of one connection: dispatch each request at its
/// arrival time, handing the reader a [`Pending`] entry per request.
fn drive_connection(
    addr: &str,
    t0: Instant,
    requests: &[PlannedRequest],
) -> Result<Vec<RequestTimeline>> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("cannot connect load connection to {addr}"))?;
    let _ = stream.set_nodelay(true);
    let reader_stream = stream.try_clone().context("cannot clone the load connection")?;
    let permits = Arc::new(Permits::new(MAX_INFLIGHT_PER_CONN));
    let (pending_tx, pending_rx) = channel::<Pending>();
    let reader = {
        let permits = Arc::clone(&permits);
        let addr = addr.to_string();
        std::thread::spawn(move || read_responses(reader_stream, t0, &pending_rx, &permits, &addr))
    };
    for (i, req) in requests.iter().enumerate() {
        let due = Duration::from_secs_f64(req.arrival_s.max(0.0));
        if let Some(wait) = due.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        permits.acquire();
        let last = i + 1 == requests.len();
        let wire = request_bytes(req, !last);
        let submit_s = t0.elapsed().as_secs_f64();
        // The reader learns about the request before any response byte
        // can be attributed to it.
        let pending =
            Pending { index: req.index, submit_s, cancel_after: req.cancel_after_events };
        pending_tx.send(pending).ok();
        stream.write_all(&wire).context("load connection write failed")?;
    }
    drop(pending_tx); // the reader drains what is owed, then exits
    reader.join().map_err(|_| crate::err!("load reader thread panicked"))?
}

/// Serialize one planned request as a `POST /v1/generate` exchange.
/// The connection's last request announces `Connection: close`.
fn request_bytes(req: &PlannedRequest, keep_alive: bool) -> Vec<u8> {
    let mut body = BTreeMap::new();
    body.insert(
        "prompt".to_string(),
        Json::Arr(req.prompt.iter().map(|&t| Json::Num(f64::from(t))).collect()),
    );
    body.insert("max_new_tokens".to_string(), Json::Num(req.max_new_tokens as f64));
    if let Some(ms) = req.deadline_ms {
        body.insert("deadline_ms".to_string(), Json::Num(ms as f64));
    }
    let body = Json::Obj(body).to_string();
    let conn = if keep_alive { "keep-alive" } else { "close" };
    format!(
        "POST /v1/generate HTTP/1.1\r\nHost: loadgen\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {conn}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// The reader half: one response per [`Pending`] entry, in order.
fn read_responses(
    stream: TcpStream,
    t0: Instant,
    pending_rx: &Receiver<Pending>,
    permits: &Permits,
    addr: &str,
) -> Result<Vec<RequestTimeline>> {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let mut wire = Wire::new(stream);
    let mut timelines = Vec::new();
    let mut cancellers: Vec<JoinHandle<()>> = Vec::new();
    for pending in pending_rx.iter() {
        let timeline = read_one_response(&mut wire, t0, &pending, addr, &mut cancellers);
        permits.release();
        timelines.push(timeline?);
    }
    for canceller in cancellers {
        let _ = canceller.join();
    }
    Ok(timelines)
}

/// Read and classify one HTTP response off the connection.
fn read_one_response(
    wire: &mut Wire,
    t0: Instant,
    pending: &Pending,
    addr: &str,
    cancellers: &mut Vec<JoinHandle<()>>,
) -> Result<RequestTimeline> {
    let head = wire.take_until(b"\r\n\r\n")?;
    let head = std::str::from_utf8(&head).map_err(|_| crate::err!("head not UTF-8"))?;
    let (status, chunked, content_length) = parse_head(head)?;
    let mut timeline = RequestTimeline {
        index: pending.index,
        id: None,
        submit_s: pending.submit_s,
        event_s: Vec::new(),
        done_s: pending.submit_s,
        outcome: Outcome::HttpShed,
        finish: None,
        tokens: 0,
    };
    if status == 200 && chunked {
        let mut line_buf: Vec<u8> = Vec::new();
        let mut terminal = false;
        let mut cancel_sent = false;
        loop {
            let size_line = wire.take_until(b"\r\n")?;
            let size = parse_chunk_size(&size_line)?;
            if size == 0 {
                wire.take_exact(2)?; // CRLF closing the chunked body
                break;
            }
            let data = wire.take_exact(size)?;
            wire.take_exact(2)?; // CRLF closing this chunk
            let now = t0.elapsed().as_secs_f64();
            line_buf.extend_from_slice(&data);
            while let Some(pos) = line_buf.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = line_buf.drain(..=pos).collect();
                let is_terminal = process_line(
                    &mut timeline,
                    &line,
                    now,
                    pending,
                    addr,
                    cancellers,
                    &mut cancel_sent,
                )?;
                terminal |= is_terminal;
            }
        }
        crate::ensure!(
            terminal,
            "stream for request {} ended without a terminal event",
            pending.index
        );
    } else {
        // Fixed-length error response: drain the body to stay aligned
        // on the keep-alive connection, then classify by status.
        let _ = wire.take_exact(content_length)?;
        timeline.done_s = t0.elapsed().as_secs_f64();
        timeline.outcome = if status == 429 { Outcome::Rejected } else { Outcome::HttpShed };
    }
    Ok(timeline)
}

/// Fold one NDJSON event line into the timeline; returns whether the
/// line was the stream's terminal event.
fn process_line(
    timeline: &mut RequestTimeline,
    line: &[u8],
    now: f64,
    pending: &Pending,
    addr: &str,
    cancellers: &mut Vec<JoinHandle<()>>,
    cancel_sent: &mut bool,
) -> Result<bool> {
    let text = std::str::from_utf8(line).map_err(|_| crate::err!("event line not UTF-8"))?.trim();
    if text.is_empty() {
        return Ok(false);
    }
    let json = Json::parse(text).map_err(|e| crate::err!("bad event line {text:?}: {e}"))?;
    if timeline.id.is_none() {
        timeline.id = json.get("id").and_then(Json::as_f64).map(|v| v as u64);
    }
    match json.get("event").and_then(Json::as_str) {
        Some("prefilled") | Some("token") => {
            timeline.event_s.push(now);
            if let (Some(after), Some(id)) = (pending.cancel_after, timeline.id) {
                if !*cancel_sent && timeline.event_s.len() >= after {
                    *cancel_sent = true;
                    let addr = addr.to_string();
                    cancellers.push(std::thread::spawn(move || post_cancel(&addr, id)));
                }
            }
            Ok(false)
        }
        Some("retired") => {
            finish_terminal(timeline, &json, now, Outcome::Completed);
            Ok(true)
        }
        Some("cancelled") => {
            finish_terminal(timeline, &json, now, Outcome::Cancelled);
            Ok(true)
        }
        Some("failed") => {
            finish_terminal(timeline, &json, now, Outcome::Failed);
            Ok(true)
        }
        _ => crate::bail!("unknown event line {text:?}"),
    }
}

fn finish_terminal(timeline: &mut RequestTimeline, json: &Json, now: f64, outcome: Outcome) {
    timeline.outcome = outcome;
    timeline.done_s = now;
    timeline.finish = json.get("finish").and_then(Json::as_str).map(str::to_string);
    timeline.tokens = json.get("tokens").and_then(Json::as_arr).map_or(0, <[Json]>::len);
}

/// `POST /v1/cancel {"id": N}` over a fresh short-lived connection.
/// Best effort by design: a request that retired before the cancel
/// landed answers 404, which is a legitimate race, not an error.
fn post_cancel(addr: &str, id: u64) {
    let Ok(mut stream) = TcpStream::connect(addr) else {
        return;
    };
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let body = format!("{{\"id\": {id}}}");
    let request = format!(
        "POST /v1/cancel HTTP/1.1\r\nHost: loadgen\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    if stream.write_all(request.as_bytes()).is_err() {
        return;
    }
    let mut sink = Vec::new();
    let _ = stream.read_to_end(&mut sink);
}

/// Parse a response head into (status, chunked, content_length).
fn parse_head(head: &str) -> Result<(u16, bool, usize)> {
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| crate::err!("bad status line {status_line:?}"))?;
    let mut chunked = false;
    let mut content_length = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            let value = value.trim();
            if name.eq_ignore_ascii_case("transfer-encoding") {
                chunked = value.eq_ignore_ascii_case("chunked");
            } else if name.eq_ignore_ascii_case("content-length") {
                content_length =
                    value.parse().map_err(|_| crate::err!("bad Content-Length {value:?}"))?;
            }
        }
    }
    Ok((status, chunked, content_length))
}

/// Parse one chunk-size line (hex, CRLF-terminated).
fn parse_chunk_size(line: &[u8]) -> Result<usize> {
    let text = std::str::from_utf8(line).map_err(|_| crate::err!("size not UTF-8"))?.trim();
    usize::from_str_radix(text, 16).map_err(|_| crate::err!("bad chunk size {text:?}"))
}

/// Buffered byte reader over the response half of the connection.
struct Wire {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Wire {
    fn new(stream: TcpStream) -> Wire {
        Wire { stream, buf: Vec::new() }
    }

    fn fill(&mut self) -> Result<()> {
        let mut tmp = [0u8; 4096];
        let n = self.stream.read(&mut tmp).context("load connection read failed")?;
        crate::ensure!(n > 0, "server closed the connection mid-response");
        self.buf.extend_from_slice(&tmp[..n]);
        Ok(())
    }

    /// Take bytes up to and including `delim`.
    fn take_until(&mut self, delim: &[u8]) -> Result<Vec<u8>> {
        loop {
            if let Some(pos) = self.buf.windows(delim.len()).position(|w| w == delim) {
                return Ok(self.buf.drain(..pos + delim.len()).collect());
            }
            self.fill()?;
        }
    }

    /// Take exactly `n` bytes.
    fn take_exact(&mut self, n: usize) -> Result<Vec<u8>> {
        while self.buf.len() < n {
            self.fill()?;
        }
        Ok(self.buf.drain(..n).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planned(deadline_ms: Option<u64>) -> PlannedRequest {
        PlannedRequest {
            index: 3,
            arrival_s: 0.1,
            prompt: vec![5, 6, 7],
            max_new_tokens: 4,
            deadline_ms,
            cancel_after_events: None,
            conn: 0,
        }
    }

    #[test]
    fn request_bytes_round_trip_through_the_server_grammar() {
        let wire = request_bytes(&planned(Some(25)), true);
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("POST /v1/generate HTTP/1.1\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        let body = text.split("\r\n\r\n").nth(1).unwrap();
        let stated: usize = text
            .lines()
            .find(|l| l.starts_with("Content-Length:"))
            .and_then(|l| l.split(':').nth(1))
            .and_then(|v| v.trim().parse().ok())
            .unwrap();
        assert_eq!(stated, body.len(), "Content-Length matches the body");
        let json = Json::parse(body).unwrap();
        assert_eq!(json.get("prompt").and_then(Json::as_arr).map(<[Json]>::len), Some(3));
        assert_eq!(json.get("max_new_tokens").and_then(Json::as_usize), Some(4));
        assert_eq!(json.get("deadline_ms").and_then(Json::as_usize), Some(25));

        let last = String::from_utf8(request_bytes(&planned(None), false)).unwrap();
        assert!(last.contains("Connection: close\r\n"));
        assert!(!last.contains("deadline_ms"));
    }

    #[test]
    fn response_heads_parse_status_and_framing() {
        let (status, chunked, len) = parse_head(
            "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n\
             Transfer-Encoding: chunked\r\nConnection: keep-alive\r\n\r\n",
        )
        .unwrap();
        assert_eq!(status, 200);
        assert!(chunked);
        assert_eq!(len, 0);

        let (status, chunked, len) =
            parse_head("HTTP/1.1 429 Too Many Requests\r\nContent-Length: 24\r\n\r\n").unwrap();
        assert_eq!(status, 429);
        assert!(!chunked);
        assert_eq!(len, 24);

        assert!(parse_head("garbage").is_err());
    }

    #[test]
    fn chunk_sizes_parse_as_hex() {
        assert_eq!(parse_chunk_size(b"1a\r\n").unwrap(), 26);
        assert_eq!(parse_chunk_size(b"0\r\n").unwrap(), 0);
        assert!(parse_chunk_size(b"zz\r\n").is_err());
    }

    #[test]
    fn event_lines_drive_the_timeline() {
        let pending = Pending { index: 9, submit_s: 1.0, cancel_after: None };
        let mut timeline = RequestTimeline {
            index: 9,
            id: None,
            submit_s: 1.0,
            event_s: Vec::new(),
            done_s: 1.0,
            outcome: Outcome::HttpShed,
            finish: None,
            tokens: 0,
        };
        let mut cancellers = Vec::new();
        let mut cancel_sent = false;
        let terminal = process_line(
            &mut timeline,
            br#"{"event":"prefilled","id":12,"index":0,"token":3}"#,
            1.2,
            &pending,
            "unused",
            &mut cancellers,
            &mut cancel_sent,
        )
        .unwrap();
        assert!(!terminal);
        assert_eq!(timeline.id, Some(12));
        let terminal = process_line(
            &mut timeline,
            br#"{"event":"retired","finish":"length","id":12,"tokens":[3,4],"error":null}"#,
            1.5,
            &pending,
            "unused",
            &mut cancellers,
            &mut cancel_sent,
        )
        .unwrap();
        assert!(terminal);
        assert_eq!(timeline.outcome, Outcome::Completed);
        assert_eq!(timeline.finish.as_deref(), Some("length"));
        assert_eq!(timeline.tokens, 2);
        assert!((timeline.done_s - 1.5).abs() < 1e-12);
        assert!(cancellers.is_empty(), "no cancel was scheduled");

        let bad = process_line(
            &mut timeline,
            b"{not json",
            1.6,
            &pending,
            "unused",
            &mut cancellers,
            &mut cancel_sent,
        );
        assert!(bad.is_err());
    }
}
