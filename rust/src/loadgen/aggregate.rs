//! Aggregation and the Prometheus cross-check.
//!
//! The client-side timelines ([`RequestTimeline`]) are one view of the
//! run; the engine's `/metrics` exposition is an independent second
//! view.  This module reduces the timelines to summary statistics for
//! the bench artifact *and* reconciles the two views outcome-by-outcome
//! (`cross_check`): a `BENCH_serve.json` with `metrics_agree: true`
//! certifies that every request the client dispatched is accounted for
//! by the engine's own counters — nothing double-counted, nothing
//! leaked.
//!
//! The reconciliation equations mirror the engine's accounting rules
//! (see `coordinator::prom`):
//!
//! * `finish="length"` + `finish="stop"` retirements ↔ client
//!   completions;
//! * `finish="cancelled"` + `finish="deadline"` ↔ client-observed
//!   cancellations (a deadline expiry streams a `cancelled` terminal);
//! * `finish="failed"` ↔ client failures **plus** 429 queue sheds —
//!   the engine books a shed as a failed retirement *and* a rejection;
//! * `tsar_tokens_emitted_total` ↔ tokens carried by terminal lines;
//! * when no genuine stream failures occurred, `tsar_rejections_total`
//!   must equal the 429 count exactly and the queue-wait histogram
//!   must hold one sample per *executed* request (completed +
//!   cancelled).  With stream failures present those two series also
//!   absorb engine-internal validation rejects, so they are only
//!   bounds, not equalities, and are skipped.
//!
//! HTTP-layer sheds (503s) never reach the engine and are excluded
//! from every equation.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::util::stats;

use super::recorder::{Outcome, RequestTimeline};

/// One parsed `/metrics` exposition: rendered series name (including
/// its label set) → value.
#[derive(Debug, Clone, Default)]
pub struct Scrape {
    pub series: BTreeMap<String, f64>,
}

impl Scrape {
    /// Parse the exposition text, skipping comments and blank lines.
    pub fn parse(text: &str) -> Scrape {
        let mut series = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some((name, value)) = line.rsplit_once(' ') {
                if let Ok(v) = value.parse::<f64>() {
                    series.insert(name.to_string(), v);
                }
            }
        }
        Scrape { series }
    }

    /// Value of one fully-labelled series; absent series read as zero
    /// (counters start unrendered until first incremented).
    pub fn value(&self, series: &str) -> f64 {
        self.series.get(series).copied().unwrap_or(0.0)
    }

    /// Sum over every series whose rendered name starts with `prefix`.
    pub fn sum_prefix(&self, prefix: &str) -> f64 {
        self.series.iter().filter(|(k, _)| k.starts_with(prefix)).map(|(_, v)| v).sum()
    }
}

/// Fetch and parse the `/metrics` exposition from a running server.
pub fn scrape_metrics(addr: &str) -> Result<Scrape> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("cannot connect to {addr} for a /metrics scrape"))?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let request = "GET /metrics HTTP/1.1\r\nHost: loadgen\r\nConnection: close\r\n\r\n";
    stream.write_all(request.as_bytes()).context("metrics scrape write failed")?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("metrics scrape read failed")?;
    let text = String::from_utf8_lossy(&raw);
    let (head, body) =
        text.split_once("\r\n\r\n").ok_or_else(|| crate::err!("malformed /metrics response"))?;
    crate::ensure!(head.contains(" 200 "), "/metrics scrape failed: {:?}", head.lines().next());
    Ok(Scrape::parse(body))
}

/// Client-side outcome totals reduced from the raw timelines.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OutcomeCounts {
    pub completed: u64,
    pub cancelled: u64,
    pub rejected: u64,
    pub failed: u64,
    pub http_shed: u64,
    /// Tokens carried by every terminal line (partial streams count).
    pub tokens_total: u64,
    /// Tokens carried by `retired` terminal lines only (goodput).
    pub tokens_completed: u64,
}

impl OutcomeCounts {
    /// Every planned request, however it ended.
    pub fn requests(&self) -> u64 {
        self.engine_requests() + self.http_shed
    }

    /// Requests that reached the engine (everything but HTTP sheds).
    pub fn engine_requests(&self) -> u64 {
        self.completed + self.cancelled + self.rejected + self.failed
    }
}

/// Reduce raw timelines to outcome totals.
pub fn tally(timelines: &[RequestTimeline]) -> OutcomeCounts {
    let mut counts = OutcomeCounts::default();
    for tl in timelines {
        match tl.outcome {
            Outcome::Completed => counts.completed += 1,
            Outcome::Cancelled => counts.cancelled += 1,
            Outcome::Rejected => counts.rejected += 1,
            Outcome::Failed => counts.failed += 1,
            Outcome::HttpShed => counts.http_shed += 1,
        }
        counts.tokens_total += tl.tokens as u64;
        if tl.outcome == Outcome::Completed {
            counts.tokens_completed += tl.tokens as u64;
        }
    }
    counts
}

/// Reconcile the client's view against the engine's `/metrics` deltas.
/// Returns `(agree, mismatches)`; every violated equation contributes
/// one human-readable mismatch line.
pub fn cross_check(before: &Scrape, after: &Scrape, counts: &OutcomeCounts) -> (bool, Vec<String>) {
    let mut mismatches = Vec::new();
    let length = delta(before, after, &finish_series("length"));
    let stop = delta(before, after, &finish_series("stop"));
    let cancelled = delta(before, after, &finish_series("cancelled"));
    let deadline = delta(before, after, &finish_series("deadline"));
    let failed = delta(before, after, &finish_series("failed"));
    let tokens = delta(before, after, "tsar_tokens_emitted_total");
    let rejections = delta(before, after, "tsar_rejections_total");
    let qw_count = delta(before, after, "tsar_queue_wait_seconds_count");
    let total = length + stop + cancelled + deadline + failed;

    expect(&mut mismatches, "completed retirements", length + stop, counts.completed);
    expect(&mut mismatches, "cancelled retirements", cancelled + deadline, counts.cancelled);
    expect(&mut mismatches, "failed retirements", failed, counts.failed + counts.rejected);
    expect(&mut mismatches, "total retirements", total, counts.engine_requests());
    expect(&mut mismatches, "tokens emitted", tokens, counts.tokens_total);
    if counts.failed == 0 {
        expect(&mut mismatches, "queue-cap rejections", rejections, counts.rejected);
        expect(&mut mismatches, "queue-wait count", qw_count, counts.completed + counts.cancelled);
    }
    (mismatches.is_empty(), mismatches)
}

/// Rendered series name of one `tsar_requests_total` finish label.
fn finish_series(label: &str) -> String {
    format!("tsar_requests_total{{finish=\"{label}\"}}")
}

fn delta(before: &Scrape, after: &Scrape, series: &str) -> f64 {
    after.value(series) - before.value(series)
}

fn expect(mismatches: &mut Vec<String>, name: &str, got: f64, want: u64) {
    if (got - want as f64).abs() > 0.5 {
        mismatches.push(format!("{name}: /metrics say {got}, the client saw {want}"));
    }
}

/// Poll `/metrics` until the engine has retired `expected` requests
/// beyond the `before` scrape (terminal lines reach the client ahead
/// of the aggregator draining its channel) or the timeout passes;
/// either way the last scrape is returned and `cross_check` renders
/// the verdict.
pub fn await_retirements(
    addr: &str,
    before: &Scrape,
    expected: u64,
    timeout: Duration,
) -> Result<Scrape> {
    let deadline = Instant::now() + timeout;
    loop {
        let after = scrape_metrics(addr)?;
        let retired =
            after.sum_prefix("tsar_requests_total{") - before.sum_prefix("tsar_requests_total{");
        if retired + 0.5 >= expected as f64 || Instant::now() >= deadline {
            return Ok(after);
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Summarize one latency sample set as the artifact's five stable keys
/// (`LATENCY_STAT_KEYS`); an empty set reads as all zeros so smoke
/// runs with no completions still validate.
pub fn latency_json(samples: &[f64]) -> Json {
    let values: [(&str, f64); 5] = if samples.is_empty() {
        [("p50", 0.0), ("p95", 0.0), ("p99", 0.0), ("mean", 0.0), ("max", 0.0)]
    } else {
        [
            ("p50", stats::percentile(samples, 50.0)),
            ("p95", stats::percentile(samples, 95.0)),
            ("p99", stats::percentile(samples, 99.0)),
            ("mean", stats::mean(samples)),
            ("max", stats::max(samples)),
        ]
    };
    let mut obj = BTreeMap::new();
    for (key, value) in values {
        obj.insert(key.to_string(), Json::Num(value));
    }
    Json::Obj(obj)
}

/// TTFT samples: every request that saw at least one streamed line.
pub fn ttft_samples(timelines: &[RequestTimeline]) -> Vec<f64> {
    timelines.iter().filter_map(RequestTimeline::ttft_s).collect()
}

/// TPOT samples: every gap between consecutive streamed lines.
pub fn tpot_samples(timelines: &[RequestTimeline]) -> Vec<f64> {
    timelines.iter().flat_map(RequestTimeline::tpot_samples).collect()
}

/// End-to-end samples for requests that actually ran a stream; sheds
/// turn around in microseconds and would poison the distribution.
pub fn e2e_samples(timelines: &[RequestTimeline]) -> Vec<f64> {
    timelines.iter().filter(|tl| ran_a_stream(tl)).map(RequestTimeline::e2e_s).collect()
}

fn ran_a_stream(tl: &RequestTimeline) -> bool {
    matches!(tl.outcome, Outcome::Completed | Outcome::Cancelled | Outcome::Failed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tl(outcome: Outcome, tokens: usize, events: &[f64]) -> RequestTimeline {
        RequestTimeline {
            index: 0,
            id: None,
            submit_s: 1.0,
            event_s: events.to_vec(),
            done_s: 2.0,
            outcome,
            finish: None,
            tokens,
        }
    }

    #[test]
    fn scrapes_parse_the_exposition_format() {
        let scrape = Scrape::parse(
            "# HELP tsar_requests_total retired requests\n\
             # TYPE tsar_requests_total counter\n\
             tsar_requests_total{finish=\"length\"} 4\n\
             tsar_requests_total{finish=\"stop\"} 2\n\
             tsar_tokens_emitted_total 31\n\
             tsar_queue_wait_seconds_sum 0.123456\n\n",
        );
        assert_eq!(scrape.value("tsar_requests_total{finish=\"length\"}"), 4.0);
        assert_eq!(scrape.value("tsar_tokens_emitted_total"), 31.0);
        assert_eq!(scrape.value("tsar_requests_total{finish=\"cancelled\"}"), 0.0);
        assert_eq!(scrape.sum_prefix("tsar_requests_total{"), 6.0);
        assert!((scrape.value("tsar_queue_wait_seconds_sum") - 0.123456).abs() < 1e-12);
    }

    #[test]
    fn tally_reduces_timelines_to_outcome_totals() {
        let timelines = vec![
            tl(Outcome::Completed, 5, &[1.2, 1.3]),
            tl(Outcome::Completed, 3, &[1.1]),
            tl(Outcome::Cancelled, 2, &[1.4]),
            tl(Outcome::Rejected, 0, &[]),
            tl(Outcome::HttpShed, 0, &[]),
        ];
        let counts = tally(&timelines);
        assert_eq!(counts.completed, 2);
        assert_eq!(counts.cancelled, 1);
        assert_eq!(counts.rejected, 1);
        assert_eq!(counts.failed, 0);
        assert_eq!(counts.http_shed, 1);
        assert_eq!(counts.tokens_total, 10);
        assert_eq!(counts.tokens_completed, 8);
        assert_eq!(counts.requests(), 5);
        assert_eq!(counts.engine_requests(), 4);
    }

    fn exposition(tokens: u64) -> String {
        format!(
            "tsar_requests_total{{finish=\"length\"}} 2\n\
             tsar_requests_total{{finish=\"stop\"}} 1\n\
             tsar_requests_total{{finish=\"cancelled\"}} 1\n\
             tsar_requests_total{{finish=\"deadline\"}} 1\n\
             tsar_requests_total{{finish=\"failed\"}} 1\n\
             tsar_tokens_emitted_total {tokens}\n\
             tsar_rejections_total 1\n\
             tsar_queue_wait_seconds_count 5\n"
        )
    }

    #[test]
    fn cross_check_accepts_a_consistent_run() {
        let counts = OutcomeCounts {
            completed: 3,
            cancelled: 2,
            rejected: 1,
            failed: 0,
            http_shed: 1,
            tokens_total: 10,
            tokens_completed: 8,
        };
        let before = Scrape::parse("tsar_tokens_emitted_total 5\n");
        let after = Scrape::parse(&exposition(15)); // delta = 10
        let (agree, mismatches) = cross_check(&before, &after, &counts);
        assert!(agree, "unexpected mismatches: {mismatches:?}");
        assert!(mismatches.is_empty());
    }

    #[test]
    fn cross_check_flags_a_leaked_token_count() {
        let counts = OutcomeCounts {
            completed: 3,
            cancelled: 2,
            rejected: 1,
            failed: 0,
            http_shed: 0,
            tokens_total: 10,
            tokens_completed: 8,
        };
        let before = Scrape::default();
        let after = Scrape::parse(&exposition(9));
        let (agree, mismatches) = cross_check(&before, &after, &counts);
        assert!(!agree);
        assert!(mismatches.iter().any(|m| m.contains("tokens emitted")), "{mismatches:?}");
    }

    #[test]
    fn latency_summaries_use_the_schema_keys() {
        let json = latency_json(&[0.1, 0.2, 0.3, 0.4]);
        let keys: Vec<&str> = json.as_obj().unwrap().keys().map(String::as_str).collect();
        let mut want = crate::util::artifact::LATENCY_STAT_KEYS.to_vec();
        want.sort_unstable();
        assert_eq!(keys, want, "artifact keys must match (sorted by BTreeMap)");
        assert!((json.get("max").and_then(Json::as_f64).unwrap() - 0.4).abs() < 1e-12);
        assert!((json.get("mean").and_then(Json::as_f64).unwrap() - 0.25).abs() < 1e-12);

        let empty = latency_json(&[]);
        assert_eq!(empty.get("p99").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn sample_collectors_partition_by_outcome() {
        let timelines = vec![
            tl(Outcome::Completed, 3, &[1.25, 1.5, 1.75]),
            tl(Outcome::Rejected, 0, &[]),
            tl(Outcome::Cancelled, 1, &[1.4]),
        ];
        assert_eq!(ttft_samples(&timelines).len(), 2);
        assert_eq!(tpot_samples(&timelines).len(), 2);
        assert_eq!(e2e_samples(&timelines).len(), 2, "the shed is excluded");
    }
}
