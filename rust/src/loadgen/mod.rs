//! Open-loop load generation for the serving stack
//! (`tsar-cli bench-serve`).
//!
//! The subsystem answers one question with a machine-checkable
//! artifact: *what does the serving stack do under load it does not
//! control?*  A seeded [`WorkloadSpec`] is expanded into a
//! deterministic trace (arrivals, prompts, budgets, deadlines,
//! cancellation points — see [`workload`]), an open-loop client
//! dispatches it over keep-alive HTTP connections ([`client`]), every
//! request's byte-level timeline is recorded ([`recorder`]), and the
//! aggregation layer reduces the timelines to tail-latency statistics
//! while reconciling every outcome against the engine's own
//! Prometheus counters ([`aggregate`]).  The result is the
//! schema-versioned `BENCH_serve.json` artifact
//! (`util::artifact::validate_serve`), whose `cross_check` block
//! certifies the two independent views of the run agree.
//!
//! The driver ([`run`]) either spins up the full in-process stack —
//! `SimBackend` → `Engine` → `HttpServer` with the Prometheus
//! aggregator attached — or targets an already-running front-end via
//! [`BenchConfig::addr`], in which case the configured serving window
//! must match the remote model's or admission validation will shed
//! the trace.

pub mod aggregate;
pub mod arrivals;
pub mod client;
pub mod recorder;
pub mod workload;

pub use aggregate::{cross_check, scrape_metrics, tally, OutcomeCounts, Scrape};
pub use arrivals::ArrivalProcess;
pub use recorder::{Outcome, RequestTimeline};
pub use workload::{PlannedRequest, Workload, WorkloadSpec};

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::platforms::{Platform, PlatformKind};
use crate::coordinator::{Engine, HttpConfig, HttpServer, PromAggregator, ServerConfig};
use crate::runtime::{Backend, SimBackend, SimBackendConfig};
use crate::util::error::Result;
use crate::util::json::Json;

/// Prompt-token vocabulary assumed when benching an external server
/// (the in-process path reads the real vocab off the backend).
const EXTERNAL_VOCAB: usize = 1000;

/// How long the driver waits for the engine's metrics aggregator to
/// observe every retirement before running the cross-check.
const RETIREMENT_TIMEOUT: Duration = Duration::from_secs(10);

/// Everything `bench-serve` needs to run: the workload shape, the
/// in-process serving stack shape, and the artifact flags.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Trace seed; fixed seed ⇒ byte-identical workload.
    pub seed: u64,
    /// Requests in the trace.
    pub requests: usize,
    /// Nominal long-run arrival rate.
    pub rate_rps: f64,
    /// Use the bursty on/off arrival process instead of Poisson.
    pub bursty: bool,
    /// Mean ON-period seconds (bursty only).
    pub on_s: f64,
    /// Mean OFF-period seconds (bursty only).
    pub off_s: f64,
    /// Keep-alive client connections.
    pub conns: usize,
    /// Fraction of requests scheduling a mid-stream cancel.
    pub cancel_rate: f64,
    /// Fraction of requests carrying a `deadline_ms` budget.
    pub deadline_frac: f64,
    /// Target an external front-end instead of the in-process stack.
    pub addr: Option<String>,
    /// Model zoo name for the in-process `SimBackend`.
    pub model: String,
    /// Engine worker lanes (in-process stack).
    pub workers: usize,
    /// Engine `max_batch` (= KV slots per lane here).
    pub max_batch: usize,
    /// Admission queue cap; `None` = unbounded (no 429s).
    pub queue_cap: Option<usize>,
    /// Serving window the workload is clamped to.
    pub prefill_len: usize,
    /// KV capacity the workload is clamped to.
    pub max_seq: usize,
    /// Recorded in the artifact so CI smoke runs are recognizable.
    pub smoke: bool,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            seed: 0x54A7,
            requests: 64,
            rate_rps: 40.0,
            bursty: false,
            on_s: 0.25,
            off_s: 0.25,
            conns: 2,
            cancel_rate: 0.1,
            deadline_frac: 0.1,
            addr: None,
            model: "BitNet-2B-4T".to_string(),
            workers: 2,
            max_batch: 2,
            queue_cap: None,
            prefill_len: 16,
            max_seq: 64,
            smoke: false,
        }
    }
}

impl BenchConfig {
    /// The CI profile: small, fast, and deliberately hostile — bursty
    /// arrivals over a tiny capped queue with cancels and deadlines in
    /// the mix, so every outcome class and every cross-check equation
    /// gets exercised in a second or two.
    pub fn smoke() -> BenchConfig {
        BenchConfig {
            requests: 24,
            rate_rps: 120.0,
            bursty: true,
            workers: 1,
            max_batch: 1,
            queue_cap: Some(2),
            cancel_rate: 0.2,
            deadline_frac: 0.2,
            smoke: true,
            ..BenchConfig::default()
        }
    }

    /// The configured arrival process.
    pub fn arrivals(&self) -> ArrivalProcess {
        if self.bursty {
            ArrivalProcess::Bursty { rate_rps: self.rate_rps, on_s: self.on_s, off_s: self.off_s }
        } else {
            ArrivalProcess::Poisson { rate_rps: self.rate_rps }
        }
    }

    /// The workload spec this config expands to (exposed so tests can
    /// reproduce the exact trace the bench will run).
    pub fn workload_spec(&self, vocab: usize) -> WorkloadSpec {
        let mut spec = WorkloadSpec::for_window(self.prefill_len, self.max_seq, vocab);
        spec.seed = self.seed;
        spec.requests = self.requests;
        spec.conns = self.conns;
        spec.cancel_rate = self.cancel_rate;
        spec.deadline_frac = self.deadline_frac;
        spec.arrivals = self.arrivals();
        spec
    }
}

/// Everything a caller needs beyond the artifact itself.
#[derive(Debug, Clone)]
pub struct BenchOutput {
    /// The full `BENCH_serve.json` document.
    pub artifact: Json,
    /// Did the client's view reconcile against `/metrics`?
    pub agree: bool,
    /// Human-readable cross-check violations (empty when `agree`).
    pub mismatches: Vec<String>,
    /// Client-side outcome totals.
    pub counts: OutcomeCounts,
    /// Wall-clock seconds from first dispatch to last terminal.
    pub wall_s: f64,
}

/// Run the configured bench: against an external front-end when
/// `cfg.addr` is set, otherwise against a freshly-started in-process
/// `SimBackend` serving stack.
pub fn run(cfg: &BenchConfig) -> Result<BenchOutput> {
    if let Some(addr) = &cfg.addr {
        let label = format!("external:{addr}");
        return drive(cfg, addr, &label, EXTERNAL_VOCAB);
    }
    let platform = Platform::by_kind(PlatformKind::Workstation);
    let sim_cfg = SimBackendConfig {
        prefill_len: cfg.prefill_len,
        max_seq: cfg.max_seq,
        threads: 0,
        seed: cfg.seed ^ 0x51AB,
    };
    let backend = SimBackend::by_name(&cfg.model, platform, sim_cfg)?;
    let label = format!("sim:{}", cfg.model);
    run_with_backend(cfg, backend, &label)
}

/// [`run`] with a caller-supplied backend — the injection point the
/// integration tests use to pin slow backends under the stack and
/// deterministically force queue-cap sheds.
pub fn run_with_backend<B>(cfg: &BenchConfig, backend: B, label: &str) -> Result<BenchOutput>
where
    B: Backend + Send + Sync + 'static,
{
    let vocab = backend.config().vocab;
    let (agg_tx, agg_rx) = channel();
    let aggregator = PromAggregator::spawn(agg_rx);
    let counters = aggregator.counters();
    let scfg = ServerConfig {
        max_batch: cfg.max_batch,
        kv_slots: cfg.max_batch,
        workers: cfg.workers,
        queue_cap: cfg.queue_cap,
    };
    let handle = Arc::new(Engine::start_with_sink(backend, scfg, Some(agg_tx))?);
    // Keep-alive load connections pin HTTP workers for their whole
    // lifetime; the extra threads keep cancel POSTs and /metrics
    // scrapes responsive while every connection is streaming.
    let http_cfg = HttpConfig { threads: cfg.conns + 2, ..HttpConfig::default() };
    let http = HttpServer::start("127.0.0.1:0", Arc::clone(&handle), counters, http_cfg)?;
    let addr = http.local_addr().to_string();

    let result = drive(cfg, &addr, label, vocab);

    http.stop();
    let handle = Arc::try_unwrap(handle)
        .map_err(|_| crate::err!("HTTP workers still hold the engine handle"))?;
    let _ = handle.shutdown();
    aggregator.finish();
    result
}

/// The measurement core, front-end-agnostic: plan, scrape, dispatch,
/// re-scrape, reconcile, build the artifact.
fn drive(cfg: &BenchConfig, addr: &str, label: &str, vocab: usize) -> Result<BenchOutput> {
    let workload = cfg.workload_spec(vocab).build()?;
    let before = aggregate::scrape_metrics(addr)?;
    let t0 = Instant::now();
    let timelines = client::run_workload(addr, &workload, t0)?;
    let wall_s = t0.elapsed().as_secs_f64().max(f64::MIN_POSITIVE);
    let counts = aggregate::tally(&timelines);
    let after =
        aggregate::await_retirements(addr, &before, counts.engine_requests(), RETIREMENT_TIMEOUT)?;
    let (agree, mismatches) = aggregate::cross_check(&before, &after, &counts);
    let artifact =
        artifact_json(cfg, label, &workload, &timelines, &counts, agree, &mismatches, wall_s);
    if agree {
        // A reconciled artifact must also satisfy its own schema; a
        // divergent one is still written by the CLI for post-mortems,
        // so it skips the check (`metrics_agree: false` fails it by
        // design for measured artifacts).
        crate::util::artifact::validate_serve(&artifact.to_string())?;
    }
    Ok(BenchOutput { artifact, agree, mismatches, counts, wall_s })
}

fn artifact_json(
    cfg: &BenchConfig,
    label: &str,
    workload: &Workload,
    timelines: &[RequestTimeline],
    counts: &OutcomeCounts,
    agree: bool,
    mismatches: &[String],
    wall_s: f64,
) -> Json {
    let queue_cap = cfg.queue_cap.map_or(Json::Null, |c| Json::Num(c as f64));
    let config = obj(vec![
        ("workers", Json::Num(cfg.workers as f64)),
        ("max_batch", Json::Num(cfg.max_batch as f64)),
        ("conns", Json::Num(cfg.conns as f64)),
        ("queue_cap", queue_cap),
    ]);
    let workload_block = obj(vec![
        ("requests", Json::Num(cfg.requests as f64)),
        ("arrivals", Json::Str(cfg.arrivals().name().to_string())),
        ("rate_rps", Json::Num(cfg.rate_rps)),
        ("trace_fingerprint", Json::Str(workload.fingerprint_hex())),
    ]);
    let outcomes = obj(vec![
        ("completed", Json::Num(counts.completed as f64)),
        ("cancelled", Json::Num(counts.cancelled as f64)),
        ("rejected", Json::Num(counts.rejected as f64)),
        ("failed", Json::Num(counts.failed as f64)),
        ("http_shed", Json::Num(counts.http_shed as f64)),
    ]);
    let tokens = obj(vec![
        ("completed", Json::Num(counts.tokens_completed as f64)),
        ("total", Json::Num(counts.tokens_total as f64)),
    ]);
    let latency = obj(vec![
        ("ttft_s", aggregate::latency_json(&aggregate::ttft_samples(timelines))),
        ("tpot_s", aggregate::latency_json(&aggregate::tpot_samples(timelines))),
        ("e2e_s", aggregate::latency_json(&aggregate::e2e_samples(timelines))),
    ]);
    let cross = obj(vec![
        ("metrics_agree", Json::Bool(agree)),
        ("mismatches", Json::Arr(mismatches.iter().map(|m| Json::Str(m.clone())).collect())),
    ]);
    let shed = (counts.rejected + counts.http_shed) as f64 / counts.requests().max(1) as f64;
    obj(vec![
        ("bench", Json::Str("serve".to_string())),
        ("schema_version", Json::Num(1.0)),
        ("measured", Json::Bool(true)),
        ("smoke", Json::Bool(cfg.smoke)),
        ("seed", Json::Num(cfg.seed as f64)),
        ("backend", Json::Str(label.to_string())),
        ("config", config),
        ("workload", workload_block),
        ("outcomes", outcomes),
        ("tokens", tokens),
        ("latency", latency),
        ("goodput_tok_per_s", Json::Num(counts.tokens_completed as f64 / wall_s)),
        ("shed_rate", Json::Num(shed)),
        ("wall_s", Json::Num(wall_s)),
        ("cross_check", cross),
    ])
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_profile_is_hostile_on_purpose() {
        let smoke = BenchConfig::smoke();
        assert!(smoke.smoke);
        assert!(smoke.bursty);
        assert_eq!(smoke.queue_cap, Some(2));
        assert!(smoke.cancel_rate > 0.0 && smoke.deadline_frac > 0.0);
        assert_eq!(smoke.arrivals().name(), "bursty");
        assert_eq!(BenchConfig::default().arrivals().name(), "poisson");
    }

    #[test]
    fn workload_spec_mirrors_the_config() {
        let cfg = BenchConfig { seed: 99, requests: 7, conns: 3, ..BenchConfig::default() };
        let spec = cfg.workload_spec(500);
        assert_eq!(spec.seed, 99);
        assert_eq!(spec.requests, 7);
        assert_eq!(spec.conns, 3);
        assert_eq!(spec.vocab, 500);
        assert_eq!(spec.arrivals.rate_rps(), cfg.rate_rps);
        // The same config expands to the same fingerprint, twice.
        let a = cfg.workload_spec(500).build().unwrap();
        let b = cfg.workload_spec(500).build().unwrap();
        assert_eq!(a.fingerprint, b.fingerprint);
    }

    #[test]
    fn artifacts_satisfy_their_own_schema() {
        let cfg = BenchConfig::default();
        let workload = cfg.workload_spec(100).build().unwrap();
        let counts = OutcomeCounts { completed: 64, tokens_total: 10, ..Default::default() };
        let json = artifact_json(&cfg, "sim:test", &workload, &[], &counts, true, &[], 1.5);
        assert_eq!(json.get("measured"), Some(&Json::Bool(true)));
        assert_eq!(json.get("bench").and_then(Json::as_str), Some("serve"));
        crate::util::artifact::validate_serve(&json.to_string()).unwrap();
    }
}
