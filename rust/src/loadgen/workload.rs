//! Deterministic workload planning: expand a seeded [`WorkloadSpec`]
//! into the full per-request trace ([`PlannedRequest`] list) before any
//! traffic flows.
//!
//! Planning everything up front — arrival times, prompt tokens, token
//! budgets, deadlines, cancellation points, connection assignment —
//! makes the workload a pure function of the spec: the same seed
//! replays the byte-identical trace, and the FNV-1a fingerprint over
//! the expanded plan ([`Workload::fingerprint`]) gives runs a stable
//! identity the bench artifact records (`workload.trace_fingerprint`).
//!
//! Prompt and output lengths are clamped to the serving window
//! ([`WorkloadSpec::for_window`]): every planned request satisfies
//! `prompt_len <= prefill_len` and `prompt_len + max_new <= max_seq`,
//! so admission *validation* never rejects — the only engine-side
//! rejections left are queue-cap sheds, which keeps the Prometheus
//! cross-check equations exact (see [`super::aggregate`]).

use crate::util::rng::Rng;

use super::arrivals::ArrivalProcess;

/// Everything the generator needs to know to plan a run.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// PRNG seed; the whole trace is a pure function of the spec.
    pub seed: u64,
    /// Total requests in the trace.
    pub requests: usize,
    /// Arrival-time process (see [`ArrivalProcess`]).
    pub arrivals: ArrivalProcess,
    /// Keep-alive client connections the trace is striped across.
    pub conns: usize,
    /// Short-prompt length range, inclusive.
    pub prompt_short: (usize, usize),
    /// Long-prompt length range, inclusive.
    pub prompt_long: (usize, usize),
    /// Fraction of requests drawing from the long-prompt range.
    pub long_frac: f64,
    /// `max_new_tokens` range, inclusive.
    pub max_new: (usize, usize),
    /// Fraction of requests that schedule a mid-stream cancellation.
    pub cancel_rate: f64,
    /// Events (prefill + decode tokens) observed before the scheduled
    /// cancel fires, inclusive range.
    pub cancel_after: (usize, usize),
    /// Fraction of requests carrying a `deadline_ms` budget.
    pub deadline_frac: f64,
    /// Deadline range in milliseconds, inclusive.
    pub deadline_ms: (u64, u64),
    /// Vocabulary size prompt tokens are drawn from.
    pub vocab: usize,
}

impl WorkloadSpec {
    /// Defaults clamped to a serving window: prompts never exceed
    /// `prefill_len` and `prompt + max_new` never exceeds `max_seq`,
    /// so the engine's admission validation accepts every request.
    pub fn for_window(prefill_len: usize, max_seq: usize, vocab: usize) -> WorkloadSpec {
        assert!(max_seq > prefill_len, "max_seq must exceed the prefill window");
        let short_hi = (prefill_len / 4).max(1);
        let long_lo = (prefill_len / 2).max(1);
        WorkloadSpec {
            seed: 0,
            requests: 32,
            arrivals: ArrivalProcess::Poisson { rate_rps: 50.0 },
            conns: 2,
            prompt_short: (1, short_hi),
            prompt_long: (long_lo, prefill_len),
            long_frac: 0.3,
            max_new: (1, (max_seq - prefill_len).max(1)),
            cancel_rate: 0.0,
            cancel_after: (2, 6),
            deadline_frac: 0.0,
            deadline_ms: (5, 50),
            vocab,
        }
    }

    /// Expand the spec into the full deterministic trace.
    pub fn build(&self) -> crate::Result<Workload> {
        crate::ensure!(self.requests >= 1, "workload needs at least one request");
        crate::ensure!(self.conns >= 1, "workload needs at least one connection");
        crate::ensure!(self.vocab >= 1, "vocab must be at least 1");
        for (name, (lo, hi)) in [
            ("prompt_short", self.prompt_short),
            ("prompt_long", self.prompt_long),
            ("max_new", self.max_new),
            ("cancel_after", self.cancel_after),
        ] {
            crate::ensure!(1 <= lo && lo <= hi, "bad {name} range [{lo}, {hi}]");
        }
        crate::ensure!(self.deadline_ms.0 <= self.deadline_ms.1, "bad deadline_ms range");
        for (name, frac) in [
            ("long_frac", self.long_frac),
            ("cancel_rate", self.cancel_rate),
            ("deadline_frac", self.deadline_frac),
        ] {
            crate::ensure!((0.0..=1.0).contains(&frac), "{name} must be in [0, 1], got {frac}");
        }

        let mut rng = Rng::new(self.seed);
        let arrivals = self.arrivals.schedule(&mut rng, self.requests);
        let requests: Vec<PlannedRequest> = arrivals
            .into_iter()
            .enumerate()
            .map(|(index, arrival_s)| {
                let (lo, hi) = if rng.f64() < self.long_frac {
                    self.prompt_long
                } else {
                    self.prompt_short
                };
                let plen = rng.range_i64(lo as i64, hi as i64) as usize;
                let prompt: Vec<i32> =
                    (0..plen).map(|_| rng.below(self.vocab as u64) as i32).collect();
                let max_new_tokens =
                    rng.range_i64(self.max_new.0 as i64, self.max_new.1 as i64) as usize;
                let cancel_after_events = if rng.f64() < self.cancel_rate {
                    let (lo, hi) = self.cancel_after;
                    Some(rng.range_i64(lo as i64, hi as i64) as usize)
                } else {
                    None
                };
                let deadline_ms = if rng.f64() < self.deadline_frac {
                    let (lo, hi) = self.deadline_ms;
                    Some(rng.range_i64(lo as i64, hi as i64) as u64)
                } else {
                    None
                };
                PlannedRequest {
                    index,
                    arrival_s,
                    prompt,
                    max_new_tokens,
                    deadline_ms,
                    cancel_after_events,
                    conn: index % self.conns,
                }
            })
            .collect();
        let fingerprint = fingerprint(self.seed, &requests);
        Ok(Workload { requests, fingerprint })
    }
}

/// One fully-planned request of the trace.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedRequest {
    /// Position in the trace (stable across the whole run).
    pub index: usize,
    /// Scheduled dispatch time, seconds from the run start.
    pub arrival_s: f64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Token budget sent as `max_new_tokens`.
    pub max_new_tokens: usize,
    /// Optional `deadline_ms` budget attached to the request body.
    pub deadline_ms: Option<u64>,
    /// Cancel via `POST /v1/cancel` after observing this many stream
    /// events (the prefill line counts as the first).
    pub cancel_after_events: Option<usize>,
    /// Keep-alive connection this request is dispatched on.
    pub conn: usize,
}

/// The expanded trace plus its identity fingerprint.
#[derive(Debug, Clone)]
pub struct Workload {
    pub requests: Vec<PlannedRequest>,
    /// FNV-1a over every planned field; equal specs produce equal
    /// fingerprints on every platform.
    pub fingerprint: u64,
}

impl Workload {
    /// The fingerprint as the artifact's `trace_fingerprint` string.
    pub fn fingerprint_hex(&self) -> String {
        format!("0x{:016x}", self.fingerprint)
    }
}

/// FNV-1a (64-bit) folded over one little-endian u64.
fn fold(mut h: u64, x: u64) -> u64 {
    for b in x.to_le_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01B3);
    }
    h
}

/// Fold an optional value as a presence flag plus payload, so
/// `Some(0)` and `None` hash differently.
fn fold_opt(h: u64, v: Option<u64>) -> u64 {
    match v {
        Some(x) => fold(fold(h, 1), x),
        None => fold(h, 0),
    }
}

fn fingerprint(seed: u64, requests: &[PlannedRequest]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    h = fold(h, seed);
    h = fold(h, requests.len() as u64);
    for r in requests {
        h = fold(h, r.arrival_s.to_bits());
        h = fold(h, r.prompt.len() as u64);
        for &t in &r.prompt {
            h = fold(h, t as u64);
        }
        h = fold(h, r.max_new_tokens as u64);
        h = fold_opt(h, r.deadline_ms);
        h = fold_opt(h, r.cancel_after_events.map(|c| c as u64));
        h = fold(h, r.conn as u64);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> WorkloadSpec {
        let mut spec = WorkloadSpec::for_window(16, 64, 1000);
        spec.seed = 0x5EED;
        spec.requests = 64;
        spec.conns = 3;
        spec.cancel_rate = 0.25;
        spec.deadline_frac = 0.25;
        spec
    }

    #[test]
    fn same_seed_reproduces_the_exact_trace() {
        let a = spec().build().unwrap();
        let b = spec().build().unwrap();
        assert_eq!(a.requests, b.requests);
        assert_eq!(a.fingerprint, b.fingerprint);
        assert!(a.fingerprint_hex().starts_with("0x"));
        assert_eq!(a.fingerprint_hex().len(), 18);
    }

    #[test]
    fn different_seeds_change_the_fingerprint() {
        let a = spec().build().unwrap();
        let mut other = spec();
        other.seed ^= 1;
        let b = other.build().unwrap();
        assert_ne!(a.fingerprint, b.fingerprint);
    }

    #[test]
    fn window_clamping_means_validation_never_rejects() {
        let (prefill_len, max_seq) = (16, 64);
        let workload = spec().build().unwrap();
        for r in &workload.requests {
            assert!(!r.prompt.is_empty());
            assert!(r.prompt.len() <= prefill_len, "prompt overflows the prefill window");
            assert!(
                r.prompt.len() + r.max_new_tokens <= max_seq,
                "prompt + budget overflows KV capacity"
            );
            assert!(r.prompt.iter().all(|&t| (0..1000).contains(&t)));
        }
    }

    #[test]
    fn connections_are_striped_round_robin() {
        let workload = spec().build().unwrap();
        for r in &workload.requests {
            assert_eq!(r.conn, r.index % 3);
        }
    }

    #[test]
    fn rate_fractions_pin_the_optional_fields() {
        let mut all = spec();
        all.cancel_rate = 1.0;
        all.deadline_frac = 1.0;
        let workload = all.build().unwrap();
        assert!(workload.requests.iter().all(|r| r.cancel_after_events.is_some()));
        assert!(workload.requests.iter().all(|r| r.deadline_ms.is_some()));

        let mut none = spec();
        none.cancel_rate = 0.0;
        none.deadline_frac = 0.0;
        let workload = none.build().unwrap();
        assert!(workload.requests.iter().all(|r| r.cancel_after_events.is_none()));
        assert!(workload.requests.iter().all(|r| r.deadline_ms.is_none()));
    }

    #[test]
    fn bad_specs_are_rejected() {
        let mut zero = spec();
        zero.requests = 0;
        assert!(zero.build().is_err());
        let mut frac = spec();
        frac.cancel_rate = 1.5;
        assert!(frac.build().is_err());
        let mut range = spec();
        range.max_new = (5, 2);
        assert!(range.build().is_err());
    }
}
