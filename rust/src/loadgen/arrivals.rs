//! Arrival-time processes of the open-loop load generator.
//!
//! Open-loop means the generator dispatches each request at its
//! pre-scheduled arrival time regardless of how many earlier requests
//! are still in flight — the load does not slow down when the server
//! saturates, which is exactly the regime where queueing, shedding and
//! tail latency show up.  Two processes are modeled:
//!
//! * **Poisson** — i.i.d. exponential inter-arrival times at a fixed
//!   rate, the classic memoryless baseline.
//! * **Bursty** — an on/off Markov-modulated Poisson process: the
//!   source alternates between exponentially-held ON periods (arrivals
//!   at an elevated rate) and OFF periods (silence).  The ON rate is
//!   scaled by the duty cycle so the *long-run* average rate equals
//!   the requested `rate_rps`, making Poisson and bursty runs at the
//!   same nominal rate directly comparable.
//!
//! Schedules are drawn from the deterministic in-tree PRNG
//! ([`crate::util::rng::Rng`]), so a fixed seed reproduces the exact
//! same arrival trace on every platform.

use crate::util::rng::Rng;

/// The arrival process shaping the request trace.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at `rate_rps` requests per second.
    Poisson { rate_rps: f64 },
    /// On/off modulated arrivals averaging `rate_rps` over the long
    /// run: ON periods (mean `on_s` seconds) emit at the elevated rate
    /// `rate_rps / duty`, OFF periods (mean `off_s` seconds) are
    /// silent.
    Bursty { rate_rps: f64, on_s: f64, off_s: f64 },
}

impl ArrivalProcess {
    /// Stable name used in the bench artifact (`workload.arrivals`).
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
        }
    }

    /// The nominal long-run arrival rate in requests per second.
    pub fn rate_rps(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate_rps } | ArrivalProcess::Bursty { rate_rps, .. } => {
                *rate_rps
            }
        }
    }

    /// Draw `n` absolute arrival times (seconds from the run start,
    /// strictly increasing).  Deterministic given the PRNG state.
    pub fn schedule(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        match *self {
            ArrivalProcess::Poisson { rate_rps } => {
                assert!(rate_rps > 0.0, "poisson rate must be positive");
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += rng.exp(rate_rps);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Bursty { rate_rps, on_s, off_s } => {
                assert!(rate_rps > 0.0, "bursty rate must be positive");
                assert!(on_s > 0.0 && off_s > 0.0, "on/off holding times must be positive");
                let duty = on_s / (on_s + off_s);
                let rate_on = rate_rps / duty;
                let mut out = Vec::with_capacity(n);
                let mut t = 0.0;
                // The source starts a fresh ON period at t = 0; holding
                // times are exponential with the configured means.
                let mut on_until = rng.exp(1.0 / on_s);
                while out.len() < n {
                    let dt = rng.exp(rate_on);
                    if t + dt <= on_until {
                        t += dt;
                        out.push(t);
                    } else {
                        // The ON period expired before the next arrival
                        // landed: jump over an OFF period and start the
                        // next burst.
                        t = on_until + rng.exp(1.0 / off_s);
                        on_until = t + rng.exp(1.0 / on_s);
                    }
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_are_deterministic_and_increasing() {
        for process in [
            ArrivalProcess::Poisson { rate_rps: 80.0 },
            ArrivalProcess::Bursty { rate_rps: 80.0, on_s: 0.2, off_s: 0.3 },
        ] {
            let a = process.schedule(&mut Rng::new(7), 200);
            let b = process.schedule(&mut Rng::new(7), 200);
            assert_eq!(a, b, "{} schedule must be seed-deterministic", process.name());
            assert!(a.windows(2).all(|w| w[0] < w[1]), "arrival times must increase");
            assert!(a[0] > 0.0);
        }
    }

    #[test]
    fn poisson_hits_the_nominal_rate() {
        let process = ArrivalProcess::Poisson { rate_rps: 200.0 };
        let times = process.schedule(&mut Rng::new(11), 4000);
        let rate = times.len() as f64 / times.last().unwrap();
        assert!((rate - 200.0).abs() / 200.0 < 0.1, "empirical rate {rate}");
    }

    #[test]
    fn bursty_hits_the_nominal_rate_with_gaps() {
        let process = ArrivalProcess::Bursty { rate_rps: 100.0, on_s: 0.5, off_s: 0.5 };
        let times = process.schedule(&mut Rng::new(13), 4000);
        let rate = times.len() as f64 / times.last().unwrap();
        assert!((rate - 100.0).abs() / 100.0 < 0.2, "long-run rate {rate}");
        // The trace must actually be bursty: OFF periods leave gaps far
        // beyond the ON-rate mean inter-arrival time (1/200 s).
        let max_gap = times.windows(2).map(|w| w[1] - w[0]).fold(0.0f64, f64::max);
        assert!(max_gap > 0.05, "no OFF-period gap in the trace (max gap {max_gap})");
    }

    #[test]
    fn names_and_rates_round_trip() {
        let p = ArrivalProcess::Poisson { rate_rps: 3.5 };
        assert_eq!(p.name(), "poisson");
        assert_eq!(p.rate_rps(), 3.5);
        let b = ArrivalProcess::Bursty { rate_rps: 9.0, on_s: 1.0, off_s: 2.0 };
        assert_eq!(b.name(), "bursty");
        assert_eq!(b.rate_rps(), 9.0);
    }
}
