//! The backend abstraction the serving stack is written against
//! (DESIGN.md §3): everything the coordinator needs from a loaded model
//! — the prefill/decode step surface plus the per-sequence KV state it
//! threads between steps.
//!
//! Two implementations ship in-tree:
//!
//! * [`super::SimBackend`] (default build) — routes token steps through
//!   the `model` shape tables, the §III-D adaptive kernel selector and
//!   the `sim` timing engine, so serving latency numbers stay
//!   paper-faithful without any external runtime.
//! * [`super::ModelRuntime`] (`--features pjrt`) — the PJRT CPU client
//!   executing the AOT HLO-text artifacts built by
//!   `python/compile/aot.py`.

use crate::util::error::Result;

use super::manifest::ModelConfig;

/// One prefill/decode step's result, generic over the backend's
/// KV-cache representation.
pub struct Step<C> {
    pub next_token: i32,
    pub cache: C,
    /// Simulated execution seconds for this step, when the backend
    /// *models* time instead of spending it ([`super::SimBackend`]).
    /// Real backends return `None` and the server falls back to
    /// wall-clock timing.
    ///
    /// For a step produced by [`Backend::decode_batch`], this is the
    /// step's *share* of the whole round's cost (the round total is the
    /// sum over the returned steps), so schedulers can account rounds
    /// and single steps uniformly.
    pub cost_s: Option<f64>,
}

/// One sequence's slice of a batched decode round: the freshly sampled
/// token to feed, its position, and a borrow of the sequence's KV state.
pub struct BatchItem<'a, C> {
    pub token: i32,
    pub pos: i32,
    pub cache: &'a C,
}

/// A loaded model a worker lane can drive: prefill/decode steps over
/// explicit per-sequence KV state, batch-1 or as whole batched decode
/// rounds ([`Backend::decode_batch`]).  All methods take `&self`, so one
/// backend instance can be shared across worker lanes.
pub trait Backend {
    /// Per-sequence KV state threaded between steps by the scheduler.
    type Cache;

    /// Architecture + serving-window description of the loaded model.
    fn config(&self) -> &ModelConfig;

    /// Human-readable identity (model/variant) for logs.
    fn describe(&self) -> String;

    /// Run prefill over a padded prompt. `tokens` must have exactly
    /// `config().prefill_len` entries; `prompt_len` is the real prompt
    /// length (padding beyond it must not affect the result).
    fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<Step<Self::Cache>>;

    /// One greedy decode step: feed `token` at position `pos` against
    /// `cache`, producing the next token and the successor cache.
    fn decode(&self, token: i32, pos: i32, cache: &Self::Cache) -> Result<Step<Self::Cache>>;

    /// One decode round over a whole batch of sequences, returning one
    /// step per item in order.
    ///
    /// The default implementation serializes batch-1 [`Backend::decode`]
    /// calls, so every backend supports the batched surface; backends
    /// that can vectorize the batch dimension (a multi-batch AOT
    /// executable, or [`super::SimBackend`]'s contention-aware round
    /// costing) override it.  Tokens must be identical to what the
    /// serialized path produces — batching is a cost/throughput
    /// optimization, never a semantic one.
    fn decode_batch(
        &self,
        reqs: &[BatchItem<'_, Self::Cache>],
    ) -> Result<Vec<Step<Self::Cache>>> {
        let mut steps = Vec::with_capacity(reqs.len());
        for r in reqs {
            steps.push(self.decode(r.token, r.pos, r.cache)?);
        }
        Ok(steps)
    }

    /// Compact description of the kernel plan this backend decodes with
    /// (for request-level metrics records).  Backends that execute for
    /// real and have no modeled plan return `None`.
    fn plan_summary(&self) -> Option<String> {
        None
    }

    /// Greedy generation: prefill + `n_new - 1` decode steps.
    fn generate(&self, prompt: &[i32], n_new: usize) -> Result<Vec<i32>> {
        self.generate_until(prompt, n_new, &[])
    }

    /// Greedy generation honoring a stop-token set: prefill + up to
    /// `n_new - 1` decode steps, returning early (stop token included
    /// in the output) as soon as any of `stop` is emitted.  This is
    /// the batch-1 reference for the serving engine's per-request
    /// generation parameters — streamed tokens of a non-cancelled
    /// ticket are bit-identical to this.
    fn generate_until(&self, prompt: &[i32], n_new: usize, stop: &[i32]) -> Result<Vec<i32>> {
        let p = self.config().prefill_len;
        crate::ensure!(prompt.len() <= p, "prompt longer than prefill window");
        crate::ensure!(n_new >= 1, "n_new must be >= 1");
        let mut padded = vec![0i32; p];
        padded[..prompt.len()].copy_from_slice(prompt);
        let step = self.prefill(&padded, prompt.len() as i32)?;
        let mut toks = vec![step.next_token];
        if stop.contains(&step.next_token) {
            return Ok(toks);
        }
        let mut cache = step.cache;
        let mut pos = prompt.len() as i32;
        for _ in 1..n_new {
            crate::ensure!(
                (pos as usize) < self.config().max_seq,
                "KV cache exhausted"
            );
            let s = self.decode(*toks.last().unwrap(), pos, &cache)?;
            toks.push(s.next_token);
            cache = s.cache;
            pos += 1;
            if stop.contains(toks.last().unwrap()) {
                break;
            }
        }
        Ok(toks)
    }
}
