//! Typed view of `artifacts/manifest.json` (produced by aot.py).

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => crate::bail!("unsupported dtype {other:?}"),
        }
    }

    pub fn bytes(&self) -> usize {
        4
    }
}

/// One packed parameter tensor in weights.bin.
#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub offset: usize,
    pub nbytes: usize,
}

impl ParamMeta {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One dynamic (non-parameter) argument of an entrypoint.
#[derive(Debug, Clone)]
pub struct DynArg {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Debug, Clone)]
pub struct EntryPoint {
    pub hlo: String,
    pub dynamic_args: Vec<DynArg>,
    pub param_args: Vec<String>,
    pub outputs: Vec<String>,
}

/// The model architecture the artifacts were built for.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn_dim: usize,
    pub max_seq: usize,
    pub prefill_len: usize,
}

impl ModelConfig {
    /// Admission-time validation of one generation request against this
    /// model's serving window: the prompt must be non-empty and fit the
    /// prefill window, the budget must be at least one token, and
    /// `prompt_len + max_new_tokens` must fit the KV capacity
    /// (`max_seq`) — rejecting at submit time what would otherwise
    /// error mid-decode with "KV cache exhausted".
    pub fn validate_request(
        &self,
        prompt_len: usize,
        max_new_tokens: usize,
    ) -> crate::util::error::Result<()> {
        crate::ensure!(prompt_len >= 1, "empty prompt");
        crate::ensure!(
            prompt_len <= self.prefill_len,
            "prompt of {prompt_len} tokens exceeds the prefill window ({})",
            self.prefill_len
        );
        crate::ensure!(max_new_tokens >= 1, "max_new_tokens must be >= 1");
        crate::ensure!(
            prompt_len + max_new_tokens <= self.max_seq,
            "prompt ({prompt_len}) + max_new_tokens ({max_new_tokens}) exceeds the KV \
             capacity (max_seq = {})",
            self.max_seq
        );
        Ok(())
    }
}

/// Golden generation recorded by aot.py (ref path, greedy).
#[derive(Debug, Clone)]
pub struct Golden {
    pub prompt: Vec<i32>,
    pub prompt_len: usize,
    pub padded_prompt: Vec<i32>,
    pub tokens: Vec<i32>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub config_name: String,
    pub config: ModelConfig,
    pub seed: u64,
    pub weights_bin: String,
    pub params: Vec<ParamMeta>,
    pub entrypoints: BTreeMap<String, EntryPoint>,
    pub golden: Golden,
    by_name: BTreeMap<String, usize>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| crate::err!("{e}"))?;

        let cfg = j.req("config")?;
        let usize_of = |node: &Json, key: &str| -> Result<usize> {
            node.req(key)?
                .as_usize()
                .with_context(|| format!("{key} not a number"))
        };
        let config = ModelConfig {
            vocab: usize_of(cfg, "vocab")?,
            d_model: usize_of(cfg, "d_model")?,
            n_layers: usize_of(cfg, "n_layers")?,
            n_heads: usize_of(cfg, "n_heads")?,
            ffn_dim: usize_of(cfg, "ffn_dim")?,
            max_seq: usize_of(cfg, "max_seq")?,
            prefill_len: usize_of(cfg, "prefill_len")?,
        };

        let mut params = Vec::new();
        for p in j.req("params")?.as_arr().context("params not array")? {
            params.push(ParamMeta {
                name: p.req("name")?.as_str().context("name")?.to_string(),
                shape: p
                    .req("shape")?
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
                dtype: DType::parse(p.req("dtype")?.as_str().context("dtype")?)?,
                offset: usize_of(p, "offset")?,
                nbytes: usize_of(p, "nbytes")?,
            });
        }
        let by_name = params
            .iter()
            .enumerate()
            .map(|(i, p)| (p.name.clone(), i))
            .collect();

        let mut entrypoints = BTreeMap::new();
        for (name, ep) in j.req("entrypoints")?.as_obj().context("entrypoints")? {
            let mut dynamic_args = Vec::new();
            for a in ep.req("dynamic_args")?.as_arr().context("dynamic_args")? {
                dynamic_args.push(DynArg {
                    name: a.req("name")?.as_str().context("name")?.to_string(),
                    shape: a
                        .req("shape")?
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(0))
                        .collect(),
                    dtype: DType::parse(a.req("dtype")?.as_str().context("dtype")?)?,
                });
            }
            let str_list = |key: &str| -> Result<Vec<String>> {
                Ok(ep
                    .req(key)?
                    .as_arr()
                    .with_context(|| format!("{key} not array"))?
                    .iter()
                    .filter_map(|x| x.as_str().map(str::to_string))
                    .collect())
            };
            entrypoints.insert(
                name.clone(),
                EntryPoint {
                    hlo: ep.req("hlo")?.as_str().context("hlo")?.to_string(),
                    dynamic_args,
                    param_args: str_list("param_args")?,
                    outputs: str_list("outputs")?,
                },
            );
        }

        let g = j.req("golden")?;
        let i32_list = |key: &str| -> Result<Vec<i32>> {
            Ok(g.req(key)?
                .as_arr()
                .with_context(|| format!("{key} not array"))?
                .iter()
                .filter_map(|x| x.as_f64().map(|v| v as i32))
                .collect())
        };
        let golden = Golden {
            prompt: i32_list("prompt")?,
            prompt_len: usize_of(g, "prompt_len")?,
            padded_prompt: i32_list("padded_prompt")?,
            tokens: i32_list("tokens")?,
        };

        Ok(Manifest {
            config_name: j
                .req("config_name")?
                .as_str()
                .context("config_name")?
                .to_string(),
            config,
            seed: j.req("seed")?.as_f64().context("seed")? as u64,
            weights_bin: j
                .req("weights_bin")?
                .as_str()
                .context("weights_bin")?
                .to_string(),
            params,
            entrypoints,
            golden,
            by_name,
        })
    }

    pub fn param(&self, name: &str) -> Result<&ParamMeta> {
        self.by_name
            .get(name)
            .map(|&i| &self.params[i])
            .with_context(|| format!("unknown param {name:?}"))
    }

    pub fn entrypoint(&self, name: &str) -> Result<&EntryPoint> {
        self.entrypoints
            .get(name)
            .with_context(|| format!("unknown entrypoint {name:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config_name": "micro",
      "config": {"vocab": 128, "d_model": 64, "n_layers": 2, "n_heads": 2,
                 "ffn_dim": 128, "max_seq": 48, "prefill_len": 8,
                 "rope_theta": 10000.0, "c": 2, "norm_eps": 1e-5},
      "seed": 0,
      "weights_bin": "weights.bin",
      "params": [
        {"name": "embed", "shape": [128, 64], "dtype": "f32",
         "offset": 0, "nbytes": 32768}
      ],
      "entrypoints": {
        "decode_ref": {
          "hlo": "decode_ref.hlo.txt",
          "dynamic_args": [
            {"name": "token", "shape": [], "dtype": "i32"},
            {"name": "pos", "shape": [], "dtype": "i32"}
          ],
          "param_args": ["embed"],
          "outputs": ["next_token", "k_cache", "v_cache"]
        }
      },
      "golden": {"prompt": [1, 8], "prompt_len": 2,
                 "padded_prompt": [1, 8, 0, 0, 0, 0, 0, 0],
                 "tokens": [5, 9, 3]}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config.d_model, 64);
        assert_eq!(m.param("embed").unwrap().elem_count(), 128 * 64);
        let ep = m.entrypoint("decode_ref").unwrap();
        assert_eq!(ep.dynamic_args.len(), 2);
        assert_eq!(ep.param_args, vec!["embed"]);
        assert_eq!(m.golden.tokens, vec![5, 9, 3]);
    }

    #[test]
    fn unknown_lookups_fail() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.param("nope").is_err());
        assert!(m.entrypoint("nope").is_err());
    }

    #[test]
    fn scalar_param_elem_count() {
        let p = ParamMeta {
            name: "s".into(),
            shape: vec![],
            dtype: DType::F32,
            offset: 0,
            nbytes: 4,
        };
        assert_eq!(p.elem_count(), 1);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if path.exists() {
            let m = Manifest::load(&path).unwrap();
            assert!(m.entrypoints.contains_key("prefill_tsar"));
            assert!(!m.params.is_empty());
        }
    }
}
