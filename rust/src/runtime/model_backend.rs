//! The real-model backend: `tsar-cli serve --backend model`.
//!
//! Unlike [`super::SimBackend`] / [`super::NativeBackend`] — whose
//! token *values* come from a synthetic seeded stream — every step
//! here samples from logits produced by the checkpoint-loaded
//! [`TernaryTransformer`] forward pass, with per-sequence KV state
//! that is a real per-layer key/value cache rather than a token
//! history.  Steps report `cost_s: None`, so the serving lanes time
//! real wall-clock execution.
//!
//! Determinism contracts the serving tests pin
//! (`tests/model_serve.rs`):
//!
//! * greedy decoding is a pure function of the prompt — worker count,
//!   batching, and scheduling order cannot change a sequence's tokens
//!   (sampling re-seeds per step from the token history);
//! * [`Backend::decode_batch`] routes whole rounds through
//!   [`TernaryTransformer::decode_round`] (one n-row GEMM per
//!   BitLinear site) and produces per-sequence tokens *and KV state*
//!   bit-identical to the serialized default path.

use crate::config::Platform;
use crate::model::checkpoint::Checkpoint;
use crate::model::sample::{sample_token, SamplerConfig};
use crate::model::transformer::{LinearEngine, ModelKv, TernaryTransformer};
use crate::util::error::Result;

use super::backend::{Backend, BatchItem, Step};
use super::manifest::ModelConfig;

/// Serving-window + sampling parameters of a [`ModelBackend`].
#[derive(Debug, Clone, Copy)]
pub struct ModelBackendConfig {
    /// Padded prompt window.
    pub prefill_len: usize,
    /// KV capacity in tokens.
    pub max_seq: usize,
    /// Sampling behaviour (greedy by default).
    pub sampler: SamplerConfig,
}

impl Default for ModelBackendConfig {
    fn default() -> Self {
        ModelBackendConfig {
            prefill_len: 32,
            max_seq: 160,
            sampler: SamplerConfig::greedy(),
        }
    }
}

/// Per-sequence state: the transformer's layer KV cache plus the token
/// history (prompt + everything fed so far) that seeds the sampler.
#[derive(Debug, Clone)]
pub struct ModelKvCache {
    pub(crate) kv: ModelKv,
    pub(crate) history: Vec<i32>,
}

impl ModelKvCache {
    /// Cached positions (tokens the model has consumed).
    pub fn len(&self) -> usize {
        self.kv.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kv.is_empty()
    }
}

/// [`Backend`] over the real ternary forward pass.
pub struct ModelBackend {
    model: TernaryTransformer,
    config: ModelConfig,
    sampler: SamplerConfig,
    ckpt_seed: u64,
    /// Platform profile attached for labeling (`serve --platform`):
    /// execution is on this host, but reports name the profile and its
    /// provenance.
    profile: Platform,
}

impl ModelBackend {
    /// Load `ckpt` for `engine` and wrap it in the serving window of
    /// `cfg`.
    pub fn new(
        ckpt: &Checkpoint,
        engine: LinearEngine,
        cfg: ModelBackendConfig,
    ) -> Result<ModelBackend> {
        crate::ensure!(cfg.prefill_len >= 1, "prefill window must be at least 1");
        crate::ensure!(
            cfg.max_seq > cfg.prefill_len,
            "max_seq must exceed the prefill window"
        );
        let model = TernaryTransformer::from_checkpoint(ckpt, engine)?;
        let c = model.config();
        let config = ModelConfig {
            vocab: c.vocab,
            d_model: c.d_model,
            n_layers: c.n_layers,
            n_heads: c.n_heads,
            ffn_dim: c.ffn_dim,
            max_seq: cfg.max_seq,
            prefill_len: cfg.prefill_len,
        };
        Ok(ModelBackend {
            model,
            config,
            sampler: cfg.sampler,
            ckpt_seed: ckpt.seed,
            profile: Platform::workstation(),
        })
    }

    /// Attach the platform profile named by `serve --platform`: surfaces
    /// its name and provenance in `plan_summary` and the per-request
    /// records (the forward pass still runs on this host).
    pub fn with_profile(mut self, profile: Platform) -> ModelBackend {
        self.profile = profile;
        self
    }

    pub fn model(&self) -> &TernaryTransformer {
        &self.model
    }

    pub fn sampler(&self) -> &SamplerConfig {
        &self.sampler
    }

    /// Weight bytes held in the engine's execution layout.
    pub fn weight_bytes(&self) -> usize {
        self.model.weight_bytes()
    }

    fn sample(&self, logits: &[f32], history: &[i32]) -> i32 {
        sample_token(&self.sampler, logits, history)
    }
}

impl Backend for ModelBackend {
    type Cache = ModelKvCache;

    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn describe(&self) -> String {
        let c = self.model.config();
        let mode = if self.sampler.is_greedy() {
            "greedy".to_string()
        } else {
            format!("t={} k={}", self.sampler.temperature, self.sampler.top_k)
        };
        format!(
            "model:ckpt(seed={:#x}) L={} d={} heads={}/{} ffn={} vocab={} via {} ({mode})",
            self.ckpt_seed,
            c.n_layers,
            c.d_model,
            c.n_heads,
            c.n_kv_heads,
            c.ffn_dim,
            c.vocab,
            self.model.engine().name()
        )
    }

    fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<Step<ModelKvCache>> {
        let p = self.config.prefill_len;
        crate::ensure!(tokens.len() == p, "expected {p} padded tokens");
        crate::ensure!(
            prompt_len >= 1 && prompt_len as usize <= p,
            "prompt_len {prompt_len} outside the prefill window"
        );
        let history: Vec<i32> = tokens[..prompt_len as usize].to_vec();
        let mut kv = self.model.new_kv();
        let logits = self.model.forward(&history, &mut kv)?;
        let next_token = self.sample(&logits, &history);
        Ok(Step {
            next_token,
            cache: ModelKvCache { kv, history },
            cost_s: None, // real backend: the lane measures wall-clock
        })
    }

    fn decode(&self, token: i32, pos: i32, cache: &ModelKvCache) -> Result<Step<ModelKvCache>> {
        crate::ensure!(
            (pos as usize) < self.config.max_seq,
            "KV cache exhausted at pos {pos}"
        );
        crate::ensure!(
            pos as usize == cache.kv.len(),
            "decode pos {pos} does not match the {} cached positions",
            cache.kv.len()
        );
        let mut kv = cache.kv.clone();
        let logits = self.model.forward(&[token], &mut kv)?;
        let mut history = cache.history.clone();
        history.push(token);
        let next_token = self.sample(&logits, &history);
        Ok(Step {
            next_token,
            cache: ModelKvCache { kv, history },
            cost_s: None,
        })
    }

    /// One real batched round: all sequences' activation rows stack
    /// into one n-row GEMM per BitLinear site
    /// ([`TernaryTransformer::decode_round`]).  Tokens and successor
    /// KV state are bit-identical to the serialized default path —
    /// `tests/model_serve.rs` carries the interleaved-batch-width
    /// regression test.
    fn decode_batch(
        &self,
        reqs: &[BatchItem<'_, ModelKvCache>],
    ) -> Result<Vec<Step<ModelKvCache>>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let mut tokens = Vec::with_capacity(reqs.len());
        let mut kvs = Vec::with_capacity(reqs.len());
        for r in reqs {
            crate::ensure!(
                (r.pos as usize) < self.config.max_seq,
                "KV cache exhausted at pos {}",
                r.pos
            );
            crate::ensure!(
                r.pos as usize == r.cache.kv.len(),
                "decode pos {} does not match the {} cached positions",
                r.pos,
                r.cache.kv.len()
            );
            tokens.push(r.token);
            kvs.push(r.cache.kv.clone());
        }
        let all_logits = self.model.decode_round(&tokens, &mut kvs)?;
        let mut steps = Vec::with_capacity(reqs.len());
        for ((r, kv), logits) in reqs.iter().zip(kvs).zip(&all_logits) {
            let mut history = r.cache.history.clone();
            history.push(r.token);
            let next_token = self.sample(logits, &history);
            steps.push(Step {
                next_token,
                cache: ModelKvCache { kv, history },
                cost_s: None,
            });
        }
        Ok(steps)
    }

    fn plan_summary(&self) -> Option<String> {
        let mut summary = crate::coordinator::describe_site_shapes(
            &self.model.site_shapes(),
            &self.model.engine().name(),
        );
        // Native engines run on the persistent pool: surface the
        // configured lane count and, per site, the *effective* count
        // after the ≥2-tiles-per-lane clamp (`threads > tiles/2` used
        // to degrade invisibly).
        if let Some(g) = self.model.engine().native_gemv() {
            use crate::quant::pack::PSHUFB_TILE_OUTS;
            let sites: Vec<String> = self
                .model
                .site_shapes()
                .iter()
                .map(|(site, sh)| {
                    let tiles = sh.m.div_ceil(PSHUFB_TILE_OUTS);
                    format!("{site}:workers={}", g.effective_workers(tiles))
                })
                .collect();
            summary = format!("{summary} | pool threads={} {}", g.threads(), sites.join(" "));
        }
        Some(format!(
            "{summary} | profile={} source={}",
            self.profile.name,
            self.profile.provenance_label()
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::IsaConfig;
    use crate::model::checkpoint::TransformerConfig;

    fn backend() -> ModelBackend {
        let ckpt = Checkpoint::synthesize(TransformerConfig::toy(), 0xC0FFEE).unwrap();
        let engine = LinearEngine::native(IsaConfig::C2, 1).unwrap();
        let cfg = ModelBackendConfig { prefill_len: 8, max_seq: 24, ..Default::default() };
        ModelBackend::new(&ckpt, engine, cfg).unwrap()
    }

    #[test]
    fn generation_is_deterministic_and_in_vocab() {
        let b = backend();
        let a = b.generate(&[3, 5, 7], 6).unwrap();
        let c = b.generate(&[3, 5, 7], 6).unwrap();
        assert_eq!(a, c);
        assert_eq!(a.len(), 6);
        assert!(a.iter().all(|&t| t >= 0 && (t as usize) < b.config().vocab));
        // A different prompt diverges: these are real logits.
        let d = b.generate(&[3, 5, 8], 6).unwrap();
        assert_ne!(a, d);
    }

    #[test]
    fn prefill_is_padding_invariant() {
        let b = backend();
        let p = b.config().prefill_len;
        let mut zeros = vec![0i32; p];
        zeros[..3].copy_from_slice(&[3, 5, 7]);
        let mut junk = vec![11i32; p];
        junk[..3].copy_from_slice(&[3, 5, 7]);
        assert_eq!(
            b.prefill(&zeros, 3).unwrap().next_token,
            b.prefill(&junk, 3).unwrap().next_token
        );
    }

    #[test]
    fn decode_enforces_the_kv_contract() {
        let b = backend();
        let p = b.config().prefill_len;
        let s = b.prefill(&vec![1i32; p], 2).unwrap();
        assert_eq!(s.cache.len(), 2);
        assert_eq!(s.cost_s, None);
        // Wrong position: the cache holds 2 tokens, pos must be 2.
        assert!(b.decode(s.next_token, 5, &s.cache).is_err());
        let d = b.decode(s.next_token, 2, &s.cache).unwrap();
        assert_eq!(d.cache.len(), 3);
        // Exhaustion.
        let max = b.config().max_seq as i32;
        assert!(b.decode(0, max, &s.cache).is_err());
    }

    #[test]
    fn decode_batch_is_bit_identical_to_serialized() {
        let b = backend();
        let p = b.config().prefill_len;
        let caches: Vec<ModelKvCache> = (0..3)
            .map(|i| {
                let mut padded = vec![0i32; p];
                padded[0] = 2 + i;
                padded[1] = 5;
                b.prefill(&padded, 2).unwrap().cache
            })
            .collect();
        let items: Vec<BatchItem<'_, ModelKvCache>> = caches
            .iter()
            .enumerate()
            .map(|(i, c)| BatchItem { token: 9 + i as i32, pos: 2, cache: c })
            .collect();
        let batched = b.decode_batch(&items).unwrap();
        for (item, step) in items.iter().zip(&batched) {
            let lone = b.decode(item.token, item.pos, item.cache).unwrap();
            assert_eq!(step.next_token, lone.next_token, "batching changed a token");
            assert_eq!(step.cache.history, lone.cache.history);
            assert_eq!(step.cache.len(), lone.cache.len());
        }
    }

    #[test]
    fn plan_summary_names_every_site() {
        let b = backend();
        let summary = b.plan_summary().unwrap();
        for site in ["wqkv", "wo", "ffn-gate-up", "ffn-down", "lm-head"] {
            assert!(summary.contains(site), "{site} missing from {summary:?}");
        }
        assert!(b.weight_bytes() > 0);
        assert!(b.describe().contains("model:ckpt"));
    }

    #[test]
    fn plan_summary_names_profile_and_provenance() {
        let b = backend();
        let summary = b.plan_summary().unwrap();
        assert!(
            summary.contains("profile=Workstation source=table1"),
            "default profile tag missing: {summary:?}"
        );
        let b = b.with_profile(Platform::laptop());
        let summary = b.plan_summary().unwrap();
        assert!(
            summary.contains("profile=Laptop source=table1"),
            "with_profile not reflected: {summary:?}"
        );
    }

    #[test]
    fn plan_summary_surfaces_pool_threads_and_effective_workers() {
        let ckpt = Checkpoint::synthesize(TransformerConfig::toy(), 0xC0FFEE).unwrap();
        let engine = LinearEngine::native(IsaConfig::C2, 4).unwrap();
        let cfg = ModelBackendConfig { prefill_len: 8, max_seq: 24, ..Default::default() };
        let b = ModelBackend::new(&ckpt, engine, cfg).unwrap();
        let summary = b.plan_summary().unwrap();
        assert!(summary.contains("pool threads=4"), "{summary:?}");
        assert!(summary.contains("workers="), "{summary:?}");
        // The toy checkpoint's sites are small: every effective count
        // must respect the ≥2-tiles-per-lane clamp.
        let g = b.model.engine().native_gemv().unwrap();
        for (site, sh) in b.model.site_shapes() {
            let tiles = sh.m.div_ceil(crate::quant::pack::PSHUFB_TILE_OUTS);
            let want = g.effective_workers(tiles);
            assert!(
                summary.contains(&format!("{site}:workers={want}")),
                "site {site} should report workers={want}: {summary:?}"
            );
        }
    }

    #[test]
    fn sampled_decoding_stays_deterministic_per_history() {
        let ckpt = Checkpoint::synthesize(TransformerConfig::toy(), 0xC0FFEE).unwrap();
        let engine = LinearEngine::native(IsaConfig::C2, 1).unwrap();
        let cfg = ModelBackendConfig {
            prefill_len: 8,
            max_seq: 24,
            sampler: SamplerConfig { temperature: 0.9, top_k: 12, seed: 7 },
        };
        let b = ModelBackend::new(&ckpt, engine, cfg).unwrap();
        let a = b.generate(&[1, 2, 3], 6).unwrap();
        let c = b.generate(&[1, 2, 3], 6).unwrap();
        assert_eq!(a, c, "temperature sampling must still be a function of history");
    }
}
