//! The default, dependency-free backend: token steps are *functional*
//! (a deterministic seeded token stream stands in for real logits,
//! exactly like the synthetic ternary checkpoints of `model::zoo`) while
//! per-step *cost* comes from the §III-D adaptive kernel plan run
//! through the `sim` timing engine — so coordinator-level latency and
//! throughput numbers stay paper-faithful (DESIGN.md §3).  Batched
//! decode rounds are costed as one plan selection on the batched GEMV
//! shape (N = round width) under the multi-core contention model, so
//! batching is cheaper than serializing without perturbing tokens.
//!
//! The KV cache substitute is the token history: that is the exact
//! information content of a real KV cache for a deterministic model, and
//! it keeps the scheduler honest (prefill/decode must thread state
//! between steps just like the PJRT path).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::config::platforms::Platform;
use crate::coordinator::selector::{select_plan, ModelPlan};
use crate::kernels::TernaryKernel;
use crate::model::zoo::{self, ModelSpec};
use crate::util::error::Result;

use super::backend::{Backend, BatchItem, Step};
use super::manifest::ModelConfig;

/// Serving-window parameters of a [`SimBackend`] (the counterpart of the
/// AOT manifest's `prefill_len` / `max_seq`).
#[derive(Debug, Clone, Copy)]
pub struct SimBackendConfig {
    /// Padded prompt window (every prefill processes exactly this many
    /// tokens, like the batch-1 AOT executable).
    pub prefill_len: usize,
    /// KV capacity in tokens.
    pub max_seq: usize,
    /// Simulated thread count; 0 = the platform's default protocol
    /// thread count.
    pub threads: usize,
    /// Seed of the synthetic token stream.
    pub seed: u64,
}

impl Default for SimBackendConfig {
    fn default() -> Self {
        SimBackendConfig { prefill_len: 32, max_seq: 160, threads: 0, seed: 0x7E54 }
    }
}

/// Per-sequence state: the token history (prompt + generated tokens).
/// Shared with [`super::NativeBackend`] — both backends' KV state *is*
/// the history, so the native/sim token-parity contract is structural.
#[derive(Debug, Clone)]
pub struct SimKvCache {
    pub(crate) history: Vec<i32>,
}

impl SimKvCache {
    pub fn len(&self) -> usize {
        self.history.len()
    }

    pub fn is_empty(&self) -> bool {
        self.history.is_empty()
    }
}

/// Simulator-backed [`Backend`]: shape tables + kernel selector + timing
/// engine behind the serving API.
pub struct SimBackend {
    spec: &'static ModelSpec,
    platform: Platform,
    threads: usize,
    config: ModelConfig,
    seed: u64,
    prefill_plan: ModelPlan,
    decode_plan: ModelPlan,
    /// Lazily selected §III-D plans for batched decode rounds, keyed by
    /// round width (N of the batched GEMV shape).  Interior-mutable so
    /// worker lanes sharing one backend can fault plans in on demand.
    batch_plans: Mutex<HashMap<usize, ModelPlan>>,
}

impl SimBackend {
    /// Build a backend for `spec` on `platform`: runs the §III-D
    /// compile-time kernel selection for both phases up front, exactly
    /// like model-load time in the paper's framework.
    pub fn new(spec: &'static ModelSpec, platform: Platform, cfg: SimBackendConfig) -> SimBackend {
        assert!(cfg.prefill_len >= 1);
        assert!(cfg.max_seq > cfg.prefill_len, "max_seq must exceed the prefill window");
        let threads = if cfg.threads == 0 { platform.threads } else { cfg.threads };
        let prefill_plan = select_plan(spec, &platform, cfg.prefill_len, threads);
        let decode_plan = select_plan(spec, &platform, 1, threads);
        let config = ModelConfig {
            vocab: spec.vocab,
            d_model: spec.d_model,
            n_layers: spec.layers,
            n_heads: spec.n_heads,
            ffn_dim: spec.ffn_dim,
            max_seq: cfg.max_seq,
            prefill_len: cfg.prefill_len,
        };
        SimBackend {
            spec,
            platform,
            threads,
            config,
            seed: cfg.seed,
            prefill_plan,
            decode_plan,
            batch_plans: Mutex::new(HashMap::new()),
        }
    }

    /// Look up `name` in the model zoo and build a backend for it.
    pub fn by_name(name: &str, platform: Platform, cfg: SimBackendConfig) -> Result<SimBackend> {
        let spec = zoo::by_name(name)
            .ok_or_else(|| crate::err!("unknown model {name:?} (see `tsar-cli models`)"))?;
        Ok(SimBackend::new(spec, platform, cfg))
    }

    pub fn spec(&self) -> &'static ModelSpec {
        self.spec
    }

    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The adaptive kernel plan driving decode-step cost (N = 1).
    pub fn decode_plan(&self) -> &ModelPlan {
        &self.decode_plan
    }

    /// The adaptive kernel plan driving prefill cost (N = prefill_len).
    pub fn prefill_plan(&self) -> &ModelPlan {
        &self.prefill_plan
    }

    /// The §III-D plan costing one batched decode round of `width`
    /// sequences: plan selection on the batched GEMV shape (N = width),
    /// simulated with one core per batch lane — `threads` grows with the
    /// width (floored at the configured thread budget), so the round is
    /// contention-aware: weights stream once for the whole batch while
    /// the lanes compete for shared cache capacity and DRAM bandwidth,
    /// exactly the Fig. 10 multi-core mechanism.  Plans are cached per
    /// width.
    pub fn decode_round_plan(&self, width: usize) -> ModelPlan {
        assert!(width >= 1, "a decode round needs at least one sequence");
        if width == 1 {
            // A width-1 round is a plain decode step: same plan, so
            // batched and serialized costing agree exactly.
            return self.decode_plan.clone();
        }
        let mut plans = self.batch_plans.lock().expect("batch-plan cache poisoned");
        plans
            .entry(width)
            .or_insert_with(|| {
                select_plan(self.spec, &self.platform, width, self.threads.max(width))
            })
            .clone()
    }

    /// Deterministic next token from a history: FNV-1a fold of the
    /// tokens seeds one PRNG draw (shared with [`super::NativeBackend`]
    /// via [`super::synthetic_next_token`]).  Same (seed, history) →
    /// same token, which gives the PJRT path's determinism and
    /// padding-invariance properties for free.
    fn next_token(&self, history: &[i32]) -> i32 {
        super::synthetic_next_token(self.seed, history, self.config.vocab)
    }
}

impl Backend for SimBackend {
    type Cache = SimKvCache;

    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn describe(&self) -> String {
        format!(
            "sim:{} on {} [{}] ({} threads)",
            self.spec.name,
            self.platform.name,
            self.platform.provenance_label(),
            self.threads
        )
    }

    fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<Step<SimKvCache>> {
        let p = self.config.prefill_len;
        crate::ensure!(tokens.len() == p, "expected {p} padded tokens");
        crate::ensure!(
            prompt_len >= 1 && prompt_len as usize <= p,
            "prompt_len {prompt_len} outside the prefill window"
        );
        let history: Vec<i32> = tokens[..prompt_len as usize].to_vec();
        let next_token = self.next_token(&history);
        Ok(Step {
            next_token,
            cache: SimKvCache { history },
            cost_s: Some(self.prefill_plan.pass_seconds()),
        })
    }

    fn decode(&self, token: i32, pos: i32, cache: &SimKvCache) -> Result<Step<SimKvCache>> {
        crate::ensure!(
            (pos as usize) < self.config.max_seq,
            "KV cache exhausted at pos {pos}"
        );
        let mut history = cache.history.clone();
        history.push(token);
        let next_token = self.next_token(&history);
        Ok(Step {
            next_token,
            cache: SimKvCache { history },
            cost_s: Some(self.decode_plan.pass_seconds()),
        })
    }

    fn decode_batch(
        &self,
        reqs: &[BatchItem<'_, SimKvCache>],
    ) -> Result<Vec<Step<SimKvCache>>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        // Tokens are computed exactly as the serialized batch-1 path
        // computes them (same functional stream); only the *cost* model
        // changes: the whole round is one batched GEMV pass, split
        // evenly across the sequences so per-request decode accounting
        // still sums to the round total.
        let round = self.decode_round_plan(reqs.len());
        let share = round.pass_seconds() / reqs.len() as f64;
        let mut steps = Vec::with_capacity(reqs.len());
        for r in reqs {
            crate::ensure!(
                (r.pos as usize) < self.config.max_seq,
                "KV cache exhausted at pos {}",
                r.pos
            );
            let mut history = r.cache.history.clone();
            history.push(r.token);
            let next_token = self.next_token(&history);
            steps.push(Step {
                next_token,
                cache: SimKvCache { history },
                cost_s: Some(share),
            });
        }
        Ok(steps)
    }

    fn plan_summary(&self) -> Option<String> {
        let sites: Vec<String> = self
            .decode_plan
            .layers
            .iter()
            .map(|l| format!("{}:{}", l.site, l.kernel.name()))
            .collect();
        Some(format!(
            "{} | profile={} source={}",
            sites.join(" "),
            self.platform.name,
            self.platform.provenance_label()
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> SimBackend {
        SimBackend::by_name(
            "BitNet-125M",
            Platform::workstation(),
            SimBackendConfig { prefill_len: 8, max_seq: 32, threads: 0, seed: 1 },
        )
        .unwrap()
    }

    #[test]
    fn unknown_model_rejected() {
        let e = SimBackend::by_name(
            "NoSuchNet",
            Platform::workstation(),
            SimBackendConfig::default(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("NoSuchNet"));
    }

    #[test]
    fn generation_is_deterministic() {
        let b = backend();
        let t1 = b.generate(&[3, 5, 7], 6).unwrap();
        let t2 = b.generate(&[3, 5, 7], 6).unwrap();
        assert_eq!(t1, t2);
        assert_eq!(t1.len(), 6);
        assert!(t1.iter().all(|&t| t >= 0 && (t as usize) < b.config().vocab));
        // A different prompt diverges (the stream depends on history).
        let t3 = b.generate(&[3, 5, 8], 6).unwrap();
        assert_ne!(t1, t3);
    }

    #[test]
    fn prefill_is_padding_invariant() {
        let b = backend();
        let p = b.config().prefill_len;
        let prompt = [3i32, 5, 7];
        let mut zeros = vec![0i32; p];
        zeros[..3].copy_from_slice(&prompt);
        let mut junk = vec![11i32; p];
        junk[..3].copy_from_slice(&prompt);
        let a = b.prefill(&zeros, 3).unwrap();
        let c = b.prefill(&junk, 3).unwrap();
        assert_eq!(a.next_token, c.next_token);
    }

    #[test]
    fn step_costs_come_from_the_plans() {
        let b = backend();
        let p = b.config().prefill_len;
        let s = b.prefill(&vec![1i32; p], 2).unwrap();
        assert_eq!(s.cost_s, Some(b.prefill_plan().pass_seconds()));
        let d = b.decode(s.next_token, 2, &s.cache).unwrap();
        assert_eq!(d.cost_s, Some(b.decode_plan().pass_seconds()));
        // Prefill over the whole window must cost more than one decode.
        assert!(s.cost_s.unwrap() > d.cost_s.unwrap());
    }

    #[test]
    fn decode_batch_matches_serialized_and_is_cheaper() {
        let b = backend();
        let p = b.config().prefill_len;
        // Three sequences with distinct histories.
        let caches: Vec<SimKvCache> = (0..3)
            .map(|i| {
                let mut padded = vec![0i32; p];
                padded[0] = 2 + i;
                padded[1] = 5;
                b.prefill(&padded, 2).unwrap().cache
            })
            .collect();
        let items: Vec<BatchItem<'_, SimKvCache>> = caches
            .iter()
            .enumerate()
            .map(|(i, c)| BatchItem { token: 9 + i as i32, pos: 2, cache: c })
            .collect();
        let batched = b.decode_batch(&items).unwrap();
        assert_eq!(batched.len(), 3);
        let mut batched_cost = 0.0;
        let mut serial_cost = 0.0;
        for (item, step) in items.iter().zip(&batched) {
            let lone = b.decode(item.token, item.pos, item.cache).unwrap();
            assert_eq!(step.next_token, lone.next_token, "batching changed a token");
            batched_cost += step.cost_s.unwrap();
            serial_cost += lone.cost_s.unwrap();
        }
        // The batched round streams the weights once for all three
        // sequences; serializing streams them three times.
        assert!(
            batched_cost < serial_cost,
            "batched round {batched_cost} not cheaper than serialized {serial_cost}"
        );
    }

    #[test]
    fn width_one_round_costs_a_plain_decode_step() {
        let b = backend();
        let p = b.config().prefill_len;
        let s = b.prefill(&vec![1i32; p], 2).unwrap();
        let item = [BatchItem { token: 4, pos: 2, cache: &s.cache }];
        let round = b.decode_batch(&item).unwrap();
        assert_eq!(round[0].cost_s, Some(b.decode_plan().pass_seconds()));
    }

    #[test]
    fn decode_batch_rejects_exhausted_kv() {
        let b = backend();
        let p = b.config().prefill_len;
        let s = b.prefill(&vec![1i32; p], 2).unwrap();
        let max = b.config().max_seq as i32;
        let items = [BatchItem { token: 0, pos: max, cache: &s.cache }];
        assert!(b.decode_batch(&items).is_err());
    }

    #[test]
    fn plan_summary_names_every_site() {
        let b = backend();
        let summary = b.plan_summary().unwrap();
        for site in ["wqkv", "wo", "ffn-gate-up", "ffn-down", "lm-head"] {
            assert!(summary.contains(site), "{site} missing from {summary:?}");
        }
        assert!(
            summary.contains("profile=Workstation source=table1"),
            "profile tag missing: {summary:?}"
        );
        assert!(b.describe().contains("[table1]"), "{}", b.describe());
    }

    #[test]
    fn kv_exhaustion_errors() {
        let b = backend();
        let p = b.config().prefill_len;
        let s = b.prefill(&vec![1i32; p], 2).unwrap();
        let max = b.config().max_seq as i32;
        assert!(b.decode(0, max, &s.cache).is_err());
        assert!(b.generate(&[1, 2], b.config().max_seq + 4).is_err());
    }
}
