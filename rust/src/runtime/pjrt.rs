//! PJRT-backed [`Backend`] (`--features pjrt`): load the AOT HLO-text
//! artifacts produced by `python/compile/aot.py` and execute them.
//!
//! Python runs only at build time; this module is everything the serving
//! binary needs at run time: the manifest (JSON), the packed parameter
//! file (`weights.bin`), and the PJRT CPU client.  Parameters are
//! uploaded to device buffers once at load; each inference step passes
//! borrowed buffers (`execute_b`), so the hot loop never re-copies
//! weights.
//!
//! Interchange is HLO *text* (see aot.py / DESIGN.md §4): xla_extension
//! 0.5.1 rejects jax≥0.5's serialized protos (64-bit instruction ids);
//! the text parser reassigns ids.
//!
//! This module is the only place the `xla` and `anyhow` crates are
//! reachable; the default offline build compiles without them (see
//! `rust/Cargo.toml` for how to enable the feature).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::backend::{Backend, Step};
use super::manifest::{DType, Manifest, ModelConfig, ParamMeta};

/// Bridge `anyhow`-reported PJRT failures into the crate error type the
/// [`Backend`] trait (and the default build) uses.
impl From<anyhow::Error> for crate::util::error::Error {
    fn from(e: anyhow::Error) -> crate::util::error::Error {
        crate::util::error::Error::msg(format!("{e:#}"))
    }
}

/// A loaded model variant ("tsar" or "ref"): compiled prefill + decode
/// executables with parameters resident on device.
pub struct ModelRuntime {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub variant: String,
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    prefill_params: Vec<xla::PjRtBuffer>,
    decode_params: Vec<xla::PjRtBuffer>,
}

/// The KV cache travels between steps as a pair of literals.
pub struct KvCache {
    pub k: xla::Literal,
    pub v: xla::Literal,
}

/// One decode/prefill step's result.
pub struct StepOut {
    pub next_token: i32,
    pub cache: KvCache,
}

impl ModelRuntime {
    /// Load a variant from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>, variant: &str) -> Result<ModelRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("{e}"))
            .context("loading manifest.json")?;
        let client = xla::PjRtClient::cpu()?;

        let compile = |phase: &str| -> Result<xla::PjRtLoadedExecutable> {
            let ep = manifest
                .entrypoint(&format!("{phase}_{variant}"))
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let path = dir.join(&ep.hlo);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let prefill_exe = compile("prefill")?;
        let decode_exe = compile("decode")?;

        let weights = std::fs::read(dir.join(&manifest.weights_bin))
            .context("reading weights.bin")?;
        let upload = |phase: &str| -> Result<Vec<xla::PjRtBuffer>> {
            let ep = manifest
                .entrypoint(&format!("{phase}_{variant}"))
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            ep.param_args
                .iter()
                .map(|name| {
                    let meta = manifest.param(name).map_err(|e| anyhow::anyhow!("{e}"))?;
                    param_buffer(&client, meta, &weights)
                })
                .collect()
        };
        let prefill_params = upload("prefill")?;
        let decode_params = upload("decode")?;

        Ok(ModelRuntime {
            dir,
            manifest,
            variant: variant.to_string(),
            client,
            prefill_exe,
            decode_exe,
            prefill_params,
            decode_params,
        })
    }

    /// Run prefill over a padded prompt. `tokens` must have exactly
    /// `prefill_len` entries; `prompt_len` is the real prompt length.
    pub fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<StepOut> {
        let p = self.manifest.config.prefill_len;
        anyhow::ensure!(tokens.len() == p, "expected {p} padded tokens");
        let tok_buf = self.client.buffer_from_host_buffer(tokens, &[p], None)?;
        let len_buf = self.client.buffer_from_host_buffer(&[prompt_len], &[], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &len_buf];
        args.extend(self.prefill_params.iter());
        let out = self.prefill_exe.execute_b(&args)?;
        self.unpack(out)
    }

    /// One greedy decode step.
    pub fn decode(&self, token: i32, pos: i32, cache: &KvCache) -> Result<StepOut> {
        let tok = self.client.buffer_from_host_buffer(&[token], &[], None)?;
        let pos_b = self.client.buffer_from_host_buffer(&[pos], &[], None)?;
        let k = self.client.buffer_from_host_literal(None, &cache.k)?;
        let v = self.client.buffer_from_host_literal(None, &cache.v)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok, &pos_b, &k, &v];
        args.extend(self.decode_params.iter());
        let out = self.decode_exe.execute_b(&args)?;
        self.unpack(out)
    }

    fn unpack(&self, out: Vec<Vec<xla::PjRtBuffer>>) -> Result<StepOut> {
        anyhow::ensure!(!out.is_empty() && !out[0].is_empty(), "empty result");
        let lit = out[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        anyhow::ensure!(parts.len() == 3, "expected 3-tuple output");
        let mut it = parts.into_iter();
        let next = it.next().unwrap().to_vec::<i32>()?[0];
        let k = it.next().unwrap();
        let v = it.next().unwrap();
        Ok(StepOut { next_token: next, cache: KvCache { k, v } })
    }

    // Greedy generation comes from the Backend trait's provided
    // `generate` (one copy of the prefill+decode loop for both
    // backends).
}

impl Backend for ModelRuntime {
    type Cache = KvCache;

    fn config(&self) -> &ModelConfig {
        &self.manifest.config
    }

    fn describe(&self) -> String {
        format!("pjrt:{} (variant {})", self.manifest.config_name, self.variant)
    }

    fn prefill(
        &self,
        tokens: &[i32],
        prompt_len: i32,
    ) -> crate::util::error::Result<Step<KvCache>> {
        let out = ModelRuntime::prefill(self, tokens, prompt_len)?;
        Ok(Step { next_token: out.next_token, cache: out.cache, cost_s: None })
    }

    fn decode(
        &self,
        token: i32,
        pos: i32,
        cache: &KvCache,
    ) -> crate::util::error::Result<Step<KvCache>> {
        let out = ModelRuntime::decode(self, token, pos, cache)?;
        Ok(Step { next_token: out.next_token, cache: out.cache, cost_s: None })
    }
}

/// Build a device buffer for one parameter from the packed weights file.
fn param_buffer(
    client: &xla::PjRtClient,
    meta: &ParamMeta,
    weights: &[u8],
) -> Result<xla::PjRtBuffer> {
    let bytes = weights
        .get(meta.offset..meta.offset + meta.nbytes)
        .with_context(|| format!("param {} out of range", meta.name))?;
    let dims: Vec<usize> = meta.shape.clone();
    let n = meta.elem_count();
    match meta.dtype {
        DType::F32 => {
            let mut v = vec![0f32; n];
            bytemuck_cast(bytes, &mut v);
            Ok(client.buffer_from_host_buffer(&v, &dims, None)?)
        }
        DType::I32 => {
            let mut v = vec![0i32; n];
            bytemuck_cast(bytes, &mut v);
            Ok(client.buffer_from_host_buffer(&v, &dims, None)?)
        }
    }
}

/// Little-endian byte reinterpretation (manifest data is LE by
/// construction; x86-64/aarch64 targets are LE).
fn bytemuck_cast<T: Copy>(bytes: &[u8], out: &mut [T]) {
    let want = std::mem::size_of_val(out);
    assert_eq!(bytes.len(), want, "byte length mismatch");
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr() as *mut u8, want);
    }
}

#[cfg(test)]
mod tests {
    // The runtime requires built artifacts; end-to-end coverage lives in
    // rust/tests/runtime_e2e.rs (gated on the pjrt feature and skipped
    // when artifacts/ is absent).

    #[test]
    fn bytemuck_roundtrip() {
        let src: Vec<f32> = vec![1.5, -2.25, 0.0, 3.0e9];
        let bytes: Vec<u8> = src.iter().flat_map(|f| f.to_le_bytes()).collect();
        let mut dst = vec![0f32; 4];
        super::bytemuck_cast(&bytes, &mut dst);
        assert_eq!(src, dst);
    }
}
