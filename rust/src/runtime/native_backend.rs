//! Real-execution backend over the native pshufb kernels
//! (`kernels::native`): `tsar-cli serve --backend native`.
//!
//! Token *values* come from the same deterministic synthetic stream as
//! [`super::SimBackend`] (shared [`super::synthetic_next_token`]), so a
//! native serve produces bit-identical tokens to a simulated serve with
//! the same seed — the cross-check the differential suite asserts.
//! Step *costs* differ in kind: every prefill/decode step executes the
//! model's full BitLinear decode workload (each site's GEMV through the
//! native AVX2 or scalar-fallback kernel, once per layer) and reports
//! `cost_s: None`, so the coordinator's lanes fall back to measured
//! wall-clock time — real silicon numbers next to the simulator's
//! modeled ones.
//!
//! Weights are synthesized per layer site (seeded ternary, like the
//! model zoo's checkpoints) and packed once at load time into the
//! [`PshufbPacked`] execution layout; activations are regenerated per
//! step so the memory system sees fresh operands.  Note the execution
//! layout costs 1 byte/weight (c=2) — loading the multi-billion
//! parameter zoo entries natively takes real RAM; the serve demo and
//! tests use the small end of the zoo.

use std::cell::RefCell;

use crate::config::{IsaConfig, Platform};
use crate::kernels::native::{NativeGemv, NativePath, Workspace};
use crate::model::zoo::{self, ModelSpec};
use crate::model::Workload;
use crate::quant::pack::PshufbPacked;
use crate::sim::GemmShape;
use crate::util::error::Result;
use crate::util::rng::Rng;

use super::backend::{Backend, Step};
use super::manifest::ModelConfig;
use super::sim_backend::{SimBackendConfig, SimKvCache};
use super::synthetic_next_token;

/// One BitLinear site's packed weights.
struct NativeLayer {
    site: &'static str,
    shape: GemmShape,
    /// Invocations per forward pass (layer count; 1 for the LM head).
    count: usize,
    packed: PshufbPacked,
}

/// [`Backend`] that spends real CPU time: decode-shaped GEMVs execute
/// through the native kernels on every step.
pub struct NativeBackend {
    spec: &'static ModelSpec,
    config: ModelConfig,
    seed: u64,
    gemv: NativeGemv,
    layers: Vec<NativeLayer>,
    /// Platform profile attached for labeling: the native backend runs
    /// on this host, but reports name the profile the serve was asked
    /// to model (`serve --platform`), provenance included.
    profile: Platform,
}

impl NativeBackend {
    /// Load `spec`: synthesize + pack every decode-workload site for
    /// the given ISA config on the detected native path.
    /// `cfg.threads` (0 → 1) chunks each GEMV's output rows across that
    /// many host worker threads — results stay bit-identical to the
    /// single-threaded path (the tile chunks are disjoint and exact).
    pub fn new(
        spec: &'static ModelSpec,
        isa: IsaConfig,
        cfg: SimBackendConfig,
    ) -> Result<NativeBackend> {
        crate::ensure!(cfg.prefill_len >= 1, "prefill window must be at least 1");
        crate::ensure!(
            cfg.max_seq > cfg.prefill_len,
            "max_seq must exceed the prefill window"
        );
        let gemv = NativeGemv::new(isa)?.with_threads(cfg.threads.max(1))?;
        let wl = Workload::decode(spec);
        let mut rng = Rng::new(cfg.seed ^ 0x7EA1_0000_0000_0001);
        let mut layers = Vec::with_capacity(wl.ops.len());
        for op in &wl.ops {
            let w = rng.ternary_matrix(op.shape.m, op.shape.k, 0.33);
            let packed = gemv.pack(&w, op.shape.m, op.shape.k)?;
            layers.push(NativeLayer {
                site: op.site,
                shape: op.shape,
                count: op.count,
                packed,
            });
        }
        let config = ModelConfig {
            vocab: spec.vocab,
            d_model: spec.d_model,
            n_layers: spec.layers,
            n_heads: spec.n_heads,
            ffn_dim: spec.ffn_dim,
            max_seq: cfg.max_seq,
            prefill_len: cfg.prefill_len,
        };
        Ok(NativeBackend {
            spec,
            config,
            seed: cfg.seed,
            gemv,
            layers,
            profile: Platform::workstation(),
        })
    }

    /// Attach the platform profile named by `serve --platform`: surfaces
    /// its name and provenance in `plan_summary` and the per-request
    /// records (the kernels still run on this host).
    pub fn with_profile(mut self, profile: Platform) -> NativeBackend {
        self.profile = profile;
        self
    }

    /// Look up `name` in the model zoo and load it natively.
    pub fn by_name(name: &str, isa: IsaConfig, cfg: SimBackendConfig) -> Result<NativeBackend> {
        let spec = zoo::by_name(name)
            .ok_or_else(|| crate::err!("unknown model {name:?} (see `tsar-cli models`)"))?;
        NativeBackend::new(spec, isa, cfg)
    }

    pub fn spec(&self) -> &'static ModelSpec {
        self.spec
    }

    pub fn path(&self) -> NativePath {
        self.gemv.path()
    }

    pub fn isa(&self) -> IsaConfig {
        self.gemv.isa()
    }

    /// Packed bytes the loaded weights occupy in the execution layout.
    pub fn packed_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.packed.packed_bytes()).sum()
    }

    fn next_token(&self, history: &[i32]) -> i32 {
        synthetic_next_token(self.seed, history, self.config.vocab)
    }

    /// One real forward pass (N = 1): every site's GEMV executes
    /// `count` times with fresh synthetic activations.  `step_tag`
    /// varies the activation stream per step.  Scratch is per-lane
    /// (thread-local), so concurrent serving lanes reuse buffers
    /// without allocating per site per step.
    fn forward_pass(&self, step_tag: u64) -> Result<()> {
        thread_local! {
            /// (activations, outputs, kernel workspace) per lane.
            static SCRATCH: RefCell<(Vec<i8>, Vec<i32>, Workspace)> =
                const { RefCell::new((Vec::new(), Vec::new(), Workspace::new())) };
        }
        SCRATCH.with(|scratch| {
            let (acts, out, ws) = &mut *scratch.borrow_mut();
            for (li, layer) in self.layers.iter().enumerate() {
                acts.clear();
                acts.resize(layer.shape.k, 0);
                out.clear();
                out.resize(layer.shape.m, 0);
                for rep in 0..layer.count {
                    let mut rng = Rng::new(
                        step_tag ^ ((li as u64) << 40) ^ (rep as u64).wrapping_mul(0x9E37_79B9),
                    );
                    for v in acts.iter_mut() {
                        *v = rng.range_i64(-127, 127) as i8;
                    }
                    self.gemv.gemm_with(ws, acts, &layer.packed, 1, out)?;
                    // Keep the kernel's work observable to the optimizer.
                    std::hint::black_box(&out);
                }
            }
            Ok(())
        })
    }
}

impl Backend for NativeBackend {
    type Cache = SimKvCache;

    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn describe(&self) -> String {
        format!(
            "native:{} ({} path, {}, {} thread(s), {} sites packed)",
            self.spec.name,
            self.gemv.path().name(),
            self.gemv.isa().name(),
            self.gemv.threads(),
            self.layers.len()
        )
    }

    fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<Step<SimKvCache>> {
        let p = self.config.prefill_len;
        crate::ensure!(tokens.len() == p, "expected {p} padded tokens");
        crate::ensure!(
            prompt_len >= 1 && prompt_len as usize <= p,
            "prompt_len {prompt_len} outside the prefill window"
        );
        let history: Vec<i32> = tokens[..prompt_len as usize].to_vec();
        self.forward_pass(0x5EED ^ history.len() as u64)?;
        let next_token = self.next_token(&history);
        Ok(Step {
            next_token,
            cache: SimKvCache { history },
            cost_s: None, // real backend: the lane measures wall-clock
        })
    }

    fn decode(&self, token: i32, pos: i32, cache: &SimKvCache) -> Result<Step<SimKvCache>> {
        crate::ensure!(
            (pos as usize) < self.config.max_seq,
            "KV cache exhausted at pos {pos}"
        );
        let mut history = cache.history.clone();
        history.push(token);
        self.forward_pass(((pos as u64) << 32) ^ token as u32 as u64)?;
        let next_token = self.next_token(&history);
        Ok(Step {
            next_token,
            cache: SimKvCache { history },
            cost_s: None,
        })
    }

    fn plan_summary(&self) -> Option<String> {
        // `workers=` is the *effective* lane count per site: the
        // `--threads` knob clamped so every pool lane owns ≥ 2 output
        // tiles — small sites silently degrading used to be invisible.
        let sites: Vec<String> = self
            .layers
            .iter()
            .map(|l| {
                format!(
                    "{}:native-{}/{} workers={}",
                    l.site,
                    self.gemv.path().name(),
                    self.gemv.isa().name(),
                    self.gemv.effective_workers(l.packed.tiles)
                )
            })
            .collect();
        Some(format!(
            "{} | profile={} source={}",
            sites.join(" "),
            self.profile.name,
            self.profile.provenance_label()
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SimBackend;

    /// Tiny synthetic architecture: real native execution stays cheap
    /// enough for debug-mode tests.
    static TINY: ModelSpec = ModelSpec {
        name: "Tiny-Native-Test",
        layers: 2,
        d_model: 64,
        n_heads: 4,
        n_kv_heads: 4,
        ffn_dim: 128,
        vocab: 512,
    };

    fn cfg() -> SimBackendConfig {
        SimBackendConfig { prefill_len: 4, max_seq: 16, threads: 0, seed: 0xBEE5 }
    }

    #[test]
    fn unknown_model_rejected() {
        let e = NativeBackend::by_name("NoSuchNet", IsaConfig::C2, cfg()).unwrap_err();
        assert!(e.to_string().contains("NoSuchNet"));
    }

    #[test]
    fn tokens_match_sim_backend_exactly() {
        let native = NativeBackend::new(&TINY, IsaConfig::C2, cfg()).unwrap();
        let sim = SimBackend::new(&TINY, Platform::workstation(), cfg());
        let a = native.generate(&[3, 1, 4], 4).unwrap();
        let b = sim.generate(&[3, 1, 4], 4).unwrap();
        assert_eq!(a, b, "native and sim token streams diverged");
        assert!(a.iter().all(|&t| t >= 0 && (t as usize) < TINY.vocab));
    }

    #[test]
    fn steps_report_wall_clock_not_simulated_cost() {
        let native = NativeBackend::new(&TINY, IsaConfig::C4, cfg()).unwrap();
        let p = native.config().prefill_len;
        let s = native.prefill(&vec![1i32; p], 2).unwrap();
        assert_eq!(s.cost_s, None);
        let d = native.decode(s.next_token, 2, &s.cache).unwrap();
        assert_eq!(d.cost_s, None);
        assert_eq!(d.cache.len(), s.cache.len() + 1);
    }

    #[test]
    fn kv_exhaustion_errors() {
        let native = NativeBackend::new(&TINY, IsaConfig::C2, cfg()).unwrap();
        let p = native.config().prefill_len;
        let s = native.prefill(&vec![1i32; p], 2).unwrap();
        let max = native.config().max_seq as i32;
        assert!(native.decode(0, max, &s.cache).is_err());
    }

    #[test]
    fn plan_summary_names_every_site() {
        let native = NativeBackend::new(&TINY, IsaConfig::C2, cfg()).unwrap();
        let summary = native.plan_summary().unwrap();
        for site in ["wqkv", "wo", "ffn-gate-up", "ffn-down", "lm-head"] {
            assert!(summary.contains(site), "{site} missing from {summary:?}");
        }
        assert!(summary.contains("native-"));
        assert!(native.packed_bytes() > 0);
    }

    #[test]
    fn plan_summary_names_profile_and_provenance() {
        let native = NativeBackend::new(&TINY, IsaConfig::C2, cfg()).unwrap();
        let summary = native.plan_summary().unwrap();
        assert!(
            summary.contains("profile=Workstation source=table1"),
            "default profile tag missing: {summary:?}"
        );
        let native = native.with_profile(Platform::mobile());
        let summary = native.plan_summary().unwrap();
        assert!(
            summary.contains("profile=Mobile source=table1"),
            "with_profile not reflected: {summary:?}"
        );
    }

    #[test]
    fn plan_summary_reports_effective_workers_per_site() {
        // threads=8 against the tiny spec: small sites clamp below 8,
        // and the clamp is visible instead of silent.
        let mut c = cfg();
        c.threads = 8;
        let native = NativeBackend::new(&TINY, IsaConfig::C2, c).unwrap();
        let summary = native.plan_summary().unwrap();
        assert!(summary.contains("workers="), "missing worker counts: {summary:?}");
        for l in &native.layers {
            let want = native.gemv.effective_workers(l.packed.tiles);
            assert!(
                summary.contains(&format!("{}:native-{}/{} workers={want}",
                    l.site,
                    native.gemv.path().name(),
                    native.gemv.isa().name())),
                "site {} should report workers={want}: {summary:?}",
                l.site
            );
            assert!(want <= 8);
        }
    }
}
