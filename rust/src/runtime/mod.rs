//! Model runtimes behind one serving-facing abstraction (DESIGN.md §3).
//!
//! [`Backend`] is the surface the coordinator's worker lanes drive:
//! prefill/decode steps — batch-1 or whole batched decode rounds
//! ([`Backend::decode_batch`] over [`BatchItem`]s) — over explicit
//! per-sequence KV state, plus the model/window description
//! ([`ModelConfig`]).  Implementations:
//!
//! * [`SimBackend`] (default) — functional token steps costed by the
//!   §III-D adaptive kernel plan through the `sim` timing engine; the
//!   whole serving stack runs offline with zero dependencies.
//! * [`ModelRuntime`] (`--features pjrt`) — the PJRT CPU client
//!   executing AOT HLO-text artifacts from `python/compile/aot.py`
//!   (DESIGN.md §4).  The `xla`/`anyhow` crates are only reachable
//!   through this feature.
//!
//! [`manifest`] (the typed view of `artifacts/manifest.json`) stays in
//! the default build: its [`ModelConfig`] doubles as the backend
//! description and the parser is plain in-tree JSON.

pub mod backend;
pub mod manifest;
pub mod sim_backend;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{Backend, BatchItem, Step};
pub use manifest::{DType, EntryPoint, Manifest, ModelConfig, ParamMeta};
pub use sim_backend::{SimBackend, SimBackendConfig, SimKvCache};

#[cfg(feature = "pjrt")]
pub use pjrt::{KvCache, ModelRuntime, StepOut};
