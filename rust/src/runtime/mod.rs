//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the request path.
//!
//! Python runs only at build time; this module is everything the serving
//! binary needs at run time: the manifest (JSON), the packed parameter
//! file (`weights.bin`), and the PJRT CPU client.  Parameters are
//! uploaded to device buffers once at load; each inference step passes
//! borrowed buffers (`execute_b`), so the hot loop never re-copies
//! weights.
//!
//! Interchange is HLO *text* (see aot.py / DESIGN.md): xla_extension
//! 0.5.1 rejects jax≥0.5's serialized protos (64-bit instruction ids);
//! the text parser reassigns ids.

pub mod manifest;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use manifest::{DType, EntryPoint, Manifest, ParamMeta};

/// A loaded model variant ("tsar" or "ref"): compiled prefill + decode
/// executables with parameters resident on device.
pub struct ModelRuntime {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub variant: String,
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    prefill_params: Vec<xla::PjRtBuffer>,
    decode_params: Vec<xla::PjRtBuffer>,
}

/// The KV cache travels between steps as a pair of literals.
pub struct KvCache {
    pub k: xla::Literal,
    pub v: xla::Literal,
}

/// One decode/prefill step's result.
pub struct StepOut {
    pub next_token: i32,
    pub cache: KvCache,
}

impl ModelRuntime {
    /// Load a variant from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>, variant: &str) -> Result<ModelRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .context("loading manifest.json")?;
        let client = xla::PjRtClient::cpu()?;

        let compile = |phase: &str| -> Result<xla::PjRtLoadedExecutable> {
            let ep = manifest.entrypoint(&format!("{phase}_{variant}"))?;
            let path = dir.join(&ep.hlo);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let prefill_exe = compile("prefill")?;
        let decode_exe = compile("decode")?;

        let weights = std::fs::read(dir.join(&manifest.weights_bin))
            .context("reading weights.bin")?;
        let upload = |phase: &str| -> Result<Vec<xla::PjRtBuffer>> {
            let ep = manifest.entrypoint(&format!("{phase}_{variant}"))?;
            ep.param_args
                .iter()
                .map(|name| {
                    let meta = manifest.param(name)?;
                    param_buffer(&client, meta, &weights)
                })
                .collect()
        };
        let prefill_params = upload("prefill")?;
        let decode_params = upload("decode")?;

        Ok(ModelRuntime {
            dir,
            manifest,
            variant: variant.to_string(),
            client,
            prefill_exe,
            decode_exe,
            prefill_params,
            decode_params,
        })
    }

    /// Run prefill over a padded prompt. `tokens` must have exactly
    /// `prefill_len` entries; `prompt_len` is the real prompt length.
    pub fn prefill(&self, tokens: &[i32], prompt_len: i32) -> Result<StepOut> {
        let p = self.manifest.config.prefill_len;
        anyhow::ensure!(tokens.len() == p, "expected {p} padded tokens");
        let tok_buf = self.client.buffer_from_host_buffer(tokens, &[p], None)?;
        let len_buf = self.client.buffer_from_host_buffer(&[prompt_len], &[], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &len_buf];
        args.extend(self.prefill_params.iter());
        let out = self.prefill_exe.execute_b(&args)?;
        self.unpack(out)
    }

    /// One greedy decode step.
    pub fn decode(&self, token: i32, pos: i32, cache: &KvCache) -> Result<StepOut> {
        let tok = self.client.buffer_from_host_buffer(&[token], &[], None)?;
        let pos_b = self.client.buffer_from_host_buffer(&[pos], &[], None)?;
        let k = self.client.buffer_from_host_literal(None, &cache.k)?;
        let v = self.client.buffer_from_host_literal(None, &cache.v)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok, &pos_b, &k, &v];
        args.extend(self.decode_params.iter());
        let out = self.decode_exe.execute_b(&args)?;
        self.unpack(out)
    }

    fn unpack(&self, out: Vec<Vec<xla::PjRtBuffer>>) -> Result<StepOut> {
        anyhow::ensure!(!out.is_empty() && !out[0].is_empty(), "empty result");
        let lit = out[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        anyhow::ensure!(parts.len() == 3, "expected 3-tuple output");
        let mut it = parts.into_iter();
        let next = it.next().unwrap().to_vec::<i32>()?[0];
        let k = it.next().unwrap();
        let v = it.next().unwrap();
        Ok(StepOut { next_token: next, cache: KvCache { k, v } })
    }

    /// Greedy generation: prefill + n_new-1 decode steps.
    pub fn generate(&self, prompt: &[i32], n_new: usize) -> Result<Vec<i32>> {
        let p = self.manifest.config.prefill_len;
        anyhow::ensure!(prompt.len() <= p, "prompt longer than prefill window");
        let mut padded = vec![0i32; p];
        padded[..prompt.len()].copy_from_slice(prompt);
        let step = self.prefill(&padded, prompt.len() as i32)?;
        let mut toks = vec![step.next_token];
        let mut cache = step.cache;
        let mut pos = prompt.len() as i32;
        for _ in 1..n_new {
            anyhow::ensure!(
                (pos as usize) < self.manifest.config.max_seq,
                "KV cache exhausted"
            );
            let s = self.decode(*toks.last().unwrap(), pos, &cache)?;
            toks.push(s.next_token);
            cache = s.cache;
            pos += 1;
        }
        Ok(toks)
    }
}

/// Build a device buffer for one parameter from the packed weights file.
fn param_buffer(
    client: &xla::PjRtClient,
    meta: &ParamMeta,
    weights: &[u8],
) -> Result<xla::PjRtBuffer> {
    let bytes = weights
        .get(meta.offset..meta.offset + meta.nbytes)
        .with_context(|| format!("param {} out of range", meta.name))?;
    let dims: Vec<usize> = meta.shape.clone();
    let n = meta.elem_count();
    match meta.dtype {
        DType::F32 => {
            let mut v = vec![0f32; n];
            bytemuck_cast(bytes, &mut v);
            Ok(client.buffer_from_host_buffer(&v, &dims, None)?)
        }
        DType::I32 => {
            let mut v = vec![0i32; n];
            bytemuck_cast(bytes, &mut v);
            Ok(client.buffer_from_host_buffer(&v, &dims, None)?)
        }
    }
}

/// Little-endian byte reinterpretation (manifest data is LE by
/// construction; x86-64/aarch64 targets are LE).
fn bytemuck_cast<T: Copy>(bytes: &[u8], out: &mut [T]) {
    let want = std::mem::size_of_val(out);
    assert_eq!(bytes.len(), want, "byte length mismatch");
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr() as *mut u8, want);
    }
}

#[cfg(test)]
mod tests {
    // The runtime requires built artifacts; end-to-end coverage lives in
    // rust/tests/runtime_e2e.rs (skipped when artifacts/ is absent).

    #[test]
    fn bytemuck_roundtrip() {
        let src: Vec<f32> = vec![1.5, -2.25, 0.0, 3.0e9];
        let bytes: Vec<u8> = src.iter().flat_map(|f| f.to_le_bytes()).collect();
        let mut dst = vec![0f32; 4];
        super::bytemuck_cast(&bytes, &mut dst);
        assert_eq!(src, dst);
    }
}
