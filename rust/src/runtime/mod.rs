//! Model runtimes behind one serving-facing abstraction (DESIGN.md §3).
//!
//! [`Backend`] is the surface the coordinator's worker lanes drive:
//! prefill/decode steps — batch-1 or whole batched decode rounds
//! ([`Backend::decode_batch`] over [`BatchItem`]s) — over explicit
//! per-sequence KV state, plus the model/window description
//! ([`ModelConfig`]).  Implementations:
//!
//! * [`SimBackend`] (default) — functional token steps costed by the
//!   §III-D adaptive kernel plan through the `sim` timing engine; the
//!   whole serving stack runs offline with zero dependencies.
//! * [`NativeBackend`] — the same functional token stream, but every
//!   decode step *executes* the model's BitLinear GEMVs through the
//!   native AVX2/scalar kernels (`kernels::native`) and reports no
//!   simulated cost, so the server times real wall-clock decode
//!   (`tsar-cli serve --backend native`).
//! * [`ModelBackend`] — a *real* forward pass: every step samples from
//!   logits produced by the checkpoint-loaded ternary transformer
//!   (`model::TernaryTransformer`) with true per-layer KV caches, its
//!   BitLinear GEMVs routed through the native or modeled kernels
//!   (`tsar-cli serve --backend model`).
//! * [`ModelRuntime`] (`--features pjrt`) — the PJRT CPU client
//!   executing AOT HLO-text artifacts from `python/compile/aot.py`
//!   (DESIGN.md §4).  The `xla`/`anyhow` crates are only reachable
//!   through this feature.
//!
//! [`manifest`] (the typed view of `artifacts/manifest.json`) stays in
//! the default build: its [`ModelConfig`] doubles as the backend
//! description and the parser is plain in-tree JSON.

pub mod backend;
pub mod manifest;
pub mod model_backend;
pub mod native_backend;
pub mod sim_backend;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{Backend, BatchItem, Step};
pub use manifest::{DType, EntryPoint, Manifest, ModelConfig, ParamMeta};
pub use model_backend::{ModelBackend, ModelBackendConfig, ModelKvCache};
pub use native_backend::NativeBackend;
pub use sim_backend::{SimBackend, SimBackendConfig, SimKvCache};

#[cfg(feature = "pjrt")]
pub use pjrt::{KvCache, ModelRuntime, StepOut};

/// Deterministic synthetic next token shared by [`SimBackend`] and
/// [`NativeBackend`]: an FNV-1a fold of the token history seeds one
/// PRNG draw, so same (seed, history) → same token on every backend —
/// the property the native/sim serve cross-check relies on.
pub(crate) fn synthetic_next_token(seed: u64, history: &[i32], vocab: usize) -> i32 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ seed;
    for &t in history {
        h = (h ^ t as u32 as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    let mut rng = crate::util::rng::Rng::new(h);
    rng.below(vocab as u64) as i32
}
