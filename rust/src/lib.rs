//! # T-SAR — CPU-only ternary LLM inference via SIMD ALU reorganization
//!
//! Reproduction of *T-SAR: A Full-Stack Co-design for CPU-Only Ternary LLM
//! Inference via In-Place SIMD ALU Reorganization* (Oh et al., 2025).
//!
//! The crate contains every subsystem the paper's evaluation depends on
//! (see `DESIGN.md` for the inventory):
//!
//! * [`quant`] — ternary quantization, the ternary→binary decomposition
//!   and the packing formats of T-SAR, BitNet.cpp TL-2 and T-MAC.
//! * [`simd`] — a functional model of an AVX2-class SIMD register file
//!   and its 16×16-bit ALU lanes / 4:1 adder trees.
//! * [`tsar`] — the paper's ISA extension: `TLUT_c×s` / `TGEMV_k×m`
//!   semantics, VEX3 encodings and µ-op sequencing (paper §III-B/C).
//! * [`kernels`] — the six T-SAR software kernels (AP-min / AP-max / OP ×
//!   two ISA configs) plus the TL-2, T-MAC and FP16 baselines, each with
//!   a functional path (bit-exact vs the Python oracle) and a workload
//!   descriptor path feeding the simulator.
//! * [`sim`] — the gem5 substitute: set-associative cache hierarchy,
//!   DRAM bandwidth/latency, per-core issue model and the multi-thread
//!   contention engine (paper §IV-A Table I platforms).
//! * [`model`] — BitNet-b1.58 / Llama-b1.58 / Falcon3-b1.58 architecture
//!   shape tables (125M…100B) and per-phase workload extraction.
//! * [`hw`] — the Table II area/power overhead model of the 256-bit SIMD
//!   slice (TSMC 28 nm analytical gate model).
//! * [`energy`] — CPU package power + Jetson AGX Orin comparison model
//!   (Table III).
//! * [`runtime`] — the model-backend abstraction the serving stack is
//!   written against: `Backend` with the default simulator-costed
//!   `SimBackend`, and the PJRT CPU client (`ModelRuntime`, behind the
//!   `pjrt` cargo feature) that loads the AOT HLO-text artifacts
//!   produced by `python/compile/aot.py` and executes them.
//! * [`coordinator`] — the serving layer: the session-based streaming
//!   engine (submit/stream/cancel tickets, per-request generation
//!   params, JSONL metrics exporter), continuous batcher, prefill/decode
//!   scheduler, KV-slot manager and the paper's adaptive AP/OP kernel
//!   selector (§III-D).
//! * [`loadgen`] — the open-loop load-generation subsystem behind
//!   `tsar-cli bench-serve`: deterministic seeded traffic (Poisson and
//!   bursty arrivals, mixed lengths, cancels, deadlines) driven over
//!   keep-alive HTTP connections, with per-request timelines reconciled
//!   against the engine's Prometheus counters into the
//!   `BENCH_serve.json` artifact.
//! * [`bench`] — harnesses that regenerate every table and figure of the
//!   paper's evaluation section.
//! * [`util`] — in-tree errors, JSON, PRNG, statistics (offline
//!   environment: no anyhow/serde/rand/criterion available).

pub mod bench;
pub mod calibrate;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod hw;
pub mod kernels;
pub mod loadgen;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod simd;
pub mod tsar;
pub mod util;

pub use util::error::{Error, Result};
