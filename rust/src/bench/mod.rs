//! Report harnesses: regenerate every table and figure of the paper's
//! evaluation section (DESIGN.md §5 experiment index).
//!
//! Each `fig*` / `table*` function prints the same rows/series the paper
//! reports and returns the numbers for tests/benches to assert on.
//! Absolute values come from our simulator substrate, so the claim being
//! reproduced is the *shape*: who wins, by roughly what factor, where
//! crossovers fall (see EXPERIMENTS.md for paper-vs-measured).

pub mod ablations;

use crate::config::platforms::{Platform, ALL_PLATFORMS};
use crate::energy;
use crate::hw;
use crate::kernels::{select_tsar_kernel, TernaryKernel, Tl2Kernel};
use crate::model::zoo::{fig9_models, ModelSpec, MODEL_ZOO};
use crate::model::Workload;
use crate::sim::{simulate, GemmShape, SimResult};
use crate::util::error::{Context, Result};
use crate::util::stats::geomean;
use crate::util::table::Table;
use crate::util::fmt_bytes;

/// Simulated seconds for a full forward pass (T-SAR adaptive kernels or
/// the TL-2 baseline).
pub fn pass_seconds(spec: &'static ModelSpec, plat: &Platform, n: usize, tsar: bool) -> f64 {
    let wl = Workload::new(spec, n);
    let mut total = 0.0;
    for op in &wl.ops {
        let r = run_op(op.shape, plat, tsar);
        total += r.seconds * op.count as f64;
    }
    total * 1.05 // attention / norms / sampling residue
}

fn run_op(shape: GemmShape, plat: &Platform, tsar: bool) -> SimResult {
    if tsar {
        select_tsar_kernel(shape, plat, plat.threads).1
    } else {
        let k = Tl2Kernel::new();
        simulate(&k.profile(shape, plat, plat.threads), plat, plat.threads)
    }
}

/// Request volume (bytes) of a full forward pass.
pub fn pass_request_bytes(spec: &'static ModelSpec, plat: &Platform, n: usize, tsar: bool) -> f64 {
    let wl = Workload::new(spec, n);
    wl.ops
        .iter()
        .map(|op| run_op(op.shape, plat, tsar).request_bytes * op.count as f64)
        .sum()
}

// ---------------------------------------------------------------------------
// Fig. 1(a): model size, ternary vs FP16
// ---------------------------------------------------------------------------

pub fn fig1a() -> Vec<(String, f64, f64)> {
    println!("== Fig. 1(a): ternary 8x size reduction ==");
    let mut t = Table::new(vec!["model", "FP16", "ternary (2b)", "reduction"]);
    let mut rows = Vec::new();
    for m in MODEL_ZOO.iter().filter(|m| m.name.starts_with("BitNet")) {
        t.row(vec![
            m.name.to_string(),
            fmt_bytes(m.fp16_bytes()),
            fmt_bytes(m.ternary_bytes()),
            format!("{:.1}x", m.fp16_bytes() / m.ternary_bytes()),
        ]);
        rows.push((m.name.to_string(), m.fp16_bytes(), m.ternary_bytes()));
    }
    t.print();
    rows
}

// ---------------------------------------------------------------------------
// Fig. 1(c) / Fig. 2(c): TLUT share of memory requests (baseline decode)
// ---------------------------------------------------------------------------

pub fn fig1c() -> Vec<(String, f64)> {
    println!("== Fig. 1(c): TLUT share of baseline memory requests (decode) ==");
    let plat = Platform::workstation();
    let mut t = Table::new(vec!["model", "TLUT MB", "total MB", "TLUT share"]);
    let mut out = Vec::new();
    for spec in MODEL_ZOO.iter().filter(|m| m.name.starts_with("BitNet")) {
        let wl = Workload::decode(spec);
        let kernel = Tl2Kernel::new();
        let mut lut = 0.0;
        let mut total = 0.0;
        for op in &wl.ops {
            let p = kernel.profile(op.shape, &plat, plat.threads);
            lut += p.request_bytes_matching("tlut") * op.count as f64;
            total += p.request_bytes() * op.count as f64;
        }
        let share = lut / total;
        t.row(vec![
            spec.name.to_string(),
            format!("{:.1}", lut / 1e6),
            format!("{:.1}", total / 1e6),
            format!("{:.1}%", share * 100.0),
        ]);
        out.push((spec.name.to_string(), share));
    }
    t.print();
    out
}

/// Fig. 2(c): footprint-vs-accesses contrast for BitNet-2B-4T.
pub fn fig2c() -> Result<(f64, f64)> {
    println!("== Fig. 2(c): BitNet-2B-4T TLUT footprint vs access share ==");
    let plat = Platform::workstation();
    let spec = crate::model::zoo::by_name("BitNet-2B-4T")
        .context("Fig. 2(c) requested unknown model \"BitNet-2B-4T\"")?;
    let wl = Workload::decode(spec);
    let kernel = Tl2Kernel::new();
    let (mut lut_req, mut total_req, mut lut_fp) = (0.0, 0.0, 0.0f64);
    for op in &wl.ops {
        let p = kernel.profile(op.shape, &plat, plat.threads);
        lut_req += p.request_bytes_matching("tlut") * op.count as f64;
        total_req += p.request_bytes() * op.count as f64;
        // The table array is a transient buffer reused across layers:
        // resident footprint = the largest layer's tables, not the sum.
        lut_fp = lut_fp.max(p.stream("tlut-read").map(|s| s.footprint).unwrap_or(0.0));
    }
    let ram = spec.ternary_bytes();
    let fp_share = lut_fp / ram;
    let req_share = lut_req / total_req;
    println!(
        "TLUT footprint {} = {:.3}% of ternary weight RAM {}",
        fmt_bytes(lut_fp),
        fp_share * 100.0,
        fmt_bytes(ram)
    );
    println!("TLUT share of memory requests: {:.1}%", req_share * 100.0);
    Ok((fp_share, req_share))
}

/// Fig. 2(d): baseline GEMV execution-time breakdown (memory vs compute).
pub fn fig2d() -> Result<f64> {
    println!("== Fig. 2(d): TL-2 BitLinear GEMV time breakdown ==");
    let plat = Platform::workstation();
    let spec = crate::model::zoo::by_name("BitNet-2B-4T")
        .context("Fig. 2(d) requested unknown model \"BitNet-2B-4T\"")?;
    let wl = Workload::decode(spec);
    let kernel = Tl2Kernel::new();
    let mut mem_weighted = 0.0;
    let mut total = 0.0;
    for op in &wl.ops {
        let r = simulate(&kernel.profile(op.shape, &plat, plat.threads), &plat, plat.threads);
        mem_weighted += r.mem_bound_frac * r.seconds * op.count as f64;
        total += r.seconds * op.count as f64;
    }
    let frac = mem_weighted / total;
    println!("memory R/W share of execution: {:.1}%", frac * 100.0);
    Ok(frac)
}

// ---------------------------------------------------------------------------
// Fig. 8: end-to-end prefill latency + decode throughput
// ---------------------------------------------------------------------------

pub struct Fig8Row {
    pub model: String,
    pub platform: String,
    pub prefill_tsar_s: f64,
    pub prefill_tl2_s: f64,
    pub decode_tsar_tps: f64,
    pub decode_tl2_tps: f64,
}

pub fn fig8() -> Vec<Fig8Row> {
    println!("== Fig. 8: end-to-end performance across platforms ==");
    let mut rows = Vec::new();
    for kind in ALL_PLATFORMS {
        let plat = Platform::by_kind(kind);
        let mut t = Table::new(vec![
            "model",
            "prefill TL-2 (s)",
            "prefill T-SAR (s)",
            "speedup",
            "decode TL-2 (tok/s)",
            "decode T-SAR (tok/s)",
            "speedup",
        ]);
        let mut prefill_speedups = Vec::new();
        let mut decode_speedups = Vec::new();
        for spec in MODEL_ZOO.iter().filter(|m| m.name.starts_with("BitNet")) {
            let pre_tsar = pass_seconds(spec, &plat, 128, true);
            let pre_tl2 = pass_seconds(spec, &plat, 128, false);
            let dec_tsar = 1.0 / pass_seconds(spec, &plat, 1, true);
            let dec_tl2 = 1.0 / pass_seconds(spec, &plat, 1, false);
            prefill_speedups.push(pre_tl2 / pre_tsar);
            decode_speedups.push(dec_tsar / dec_tl2);
            t.row(vec![
                spec.name.to_string(),
                format!("{:.3}", pre_tl2),
                format!("{:.3}", pre_tsar),
                format!("{:.1}x", pre_tl2 / pre_tsar),
                format!("{:.2}", dec_tl2),
                format!("{:.2}", dec_tsar),
                format!("{:.1}x", dec_tsar / dec_tl2),
            ]);
            rows.push(Fig8Row {
                model: spec.name.to_string(),
                platform: plat.name.clone(),
                prefill_tsar_s: pre_tsar,
                prefill_tl2_s: pre_tl2,
                decode_tsar_tps: dec_tsar,
                decode_tl2_tps: dec_tl2,
            });
        }
        println!(
            "-- {} [{}] ({} threads) --",
            plat.name,
            plat.provenance_label(),
            plat.threads
        );
        t.print();
        println!(
            "geomean prefill speedup {:.1}x | geomean decode speedup {:.1}x\n",
            geomean(&prefill_speedups),
            geomean(&decode_speedups)
        );
    }
    rows
}

// ---------------------------------------------------------------------------
// Fig. 9: memory request volume, T-SAR vs TL-2
// ---------------------------------------------------------------------------

pub struct Fig9Row {
    pub model: String,
    pub phase: &'static str,
    pub tsar_mb: f64,
    pub tl2_mb: f64,
}

pub fn fig9() -> Vec<Fig9Row> {
    println!("== Fig. 9: kernel memory request volume (MB) ==");
    let plat = Platform::workstation();
    let mut rows = Vec::new();
    for (phase, n) in [("GEMM(N=128)", 128usize), ("GEMV(N=1)", 1)] {
        let mut t = Table::new(vec!["model", "TL-2 MB", "T-SAR MB", "reduction"]);
        for spec in fig9_models() {
            let tsar = pass_request_bytes(spec, &plat, n, true) / 1e6;
            let tl2 = pass_request_bytes(spec, &plat, n, false) / 1e6;
            t.row(vec![
                spec.name.to_string(),
                format!("{:.0}", tl2),
                format!("{:.0}", tsar),
                format!("{:.1}x", tl2 / tsar),
            ]);
            rows.push(Fig9Row {
                model: spec.name.to_string(),
                phase,
                tsar_mb: tsar,
                tl2_mb: tl2,
            });
        }
        println!("-- {phase} --");
        t.print();
    }
    rows
}

// ---------------------------------------------------------------------------
// Fig. 10: multi-thread scaling on BitNet-2B-4T shapes
// ---------------------------------------------------------------------------

pub struct Fig10Point {
    pub platform: String,
    pub shape: GemmShape,
    pub threads: usize,
    pub tsar_s: f64,
    pub tl2_s: f64,
}

pub fn fig10_shapes() -> [GemmShape; 4] {
    [
        GemmShape::new(128, 2560, 6912),
        GemmShape::new(128, 6912, 2560),
        GemmShape::new(1, 2560, 6912),
        GemmShape::new(1, 6912, 2560),
    ]
}

pub fn fig10() -> Vec<Fig10Point> {
    println!("== Fig. 10: multi-thread scaling (BitNet-2B-4T shapes) ==");
    let mut out = Vec::new();
    for kind in ALL_PLATFORMS {
        let plat = Platform::by_kind(kind);
        for shape in fig10_shapes() {
            let mut t = Table::new(vec![
                "threads",
                "TL-2 (ms)",
                "T-SAR (ms)",
                "speedup",
                "T-SAR scaling",
            ]);
            let mut base_tsar = 0.0;
            for tn in [1usize, 2, 4, 8, 16] {
                if tn > plat.cores {
                    continue;
                }
                let tsar = {
                    let (k, _) = select_tsar_kernel(shape, &plat, tn);
                    simulate(&k.profile(shape, &plat, tn), &plat, tn)
                };
                let tl2 = {
                    let k = Tl2Kernel::new();
                    simulate(&k.profile(shape, &plat, tn), &plat, tn)
                };
                if tn == 1 {
                    base_tsar = tsar.seconds;
                }
                t.row(vec![
                    tn.to_string(),
                    format!("{:.3}", tl2.seconds * 1e3),
                    format!("{:.3}", tsar.seconds * 1e3),
                    format!("{:.1}x", tl2.seconds / tsar.seconds),
                    format!("{:.2}x", base_tsar / tsar.seconds),
                ]);
                out.push(Fig10Point {
                    platform: plat.name.clone(),
                    shape,
                    threads: tn,
                    tsar_s: tsar.seconds,
                    tl2_s: tl2.seconds,
                });
            }
            println!(
                "-- {} [{}] {}x{}x{} --",
                plat.name,
                plat.provenance_label(),
                shape.n,
                shape.k,
                shape.m
            );
            t.print();
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Tables I–III
// ---------------------------------------------------------------------------

pub fn table1() {
    println!("== Table I: gem5-substitute platform configurations ==");
    let mut t = Table::new(vec![
        "System", "CPU", "Cores", "Freq", "L1D", "L2", "L3", "DRAM BW",
    ]);
    for kind in ALL_PLATFORMS {
        let p = Platform::by_kind(kind);
        t.row(vec![
            p.name.clone(),
            p.cpu_model.clone(),
            p.cores.to_string(),
            format!("{:.1} GHz", p.freq_ghz),
            fmt_bytes(p.l1d.size_bytes as f64),
            fmt_bytes(p.l2.size_bytes as f64),
            fmt_bytes(p.l3.size_bytes as f64),
            format!("{:.1} GB/s", p.dram_bw_gbps),
        ]);
    }
    t.print();
}

pub fn table2() {
    println!("== Table II: 256-bit SIMD slice synthesis (28nm model) ==");
    let (rows, total) = hw::table2();
    let mut t = Table::new(vec![
        "Block", "Area base", "Area T-SAR", "dA", "Pwr base", "Pwr T-SAR", "dP",
    ]);
    let (ba, bp) = (rows[0].base_area, rows[0].base_power);
    for r in rows.iter().chain(std::iter::once(&total)) {
        t.row(vec![
            r.name.to_string(),
            format!("{:.0}", r.base_area),
            format!("{:.0}", r.tsar_area),
            format!("{:+.1}%", (r.tsar_area - r.base_area) / ba * 100.0),
            format!("{:.0}", r.base_power),
            format!("{:.0}", r.tsar_power),
            format!("{:+.1}%", (r.tsar_power - r.base_power) / bp * 100.0),
        ]);
    }
    t.print();
    println!(
        "headline: area {:+.1}%, power {:+.1}%",
        hw::area_overhead_frac() * 100.0,
        hw::power_overhead_frac() * 100.0
    );
}

pub fn table3() -> Result<()> {
    println!("== Table III: cross-platform decode throughput & energy ==");
    let profiles: Vec<String> = ALL_PLATFORMS
        .iter()
        .map(|&kind| {
            let p = Platform::by_kind(kind);
            format!("{} [{}]", p.name, p.provenance_label())
        })
        .collect();
    println!("platform profiles: {}", profiles.join(", "));
    for name in ["Llama-b1.58-8B", "Falcon3-b1.58-10B"] {
        let spec = crate::model::zoo::by_name(name)
            .with_context(|| format!("Table III requested unknown model {name:?}"))?;
        println!("-- {name} --");
        let rows = energy::table3_rows(spec);
        let mut t = Table::new(vec!["Platform", "node", "tokens/s", "J/token"]);
        for r in &rows {
            t.row(vec![
                r.platform.clone(),
                r.node.to_string(),
                format!("{:.2}", r.tokens_per_s),
                format!("{:.3}", r.joules_per_token),
            ]);
        }
        t.print();
        for (platform, tps_ratio, eff_ratio) in table3_comparisons(&rows)? {
            println!(
                "{platform:<14} vs Jetson: {tps_ratio:.1}x tokens/s, \
                 {eff_ratio:.1}x energy efficiency"
            );
        }
    }
    Ok(())
}

/// Baseline-relative Table III comparisons: `(short platform name,
/// tokens/s ratio, energy-efficiency ratio)` per CPU row.
///
/// The Jetson baseline is selected *by platform name*, never by
/// position, so a shuffled, reordered or extended platform list cannot
/// silently change the denominator (the old code assumed the baseline
/// was the final row), and malformed rows return errors instead of
/// panicking.
pub fn table3_comparisons(
    rows: &[energy::CrossPlatformRow],
) -> Result<Vec<(String, f64, f64)>> {
    let jetson = rows
        .iter()
        .find(|r| r.platform.contains("Jetson"))
        .context("Table III rows are missing the Jetson baseline row")?;
    let mut out = Vec::new();
    for r in rows.iter().filter(|r| !r.platform.contains("Jetson")) {
        let short = r.platform.split_whitespace().next().with_context(|| {
            format!(
                "Table III row with empty platform name ({:.2} tokens/s)",
                r.tokens_per_s
            )
        })?;
        out.push((
            short.to_string(),
            r.tokens_per_s / jetson.tokens_per_s,
            jetson.joules_per_token / r.joules_per_token,
        ));
    }
    Ok(out)
}

/// §IV-C LLC hit-rate shifts.
pub fn llc_report() {
    println!("== §IV-C: LLC hit-rate shifts (TL-2 -> T-SAR) ==");
    let mut t = Table::new(vec![
        "platform", "shape", "TL-2 LLC hit", "T-SAR LLC hit",
    ]);
    for (kind, shape) in [
        (crate::config::PlatformKind::Mobile, GemmShape::new(1, 8192, 45568)),
        (crate::config::PlatformKind::Workstation, GemmShape::new(128, 2560, 6912)),
    ] {
        let plat = Platform::by_kind(kind);
        let tl2 = simulate(
            &Tl2Kernel::new().profile(shape, &plat, plat.threads),
            &plat,
            plat.threads,
        );
        let (k, _) = select_tsar_kernel(shape, &plat, plat.threads);
        let tsar = simulate(&k.profile(shape, &plat, plat.threads), &plat, plat.threads);
        t.row(vec![
            plat.name.clone(),
            format!("{}x{}x{}", shape.n, shape.k, shape.m),
            format!("{:.0}%", tl2.llc_hit_rate * 100.0),
            format!("{:.0}%", tsar.llc_hit_rate * 100.0),
        ]);
    }
    t.print();
}

/// Everything, in paper order.
pub fn report_all() -> Result<()> {
    fig1a();
    println!();
    fig1c();
    println!();
    fig2c()?;
    println!();
    fig2d()?;
    println!();
    fig8();
    println!();
    fig9();
    println!();
    fig10();
    println!();
    table1();
    println!();
    table2();
    println!();
    table3()?;
    println!();
    llc_report();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_tsar_always_wins() {
        let rows = fig9();
        for r in &rows {
            assert!(
                r.tl2_mb > r.tsar_mb,
                "{} {}: TL-2 {} <= T-SAR {}",
                r.model,
                r.phase,
                r.tl2_mb,
                r.tsar_mb
            );
        }
    }

    #[test]
    fn fig1c_share_exceeds_75_percent() {
        let shares = fig1c();
        for (model, share) in &shares {
            assert!(share > &0.70, "{model}: TLUT share {share:.2}");
        }
    }

    #[test]
    fn fig2d_memory_dominates_baseline() {
        // Paper: 91.6%.  Our model is charitable to the baseline's
        // compute overlap; require a clear memory-dominated majority.
        assert!(fig2d().unwrap() > 0.65);
    }

    fn row(platform: &str, tps: f64, jpt: f64) -> energy::CrossPlatformRow {
        energy::CrossPlatformRow {
            platform: platform.to_string(),
            node: "test".into(),
            tokens_per_s: tps,
            joules_per_token: jpt,
        }
    }

    #[test]
    fn table3_baseline_found_by_name_in_shuffled_order() {
        // Regression: the baseline used to be `rows.last()`; pin that a
        // shuffled platform list yields identical comparisons.
        let ordered = vec![
            row("Workstation CPU (X, T-SAR)", 20.0, 1.0),
            row("Laptop CPU (Y, T-SAR)", 10.0, 2.0),
            row("Jetson AGX Orin GPU (llama.cpp)", 5.0, 4.0),
        ];
        let shuffled = vec![ordered[2].clone(), ordered[0].clone(), ordered[1].clone()];
        let mut a = table3_comparisons(&ordered).unwrap();
        let mut b = table3_comparisons(&shuffled).unwrap();
        a.sort_by(|x, y| x.0.cmp(&y.0));
        b.sort_by(|x, y| x.0.cmp(&y.0));
        assert_eq!(a.len(), 2);
        for ((pa, ta, ea), (pb, tb, eb)) in a.iter().zip(&b) {
            assert_eq!(pa, pb);
            assert!((ta - tb).abs() < 1e-12 && (ea - eb).abs() < 1e-12);
        }
        // Ratios are against the Jetson row, wherever it sits.
        let ws = a.iter().find(|r| r.0 == "Workstation").unwrap();
        assert!((ws.1 - 4.0).abs() < 1e-12, "tokens/s ratio {}", ws.1);
        assert!((ws.2 - 4.0).abs() < 1e-12, "efficiency ratio {}", ws.2);
    }

    #[test]
    fn table3_malformed_rows_error_instead_of_panicking() {
        // No Jetson baseline at all.
        let no_jetson = vec![row("Workstation CPU", 20.0, 1.0)];
        let e = table3_comparisons(&no_jetson).unwrap_err();
        assert!(e.to_string().contains("Jetson"), "{e}");
        // Empty platform name on a CPU row.
        let empty_name = vec![
            row("", 20.0, 1.0),
            row("Jetson AGX Orin GPU (llama.cpp)", 5.0, 4.0),
        ];
        let e = table3_comparisons(&empty_name).unwrap_err();
        assert!(e.to_string().contains("empty platform name"), "{e}");
    }

    #[test]
    fn table3_real_rows_pin_jetson_by_name() {
        // The real generator appends Jetson last today; reverse it to
        // prove selection no longer depends on that.
        let spec = crate::model::zoo::by_name("Llama-b1.58-8B").unwrap();
        let mut rows = energy::table3_rows(spec);
        rows.reverse();
        let cmps = table3_comparisons(&rows).unwrap();
        assert_eq!(cmps.len(), rows.len() - 1);
        assert!(cmps.iter().all(|c| !c.0.contains("Jetson")));
        // Workstation beats Jetson in throughput (Table III headline).
        let ws = cmps.iter().find(|c| c.0 == "Workstation").unwrap();
        assert!(ws.1 > 1.0);
    }
}
