//! Ablation studies for the design choices DESIGN.md calls out and the
//! paper's §V extension claims:
//!
//! * **A1 — decomposition vs placement**: is the win from the ternary→
//!   binary LUT *compression* (3^c → 2·2^c) or from moving LUTs
//!   *in-register*?  We build a hypothetical "compressed but in-memory"
//!   kernel and compare all three.
//! * **A2 — block size c**: 2 vs 4 across the Fig. 10 shapes.
//! * **A3 — dataflows**: AP-min / AP-max / OP per shape.
//! * **A4 — sparsity skip** (§V "integrates naturally with sparsity"):
//!   zero-block skipping in TGEMV as a function of weight sparsity.
//! * **A5 — ISA-family retargeting** (§V / footnote 1): AVX2 vs NEON vs
//!   RVV with retuned (c,s,k,m).

use crate::config::isa_family::ALL_FAMILIES;
use crate::config::platforms::Platform;
use crate::config::IsaConfig;
use crate::kernels::{Dataflow, TernaryKernel, Tl2Kernel, TsarKernel};
use crate::sim::{simulate, GemmShape, KernelProfile, Stream};
use crate::util::error::{Context, Result};
use crate::util::table::Table;

/// A1: hypothetical kernel with T-SAR's compressed binary LUTs kept in
/// *memory* (TL-2-style placement): same table traffic structure as
/// TL-2 but with 2·2^c 16-bit entries per c-block and 2 b/w weights.
fn compressed_in_memory_profile(
    shape: GemmShape,
    c: usize,
    plat: &Platform,
    threads: usize,
) -> KernelProfile {
    let base = Tl2Kernel::new().profile(shape, plat, threads);
    let (nf, kf, mf) = (shape.n as f64, shape.k as f64, shape.m as f64);
    let blocks = kf / c as f64;
    let table_bytes = 2.0 * (1usize << c) as f64 * 2.0; // dense+sparse, 16-bit
    let m_res = if shape.is_gemv() { 8.0 } else { 32.0 };
    let table_fp = blocks * table_bytes;
    let mut streams: Vec<Stream> = base
        .streams
        .iter()
        .filter(|s| !s.name.contains("tlut") && !s.name.contains("weights"))
        .cloned()
        .collect();
    // 2 b/w weights (the decomposition's packing).
    streams.push(Stream::read_once("weights-cold", kf * mf / 4.0));
    streams.push(Stream {
        name: "tlut-build",
        footprint: table_fp,
        bytes_accessed: nf * table_fp,
        passes: nf,
        write_frac: 1.0,
        dependent: false,
    });
    streams.push(Stream {
        name: "tlut-read",
        footprint: table_fp,
        bytes_accessed: nf * (mf / m_res).ceil() * blocks * table_bytes,
        passes: nf * (mf / m_res).ceil(),
        write_frac: 0.0,
        dependent: true, // still memory gathers — placement unchanged
    });
    KernelProfile {
        kernel: format!("binary-LUT-in-memory(c={c})"),
        shape,
        streams,
        simd_uops: base.simd_uops,
        scalar_uops: base.scalar_uops,
    }
}

/// A1 rows: (kernel, time_ms, request_MB) for the decode GEMV shape.
pub fn ablation_decomposition() -> Vec<(String, f64, f64)> {
    println!("== A1: LUT compression vs in-register placement (1x2560x6912, Workstation, 1 thread) ==");
    let plat = Platform::workstation();
    let shape = GemmShape::new(1, 2560, 6912);
    let t = 1; // single-core view isolates the gather wall from the DRAM floor
    let mut rows = Vec::new();

    let tl2 = Tl2Kernel::new().profile(shape, &plat, t);
    let hybrid = compressed_in_memory_profile(shape, 2, &plat, t);
    let tsar = TsarKernel::new(IsaConfig::C2, Dataflow::Op).profile(shape, &plat, t);

    let mut tab = Table::new(vec!["kernel", "time (us)", "requests (MB)", "LUT bytes/block"]);
    for (p, lut_note) in [(&tl2, "3^3 x 16b in mem"), (&hybrid, "2*2^2 x 16b in mem"), (&tsar, "2*2^2 x 16b in REGS")] {
        let r = simulate(p, &plat, t);
        tab.row(vec![
            p.kernel.clone(),
            format!("{:.2}", r.seconds * 1e6),
            format!("{:.1}", r.request_bytes / 1e6),
            lut_note.to_string(),
        ]);
        rows.push((p.kernel.clone(), r.seconds, r.request_bytes));
    }
    tab.print();
    println!("(compression alone keeps the gather bottleneck; the register move is the win)");
    rows
}

/// A2/A3: c ∈ {2,4} × dataflow grid over the Fig. 10 shapes.
pub fn ablation_config_dataflow() {
    println!("== A2/A3: block size x dataflow (Workstation, protocol threads) ==");
    let plat = Platform::workstation();
    for shape in super::fig10_shapes() {
        let mut tab = Table::new(vec!["variant", "time (ms)", "req (MB)", "SIMD uops (M)"]);
        for cfg in [IsaConfig::C2, IsaConfig::C4] {
            for df in [Dataflow::ApMin, Dataflow::ApMax, Dataflow::Op] {
                let k = TsarKernel::new(cfg, df);
                let p = k.profile(shape, &plat, plat.threads);
                let r = simulate(&p, &plat, plat.threads);
                tab.row(vec![
                    k.name(),
                    format!("{:.3}", r.seconds * 1e3),
                    format!("{:.1}", r.request_bytes / 1e6),
                    format!("{:.1}", p.simd_uops / 1e6),
                ]);
            }
        }
        println!("-- {}x{}x{} --", shape.n, shape.k, shape.m);
        tab.print();
    }
}

/// A4: sparsity-aware TGEMV (§V): blocks whose c weights are all zero
/// (sparse index = 2^c − 1 after densification ⇒ contribution exactly 0)
/// can be skipped.  With i.i.d. zero fraction z, a c-block is skippable
/// with probability z^c; report the expected TGEMV µ-op and weight-
/// traffic savings across z.
pub fn ablation_sparsity() -> Vec<(f64, f64, f64)> {
    println!("== A4: zero-block skipping vs weight sparsity (c=2 and c=4) ==");
    let mut tab = Table::new(vec![
        "zero frac", "skip p (c=2)", "uop savings c=2", "skip p (c=4)", "uop savings c=4",
    ]);
    let mut rows = Vec::new();
    for z in [0.0, 0.1, 0.3, 0.33, 0.5, 0.7, 0.9] {
        let p2 = z * z;
        let p4 = z * z * z * z;
        // TGEMV work scales with non-skipped blocks; TLUT unchanged.
        tab.row(vec![
            format!("{z:.2}"),
            format!("{:.3}", p2),
            format!("{:.1}%", p2 * 100.0),
            format!("{:.3}", p4),
            format!("{:.1}%", p4 * 100.0),
        ]);
        rows.push((z, p2, p4));
    }
    tab.print();
    println!("(BitNet-like z~0.33: c=2 skips ~11% of blocks; structured sparsity would raise this)");
    rows
}

/// A5: ISA-family retargeting (footnote 1): decode tok/s per family on
/// BitNet-2B-4T, with per-family register budgets and issue scaling.
pub fn ablation_isa_family() -> Result<Vec<(&'static str, f64)>> {
    println!("== A5: ISA family retargeting (BitNet-2B-4T decode, Workstation-class core) ==");
    let spec = crate::model::zoo::by_name("BitNet-2B-4T")
        .context("A5 ISA-family ablation requested unknown model \"BitNet-2B-4T\"")?;
    let mut tab = Table::new(vec!["family", "config", "regs for LUTs", "tok/s"]);
    let mut out = Vec::new();
    for fam in ALL_FAMILIES {
        let mut plat = Platform::workstation();
        plat.simd_ports *= fam.throughput_scale();
        let cfg = fam.configs()[0];
        let kern = TsarKernel::new(cfg, Dataflow::Op);
        let wl = crate::model::Workload::decode(spec);
        let mut secs = 0.0;
        for op in &wl.ops {
            let r = simulate(&kern.profile(op.shape, &plat, plat.threads), &plat, plat.threads);
            secs += r.seconds * op.count as f64;
        }
        secs *= 1.05;
        tab.row(vec![
            fam.name().to_string(),
            cfg.name(),
            format!("{}", fam.lut_group_budget(&cfg) * fam.tlut_result_regs(&cfg)),
            format!("{:.1}", 1.0 / secs),
        ]);
        out.push((fam.name(), 1.0 / secs));
    }
    tab.print();
    println!("(decode is bandwidth-bound: the narrower NEON datapath costs little — the paper's portability claim)");
    Ok(out)
}

pub fn all() -> Result<()> {
    ablation_decomposition();
    println!();
    ablation_config_dataflow();
    println!();
    ablation_sparsity();
    println!();
    ablation_isa_family()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a1_in_register_beats_in_memory_beats_tl2() {
        let rows = ablation_decomposition();
        assert_eq!(rows.len(), 3);
        let (tl2, hybrid, tsar) = (&rows[0], &rows[1], &rows[2]);
        // Compression helps a little; placement is the big win.
        assert!(hybrid.2 < tl2.2, "compression should cut request bytes");
        assert!(tsar.1 < hybrid.1 * 0.6, "in-register must beat in-memory clearly");
        assert!(tsar.2 < hybrid.2 * 0.5);
    }

    #[test]
    fn a4_sparsity_monotone() {
        let rows = ablation_sparsity();
        for w in rows.windows(2) {
            assert!(w[1].1 >= w[0].1 && w[1].2 >= w[0].2);
        }
        // c=2 always skips at least as often as c=4 for z<1.
        for (z, p2, p4) in &rows {
            if *z < 1.0 {
                assert!(p2 >= p4);
            }
        }
    }

    #[test]
    fn a5_all_families_run() {
        let rows = ablation_isa_family().unwrap();
        assert_eq!(rows.len(), 3);
        for (fam, tps) in &rows {
            assert!(*tps > 0.0, "{fam} produced no throughput");
        }
        // Decode is bandwidth-bound: families should land within 2x.
        let tps: Vec<f64> = rows.iter().map(|r| r.1).collect();
        let (lo, hi) = (tps.iter().cloned().fold(f64::INFINITY, f64::min),
                        tps.iter().cloned().fold(0.0f64, f64::max));
        assert!(hi / lo < 2.0, "portable-speedup claim: {lo:.1}..{hi:.1}");
    }
}
