//! Calibration constants of the baseline kernel models.
//!
//! We cannot run bitnet.cpp / T-MAC binaries inside this environment, so
//! their memory behaviour is modeled structurally from their published
//! kernel designs, with the residency constants below calibrated so the
//! TL-2 figures land in the bands the paper *measured* (Fig. 9's
//! 8.7–13.8× request-volume reduction, Fig. 2(c)'s ~87% TLUT share).
//! Every constant has a microarchitectural justification; see DESIGN.md
//! §2 (substitution table) and EXPERIMENTS.md for the sensitivity check.

/// TL-2 groups 3 ternary weights into one 5-bit code (1.67 b/w).
pub const TL2_GROUP: usize = 3;

/// Bytes of one TL-2 lookup table: 3^3 = 27 16-bit partial sums padded to
/// 32 entries (bitnet.cpp stores sign-expanded int16 entries).
pub const TL2_TABLE_BYTES: f64 = 64.0;

/// GEMV: outputs processed per table residency.  AVX2 has 16 YMM regs;
/// the TL-2 GEMV microkernel keeps accumulators, weight codes and scales
/// resident, leaving room to keep one int16 table (2 regs, hi/lo pshufb
/// halves) live for ~8 outputs before it is evicted and re-fetched.
pub const TL2_GEMV_M_RESIDENCY: f64 = 8.0;

/// GEMM: with the N-loop providing register-level reuse of accumulators,
/// the microkernel affords a wider M tile per table fetch (~32 outputs).
pub const TL2_GEMM_M_RESIDENCY: f64 = 32.0;

/// T-MAC groups 4 weights per 4-bit LUT index.
pub const TMAC_GROUP: usize = 4;

/// One T-MAC table: 2^4 = 16 int8 entries for the pshufb low half plus
/// the mirrored high half = 32 B.
pub const TMAC_TABLE_BYTES: f64 = 32.0;

/// T-MAC keeps int8 tables in fewer registers: ~8 outputs per residency
/// for GEMV, ~32 for GEMM (same reasoning as TL-2).
pub const TMAC_GEMV_M_RESIDENCY: f64 = 8.0;
pub const TMAC_GEMM_M_RESIDENCY: f64 = 32.0;

/// Lookup µ-ops per 8 table lookups for the pshufb-based baselines
/// (2 pshufb + unpack + add per 8 entries of int16 result).
pub const BASELINE_UOPS_PER_8_LOOKUPS: f64 = 4.0;

/// T-SAR GEMM: rows of LUTs held register-resident simultaneously in the
/// AP-max dataflow is bounded by the register file (16 YMM): see
/// `TsarKernel::lut_groups`.
pub const TSAR_STAGING_REGS: usize = 2; // activation + weight staging
pub const TSAR_ACC_REGS: usize = 2; // one 32-bit accumulator pair

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tl2_density() {
        // 5 bits / 3 weights = 1.67 b/w, the paper's quoted density.
        assert!((5.0 / TL2_GROUP as f64 - 1.667).abs() < 0.01);
    }

    #[test]
    fn residency_ordering() {
        // GEMM affords wider residency than GEMV for both baselines.
        assert!(TL2_GEMM_M_RESIDENCY > TL2_GEMV_M_RESIDENCY);
        assert!(TMAC_GEMM_M_RESIDENCY > TMAC_GEMV_M_RESIDENCY);
    }
}
