//! BitNet.cpp **TL-2** baseline model (paper §IV-A; Wang et al. 2025).
//!
//! TL-2 packs 3 ternary weights into a 5-bit code (1.67 b/w) and
//! precomputes, per 3-activation block, a table of all 27 partial sums
//! stored **in memory** (Fig. 3(a)).  At run time every (output, block)
//! pair fetches its table (register residency permitting) and looks up
//! one 16-bit entry per weight code.  That table traffic is the memory
//! bottleneck T-SAR removes — Fig. 2(c)/(d) and Fig. 9.
//!
//! The functional path executes exactly this algorithm (table build +
//! indexed lookups over the packed codes); the profile charges the table
//! build, the table re-fetches (with the calibrated register-residency
//! constants in [`super::params`]) and TL-2's denser weight stream.

use crate::config::platforms::Platform;
use crate::quant::pack::Tl2Packed;
use crate::sim::{GemmShape, KernelProfile, Stream};

use super::params::{
    BASELINE_UOPS_PER_8_LOOKUPS, TL2_GEMM_M_RESIDENCY, TL2_GEMV_M_RESIDENCY,
    TL2_GROUP, TL2_TABLE_BYTES,
};
use super::{quant_dequant_streams, quant_dequant_uops, TernaryKernel};

#[derive(Debug, Clone, Copy, Default)]
pub struct Tl2Kernel;

impl Tl2Kernel {
    pub fn new() -> Tl2Kernel {
        Tl2Kernel
    }

    /// Build the 27-entry table for one 3-activation block: entry `code`
    /// holds Σ_i digit_i(code)·a_i with digits in {-1,0,1} (msb-first,
    /// matching `Tl2Packed`'s base-3 encoding).
    fn build_table(block: &[i8]) -> [i32; 27] {
        assert_eq!(block.len(), TL2_GROUP);
        let mut t = [0i32; 27];
        for code in 0..27usize {
            let mut c = code;
            let mut digits = [0i32; 3];
            for i in (0..3).rev() {
                digits[i] = (c % 3) as i32 - 1;
                c /= 3;
            }
            t[code] = digits
                .iter()
                .zip(block)
                .map(|(&d, &a)| d * a as i32)
                .sum();
        }
        t
    }
}

impl TernaryKernel for Tl2Kernel {
    fn name(&self) -> String {
        "TL-2".into()
    }

    fn run(&self, acts: &[i8], w_t: &[i8], shape: GemmShape) -> Vec<i32> {
        let GemmShape { n, k, m } = shape;
        assert_eq!(acts.len(), n * k);
        assert_eq!(w_t.len(), m * k);
        let packed = Tl2Packed::pack(w_t, m, k);
        let groups = packed.groups_per_row;
        let mut out = vec![0i32; n * m];
        for row in 0..n {
            let a = &acts[row * k..(row + 1) * k];
            // Phase 1: table precompute for every block (stored in the
            // "memory-resident TLUT" — a plain Vec here).
            let mut tables = Vec::with_capacity(groups);
            for g in 0..groups {
                let mut block = [0i8; TL2_GROUP];
                for i in 0..TL2_GROUP {
                    let col = g * TL2_GROUP + i;
                    block[i] = if col < k { a[col] } else { 0 };
                }
                tables.push(Self::build_table(&block));
            }
            // Phase 2: indexed lookups per (output, block).
            for j in 0..m {
                let mut acc = 0i32;
                for g in 0..groups {
                    let code = packed.code(j, g) as usize;
                    acc += tables[g][code];
                }
                out[row * m + j] = acc;
            }
        }
        out
    }

    fn profile(&self, shape: GemmShape, plat: &Platform, threads: usize) -> KernelProfile {
        let (nf, kf, mf) = (shape.n as f64, shape.k as f64, shape.m as f64);
        let blocks = (kf / TL2_GROUP as f64).ceil();
        let m_res = if shape.is_gemv() {
            TL2_GEMV_M_RESIDENCY
        } else {
            TL2_GEMM_M_RESIDENCY
        };

        let mut streams = quant_dequant_streams(shape);
        let mut simd_uops = quant_dequant_uops(shape);

        // Weight codes: 1.67 b/w, cold pass + per-row re-reads (GEMM).
        let wbytes = mf * blocks * 5.0 / 8.0;
        streams.push(Stream::read_once("weights-cold", wbytes));
        if nf > 1.0 {
            streams.push(Stream {
                name: "weights-tile",
                footprint: (blocks * 5.0 / 8.0 * m_res * 64.0).min(wbytes),
                bytes_accessed: (nf - 1.0) * wbytes,
                passes: nf - 1.0,
                write_frac: 0.0,
                dependent: false,
            });
        }

        // Activations feed the table build.
        streams.push(Stream::read_once("acts", nf * kf));

        // TLUT build: one table per (row, block) written to memory.
        let table_fp = blocks * TL2_TABLE_BYTES; // per-row table array
        streams.push(Stream {
            name: "tlut-build",
            footprint: table_fp,
            bytes_accessed: nf * table_fp,
            passes: nf,
            write_frac: 1.0,
            dependent: false,
        });
        simd_uops += nf * blocks * (TL2_TABLE_BYTES / 2.0) / 16.0 * 2.0;

        // TLUT fetch: every (row, m-residency group, block) re-fetches
        // the block's table — the dominant request stream (Fig. 2(c)).
        let lut_read = nf * (mf / m_res).ceil() * blocks * TL2_TABLE_BYTES;
        streams.push(Stream {
            name: "tlut-read",
            footprint: table_fp,
            bytes_accessed: lut_read,
            passes: nf * (mf / m_res).ceil(),
            write_frac: 0.0,
            // Table addresses depend on just-loaded weight codes: these
            // gathers cannot be prefetched (Fig. 2(d)'s latency wall).
            dependent: true,
        });

        // Lookup compute: pshufb-class µ-ops.
        let lookups = nf * mf * blocks;
        simd_uops += lookups / 8.0 * BASELINE_UOPS_PER_8_LOOKUPS;

        streams.push(Stream::write_once("out", nf * mf * 4.0));

        let _ = (plat, threads);
        KernelProfile {
            kernel: self.name(),
            shape,
            streams,
            simd_uops,
            scalar_uops: simd_uops * 0.25,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::scalar_gemm;
    use crate::util::rng::Rng;

    #[test]
    fn functional_matches_scalar() {
        let mut rng = Rng::new(31);
        for shape in [GemmShape::new(1, 48, 20), GemmShape::new(3, 50, 17)] {
            let acts = rng.int8_acts(shape.n * shape.k);
            let w = rng.ternary_matrix(shape.m, shape.k, 0.33);
            assert_eq!(
                Tl2Kernel::new().run(&acts, &w, shape),
                scalar_gemm(&acts, &w, shape),
                "{shape:?}"
            );
        }
    }

    #[test]
    fn table_entries_cover_all_codes() {
        let t = Tl2Kernel::build_table(&[1, -2, 3]);
        // code of (w0,w1,w2)=(1,1,1) -> digits (0,0,0)+1 each -> base-3 26.
        assert_eq!(t[26], 1 - 2 + 3);
        // all-zero weights -> code 13 (digits 1,1,1 -> w=0).
        assert_eq!(t[13], 0);
        // (-1,-1,-1) -> code 0.
        assert_eq!(t[0], -(1 - 2 + 3));
    }

    #[test]
    fn profile_is_tlut_dominated_for_gemv() {
        // Fig. 2(c): TLUT accesses dominate the baseline's request volume.
        let plat = Platform::workstation();
        let p = Tl2Kernel::new().profile(GemmShape::new(1, 2560, 6912), &plat, 1);
        let lut = p.request_bytes_matching("tlut");
        let share = lut / p.request_bytes();
        assert!(share > 0.75, "TLUT share {share:.2} should exceed 75%");
    }

    #[test]
    fn tlut_footprint_tiny_but_traffic_huge() {
        // Fig. 2(c)'s contrast: table footprint is tiny relative to
        // weights, but its requested bytes dwarf everything else.
        let plat = Platform::workstation();
        let p = Tl2Kernel::new().profile(GemmShape::new(1, 2560, 6912), &plat, 1);
        let lut = p.stream("tlut-read").unwrap();
        let w = p.stream("weights-cold").unwrap();
        assert!(lut.footprint < 0.02 * w.footprint);
        assert!(lut.bytes_accessed > 5.0 * w.bytes_accessed);
    }
}
