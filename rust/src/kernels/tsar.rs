//! The six T-SAR software kernels (paper §III-D, §IV-A): dataflows
//! {AP-min, AP-max, OP} × ISA configs {TLUT_2×4+TGEMV_8×16,
//! TLUT_4×4+TGEMV_16×16}.
//!
//! Loop nests (shared by the functional path and the profile):
//!
//! **AP (activation-persistent, Fig. 7(a))** — outer loop over K-chunks
//! of `G·k` inputs whose LUTs stay register-resident; per chunk, per row,
//! the full M sweep runs before the LUTs are replaced.  TLUT invocations
//! are minimal (`N·K/k`), weight slices get row-to-row reuse, but partial
//! output sums spill to memory between chunks.  `G` (LUT register
//! groups) distinguishes AP-min (G=1, minimal register use) from AP-max
//! (all spare registers hold LUTs).
//!
//! **OP (output-persistent, Fig. 7(b))** — outer loop over accumulator
//! tiles of `m_acc` outputs held in registers; the K loop streams past
//! them, rebuilding LUTs per (tile, k-slice).  No partial-sum traffic at
//! the cost of `M/m_acc ×` more TLUT work — the trade the paper's
//! adaptive selector exploits per layer.

use crate::config::IsaConfig;
use crate::config::platforms::Platform;
use crate::quant::encode_indices;
use crate::sim::{GemmShape, KernelProfile, Stream};
use crate::simd::RegFile;
use crate::tsar::exec::{tgemv_slices, tlut};
use crate::tsar::uops::{tgemv_uops, tlut_uops};

use super::params::{TSAR_ACC_REGS, TSAR_STAGING_REGS};
use super::{quant_dequant_streams, quant_dequant_uops, TernaryKernel};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataflow {
    /// Activation-persistent, one LUT register group.
    ApMin,
    /// Activation-persistent, all spare registers hold LUT groups.
    ApMax,
    /// Output-persistent.
    Op,
}

impl Dataflow {
    pub fn name(&self) -> &'static str {
        match self {
            Dataflow::ApMin => "AP-min",
            Dataflow::ApMax => "AP-max",
            Dataflow::Op => "OP",
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct TsarKernel {
    pub isa: IsaConfig,
    pub dataflow: Dataflow,
}

impl TsarKernel {
    pub fn new(isa: IsaConfig, dataflow: Dataflow) -> TsarKernel {
        isa.validate().expect("invalid ISA config");
        TsarKernel { isa, dataflow }
    }

    /// LUT register groups held resident (the AP `G` parameter).
    pub fn lut_groups(&self) -> usize {
        let spare = 16 - TSAR_STAGING_REGS - TSAR_ACC_REGS;
        match self.dataflow {
            Dataflow::ApMin => 1,
            Dataflow::ApMax | Dataflow::Op => {
                (spare / self.isa.tlut_result_regs()).max(1)
            }
        }
    }

    /// OP: output accumulators held register-resident (multiple of the
    /// TGEMV m), from the registers not holding one LUT group + staging.
    pub fn m_acc(&self) -> usize {
        let spare = 16 - TSAR_STAGING_REGS - self.isa.tlut_result_regs();
        // Each YMM holds 8 × 32-bit accumulators.
        let outs = (spare * 8 / self.isa.m).max(1) * self.isa.m;
        outs
    }

    // -- functional helpers --------------------------------------------------

    /// Pre-encode the padded weight matrix once per `run` call (the
    /// paper's compile-time encoding, Fig. 5): per (m-tile, k-slice) the
    /// TGEMV weight operand slices straight out of this buffer — no
    /// per-tile allocation or re-encoding on the hot path (§Perf L3).
    fn encode_weights(&self, w_t: &[i8], k: usize, m: usize) -> (Vec<u8>, Vec<u8>, usize) {
        let cfg = &self.isa;
        let k_pad = k.div_ceil(cfg.k) * cfg.k;
        let m_pad = m.div_ceil(cfg.m) * cfg.m;
        let mut w = vec![0i8; m_pad * k_pad];
        for j in 0..m {
            w[j * k_pad..j * k_pad + k].copy_from_slice(&w_t[j * k..(j + 1) * k]);
        }
        let enc = encode_indices(&w, m_pad, k_pad, cfg.c);
        (enc.wd, enc.ws, k_pad)
    }

    /// One row's GEMV through the modeled ISA over pre-encoded weights.
    fn run_row(
        &self,
        acts: &[i8],
        wd: &[u8],
        ws: &[u8],
        k_pad: usize,
        m_pad: usize,
        out: &mut [i32],
    ) {
        let cfg = &self.isa;
        let m = out.len();
        let mut a = acts.to_vec();
        a.resize(k_pad, 0);
        let nb_row = k_pad / cfg.c; // encoded blocks per weight row
        let s = cfg.s;

        let mut acc = vec![0i32; m_pad];
        let mut rf = RegFile::new();
        for ks in 0..k_pad / cfg.k {
            // The LUT group register base: AP variants rotate over G
            // groups; the functional result is identical, so base 0 is
            // used (timing differences live in the profile).
            tlut(&mut rf, cfg, 0, &a[ks * cfg.k..(ks + 1) * cfg.k]);
            let blk0 = ks * s; // first encoded block of this k-slice
            for mt in 0..m_pad / cfg.m {
                // Weight operands stream straight from the pre-encoded
                // buffer (row stride = blocks/row), zero copies.
                let start = (mt * cfg.m) * nb_row + blk0;
                let end = (mt * cfg.m + cfg.m - 1) * nb_row + blk0 + s;
                tgemv_slices(
                    &rf,
                    cfg,
                    0,
                    &wd[start..end],
                    &ws[start..end],
                    nb_row,
                    &mut acc[mt * cfg.m..(mt + 1) * cfg.m],
                );
            }
        }
        out.copy_from_slice(&acc[..m]);
    }
}

impl TernaryKernel for TsarKernel {
    fn name(&self) -> String {
        format!("T-SAR/{}/{}", self.isa.name(), self.dataflow.name())
    }

    fn run(&self, acts: &[i8], w_t: &[i8], shape: GemmShape) -> Vec<i32> {
        let GemmShape { n, k, m } = shape;
        assert_eq!(acts.len(), n * k);
        assert_eq!(w_t.len(), m * k);
        let (wd, ws, k_pad) = self.encode_weights(w_t, k, m);
        let m_pad = m.div_ceil(self.isa.m) * self.isa.m;
        let mut out = vec![0i32; n * m];
        for i in 0..n {
            self.run_row(
                &acts[i * k..(i + 1) * k],
                &wd,
                &ws,
                k_pad,
                m_pad,
                &mut out[i * m..(i + 1) * m],
            );
        }
        out
    }

    fn profile(&self, shape: GemmShape, plat: &Platform, threads: usize) -> KernelProfile {
        let cfg = &self.isa;
        let (nf, kf, mf) = (shape.n as f64, shape.k as f64, shape.m as f64);
        let k_slices = (kf / cfg.k as f64).ceil();
        let m_tiles = (mf / cfg.m as f64).ceil();

        let mut streams = quant_dequant_streams(shape);
        let mut simd_uops = quant_dequant_uops(shape);

        // Encoded weights: 2 bits/weight (1+1 split), streamed from DRAM
        // once (cold) and re-read per row group with tile-level reuse.
        let wbytes = kf * mf / 4.0;
        streams.push(Stream::read_once("weights-cold", wbytes));

        // Quantized activations.
        let abytes = nf * kf;

        match self.dataflow {
            Dataflow::ApMin | Dataflow::ApMax => {
                let g = self.lut_groups() as f64;
                // For GEMM, the G register-resident LUT groups hold G
                // *rows'* LUTs for one k-slice: a weight operand loaded
                // once feeds G TGEMVs — register-level weight reuse (the
                // paper's "increasing weight cache hits").  For GEMV the
                // G groups extend the K-chunk instead.
                let (rows_resident, chunk_inputs) = if shape.is_gemv() {
                    (1.0, g * cfg.k as f64)
                } else {
                    (g.min(nf), cfg.k as f64)
                };
                let chunks = (kf / chunk_inputs).ceil();
                // TLUT once per (row, k-slice): minimal recomputation.
                simd_uops += nf * k_slices * tlut_uops(cfg) as f64;
                simd_uops += nf * k_slices * m_tiles * tgemv_uops(cfg) as f64;

                // Weight requests: once per row *group* after the cold
                // pass (register reuse divides the request volume by G).
                let group_passes = (nf / rows_resident).ceil();
                if group_passes > 1.0 {
                    let slice = chunk_inputs * mf / 4.0;
                    streams.push(Stream {
                        name: "weights-tile",
                        footprint: slice.min(wbytes),
                        bytes_accessed: (group_passes - 1.0) * wbytes,
                        passes: (group_passes - 1.0) * chunks.max(1.0),
                        write_frac: 0.0,
                        dependent: false,
                    });
                }
                streams.push(Stream::read_once("acts", abytes));
                // Partial sums spill between chunks (the AP cost): one
                // read+write of the panel per chunk boundary.  Spills are
                // 16-bit (the datapath's native accumulator width;
                // widening happens once at the end).
                if chunks > 1.0 {
                    let panel = nf * mf * 2.0;
                    streams.push(Stream {
                        name: "partials",
                        footprint: panel,
                        bytes_accessed: 2.0 * (chunks - 1.0) * panel,
                        passes: 2.0 * (chunks - 1.0),
                        write_frac: 0.5,
                        dependent: false,
                    });
                }
            }
            Dataflow::Op => {
                let m_acc = self.m_acc() as f64;
                let acc_tiles = (mf / m_acc).ceil();
                // LUTs rebuilt per (row, acc tile, k-slice).
                simd_uops += nf * acc_tiles * k_slices * tlut_uops(cfg) as f64;
                simd_uops += nf * k_slices * m_tiles * tgemv_uops(cfg) as f64;

                // m-outer / n-inner: the K×m_acc weight slice stays
                // resident across the N rows.
                if nf > 1.0 {
                    let slice = kf * m_acc / 4.0;
                    streams.push(Stream {
                        name: "weights-tile",
                        footprint: slice.min(wbytes),
                        bytes_accessed: (nf - 1.0) * wbytes,
                        passes: (nf - 1.0) * acc_tiles.max(1.0),
                        write_frac: 0.0,
                        dependent: false,
                    });
                }
                // Activations swept once per accumulator tile.
                streams.push(Stream {
                    name: "acts",
                    footprint: abytes,
                    bytes_accessed: abytes * acc_tiles,
                    passes: acc_tiles,
                    write_frac: 0.0,
                    dependent: false,
                });
                // No partial-sum traffic: that is OP's point.
            }
        }

        // Outputs written once (int32 accumulator panel).
        streams.push(Stream::write_once("out", nf * mf * 4.0));

        let _ = (plat, threads); // blocking is register-file driven for T-SAR
        KernelProfile {
            kernel: self.name(),
            shape,
            streams,
            simd_uops,
            scalar_uops: simd_uops * 0.25,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::scalar_gemm;
    use crate::util::rng::Rng;

    #[test]
    fn functional_matches_scalar_all_variants() {
        let mut rng = Rng::new(21);
        let shape = GemmShape::new(3, 72, 40);
        let acts = rng.int8_acts(shape.n * shape.k);
        let w = rng.ternary_matrix(shape.m, shape.k, 0.33);
        let want = scalar_gemm(&acts, &w, shape);
        for isa in [IsaConfig::C2, IsaConfig::C4] {
            for df in [Dataflow::ApMin, Dataflow::ApMax, Dataflow::Op] {
                let kern = TsarKernel::new(isa, df);
                assert_eq!(kern.run(&acts, &w, shape), want, "{}", kern.name());
            }
        }
    }

    #[test]
    fn unpadded_shapes() {
        // K and M not multiples of (k, m): padding must not change results.
        let mut rng = Rng::new(22);
        let shape = GemmShape::new(1, 37, 19);
        let acts = rng.int8_acts(shape.k);
        let w = rng.ternary_matrix(shape.m, shape.k, 0.5);
        let want = scalar_gemm(&acts, &w, shape);
        let kern = TsarKernel::new(IsaConfig::C2, Dataflow::Op);
        assert_eq!(kern.run(&acts, &w, shape), want);
    }

    #[test]
    fn register_budgets() {
        let ap_max_c2 = TsarKernel::new(IsaConfig::C2, Dataflow::ApMax);
        assert_eq!(ap_max_c2.lut_groups(), 6); // (16-4)/2
        let ap_min = TsarKernel::new(IsaConfig::C2, Dataflow::ApMin);
        assert_eq!(ap_min.lut_groups(), 1);
        let op_c2 = TsarKernel::new(IsaConfig::C2, Dataflow::Op);
        assert_eq!(op_c2.m_acc(), 96); // (16-2-2)*8 = 96 outputs
        let op_c4 = TsarKernel::new(IsaConfig::C4, Dataflow::Op);
        assert_eq!(op_c4.m_acc(), 48); // (16-2-8)*8 = 48
    }

    #[test]
    fn no_lut_streams_in_profile() {
        // The whole point: T-SAR's profile must contain NO memory stream
        // for LUTs — they live in registers.
        let plat = Platform::workstation();
        let kern = TsarKernel::new(IsaConfig::C2, Dataflow::Op);
        let p = kern.profile(GemmShape::new(1, 2560, 6912), &plat, 1);
        assert!(p.streams.iter().all(|s| !s.name.contains("lut")));
        assert_eq!(p.request_bytes_matching("lut"), 0.0);
    }

    #[test]
    fn ap_min_has_more_partial_traffic_than_ap_max() {
        let plat = Platform::workstation();
        let shape = GemmShape::new(1, 4096, 4096);
        let pmin = TsarKernel::new(IsaConfig::C2, Dataflow::ApMin)
            .profile(shape, &plat, 1);
        let pmax = TsarKernel::new(IsaConfig::C2, Dataflow::ApMax)
            .profile(shape, &plat, 1);
        let part = |p: &KernelProfile| {
            p.stream("partials").map(|s| s.bytes_accessed).unwrap_or(0.0)
        };
        assert!(part(&pmin) > 4.0 * part(&pmax));
    }

    #[test]
    fn op_trades_tlut_uops_for_no_partials() {
        let plat = Platform::workstation();
        let shape = GemmShape::new(1, 4096, 8192);
        let ap = TsarKernel::new(IsaConfig::C2, Dataflow::ApMin).profile(shape, &plat, 1);
        let op = TsarKernel::new(IsaConfig::C2, Dataflow::Op).profile(shape, &plat, 1);
        assert!(op.stream("partials").is_none());
        assert!(ap.stream("partials").is_some());
        assert!(op.simd_uops > ap.simd_uops, "OP rebuilds LUTs");
    }
}
